"""Layer-2 JAX compute graphs for cp-select.

Composes the Layer-1 kernels into the exact computations the rust
coordinator executes per probe. Each public builder returns a function of
concrete example shapes ready for ``jax.jit(...).lower(...)`` in ``aot.py``.

Flavors:
- ``pallas`` — the TPU-shaped Pallas kernels (interpret-lowered for the CPU
  substrate). Default for the hot kernels.
- ``jnp``    — the pure-jnp oracle, which XLA fuses aggressively; used as the
  L1/L2 performance ablation and for auxiliary kernels.

Everything here runs at build time only (``make artifacts``); nothing in this
package is imported by the runtime.
"""

import functools

import jax.numpy as jnp

from . import kernels
from .kernels import ref

_FLAVORS = ("pallas", "jnp")


def _impl(flavor: str, name: str):
    if flavor not in _FLAVORS:
        raise ValueError(f"unknown flavor {flavor!r}, expected one of {_FLAVORS}")
    mod = kernels if flavor == "pallas" else ref
    return getattr(mod, name)


# --- probe graphs (one device round-trip per cutting-plane iteration) -----


def objective_probe(flavor: str = "pallas"):
    """(x, y, n_valid) -> (s_lo, s_hi, c_lt, c_eq, c_gt).

    One cutting-plane / bisection / Brent iteration = one execution of this
    graph. The host composes f and the subgradient interval for any k.
    """
    fn = _impl(flavor, "fused_objective")

    def probe(x, y, n_valid):
        return fn(x, y, n_valid)

    return probe


def ladder_probe(flavor: str = "pallas"):
    """(x, ys, n_valid) -> per-rung (s_lo, s_hi, c_lt, c_eq, c_gt).

    One multisection pass = one execution of this graph: a whole sorted
    width-p probe ladder is answered by a single binned reduction over x,
    the device analogue of ``HostEvaluator::probe_many``. Emitted per
    ladder-width bucket p ∈ LADDER_WIDTHS (aot.py); the runtime pads short
    ladders to the nearest bucket by repeating the last rung.
    """
    fn = _impl(flavor, "fused_ladder")

    def probe(x, ys, n_valid):
        return fn(x, ys, n_valid)

    return probe


def init_stats(flavor: str = "pallas"):
    """(x, n_valid) -> (min, max, sum): Algorithm 1 step 0 in one reduction."""
    fn = _impl(flavor, "minmaxsum")

    def init(x, n_valid):
        return fn(x, n_valid)

    return init


def neighbors_probe(flavor: str = "pallas"):
    """(x, y, n_valid) -> (lower, upper, c_le): exact-rank fixup."""
    fn = _impl(flavor, "neighbors")

    def probe(x, y, n_valid):
        return fn(x, y, n_valid)

    return probe


def interval_probe(flavor: str = "jnp"):
    """(x, lo, hi, n_valid) -> (c_le, c_in, c_ge): pivot-interval occupancy."""
    fn = _impl(flavor, "interval_count")

    def probe(x, lo, hi, n_valid):
        return fn(x, lo, hi, n_valid)

    return probe


def threshold_probe(flavor: str = "jnp"):
    """(r, t, n_valid) -> (ssq_below, c_lt, c_eq): LTS rho-trick."""
    fn = _impl(flavor, "threshold_stats")

    def probe(r, t, n_valid):
        return fn(r, t, n_valid)

    return probe


# --- application graphs ----------------------------------------------------


def residuals_graph(flavor: str = "pallas"):
    """(X, y, theta) -> |X @ theta - y| kept on device."""
    fn = _impl(flavor, "residuals")

    def graph(X, y, theta):
        return (fn(X, y, theta),)

    return graph


def lms_probe(flavor: str = "pallas"):
    """(X, y, theta, t, n_valid) -> objective stats of |X@theta - y| at t.

    The fully fused regression probe: residuals are recomputed on-device and
    reduced against the probe ``t`` in a single HLO module, so evaluating the
    LMS criterion for a candidate theta never materializes residuals on the
    host (DESIGN.md §6.3).
    """
    res = _impl(flavor, "residuals")
    obj = _impl(flavor, "fused_objective")

    def probe(X, y, theta, t, n_valid):
        r = res(X, y, theta)
        return obj(r, t, n_valid)

    return probe


def dists_graph(flavor: str = "pallas"):
    """(X, q) -> squared distances, kept on device for OS_k selection."""
    fn = _impl(flavor, "dists")

    def graph(X, q):
        return (fn(X, q),)

    return graph


def knn_sum_graph(flavor: str = "jnp"):
    """(d, f, t, n_valid) -> (sum_wf, sum_w, count)."""
    fn = _impl(flavor, "knn_weighted_sum")

    def graph(d, f, t, n_valid):
        return fn(d, f, t, n_valid)

    return graph


# --- registry used by aot.py ------------------------------------------------

# name -> (builder, signature builder). Signatures are produced from the
# bucket parameters (n, p, dtype) by aot.py.


def sig_vector_probe(n, dtype):
    """x[n], y[1], n_valid[1]."""
    return [((n,), dtype), ((1,), dtype), ((1,), "int32")]


def sig_vector_only(n, dtype):
    return [((n,), dtype), ((1,), "int32")]


def sig_ladder(n, p, dtype):
    """x[n], ys[p] (sorted probe ladder), n_valid[1]."""
    return [((n,), dtype), ((p,), dtype), ((1,), "int32")]


def sig_interval(n, dtype):
    return [((n,), dtype), ((1,), dtype), ((1,), dtype), ((1,), "int32")]


def sig_residuals(n, p, dtype):
    return [((n, p), dtype), ((n,), dtype), ((p,), dtype)]


def sig_lms(n, p, dtype):
    return [((n, p), dtype), ((n,), dtype), ((p,), dtype), ((1,), dtype),
            ((1,), "int32")]


def sig_dists(n, p, dtype):
    return [((n, p), dtype), ((p,), dtype)]


def sig_knn_sum(n, dtype):
    return [((n,), dtype), ((n,), dtype), ((1,), dtype), ((1,), "int32")]


REGISTRY = {
    # vector probes, emitted per (dtype, n-bucket, flavor)
    "fused_objective": (objective_probe, sig_vector_probe, "vector"),
    # ladder probe, emitted per (dtype, n-bucket, ladder-width p, flavor)
    "fused_ladder": (ladder_probe, sig_ladder, "ladder"),
    "minmaxsum": (init_stats, sig_vector_only, "vector"),
    "neighbors": (neighbors_probe, sig_vector_probe, "vector"),
    "interval_count": (interval_probe, sig_interval, "vector"),
    "threshold_stats": (threshold_probe, sig_vector_probe, "vector"),
    "knn_weighted_sum": (knn_sum_graph, sig_knn_sum, "vector"),
    # matrix graphs, emitted per (dtype, n-bucket, p)
    "residuals": (residuals_graph, sig_residuals, "matrix"),
    "lms_probe": (lms_probe, sig_lms, "matrix"),
    "dists": (dists_graph, sig_dists, "matrix"),
}


def build(name: str, flavor: str):
    builder, sig, kind = REGISTRY[name]
    fn = builder(flavor)

    @functools.wraps(fn)
    def tupled(*args):
        out = fn(*args)
        return tuple(out) if isinstance(out, (tuple, list)) else (out,)

    return tupled, sig, kind


DTYPES = {"float32": jnp.float32, "float64": jnp.float64, "int32": jnp.int32}
