"""AOT pipeline: lower every Layer-2 graph to HLO text + manifest.json.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the runtime's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run once via ``make artifacts``. The rust runtime loads artifacts lazily by
``(kernel, flavor, dtype, n, p)`` key through ``manifest.json``; python never
appears on the request path.

Usage:
    python -m compile.aot --out ../artifacts [--min-log2n 12] [--max-log2n 25]
                          [--report] [--force]
"""

import argparse
import hashlib
import json
import os
import sys
import time

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402

DTYPE_NAMES = {"float32": "f32", "float64": "f64", "int32": "i32"}
JNP_DTYPES = {"f32": jnp.float32, "f64": jnp.float64, "i32": jnp.int32}

# Matrix kernels are emitted for this regression dimension (explanatory
# variables + intercept). The paper's examples are low-dimensional.
DEFAULT_P = 8

# Ladder-width buckets for ``fused_ladder``: the runtime pads a probe
# ladder to the nearest width by repeating the last rung, so a handful of
# buckets covers every pass shape (multisection default is the widest).
LADDER_WIDTHS = (3, 7, 15)

MANIFEST_VERSION = 2


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def hlo_op_report(text: str) -> dict:
    """Crude op histogram of an HLO module — used by --report to verify the
    L2 graphs stay fused (no duplicated passes over x)."""
    ops = {}
    for line in text.splitlines():
        line = line.strip()
        if "=" not in line or line.startswith(("HloModule", "ENTRY", "//", "%")):
            continue
        body = line.split("=", 1)[-1].strip()
        # e.g. "f32[4096]{0} add(f32[4096]{0} ..." -> "add"
        parts = body.split("(", 1)
        if len(parts) == 2:
            head = parts[0].split()
            if head:
                op = head[-1]
                if op.isidentifier():
                    ops[op] = ops.get(op, 0) + 1
    return ops


def spec_args(sig):
    out = []
    for shape, dtype in sig:
        out.append(jax.ShapeDtypeStruct(shape, JNP_DTYPES[DTYPE_NAMES.get(dtype, dtype)]))
    return out


def entry_plan(min_log2n: int, max_log2n: int, p: int,
               small_max_log2n: int, matrix_max_log2n: int,
               pallas_max_log2n: int = 16):
    """Enumerate (kernel, flavor, dtype, n, p) artifact entries.

    The ``jnp`` flavor (XLA-fused single-pass reduce) is the runtime default
    on the CPU substrate. The ``pallas`` flavor — the authored TPU kernel,
    interpret-lowered — is emitted for buckets up to ``pallas_max_log2n``:
    interpret mode exists for correctness and the flavor ablation, not for
    wallclock (DESIGN.md §2, §6.4).
    """
    vec_buckets = [1 << k for k in range(min_log2n, max_log2n + 1)]
    small_buckets = [1 << k for k in range(min_log2n, min(small_max_log2n, max_log2n) + 1)]
    mat_buckets = [1 << k for k in range(min_log2n, min(matrix_max_log2n, max_log2n) + 1)]
    pallas_cap = 1 << pallas_max_log2n
    dtypes = ["f32", "f64"]

    plan = []
    for dt in dtypes:
        for n in vec_buckets:
            plan.append(("fused_objective", "jnp", dt, n, None))
            plan.append(("minmaxsum", "jnp", dt, n, None))
            plan.append(("neighbors", "jnp", dt, n, None))
            plan.append(("interval_count", "jnp", dt, n, None))
            for w in LADDER_WIDTHS:
                plan.append(("fused_ladder", "jnp", dt, n, w))
            if n <= pallas_cap:
                plan.append(("fused_objective", "pallas", dt, n, None))
                plan.append(("minmaxsum", "pallas", dt, n, None))
                plan.append(("neighbors", "pallas", dt, n, None))
                for w in LADDER_WIDTHS:
                    plan.append(("fused_ladder", "pallas", dt, n, w))
        for n in small_buckets:
            plan.append(("threshold_stats", "jnp", dt, n, None))
            plan.append(("knn_weighted_sum", "jnp", dt, n, None))
        for n in mat_buckets:
            plan.append(("residuals", "jnp", dt, n, p))
            plan.append(("lms_probe", "jnp", dt, n, p))
            plan.append(("dists", "jnp", dt, n, p))
            if n <= pallas_cap:
                plan.append(("residuals", "pallas", dt, n, p))
                plan.append(("lms_probe", "pallas", dt, n, p))
                plan.append(("dists", "pallas", dt, n, p))
    return plan


def build_signature(kernel, dtype, n, p):
    _, sig_builder, kind = model.REGISTRY[kernel]
    if kind in ("matrix", "ladder"):
        return sig_builder(n, p, dtype)
    return sig_builder(n, dtype)


def artifact_filename(kernel, flavor, dtype, n, p):
    stem = f"{kernel}.{flavor}.{dtype}.n{n}"
    if p is not None:
        stem += f".p{p}"
    return stem + ".hlo.txt"


def lower_entry(kernel, flavor, dtype, n, p):
    fn, _, _ = model.build(kernel, flavor)
    sig = build_signature(kernel, dtype, n, p)
    args = spec_args(sig)
    lowered = jax.jit(fn).lower(*args)
    return to_hlo_text(lowered), sig


def output_spec(kernel, dtype, n, p):
    """Abstract-eval the graph to record output shapes/dtypes in the manifest."""
    fn, _, _ = model.build(kernel, "jnp")
    sig = build_signature(kernel, dtype, n, p)
    out = jax.eval_shape(fn, *spec_args(sig))
    specs = []
    for o in out:
        name = DTYPE_NAMES.get(o.dtype.name, o.dtype.name)
        specs.append({"dtype": name, "shape": list(o.shape)})
    return specs


def plan_digest(plan) -> str:
    h = hashlib.sha256()
    for e in plan:
        h.update(repr(e).encode())
    # Key source files participate in the digest so edits retrigger builds.
    here = os.path.dirname(os.path.abspath(__file__))
    for rel in ("model.py", "kernels/reductions.py", "kernels/regression.py",
                "kernels/ref.py", "aot.py"):
        with open(os.path.join(here, rel), "rb") as f:
            h.update(f.read())
    return h.hexdigest()


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--min-log2n", type=int, default=12)
    ap.add_argument("--max-log2n", type=int, default=25)
    ap.add_argument("--small-max-log2n", type=int, default=21,
                    help="cap for threshold_stats / knn_weighted_sum buckets")
    ap.add_argument("--matrix-max-log2n", type=int, default=20,
                    help="cap for residuals / lms_probe / dists buckets")
    ap.add_argument("--pallas-max-log2n", type=int, default=16,
                    help="largest bucket also emitted in the pallas flavor")
    ap.add_argument("--p", type=int, default=DEFAULT_P)
    ap.add_argument("--report", action="store_true",
                    help="print an HLO op histogram per artifact")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    out_dir = os.path.abspath(args.out)
    os.makedirs(out_dir, exist_ok=True)
    manifest_path = os.path.join(out_dir, "manifest.json")

    plan = entry_plan(args.min_log2n, args.max_log2n, args.p,
                      args.small_max_log2n, args.matrix_max_log2n,
                      args.pallas_max_log2n)
    digest = plan_digest(plan)

    if not args.force and os.path.exists(manifest_path):
        try:
            with open(manifest_path) as f:
                old = json.load(f)
            if old.get("digest") == digest and all(
                os.path.exists(os.path.join(out_dir, e["path"]))
                for e in old.get("entries", [])
            ):
                print(f"artifacts up to date ({len(old['entries'])} entries), "
                      f"nothing to do")
                return 0
        except (json.JSONDecodeError, KeyError, OSError):
            pass  # rebuild on any manifest damage

    entries = []
    t0 = time.time()
    for i, (kernel, flavor, dtype, n, p) in enumerate(plan):
        fname = artifact_filename(kernel, flavor, dtype, n, p)
        path = os.path.join(out_dir, fname)
        text, sig = lower_entry(kernel, flavor, dtype, n, p)
        with open(path, "w") as f:
            f.write(text)
        inputs = [{"dtype": DTYPE_NAMES.get(dt, dt), "shape": list(shape)}
                  for shape, dt in sig]
        entries.append({
            "kernel": kernel,
            "flavor": flavor,
            "dtype": dtype,
            "n": n,
            "p": p,
            "path": fname,
            "inputs": inputs,
            "outputs": output_spec(kernel, dtype, n, p),
        })
        if args.report:
            ops = hlo_op_report(text)
            interesting = {k: v for k, v in sorted(ops.items())
                           if k in ("add", "multiply", "subtract", "compare",
                                    "select", "reduce", "while", "fusion",
                                    "dynamic-slice", "dot", "convert")}
            print(f"{fname}: {interesting}")
        if (i + 1) % 25 == 0:
            print(f"  lowered {i + 1}/{len(plan)} "
                  f"({time.time() - t0:.1f}s)", file=sys.stderr)

    manifest = {
        "version": MANIFEST_VERSION,
        "digest": digest,
        "default_p": args.p,
        "min_log2n": args.min_log2n,
        "max_log2n": args.max_log2n,
        "entries": entries,
    }
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(entries)} artifacts + manifest to {out_dir} "
          f"in {time.time() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
