"""Pure-jnp oracle for every Layer-1 kernel.

These are the ground-truth implementations the Pallas kernels are tested
against (pytest + hypothesis), and also the ``jnp`` artifact flavor that the
rust runtime executes by default on the CPU substrate (DESIGN.md §6.4): a
single **variadic** ``lax.reduce`` makes each probe one fused pass over x,
which is the practical roofline of this backend (measured 11x faster than
the naive five-reduction formulation; see EXPERIMENTS.md §Perf/L2).

Each function has exactly the same signature and padding/masking semantics
as its Pallas twin in ``reductions.py`` / ``regression.py``.
"""

import jax
import jax.numpy as jnp


def _mask(x, n_valid):
    idx = jax.lax.iota(jnp.int32, x.shape[0])
    return idx < jnp.asarray(n_valid, jnp.int32).reshape(())


def _reduce1(operands, inits, combiners):
    """Variadic single-pass reduction; returns shape-(1,) arrays."""
    def comp(a, b):
        return tuple(c(u, v) for c, u, v in zip(combiners, a, b))
    out = jax.lax.reduce(tuple(operands), tuple(inits), comp, (0,))
    return tuple(o.reshape((1,)) for o in out)


def fused_objective(x, y, n_valid):
    y = jnp.asarray(y, x.dtype).reshape(())
    valid = _mask(x, n_valid)
    d = x - y
    lt = valid & (d < 0)
    gt = valid & (d > 0)
    eq = valid & (d == 0)
    zero = jnp.zeros((), x.dtype)
    add = jnp.add
    return _reduce1(
        (jnp.where(lt, -d, zero), jnp.where(gt, d, zero),
         lt.astype(jnp.int32), eq.astype(jnp.int32), gt.astype(jnp.int32)),
        (zero, zero, jnp.int32(0), jnp.int32(0), jnp.int32(0)),
        (add, add, add, add, add),
    )


def fused_ladder(x, ys, n_valid):
    """Per-rung ``fused_objective`` stats for a sorted width-p ladder.

    One variadic reduction over the rung axis: the ``(p, n)`` compare plane
    is XLA-fused into a single pass over ``x`` (p compares per element —
    the probes-per-pass trade the multisection method is built on).
    Outputs are each shape ``(p,)``, positionally aligned with ``ys``.
    """
    ys = jnp.asarray(ys, x.dtype)
    valid = _mask(x, n_valid)[None, :]
    d = x[None, :] - ys[:, None]
    lt = valid & (d < 0)
    gt = valid & (d > 0)
    eq = valid & (d == 0)
    zero = jnp.zeros((), x.dtype)
    add = jnp.add

    def comp(a, b):
        return tuple(add(u, v) for u, v in zip(a, b))

    return jax.lax.reduce(
        (jnp.where(lt, -d, zero), jnp.where(gt, d, zero),
         lt.astype(jnp.int32), eq.astype(jnp.int32), gt.astype(jnp.int32)),
        (zero, zero, jnp.int32(0), jnp.int32(0), jnp.int32(0)),
        comp, (1,),
    )


def minmaxsum(x, n_valid):
    valid = _mask(x, n_valid)
    dt = x.dtype
    pinf = jnp.array(jnp.inf, dt)
    ninf = jnp.array(-jnp.inf, dt)
    zero = jnp.zeros((), dt)
    return _reduce1(
        (jnp.where(valid, x, pinf), jnp.where(valid, x, ninf),
         jnp.where(valid, x, zero)),
        (pinf, ninf, zero),
        (jnp.minimum, jnp.maximum, jnp.add),
    )


def neighbors(x, y, n_valid):
    y = jnp.asarray(y, x.dtype).reshape(())
    valid = _mask(x, n_valid)
    dt = x.dtype
    pinf = jnp.array(jnp.inf, dt)
    ninf = jnp.array(-jnp.inf, dt)
    le = valid & (x <= y)
    ge = valid & (x >= y)
    return _reduce1(
        (jnp.where(le, x, ninf), jnp.where(ge, x, pinf),
         le.astype(jnp.int32)),
        (ninf, pinf, jnp.int32(0)),
        (jnp.maximum, jnp.minimum, jnp.add),
    )


def interval_count(x, lo, hi, n_valid):
    lo = jnp.asarray(lo, x.dtype).reshape(())
    hi = jnp.asarray(hi, x.dtype).reshape(())
    valid = _mask(x, n_valid)
    le = valid & (x <= lo)
    inside = valid & (x > lo) & (x < hi)
    ge = valid & (x >= hi)
    add = jnp.add
    return _reduce1(
        (le.astype(jnp.int32), inside.astype(jnp.int32),
         ge.astype(jnp.int32)),
        (jnp.int32(0), jnp.int32(0), jnp.int32(0)),
        (add, add, add),
    )


def threshold_stats(r, t, n_valid):
    t = jnp.asarray(t, r.dtype).reshape(())
    valid = _mask(r, n_valid)
    zero = jnp.zeros((), r.dtype)
    lt = valid & (r < t)
    eq = valid & (r == t)
    add = jnp.add
    return _reduce1(
        (jnp.where(lt, r * r, zero), lt.astype(jnp.int32),
         eq.astype(jnp.int32)),
        (zero, jnp.int32(0), jnp.int32(0)),
        (add, add, add),
    )


def residuals(X, y, theta):
    return jnp.abs(X @ theta - y)


def dists(X, q):
    diff = X - q[None, :]
    return jnp.sum(diff * diff, axis=1)


def knn_weighted_sum(d, f, t, n_valid):
    t = jnp.asarray(t, d.dtype).reshape(())
    valid = _mask(d, n_valid)
    dt = d.dtype
    zero = jnp.zeros((), dt)
    one = jnp.ones((), dt)
    keep = valid & (d <= t)
    w = jnp.where(keep, one / (one + d), zero)
    add = jnp.add
    return _reduce1(
        (w * jnp.where(keep, f, zero), w, keep.astype(jnp.int32)),
        (zero, zero, jnp.int32(0)),
        (add, add, add),
    )
