"""Fused transform-reduce Pallas kernels (Layer 1).

The paper's single device primitive is a fused transform-reduce over the
device-resident array ``x`` against a scalar probe ``y`` (Fig. 1 in the
paper, implemented there with ``thrust::transform_reduce``). Here each
kernel is a Pallas grid over VMEM-sized blocks of ``x``; per-block partial
reductions run on the VPU and are accumulated across sequential grid steps
into scalar output refs (the TPU analogue of the paper's shared-memory
partial sums + final combine).

Padding convention: arrays are padded up to the artifact's bucket size; a
scalar ``n_valid`` masks the tail via a global-index comparison, so the pad
value itself is never observed.

All ``pallas_call``s use ``interpret=True``: the CPU PJRT plugin cannot run
Mosaic custom-calls, so the kernels lower to plain HLO (see DESIGN.md
"Hardware adaptation").
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# One VMEM block: 64Ki f32 = 256 KiB (f64: 512 KiB), far below the ~16 MiB
# VMEM budget so a real TPU lowering could double-buffer HBM->VMEM streams.
DEFAULT_BLOCK = 65536


def _block_for(n: int, block: int | None = None) -> int:
    b = block or DEFAULT_BLOCK
    b = min(b, n)
    if n % b != 0:
        raise ValueError(f"n={n} must be a multiple of the block size {b}")
    return b


def _scalar_spec():
    # Scalar operands/outputs travel as shape-(1,) arrays pinned to block 0
    # for every grid step (the accumulator trick relies on this).
    return pl.BlockSpec((1,), lambda i: (0,))


def _valid_mask(pid, block, n_valid):
    idx = pid * block + jax.lax.iota(jnp.int32, block)
    return idx < n_valid


# ---------------------------------------------------------------------------
# fused_objective
# ---------------------------------------------------------------------------


def _fused_objective_kernel(x_ref, y_ref, nv_ref, slo_ref, shi_ref, clt_ref,
                            ceq_ref, cgt_ref, *, block):
    pid = pl.program_id(0)
    x = x_ref[...]
    y = y_ref[0]
    valid = _valid_mask(pid, block, nv_ref[0])

    d = x - y
    lt = valid & (d < 0)
    gt = valid & (d > 0)
    eq = valid & (d == 0)

    # Branchless selects: the paper notes Eq. (2) introduces "only minimal
    # branching"; on the VPU these are lane-wise selects, no divergence.
    zero = jnp.zeros((), dtype=x.dtype)
    slo = jnp.sum(jnp.where(lt, -d, zero))
    shi = jnp.sum(jnp.where(gt, d, zero))
    clt = jnp.sum(lt, dtype=jnp.int32)
    ceq = jnp.sum(eq, dtype=jnp.int32)
    cgt = jnp.sum(gt, dtype=jnp.int32)

    @pl.when(pid == 0)
    def _init():
        slo_ref[0] = zero
        shi_ref[0] = zero
        clt_ref[0] = jnp.zeros((), jnp.int32)
        ceq_ref[0] = jnp.zeros((), jnp.int32)
        cgt_ref[0] = jnp.zeros((), jnp.int32)

    slo_ref[0] = slo_ref[0] + slo
    shi_ref[0] = shi_ref[0] + shi
    clt_ref[0] = clt_ref[0] + clt
    ceq_ref[0] = ceq_ref[0] + ceq
    cgt_ref[0] = cgt_ref[0] + cgt


def fused_objective(x, y, n_valid, *, block=None):
    """Sufficient statistics of the convex selection objective at probe y.

    Returns ``(s_lo, s_hi, c_lt, c_eq, c_gt)`` where

    - ``s_lo = sum_{x_i < y} (y - x_i)``  (counted over valid entries only)
    - ``s_hi = sum_{x_i > y} (x_i - y)``
    - ``c_lt/c_eq/c_gt``: counts of valid ``x_i`` <,==,> ``y`` (int32).

    The host composes, for any order statistic k (Eqs. 1-2 of the paper):
    ``f(y) = (k - 1/2) * s_lo + (n - k + 1/2) * s_hi`` and the subgradient
    interval from the counts. For the median both weights are n/2-ish and
    ``f = s_lo + s_hi``.
    """
    n = x.shape[0]
    block = _block_for(n, block)
    dt = x.dtype
    y = jnp.asarray(y, dt).reshape((1,))
    n_valid = jnp.asarray(n_valid, jnp.int32).reshape((1,))
    kernel = functools.partial(_fused_objective_kernel, block=block)
    out = pl.pallas_call(
        kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            _scalar_spec(),
            _scalar_spec(),
        ],
        out_specs=[_scalar_spec()] * 5,
        out_shape=[
            jax.ShapeDtypeStruct((1,), dt),
            jax.ShapeDtypeStruct((1,), dt),
            jax.ShapeDtypeStruct((1,), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        interpret=True,
    )(x, y, n_valid)
    return tuple(out)


# ---------------------------------------------------------------------------
# fused_ladder
# ---------------------------------------------------------------------------


def compose_ladder(ys, cnt, bsum, eq):
    """Recover per-rung sufficient statistics from ladder bin partials.

    ``cnt``/``bsum`` hold, per bin ``j``, the count/sum of valid elements in
    ``(y_{j-1}, y_j]`` against the sorted ladder ``ys`` (bin ``p`` is the
    overflow above the top rung); ``eq`` holds per-rung equality counts.
    Mirrors ``HostEvaluator``'s ``compose_ladder``: the high side uses
    **suffix** sums so each side's rounding error scales only with its own
    mass, and empty sides are pinned to exactly zero (also avoids inf·0 for
    infinite rungs). O(p) epilogue arithmetic — not a second data pass.
    """
    dt = bsum.dtype
    c_le = jnp.cumsum(cnt, dtype=jnp.int32)[:-1]
    sum_le = jnp.cumsum(bsum)[:-1]
    c_gt = jnp.cumsum(cnt[::-1], dtype=jnp.int32)[::-1][1:]
    s_gt = jnp.cumsum(bsum[::-1])[::-1][1:]
    c_lt = c_le - eq
    zero = jnp.zeros((), dt)
    sum_lt = jnp.where(eq > 0, sum_le - ys * eq.astype(dt), sum_le)
    s_lo = jnp.where(
        c_lt > 0, jnp.maximum(ys * c_lt.astype(dt) - sum_lt, zero), zero
    )
    s_hi = jnp.where(
        c_gt > 0, jnp.maximum(s_gt - ys * c_gt.astype(dt), zero), zero
    )
    return s_lo, s_hi, c_lt, eq, c_gt


def _fused_ladder_kernel(x_ref, ys_ref, nv_ref, cnt_ref, sum_ref, eq_ref, *,
                         block, p):
    pid = pl.program_id(0)
    x = x_ref[...]
    ys = ys_ref[...]
    valid = _valid_mask(pid, block, nv_ref[0])
    dt = x.dtype
    zero = jnp.zeros((), dt)

    # Binned sweep (Tibshirani 2008's successive binning): each element's
    # bin is the count of rungs strictly below it, so elements equal to a
    # rung land in that rung's own bin. One compare ladder per element,
    # branchless on the VPU.
    b = jnp.sum((ys[:, None] < x[None, :]).astype(jnp.int32), axis=0,
                dtype=jnp.int32)
    oh = (b[None, :] == jax.lax.iota(jnp.int32, p + 1)[:, None]) & valid[None, :]
    bcnt = jnp.sum(oh, axis=1, dtype=jnp.int32)
    bsum = jnp.sum(jnp.where(oh, x[None, :], zero), axis=1)
    beq = jnp.sum((x[None, :] == ys[:, None]) & valid[None, :], axis=1,
                  dtype=jnp.int32)

    @pl.when(pid == 0)
    def _init():
        cnt_ref[...] = jnp.zeros((p + 1,), jnp.int32)
        sum_ref[...] = jnp.zeros((p + 1,), dt)
        eq_ref[...] = jnp.zeros((p,), jnp.int32)

    cnt_ref[...] = cnt_ref[...] + bcnt
    sum_ref[...] = sum_ref[...] + bsum
    eq_ref[...] = eq_ref[...] + beq


def fused_ladder(x, ys, n_valid, *, block=None):
    """Sufficient statistics at every rung of a sorted probe ladder.

    The multi-probe analogue of ``fused_objective``: one binned sweep over
    ``x`` answers the whole width-``p`` ladder ``ys`` (sorted ascending;
    duplicate rungs allowed — the runtime pads short ladders by repeating
    the last probe). Returns ``(s_lo, s_hi, c_lt, c_eq, c_gt)``, each shape
    ``(p,)``, positionally aligned with ``ys`` — exactly the per-probe
    outputs of ``fused_objective``, recovered from the bin partials by
    prefix/suffix summation over ``p + 1`` scalars.
    """
    n = x.shape[0]
    block = _block_for(n, block)
    p = ys.shape[0]
    dt = x.dtype
    ys = jnp.asarray(ys, dt)
    n_valid = jnp.asarray(n_valid, jnp.int32).reshape((1,))
    kernel = functools.partial(_fused_ladder_kernel, block=block, p=p)
    cnt, bsum, eq = pl.pallas_call(
        kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((p,), lambda i: (0,)),
            _scalar_spec(),
        ],
        out_specs=[
            pl.BlockSpec((p + 1,), lambda i: (0,)),
            pl.BlockSpec((p + 1,), lambda i: (0,)),
            pl.BlockSpec((p,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p + 1,), jnp.int32),
            jax.ShapeDtypeStruct((p + 1,), dt),
            jax.ShapeDtypeStruct((p,), jnp.int32),
        ],
        interpret=True,
    )(x, ys, n_valid)
    return compose_ladder(ys, cnt, bsum, eq)


# ---------------------------------------------------------------------------
# minmaxsum
# ---------------------------------------------------------------------------


def _minmaxsum_kernel(x_ref, nv_ref, min_ref, max_ref, sum_ref, *, block):
    pid = pl.program_id(0)
    x = x_ref[...]
    valid = _valid_mask(pid, block, nv_ref[0])
    dt = x.dtype
    pinf = jnp.array(jnp.inf, dt)
    ninf = jnp.array(-jnp.inf, dt)
    zero = jnp.zeros((), dt)

    bmin = jnp.min(jnp.where(valid, x, pinf))
    bmax = jnp.max(jnp.where(valid, x, ninf))
    bsum = jnp.sum(jnp.where(valid, x, zero))

    @pl.when(pid == 0)
    def _init():
        min_ref[0] = pinf
        max_ref[0] = ninf
        sum_ref[0] = zero

    min_ref[0] = jnp.minimum(min_ref[0], bmin)
    max_ref[0] = jnp.maximum(max_ref[0], bmax)
    sum_ref[0] = sum_ref[0] + bsum


def minmaxsum(x, n_valid, *, block=None):
    """Single-pass ``(min, max, sum)`` — seeds the cutting plane (paper §IV).

    The paper stresses that ``y_L = x_(1)``, ``y_R = x_(n)`` and ``sum(x)``
    come out of *one* reduction (then ``f`` and ``g`` at the ends are closed
    form: ``g(y_L) = -n + 2``, ``f(y_L) = sum(x) - n*y_L``, ...), so Algorithm
    1 costs ``maxit + 1`` reductions total.
    """
    n = x.shape[0]
    block = _block_for(n, block)
    dt = x.dtype
    n_valid = jnp.asarray(n_valid, jnp.int32).reshape((1,))
    kernel = functools.partial(_minmaxsum_kernel, block=block)
    out = pl.pallas_call(
        kernel,
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,)), _scalar_spec()],
        out_specs=[_scalar_spec()] * 3,
        out_shape=[jax.ShapeDtypeStruct((1,), dt)] * 3,
        interpret=True,
    )(x, n_valid)
    return tuple(out)


# ---------------------------------------------------------------------------
# neighbors
# ---------------------------------------------------------------------------


def _neighbors_kernel(x_ref, y_ref, nv_ref, lo_ref, hi_ref, cle_ref, *, block):
    pid = pl.program_id(0)
    x = x_ref[...]
    y = y_ref[0]
    valid = _valid_mask(pid, block, nv_ref[0])
    dt = x.dtype
    pinf = jnp.array(jnp.inf, dt)
    ninf = jnp.array(-jnp.inf, dt)

    le = valid & (x <= y)
    ge = valid & (x >= y)
    blo = jnp.max(jnp.where(le, x, ninf))      # largest x_i <= y
    bhi = jnp.min(jnp.where(ge, x, pinf))      # smallest x_i >= y
    bcle = jnp.sum(le, dtype=jnp.int32)

    @pl.when(pid == 0)
    def _init():
        lo_ref[0] = ninf
        hi_ref[0] = pinf
        cle_ref[0] = jnp.zeros((), jnp.int32)

    lo_ref[0] = jnp.maximum(lo_ref[0], blo)
    hi_ref[0] = jnp.minimum(hi_ref[0], bhi)
    cle_ref[0] = cle_ref[0] + bcle


def neighbors(x, y, n_valid, *, block=None):
    """Exact-value fixup reduction (paper footnote 1).

    Returns ``(lower, upper, c_le)``: the largest valid ``x_i <= y`` (−inf if
    none), the smallest valid ``x_i >= y`` (+inf if none), and
    ``count(x_i <= y)``. Once the cutting plane converges to an approximate
    minimizer ỹ, one such reduction pins the *exact* order statistic and lets
    the host verify its rank.
    """
    n = x.shape[0]
    block = _block_for(n, block)
    dt = x.dtype
    y = jnp.asarray(y, dt).reshape((1,))
    n_valid = jnp.asarray(n_valid, jnp.int32).reshape((1,))
    kernel = functools.partial(_neighbors_kernel, block=block)
    out = pl.pallas_call(
        kernel,
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,)), _scalar_spec(),
                  _scalar_spec()],
        out_specs=[_scalar_spec()] * 3,
        out_shape=[
            jax.ShapeDtypeStruct((1,), dt),
            jax.ShapeDtypeStruct((1,), dt),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        interpret=True,
    )(x, y, n_valid)
    return tuple(out)


# ---------------------------------------------------------------------------
# interval_count
# ---------------------------------------------------------------------------


def _interval_count_kernel(x_ref, lo_ref_in, hi_ref_in, nv_ref, cle_ref,
                           cin_ref, cge_ref, *, block):
    pid = pl.program_id(0)
    x = x_ref[...]
    lo = lo_ref_in[0]
    hi = hi_ref_in[0]
    valid = _valid_mask(pid, block, nv_ref[0])

    le = valid & (x <= lo)
    inside = valid & (x > lo) & (x < hi)
    ge = valid & (x >= hi)
    ble = jnp.sum(le, dtype=jnp.int32)
    bin_ = jnp.sum(inside, dtype=jnp.int32)
    bge = jnp.sum(ge, dtype=jnp.int32)

    @pl.when(pid == 0)
    def _init():
        cle_ref[0] = jnp.zeros((), jnp.int32)
        cin_ref[0] = jnp.zeros((), jnp.int32)
        cge_ref[0] = jnp.zeros((), jnp.int32)

    cle_ref[0] = cle_ref[0] + ble
    cin_ref[0] = cin_ref[0] + bin_
    cge_ref[0] = cge_ref[0] + bge


def interval_count(x, lo, hi, n_valid, *, block=None):
    """Occupancy of the open pivot interval ``]lo, hi[`` (hybrid method §IV).

    Returns int32 ``(c_le, c_in, c_ge)`` = counts of valid ``x_i <= lo``,
    ``lo < x_i < hi`` and ``x_i >= hi``. ``c_le`` is the paper's ``m`` (rank
    offset into the compacted array z); ``c_in`` is ``|z|``, used to decide
    when CP iterations stop paying for themselves.
    """
    n = x.shape[0]
    block = _block_for(n, block)
    dt = x.dtype
    lo = jnp.asarray(lo, dt).reshape((1,))
    hi = jnp.asarray(hi, dt).reshape((1,))
    n_valid = jnp.asarray(n_valid, jnp.int32).reshape((1,))
    kernel = functools.partial(_interval_count_kernel, block=block)
    out = pl.pallas_call(
        kernel,
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,)), _scalar_spec(),
                  _scalar_spec(), _scalar_spec()],
        out_specs=[_scalar_spec()] * 3,
        out_shape=[jax.ShapeDtypeStruct((1,), jnp.int32)] * 3,
        interpret=True,
    )(x, lo, hi, n_valid)
    return tuple(out)


# ---------------------------------------------------------------------------
# threshold_stats (LTS rho-trick, paper §VI Eq. 4)
# ---------------------------------------------------------------------------


def _threshold_stats_kernel(r_ref, t_ref, nv_ref, ssq_ref, clt_ref, ceq_ref,
                            *, block):
    pid = pl.program_id(0)
    r = r_ref[...]
    t = t_ref[0]
    valid = _valid_mask(pid, block, nv_ref[0])
    dt = r.dtype
    zero = jnp.zeros((), dt)

    lt = valid & (r < t)
    eq = valid & (r == t)
    bssq = jnp.sum(jnp.where(lt, r * r, zero))
    bclt = jnp.sum(lt, dtype=jnp.int32)
    bceq = jnp.sum(eq, dtype=jnp.int32)

    @pl.when(pid == 0)
    def _init():
        ssq_ref[0] = zero
        clt_ref[0] = jnp.zeros((), jnp.int32)
        ceq_ref[0] = jnp.zeros((), jnp.int32)

    ssq_ref[0] = ssq_ref[0] + bssq
    clt_ref[0] = clt_ref[0] + bclt
    ceq_ref[0] = ceq_ref[0] + bceq


def threshold_stats(r, t, n_valid, *, block=None):
    """LTS trimmed-sum statistics (paper Eq. 4).

    Returns ``(ssq_below, c_lt, c_eq)``: the sum of ``r_i**2`` over valid
    ``r_i < t``, and the counts of ``r_i < t`` / ``r_i == t``. With
    ``t = Med(|r|)`` the host forms the exact sum of the ``h`` smallest
    squared residuals as ``ssq_below + a * t**2`` with ``a = h - c_lt``,
    replacing the partial sort the LTS definition appears to require.
    """
    n = r.shape[0]
    block = _block_for(n, block)
    dt = r.dtype
    t = jnp.asarray(t, dt).reshape((1,))
    n_valid = jnp.asarray(n_valid, jnp.int32).reshape((1,))
    kernel = functools.partial(_threshold_stats_kernel, block=block)
    out = pl.pallas_call(
        kernel,
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,)), _scalar_spec(),
                  _scalar_spec()],
        out_specs=[_scalar_spec()] * 3,
        out_shape=[
            jax.ShapeDtypeStruct((1,), dt),
            jax.ShapeDtypeStruct((1,), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        interpret=True,
    )(r, t, n_valid)
    return tuple(out)
