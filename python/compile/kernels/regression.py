"""Regression / kNN Pallas kernels (Layer 1).

These feed the paper's two applications (Section VI): high-breakdown robust
regression (LMS/LTS need ``|X @ theta - y|`` recomputed for every candidate
``theta``) and kNN (squared distances to a query point). Both keep the bulk
data device-resident; only scalars (probes, medians, predictions) cross to
the host, which is the paper's multi-GPU argument in miniature.

The matvec tiles are shaped for the MXU model: a ``(block, p)`` VMEM tile of
``X`` against a ``(p,)`` replicated ``theta`` (p is small — regression
dimension), with the row-block grid streaming HBM->VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .reductions import _scalar_spec, _valid_mask

DEFAULT_ROW_BLOCK = 8192


def _row_block_for(n: int, block: int | None = None) -> int:
    b = block or DEFAULT_ROW_BLOCK
    b = min(b, n)
    if n % b != 0:
        raise ValueError(f"n={n} must be a multiple of the row block {b}")
    return b


# ---------------------------------------------------------------------------
# residuals: r = |X @ theta - y|
# ---------------------------------------------------------------------------


def _residuals_kernel(x_ref, y_ref, theta_ref, r_ref):
    x = x_ref[...]            # (block, p) VMEM tile
    theta = theta_ref[...]    # (p,) replicated across the grid
    y = y_ref[...]            # (block,)
    # MXU-shaped contraction; p is tiny so this is effectively a fused
    # multiply-add across lanes, but the same BlockSpec scales to larger p.
    pred = jax.lax.dot_general(
        x, theta, (((1,), (0,)), ((), ())),
        preferred_element_type=x.dtype,
    )
    r_ref[...] = jnp.abs(pred - y)


def residuals(X, y, theta, *, block=None):
    """Absolute residuals ``|X @ theta - y|`` (paper §VI, Eq. 3).

    Output stays on-device: it is the input of ``fused_objective`` (median of
    residuals for LMS) or ``threshold_stats`` (LTS trimmed sum). Padding rows
    of ``X``/``y`` are zeros, producing ``r = 0`` pads that downstream
    kernels mask out via their own ``n_valid``.
    """
    n, p = X.shape
    block = _row_block_for(n, block)
    out = pl.pallas_call(
        _residuals_kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block, p), lambda i: (i, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((p,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), X.dtype),
        interpret=True,
    )(X, y, theta)
    return out


# ---------------------------------------------------------------------------
# dists: squared Euclidean distances to a query
# ---------------------------------------------------------------------------


def _dists_kernel(x_ref, q_ref, d_ref):
    x = x_ref[...]        # (block, p)
    q = q_ref[...]        # (p,)
    diff = x - q[None, :]
    d_ref[...] = jnp.sum(diff * diff, axis=1)


def dists(X, q, *, block=None):
    """Squared Euclidean distances ``d_i = ||X_i - q||^2`` (paper §VI, kNN).

    The k-th order statistic of ``d`` (found by the cutting plane on the
    host) then acts as the neighbourhood threshold for ``knn_weighted_sum``.
    """
    n, p = X.shape
    block = _row_block_for(n, block)
    out = pl.pallas_call(
        _dists_kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block, p), lambda i: (i, 0)),
            pl.BlockSpec((p,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), X.dtype),
        interpret=True,
    )(X, q)
    return out


# ---------------------------------------------------------------------------
# knn_weighted_sum: thresholded inverse-distance-weighted reduction
# ---------------------------------------------------------------------------


def _knn_sum_kernel(d_ref, f_ref, t_ref, nv_ref, swf_ref, sw_ref, cnt_ref,
                    *, block):
    pid = pl.program_id(0)
    d = d_ref[...]
    f = f_ref[...]
    t = t_ref[0]
    valid = _valid_mask(pid, block, nv_ref[0])
    dt = d.dtype
    zero = jnp.zeros((), dt)
    one = jnp.ones((), dt)

    # Indicator adapted from the paper's rho (Eq. 4): keep d_i <= d_(k).
    keep = valid & (d <= t)
    w = jnp.where(keep, one / (one + d), zero)  # decreasing in distance
    bswf = jnp.sum(w * jnp.where(keep, f, zero))
    bsw = jnp.sum(w)
    bcnt = jnp.sum(keep, dtype=jnp.int32)

    @pl.when(pid == 0)
    def _init():
        swf_ref[0] = zero
        sw_ref[0] = zero
        cnt_ref[0] = jnp.zeros((), jnp.int32)

    swf_ref[0] = swf_ref[0] + bswf
    sw_ref[0] = sw_ref[0] + bsw
    cnt_ref[0] = cnt_ref[0] + bcnt


def knn_weighted_sum(d, f, t, n_valid, *, block=None):
    """Weighted kNN prediction pieces (paper §VI).

    Returns ``(sum_wf, sum_w, count)`` over valid points with ``d_i <= t``
    where ``w_i = 1 / (1 + d_i)``. The host forms the kNN regression
    prediction ``sum_wf / sum_w``; ``count`` verifies that ``t`` really was
    the k-th order statistic of ``d``.
    """
    n = d.shape[0]
    block = _row_block_for(n, block)
    dt = d.dtype
    t = jnp.asarray(t, dt).reshape((1,))
    n_valid = jnp.asarray(n_valid, jnp.int32).reshape((1,))
    kernel = functools.partial(_knn_sum_kernel, block=block)
    out = pl.pallas_call(
        kernel,
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                  pl.BlockSpec((block,), lambda i: (i,)),
                  _scalar_spec(), _scalar_spec()],
        out_specs=[_scalar_spec()] * 3,
        out_shape=[
            jax.ShapeDtypeStruct((1,), dt),
            jax.ShapeDtypeStruct((1,), dt),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        interpret=True,
    )(d, f, t, n_valid)
    return tuple(out)
