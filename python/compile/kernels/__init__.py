"""Layer-1 Pallas kernels for cp-select.

Every kernel here is the TPU-shaped (Pallas) implementation of one device
primitive the paper needs (Beliakov 2011, GPU median via convex minimization):

- ``fused_objective`` — the paper's ``thrust::transform_reduce`` computing the
  sufficient statistics of the convex objective f(y) = sum |x_i - y| and its
  subgradient in a single pass (Fig. 1 of the paper).
- ``fused_ladder``    — the multi-probe generalization: one binned sweep
  answers a whole sorted width-p probe ladder (per-rung ``fused_objective``
  stats recovered by prefix/suffix summation of the bin partials), so one
  multisection pass costs one device reduction.
- ``minmaxsum``       — the single fused reduction that seeds Kelley's cutting
  plane with y_L = x_(1), y_R = x_(n) and sum(x) (Section IV).
- ``neighbors``       — exact-median fixup: largest x_i <= y, smallest
  x_i >= y, and rank counts (footnote 1 of the paper).
- ``interval_count``  — pivot-interval occupancy for the hybrid method.
- ``threshold_stats`` — LTS rho-trick reduction (Section VI, Eq. 4).
- ``residuals``       — |X @ theta - y| for the regression application.
- ``dists``           — squared distances for the kNN application.
- ``knn_weighted_sum``— weighted kNN prediction as a thresholded reduction.

All kernels are lowered with ``interpret=True`` (CPU-PJRT substrate; a real
TPU lowering would produce Mosaic custom-calls). Correctness oracle:
``kernels/ref.py``; pytest compares them under hypothesis sweeps.
"""

from . import ref  # noqa: F401
from .reductions import (  # noqa: F401
    fused_ladder,
    fused_objective,
    minmaxsum,
    neighbors,
    interval_count,
    threshold_stats,
)
from .regression import residuals, dists, knn_weighted_sum  # noqa: F401

__all__ = [
    "fused_ladder",
    "fused_objective",
    "minmaxsum",
    "neighbors",
    "interval_count",
    "threshold_stats",
    "residuals",
    "dists",
    "knn_weighted_sum",
    "ref",
]
