"""fused_ladder (L1 + L2): binned multi-probe sweep vs per-probe oracle.

The ladder kernel must agree rung-by-rung with sequential
``fused_objective`` probes — including duplicate rungs (how the runtime
pads short ladders), rungs equal to data values, and out-of-range rungs.
"""

import numpy as np
import pytest
import jax.numpy as jnp

import compile.kernels as K
from compile.kernels import ref
from compile import aot, model

DTYPES = [np.float32, np.float64]


def _rtol(dtype):
    return 5e-4 if dtype == np.float32 else 1e-9


def _ladders(x, nv):
    v = np.sort(x[:nv])
    lo, hi = float(v[0]), float(v[-1])
    return [
        np.linspace(lo, hi, 7),                      # evenly spaced, in range
        np.array([lo - 1e3, lo, float(np.median(v)), hi, hi + 1e3]),
        np.array([float(v[3])] * 4 + [float(v[5])]),  # duplicate-heavy (pad style)
        np.array([float(np.median(v))]),              # width 1
    ]


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("flavor", ["pallas", "jnp"])
@pytest.mark.parametrize("dist", ["normal", "constant", "duplicates"])
def test_fused_ladder_matches_sequential_probes(dtype, flavor, dist):
    n, nv = 2048, 2000
    rng = np.random.default_rng(hash((dtype.__name__, dist)) % 2**32)
    if dist == "normal":
        x = rng.normal(0, 1, n)
    elif dist == "constant":
        x = np.full(n, 2.5)
    else:
        x = rng.integers(0, 9, n).astype(np.float64)
    x = x.astype(dtype)
    fn = K.fused_ladder if flavor == "pallas" else ref.fused_ladder
    obj = K.fused_objective if flavor == "pallas" else ref.fused_objective
    for ys in _ladders(x, nv):
        ys = np.sort(ys).astype(dtype)
        got = fn(jnp.asarray(x), jnp.asarray(ys), nv)
        assert all(np.asarray(g).shape == (len(ys),) for g in got)
        for j, y in enumerate(ys):
            want = obj(jnp.asarray(x), float(y), nv)
            for gi, wi in zip(got, want):
                g = np.asarray(gi)[j]
                w = np.asarray(wi)[0]
                if np.issubdtype(np.asarray(gi).dtype, np.integer):
                    assert g == w, f"rung {j} y={y}: {g} vs {w}"
                else:
                    np.testing.assert_allclose(
                        g, w, rtol=_rtol(dtype), atol=10 * _rtol(dtype),
                        err_msg=f"rung {j} y={y}",
                    )


@pytest.mark.parametrize("dtype", DTYPES)
def test_fused_ladder_flavors_agree(dtype):
    n, nv = 4096, 4000
    rng = np.random.default_rng(17)
    x = rng.normal(0, 1, n).astype(dtype)
    ys = np.sort(rng.normal(0, 1, 15)).astype(dtype)
    got = K.fused_ladder(jnp.asarray(x), jnp.asarray(ys), nv,
                         block=min(n, 1024))
    want = ref.fused_ladder(jnp.asarray(x), jnp.asarray(ys), nv)
    for g, w in zip(got, want):
        g, w = np.asarray(g), np.asarray(w)
        assert g.dtype == w.dtype
        if np.issubdtype(g.dtype, np.integer):
            np.testing.assert_array_equal(g, w)
        else:
            np.testing.assert_allclose(g, w, rtol=10 * _rtol(dtype),
                                       atol=10 * _rtol(dtype))


def test_fused_ladder_count_partition():
    """Every valid element lands in exactly one of lt/eq/gt per rung."""
    n, nv = 512, 500
    rng = np.random.default_rng(3)
    x = rng.normal(0, 1, n)
    ys = np.sort(rng.normal(0, 1, 7))
    s_lo, s_hi, c_lt, c_eq, c_gt = (
        np.asarray(o) for o in K.fused_ladder(jnp.asarray(x), jnp.asarray(ys), nv)
    )
    assert (c_lt + c_eq + c_gt == nv).all()
    # rank monotonicity along the sorted ladder
    c_le = c_lt + c_eq
    assert (np.diff(c_le) >= 0).all()
    assert (s_lo >= 0).all() and (s_hi >= 0).all()


def test_fused_ladder_lowers_and_plan_covers_widths():
    text, sig = aot.lower_entry("fused_ladder", "jnp", "f64", 128, 7)
    assert text.startswith("HloModule")
    assert [s[0] for s in sig] == [(128,), (7,), (1,)]
    ops = aot.hlo_op_report(text)
    assert ops.get("sort", 0) == 0, ops
    specs = aot.output_spec("fused_ladder", "f64", 128, 7)
    assert [tuple(s["shape"]) for s in specs] == [(7,)] * 5
    assert [s["dtype"] for s in specs] == ["f64", "f64", "i32", "i32", "i32"]

    plan = aot.entry_plan(12, 13, 8, 12, 12, pallas_max_log2n=12)
    widths = {e[4] for e in plan if e[0] == "fused_ladder" and e[1] == "jnp"}
    assert widths == set(aot.LADDER_WIDTHS)
    pal = {(e[3], e[4]) for e in plan
           if e[0] == "fused_ladder" and e[1] == "pallas"}
    assert pal == {(1 << 12, w) for w in aot.LADDER_WIDTHS}
    assert model.REGISTRY["fused_ladder"][2] == "ladder"
