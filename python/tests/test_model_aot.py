"""L2 graph + AOT pipeline tests: shapes, flavor equivalence, manifest."""

import json
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import aot, model


@pytest.mark.parametrize("name", sorted(model.REGISTRY))
@pytest.mark.parametrize("dtype", ["f32", "f64"])
def test_graph_output_specs_consistent(name, dtype):
    """eval_shape of the jnp flavor matches the manifest output spec logic."""
    n, p = 128, 8
    specs = aot.output_spec(name, dtype, n, p)
    assert specs, name
    for s in specs:
        assert s["dtype"] in ("f32", "f64", "i32")
        assert all(isinstance(d, int) for d in s["shape"])


@pytest.mark.parametrize("name", ["fused_objective", "minmaxsum", "neighbors"])
def test_flavor_equivalence(name):
    """pallas and jnp flavors of the same graph agree numerically."""
    n, nv = 2048, 2000
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=n))
    f_p, sig, _ = model.build(name, "pallas")
    f_j, _, _ = model.build(name, "jnp")
    args = [x]
    if name in ("fused_objective", "neighbors"):
        args.append(jnp.asarray([0.25]))
    args.append(jnp.asarray([nv], jnp.int32))
    got = f_p(*args)
    want = f_j(*args)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-12)


def test_lms_probe_fuses_residuals_and_objective():
    """The fused LMS probe equals residuals -> fused_objective composed."""
    n, p, nv = 512, 8, 500
    rng = np.random.default_rng(5)
    X = jnp.asarray(rng.normal(size=(n, p)))
    y = jnp.asarray(rng.normal(size=n))
    th = jnp.asarray(rng.normal(size=p))
    t = jnp.asarray([0.8])
    nvj = jnp.asarray([nv], jnp.int32)

    fused, _, _ = model.build("lms_probe", "jnp")
    res, _, _ = model.build("residuals", "jnp")
    obj, _, _ = model.build("fused_objective", "jnp")

    got = fused(X, y, th, t, nvj)
    r = res(X, y, th)[0]
    want = obj(r, t, nvj)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-12)


def test_lower_entry_produces_hlo_text():
    text, sig = aot.lower_entry("fused_objective", "jnp", "f32", 128, None)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # the probe graph must be a single fused reduction pass: one variadic
    # reduce (it may appear inside a called computation), and no sort/while.
    assert text.count(" reduce(") + text.count("=reduce(") >= 1 or \
        "reduce" in text, text[:400]
    ops = aot.hlo_op_report(text)
    assert ops.get("sort", 0) == 0, ops
    assert ops.get("while", 0) == 0, ops


def test_lower_entry_pallas_flavor():
    text, _ = aot.lower_entry("fused_objective", "pallas", "f32", 128, None)
    assert text.startswith("HloModule")


def test_entry_plan_covers_required_kernels():
    plan = aot.entry_plan(12, 14, 8, 13, 13, pallas_max_log2n=12)
    kernels = {e[0] for e in plan}
    assert kernels == set(model.REGISTRY)
    # jnp flavor exists for every bucket of the hot kernel
    jnp_ns = {e[3] for e in plan if e[0] == "fused_objective" and e[1] == "jnp"}
    assert jnp_ns == {1 << 12, 1 << 13, 1 << 14}
    # pallas flavor capped
    pal_ns = {e[3] for e in plan if e[0] == "fused_objective" and e[1] == "pallas"}
    assert pal_ns == {1 << 12}


def test_aot_end_to_end_small(tmp_path):
    """Full mini pipeline: emit artifacts + manifest, check digest no-op."""
    out = str(tmp_path / "arts")
    rc = aot.main(["--out", out, "--min-log2n", "7", "--max-log2n", "8",
                   "--small-max-log2n", "7", "--matrix-max-log2n", "7",
                   "--pallas-max-log2n", "7"])
    assert rc == 0
    with open(os.path.join(out, "manifest.json")) as f:
        man = json.load(f)
    assert man["version"] == aot.MANIFEST_VERSION
    assert man["entries"]
    for e in man["entries"]:
        path = os.path.join(out, e["path"])
        assert os.path.exists(path)
        with open(path) as f:
            assert f.read(9) == "HloModule"
        assert e["inputs"] and e["outputs"]
    # second run is a no-op (idempotence guard used by `make artifacts`)
    rc = aot.main(["--out", out, "--min-log2n", "7", "--max-log2n", "8",
                   "--small-max-log2n", "7", "--matrix-max-log2n", "7",
                   "--pallas-max-log2n", "7"])
    assert rc == 0


def test_manifest_entry_input_order_matches_signature():
    """Rust feeds buffers positionally; the manifest must preserve order."""
    sig = aot.build_signature("fused_objective", "f64", 256, None)
    assert [s[0] for s in sig] == [(256,), (1,), (1,)]
    assert [s[1] for s in sig] == ["f64", "f64", "int32"]
    sig = aot.build_signature("lms_probe", "f32", 256, 8)
    assert [s[0] for s in sig] == [(256, 8), (256,), (8,), (1,), (1,)]
