"""Regression test for the ``hlo_op_report`` skip-check (bare ``pass`` bug).

Header/comment lines that happen to contain ``=`` used to fall through the
skip-check and pollute the op histogram; they must be skipped entirely.
"""

from compile import aot

CANNED = """\
HloModule jit_probe, entry_computation_layout={(f32[128]{0})->f32[1]{0}}, scheduler=list(x)

region_0.5 {
  Arg_0.6 = f32[] parameter(0)
  Arg_1.7 = f32[] parameter(1)
  ROOT add.8 = f32[] add(Arg_0.6, Arg_1.7)
}

// tuned config = custom(foo)
ENTRY main.12 {
  p0.1 = f32[128]{0} parameter(0)
  c.2 = f32[] constant(0)
  sub.3 = f32[128]{0} subtract(p0.1, p0.1)
  %legacy.4 = f32[128]{0} multiply(sub.3, sub.3)
  ROOT r.9 = f32[1]{0} reduce(sub.3, c.2), dimensions={0}, to_apply=region_0.5
}
"""


def test_header_and_comment_lines_are_skipped():
    ops = aot.hlo_op_report(CANNED)
    # the bug counted "list" from the HloModule header and "custom" from
    # the comment line; both must be absent now
    assert "list" not in ops, ops
    assert "custom" not in ops, ops
    # %-prefixed legacy-style lines are in the skip list too
    assert "multiply" not in ops, ops


def test_instruction_lines_still_counted():
    ops = aot.hlo_op_report(CANNED)
    assert ops.get("add") == 1, ops
    assert ops.get("subtract") == 1, ops
    assert ops.get("reduce") == 1, ops
    assert ops.get("parameter") == 3, ops


def test_report_on_real_lowering_is_nonempty():
    text, _ = aot.lower_entry("minmaxsum", "jnp", "f32", 128, None)
    ops = aot.hlo_op_report(text)
    # the fix must not empty the histogram on real modules
    assert ops, "histogram empty on a real lowering"
    assert ops.get("sort", 0) == 0
