import os
import sys

import jax

# f64 artifacts and tests require x64; set before any kernel import.
jax.config.update("jax_enable_x64", True)

# Make `compile` importable when pytest is invoked from python/ or repo root.
_HERE = os.path.dirname(os.path.abspath(__file__))
_PY_ROOT = os.path.dirname(_HERE)
if _PY_ROOT not in sys.path:
    sys.path.insert(0, _PY_ROOT)
