"""Pallas kernels vs the pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps shapes, dtypes, probe positions and data distributions
(including the paper's adversarial cases: huge outliers, constant arrays,
pre-sorted data, duplicated medians) and asserts exact/allclose agreement
between the interpret-mode Pallas kernels and ``ref.py``.
"""

import numpy as np
import pytest
import jax.numpy as jnp

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st  # noqa: E402

import compile.kernels as K
from compile.kernels import ref

DTYPES = [np.float32, np.float64]
SIZES = [128, 4096, 8192]


def _assert_outputs_close(got, want, rtol):
    assert len(got) == len(want)
    for g, w in zip(got, want):
        g = np.asarray(g)
        w = np.asarray(w)
        assert g.dtype == w.dtype, (g.dtype, w.dtype)
        if np.issubdtype(g.dtype, np.integer):
            np.testing.assert_array_equal(g, w)
        else:
            np.testing.assert_allclose(g, w, rtol=rtol)


def _rtol(dtype):
    # f32 tolerance allows for accumulation-order differences between the
    # blocked pallas reduction and XLA's lax.reduce tree at n ~ 8192 with
    # probe magnitudes up to 1e9 (sums reach ~1e13).
    return 5e-4 if dtype == np.float32 else 1e-11


# ---------------------------------------------------------------------------
# deterministic sweeps
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("dist", ["uniform", "normal", "halfnormal",
                                  "mixture", "constant", "sorted",
                                  "outlier1e9"])
def test_fused_objective_matches_ref(dtype, n, dist):
    rng = np.random.default_rng(hash((n, dist)) % 2**32)
    x = _make(rng, n, dist, dtype)
    nv = n - 7 if n > 16 else n
    for y in [float(np.median(x[:nv])), 0.0, float(x[0]), -1e9, 1e9]:
        got = K.fused_objective(jnp.asarray(x), y, nv, block=min(n, 1024))
        want = ref.fused_objective(jnp.asarray(x), y, nv)
        _assert_outputs_close(got, want, _rtol(dtype))


def _make(rng, n, dist, dtype):
    if dist == "uniform":
        x = rng.uniform(0, 1, n)
    elif dist == "normal":
        x = rng.normal(0, 1, n)
    elif dist == "halfnormal":
        x = np.abs(rng.normal(0, 1, n))
    elif dist == "mixture":
        k = n // 3
        x = np.concatenate([rng.normal(100, 1, k), rng.normal(0, 1, n - k)])
        rng.shuffle(x)
    elif dist == "constant":
        x = np.full(n, 3.25)
    elif dist == "sorted":
        x = np.sort(rng.normal(0, 1, n))
    elif dist == "outlier1e9":
        x = rng.normal(0, 1, n)
        x[rng.integers(0, n)] = 1e9
    else:
        raise AssertionError(dist)
    return x.astype(dtype)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("n", SIZES)
def test_minmaxsum_matches_ref(dtype, n):
    rng = np.random.default_rng(n)
    x = rng.normal(0, 10, n).astype(dtype)
    nv = n - 3
    got = K.minmaxsum(jnp.asarray(x), nv, block=min(n, 1024))
    want = ref.minmaxsum(jnp.asarray(x), nv)
    _assert_outputs_close(got, want, _rtol(dtype))
    # cross-check against numpy directly on the valid prefix
    np.testing.assert_allclose(float(got[0][0]), x[:nv].min(), rtol=_rtol(dtype))
    np.testing.assert_allclose(float(got[1][0]), x[:nv].max(), rtol=_rtol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("n", [256, 4096])
def test_neighbors_matches_ref_and_numpy(dtype, n):
    rng = np.random.default_rng(n + 1)
    x = rng.normal(0, 1, n).astype(dtype)
    nv = n - 5
    for y in [float(np.median(x[:nv])), float(x[3]), -100.0, 100.0]:
        got = K.neighbors(jnp.asarray(x), y, nv, block=min(n, 512))
        want = ref.neighbors(jnp.asarray(x), y, nv)
        _assert_outputs_close(got, want, _rtol(dtype))
        lo, hi, c_le = (np.asarray(v)[0] for v in got)
        v = x[:nv]
        le = v[v <= y]
        ge = v[v >= y]
        assert lo == (le.max() if le.size else -np.inf)
        assert hi == (ge.min() if ge.size else np.inf)
        assert c_le == le.size


@pytest.mark.parametrize("dtype", DTYPES)
def test_interval_count_matches_ref(dtype):
    n = 4096
    rng = np.random.default_rng(7)
    x = rng.normal(0, 1, n).astype(dtype)
    nv = n - 9
    for lo, hi in [(-0.5, 0.5), (0.0, 0.0), (-10, 10), (2, 1)]:
        got = K.interval_count(jnp.asarray(x), lo, hi, nv, block=512)
        want = ref.interval_count(jnp.asarray(x), lo, hi, nv)
        _assert_outputs_close(got, want, 0)
        c_le, c_in, c_ge = (int(np.asarray(v)[0]) for v in got)
        v = x[:nv]
        assert c_le == int((v <= lo).sum())
        assert c_in == int(((v > lo) & (v < hi)).sum())
        assert c_ge == int((v >= hi).sum())


@pytest.mark.parametrize("dtype", DTYPES)
def test_threshold_stats_matches_ref(dtype):
    n = 4096
    rng = np.random.default_rng(11)
    r = np.abs(rng.normal(0, 1, n)).astype(dtype)
    nv = n - 13
    t = float(np.median(r[:nv]))
    got = K.threshold_stats(jnp.asarray(r), t, nv, block=512)
    want = ref.threshold_stats(jnp.asarray(r), t, nv)
    _assert_outputs_close(got, want, _rtol(dtype))
    v = r[:nv]
    np.testing.assert_allclose(
        float(np.asarray(got[0])[0]),
        float((v[v < t] ** 2).sum()),
        rtol=10 * _rtol(dtype),
    )


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("p", [2, 8])
def test_residuals_matches_ref(dtype, p):
    n = 2048
    rng = np.random.default_rng(p)
    X = rng.normal(size=(n, p)).astype(dtype)
    y = rng.normal(size=n).astype(dtype)
    th = rng.normal(size=p).astype(dtype)
    got = K.residuals(jnp.asarray(X), jnp.asarray(y), jnp.asarray(th),
                      block=256)
    want = ref.residuals(jnp.asarray(X), jnp.asarray(y), jnp.asarray(th))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=10 * _rtol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("p", [2, 8])
def test_dists_matches_ref_and_numpy(dtype, p):
    n = 2048
    rng = np.random.default_rng(p + 100)
    X = rng.normal(size=(n, p)).astype(dtype)
    q = rng.normal(size=p).astype(dtype)
    got = np.asarray(K.dists(jnp.asarray(X), jnp.asarray(q), block=256))
    want = np.asarray(ref.dists(jnp.asarray(X), jnp.asarray(q)))
    np.testing.assert_allclose(got, want, rtol=10 * _rtol(dtype))
    np.testing.assert_allclose(got, ((X - q) ** 2).sum(axis=1),
                               rtol=50 * _rtol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
def test_knn_weighted_sum_matches_ref(dtype):
    n = 2048
    rng = np.random.default_rng(42)
    d = np.abs(rng.normal(0, 1, n)).astype(dtype)
    f = rng.normal(0, 1, n).astype(dtype)
    nv = n - 17
    t = float(np.partition(d[:nv], 32)[32])  # 33rd order statistic
    got = K.knn_weighted_sum(jnp.asarray(d), jnp.asarray(f), t, nv, block=256)
    want = ref.knn_weighted_sum(jnp.asarray(d), jnp.asarray(f), t, nv)
    _assert_outputs_close(got, want, 10 * _rtol(dtype))
    assert int(np.asarray(got[2])[0]) == int((d[:nv] <= t).sum())


# ---------------------------------------------------------------------------
# hypothesis sweeps
# ---------------------------------------------------------------------------

# allow_subnormal=False: XLA CPU flushes denormals to zero, which is an
# accepted substrate behaviour, not a kernel bug.
finite = st.floats(allow_nan=False, allow_infinity=False,
                   allow_subnormal=False,
                   min_value=-1e12, max_value=1e12, width=64)


@settings(max_examples=40, deadline=None)
@given(
    data=st.lists(finite, min_size=1, max_size=300),
    probe=finite,
    dtype=st.sampled_from(DTYPES),
)
def test_fused_objective_hypothesis(data, probe, dtype):
    nv = len(data)
    n = 1
    while n < max(nv, 8):
        n *= 2
    x = np.zeros(n, dtype=dtype)
    x[:nv] = np.asarray(data, dtype=dtype)
    got = K.fused_objective(jnp.asarray(x), probe, nv, block=min(n, 64))
    want = ref.fused_objective(jnp.asarray(x), probe, nv)
    _assert_outputs_close(got, want, 1e-4 if dtype == np.float32 else 1e-9)
    # count invariant: every valid element lands in exactly one bucket
    c = sum(int(np.asarray(got[i])[0]) for i in (2, 3, 4))
    assert c == nv


@settings(max_examples=40, deadline=None)
@given(
    data=st.lists(finite, min_size=1, max_size=300),
    dtype=st.sampled_from(DTYPES),
)
def test_minmaxsum_hypothesis(data, dtype):
    nv = len(data)
    n = 1
    while n < max(nv, 8):
        n *= 2
    x = np.zeros(n, dtype=dtype)
    x[:nv] = np.asarray(data, dtype=dtype)
    got = K.minmaxsum(jnp.asarray(x), nv, block=min(n, 64))
    want = ref.minmaxsum(jnp.asarray(x), nv)
    _assert_outputs_close(got, want, 1e-4 if dtype == np.float32 else 1e-9)
    assert float(np.asarray(got[0])[0]) == x[:nv].min()
    assert float(np.asarray(got[1])[0]) == x[:nv].max()


@settings(max_examples=25, deadline=None)
@given(
    data=st.lists(finite, min_size=2, max_size=200),
    frac=st.floats(min_value=0.0, max_value=1.0),
)
def test_neighbors_brackets_probe(data, frac):
    """lower <= y <= upper always, and ranks are consistent."""
    nv = len(data)
    n = 1
    while n < max(nv, 8):
        n *= 2
    x = np.zeros(n)
    x[:nv] = np.asarray(data)
    v = x[:nv]
    y = float(v.min() + frac * (v.max() - v.min()))
    lo, hi, c_le = (np.asarray(o)[0]
                    for o in K.neighbors(jnp.asarray(x), y, nv,
                                         block=min(n, 64)))
    assert lo <= y <= hi
    assert 0 <= c_le <= nv
    if c_le > 0:
        # lower is the c_le-th smallest element (1-indexed)
        assert lo == np.sort(v)[int(c_le) - 1]


@settings(max_examples=25, deadline=None)
@given(
    data=st.lists(finite, min_size=1, max_size=200),
    lo=finite,
    hi=finite,
)
def test_interval_count_hypothesis(data, lo, hi):
    nv = len(data)
    n = 1
    while n < max(nv, 8):
        n *= 2
    x = np.zeros(n)
    x[:nv] = np.asarray(data)
    got = K.interval_count(jnp.asarray(x), lo, hi, nv, block=min(n, 64))
    want = ref.interval_count(jnp.asarray(x), lo, hi, nv)
    _assert_outputs_close(got, want, 0)
    v = x[:nv]
    c_le, c_in, c_ge = (int(np.asarray(o)[0]) for o in got)
    assert c_le == int((v <= lo).sum())
    assert c_in == int(((v > lo) & (v < hi)).sum())
    # partition invariant when lo < hi
    if lo < hi:
        assert c_le + c_in + c_ge == nv


@settings(max_examples=25, deadline=None)
@given(
    data=st.lists(st.floats(min_value=0, max_value=1e6, allow_nan=False,
                            allow_subnormal=False), min_size=1, max_size=200),
    t=st.floats(min_value=0, max_value=1e6, allow_nan=False),
)
def test_threshold_stats_hypothesis(data, t):
    nv = len(data)
    n = 1
    while n < max(nv, 8):
        n *= 2
    r = np.zeros(n)
    r[:nv] = np.asarray(data)
    got = K.threshold_stats(jnp.asarray(r), t, nv, block=min(n, 64))
    want = ref.threshold_stats(jnp.asarray(r), t, nv)
    _assert_outputs_close(got, want, 1e-9)
    v = r[:nv]
    np.testing.assert_allclose(
        float(np.asarray(got[0])[0]), float((v[v < t] ** 2).sum()), rtol=1e-9
    )


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=120),
    p=st.integers(min_value=1, max_value=12),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_residuals_hypothesis_shapes(n, p, seed):
    rng = np.random.default_rng(seed)
    # pad rows to a pallas-friendly multiple
    nn = max(8, 1 << (n - 1).bit_length())
    X = np.zeros((nn, p))
    X[:n] = rng.normal(size=(n, p))
    y = np.zeros(nn)
    y[:n] = rng.normal(size=n)
    th = rng.normal(size=p)
    got = np.asarray(K.residuals(jnp.asarray(X), jnp.asarray(y), jnp.asarray(th),
                                 block=min(nn, 32)))
    want = np.abs(X @ th - y)
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-12)
