//! Order statistics beyond the median (paper Eq. 2): quantile ladders,
//! trimmed ranges, and the outlier-guarded path, through the selection
//! service.

use cp_select::coordinator::{HostBackend, KSpec, SelectionService};
use cp_select::select::cutting_plane::CpOptions;
use cp_select::select::transform::{needs_transform, select_transformed};
use cp_select::select::{DType, Method};
use cp_select::stats::{Distribution, Rng};

fn main() -> cp_select::Result<()> {
    let mut rng = Rng::seeded(99);
    let n = 1 << 18;

    // --- a quantile ladder served concurrently --------------------------
    let svc = SelectionService::start(2, 128, Method::CuttingPlane, HostBackend::factory())?;
    let data = Distribution::Beta25.sample_vec(&mut rng, n);
    let id = svc.upload(data, DType::F64)?;
    println!("quantile ladder over Beta(2,5), n=2^18 (service, 2 workers):");
    let mut rxs = Vec::new();
    for q in [0.01, 0.05, 0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99] {
        rxs.push((q, svc.query_async(id, KSpec::Quantile(q), Method::CuttingPlane)?));
    }
    for (q, rx) in rxs {
        let r = rx.recv().expect("service reply")?;
        println!("  q{:>4.0}%: {:.6}  ({} reductions)", q * 100.0, r.value, r.probes);
    }
    println!("service metrics: {}\n", svc.metrics.snapshot());
    svc.shutdown();

    // --- the §V.D extreme-magnitude guard --------------------------------
    let mut data = Distribution::HalfNormal.sample_vec(&mut rng, 65_535);
    data[0] = 1e20;
    data[1] = 5e20;
    let k = cp_select::util::median_rank(data.len());
    let naive = {
        let mut ev = cp_select::select::HostEvaluator::new(&data);
        cp_select::select::order_statistic(&mut ev, k, Method::CuttingPlane)?.value
    };
    let (guarded, out) = select_transformed(&data, k, &CpOptions::default())?;
    let oracle = cp_select::stats::sorted_median(&data);
    println!("extreme magnitudes (two elements ~1e20), n=65535:");
    println!("  range triggers guard: {}", needs_transform(0.0, 5e20));
    println!("  naive CP median   : {naive:.9}   (f64 absorption risk)");
    println!(
        "  log-guarded median: {guarded:.9}   ({} iterations)  exact={}",
        out.iterations,
        guarded == oracle
    );
    println!("  sort oracle       : {oracle:.9}");
    Ok(())
}
