//! kNN via order statistics (paper §VI, application 2; DESIGN.md E11).
//!
//! Device path: distances computed by the AOT `dists` artifact (L1 Pallas
//! kernel), the k-th order statistic found by the cutting plane over the
//! device-resident distance vector, and the prediction read from one
//! `knn_weighted_sum` thresholded reduction — no sort anywhere.

use std::rc::Rc;

use cp_select::knn::KnnModel;
use cp_select::regression::HostSelector;
use cp_select::runtime::{DeviceEvaluator, Kernel, Runtime};
use cp_select::select::{self, DType, Method};
use cp_select::stats::Rng;

fn device_knn_predict(
    rt: &Rc<Runtime>,
    x_flat: &[f64],
    f: &[f64],
    q: &[f64],
    n: usize,
    p: usize,
    k: usize,
) -> cp_select::Result<f64> {
    // distances on device
    let bucket = rt.manifest.bucket_for(Kernel::Dists, rt.flavor, DType::F64, n)?;
    let exe = rt.executable(Kernel::Dists, rt.flavor, DType::F64, bucket, Some(p))?;
    let xb = rt.upload_matrix(x_flat, n, p, DType::F64, bucket)?;
    let qb = rt.upload_vector(q, DType::F64, p)?;
    let out = exe.run(&[&xb, &qb])?;
    let mut d = cp_select::runtime::client::literal_vec_f64(&out[0], DType::F64)?;
    d.truncate(n);

    // k-th order statistic of d via cutting plane (device reductions)
    let mut ev = DeviceEvaluator::upload(rt, &d, DType::F64)?;
    let t = select::order_statistic(&mut ev, k, Method::CuttingPlane)?.value;

    // thresholded weighted reduction on device
    let kb = rt
        .manifest
        .bucket_for(Kernel::KnnWeightedSum, rt.flavor, DType::F64, n)?;
    let exe = rt.executable(Kernel::KnnWeightedSum, rt.flavor, DType::F64, kb, None)?;
    let db = rt.upload_vector(&d, DType::F64, kb)?;
    let fb = rt.upload_vector(f, DType::F64, kb)?;
    let tb = rt.upload_scalar(t, DType::F64)?;
    let nv = rt.upload_i32(n as i32)?;
    let out = exe.run(&[&db, &fb, &tb, &nv])?;
    let swf = cp_select::runtime::client::literal_scalar_f64(&out[0], DType::F64)?;
    let sw = cp_select::runtime::client::literal_scalar_f64(&out[1], DType::F64)?;
    Ok(swf / sw)
}

fn main() -> cp_select::Result<()> {
    let n = 4096;
    let p = 8;
    let k = 12;
    let mut rng = Rng::seeded(77);

    // target: f(x) = sum of sin over the first 3 coordinates
    let mut rows = Vec::with_capacity(n);
    let mut f = Vec::with_capacity(n);
    for _ in 0..n {
        let row: Vec<f64> = (0..p).map(|_| rng.range(0.0, 2.0)).collect();
        f.push(row[..3].iter().map(|v| v.sin()).sum::<f64>());
        rows.push(row);
    }
    let model = KnnModel::new(rows.clone(), f.clone())?;
    let mut sel = HostSelector::default();

    let queries: Vec<Vec<f64>> =
        (0..20).map(|_| (0..p).map(|_| rng.range(0.3, 1.7)).collect()).collect();

    // host path
    let t0 = std::time::Instant::now();
    let mut host_err = 0.0;
    for q in &queries {
        let pred = model.predict_regression(q, k, &mut sel)?;
        let truth: f64 = q[..3].iter().map(|v| v.sin()).sum();
        host_err += (pred - truth).abs();
    }
    println!(
        "host kNN   : mean|err| = {:.4} over {} queries ({:.1} ms)",
        host_err / queries.len() as f64,
        queries.len(),
        t0.elapsed().as_secs_f64() * 1e3
    );

    // device path
    let dir = Runtime::default_dir();
    if dir.join("manifest.json").exists() {
        let rt = Runtime::new(&dir)?;
        let x_flat: Vec<f64> = rows.iter().flatten().copied().collect();
        let t0 = std::time::Instant::now();
        let mut dev_err = 0.0;
        let mut agree = 0.0f64;
        for q in &queries {
            let pred = device_knn_predict(&rt, &x_flat, &f, q, n, p, k)?;
            let truth: f64 = q[..3].iter().map(|v| v.sin()).sum();
            dev_err += (pred - truth).abs();
            let host_pred = model.predict_regression(q, k, &mut sel)?;
            agree = agree.max((pred - host_pred).abs());
        }
        println!(
            "device kNN : mean|err| = {:.4} over {} queries ({:.1} ms); \
             max host/device disagreement = {:.2e}",
            dev_err / queries.len() as f64,
            queries.len(),
            t0.elapsed().as_secs_f64() * 1e3,
            agree
        );
    } else {
        println!("device kNN : skipped (run `make artifacts`)");
    }

    // classification demo
    let mut xs = Vec::new();
    let mut labels = Vec::new();
    for _ in 0..500 {
        let c = rng.below(3) as f64;
        let center = c * 4.0;
        xs.push(vec![center + rng.normal() * 0.6, center + rng.normal() * 0.6]);
        labels.push(c);
    }
    let clf = KnnModel::new(xs, labels)?;
    let mut correct = 0;
    for trial in 0..60 {
        let c = (trial % 3) as f64;
        let q = [c * 4.0 + rng.normal() * 0.4, c * 4.0 + rng.normal() * 0.4];
        if clf.predict_class(&q, 9, &mut sel)? == c as i64 {
            correct += 1;
        }
    }
    println!("classification: {correct}/60 correct on 3 gaussian blobs (k=9)");
    Ok(())
}
