//! Multi-device median (paper §V.D; DESIGN.md E12).
//!
//! The array is sharded across several simulated devices; every cutting-
//! plane probe runs as independent per-shard reductions whose five scalar
//! statistics are combined on the host — the communication pattern that
//! makes the minimization approach multi-GPU friendly, versus sorting
//! which must move bulk data between devices.
//!
//! With artifacts present each shard is a real PJRT buffer; otherwise the
//! shards are host evaluators (identical math).

use cp_select::device::{shard_data, ShardedEvaluator, TransferModel};
use cp_select::runtime::{DeviceEvaluator, Runtime};
use cp_select::select::{self, DType, Evaluator, HostEvaluator, Method};
use cp_select::stats::{sorted_median, Distribution, Rng};

fn main() -> cp_select::Result<()> {
    let n = 1 << 20;
    let mut rng = Rng::seeded(31);
    let data = Distribution::Mixture4.sample_vec(&mut rng, n);
    let oracle = sorted_median(&data);
    let dir = Runtime::default_dir();
    let device = dir.join("manifest.json").exists();
    let rt = if device { Some(Runtime::new(&dir)?) } else { None };

    println!("median of n=2^20 across simulated device groups (oracle {oracle:.6}):\n");
    println!("shards |   value    | probes | group ms | sort-baseline est. interconnect");
    println!("-------+------------+--------+----------+---------------------------------");

    for shards in [1usize, 2, 4, 8] {
        let t0 = std::time::Instant::now();
        let (value, probes) = if let Some(rt) = &rt {
            let evs = shard_data(&data, shards)
                .into_iter()
                .map(|s| DeviceEvaluator::upload(rt, s, DType::F64))
                .collect::<cp_select::Result<Vec<_>>>()?;
            let mut group = ShardedEvaluator::new(evs)?;
            let r = select::median(&mut group, Method::CuttingPlane)?;
            (r.value, r.probes)
        } else {
            let evs = shard_data(&data, shards)
                .into_iter()
                .map(HostEvaluator::new)
                .collect::<Vec<_>>();
            let mut group = ShardedEvaluator::new(evs)?;
            let r = select::median(&mut group, Method::CuttingPlane)?;
            (r.value, r.probes)
        };
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        assert_eq!(value, oracle, "sharded result must be exact");

        // What a sort-based approach would pay just to move the data once
        // across the paper's PCIe (per §V.D, sorting requires inter-device
        // traffic of bulk data; CP moves probes * shards * 5 scalars).
        let pcie = TransferModel::paper_pcie();
        let sort_traffic_ms = pcie.cost(n, 8).as_secs_f64() * 1e3;
        let cp_traffic_bytes = probes as usize * shards * 5 * 8;
        println!(
            "{shards:>6} | {value:>10.6} | {probes:>6} | {ms:>8.2} | sort moves ~{:.0} ms of data; CP moves {} bytes",
            sort_traffic_ms, cp_traffic_bytes
        );
    }

    println!(
        "\nbackend: {}",
        if device { "PJRT device shards" } else { "host shards (run `make artifacts`)" }
    );
    println!("note: identical result for every shard count — the combine is exact.");
    Ok(())
}
