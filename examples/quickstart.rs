//! Quickstart: compute a median on the device runtime in a dozen lines.
//!
//! ```bash
//! make artifacts                       # once: AOT-lower the kernels
//! cargo run --release --example quickstart
//! ```
//!
//! Falls back to the host oracle when artifacts are missing, so the example
//! always runs.

use cp_select::runtime::{DeviceEvaluator, Runtime};
use cp_select::select::{self, Evaluator, HostEvaluator, Method};
use cp_select::stats::{Distribution, Rng};

fn main() -> cp_select::Result<()> {
    // 1) get some data (pretend it was produced on the device, as in the
    //    paper's regression workload)
    let mut rng = Rng::seeded(7);
    let data = Distribution::HalfNormal.sample_vec(&mut rng, 1 << 20);

    // 2) build an evaluator: device-backed if artifacts exist
    let dir = Runtime::default_dir();
    let mut ev: Box<dyn Evaluator> = if dir.join("manifest.json").exists() {
        let rt = Runtime::new(&dir)?;
        println!("backend: PJRT {} (artifacts: {})", rt.platform(), dir.display());
        Box::new(DeviceEvaluator::upload(&rt, &data, select::DType::F64)?)
    } else {
        println!("backend: host oracle (run `make artifacts` for the device path)");
        Box::new(HostEvaluator::new(&data))
    };

    // 3) median by the paper's hybrid method (cutting plane + copy_if +
    //    radix sort of the surviving pivot interval)
    let t0 = std::time::Instant::now();
    let r = select::median(ev.as_mut(), Method::Hybrid)?;
    println!(
        "median of {} samples = {:.6} ({} device reductions, {:.2} ms)",
        data.len(),
        r.value,
        r.probes,
        t0.elapsed().as_secs_f64() * 1e3
    );

    // 4) arbitrary order statistics / quantiles through the same evaluator
    for q in [0.01, 0.25, 0.75, 0.99] {
        let k = ((q * data.len() as f64).ceil() as usize).clamp(1, data.len());
        let r = select::order_statistic(ev.as_mut(), k, Method::CuttingPlane)?;
        println!("q{:>4}: x_({k}) = {:.6}", (q * 100.0) as u32, r.value);
    }
    Ok(())
}
