//! Full §V evaluation in one run: regenerates the paper's tables/figures
//! at a reduced but meaningful scale and prints the headline comparison
//! (hybrid cutting plane vs radix-sort baseline). `make bench` / the
//! criterion-style benches in `rust/benches/` run the bigger sweeps; this
//! example is the one-command smoke of the whole evaluation, recorded in
//! EXPERIMENTS.md.

use cp_select::harness::{self, report, Backend, Runner, TableConfig};
use cp_select::runtime::Runtime;
use cp_select::select::DType;

fn main() -> cp_select::Result<()> {
    // Substrate choice: host by default — its reduction:sort cost balance
    // matches the paper's GPU (EXPERIMENTS.md "substrate calibration");
    // set CP_EVAL_BACKEND=device to run over the PJRT artifacts instead.
    let dir = Runtime::default_dir();
    let want_device = std::env::var("CP_EVAL_BACKEND").as_deref() == Ok("device");
    let device = want_device && dir.join("manifest.json").exists();
    let backend = if device {
        Backend::Device { artifacts_dir: dir, flavor: cp_select::runtime::Flavor::Jnp }
    } else {
        Backend::Host
    };
    let mut runner = Runner::new(backend)?;
    println!(
        "full evaluation on {} backend\n",
        if device { "PJRT device" } else { "host" }
    );

    // Tables I & II (reduced sweep) + Fig 2/3 CSVs
    for dtype in [DType::F32, DType::F64] {
        let cfg = TableConfig {
            dtype,
            log2_sizes: vec![13, 15, 17, 19],
            instances: 2,
            reps: 2,
            ..Default::default()
        };
        let table = harness::run_table(&mut runner, &cfg)?;
        println!("{}", report::table_markdown(&table));
        let stem = format!("example_table_{}", dtype.name());
        report::write_result(std::path::Path::new("results"), &format!("{stem}.csv"),
                             &report::table_csv(&table))?;

        // headline: hybrid vs sort at the largest size of this sweep
        let sort_row = table.rows.iter().find(|r| r.label.contains("Radix")).unwrap();
        let hyb_row = table.rows.iter().find(|r| r.label.contains("Cutting Plane")).unwrap();
        if let (Some(s), Some(h)) = (sort_row.ms.last().copied().flatten(),
                                     hyb_row.ms.last().copied().flatten()) {
            println!(
                "headline ({}, n=2^19): sort {:.2} ms vs hybrid {:.2} ms -> {:.2}x\n",
                dtype.name(),
                s,
                h,
                s / h
            );
        }
    }

    // Fig 4 trace
    let trace = harness::trace_fig4(4096, 42)?;
    report::write_result(
        std::path::Path::new("results"),
        "example_fig4_trace.csv",
        &report::trace_csv(&trace),
    )?;
    println!("fig 4: cutting plane converged in {} iterations (trace written)",
             trace.last().map(|t| t.iter).unwrap_or(0));

    // Fig 5 sweep
    let pts = harness::outlier_sweep_fig5(&mut runner, 1 << 15, &[1e3, 1e7, 1e11], DType::F64, 7)?;
    report::write_result(
        std::path::Path::new("results"),
        "example_fig5.csv",
        &report::outlier_csv(&pts),
    )?;
    println!("\nfig 5 (outlier sensitivity, probes per magnitude):");
    for m in ["cutting-plane", "bisection", "brent-min"] {
        let series: Vec<String> = pts
            .iter()
            .filter(|p| p.method == m)
            .map(|p| format!("{:.0e}:{}", p.magnitude, p.probes))
            .collect();
        println!("  {m:>14}: {}", series.join("  "));
    }
    println!("\nall outputs under results/");
    Ok(())
}
