//! End-to-end driver (DESIGN.md E10): the paper's motivating application,
//! exercised through **all three layers**.
//!
//! Workload: high-breakdown regression on contaminated synthetic data.
//! The LMS elemental-subset search evaluates hundreds of candidate models;
//! each evaluation is a *median of n absolute residuals*. With the device
//! backend, residuals are computed by the AOT `residuals` artifact (L2
//! graph calling the L1 Pallas matvec kernel), stay resident as a PJRT
//! buffer, and every median runs as fused `fused_objective` reductions
//! driven by the rust cutting plane — python never runs.
//!
//! The run reports the paper's headline qualitative result: OLS/LAD break
//! under 30% contamination, LMS/LTS recover the true model; plus the
//! throughput of the selection backend that makes it fast.
//!
//! ```bash
//! make artifacts && cargo run --release --example robust_regression
//! ```

use std::rc::Rc;

use cp_select::regression::{
    self, lad, lms, lts, ols, ContaminatedLinear, LmsOptions, LtsOptions, MedianSelector,
};
use cp_select::runtime::{DeviceEvaluator, Kernel, Runtime};
use cp_select::select::{self, DType, Method};
use cp_select::stats::Rng;
use cp_select::util::Stopwatch;

/// Device-backed selector: uploads each residual vector once and runs the
/// hybrid method against the PJRT artifacts.
struct DeviceSelector {
    rt: Rc<Runtime>,
    medians: usize,
    reductions: u64,
}

impl MedianSelector for DeviceSelector {
    fn order_statistic(&mut self, v: &[f64], k: usize) -> cp_select::Result<f64> {
        let mut ev = DeviceEvaluator::upload(&self.rt, v, DType::F64)?;
        let r = select::order_statistic(&mut ev, k, Method::CuttingPlane)?;
        self.medians += 1;
        self.reductions += r.probes;
        Ok(r.value)
    }
}

/// Compute |X·θ − y| *on the device* through the AOT residuals artifact.
fn device_residuals(
    rt: &Rc<Runtime>,
    x_flat: &[f64],
    y: &[f64],
    theta: &[f64],
    p: usize,
) -> cp_select::Result<Vec<f64>> {
    let n = y.len();
    let bucket = rt
        .manifest
        .bucket_for(Kernel::Residuals, rt.flavor, DType::F64, n)?;
    let exe = rt.executable(Kernel::Residuals, rt.flavor, DType::F64, bucket, Some(p))?;
    let xb = rt.upload_matrix(x_flat, n, p, DType::F64, bucket)?;
    let yb = rt.upload_vector(y, DType::F64, bucket)?;
    let tb = rt.upload_vector(theta, DType::F64, p)?;
    let out = exe.run(&[&xb, &yb, &tb])?;
    let mut r = cp_select::runtime::client::literal_vec_f64(&out[0], DType::F64)?;
    r.truncate(n);
    Ok(r)
}

fn max_err(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

fn main() -> cp_select::Result<()> {
    let n = 4000;
    let p = 8; // matches the AOT matrix artifacts (aot.py --p 8)
    let contamination = 0.3;
    let mut rng = Rng::seeded(2011);
    let data = ContaminatedLinear {
        n,
        p,
        contamination,
        sigma: 0.2,
        ..Default::default()
    }
    .generate(&mut rng);
    let x = data.design();
    println!(
        "robust regression end-to-end: n={n}, p={p}, contamination={:.0}%",
        contamination * 100.0
    );
    println!("true theta = {:?}\n", data.theta);

    // --- fragile baselines --------------------------------------------
    let sw = Stopwatch::start();
    let theta_ols = ols(&x, &data.y)?;
    println!(
        "OLS : max|θ̂−θ| = {:8.4}   ({:6.1} ms)   <- breaks, as expected",
        max_err(&theta_ols, &data.theta),
        sw.elapsed_ms()
    );
    let sw = Stopwatch::start();
    let theta_lad = lad(&x, &data.y, 50)?;
    println!(
        "LAD : max|θ̂−θ| = {:8.4}   ({:6.1} ms)   <- breaks under leverage",
        max_err(&theta_lad, &data.theta),
        sw.elapsed_ms()
    );

    // --- robust estimators over the selection service ------------------
    let dir = Runtime::default_dir();
    let device = dir.join("manifest.json").exists();
    let mut host_sel = regression::HostSelector::default();

    let sw = Stopwatch::start();
    let fit_lms = lms(&x, &data.y, &LmsOptions::default(), &mut host_sel)?;
    println!(
        "LMS : max|θ̂−θ| = {:8.4}   ({:6.1} ms, {} medians, host selector)",
        max_err(&fit_lms.theta, &data.theta),
        sw.elapsed_ms(),
        fit_lms.candidates
    );

    let sw = Stopwatch::start();
    let fit_lts = lts(&x, &data.y, &LtsOptions::default(), &mut host_sel)?;
    println!(
        "LTS : max|θ̂−θ| = {:8.4}   ({:6.1} ms, h={}, ρ-trick objective)",
        max_err(&fit_lts.theta, &data.theta),
        sw.elapsed_ms(),
        fit_lts.h
    );

    if !device {
        println!("\n(no artifacts/ — run `make artifacts` for the device path)");
        return Ok(());
    }

    // --- full three-layer path -----------------------------------------
    println!("\n--- device path (PJRT artifacts; python not involved) ---");
    let rt = Runtime::new(&dir)?;
    let x_flat = data.x_flat();

    // (a) residuals on device for the LMS winner, then median on device
    let sw = Stopwatch::start();
    let r_dev = device_residuals(&rt, &x_flat, &data.y, &fit_lms.theta, p)?;
    let mut dev_sel = DeviceSelector { rt: rt.clone(), medians: 0, reductions: 0 };
    let med_dev = dev_sel.median(&r_dev)?;
    println!(
        "device residuals + median: med|r| = {:.6} ({:.1} ms)",
        med_dev,
        sw.elapsed_ms()
    );
    assert!((med_dev - fit_lms.med_abs_residual).abs() <= 1e-6 * med_dev.max(1.0));

    // (b) a shortened LMS search scored entirely by device medians
    let sw = Stopwatch::start();
    let fit_dev = lms(
        &x,
        &data.y,
        &LmsOptions { subsets: 150, adjust_intercept: false, ..Default::default() },
        &mut dev_sel,
    )?;
    println!(
        "device-scored LMS (150 subsets): max|θ̂−θ| = {:.4} \
         ({:.1} ms, {} medians, {} device reductions)",
        max_err(&fit_dev.theta, &data.theta),
        sw.elapsed_ms(),
        dev_sel.medians,
        dev_sel.reductions
    );
    println!("\nOK: all three layers composed (L1 pallas kernels -> L2 jax graphs -> L3 rust coordinator)");
    Ok(())
}
