//! Integration tests over the PJRT runtime + AOT artifacts.
//!
//! These need `artifacts/` (built by `make artifacts`); they are skipped
//! with a notice when the manifest is missing so `cargo test` stays green
//! on a fresh checkout.

use cp_select::runtime::{DeviceEvaluator, Flavor, Kernel, Runtime};
use cp_select::select::{self, DType, Evaluator, HostEvaluator, Method};
use cp_select::stats::{sorted_median, sorted_order_statistic, Distribution, Rng};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = Runtime::default_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at {dir:?} (run `make artifacts`)");
        None
    }
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => return,
        }
    };
}

#[test]
fn device_probe_matches_host() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let mut rng = Rng::seeded(201);
    let data = Distribution::Mixture1.sample_vec(&mut rng, 5000); // pads to 8192
    let mut dev = DeviceEvaluator::upload(&rt, &data, DType::F64).unwrap();
    let mut host = HostEvaluator::new(&data);
    for y in [-3.0, 0.0, 0.77, 50.0, 101.0, 1e9] {
        let a = dev.probe(y).unwrap();
        let b = host.probe(y).unwrap();
        assert_eq!((a.c_lt, a.c_eq, a.c_gt), (b.c_lt, b.c_eq, b.c_gt), "y={y}");
        assert!((a.s_lo - b.s_lo).abs() <= 1e-6 * b.s_lo.abs().max(1.0), "y={y}");
        assert!((a.s_hi - b.s_hi).abs() <= 1e-6 * b.s_hi.abs().max(1.0), "y={y}");
    }
}

#[test]
fn device_init_neighbors_interval_match_host() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let mut rng = Rng::seeded(202);
    let data = Distribution::HalfNormal.sample_vec(&mut rng, 4096);
    let mut dev = DeviceEvaluator::upload(&rt, &data, DType::F64).unwrap();
    let mut host = HostEvaluator::new(&data);

    let (a, b) = (dev.init_stats().unwrap(), host.init_stats().unwrap());
    assert_eq!((a.min, a.max), (b.min, b.max));
    assert!((a.sum - b.sum).abs() <= 1e-9 * b.sum.abs());

    let (a, b) = (dev.neighbors(0.7).unwrap(), host.neighbors(0.7).unwrap());
    assert_eq!(a, b);

    let (a, b) = (
        dev.interval(0.2, 1.4).unwrap(),
        host.interval(0.2, 1.4).unwrap(),
    );
    assert_eq!(a, b);

    // compaction + download
    let mut z = dev.compact(0.2, 1.4).unwrap();
    let mut zh = host.compact(0.2, 1.4).unwrap();
    z.sort_by(|x, y| x.total_cmp(y));
    zh.sort_by(|x, y| x.total_cmp(y));
    assert_eq!(z, zh);
    assert_eq!(dev.download().unwrap(), data);
}

#[test]
fn device_median_every_method() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let mut rng = Rng::seeded(203);
    for dist in [Distribution::Uniform, Distribution::Mixture3, Distribution::Beta25] {
        let data = dist.sample_vec(&mut rng, 3000);
        let want = sorted_median(&data);
        for m in [
            Method::CuttingPlane,
            Method::Hybrid,
            Method::Bisection,
            Method::BrentRoot,
            Method::Quickselect,
            Method::SortRadix,
        ] {
            let mut dev = DeviceEvaluator::upload(&rt, &data, DType::F64).unwrap();
            let r = select::median(&mut dev, m).unwrap();
            assert_eq!(r.value, want, "{} on {}", m.name(), dist.name());
        }
    }
}

#[test]
fn device_f32_median_quantizes_like_host_f32() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let mut rng = Rng::seeded(204);
    let data = Distribution::Normal.sample_vec(&mut rng, 4096);
    let rounded: Vec<f64> = data.iter().map(|&v| v as f32 as f64).collect();
    let want = sorted_median(&rounded);
    let mut dev = DeviceEvaluator::upload(&rt, &data, DType::F32).unwrap();
    let r = select::median(&mut dev, Method::CuttingPlane).unwrap();
    assert_eq!(r.value, want);
}

#[test]
fn device_order_statistics_random_k() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let mut rng = Rng::seeded(205);
    let data = Distribution::Mixture2.sample_vec(&mut rng, 6000);
    for k in [1usize, 2, 1500, 3000, 5999, 6000] {
        let want = sorted_order_statistic(&data, k);
        let mut dev = DeviceEvaluator::upload(&rt, &data, DType::F64).unwrap();
        let r = select::order_statistic(&mut dev, k, Method::Hybrid).unwrap();
        assert_eq!(r.value, want, "k={k}");
    }
}

#[test]
fn pallas_flavor_agrees_with_jnp_flavor() {
    let dir = require_artifacts!();
    let mut rng = Rng::seeded(206);
    let data = Distribution::Uniform.sample_vec(&mut rng, 2048);
    let rt = Runtime::new(&dir).unwrap();
    let mut a = DeviceEvaluator::upload_with_flavor(&rt, &data, DType::F64, Flavor::Jnp).unwrap();
    let mut b =
        DeviceEvaluator::upload_with_flavor(&rt, &data, DType::F64, Flavor::Pallas).unwrap();
    for y in [0.1, 0.5, 0.9] {
        let (sa, sb) = (a.probe(y).unwrap(), b.probe(y).unwrap());
        assert_eq!((sa.c_lt, sa.c_eq, sa.c_gt), (sb.c_lt, sb.c_eq, sb.c_gt));
        assert!((sa.s_lo - sb.s_lo).abs() <= 1e-9 * sb.s_lo.abs().max(1.0));
    }
}

#[test]
fn executable_cache_compiles_once() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let mut rng = Rng::seeded(207);
    let data = Distribution::Normal.sample_vec(&mut rng, 1024);
    let mut dev = DeviceEvaluator::upload(&rt, &data, DType::F64).unwrap();
    for _ in 0..5 {
        dev.probe(0.0).unwrap();
    }
    // fused_objective compiled exactly once despite 5 probes
    assert_eq!(rt.compiles(), 1, "compiles={}", rt.compiles());
    dev.init_stats().unwrap();
    assert_eq!(rt.compiles(), 2);
}

#[test]
fn missing_artifact_is_a_clean_error() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    // bucket larger than anything emitted
    let err = rt
        .manifest
        .bucket_for(Kernel::FusedObjective, Flavor::Jnp, DType::F64, 1 << 30, None)
        .unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("max-log2n") || msg.contains("bucket"), "{msg}");
}

#[test]
fn bad_manifest_fails_loud() {
    let tmp = std::env::temp_dir().join(format!("cp_select_badman_{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    std::fs::write(tmp.join("manifest.json"), "{ not json").unwrap();
    assert!(Runtime::new(&tmp).is_err());
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn device_probe_many_matches_host_ladder() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let mut rng = Rng::seeded(208);
    let data = Distribution::Mixture2.sample_vec(&mut rng, 3000);
    let mut dev = DeviceEvaluator::upload(&rt, &data, DType::F64).unwrap();
    let mut host = HostEvaluator::new(&data);
    let ys = [-2.0, 0.5, 0.5, 1.4, 99.0, 103.0];
    let a = dev.probe_many(&ys).unwrap();
    let b = host.probe_many(&ys).unwrap();
    assert_eq!(a.len(), b.len());
    for (i, (da, hb)) in a.iter().zip(&b).enumerate() {
        assert_eq!((da.c_lt, da.c_eq, da.c_gt), (hb.c_lt, hb.c_eq, hb.c_gt), "probe {i}");
        assert!((da.s_lo - hb.s_lo).abs() <= 1e-6 * hb.s_lo.abs().max(1.0), "probe {i}");
        assert!((da.s_hi - hb.s_hi).abs() <= 1e-6 * hb.s_hi.abs().max(1.0), "probe {i}");
    }
    assert_eq!(host.probes(), 1);
    if dev.has_fused_ladder() {
        // fused_ladder artifacts present: the whole batch (5 distinct
        // rungs, fits one width bucket) is ONE device reduction, matching
        // the host/sharded accounting
        assert_eq!(dev.probes(), 1, "ladder batch must cost one reduction");
    } else {
        // pre-ladder artifact set: back-to-back launches, honestly
        // counted per launch
        assert_eq!(dev.probes(), ys.len() as u64);
    }
}

#[test]
fn device_fused_ladder_matches_sequential_launches() {
    // Parity: the fused_ladder output must equal sequential
    // fused_objective launches rung by rung — duplicate-heavy ladders,
    // padded widths, data-valued rungs, f32 and f64.
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let mut rng = Rng::seeded(210);
    let data = Distribution::Mixture4.sample_vec(&mut rng, 2500);
    for dtype in [DType::F64, DType::F32] {
        let mut dev = DeviceEvaluator::upload(&rt, &data, dtype).unwrap();
        if !dev.has_fused_ladder() {
            eprintln!("SKIP: no fused_ladder artifacts (pre-ladder set)");
            return;
        }
        let tol = if dtype == DType::F32 { 1e-3 } else { 1e-6 };
        let ladders: Vec<Vec<f64>> = vec![
            vec![0.5],                                        // width 1, pads to 3
            vec![data[0], data[1], data[0], 0.9, 1e6],        // dups + data rungs
            (1..=15).map(|i| i as f64 / 16.0).collect(),      // full width
            (1..=23).map(|i| i as f64 / 24.0 * 100.0).collect(), // wider: chunks
        ];
        for ys in &ladders {
            let batch = dev.probe_many(ys).unwrap();
            assert_eq!(batch.len(), ys.len());
            // sequential launches on a fresh evaluator (probe() never
            // touches the ladder path)
            let mut seq = DeviceEvaluator::upload(&rt, &data, dtype).unwrap();
            for (y, got) in ys.iter().zip(&batch) {
                let want = seq.probe(*y).unwrap();
                assert_eq!(
                    (got.c_lt, got.c_eq, got.c_gt),
                    (want.c_lt, want.c_eq, want.c_gt),
                    "{} y={y}",
                    dtype.name()
                );
                assert!(
                    (got.s_lo - want.s_lo).abs() <= tol * want.s_lo.abs().max(1.0),
                    "{} y={y}: s_lo {} vs {}",
                    dtype.name(),
                    got.s_lo,
                    want.s_lo
                );
                assert!(
                    (got.s_hi - want.s_hi).abs() <= tol * want.s_hi.abs().max(1.0),
                    "{} y={y}: s_hi {} vs {}",
                    dtype.name(),
                    got.s_hi,
                    want.s_hi
                );
            }
        }
    }
}

#[test]
fn device_ladder_accounting_one_reduction_per_pass() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let mut rng = Rng::seeded(211);
    let data = Distribution::Uniform.sample_vec(&mut rng, 3000);
    let mut dev = DeviceEvaluator::upload(&rt, &data, DType::F64).unwrap();
    if !dev.has_fused_ladder() {
        eprintln!("SKIP: no fused_ladder artifacts (pre-ladder set)");
        return;
    }
    let widest = dev.ladder_width_hint().unwrap();
    assert!(widest >= 2);
    // one pass of `widest` distinct rungs = exactly one reduction
    let ys: Vec<f64> = (1..=widest).map(|i| i as f64 / (widest + 1) as f64).collect();
    let p0 = dev.probes();
    dev.probe_many(&ys).unwrap();
    assert_eq!(dev.probes() - p0, 1, "one ladder = one fused reduction");
    // a ladder wider than every bucket chunks: ceil(len/widest) reductions
    let wide: Vec<f64> = (1..=2 * widest + 1)
        .map(|i| i as f64 / (2 * widest + 2) as f64)
        .collect();
    let p0 = dev.probes();
    dev.probe_many(&wide).unwrap();
    assert_eq!(dev.probes() - p0, wide.len().div_ceil(widest) as u64);
}

#[test]
fn multisection_on_device_backend() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let mut rng = Rng::seeded(209);
    let data = Distribution::HalfNormal.sample_vec(&mut rng, 4000);
    let want = sorted_median(&data);
    let mut dev = DeviceEvaluator::upload(&rt, &data, DType::F64).unwrap();
    let r = select::median(&mut dev, Method::Multisection).unwrap();
    assert_eq!(r.value, want);
    if dev.has_fused_ladder() {
        // Acceptance: a device multisection reports `passes` fused
        // reductions (one per ladder) — not passes × p. Budget: one seed
        // reduction + one per pass + a short exact-fixup tail.
        let passes = r.iterations as u64;
        assert!(
            r.probes <= passes + 1 + 16,
            "probes={} passes={passes}: device pass must be one reduction",
            r.probes
        );
        let p = dev.ladder_width_hint().unwrap() as u64;
        assert!(r.probes < passes * p.max(2), "probes={} ≈ passes×p: ladder not fused", r.probes);
    }
}
