//! Property-based suites over the selection stack (testkit harness —
//! DESIGN.md §9).

use cp_select::select::cutting_plane::{cutting_plane, CpOptions};
use cp_select::select::hybrid::{hybrid_select, HybridOptions};
use cp_select::select::{self, Evaluator, HostEvaluator, Method, ObjectiveSpec};
use cp_select::stats::{sorted_order_statistic, Rng};
use cp_select::testkit::{check, Case, CaseGen};

fn oracle(c: &Case) -> f64 {
    sorted_order_statistic(&c.data, c.k)
}

#[test]
fn prop_every_probe_method_matches_sort_oracle() {
    for (i, method) in [
        Method::CuttingPlane,
        Method::Hybrid,
        Method::Bisection,
        Method::BrentMinimize,
        Method::BrentRoot,
        Method::GoldenSection,
    ]
    .into_iter()
    .enumerate()
    {
        check(1000 + i as u64, 120, &CaseGen::default(), |c| {
            let mut ev = HostEvaluator::new(&c.data);
            let got = select::order_statistic(&mut ev, c.k, method)
                .map_err(|e| format!("{method:?}: {e}"))?;
            if got.value == oracle(c) {
                Ok(())
            } else {
                Err(format!("{method:?}: got {} want {}", got.value, oracle(c)))
            }
        });
    }
}

#[test]
fn prop_download_methods_match_sort_oracle() {
    for (i, method) in
        [Method::Quickselect, Method::Bfprt, Method::SortRadix, Method::FixedPivot]
            .into_iter()
            .enumerate()
    {
        check(2000 + i as u64, 120, &CaseGen::default(), |c| {
            let mut ev = HostEvaluator::new(&c.data);
            let got = select::order_statistic(&mut ev, c.k, method)
                .map_err(|e| format!("{method:?}: {e}"))?;
            (got.value == oracle(c))
                .then_some(())
                .ok_or_else(|| format!("{method:?} mismatch"))
        });
    }
}

#[test]
fn prop_permutation_invariance() {
    // Eq. (1) is permutation invariant; so must be every probe method.
    check(3000, 80, &CaseGen::default(), |c| {
        let mut ev = HostEvaluator::new(&c.data);
        let a = select::order_statistic(&mut ev, c.k, Method::CuttingPlane)
            .map_err(|e| e.to_string())?;
        let mut shuffled = c.data.clone();
        let mut rng = Rng::seeded(c.data.len() as u64);
        rng.shuffle(&mut shuffled);
        let mut ev = HostEvaluator::new(&shuffled);
        let b = select::order_statistic(&mut ev, c.k, Method::CuttingPlane)
            .map_err(|e| e.to_string())?;
        (a.value == b.value)
            .then_some(())
            .ok_or_else(|| format!("permutation changed result: {} vs {}", a.value, b.value))
    });
}

#[test]
fn prop_monotone_transform_commutes() {
    // OS_k(F(x)) == F(OS_k(x)) for increasing F (paper §V.D identity).
    check(4000, 60, &CaseGen { outlier_prob: 0.0, ..Default::default() }, |c| {
        let f = |t: f64| (t * 0.5).atan() * 3.0 + 0.1 * t; // strictly increasing
        let mapped: Vec<f64> = c.data.iter().map(|&t| f(t)).collect();
        let want = f(oracle(c));
        let mut ev = HostEvaluator::new(&mapped);
        let got = select::order_statistic(&mut ev, c.k, Method::CuttingPlane)
            .map_err(|e| e.to_string())?;
        ((got.value - want).abs() <= 1e-9 * want.abs().max(1.0))
            .then_some(())
            .ok_or_else(|| format!("transform mismatch: {} vs {}", got.value, want))
    });
}

#[test]
fn prop_cutting_plane_bracket_always_contains_answer() {
    check(5000, 100, &CaseGen::default(), |c| {
        let mut ev = HostEvaluator::new(&c.data);
        let out = cutting_plane(
            &mut ev,
            c.k,
            &CpOptions { stop_after: Some(4), ..CpOptions::default() },
        )
        .map_err(|e| e.to_string())?;
        let ans = oracle(c);
        if out.exact {
            return (out.value == ans)
                .then_some(())
                .ok_or_else(|| "early exact value wrong".to_string());
        }
        (out.bracket.0 <= ans && ans <= out.bracket.1)
            .then_some(())
            .ok_or_else(|| format!("bracket {:?} excludes {ans}", out.bracket))
    });
}

#[test]
fn prop_subgradient_interval_is_monotone_in_y() {
    // g is the subdifferential of a convex function: intervals are ordered
    // and non-decreasing along y.
    check(6000, 60, &CaseGen { outlier_prob: 0.0, ..Default::default() }, |c| {
        let n = c.data.len();
        let spec = ObjectiveSpec::order(n, c.k).map_err(|e| e.to_string())?;
        let mut ev = HostEvaluator::new(&c.data);
        let mut prev = f64::NEG_INFINITY;
        let lo = c.data.iter().cloned().fold(f64::INFINITY, f64::min) - 1.0;
        let hi = c.data.iter().cloned().fold(f64::NEG_INFINITY, f64::max) + 1.0;
        for i in 0..=20 {
            let y = lo + (hi - lo) * i as f64 / 20.0;
            let s = ev.probe(y).map_err(|e| e.to_string())?;
            let (g_lo, g_hi) = spec.g(&s);
            if g_lo > g_hi + 1e-9 {
                return Err(format!("inverted subgradient interval at y={y}"));
            }
            if g_hi < prev - 1e-9 {
                return Err(format!("subgradient decreased at y={y}"));
            }
            prev = g_lo.max(prev);
        }
        Ok(())
    });
}

#[test]
fn prop_hybrid_matches_oracle() {
    check(7000, 80, &CaseGen::default(), |c| {
        let mut ev = HostEvaluator::new(&c.data);
        let out = hybrid_select(&mut ev, c.k, &HybridOptions::default())
            .map_err(|e| e.to_string())?;
        (out.value == oracle(c))
            .then_some(())
            .ok_or_else(|| format!("hybrid {} vs oracle {}", out.value, oracle(c)))
    });
}

#[test]
fn prop_f32_storage_matches_f32_oracle() {
    check(8000, 80, &CaseGen { outlier_prob: 0.1, ..Default::default() }, |c| {
        let rounded: Vec<f64> = c.data.iter().map(|&v| v as f32 as f64).collect();
        let want = sorted_order_statistic(&rounded, c.k);
        let mut ev = HostEvaluator::new_f32(&c.data);
        let got = select::order_statistic(&mut ev, c.k, Method::Hybrid)
            .map_err(|e| e.to_string())?;
        (got.value == want)
            .then_some(())
            .ok_or_else(|| format!("f32 mismatch: {} vs {}", got.value, want))
    });
}

#[test]
fn prop_probe_counts_partition_n() {
    check(9000, 80, &CaseGen::default(), |c| {
        let mut ev = HostEvaluator::new(&c.data);
        let mut rng = Rng::seeded(c.k as u64);
        for _ in 0..5 {
            let y = rng.range(-200.0, 200.0);
            let s = ev.probe(y).map_err(|e| e.to_string())?;
            if (s.c_lt + s.c_eq + s.c_gt) as usize != c.data.len() {
                return Err(format!("counts don't partition n at y={y}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sharded_equals_single_device() {
    use cp_select::device::{shard_data, ShardedEvaluator};
    check(9500, 60, &CaseGen { min_n: 4, ..Default::default() }, |c| {
        let shards = 1 + c.data.len() % 5;
        let evs: Vec<HostEvaluator> =
            shard_data(&c.data, shards).into_iter().map(HostEvaluator::new).collect();
        let mut group = ShardedEvaluator::new(evs).map_err(|e| e.to_string())?;
        let got = select::order_statistic(&mut group, c.k, Method::CuttingPlane)
            .map_err(|e| e.to_string())?;
        (got.value == oracle(c))
            .then_some(())
            .ok_or_else(|| format!("sharded({shards}) {} vs {}", got.value, oracle(c)))
    });
}
