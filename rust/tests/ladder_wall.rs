//! Parity pins for the wall-clock PR: the vectorized lane-split ladder
//! kernel must agree with the retained scalar oracle on adversarial
//! inputs (exact `cnt`/`eq`, bounded `sum` drift from per-lane
//! reassociation), and the fixed-pivot host selector must match the
//! sort oracle wherever the total order holds.

use cp_select::select::fixed_pivot::fixed_pivot_select;
use cp_select::select::{ladder_sweep, ladder_sweep_scalar, LadderPartial};
use cp_select::stats::{sorted_order_statistic, Rng};

/// Exact equality on `cnt`/`eq`; tolerant compare on `sum`, whose only
/// licensed deviation is per-lane reassociation of a finite series.
fn assert_parity(v: &LadderPartial, s: &LadderPartial, ctx: &str) {
    assert_eq!(v.cnt, s.cnt, "cnt diverged ({ctx})");
    assert_eq!(v.eq, s.eq, "eq diverged ({ctx})");
    assert_eq!(v.sum.len(), s.sum.len(), "sum length diverged ({ctx})");
    for (j, (&a, &b)) in v.sum.iter().zip(&s.sum).enumerate() {
        if a.is_nan() && b.is_nan() {
            continue; // e.g. +inf and -inf landed in one bin on both sides
        }
        if a == b {
            continue; // covers equal infinities and exact finite agreement
        }
        let scale = a.abs().max(b.abs()).max(1.0);
        assert!(
            (a - b).abs() <= 1e-9 * scale,
            "sum[{j}] diverged beyond reassociation bound ({ctx}): {a} vs {b}"
        );
    }
}

/// Adversarial element corpora: every tile-remainder length, NaN and
/// ±inf payloads, heavy duplicates, and constant arrays.
fn corpus(len: usize, flavor: usize, rng: &mut Rng) -> Vec<f64> {
    (0..len)
        .map(|i| match flavor {
            0 => rng.range(-100.0, 100.0),
            1 => match rng.below(8) {
                0 => f64::NAN,
                1 => f64::INFINITY,
                2 => f64::NEG_INFINITY,
                _ => rng.range(-10.0, 10.0),
            },
            2 => rng.below(7) as f64, // heavy duplicates across 7 values
            3 => 3.25,                // constant array
            _ => (i as f64) * if i % 2 == 0 { 1.0 } else { -1.0 },
        })
        .collect()
}

/// Ladder corpora: sorted rung sets, including ±inf endpoints and
/// duplicate-colliding rungs; `p = 0` exercises the no-rung edge.
fn ladders(flavor: usize) -> Vec<Vec<f64>> {
    match flavor {
        1 => vec![
            vec![],
            vec![f64::NEG_INFINITY],
            vec![f64::NEG_INFINITY, 0.0, f64::INFINITY],
            vec![-5.0, 5.0],
        ],
        2 => vec![vec![3.0], vec![0.0, 2.0, 4.0, 6.0], (0..15).map(|i| i as f64 * 0.5).collect()],
        3 => vec![vec![3.25], vec![1.0, 3.25, 7.0]],
        _ => vec![
            vec![],
            vec![0.0],
            vec![-50.0, 0.0, 50.0],
            (0..15).map(|i| -70.0 + 10.0 * i as f64).collect(),
        ],
    }
}

#[test]
fn vectorized_ladder_matches_scalar_oracle_on_adversarial_corpora() {
    let mut rng = Rng::seeded(0x1adde2);
    for flavor in 0..5 {
        // 0..=40 covers every mod-8 remainder path with multi-tile bodies;
        // 1037 adds a long run with a 5-element remainder.
        for len in (0..=40).chain([1037]) {
            let data = corpus(len, flavor, &mut rng);
            for ys in ladders(flavor) {
                let v = ladder_sweep(&data, &ys);
                let s = ladder_sweep_scalar(&data, &ys);
                assert_parity(&v, &s, &format!("flavor={flavor} len={len} p={}", ys.len()));
            }
        }
    }
}

#[test]
fn vectorized_ladder_counts_partition_n_without_nans() {
    // With no NaN payloads every element lands in exactly one real bin,
    // so cnt sums to n and the trash bin stays empty.
    let mut rng = Rng::seeded(0xc0de);
    for len in [0, 1, 7, 8, 9, 255, 1024] {
        let data: Vec<f64> = (0..len).map(|_| rng.normal()).collect();
        let ys = vec![-1.0, -0.25, 0.25, 1.0];
        let part = ladder_sweep(&data, &ys);
        assert_eq!(part.cnt.iter().sum::<u64>(), len as u64, "len={len}");
    }
}

#[test]
fn vectorized_ladder_routes_nan_elements_to_no_bin() {
    // NaN compares false against every rung: the scalar oracle never
    // counts it, and the lane-split kernel must agree (trash slot is
    // internal — it may not leak into any public bin).
    let data = [1.0, f64::NAN, 2.0, f64::NAN, f64::NAN, 3.0, 4.0, 5.0, 6.0];
    let ys = vec![1.5, 3.5];
    let v = ladder_sweep(&data, &ys);
    let s = ladder_sweep_scalar(&data, &ys);
    assert_parity(&v, &s, "explicit NaN payload");
    assert_eq!(v.cnt.iter().sum::<u64>(), 6, "only the 6 non-NaN elements count");
}

#[test]
fn fixed_pivot_matches_sort_oracle_on_the_same_corpus() {
    let mut rng = Rng::seeded(0xf1ed);
    for flavor in [0usize, 2, 3, 4] {
        // NaN-free flavors only: selection is specified via the total order
        for len in [1usize, 2, 3, 17, 64, 1037] {
            let data = corpus(len, flavor, &mut rng);
            for k in [1, (len + 1) / 2, len] {
                let mut scratch = data.clone();
                let got = fixed_pivot_select(&mut scratch, k);
                let want = sorted_order_statistic(&data, k);
                assert_eq!(
                    got.to_bits(),
                    want.to_bits(),
                    "flavor={flavor} len={len} k={k}: {got} vs {want}"
                );
            }
        }
    }
}
