//! Cluster mode end-to-end: loopback-wire coordinator/worker stacks under
//! a frozen virtual clock (deterministic coalescing parity with the
//! in-process service), scripted mid-ladder disconnects (fault isolation,
//! reconnect, dataset survival), and one real-TCP run of the full
//! coordinator/worker/client stack.

use std::sync::Arc;
use std::time::Duration;

use cp_select::cluster::coordinator::Registry;
use cp_select::cluster::transport::loopback_pair;
use cp_select::cluster::{
    run_coordinator, run_worker, serve, ClusterClient, RemoteBackend, ServeExit, ServeOptions,
    WorkerOptions,
};
use cp_select::coordinator::messages::WireRequest;
use cp_select::coordinator::{
    CoordinatorOptions, CostModelPool, HostBackend, KSpec, SelectionService,
};
use cp_select::error::ErrorKind;
use cp_select::select::{DType, Method, PassCostModel};
use cp_select::stats::{sorted_median, Distribution, Rng};
use cp_select::testkit::{Clock, Fault, FaultInjectingBackend, FaultScript};

/// Start `workers` loopback serve loops over host backends, registered in
/// `registry` as worker ids `0..workers`. Returns the join handles; each
/// exits with the [`ServeExit`] its serve loop reported.
fn spawn_loopback_workers(
    registry: &Arc<Registry>,
    clock: &Clock,
    workers: u32,
) -> Vec<std::thread::JoinHandle<ServeExit>> {
    (0..workers)
        .map(|w| {
            let (coord_side, mut worker_side) =
                loopback_pair(&format!("worker-{w}"), "coordinator");
            let version = registry
                .register(w, Box::new(coord_side), clock.now_us())
                .expect("register");
            let w_clock = clock.clone();
            std::thread::spawn(move || {
                let _ = worker_side.recv(); // Registered ack
                let mut backend = HostBackend::default();
                let mut stats = PassCostModel::seeded();
                serve(&mut worker_side, &mut backend, &mut stats, version, &w_clock)
            })
        })
        .collect()
}

/// Shut a cluster service down the way `run_coordinator` does: the service
/// first (parks every wire back in the registry), then shutdown frames to
/// every parked worker connection.
fn shutdown_cluster(svc: SelectionService, registry: &Registry) {
    svc.shutdown();
    for mut conn in registry.drain_conns() {
        if conn.send(&WireRequest::Shutdown.encode()).is_ok() {
            let _ = conn.recv();
        }
    }
}

/// Acceptance: the 8-client windowed burst answered over the cluster
/// message layer (2 remote workers behind loopback wires) returns
/// bit-exact values and costs exactly the fused reductions of the same
/// burst on the in-process service — the wire path enters through the same
/// `BackendFactory` seam, so the planner cannot tell the difference.
#[test]
fn eight_clients_two_workers_match_the_in_process_run_exactly() {
    let mut rng = Rng::seeded(42);
    let data = Distribution::Uniform.sample_vec(&mut rng, 1 << 14);
    let want = sorted_median(&data);
    let opts = || CoordinatorOptions {
        batch_window: Duration::from_millis(250),
        batch_cap: 8,
        ..Default::default()
    };

    // In-process reference run: frozen virtual window, cap closes it.
    let in_process_fused = {
        let (clock, _vc) = Clock::manual();
        let svc = SelectionService::start_full(
            1,
            64,
            Method::Multisection,
            HostBackend::factory(),
            opts(),
            clock,
            CostModelPool::seeded(),
        )
        .unwrap();
        let id = svc.upload(data.clone(), DType::F64).unwrap();
        let p0 = svc.metrics.snapshot().probes;
        let rxs: Vec<_> = (0..8)
            .map(|_| svc.query_async(id, KSpec::Median, Method::Multisection).unwrap())
            .collect();
        for rx in rxs {
            let r = rx.recv().expect("reply").expect("query");
            assert_eq!(r.value.to_bits(), want.to_bits());
        }
        let fused = svc.metrics.snapshot().probes - p0;
        svc.shutdown();
        fused
    };

    // Same burst, but every probe ladder crosses a wire.
    let (clock, _vc) = Clock::manual();
    let registry = Registry::new();
    let serves = spawn_loopback_workers(&registry, &clock, 2);
    let pool = CostModelPool::seeded();
    let factory = RemoteBackend::factory(
        Arc::clone(&registry),
        Arc::clone(&pool),
        2,
        Duration::from_secs(10),
    );
    let svc = SelectionService::start_full(
        2,
        64,
        Method::Multisection,
        factory,
        opts(),
        clock,
        pool,
    )
    .unwrap();
    let id = svc.upload(data, DType::F64).unwrap();
    let p0 = svc.metrics.snapshot().probes;
    let rxs: Vec<_> = (0..8)
        .map(|_| svc.query_async(id, KSpec::Median, Method::Multisection).unwrap())
        .collect();
    for rx in rxs {
        let r = rx.recv().expect("reply").expect("cluster query");
        assert_eq!(r.value.to_bits(), want.to_bits(), "cluster answer must be bit-exact");
    }
    let snap = svc.metrics.snapshot();
    assert!(snap.coalesced >= 8, "cluster window caught {} of 8 clients", snap.coalesced);
    assert_eq!(
        snap.probes - p0,
        in_process_fused,
        "cluster burst must cost exactly the in-process fused reductions"
    );
    shutdown_cluster(svc, &registry);
    for h in serves {
        assert_eq!(h.join().expect("serve thread"), ServeExit::Shutdown);
    }
}

/// A worker whose backend reports `Disconnected` mid-ladder (a scripted
/// [`Fault::Disconnect`] on the 4th fused pass) drops its coordinator
/// connection without a reply. The in-flight batch — and only it — fails
/// with a typed `Disconnected` error; the worker re-registers (version
/// bump) keeping its backend, so the next query on the same dataset
/// succeeds without a re-upload.
#[test]
fn mid_ladder_disconnect_fails_one_batch_and_reconnect_recovers() {
    let (clock, vc) = Clock::manual();
    let script = FaultScript::new(vc, 0);
    let registry = Registry::new();
    let worker = std::thread::spawn({
        let registry = Arc::clone(&registry);
        let clock = clock.clone();
        let factory = FaultInjectingBackend::factory(script.clone());
        move || {
            // run_worker's shape without TCP: one backend across
            // reconnects, a fresh wire + registration per serve loop.
            let mut backend = factory(0).expect("worker backend");
            let mut stats = PassCostModel::seeded();
            loop {
                let (coord_side, mut worker_side) = loopback_pair("worker-0", "coordinator");
                let version = registry
                    .register(0, Box::new(coord_side), clock.now_us())
                    .expect("register");
                let _ = worker_side.recv(); // Registered ack
                match serve(&mut worker_side, backend.as_mut(), &mut stats, version, &clock) {
                    ServeExit::Shutdown => break,
                    ServeExit::Disconnected => continue,
                }
            }
        }
    });
    let pool = CostModelPool::seeded();
    let factory = RemoteBackend::factory(
        Arc::clone(&registry),
        Arc::clone(&pool),
        1,
        Duration::from_secs(10),
    );
    let svc = SelectionService::start_full(
        1,
        64,
        Method::Multisection,
        factory,
        CoordinatorOptions::default(),
        clock,
        pool,
    )
    .unwrap();
    let mut rng = Rng::seeded(7);
    let data = Distribution::Mixture2.sample_vec(&mut rng, 4096);
    let want = sorted_median(&data);
    let id = svc.upload(data, DType::F64).unwrap();

    // Healthy query first: the ladder works end to end over the wire.
    assert_eq!(svc.query(id, KSpec::Median).unwrap().value.to_bits(), want.to_bits());

    // Script a disconnect mid-ladder on this dataset's next run. Passes
    // are counted per dataset: the healthy run consumed some, so schedule
    // relative to the current count (init + 3 passes into the new run).
    let burned = script.calls(id);
    script.fault_at(id, burned + 3, Fault::Disconnect);
    let err = svc.query(id, KSpec::Median).unwrap_err();
    assert_eq!(err.kind(), ErrorKind::Disconnected, "typed disconnect, got {err}");

    // Only that batch failed: the worker re-registered with its datasets
    // intact, so the same query now succeeds without any re-upload.
    assert_eq!(svc.query(id, KSpec::Median).unwrap().value.to_bits(), want.to_bits());
    assert!(
        registry.current_version(0) >= 2,
        "reconnect must bump the registration version, got {}",
        registry.current_version(0)
    );

    shutdown_cluster(svc, &registry);
    worker.join().expect("worker thread");
}

/// The full TCP stack in one process: `run_coordinator` + two `run_worker`
/// bodies + a `ClusterClient`, on an OS-assigned port. Mirrors the CI
/// cluster-smoke job (which runs the same roles as separate processes via
/// the CLI).
#[test]
fn tcp_coordinator_two_workers_and_a_client_round_trip() {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr").to_string();
    let registry = Registry::new();
    let pool = CostModelPool::seeded();
    let factory = RemoteBackend::factory(
        Arc::clone(&registry),
        Arc::clone(&pool),
        2,
        Duration::from_secs(10),
    );
    let svc = SelectionService::start_full(
        2,
        64,
        Method::Multisection,
        factory,
        CoordinatorOptions::default(),
        Clock::real(),
        pool,
    )
    .unwrap();
    let coordinator = std::thread::spawn({
        let registry = Arc::clone(&registry);
        move || {
            run_coordinator(
                listener,
                svc,
                registry,
                Clock::real(),
                ServeOptions {
                    client_poll: Duration::from_millis(100),
                    shard_io_timeout: Duration::from_secs(10),
                },
            )
        }
    });
    let workers: Vec<_> = (0..2u32)
        .map(|id| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                run_worker(
                    &addr,
                    id,
                    HostBackend::factory(),
                    Clock::real(),
                    WorkerOptions {
                        connect_timeout: Duration::from_secs(5),
                        reconnect_backoff: Duration::from_millis(50),
                        heartbeat: Duration::ZERO,
                    },
                )
            })
        })
        .collect();

    let mut rng = Rng::seeded(11);
    let data = Distribution::HalfNormal.sample_vec(&mut rng, 4096);
    let mut sorted = data.clone();
    sorted.sort_by(f64::total_cmp);
    let want_med = sorted_median(&data);

    let mut client =
        ClusterClient::connect(&addr, Duration::from_secs(5), Duration::from_secs(30))
            .expect("client connects");
    let id = client.upload(data, DType::F64).expect("upload");
    let r = client.query(id, KSpec::Median, None, 0, None).expect("median");
    assert_eq!(r.value.to_bits(), want_med.to_bits());
    let many = client
        .query_many(id, vec![KSpec::Rank(100), KSpec::Quantile(0.9)], None, 0, None)
        .expect("query_many");
    assert_eq!(many.len(), 2);
    assert_eq!(many[0].value.to_bits(), sorted[99].to_bits());
    let stats = client.stats().expect("stats");
    assert!(stats.contains("queries="), "{stats}");
    client.shutdown().expect("shutdown");

    assert!(coordinator.join().expect("coordinator thread").is_ok());
    for w in workers {
        assert!(w.join().expect("worker thread").is_ok());
    }
}
