//! Fixture tests for the in-repo invariant linter (`cp_select::analysis`).
//! Every rule is exercised three ways — a known-bad snippet that must
//! fire, a clean snippet that must not, and a pragma-suppressed snippet —
//! plus a self-check that the real tree is lint-clean with an exact
//! suppression inventory, and a schema check on the JSON output.

use cp_select::analysis::{lint_files, Report, SourceFile};
use cp_select::util::json::Json;

fn lint_one(path: &str, src: &str) -> Report {
    lint_files(&[SourceFile { path: path.to_string(), src: src.to_string() }])
}

fn lint_two(a: (&str, &str), b: (&str, &str)) -> Report {
    lint_files(&[
        SourceFile { path: a.0.to_string(), src: a.1.to_string() },
        SourceFile { path: b.0.to_string(), src: b.1.to_string() },
    ])
}

fn rules_of(report: &Report) -> Vec<&'static str> {
    report.findings.iter().map(|f| f.rule).collect()
}

// ---------------------------------------------------------------------------
// clock_discipline

#[test]
fn clock_discipline_fires_outside_wall_clock_files() {
    let report = lint_one(
        "src/coordinator/worker.rs",
        r#"
use std::time::Instant;
fn stamp() {
    let t0 = Instant::now();
    let _ = t0;
}
"#,
    );
    assert_eq!(rules_of(&report), ["clock_discipline"]);
    assert_eq!(report.findings[0].line, 4);
}

#[test]
fn clock_discipline_flags_sleep_outside_benches() {
    let report = lint_one(
        "src/select/pump.rs",
        "fn nap() {\n    std::thread::sleep(std::time::Duration::from_millis(1));\n}\n",
    );
    assert_eq!(rules_of(&report), ["clock_discipline"]);
}

#[test]
fn clock_discipline_allows_the_wall_clock_files() {
    let src = "fn stamp() -> std::time::Instant {\n    std::time::Instant::now()\n}\n";
    assert!(lint_one("src/testkit/clock.rs", src).clean());
    assert!(lint_one("src/harness/mod.rs", src).clean());
    let nap = "fn nap() {\n    std::thread::sleep(std::time::Duration::from_millis(1));\n}\n";
    assert!(lint_one("benches/scaling.rs", nap).clean());
}

#[test]
fn clock_discipline_pragma_suppresses_with_justification() {
    let report = lint_one(
        "src/select/pump.rs",
        "fn nap() {\n    // lint: allow(clock_discipline) — fixture exercises suppression\n    std::thread::sleep(std::time::Duration::from_millis(1));\n}\n",
    );
    assert!(report.clean(), "{report}");
    assert_eq!(report.suppressed.len(), 1);
    assert_eq!(report.suppressed[0].rule, "clock_discipline");
}

// ---------------------------------------------------------------------------
// poison_discipline

#[test]
fn poison_discipline_flags_unwrap_expect_and_question_mark() {
    let report = lint_one(
        "src/coordinator/state.rs",
        r#"
fn read(m: &std::sync::Mutex<u32>) -> u32 {
    *m.lock().unwrap()
}
fn read2(m: &std::sync::Mutex<u32>) -> u32 {
    *m.lock().expect("poisoned")
}
fn read3(m: &std::sync::Mutex<u32>) -> Result<u32, Box<dyn std::error::Error>> {
    Ok(*m.lock()?)
}
"#,
    );
    // error_discipline independently flags the same unwrap/expect sites.
    let poison = report
        .findings
        .iter()
        .filter(|f| f.rule == "poison_discipline")
        .count();
    assert_eq!(poison, 3, "{report}");
}

#[test]
fn poison_discipline_rejects_recovery_that_drops_the_guard() {
    let report = lint_one(
        "src/coordinator/state.rs",
        "fn read(m: &std::sync::Mutex<u32>) -> u32 {\n    *m.lock().unwrap_or_else(|_| todo!())\n}\n",
    );
    assert_eq!(rules_of(&report), ["poison_discipline"]);
}

#[test]
fn poison_discipline_accepts_recovery_and_bare_lock() {
    let report = lint_one(
        "src/coordinator/state.rs",
        r#"
fn read(m: &std::sync::Mutex<u32>) -> u32 {
    *m.lock().unwrap_or_else(|e| e.into_inner())
}
fn guard(m: &OrderedMutex<u32>) -> u32 {
    let g = m.lock();
    *g
}
"#,
    );
    assert!(report.clean(), "{report}");
}

#[test]
fn poison_discipline_pragma_suppresses() {
    // util/ is outside error_discipline's scope, so a single pragma covers
    // the site (poison_discipline itself applies tree-wide).
    let report = lint_one(
        "src/util/state.rs",
        r#"
fn read(m: &std::sync::Mutex<u32>) -> u32 {
    // lint: allow(poison_discipline) — fixture exercises suppression
    *m.lock().unwrap()
}
"#,
    );
    assert!(report.clean(), "{report}");
    assert_eq!(report.suppressed.len(), 1);
}

// ---------------------------------------------------------------------------
// panic_boundary

const BACKEND_TRAIT: &str = r#"
pub trait DatasetBackend {
    fn upload(&mut self, n: usize) -> bool;
    fn drop_dataset(&mut self, id: u32) -> bool;
}
"#;

#[test]
fn panic_boundary_fires_on_unprotected_backend_call() {
    let report = lint_two(
        ("src/coordinator/backend.rs", BACKEND_TRAIT),
        (
            "src/coordinator/dispatch.rs",
            r#"
fn worker(backend: &mut dyn DatasetBackend) {
    backend.upload(3);
}
"#,
        ),
    );
    assert_eq!(rules_of(&report), ["panic_boundary"]);
    assert!(report.findings[0].message.contains("upload"));
}

#[test]
fn panic_boundary_covers_the_cluster_serve_loop_too() {
    let report = lint_two(
        ("src/coordinator/backend.rs", BACKEND_TRAIT),
        (
            "src/cluster/worker.rs",
            r#"
fn serve(backend: &mut dyn DatasetBackend) {
    backend.upload(3);
}
"#,
        ),
    );
    assert_eq!(rules_of(&report), ["panic_boundary"]);
}

#[test]
fn panic_boundary_accepts_catch_unwind_and_protected_helpers() {
    let report = lint_two(
        ("src/coordinator/backend.rs", BACKEND_TRAIT),
        (
            "src/coordinator/dispatch.rs",
            r#"
fn run_query(backend: &mut dyn DatasetBackend) -> bool {
    backend.upload(3)
}
fn worker(backend: &mut dyn DatasetBackend) {
    let _ = catch_unwind(AssertUnwindSafe(|| backend.upload(1)));
    let _ = catch_unwind(AssertUnwindSafe(|| run_query(backend)));
}
"#,
        ),
    );
    assert!(report.clean(), "{report}");
}

#[test]
fn panic_boundary_only_applies_to_the_worker_loop_files() {
    // Neither an unrelated coordinator file nor service.rs (the worker
    // loop moved to dispatch.rs) is in the rule's scope.
    let report = lint_two(
        ("src/coordinator/backend.rs", BACKEND_TRAIT),
        (
            "src/coordinator/ingest.rs",
            "fn feed(backend: &mut dyn DatasetBackend) {\n    backend.upload(3);\n}\n",
        ),
    );
    assert!(report.clean(), "{report}");
    let report = lint_two(
        ("src/coordinator/backend.rs", BACKEND_TRAIT),
        (
            "src/coordinator/service.rs",
            "fn feed(backend: &mut dyn DatasetBackend) {\n    backend.upload(3);\n}\n",
        ),
    );
    assert!(report.clean(), "{report}");
}

#[test]
fn panic_boundary_pragma_suppresses() {
    let report = lint_two(
        ("src/coordinator/backend.rs", BACKEND_TRAIT),
        (
            "src/coordinator/dispatch.rs",
            r#"
fn worker(backend: &mut dyn DatasetBackend) {
    // lint: allow(panic_boundary) — fixture exercises suppression
    backend.upload(3);
}
"#,
        ),
    );
    assert!(report.clean(), "{report}");
    assert_eq!(report.suppressed.len(), 1);
}

// ---------------------------------------------------------------------------
// metrics_triple_entry

const METRICS_CLEAN: &str = r#"
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};

pub struct Metrics {
    pub uploads: AtomicU64,
}

pub struct Snapshot {
    pub uploads: u64,
}

impl Metrics {
    pub fn snapshot(&self) -> Snapshot {
        Snapshot { uploads: self.uploads.load(Ordering::Relaxed) }
    }
}

impl fmt::Display for Snapshot {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "uploads {}", self.uploads)
    }
}
"#;

#[test]
fn metrics_triple_entry_clean_when_all_legs_present() {
    let report = lint_one("src/coordinator/metrics.rs", METRICS_CLEAN);
    assert!(report.clean(), "{report}");
}

#[test]
fn metrics_triple_entry_fires_once_per_missing_leg() {
    let src = METRICS_CLEAN.replace(
        "pub uploads: AtomicU64,",
        "pub uploads: AtomicU64,\n    pub shed: AtomicU64,",
    );
    let report = lint_one("src/coordinator/metrics.rs", &src);
    assert_eq!(rules_of(&report), ["metrics_triple_entry"; 3]);
    for f in &report.findings {
        assert!(f.message.contains("`shed`"), "{f}");
    }
}

#[test]
fn metrics_triple_entry_pragma_suppresses_all_legs() {
    let src = METRICS_CLEAN.replace(
        "pub uploads: AtomicU64,",
        "pub uploads: AtomicU64,\n    // lint: allow(metrics_triple_entry) — fixture counter is deliberately unplumbed\n    pub shed: AtomicU64,",
    );
    let report = lint_one("src/coordinator/metrics.rs", &src);
    assert!(report.clean(), "{report}");
    assert_eq!(report.suppressed.len(), 3);
}

#[test]
fn metrics_triple_entry_requires_the_snapshot_plumbing() {
    let report = lint_one(
        "src/coordinator/metrics.rs",
        "use std::sync::atomic::AtomicU64;\npub struct Metrics {\n    pub uploads: AtomicU64,\n}\n",
    );
    assert_eq!(rules_of(&report), ["metrics_triple_entry"]);
    assert!(report.findings[0].message.contains("Snapshot"));
}

// ---------------------------------------------------------------------------
// lock_order

const LOCK_CYCLE: &str = r#"
use std::sync::Mutex;

pub struct Pair {
    a: Mutex<u32>,
    b: Mutex<u32>,
}

impl Pair {
    fn ab(&self) -> u32 {
        let ga = self.a.lock().unwrap_or_else(|e| e.into_inner());
        let gb = self.b.lock().unwrap_or_else(|e| e.into_inner());
        *ga + *gb
    }
    fn ba(&self) -> u32 {
        let gb = self.b.lock().unwrap_or_else(|e| e.into_inner());
        let ga = self.a.lock().unwrap_or_else(|e| e.into_inner());
        *ga + *gb
    }
}
"#;

#[test]
fn lock_order_cycle_is_a_finding() {
    let report = lint_one("src/coordinator/pair.rs", LOCK_CYCLE);
    assert_eq!(rules_of(&report), ["lock_order"]);
    let msg = &report.findings[0].message;
    assert!(msg.contains("pair.a") && msg.contains("pair.b"), "{msg}");
}

#[test]
fn lock_order_consistent_nesting_is_clean() {
    let src = LOCK_CYCLE.replace(
        "let gb = self.b.lock().unwrap_or_else(|e| e.into_inner());\n        let ga = self.a.lock().unwrap_or_else(|e| e.into_inner());",
        "let ga = self.a.lock().unwrap_or_else(|e| e.into_inner());\n        let gb = self.b.lock().unwrap_or_else(|e| e.into_inner());",
    );
    let report = lint_one("src/coordinator/pair.rs", &src);
    assert!(report.clean(), "{report}");
}

#[test]
fn lock_order_drop_releases_the_guard() {
    let src = LOCK_CYCLE.replace(
        "let gb = self.b.lock().unwrap_or_else(|e| e.into_inner());\n        let ga = self.a.lock().unwrap_or_else(|e| e.into_inner());\n        *ga + *gb",
        "let gb = self.b.lock().unwrap_or_else(|e| e.into_inner());\n        let x = *gb;\n        drop(gb);\n        let ga = self.a.lock().unwrap_or_else(|e| e.into_inner());\n        *ga + x",
    );
    let report = lint_one("src/coordinator/pair.rs", &src);
    assert!(report.clean(), "dropping the guard ends its held scope:\n{report}");
}

#[test]
fn lock_order_sees_through_helper_calls() {
    // `ba` routes its second acquisition through a helper; the call-graph
    // fixpoint must still draw the b → a edge and close the cycle.
    let src = LOCK_CYCLE.replace(
        "let gb = self.b.lock().unwrap_or_else(|e| e.into_inner());\n        let ga = self.a.lock().unwrap_or_else(|e| e.into_inner());\n        *ga + *gb",
        "let gb = self.b.lock().unwrap_or_else(|e| e.into_inner());\n        *gb + self.via_helper()",
    ) + r#"
impl Pair {
    fn via_helper(&self) -> u32 {
        *self.a.lock().unwrap_or_else(|e| e.into_inner())
    }
}
"#;
    let report = lint_one("src/coordinator/pair.rs", &src);
    assert_eq!(rules_of(&report), ["lock_order"], "{report}");
}

#[test]
fn lock_order_pragma_suppresses_at_the_cycle_anchor() {
    let src = LOCK_CYCLE.replace(
        "let gb = self.b.lock().unwrap_or_else(|e| e.into_inner());\n        let ga =",
        "let gb = self.b.lock().unwrap_or_else(|e| e.into_inner());\n        // lint: allow(lock_order) — fixture exercises suppression\n        let ga =",
    );
    let report = lint_one("src/coordinator/pair.rs", &src);
    assert!(report.clean(), "{report}");
    assert_eq!(report.suppressed.len(), 1);
}

// ---------------------------------------------------------------------------
// float_order_discipline

#[test]
fn float_order_flags_partial_cmp_in_the_numeric_core() {
    let report = lint_one(
        "src/select/fx.rs",
        "fn s(v: &mut Vec<f64>) {\n    v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));\n}\n",
    );
    assert_eq!(rules_of(&report), ["float_order_discipline"]);
    assert!(report.findings[0].message.contains("total_cmp"));
}

#[test]
fn float_order_flags_raw_comparison_in_comparator_closures() {
    let report = lint_one(
        "src/stats/fx.rs",
        "fn s(v: &mut Vec<f64>) {\n    v.sort_by(|a, b| if a < b { std::cmp::Ordering::Less } else { std::cmp::Ordering::Greater });\n}\n",
    );
    assert_eq!(rules_of(&report), ["float_order_discipline"]);
}

#[test]
fn float_order_accepts_total_cmp_keys_and_ieee_guards() {
    let report = lint_one(
        "src/select/fx.rs",
        r#"
fn s(v: &mut Vec<f64>) {
    v.sort_by(f64::total_cmp);
    v.sort_by_key(|&x| crate::util::f64_key(x));
}
fn converge(mut lo: f64, mut hi: f64) -> f64 {
    while lo < hi {
        let mid = 0.5 * (lo + hi);
        if mid <= lo || mid >= hi {
            break;
        }
        hi = mid;
    }
    hi
}
"#,
    );
    assert!(report.clean(), "raw comparisons outside comparators are legal:\n{report}");
}

#[test]
fn float_order_scope_is_select_and_stats_only() {
    let src = "fn s(v: &mut Vec<f64>) {\n    v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));\n}\n";
    assert!(lint_one("src/util/fx.rs", src).clean());
    assert!(lint_one("src/coordinator/fx.rs", src).clean());
}

#[test]
fn float_order_pragma_suppresses() {
    let report = lint_one(
        "src/select/fx.rs",
        "fn s(v: &mut Vec<f64>) {\n    // lint: allow(float_order_discipline) — fixture exercises suppression\n    v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));\n}\n",
    );
    assert!(report.clean(), "{report}");
    assert_eq!(report.suppressed.len(), 1);
}

// ---------------------------------------------------------------------------
// cancellation_discipline

const CANCEL_ROOT: &str =
    "pub fn order_statistic(ev: &mut Ev, k: usize) -> f64 {\n    probe_loop(ev, k)\n}\n";

#[test]
fn cancellation_fires_on_unpolled_pass_loop() {
    let src = format!(
        "{CANCEL_ROOT}fn probe_loop(ev: &mut Ev, k: usize) -> f64 {{\n    let mut y = 0.0;\n    while y < 10.0 {{\n        let s = ev.probe(y);\n        y += s;\n    }}\n    y\n}}\n"
    );
    let report = lint_one("src/select/fx.rs", &src);
    assert_eq!(rules_of(&report), ["cancellation_discipline"]);
    assert!(report.findings[0].message.contains("probe_loop"));
}

#[test]
fn cancellation_accepts_polled_pass_loops_and_non_pass_loops() {
    let src = format!(
        "{CANCEL_ROOT}fn probe_loop(ev: &mut Ev, k: usize) -> f64 {{\n    let mut y = 0.0;\n    while y < 10.0 {{\n        if cancel().is_some() {{\n            return y;\n        }}\n        let s = ev.probe(y);\n        y += s;\n    }}\n    for i in 0..3 {{\n        y += i as f64;\n    }}\n    y\n}}\n"
    );
    let report = lint_one("src/select/fx.rs", &src);
    assert!(report.clean(), "{report}");
}

#[test]
fn cancellation_rule_is_inert_without_a_root_in_scope() {
    // Same unpolled loop, but no order_statistic/solve_group in the scan:
    // small fixture sets must not arm the rule.
    let src = "fn probe_loop(ev: &mut Ev) -> f64 {\n    let mut y = 0.0;\n    while y < 10.0 {\n        y += ev.probe(y);\n    }\n    y\n}\n";
    assert!(lint_one("src/select/fx.rs", src).clean());
}

#[test]
fn cancellation_skips_the_pass_primitives_themselves() {
    // A fn *named* like a primitive is the pass implementation: its
    // internal fan-out loop (shards, chunks) runs within one pass.
    let src = format!(
        "{CANCEL_ROOT}fn probe_loop(ev: &mut Ev, k: usize) -> f64 {{\n    ev.probe(k as f64)\n}}\nfn probe(shards: &mut Vec<Sh>, y: f64) -> f64 {{\n    let mut acc = 0.0;\n    for s in shards.iter_mut() {{\n        acc += s.probe(y);\n    }}\n    acc\n}}\n"
    );
    let report = lint_one("src/select/fx.rs", &src);
    assert!(report.clean(), "{report}");
}

#[test]
fn cancellation_registry_flags_entries_that_grew_a_poll() {
    let src = "pub fn order_statistic(ev: &mut Ev) -> f64 {\n    bisect_resolve(ev)\n}\nfn bisect_resolve(ev: &mut Ev) -> f64 {\n    if cancel().is_some() {\n        return 0.0;\n    }\n    ev.probe(1.0)\n}\n";
    let report = lint_one("src/select/fx.rs", src);
    assert_eq!(rules_of(&report), ["cancellation_discipline"]);
    assert!(report.findings[0].message.contains("polls the cancel hook"));
}

#[test]
fn cancellation_registry_flags_unreachable_entries() {
    let src = "pub fn order_statistic(ev: &mut Ev) -> f64 {\n    ev.probe(0.0)\n}\nfn bisect_resolve(ev: &mut Ev) -> f64 {\n    ev.probe(1.0)\n}\n";
    let report = lint_one("src/select/fx.rs", src);
    assert_eq!(rules_of(&report), ["cancellation_discipline"]);
    assert!(report.findings[0].message.contains("no longer reachable"));
}

#[test]
fn cancellation_pragma_suppresses_at_the_loop_head() {
    let src = format!(
        "{CANCEL_ROOT}fn probe_loop(ev: &mut Ev, k: usize) -> f64 {{\n    let mut y = 0.0;\n    // lint: allow(cancellation_discipline) — fixture exercises suppression\n    while y < 10.0 {{\n        y += ev.probe(y);\n    }}\n    y\n}}\n"
    );
    let report = lint_one("src/select/fx.rs", &src);
    assert!(report.clean(), "{report}");
    assert_eq!(report.suppressed.len(), 1);
}

// ---------------------------------------------------------------------------
// error_discipline

#[test]
fn error_discipline_flags_panics_on_worker_paths() {
    let report = lint_one(
        "src/runtime/fx.rs",
        r#"
fn f(v: Option<u32>) -> u32 {
    v.unwrap()
}
fn g(v: Option<u32>) -> u32 {
    v.expect("present")
}
fn h(x: u32) -> u32 {
    match x {
        0 => panic!("zero"),
        1 => unreachable!(),
        n => n,
    }
}
"#,
    );
    assert_eq!(rules_of(&report), ["error_discipline"; 4]);
}

#[test]
fn error_discipline_accepts_fallible_recovery_and_asserts() {
    let report = lint_one(
        "src/runtime/fx.rs",
        r#"
fn f(v: Option<u32>) -> u32 {
    assert!(v.is_some() || true);
    v.unwrap_or_default()
}
fn g(v: Option<u32>) -> u32 {
    v.unwrap_or_else(|| 7)
}
"#,
    );
    assert!(report.clean(), "{report}");
}

#[test]
fn error_discipline_scope_excludes_util_and_test_modules() {
    let src = "fn f(v: Option<u32>) -> u32 {\n    v.unwrap()\n}\n";
    assert!(lint_one("src/util/fx.rs", src).clean());
    assert!(lint_one("src/testkit/fx.rs", src).clean());
    assert_eq!(rules_of(&lint_one("src/cluster/fx.rs", src)), ["error_discipline"]);
    let test_mod = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        Some(1).unwrap();\n        panic!(\"fine in tests\");\n    }\n}\n";
    assert!(lint_one("src/select/fx.rs", test_mod).clean());
}

#[test]
fn error_discipline_pragma_suppresses() {
    let report = lint_one(
        "src/select/fx.rs",
        "fn f(v: Option<u32>) -> u32 {\n    // lint: allow(error_discipline) — fixture exercises suppression\n    v.unwrap()\n}\n",
    );
    assert!(report.clean(), "{report}");
    assert_eq!(report.suppressed.len(), 1);
}

// ---------------------------------------------------------------------------
// atomic_ordering

#[test]
fn atomic_ordering_flags_non_relaxed_counter_access() {
    let report = lint_two(
        ("src/coordinator/metrics.rs", METRICS_CLEAN),
        (
            "src/coordinator/ingest.rs",
            "fn bump(m: &Metrics) {\n    m.uploads.fetch_add(1, Ordering::SeqCst);\n}\n",
        ),
    );
    assert_eq!(rules_of(&report), ["atomic_ordering"]);
    assert!(report.findings[0].message.contains("`uploads`"));
}

#[test]
fn atomic_ordering_accepts_relaxed_everywhere() {
    let report = lint_two(
        ("src/coordinator/metrics.rs", METRICS_CLEAN),
        (
            "src/coordinator/ingest.rs",
            "fn bump(m: &Metrics) {\n    m.uploads.fetch_add(1, Ordering::Relaxed);\n    let _ = m.uploads.load(Ordering::Relaxed);\n}\n",
        ),
    );
    assert!(report.clean(), "{report}");
}

#[test]
fn atomic_ordering_ignores_non_counter_atomics() {
    let report = lint_two(
        ("src/coordinator/metrics.rs", METRICS_CLEAN),
        (
            "src/coordinator/ingest.rs",
            "fn flag(stop: &std::sync::atomic::AtomicBool) {\n    stop.store(true, Ordering::SeqCst);\n}\n",
        ),
    );
    assert!(report.clean(), "non-Metrics atomics may order as they like:\n{report}");
}

#[test]
fn atomic_ordering_pragma_suppresses() {
    let report = lint_two(
        ("src/coordinator/metrics.rs", METRICS_CLEAN),
        (
            "src/coordinator/ingest.rs",
            "fn bump(m: &Metrics) {\n    // lint: allow(atomic_ordering) — fixture exercises suppression\n    m.uploads.fetch_add(1, Ordering::SeqCst);\n}\n",
        ),
    );
    assert!(report.clean(), "{report}");
    assert_eq!(report.suppressed.len(), 1);
}

// ---------------------------------------------------------------------------
// pragma hygiene

#[test]
fn malformed_pragmas_are_findings_and_not_suppressible() {
    let report = lint_one(
        "src/coordinator/worker.rs",
        "// lint: allow(pragma) — an attempt to silence the checker below\n// lint: allow(totally_unknown) — no such rule\n",
    );
    assert_eq!(rules_of(&report), ["pragma"]);
    assert!(report.findings[0].message.contains("totally_unknown"));
    assert!(report.suppressed.is_empty());
}

#[test]
fn pragmas_require_a_justification() {
    let report = lint_one("src/x.rs", "// lint: allow(clock_discipline)\n");
    assert_eq!(rules_of(&report), ["pragma"]);
    assert!(report.findings[0].message.contains("justification"));
}

#[test]
fn pragmas_only_cover_their_rule_and_adjacent_line() {
    let report = lint_one(
        "src/select/pump.rs",
        "// lint: allow(poison_discipline) — wrong rule on purpose\nfn nap() {\n    std::thread::sleep(std::time::Duration::from_millis(1));\n}\n",
    );
    assert_eq!(rules_of(&report), ["clock_discipline"]);
    assert!(report.suppressed.is_empty());
}

// ---------------------------------------------------------------------------
// JSON output

#[test]
fn json_report_round_trips_through_the_schema() {
    let report = lint_one(
        "src/select/pump.rs",
        "fn nap() {\n    std::thread::sleep(std::time::Duration::from_millis(1));\n}\nfn nap2() {\n    // lint: allow(clock_discipline) — fixture exercises suppression\n    std::thread::sleep(std::time::Duration::from_millis(1));\n}\n",
    );
    assert_eq!(report.findings.len(), 1);
    assert_eq!(report.suppressed.len(), 1);

    let v = Json::parse(&report.to_json()).expect("lint --format json must be valid JSON");
    assert_eq!(v.get("version").unwrap().as_usize().unwrap(), 1);
    assert_eq!(v.get("files").unwrap().as_usize().unwrap(), 1);
    assert_eq!(v.get("suppressed").unwrap().as_usize().unwrap(), 1);
    let findings = v.get("findings").unwrap().as_arr().unwrap();
    assert_eq!(findings.len(), 2, "active and suppressed findings are both present");
    for f in findings {
        assert_eq!(f.get("rule").unwrap().as_str().unwrap(), "clock_discipline");
        assert_eq!(f.get("file").unwrap().as_str().unwrap(), "src/select/pump.rs");
        assert!(f.get("line").unwrap().as_usize().unwrap() > 0);
        assert!(!f.get("message").unwrap().as_str().unwrap().is_empty());
        f.get("suppressed").expect("every finding carries the suppressed tag");
    }
    let tags: Vec<bool> = findings
        .iter()
        .map(|f| matches!(f.get("suppressed"), Ok(cp_select::util::json::Json::Bool(true))))
        .collect();
    assert_eq!(tags.iter().filter(|&&t| t).count(), 1, "exactly one suppressed entry");
}

#[test]
fn json_escapes_pathological_messages() {
    // A path with quotes/backslashes must not break the document.
    let report = lint_one(
        r#"src\select\we"ird.rs"#,
        "fn nap() {\n    std::thread::sleep(std::time::Duration::from_millis(1));\n}\n",
    );
    let v = Json::parse(&report.to_json()).expect("escaping must keep the JSON valid");
    let findings = v.get("findings").unwrap().as_arr().unwrap();
    assert_eq!(findings[0].get("file").unwrap().as_str().unwrap(), r#"src\select\we"ird.rs"#);
}

// ---------------------------------------------------------------------------
// the real tree

#[test]
fn real_tree_is_lint_clean() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let roots: Vec<std::path::PathBuf> =
        ["src", "tests", "benches"].iter().map(|d| root.join(d)).collect();
    let report = cp_select::analysis::lint_paths(&roots).expect("lint walks the tree");
    assert!(report.clean(), "expected a lint-clean tree, got:\n{report}");
    assert!(report.files > 50, "expected to scan the whole crate, saw {} files", report.files);

    // Exact suppression inventory: every pragma in the tree is accounted
    // for here, so a new suppression is a reviewed, deliberate act.
    let mut inventory: Vec<(&'static str, &str)> = report
        .suppressed
        .iter()
        .map(|f| (f.rule, f.path.rsplit('/').next().unwrap_or(f.path.as_str())))
        .collect();
    inventory.sort_unstable();
    assert_eq!(
        inventory,
        [
            ("clock_discipline", "timer.rs"),
            ("error_discipline", "multisection.rs"),
            ("error_discipline", "objective.rs"),
            ("error_discipline", "objective.rs"),
        ],
        "suppression inventory drifted — update this list only with a justified pragma"
    );
}
