//! Fixture tests for the in-repo invariant linter (`cp_select::analysis`).
//! Every rule is exercised three ways — a known-bad snippet that must
//! fire, a clean snippet that must not, and a pragma-suppressed snippet —
//! plus a self-check that the real tree is lint-clean.

use cp_select::analysis::{lint_files, Report, SourceFile};

fn lint_one(path: &str, src: &str) -> Report {
    lint_files(&[SourceFile { path: path.to_string(), src: src.to_string() }])
}

fn lint_two(a: (&str, &str), b: (&str, &str)) -> Report {
    lint_files(&[
        SourceFile { path: a.0.to_string(), src: a.1.to_string() },
        SourceFile { path: b.0.to_string(), src: b.1.to_string() },
    ])
}

fn rules_of(report: &Report) -> Vec<&'static str> {
    report.findings.iter().map(|f| f.rule).collect()
}

// ---------------------------------------------------------------------------
// clock_discipline

#[test]
fn clock_discipline_fires_outside_wall_clock_files() {
    let report = lint_one(
        "src/coordinator/worker.rs",
        r#"
use std::time::Instant;
fn stamp() {
    let t0 = Instant::now();
    let _ = t0;
}
"#,
    );
    assert_eq!(rules_of(&report), ["clock_discipline"]);
    assert_eq!(report.findings[0].line, 4);
}

#[test]
fn clock_discipline_flags_sleep_outside_benches() {
    let report = lint_one(
        "src/select/pump.rs",
        "fn nap() {\n    std::thread::sleep(std::time::Duration::from_millis(1));\n}\n",
    );
    assert_eq!(rules_of(&report), ["clock_discipline"]);
}

#[test]
fn clock_discipline_allows_the_wall_clock_files() {
    let src = "fn stamp() -> std::time::Instant {\n    std::time::Instant::now()\n}\n";
    assert!(lint_one("src/testkit/clock.rs", src).clean());
    assert!(lint_one("src/harness/mod.rs", src).clean());
    let nap = "fn nap() {\n    std::thread::sleep(std::time::Duration::from_millis(1));\n}\n";
    assert!(lint_one("benches/scaling.rs", nap).clean());
}

#[test]
fn clock_discipline_pragma_suppresses_with_justification() {
    let report = lint_one(
        "src/select/pump.rs",
        "fn nap() {\n    // lint: allow(clock_discipline) — fixture exercises suppression\n    std::thread::sleep(std::time::Duration::from_millis(1));\n}\n",
    );
    assert!(report.clean(), "{report}");
    assert_eq!(report.suppressed, 1);
}

// ---------------------------------------------------------------------------
// poison_discipline

#[test]
fn poison_discipline_flags_unwrap_expect_and_question_mark() {
    let report = lint_one(
        "src/coordinator/state.rs",
        r#"
fn read(m: &std::sync::Mutex<u32>) -> u32 {
    *m.lock().unwrap()
}
fn read2(m: &std::sync::Mutex<u32>) -> u32 {
    *m.lock().expect("poisoned")
}
fn read3(m: &std::sync::Mutex<u32>) -> Result<u32, Box<dyn std::error::Error>> {
    Ok(*m.lock()?)
}
"#,
    );
    assert_eq!(rules_of(&report), ["poison_discipline"; 3]);
}

#[test]
fn poison_discipline_rejects_recovery_that_drops_the_guard() {
    let report = lint_one(
        "src/coordinator/state.rs",
        "fn read(m: &std::sync::Mutex<u32>) -> u32 {\n    *m.lock().unwrap_or_else(|_| todo!())\n}\n",
    );
    assert_eq!(rules_of(&report), ["poison_discipline"]);
}

#[test]
fn poison_discipline_accepts_recovery_and_bare_lock() {
    let report = lint_one(
        "src/coordinator/state.rs",
        r#"
fn read(m: &std::sync::Mutex<u32>) -> u32 {
    *m.lock().unwrap_or_else(|e| e.into_inner())
}
fn guard(m: &OrderedMutex<u32>) -> u32 {
    let g = m.lock();
    *g
}
"#,
    );
    assert!(report.clean(), "{report}");
}

#[test]
fn poison_discipline_pragma_suppresses() {
    let report = lint_one(
        "src/coordinator/state.rs",
        r#"
fn read(m: &std::sync::Mutex<u32>) -> u32 {
    // lint: allow(poison_discipline) — fixture exercises suppression
    *m.lock().unwrap()
}
"#,
    );
    assert!(report.clean(), "{report}");
    assert_eq!(report.suppressed, 1);
}

// ---------------------------------------------------------------------------
// panic_boundary

const BACKEND_TRAIT: &str = r#"
pub trait DatasetBackend {
    fn upload(&mut self, n: usize) -> bool;
    fn drop_dataset(&mut self, id: u32) -> bool;
}
"#;

#[test]
fn panic_boundary_fires_on_unprotected_backend_call() {
    let report = lint_two(
        ("src/coordinator/backend.rs", BACKEND_TRAIT),
        (
            "src/coordinator/service.rs",
            r#"
fn worker(backend: &mut dyn DatasetBackend) {
    backend.upload(3);
}
"#,
        ),
    );
    assert_eq!(rules_of(&report), ["panic_boundary"]);
    assert!(report.findings[0].message.contains("upload"));
}

#[test]
fn panic_boundary_accepts_catch_unwind_and_protected_helpers() {
    let report = lint_two(
        ("src/coordinator/backend.rs", BACKEND_TRAIT),
        (
            "src/coordinator/service.rs",
            r#"
fn run_query(backend: &mut dyn DatasetBackend) -> bool {
    backend.upload(3)
}
fn worker(backend: &mut dyn DatasetBackend) {
    let _ = catch_unwind(AssertUnwindSafe(|| backend.upload(1)));
    let _ = catch_unwind(AssertUnwindSafe(|| run_query(backend)));
}
"#,
        ),
    );
    assert!(report.clean(), "{report}");
}

#[test]
fn panic_boundary_only_applies_to_the_service_file() {
    let report = lint_two(
        ("src/coordinator/backend.rs", BACKEND_TRAIT),
        (
            "src/coordinator/ingest.rs",
            "fn feed(backend: &mut dyn DatasetBackend) {\n    backend.upload(3);\n}\n",
        ),
    );
    assert!(report.clean(), "{report}");
}

#[test]
fn panic_boundary_pragma_suppresses() {
    let report = lint_two(
        ("src/coordinator/backend.rs", BACKEND_TRAIT),
        (
            "src/coordinator/service.rs",
            r#"
fn worker(backend: &mut dyn DatasetBackend) {
    // lint: allow(panic_boundary) — fixture exercises suppression
    backend.upload(3);
}
"#,
        ),
    );
    assert!(report.clean(), "{report}");
    assert_eq!(report.suppressed, 1);
}

// ---------------------------------------------------------------------------
// metrics_triple_entry

const METRICS_CLEAN: &str = r#"
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};

pub struct Metrics {
    pub uploads: AtomicU64,
}

pub struct Snapshot {
    pub uploads: u64,
}

impl Metrics {
    pub fn snapshot(&self) -> Snapshot {
        Snapshot { uploads: self.uploads.load(Ordering::Relaxed) }
    }
}

impl fmt::Display for Snapshot {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "uploads {}", self.uploads)
    }
}
"#;

#[test]
fn metrics_triple_entry_clean_when_all_legs_present() {
    let report = lint_one("src/coordinator/metrics.rs", METRICS_CLEAN);
    assert!(report.clean(), "{report}");
}

#[test]
fn metrics_triple_entry_fires_once_per_missing_leg() {
    let src = METRICS_CLEAN.replace(
        "pub uploads: AtomicU64,",
        "pub uploads: AtomicU64,\n    pub shed: AtomicU64,",
    );
    let report = lint_one("src/coordinator/metrics.rs", &src);
    assert_eq!(rules_of(&report), ["metrics_triple_entry"; 3]);
    for f in &report.findings {
        assert!(f.message.contains("`shed`"), "{f}");
    }
}

#[test]
fn metrics_triple_entry_pragma_suppresses_all_legs() {
    let src = METRICS_CLEAN.replace(
        "pub uploads: AtomicU64,",
        "pub uploads: AtomicU64,\n    // lint: allow(metrics_triple_entry) — fixture counter is deliberately unplumbed\n    pub shed: AtomicU64,",
    );
    let report = lint_one("src/coordinator/metrics.rs", &src);
    assert!(report.clean(), "{report}");
    assert_eq!(report.suppressed, 3);
}

#[test]
fn metrics_triple_entry_requires_the_snapshot_plumbing() {
    let report = lint_one(
        "src/coordinator/metrics.rs",
        "use std::sync::atomic::AtomicU64;\npub struct Metrics {\n    pub uploads: AtomicU64,\n}\n",
    );
    assert_eq!(rules_of(&report), ["metrics_triple_entry"]);
    assert!(report.findings[0].message.contains("Snapshot"));
}

// ---------------------------------------------------------------------------
// lock_order

const LOCK_CYCLE: &str = r#"
use std::sync::Mutex;

pub struct Pair {
    a: Mutex<u32>,
    b: Mutex<u32>,
}

impl Pair {
    fn ab(&self) -> u32 {
        let ga = self.a.lock().unwrap_or_else(|e| e.into_inner());
        let gb = self.b.lock().unwrap_or_else(|e| e.into_inner());
        *ga + *gb
    }
    fn ba(&self) -> u32 {
        let gb = self.b.lock().unwrap_or_else(|e| e.into_inner());
        let ga = self.a.lock().unwrap_or_else(|e| e.into_inner());
        *ga + *gb
    }
}
"#;

#[test]
fn lock_order_cycle_is_a_finding() {
    let report = lint_one("src/coordinator/pair.rs", LOCK_CYCLE);
    assert_eq!(rules_of(&report), ["lock_order"]);
    let msg = &report.findings[0].message;
    assert!(msg.contains("pair.a") && msg.contains("pair.b"), "{msg}");
}

#[test]
fn lock_order_consistent_nesting_is_clean() {
    let src = LOCK_CYCLE.replace(
        "let gb = self.b.lock().unwrap_or_else(|e| e.into_inner());\n        let ga = self.a.lock().unwrap_or_else(|e| e.into_inner());",
        "let ga = self.a.lock().unwrap_or_else(|e| e.into_inner());\n        let gb = self.b.lock().unwrap_or_else(|e| e.into_inner());",
    );
    let report = lint_one("src/coordinator/pair.rs", &src);
    assert!(report.clean(), "{report}");
}

#[test]
fn lock_order_drop_releases_the_guard() {
    let src = LOCK_CYCLE.replace(
        "let gb = self.b.lock().unwrap_or_else(|e| e.into_inner());\n        let ga = self.a.lock().unwrap_or_else(|e| e.into_inner());\n        *ga + *gb",
        "let gb = self.b.lock().unwrap_or_else(|e| e.into_inner());\n        let x = *gb;\n        drop(gb);\n        let ga = self.a.lock().unwrap_or_else(|e| e.into_inner());\n        *ga + x",
    );
    let report = lint_one("src/coordinator/pair.rs", &src);
    assert!(report.clean(), "dropping the guard ends its held scope:\n{report}");
}

#[test]
fn lock_order_pragma_suppresses_at_the_cycle_anchor() {
    let src = LOCK_CYCLE.replace(
        "let gb = self.b.lock().unwrap_or_else(|e| e.into_inner());\n        let ga =",
        "let gb = self.b.lock().unwrap_or_else(|e| e.into_inner());\n        // lint: allow(lock_order) — fixture exercises suppression\n        let ga =",
    );
    let report = lint_one("src/coordinator/pair.rs", &src);
    assert!(report.clean(), "{report}");
    assert_eq!(report.suppressed, 1);
}

// ---------------------------------------------------------------------------
// pragma hygiene

#[test]
fn malformed_pragmas_are_findings_and_not_suppressible() {
    let report = lint_one(
        "src/coordinator/worker.rs",
        "// lint: allow(pragma) — an attempt to silence the checker below\n// lint: allow(totally_unknown) — no such rule\n",
    );
    assert_eq!(rules_of(&report), ["pragma"]);
    assert!(report.findings[0].message.contains("totally_unknown"));
    assert_eq!(report.suppressed, 0);
}

#[test]
fn pragmas_require_a_justification() {
    let report = lint_one("src/x.rs", "// lint: allow(clock_discipline)\n");
    assert_eq!(rules_of(&report), ["pragma"]);
    assert!(report.findings[0].message.contains("justification"));
}

#[test]
fn pragmas_only_cover_their_rule_and_adjacent_line() {
    let report = lint_one(
        "src/select/pump.rs",
        "// lint: allow(poison_discipline) — wrong rule on purpose\nfn nap() {\n    std::thread::sleep(std::time::Duration::from_millis(1));\n}\n",
    );
    assert_eq!(rules_of(&report), ["clock_discipline"]);
    assert_eq!(report.suppressed, 0);
}

// ---------------------------------------------------------------------------
// the real tree

#[test]
fn real_tree_is_lint_clean() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let roots: Vec<std::path::PathBuf> =
        ["src", "tests", "benches"].iter().map(|d| root.join(d)).collect();
    let report = cp_select::analysis::lint_paths(&roots).expect("lint walks the tree");
    assert!(report.clean(), "expected a lint-clean tree, got:\n{report}");
    assert!(report.files > 50, "expected to scan the whole crate, saw {} files", report.files);
    assert!(report.suppressed >= 1, "the util/timer.rs sleep pragma should be tallied");
}
