//! Property suites for cross-worker `PassCostModel` pooling: merge
//! order/partition independence, degenerate-pool seed retention, and
//! least-squares optimality of the pooled fit against any single worker's.
//!
//! (testkit's `CaseGen` generates selection datasets, not run timings, so
//! these properties drive seeded trial loops over a synthetic observation
//! generator instead.)

use std::time::Duration;

use cp_select::select::{CostModelPool, PassCostModel};
use cp_select::stats::Rng;

/// One `observe_run` call's arguments (a measured shared-ladder run).
#[derive(Debug, Clone, Copy)]
struct Obs {
    passes: usize,
    rungs: u64,
    total: u64,
    n: usize,
    wall: Duration,
}

fn apply(model: &mut PassCostModel, o: &Obs) {
    model.observe_run(o.passes, o.rungs, o.total, o.n, o.wall);
}

/// Synthesize a run from ground-truth coefficients `(a, b)` — the model's
/// own cost law `wall = a·(total·n) + b·((rungs + total − passes)·n)` —
/// with optional multiplicative noise.
fn random_obs(rng: &mut Rng, a: f64, b: f64, noise: f64) -> Obs {
    let widths = [1usize, 2, 3, 5, 7, 11, 15, 23, 31, 63];
    let w = widths[rng.below(widths.len())];
    let passes = 2 + rng.below(8);
    let fixups = rng.below(5);
    let total = (passes + fixups) as u64;
    let n = 1usize << (10 + rng.below(6));
    let rungs = (passes * w) as u64;
    let xa = total as f64 * n as f64;
    let xb = (rungs + fixups as u64) as f64 * n as f64;
    let mut y = a * xa + b * xb;
    if noise > 0.0 {
        y *= 1.0 + noise * (rng.f64() * 2.0 - 1.0);
    }
    Obs { passes, rungs, total, n, wall: Duration::from_secs_f64(y) }
}

/// Residual sum of squares of `model`'s in-force coefficients over `obs`,
/// in the regression's own (xa, xb) coordinates.
fn rss(model: &PassCostModel, obs: &[Obs]) -> f64 {
    let (a, b) = model.coefficients();
    obs.iter()
        .map(|o| {
            let xa = o.total as f64 * o.n as f64;
            let xb = (o.rungs as f64 + (o.total - o.passes as u64) as f64) * o.n as f64;
            let r = o.wall.as_secs_f64() - (a * xa + b * xb);
            r * r
        })
        .sum()
}

#[test]
fn prop_merge_is_order_and_partition_independent() {
    // Any permutation of the observation set, distributed over any
    // partition into workers, merged in any order, fits like one model
    // that saw every run directly: identical planned width, coefficients
    // equal to float-rounding of the sufficient-statistic sums.
    let mut rng = Rng::seeded(501);
    for trial in 0..40 {
        let m = 8 + rng.below(17);
        let obs: Vec<Obs> = (0..m).map(|_| random_obs(&mut rng, 2e-9, 4e-10, 0.0)).collect();
        let mut whole = PassCostModel::seeded();
        for o in &obs {
            apply(&mut whole, o);
        }
        // random permutation (Fisher–Yates) → random partition → rotated
        // merge order
        let mut perm: Vec<usize> = (0..obs.len()).collect();
        for i in (1..perm.len()).rev() {
            let j = rng.below(i + 1);
            perm.swap(i, j);
        }
        let workers = 1 + rng.below(4);
        let mut parts = vec![PassCostModel::seeded(); workers];
        for (pos, &idx) in perm.iter().enumerate() {
            apply(&mut parts[pos % workers], &obs[idx]);
        }
        let mut pooled = PassCostModel::seeded();
        let start = rng.below(workers);
        for k in 0..workers {
            pooled.merge(&parts[(start + k) % workers]);
        }
        assert_eq!(pooled.samples(), whole.samples(), "trial {trial}");
        assert_eq!(pooled.best_width(None), whole.best_width(None), "trial {trial}");
        let (pa, pb) = pooled.coefficients();
        let (wa, wb) = whole.coefficients();
        assert!((pa - wa).abs() <= 1e-9 * wa.abs(), "trial {trial}: sweep {pa} vs {wa}");
        assert!((pb - wb).abs() <= 1e-9 * wa.abs(), "trial {trial}: probe {pb} vs {wb}");
    }
}

#[test]
fn prop_degenerate_pools_hold_the_seed_argmin() {
    let seed_coeffs = PassCostModel::seeded().coefficients();

    // merging empty models is still the seed
    let mut pooled = PassCostModel::seeded();
    pooled.merge(&PassCostModel::seeded());
    pooled.merge(&PassCostModel::seeded());
    assert_eq!(pooled.samples(), 0);
    assert_eq!(pooled.best_width(None), 15);
    assert_eq!(pooled.coefficients(), seed_coeffs);

    // collinear streams (every worker repeats one identical run shape)
    // pool into a zero ratio spread: the merged fit is unidentifiable and
    // the seed argmin of 15 holds no matter how many samples pile up
    let mut rng = Rng::seeded(502);
    for trial in 0..20 {
        let o = random_obs(&mut rng, 2e-9, 4e-10, 0.0);
        let workers = 1 + rng.below(4);
        let mut pooled = PassCostModel::seeded();
        for _ in 0..workers {
            let mut part = PassCostModel::seeded();
            for _ in 0..3 + rng.below(8) {
                apply(&mut part, &o);
            }
            pooled.merge(&part);
        }
        assert!(pooled.samples() >= 3);
        assert_eq!(pooled.best_width(None), 15, "trial {trial}");
        assert_eq!(pooled.coefficients(), seed_coeffs, "trial {trial}");
    }
}

#[test]
fn prop_pooled_fit_never_has_worse_residual_than_any_single_worker() {
    // Least-squares optimality: the pooled fit minimizes the residual sum
    // of squares over the UNION of observations among all linear models —
    // so on shared data it can never lose to any single worker's fit (nor
    // to the seed). Noisy observations make the per-worker fits genuinely
    // differ.
    let mut rng = Rng::seeded(503);
    let seed_coeffs = PassCostModel::seeded().coefficients();
    let mut checked = 0;
    for trial in 0..40 {
        let m = 24 + rng.below(17);
        let obs: Vec<Obs> = (0..m).map(|_| random_obs(&mut rng, 2e-9, 2e-10, 0.05)).collect();
        let workers = 2 + rng.below(3);
        let mut parts = vec![PassCostModel::seeded(); workers];
        for (i, o) in obs.iter().enumerate() {
            apply(&mut parts[i % workers], o);
        }
        let mut pooled = PassCostModel::seeded();
        for p in &parts {
            pooled.merge(p);
        }
        if pooled.coefficients() == seed_coeffs {
            // guards held the seed (unidentifiable draw): optimality says
            // nothing here, and the width is pinned by the seed instead
            assert_eq!(pooled.best_width(None), 15);
            continue;
        }
        checked += 1;
        let rss_pool = rss(&pooled, &obs);
        for (wi, p) in parts.iter().enumerate() {
            let rss_w = rss(p, &obs);
            assert!(
                rss_pool <= rss_w * (1.0 + 1e-9) + 1e-30,
                "trial {trial}: pooled rss {rss_pool} beats worker {wi}'s {rss_w}"
            );
        }
    }
    assert!(checked > 0, "no identifiable pooled fit in 40 trials");
}

#[test]
fn sidecar_persist_is_crash_safe_against_truncated_writes() {
    // `persist` must stage into a temp file and atomically rename, so a
    // crash mid-write can only ever leave (a) the previous intact sidecar
    // plus an orphaned staging file, or (b) the new intact sidecar —
    // never a truncated document at the sidecar path. `load_or_seed`
    // therefore either sees real statistics or (for a corrupt document
    // someone else produced) falls back to the seed, but it never parses
    // half a write into a mangled model.
    let dir = std::env::temp_dir().join(format!("cp_select_cost_pool_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create test dir");
    let sidecar = dir.join("BENCH_select.cost_model.json");

    // boot 1: observe identifiable runs and persist a full document
    let pool = CostModelPool::load_or_seed(&sidecar);
    let mut rng = Rng::seeded(504);
    for _ in 0..12 {
        let o = random_obs(&mut rng, 2e-9, 4e-10, 0.02);
        pool.observe_run(o.passes, o.rungs, o.total, o.n, o.wall);
    }
    let written = pool.persist().expect("persist").expect("sidecar-bound");
    assert_eq!(written, sidecar);
    let samples = pool.samples();
    assert!(samples >= 12);
    let full = std::fs::read_to_string(&sidecar).expect("read sidecar");

    // crash simulation: a writer died after staging only a prefix of the
    // next document. The staging path is pid-qualified and distinct from
    // the sidecar, so the intact previous document is what loaders see.
    let staged = sidecar.with_extension(format!("json.{}.tmp", std::process::id()));
    std::fs::write(&staged, &full[..full.len() / 2]).expect("stage truncated write");
    let reloaded = CostModelPool::load_or_seed(&sidecar);
    assert_eq!(reloaded.samples(), samples, "truncated staging write was observed");
    assert_eq!(
        reloaded.snapshot().coefficients(),
        pool.snapshot().coefficients(),
        "reloaded model differs from the persisted one"
    );

    // a truncated document AT the sidecar path (legacy in-place writer
    // crashed) parses strictly and reseeds instead of loading garbage
    std::fs::write(&sidecar, &full[..full.len() / 2]).expect("truncate sidecar");
    let seeded = CostModelPool::load_or_seed(&sidecar);
    assert_eq!(seeded.samples(), 0, "truncated sidecar must reseed, not half-load");
    assert_eq!(seeded.snapshot().coefficients(), PassCostModel::seeded().coefficients());

    // and persisting over the truncated file repairs it atomically
    seeded.persist().expect("persist over truncated").expect("sidecar-bound");
    let repaired = std::fs::read_to_string(&sidecar).expect("read repaired");
    PassCostModel::from_json(&repaired).expect("repaired sidecar parses");

    let _ = std::fs::remove_dir_all(&dir);
}
