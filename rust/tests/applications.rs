//! Application-level integration: robust regression + kNN through device
//! artifacts (when present) and the host selector; cross-layer consistency.

use cp_select::regression::{
    lms, lts, ols, ContaminatedLinear, HostSelector, LmsOptions, LtsOptions,
};
use cp_select::runtime::{DeviceEvaluator, Kernel, Runtime};
use cp_select::select::{self, DType, Method};
use cp_select::stats::{sorted_median, Rng};

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = Runtime::default_dir();
    dir.join("manifest.json").exists().then_some(dir)
}

fn max_err(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

#[test]
fn breakdown_story_holds() {
    // the paper's qualitative §VI result: OLS breaks at 30% contamination,
    // LMS and LTS recover the true model.
    let mut rng = Rng::seeded(401);
    let gen = ContaminatedLinear {
        n: 600,
        p: 4,
        contamination: 0.3,
        sigma: 0.15,
        ..Default::default()
    };
    let d = gen.generate(&mut rng);
    let x = d.design();
    let mut sel = HostSelector::default();
    let e_ols = max_err(&ols(&x, &d.y).unwrap(), &d.theta);
    let e_lms = max_err(&lms(&x, &d.y, &LmsOptions::default(), &mut sel).unwrap().theta, &d.theta);
    let e_lts = max_err(&lts(&x, &d.y, &LtsOptions::default(), &mut sel).unwrap().theta, &d.theta);
    assert!(e_ols > 1.0, "OLS should break: {e_ols}");
    assert!(e_lms < 0.5, "LMS should survive: {e_lms}");
    assert!(e_lts < 0.5, "LTS should survive: {e_lts}");
}

#[test]
fn lms_selector_backends_agree() {
    // Scoring the same subsets with different median methods must produce
    // the same winner (medians are exact under every method).
    let mut rng = Rng::seeded(402);
    let d = ContaminatedLinear { n: 300, p: 3, contamination: 0.25, ..Default::default() }
        .generate(&mut rng);
    let x = d.design();
    let opts = LmsOptions { subsets: 120, adjust_intercept: false, ..Default::default() };
    let mut sel_a = HostSelector { method: Method::Hybrid };
    let mut sel_b = HostSelector { method: Method::Bisection };
    let fit_a = lms(&x, &d.y, &opts, &mut sel_a).unwrap();
    let fit_b = lms(&x, &d.y, &opts, &mut sel_b).unwrap();
    assert_eq!(fit_a.theta, fit_b.theta);
    assert_eq!(fit_a.med_abs_residual, fit_b.med_abs_residual);
}

#[test]
fn device_residual_pipeline_matches_host() {
    let Some(dir) = artifacts() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let rt = Runtime::new(&dir).unwrap();
    let mut rng = Rng::seeded(403);
    let p = 8;
    let d = ContaminatedLinear { n: 2000, p, contamination: 0.2, ..Default::default() }
        .generate(&mut rng);
    let theta: Vec<f64> = (0..p).map(|i| 0.3 * i as f64 - 1.0).collect();

    // host residuals
    let x = d.design();
    let host_r: Vec<f64> = cp_select::regression::residuals(&x, &theta, &d.y)
        .iter()
        .map(|v| v.abs())
        .collect();

    // device residuals via the AOT artifact
    let n = d.n();
    let bucket = rt
        .manifest
        .bucket_for(Kernel::Residuals, rt.flavor, DType::F64, n, Some(p))
        .unwrap();
    let exe = rt
        .executable(Kernel::Residuals, rt.flavor, DType::F64, bucket, Some(p))
        .unwrap();
    let xb = rt.upload_matrix(&d.x_flat(), n, p, DType::F64, bucket).unwrap();
    let yb = rt.upload_vector(&d.y, DType::F64, bucket).unwrap();
    let tb = rt.upload_vector(&theta, DType::F64, p).unwrap();
    let out = exe.run(&[&xb, &yb, &tb]).unwrap();
    let mut dev_r =
        cp_select::runtime::client::literal_vec_f64(&out[0], DType::F64).unwrap();
    dev_r.truncate(n);

    for (a, b) in host_r.iter().zip(&dev_r) {
        assert!((a - b).abs() <= 1e-9 * b.abs().max(1.0));
    }

    // median of residuals on device == host oracle
    let want = sorted_median(&dev_r);
    let mut ev = DeviceEvaluator::upload(&rt, &dev_r, DType::F64).unwrap();
    let got = select::median(&mut ev, Method::CuttingPlane).unwrap();
    assert_eq!(got.value, want);
}

#[test]
fn device_lms_probe_fused_graph_matches_composed() {
    // the fused lms_probe artifact == residuals artifact + fused_objective
    let Some(dir) = artifacts() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let rt = Runtime::new(&dir).unwrap();
    let mut rng = Rng::seeded(404);
    let p = 8;
    let n = 1500;
    let d = ContaminatedLinear { n, p, contamination: 0.1, ..Default::default() }
        .generate(&mut rng);
    let theta: Vec<f64> = (0..p).map(|i| 0.1 * (i as f64 + 1.0)).collect();
    let t = 0.9;

    let bucket = rt
        .manifest
        .bucket_for(Kernel::LmsProbe, rt.flavor, DType::F64, n, Some(p))
        .unwrap();
    let exe = rt
        .executable(Kernel::LmsProbe, rt.flavor, DType::F64, bucket, Some(p))
        .unwrap();
    let xb = rt.upload_matrix(&d.x_flat(), n, p, DType::F64, bucket).unwrap();
    let yb = rt.upload_vector(&d.y, DType::F64, bucket).unwrap();
    let thb = rt.upload_vector(&theta, DType::F64, p).unwrap();
    let tb = rt.upload_scalar(t, DType::F64).unwrap();
    let nv = rt.upload_i32(n as i32).unwrap();
    let out = exe.run(&[&xb, &yb, &thb, &tb, &nv]).unwrap();
    assert_eq!(out.len(), 5);
    let s_lo = cp_select::runtime::client::literal_scalar_f64(&out[0], DType::F64).unwrap();
    let c_lt = cp_select::runtime::client::literal_scalar_i32(&out[2]).unwrap();

    // composed host reference
    let x = d.design();
    let abs_r: Vec<f64> = cp_select::regression::residuals(&x, &theta, &d.y)
        .iter()
        .map(|v| v.abs())
        .collect();
    let mut ev = cp_select::select::HostEvaluator::new(&abs_r);
    let s = cp_select::select::objective::Evaluator::probe(&mut ev, t).unwrap();
    assert_eq!(c_lt as u64, s.c_lt);
    assert!((s_lo - s.s_lo).abs() <= 1e-9 * s.s_lo.max(1.0));
}

#[test]
fn knn_device_kernels_match_host_model() {
    let Some(dir) = artifacts() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let rt = Runtime::new(&dir).unwrap();
    let mut rng = Rng::seeded(405);
    let (n, p, k) = (1000, 8, 7);
    let mut rows = Vec::new();
    let mut fvals = Vec::new();
    for _ in 0..n {
        let row: Vec<f64> = (0..p).map(|_| rng.range(0.0, 1.0)).collect();
        fvals.push(row.iter().sum::<f64>());
        rows.push(row);
    }
    let model = cp_select::knn::KnnModel::new(rows.clone(), fvals.clone()).unwrap();
    let mut sel = HostSelector::default();
    let q: Vec<f64> = (0..p).map(|_| 0.5).collect();
    let host_pred = model.predict_regression(&q, k, &mut sel).unwrap();

    // device: dists -> OS_k -> knn_weighted_sum
    let bucket = rt
        .manifest
        .bucket_for(Kernel::Dists, rt.flavor, DType::F64, n, Some(p))
        .unwrap();
    let exe = rt.executable(Kernel::Dists, rt.flavor, DType::F64, bucket, Some(p)).unwrap();
    let x_flat: Vec<f64> = rows.iter().flatten().copied().collect();
    let xb = rt.upload_matrix(&x_flat, n, p, DType::F64, bucket).unwrap();
    let qb = rt.upload_vector(&q, DType::F64, p).unwrap();
    let out = exe.run(&[&xb, &qb]).unwrap();
    let mut dists = cp_select::runtime::client::literal_vec_f64(&out[0], DType::F64).unwrap();
    dists.truncate(n);

    let mut ev = DeviceEvaluator::upload(&rt, &dists, DType::F64).unwrap();
    let t = select::order_statistic(&mut ev, k, Method::CuttingPlane).unwrap().value;

    let kb = rt
        .manifest
        .bucket_for(Kernel::KnnWeightedSum, rt.flavor, DType::F64, n, None)
        .unwrap();
    let exe = rt
        .executable(Kernel::KnnWeightedSum, rt.flavor, DType::F64, kb, None)
        .unwrap();
    let db = rt.upload_vector(&dists, DType::F64, kb).unwrap();
    let fb = rt.upload_vector(&fvals, DType::F64, kb).unwrap();
    let tb = rt.upload_scalar(t, DType::F64).unwrap();
    let nv = rt.upload_i32(n as i32).unwrap();
    let out = exe.run(&[&db, &fb, &tb, &nv]).unwrap();
    let swf = cp_select::runtime::client::literal_scalar_f64(&out[0], DType::F64).unwrap();
    let sw = cp_select::runtime::client::literal_scalar_f64(&out[1], DType::F64).unwrap();
    let count = cp_select::runtime::client::literal_scalar_i32(&out[2]).unwrap();

    assert!(count as usize >= k);
    let dev_pred = swf / sw;
    assert!(
        (dev_pred - host_pred).abs() <= 1e-9 * host_pred.abs().max(1.0),
        "device {dev_pred} vs host {host_pred}"
    );
}

#[test]
fn lts_rho_trick_equals_sorted_definition_large() {
    let mut rng = Rng::seeded(406);
    let r: Vec<f64> = (0..50_000).map(|_| rng.normal().abs()).collect();
    let h = cp_select::util::lts_h(r.len());
    let mut sel = HostSelector::default();
    let got = cp_select::regression::trimmed_sum_via_median(&r, h, &mut sel).unwrap();
    let mut sorted = r.clone();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let want: f64 = sorted[..h].iter().map(|v| v * v).sum();
    assert!((got - want).abs() <= 1e-9 * want);
}
