//! Coordinator end-to-end: service over host and device backends, failure
//! injection, concurrent load, window coalescing, metrics consistency.

use std::sync::{Arc, Barrier};
use std::time::Duration;

use cp_select::coordinator::{
    BackendFactory, CoordinatorOptions, DatasetBackend, DeviceBackend, HostBackend, KSpec,
    SelectionService,
};
use cp_select::runtime::{Flavor, Runtime};
use cp_select::select::{DType, Method};
use cp_select::stats::{sorted_median, sorted_order_statistic, Distribution, Rng};

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = Runtime::default_dir();
    dir.join("manifest.json").exists().then_some(dir)
}

#[test]
fn service_survives_sustained_concurrent_load() {
    let svc = Arc::new(
        SelectionService::start(4, 32, Method::Hybrid, HostBackend::factory()).unwrap(),
    );
    let mut rng = Rng::seeded(301);
    let data = Distribution::Mixture5.sample_vec(&mut rng, 4096);
    let want = sorted_median(&data);
    let id = svc.upload(data, DType::F64).unwrap();

    let mut handles = Vec::new();
    for t in 0..8 {
        let svc = svc.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..25 {
                let r = svc.query(id, KSpec::Median).unwrap();
                assert_eq!(r.value, want, "thread {t} iter {i}");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let snap = svc.metrics.snapshot();
    assert_eq!(snap.queries, 200);
    assert_eq!(snap.errors, 0);
    assert!(snap.probes > 0);
}

#[test]
fn device_backend_through_service() {
    let Some(dir) = artifacts() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let svc = SelectionService::start(
        2,
        16,
        Method::CuttingPlane,
        DeviceBackend::factory(dir, Flavor::Jnp),
    )
    .unwrap();
    let mut rng = Rng::seeded(302);
    let data = Distribution::HalfNormal.sample_vec(&mut rng, 3000);
    let want_med = sorted_median(&data);
    let want_q9 = sorted_order_statistic(&data, 2700);
    let id = svc.upload(data, DType::F64).unwrap();
    assert_eq!(svc.query(id, KSpec::Median).unwrap().value, want_med);
    assert_eq!(svc.query(id, KSpec::Rank(2700)).unwrap().value, want_q9);
    assert_eq!(svc.query_with(id, KSpec::Median, Method::Hybrid).unwrap().value, want_med);
    svc.shutdown();
}

#[test]
fn failing_backend_factory_degrades_gracefully() {
    struct NoBackend;
    let factory: BackendFactory = Arc::new(|w| {
        if w == 0 {
            Err(cp_select::Error::Service("simulated init failure".into()))
        } else {
            Ok(Box::<HostBackend>::default() as Box<dyn DatasetBackend>)
        }
    });
    let _ = NoBackend;
    let svc = SelectionService::start(1, 4, Method::Hybrid, factory).unwrap();
    // worker 0 failed to init: uploads must error, not hang or panic
    let err = svc.upload(vec![1.0, 2.0], DType::F64).unwrap_err();
    assert!(err.to_string().contains("init failed"), "{err}");
    svc.shutdown();
}

#[test]
fn per_worker_datasets_are_isolated() {
    // Two workers: dataset routing is sticky, so queries must find their
    // data regardless of which client thread asks.
    let svc = SelectionService::start(2, 16, Method::Hybrid, HostBackend::factory()).unwrap();
    let mut ids = Vec::new();
    let mut wants = Vec::new();
    let mut rng = Rng::seeded(303);
    for i in 0..10 {
        let data = Distribution::ALL[i % 9].sample_vec(&mut rng, 257 + 31 * i);
        wants.push(sorted_median(&data));
        ids.push(svc.upload(data, DType::F64).unwrap());
    }
    for (id, want) in ids.iter().zip(&wants) {
        assert_eq!(svc.query(*id, KSpec::Median).unwrap().value, *want);
    }
    svc.shutdown();
}

#[test]
fn shutdown_then_queries_fail_cleanly() {
    let svc = SelectionService::start(1, 4, Method::Hybrid, HostBackend::factory()).unwrap();
    let id = svc.upload(vec![1.0, 2.0, 3.0], DType::F64).unwrap();
    assert_eq!(svc.query(id, KSpec::Median).unwrap().value, 2.0);
    svc.shutdown();
    // service consumed; nothing to assert beyond clean drop (no hang)
}

#[test]
fn mixed_dtypes_one_service() {
    let svc = SelectionService::start(2, 16, Method::Hybrid, HostBackend::factory()).unwrap();
    let data = vec![0.1, 0.2, 0.3, 0.4, 0.5];
    let id64 = svc.upload(data.clone(), DType::F64).unwrap();
    let id32 = svc.upload(data.clone(), DType::F32).unwrap();
    let r64 = svc.query(id64, KSpec::Median).unwrap().value;
    let r32 = svc.query(id32, KSpec::Median).unwrap().value;
    assert_eq!(r64, 0.3);
    assert_eq!(r32, 0.3f32 as f64);
    svc.shutdown();
}

/// Acceptance: 8 threads issuing plain single-shot `query()` calls (no
/// `query_many`, no shared client-side state) against one dataset land in
/// one batching window, coalesce into shared ladder rounds
/// (`coalesced` ≥ 8), and cost strictly less than 8× the single-query run.
#[test]
fn eight_concurrent_clients_coalesce_through_the_window() {
    let svc = Arc::new(
        SelectionService::start_with(
            1,
            64,
            Method::Multisection,
            HostBackend::factory(),
            // cap 8 closes the window as soon as the whole burst is in
            // hand; 250ms is straggler headroom, not a fixed wait
            CoordinatorOptions { batch_window: Duration::from_millis(250), batch_cap: 8 },
        )
        .unwrap(),
    );
    let mut rng = Rng::seeded(305);
    let data = Distribution::Uniform.sample_vec(&mut rng, 1 << 14);
    let want = sorted_median(&data);

    // single-query cost, measured outside the service
    let single = {
        let mut ev = cp_select::select::HostEvaluator::new(&data);
        cp_select::select::median(&mut ev, Method::Multisection).unwrap();
        ev.probes()
    };

    let id = svc.upload(data, DType::F64).unwrap();
    let p0 = svc.metrics.snapshot().probes;
    let barrier = Arc::new(Barrier::new(8));
    let mut handles = Vec::new();
    for _ in 0..8 {
        let svc = svc.clone();
        let barrier = barrier.clone();
        handles.push(std::thread::spawn(move || {
            barrier.wait();
            svc.query(id, KSpec::Median).unwrap()
        }));
    }
    for h in handles {
        let r = h.join().unwrap();
        assert_eq!(r.value, want);
        assert_eq!(r.method, Method::Multisection, "coalesced singles ride the shared engine");
    }
    let snap = svc.metrics.snapshot();
    assert!(snap.coalesced >= 8, "window caught {} of 8 clients", snap.coalesced);
    let burst = snap.probes - p0;
    assert!(
        burst < 8 * single,
        "8 windowed clients cost {burst} fused reductions, not below 8x single {single}"
    );
    // one shared run = one latency sample, 8 queries
    assert_eq!(snap.queries, 8);
    assert!(snap.latency_samples < 8, "expected shared-run latency accounting, {snap}");
    if let Ok(s) = Arc::try_unwrap(svc) {
        s.shutdown();
    }
}

/// Parity: mixed probe-based `Query` singles and `QueryMany` batches
/// against one dataset, planned into one unified group, return exactly the
/// values a sequential run produces.
#[test]
fn mixed_singles_and_query_many_unified_plan_is_exact() {
    let svc = Arc::new(
        SelectionService::start_with(
            1,
            64,
            Method::Multisection,
            HostBackend::factory(),
            // 5 requests total: 4 singles + 1 QueryMany; cap closes early
            CoordinatorOptions { batch_window: Duration::from_millis(150), batch_cap: 5 },
        )
        .unwrap(),
    );
    let mut rng = Rng::seeded(306);
    let data = Distribution::Mixture2.sample_vec(&mut rng, 5000);
    let mut sorted = data.clone();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let id = svc.upload(data, DType::F64).unwrap();

    let single_ks = [100usize, 2500, 4900, 1];
    let many_specs = vec![
        KSpec::Quantile(0.2),
        KSpec::Median,
        KSpec::Rank(3333),
        KSpec::Quantile(0.95),
    ];
    let barrier = Arc::new(Barrier::new(single_ks.len() + 1));
    let mut singles = Vec::new();
    for &k in &single_ks {
        let svc = svc.clone();
        let barrier = barrier.clone();
        singles.push(std::thread::spawn(move || {
            barrier.wait();
            svc.query_with(id, KSpec::Rank(k), Method::Multisection).unwrap()
        }));
    }
    let many = {
        let svc = svc.clone();
        let barrier = barrier.clone();
        let specs = many_specs.clone();
        std::thread::spawn(move || {
            barrier.wait();
            svc.query_many(id, specs, Method::Multisection).unwrap()
        })
    };
    for (h, &k) in singles.into_iter().zip(&single_ks) {
        let r = h.join().unwrap();
        assert_eq!(r.k, k);
        assert_eq!(r.value, sorted[k - 1], "single k={k}");
    }
    let rs = many.join().unwrap();
    assert_eq!(rs.len(), many_specs.len());
    for r in &rs {
        assert_eq!(r.value, sorted[r.k - 1], "query_many k={}", r.k);
    }
    // the interleaved QueryMany no longer breaks single coalescing: the
    // whole mixed burst shares one plan
    let snap = svc.metrics.snapshot();
    assert!(snap.coalesced >= 8, "mixed burst coalesced only {} of 8 specs", snap.coalesced);
    if let Ok(s) = Arc::try_unwrap(svc) {
        s.shutdown();
    }
}

/// Regression (drained-batch reordering): a query fired before a drop of
/// the same dataset must be answered even when both are collected into one
/// batch at a busy worker — the old `(kind, id)` sort ran the drop first
/// and failed the query with "unknown dataset". Window zero exercises the
/// drain-only ingest path.
#[test]
fn query_then_drop_at_a_busy_worker_keeps_fifo() {
    let svc = SelectionService::start_with(
        1,
        64,
        Method::Multisection,
        HostBackend::factory(),
        CoordinatorOptions { batch_window: Duration::ZERO, batch_cap: 64 },
    )
    .unwrap();
    let mut rng = Rng::seeded(307);
    let busy_data = Distribution::Normal.sample_vec(&mut rng, 1 << 20);
    let busy = svc.upload(busy_data, DType::F64).unwrap();
    for round in 0..5 {
        let id = svc.upload(vec![5.0, 1.0, 4.0, 2.0, 3.0], DType::F64).unwrap();
        // occupy the worker so the query+drop pair queues up behind it
        // and drains into a single batch
        let slow = svc.query_async(busy, KSpec::Median, Method::Bisection).unwrap();
        std::thread::sleep(Duration::from_millis(2));
        let rx = svc.query_async(id, KSpec::Median, Method::Multisection).unwrap();
        svc.drop_dataset(id).unwrap();
        let r = rx.recv().unwrap();
        assert_eq!(
            r.expect("query fired before the drop must succeed").value,
            3.0,
            "round {round}"
        );
        assert!(slow.recv().unwrap().is_ok());
        assert!(svc.query(id, KSpec::Median).is_err(), "round {round}: drop must stick");
    }
    svc.shutdown();
}

/// The synchronous drop ack replaces the sleep the fire-and-forget drop
/// needed: the ack IS the ordering guarantee, even with traffic in flight.
#[test]
fn drop_dataset_sync_acks_under_load() {
    let svc = SelectionService::start(2, 32, Method::Multisection, HostBackend::factory()).unwrap();
    let mut rng = Rng::seeded(308);
    for _ in 0..4 {
        let data = Distribution::HalfNormal.sample_vec(&mut rng, 2048);
        let want = sorted_median(&data);
        let id = svc.upload(data, DType::F64).unwrap();
        let inflight = svc.query_async(id, KSpec::Median, Method::Multisection).unwrap();
        assert_eq!(inflight.recv().unwrap().unwrap().value, want);
        svc.drop_dataset_sync(id).unwrap();
        assert!(svc.query(id, KSpec::Median).is_err());
        assert!(svc.drop_dataset_sync(id).is_err(), "double drop reports unknown dataset");
    }
    svc.shutdown();
}

#[test]
fn quantile_ladder_consistency() {
    let svc = SelectionService::start(2, 64, Method::CuttingPlane, HostBackend::factory()).unwrap();
    let mut rng = Rng::seeded(304);
    let data = Distribution::Beta25.sample_vec(&mut rng, 2000);
    let mut sorted = data.clone();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let id = svc.upload(data, DType::F64).unwrap();
    let mut prev = f64::NEG_INFINITY;
    for i in 1..=10 {
        let q = i as f64 / 10.0;
        let r = svc.query(id, KSpec::Quantile(q)).unwrap();
        assert!(r.value >= prev, "quantiles must be monotone");
        let k = ((q * 2000.0).ceil() as usize).clamp(1, 2000);
        assert_eq!(r.value, sorted[k - 1]);
        prev = r.value;
    }
    svc.shutdown();
}
