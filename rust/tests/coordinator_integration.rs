//! Coordinator end-to-end: service over host and device backends, failure
//! injection, concurrent load, metrics consistency.

use std::sync::Arc;

use cp_select::coordinator::{
    BackendFactory, DatasetBackend, DeviceBackend, HostBackend, KSpec, SelectionService,
};
use cp_select::runtime::{Flavor, Runtime};
use cp_select::select::{DType, Method};
use cp_select::stats::{sorted_median, sorted_order_statistic, Distribution, Rng};

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = Runtime::default_dir();
    dir.join("manifest.json").exists().then_some(dir)
}

#[test]
fn service_survives_sustained_concurrent_load() {
    let svc = Arc::new(
        SelectionService::start(4, 32, Method::Hybrid, HostBackend::factory()).unwrap(),
    );
    let mut rng = Rng::seeded(301);
    let data = Distribution::Mixture5.sample_vec(&mut rng, 4096);
    let want = sorted_median(&data);
    let id = svc.upload(data, DType::F64).unwrap();

    let mut handles = Vec::new();
    for t in 0..8 {
        let svc = svc.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..25 {
                let r = svc.query(id, KSpec::Median).unwrap();
                assert_eq!(r.value, want, "thread {t} iter {i}");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let snap = svc.metrics.snapshot();
    assert_eq!(snap.queries, 200);
    assert_eq!(snap.errors, 0);
    assert!(snap.probes > 0);
}

#[test]
fn device_backend_through_service() {
    let Some(dir) = artifacts() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let svc = SelectionService::start(
        2,
        16,
        Method::CuttingPlane,
        DeviceBackend::factory(dir, Flavor::Jnp),
    )
    .unwrap();
    let mut rng = Rng::seeded(302);
    let data = Distribution::HalfNormal.sample_vec(&mut rng, 3000);
    let want_med = sorted_median(&data);
    let want_q9 = sorted_order_statistic(&data, 2700);
    let id = svc.upload(data, DType::F64).unwrap();
    assert_eq!(svc.query(id, KSpec::Median).unwrap().value, want_med);
    assert_eq!(svc.query(id, KSpec::Rank(2700)).unwrap().value, want_q9);
    assert_eq!(svc.query_with(id, KSpec::Median, Method::Hybrid).unwrap().value, want_med);
    svc.shutdown();
}

#[test]
fn failing_backend_factory_degrades_gracefully() {
    struct NoBackend;
    let factory: BackendFactory = Arc::new(|w| {
        if w == 0 {
            Err(cp_select::Error::Service("simulated init failure".into()))
        } else {
            Ok(Box::<HostBackend>::default() as Box<dyn DatasetBackend>)
        }
    });
    let _ = NoBackend;
    let svc = SelectionService::start(1, 4, Method::Hybrid, factory).unwrap();
    // worker 0 failed to init: uploads must error, not hang or panic
    let err = svc.upload(vec![1.0, 2.0], DType::F64).unwrap_err();
    assert!(err.to_string().contains("init failed"), "{err}");
    svc.shutdown();
}

#[test]
fn per_worker_datasets_are_isolated() {
    // Two workers: dataset routing is sticky, so queries must find their
    // data regardless of which client thread asks.
    let svc = SelectionService::start(2, 16, Method::Hybrid, HostBackend::factory()).unwrap();
    let mut ids = Vec::new();
    let mut wants = Vec::new();
    let mut rng = Rng::seeded(303);
    for i in 0..10 {
        let data = Distribution::ALL[i % 9].sample_vec(&mut rng, 257 + 31 * i);
        wants.push(sorted_median(&data));
        ids.push(svc.upload(data, DType::F64).unwrap());
    }
    for (id, want) in ids.iter().zip(&wants) {
        assert_eq!(svc.query(*id, KSpec::Median).unwrap().value, *want);
    }
    svc.shutdown();
}

#[test]
fn shutdown_then_queries_fail_cleanly() {
    let svc = SelectionService::start(1, 4, Method::Hybrid, HostBackend::factory()).unwrap();
    let id = svc.upload(vec![1.0, 2.0, 3.0], DType::F64).unwrap();
    assert_eq!(svc.query(id, KSpec::Median).unwrap().value, 2.0);
    svc.shutdown();
    // service consumed; nothing to assert beyond clean drop (no hang)
}

#[test]
fn mixed_dtypes_one_service() {
    let svc = SelectionService::start(2, 16, Method::Hybrid, HostBackend::factory()).unwrap();
    let data = vec![0.1, 0.2, 0.3, 0.4, 0.5];
    let id64 = svc.upload(data.clone(), DType::F64).unwrap();
    let id32 = svc.upload(data.clone(), DType::F32).unwrap();
    let r64 = svc.query(id64, KSpec::Median).unwrap().value;
    let r32 = svc.query(id32, KSpec::Median).unwrap().value;
    assert_eq!(r64, 0.3);
    assert_eq!(r32, 0.3f32 as f64);
    svc.shutdown();
}

#[test]
fn quantile_ladder_consistency() {
    let svc = SelectionService::start(2, 64, Method::CuttingPlane, HostBackend::factory()).unwrap();
    let mut rng = Rng::seeded(304);
    let data = Distribution::Beta25.sample_vec(&mut rng, 2000);
    let mut sorted = data.clone();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let id = svc.upload(data, DType::F64).unwrap();
    let mut prev = f64::NEG_INFINITY;
    for i in 1..=10 {
        let q = i as f64 / 10.0;
        let r = svc.query(id, KSpec::Quantile(q)).unwrap();
        assert!(r.value >= prev, "quantiles must be monotone");
        let k = ((q * 2000.0).ceil() as usize).clamp(1, 2000);
        assert_eq!(r.value, sorted[k - 1]);
        prev = r.value;
    }
    svc.shutdown();
}
