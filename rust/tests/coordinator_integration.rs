//! Coordinator end-to-end: service over host and device backends, failure
//! injection, concurrent load, window coalescing (fixed and adaptive, all
//! under virtual time — no test here sleeps for correctness), cost-model
//! pooling/persistence, metrics consistency.

use std::sync::{Arc, Barrier};
use std::time::Duration;

use cp_select::coordinator::{
    lru_factory, AdaptiveWindow, BackendFactory, CoordinatorOptions, CostModelPool,
    DatasetBackend, DeviceBackend, HostBackend, KSpec, QueryOptions, SelectionService,
    ShedPolicy, TenantQuota,
};
use cp_select::runtime::{Flavor, Runtime};
use cp_select::select::multisection::MultisectOptions;
use cp_select::select::{DType, HostEvaluator, Method, PassCostModel};
use cp_select::stats::{sorted_median, sorted_order_statistic, Distribution, Rng};
use cp_select::testkit::{Clock, Fault, FaultInjectingBackend, FaultScript};
use cp_select::Error;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = Runtime::default_dir();
    dir.join("manifest.json").exists().then_some(dir)
}

#[test]
fn service_survives_sustained_concurrent_load() {
    let svc = Arc::new(
        SelectionService::start(4, 32, Method::Hybrid, HostBackend::factory()).unwrap(),
    );
    let mut rng = Rng::seeded(301);
    let data = Distribution::Mixture5.sample_vec(&mut rng, 4096);
    let want = sorted_median(&data);
    let id = svc.upload(data, DType::F64).unwrap();

    let mut handles = Vec::new();
    for t in 0..8 {
        let svc = svc.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..25 {
                let r = svc.query(id, KSpec::Median).unwrap();
                assert_eq!(r.value, want, "thread {t} iter {i}");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let snap = svc.metrics.snapshot();
    assert_eq!(snap.queries, 200);
    assert_eq!(snap.errors, 0);
    assert!(snap.probes > 0);
}

#[test]
fn device_backend_through_service() {
    let Some(dir) = artifacts() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let svc = SelectionService::start(
        2,
        16,
        Method::CuttingPlane,
        DeviceBackend::factory(dir, Flavor::Jnp),
    )
    .unwrap();
    let mut rng = Rng::seeded(302);
    let data = Distribution::HalfNormal.sample_vec(&mut rng, 3000);
    let want_med = sorted_median(&data);
    let want_q9 = sorted_order_statistic(&data, 2700);
    let id = svc.upload(data, DType::F64).unwrap();
    assert_eq!(svc.query(id, KSpec::Median).unwrap().value, want_med);
    assert_eq!(svc.query(id, KSpec::Rank(2700)).unwrap().value, want_q9);
    assert_eq!(svc.query_with(id, KSpec::Median, Method::Hybrid).unwrap().value, want_med);
    svc.shutdown();
}

#[test]
fn failing_backend_factory_degrades_gracefully() {
    struct NoBackend;
    let factory: BackendFactory = Arc::new(|w| {
        if w == 0 {
            Err(cp_select::Error::Service("simulated init failure".into()))
        } else {
            Ok(Box::<HostBackend>::default() as Box<dyn DatasetBackend>)
        }
    });
    let _ = NoBackend;
    let svc = SelectionService::start(1, 4, Method::Hybrid, factory).unwrap();
    // worker 0 failed to init: uploads must error, not hang or panic
    let err = svc.upload(vec![1.0, 2.0], DType::F64).unwrap_err();
    assert!(err.to_string().contains("init failed"), "{err}");
    svc.shutdown();
}

#[test]
fn per_worker_datasets_are_isolated() {
    // Two workers: dataset routing is sticky, so queries must find their
    // data regardless of which client thread asks.
    let svc = SelectionService::start(2, 16, Method::Hybrid, HostBackend::factory()).unwrap();
    let mut ids = Vec::new();
    let mut wants = Vec::new();
    let mut rng = Rng::seeded(303);
    for i in 0..10 {
        let data = Distribution::ALL[i % 9].sample_vec(&mut rng, 257 + 31 * i);
        wants.push(sorted_median(&data));
        ids.push(svc.upload(data, DType::F64).unwrap());
    }
    for (id, want) in ids.iter().zip(&wants) {
        assert_eq!(svc.query(*id, KSpec::Median).unwrap().value, *want);
    }
    svc.shutdown();
}

#[test]
fn shutdown_then_queries_fail_cleanly() {
    let svc = SelectionService::start(1, 4, Method::Hybrid, HostBackend::factory()).unwrap();
    let id = svc.upload(vec![1.0, 2.0, 3.0], DType::F64).unwrap();
    assert_eq!(svc.query(id, KSpec::Median).unwrap().value, 2.0);
    svc.shutdown();
    // service consumed; nothing to assert beyond clean drop (no hang)
}

#[test]
fn mixed_dtypes_one_service() {
    let svc = SelectionService::start(2, 16, Method::Hybrid, HostBackend::factory()).unwrap();
    let data = vec![0.1, 0.2, 0.3, 0.4, 0.5];
    let id64 = svc.upload(data.clone(), DType::F64).unwrap();
    let id32 = svc.upload(data.clone(), DType::F32).unwrap();
    let r64 = svc.query(id64, KSpec::Median).unwrap().value;
    let r32 = svc.query(id32, KSpec::Median).unwrap().value;
    assert_eq!(r64, 0.3);
    assert_eq!(r32, 0.3f32 as f64);
    svc.shutdown();
}

/// Acceptance: 8 threads issuing plain single-shot `query()` calls (no
/// `query_many`, no shared client-side state) against one dataset land in
/// one batching window, coalesce into shared ladder rounds
/// (`coalesced` ≥ 8), and cost strictly less than 8× the single-query run.
/// The window runs on virtual time that is never advanced: it *cannot*
/// expire under a scheduler stall, so the cap (8) is what closes it and
/// the burst coalesces deterministically on every run.
#[test]
fn eight_concurrent_clients_coalesce_through_the_window() {
    let (clock, _vc) = Clock::manual();
    let svc = Arc::new(
        SelectionService::start_full(
            1,
            64,
            Method::Multisection,
            HostBackend::factory(),
            CoordinatorOptions {
                batch_window: Duration::from_millis(250),
                batch_cap: 8,
                ..Default::default()
            },
            clock,
            CostModelPool::seeded(),
        )
        .unwrap(),
    );
    let mut rng = Rng::seeded(305);
    let data = Distribution::Uniform.sample_vec(&mut rng, 1 << 14);
    let want = sorted_median(&data);

    // single-query cost, measured outside the service
    let single = {
        let mut ev = cp_select::select::HostEvaluator::new(&data);
        cp_select::select::median(&mut ev, Method::Multisection).unwrap();
        ev.probes()
    };

    let id = svc.upload(data, DType::F64).unwrap();
    let p0 = svc.metrics.snapshot().probes;
    let barrier = Arc::new(Barrier::new(8));
    let mut handles = Vec::new();
    for _ in 0..8 {
        let svc = svc.clone();
        let barrier = barrier.clone();
        handles.push(std::thread::spawn(move || {
            barrier.wait();
            svc.query(id, KSpec::Median).unwrap()
        }));
    }
    for h in handles {
        let r = h.join().unwrap();
        assert_eq!(r.value, want);
        assert_eq!(r.method, Method::Multisection, "coalesced singles ride the shared engine");
    }
    let snap = svc.metrics.snapshot();
    assert!(snap.coalesced >= 8, "window caught {} of 8 clients", snap.coalesced);
    let burst = snap.probes - p0;
    assert!(
        burst < 8 * single,
        "8 windowed clients cost {burst} fused reductions, not below 8x single {single}"
    );
    // one shared run = one latency sample, 8 queries
    assert_eq!(snap.queries, 8);
    assert!(snap.latency_samples < 8, "expected shared-run latency accounting, {snap}");
    if let Ok(s) = Arc::try_unwrap(svc) {
        s.shutdown();
    }
}

/// Parity: mixed probe-based `Query` singles and `QueryMany` batches
/// against one dataset, planned into one unified group, return exactly the
/// values a sequential run produces.
#[test]
fn mixed_singles_and_query_many_unified_plan_is_exact() {
    // Virtual clock: the window cannot expire before all 5 requests
    // (4 singles + 1 QueryMany) are in hand, so the mixed burst plans
    // into one unified group deterministically.
    let (clock, _vc) = Clock::manual();
    let svc = Arc::new(
        SelectionService::start_full(
            1,
            64,
            Method::Multisection,
            HostBackend::factory(),
            CoordinatorOptions {
                batch_window: Duration::from_millis(150),
                batch_cap: 5,
                ..Default::default()
            },
            clock,
            CostModelPool::seeded(),
        )
        .unwrap(),
    );
    let mut rng = Rng::seeded(306);
    let data = Distribution::Mixture2.sample_vec(&mut rng, 5000);
    let mut sorted = data.clone();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let id = svc.upload(data, DType::F64).unwrap();

    let single_ks = [100usize, 2500, 4900, 1];
    let many_specs = vec![
        KSpec::Quantile(0.2),
        KSpec::Median,
        KSpec::Rank(3333),
        KSpec::Quantile(0.95),
    ];
    let barrier = Arc::new(Barrier::new(single_ks.len() + 1));
    let mut singles = Vec::new();
    for &k in &single_ks {
        let svc = svc.clone();
        let barrier = barrier.clone();
        singles.push(std::thread::spawn(move || {
            barrier.wait();
            svc.query_with(id, KSpec::Rank(k), Method::Multisection).unwrap()
        }));
    }
    let many = {
        let svc = svc.clone();
        let barrier = barrier.clone();
        let specs = many_specs.clone();
        std::thread::spawn(move || {
            barrier.wait();
            svc.query_many(id, specs, Method::Multisection).unwrap()
        })
    };
    for (h, &k) in singles.into_iter().zip(&single_ks) {
        let r = h.join().unwrap();
        assert_eq!(r.k, k);
        assert_eq!(r.value, sorted[k - 1], "single k={k}");
    }
    let rs = many.join().unwrap();
    assert_eq!(rs.len(), many_specs.len());
    for r in &rs {
        assert_eq!(r.value, sorted[r.k - 1], "query_many k={}", r.k);
    }
    // the interleaved QueryMany no longer breaks single coalescing: the
    // whole mixed burst shares one plan
    let snap = svc.metrics.snapshot();
    assert!(snap.coalesced >= 8, "mixed burst coalesced only {} of 8 specs", snap.coalesced);
    if let Ok(s) = Arc::try_unwrap(svc) {
        s.shutdown();
    }
}

/// Regression (drained-batch reordering): a query fired before a drop of
/// the same dataset must be answered even when both are collected into one
/// batch at a busy worker — the old `(kind, id)` sort ran the drop first
/// and failed the query with "unknown dataset". Under virtual time the
/// busy head query opens a window that cannot expire, so busy + query +
/// drop deterministically form ONE batch (cap 3 closes it) on every run —
/// the planner, not arrival luck, is what keeps the FIFO. (This test used
/// to sleep 2 ms per round to line the batch up; the virtual clock makes
/// the alignment a guarantee instead of a race.)
#[test]
fn query_then_drop_at_a_busy_worker_keeps_fifo() {
    let (clock, vc) = Clock::manual();
    let svc = SelectionService::start_full(
        1,
        64,
        Method::Multisection,
        HostBackend::factory(),
        CoordinatorOptions {
            batch_window: Duration::from_millis(250),
            batch_cap: 3,
            ..Default::default()
        },
        clock,
        CostModelPool::seeded(),
    )
    .unwrap();
    let mut rng = Rng::seeded(307);
    let busy_data = Distribution::Normal.sample_vec(&mut rng, 1 << 12);
    let busy = svc.upload(busy_data, DType::F64).unwrap();
    for round in 0..5 {
        let id = svc.upload(vec![5.0, 1.0, 4.0, 2.0, 3.0], DType::F64).unwrap();
        // the busy query heads the batch; query+drop queue up behind it
        // inside the same (virtually frozen) window
        let slow = svc.query_async(busy, KSpec::Median, Method::Bisection).unwrap();
        let rx = svc.query_async(id, KSpec::Median, Method::Multisection).unwrap();
        svc.drop_dataset(id).unwrap();
        let r = rx.recv().unwrap();
        assert_eq!(
            r.expect("query fired before the drop must succeed").value,
            3.0,
            "round {round}"
        );
        assert!(slow.recv().unwrap().is_ok());
        // drop must stick: the follow-up probe opens a lone window that
        // the cap will not fill — expire it by advancing virtual time
        let rx = svc.query_async(id, KSpec::Median, Method::Multisection).unwrap();
        vc.wait_for_waiters(1);
        vc.advance(Duration::from_millis(251));
        assert!(rx.recv().unwrap().is_err(), "round {round}: drop must stick");
    }
    svc.shutdown();
}

/// The synchronous drop ack replaces the sleep the fire-and-forget drop
/// needed: the ack IS the ordering guarantee, even with traffic in flight.
#[test]
fn drop_dataset_sync_acks_under_load() {
    let svc = SelectionService::start(2, 32, Method::Multisection, HostBackend::factory()).unwrap();
    let mut rng = Rng::seeded(308);
    for _ in 0..4 {
        let data = Distribution::HalfNormal.sample_vec(&mut rng, 2048);
        let want = sorted_median(&data);
        let id = svc.upload(data, DType::F64).unwrap();
        let inflight = svc.query_async(id, KSpec::Median, Method::Multisection).unwrap();
        assert_eq!(inflight.recv().unwrap().unwrap().value, want);
        svc.drop_dataset_sync(id).unwrap();
        assert!(svc.query(id, KSpec::Median).is_err());
        assert!(svc.drop_dataset_sync(id).is_err(), "double drop reports unknown dataset");
    }
    svc.shutdown();
}

/// Acceptance: the *adaptive* controller matches the fixed window's
/// coalescing on a real 8-thread burst — the fresh controller's min-window
/// (frozen virtual time) holds the worker until the cap closes, whatever
/// the thread scheduler does — then widens, and idle traffic decays it
/// back to zero without ever blowing the SLA.
#[test]
fn adaptive_controller_coalesces_a_threaded_burst_and_respects_the_sla() {
    let sla = Duration::from_millis(250);
    let (clock, vc) = Clock::manual();
    let svc = Arc::new(
        SelectionService::start_full(
            1,
            64,
            Method::Multisection,
            HostBackend::factory(),
            CoordinatorOptions {
                batch_window: Duration::ZERO,
                batch_cap: 8,
                adaptive: Some(AdaptiveWindow { latency_sla: sla, ..AdaptiveWindow::default() }),
                ..Default::default()
            },
            clock,
            CostModelPool::seeded(),
        )
        .unwrap(),
    );
    let mut rng = Rng::seeded(309);
    let data = Distribution::Uniform.sample_vec(&mut rng, 1 << 14);
    let want = sorted_median(&data);

    let single = {
        let mut ev = HostEvaluator::new(&data);
        cp_select::select::median(&mut ev, Method::Multisection).unwrap();
        ev.probes()
    };

    let id = svc.upload(data, DType::F64).unwrap();
    let p0 = svc.metrics.snapshot().probes;
    let barrier = Arc::new(Barrier::new(8));
    let mut handles = Vec::new();
    for _ in 0..8 {
        let svc = svc.clone();
        let barrier = barrier.clone();
        handles.push(std::thread::spawn(move || {
            barrier.wait();
            svc.query(id, KSpec::Median).unwrap()
        }));
    }
    for h in handles {
        assert_eq!(h.join().unwrap().value, want);
    }
    let snap = svc.metrics.snapshot();
    assert_eq!(snap.coalesced, 8, "adaptive window caught {} of 8 clients", snap.coalesced);
    assert!(snap.probes - p0 < 8 * single, "burst must share ladder passes");
    assert!(snap.window_us > 0 && snap.window_widen >= 1, "burst must widen: {snap}");
    assert!(snap.window_us as u128 <= sla.as_micros(), "SLA violated: {snap}");

    // idle decay back to a zero window
    let mut rounds = 0;
    while svc.metrics.snapshot().window_us > 0 {
        rounds += 1;
        assert!(rounds <= 32, "idle decay must terminate");
        let w = svc.metrics.snapshot().window_us;
        let rx = svc.query_async(id, KSpec::Median, Method::Multisection).unwrap();
        vc.wait_for_waiters(1);
        vc.advance_us(w + 1);
        assert_eq!(rx.recv().unwrap().unwrap().value, want);
    }
    // an idle query at the closed window costs zero virtual time
    let t0 = vc.now_us();
    assert_eq!(svc.query(id, KSpec::Median).unwrap().value, want);
    assert_eq!(vc.now_us(), t0);
    if let Ok(s) = Arc::try_unwrap(svc) {
        s.shutdown();
    }
}

/// Every worker feeds the same [`CostModelPool`]: runs executed by
/// different workers (sticky datasets route `id % workers`) land in one
/// set of pooled statistics.
#[test]
fn one_pool_collects_runs_from_every_worker() {
    let pool = CostModelPool::seeded();
    let svc = SelectionService::start_full(
        2,
        16,
        Method::Multisection,
        HostBackend::factory(),
        CoordinatorOptions::default(),
        Clock::real(),
        pool.clone(),
    )
    .unwrap();
    let mut rng = Rng::seeded(310);
    // ids 1 and 2 route to different workers (1 % 2 vs 2 % 2)
    let id1 = svc.upload(Distribution::Normal.sample_vec(&mut rng, 2048), DType::F64).unwrap();
    let id2 = svc.upload(Distribution::Uniform.sample_vec(&mut rng, 2048), DType::F64).unwrap();
    assert_eq!(pool.samples(), 0, "uploads observe nothing");
    svc.query_many(id1, vec![KSpec::Median; 3], Method::Multisection).unwrap();
    svc.query_many(id2, vec![KSpec::Median; 3], Method::Multisection).unwrap();
    assert_eq!(pool.samples(), 2, "both workers' shared runs must pool");
    svc.shutdown();
}

/// The canonical synthetic stream (`testkit::synthetic_cost_runs`) in its
/// passes-dominate regime: per-probe cost negligible, so the identifiable
/// fit plans the widest ladder, far from the seed's 15.
fn feed_overhead_heavy(pool: &CostModelPool) {
    for (passes, rungs, total, n, wall) in cp_select::testkit::synthetic_cost_runs(1e-9, 1e-14) {
        pool.observe_run(passes, rungs, total, n, wall);
    }
}

/// Acceptance: a restarted service loads the pooled coefficients its
/// predecessor persisted, and its first `MultisectOptions::for_evaluator`
/// argmin matches the pre-restart fitted width — restarts start measured,
/// not seeded.
#[test]
fn restarted_service_plans_with_the_persisted_fitted_width() {
    let dir = std::env::temp_dir().join(format!("cp_select_sidecar_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let sidecar = dir.join("BENCH_select.cost_model.json");

    // pre-restart service: its pool carries an identifiable measured
    // stream (stand-in for a long serving run; deterministic timings so
    // the fitted width is reproducible, unlike live host wall clocks)
    let pool = CostModelPool::load_or_seed(&sidecar);
    feed_overhead_heavy(&pool);
    let mut rng = Rng::seeded(311);
    let data = Distribution::Normal.sample_vec(&mut rng, 4096);
    {
        let svc = SelectionService::start_full(
            1,
            16,
            Method::Multisection,
            HostBackend::factory(),
            CoordinatorOptions::default(),
            Clock::real(),
            pool.clone(),
        )
        .unwrap();
        let id = svc.upload(data.clone(), DType::F64).unwrap();
        let want = sorted_median(&data);
        assert_eq!(svc.query(id, KSpec::Median).unwrap().value, want);
        svc.shutdown(); // persists the sidecar
    }
    let fitted = pool.best_width(None);
    assert_ne!(fitted, 15, "the fitted width must have left the seed");
    assert!(sidecar.exists(), "shutdown must write the sidecar");

    // restart: a fresh pool + service over the same sidecar
    let pool2 = CostModelPool::load_or_seed(&sidecar);
    let svc2 = SelectionService::start_full(
        1,
        16,
        Method::Multisection,
        HostBackend::factory(),
        CoordinatorOptions::default(),
        Clock::real(),
        pool2.clone(),
    )
    .unwrap();
    assert_eq!(pool2.samples(), pool.samples());
    assert_eq!(
        pool2.best_width(None),
        fitted,
        "restart must plan with the pre-restart fitted width"
    );
    // the width the restarted service's first shared run would plan with
    let model = svc2.cost_pool().snapshot();
    let ev = HostEvaluator::new(&data);
    let opts = MultisectOptions::for_evaluator_with(&ev, &model);
    assert_eq!(opts.probes_per_pass, fitted);
    svc2.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// A truncated or garbage sidecar must log and fall back to the seed —
/// never error the service out of starting or serving.
#[test]
fn corrupt_cost_model_sidecar_falls_back_to_the_seed_and_serves() {
    let dir = std::env::temp_dir().join(format!("cp_select_corrupt_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let sidecar = dir.join("BENCH_select.cost_model.json");

    // garbage, then a truncated-but-valid-prefix document
    let mut m = PassCostModel::seeded();
    m.observe_run(4, 60, 5, 1 << 12, Duration::from_millis(1));
    let valid = m.to_json();
    for corrupt in ["∞ not json ∞".to_string(), valid[..valid.len() / 2].to_string()] {
        std::fs::write(&sidecar, &corrupt).unwrap();
        let pool = CostModelPool::load_or_seed(&sidecar);
        assert_eq!(pool.samples(), 0, "corrupt sidecar must seed, not load");
        assert_eq!(pool.best_width(None), 15);
        let svc = SelectionService::start_full(
            1,
            16,
            Method::Multisection,
            HostBackend::factory(),
            CoordinatorOptions::default(),
            Clock::real(),
            pool,
        )
        .unwrap();
        let id = svc.upload(vec![9.0, 1.0, 5.0], DType::F64).unwrap();
        assert_eq!(svc.query(id, KSpec::Median).unwrap().value, 5.0);
        svc.shutdown(); // overwrites the corrupt file with valid statistics
        let healed = std::fs::read_to_string(&sidecar).unwrap();
        assert!(PassCostModel::from_json(&healed).is_ok(), "shutdown must heal the sidecar");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Under [`ShedPolicy::Shed`] a full worker queue rejects synchronously
/// with a typed `Overloaded` error carrying a retry hint, instead of
/// blocking the caller. The worker is provably parked (virtual-clock
/// handshake) so exactly the queue capacity can be in flight.
#[test]
fn shed_policy_rejects_when_the_queue_is_full() {
    let (clock, vc) = Clock::manual();
    let script = FaultScript::new(vc.clone(), 100);
    let svc = SelectionService::start_full(
        1,
        64,
        Method::Multisection,
        FaultInjectingBackend::factory(script.clone()),
        CoordinatorOptions {
            shed_policy: ShedPolicy::Shed,
            queue_cap: Some(2),
            ..Default::default()
        },
        clock,
        CostModelPool::seeded(),
    )
    .unwrap();
    let mut rng = Rng::seeded(401);
    let data = Distribution::Normal.sample_vec(&mut rng, 2048);
    let want = sorted_median(&data);
    let id = svc.upload(data, DType::F64).unwrap();
    script.fault_at(id, 0, Fault::HoldUntil(1_000));
    // the plug occupies the worker; the 2-slot queue then fills behind it
    let plug = svc.query_async(id, KSpec::Median, Method::Multisection).unwrap();
    vc.wait_for_waiters(1);
    let q1 = svc.query_async(id, KSpec::Median, Method::Multisection).unwrap();
    let q2 = svc.query_async(id, KSpec::Median, Method::Multisection).unwrap();
    match svc.query_async(id, KSpec::Median, Method::Multisection) {
        Err(Error::Overloaded { retry_after_us }) => {
            assert!(retry_after_us > 0, "shed must carry a retry hint");
        }
        Err(e) => panic!("full queue under Shed must report Overloaded, got {e}"),
        Ok(_) => panic!("full queue under Shed must not enqueue"),
    }
    vc.advance_us(1_000); // release the plug; the queue drains normally
    assert_eq!(plug.recv().unwrap().unwrap().value, want);
    assert_eq!(q1.recv().unwrap().unwrap().value, want);
    assert_eq!(q2.recv().unwrap().unwrap().value, want);
    let snap = svc.metrics.snapshot();
    assert_eq!(snap.shed, 1);
    assert_eq!(snap.errors, 0, "shedding is not an execution error");
    svc.shutdown();
}

/// Per-tenant token buckets: a tenant that exhausts its burst is shed with
/// an exact retry hint while other tenants stay admitted, and tokens
/// refill on the service clock (virtual here, so the refill instant is
/// exact, not timing-dependent).
#[test]
fn token_buckets_gate_admission_per_tenant_and_refill_on_the_clock() {
    let (clock, vc) = Clock::manual();
    let svc = SelectionService::start_full(
        1,
        64,
        Method::Multisection,
        HostBackend::factory(),
        CoordinatorOptions {
            tenant_quota: Some(TenantQuota { rate_per_sec: 1_000.0, burst: 2.0 }),
            ..Default::default()
        },
        clock,
        CostModelPool::seeded(),
    )
    .unwrap();
    let id = svc.upload(vec![5.0, 1.0, 4.0, 2.0, 3.0], DType::F64).unwrap();
    let q = |tenant: u32| QueryOptions { method: None, tenant, deadline: None };
    // burst of 2 per tenant at frozen virtual time; the third is shed
    assert_eq!(svc.query_opts(id, KSpec::Median, q(7)).unwrap().value, 3.0);
    assert_eq!(svc.query_opts(id, KSpec::Median, q(7)).unwrap().value, 3.0);
    match svc.query_opts(id, KSpec::Median, q(7)) {
        Err(Error::Overloaded { retry_after_us }) => {
            assert_eq!(retry_after_us, 1_000, "one token at 1000/s is exactly 1ms away");
        }
        other => panic!("tenant 7 over quota must shed, got {other:?}"),
    }
    // other tenants have their own buckets
    assert_eq!(svc.query_opts(id, KSpec::Median, q(8)).unwrap().value, 3.0);
    // advancing the clock 1ms refills exactly one token
    vc.advance_us(1_000);
    assert_eq!(svc.query_opts(id, KSpec::Median, q(7)).unwrap().value, 3.0);
    assert_eq!(svc.metrics.snapshot().shed, 1);
    svc.shutdown();
}

/// Deadlines cancel cooperatively *between* fused passes: a budget that
/// survives admission and the pre-run check still dies mid-run once the
/// scripted pass costs push the virtual clock past it — and the worker
/// survives to serve the next query of the same dataset.
#[test]
fn deadlines_cancel_between_passes_and_the_worker_survives() {
    let (clock, vc) = Clock::manual();
    let script = FaultScript::new(vc, 500);
    let svc = SelectionService::start_full(
        1,
        64,
        Method::Multisection,
        FaultInjectingBackend::factory(script),
        CoordinatorOptions::default(),
        clock,
        CostModelPool::seeded(),
    )
    .unwrap();
    let mut rng = Rng::seeded(402);
    let data = Distribution::Mixture1.sample_vec(&mut rng, 4096);
    let want = sorted_median(&data);
    let id = svc.upload(data, DType::F64).unwrap();
    // every fused pass costs 500us of virtual time; an 800us budget passes
    // the pre-run check (clock still at 0) but dies at a pass boundary
    let opts = QueryOptions {
        method: Some(Method::Multisection),
        tenant: 0,
        deadline: Some(Duration::from_micros(800)),
    };
    let specs = vec![KSpec::Median, KSpec::Quantile(0.9)];
    match svc.query_many_opts(id, specs.clone(), opts) {
        Err(Error::DeadlineExceeded { late_us }) => assert!(late_us > 0),
        other => panic!("expected a deadline error, got {other:?}"),
    }
    let snap = svc.metrics.snapshot();
    assert_eq!(snap.deadline_exceeded, specs.len() as u64, "one count per abandoned spec");
    assert_eq!(snap.errors, 0, "a deadline is not an execution error");
    // the worker is alive and the dataset unharmed
    assert_eq!(svc.query(id, KSpec::Median).unwrap().value, want);
    svc.shutdown();
}

/// Worker fault isolation: a panicking backend pass fails that query with
/// a typed error, bumps `worker_faults`, and the worker thread survives to
/// answer later queries — including on the dataset that just panicked.
#[test]
fn a_panicking_pass_is_contained_and_the_worker_keeps_serving() {
    let (clock, vc) = Clock::manual();
    let script = FaultScript::new(vc, 0);
    let svc = SelectionService::start_full(
        1,
        16,
        Method::Multisection,
        FaultInjectingBackend::factory(script.clone()),
        CoordinatorOptions::default(),
        clock,
        CostModelPool::seeded(),
    )
    .unwrap();
    let a = svc.upload(vec![3.0, 1.0, 2.0], DType::F64).unwrap();
    let b = svc.upload(vec![6.0, 4.0, 5.0], DType::F64).unwrap();
    script.fault_at(a, 0, Fault::Panic("injected backend panic".into()));
    let err = svc.query(a, KSpec::Median).unwrap_err();
    assert!(err.to_string().contains("worker fault"), "{err}");
    assert!(err.to_string().contains("injected backend panic"), "{err}");
    assert_eq!(svc.metrics.snapshot().worker_faults, 1);
    // the same (sole) worker answers the next queries
    assert_eq!(svc.query(b, KSpec::Median).unwrap().value, 5.0);
    assert_eq!(svc.query(a, KSpec::Median).unwrap().value, 2.0);
    assert_eq!(svc.metrics.snapshot().worker_faults, 1, "no further faults");
    svc.shutdown();
}

/// Pressure-driven eviction racing an in-flight query: the query was
/// admitted while its dataset was resident, but a queued upload evicts the
/// dataset before the query executes. The query must resolve with the
/// typed re-upload error (never hang or panic), the `evictions` metric
/// must tick, and re-uploading must restore service — all under virtual
/// time, zero sleeps.
#[test]
fn eviction_races_an_inflight_query_and_reupload_recovers() {
    let (clock, vc) = Clock::manual();
    let script = FaultScript::new(vc.clone(), 100);
    let svc = SelectionService::start_full(
        1,
        64,
        Method::Multisection,
        lru_factory(FaultInjectingBackend::factory(script.clone()), 2),
        CoordinatorOptions::default(),
        clock,
        CostModelPool::seeded(),
    )
    .unwrap();
    let mut rng = Rng::seeded(403);
    let victim_data = vec![5.0, 1.0, 4.0, 2.0, 3.0];
    let plug = svc.upload(Distribution::Normal.sample_vec(&mut rng, 2048), DType::F64).unwrap();
    let victim = svc.upload(victim_data.clone(), DType::F64).unwrap();
    script.fault_at(plug, 0, Fault::HoldUntil(1_000));
    // park the worker on the plug's query (touching `plug`, making
    // `victim` the LRU entry), then queue an upload that will evict
    // `victim`, then a query for `victim` — admitted while still resident
    let busy = svc.query_async(plug, KSpec::Median, Method::Multisection).unwrap();
    vc.wait_for_waiters(1);
    let (_newest, up_rx) =
        svc.upload_async(Distribution::Uniform.sample_vec(&mut rng, 256), DType::F64).unwrap();
    let racing = svc.query_async(victim, KSpec::Median, Method::Multisection).unwrap();
    vc.advance_us(1_000);
    assert!(busy.recv().unwrap().is_ok());
    up_rx.recv().unwrap().unwrap();
    let err = racing.recv().unwrap().unwrap_err();
    assert!(err.to_string().contains("re-upload"), "{err}");
    assert!(svc.metrics.snapshot().evictions >= 1, "live pressure must reach the metric");
    // the re-upload contract: upload the data again, query the new id
    let again = svc.upload(victim_data, DType::F64).unwrap();
    assert_eq!(svc.query(again, KSpec::Median).unwrap().value, 3.0);
    svc.shutdown();
}

/// Smoke copy of the chaos/overload harness invariant (the full run also
/// gates BENCH_select.json): every submitted request resolves with a
/// result or a typed error, and the counts are the analytic constants of
/// the scripted admission math.
#[test]
fn overload_chaos_run_resolves_every_request() {
    let o = cp_select::harness::bench_overload().unwrap();
    assert!(o.all_resolved, "{o:?}");
    assert_eq!((o.submitted, o.shed, o.ok), (41, 23, 15), "{o:?}");
    assert_eq!((o.deadline_exceeded, o.worker_faults), (1, 1), "{o:?}");
    assert!(o.fairness_ratio >= 1.0 && o.fairness_ratio <= 3.0, "{o:?}");
}

/// Stress leg (CI runs this with `cargo test --release -- --ignored`):
/// the chaos choreography is deterministic on the virtual clock, so its
/// exact counts must survive arbitrarily many repetitions — any flake
/// here is a real ordering bug in admission, planning, or fault isolation.
#[test]
#[ignore = "stress: run explicitly via cargo test --release -- --ignored"]
fn overload_chaos_counts_are_stable_across_repetitions() {
    for round in 0..25 {
        let o = cp_select::harness::bench_overload().unwrap();
        assert!(o.all_resolved, "round {round}: {o:?}");
        assert_eq!(
            (o.submitted, o.shed, o.ok, o.deadline_exceeded, o.worker_faults),
            (41, 23, 15, 1, 1),
            "round {round}: {o:?}"
        );
        assert!(
            o.fairness_ratio >= 1.0 && o.fairness_ratio <= 3.0,
            "round {round}: {o:?}"
        );
    }
}

/// Clock routing end to end: with every `Instant::now` in the run
/// accounting re-routed through `testkit::Clock`, a run's *recorded*
/// latency is exactly the virtual time its scripted passes cost. A wall
/// clock anywhere on the path (the old `t0.elapsed()` sites) would make
/// `wall` a host-dependent nonzero-noise value instead of this identity.
#[test]
fn recorded_run_latency_is_exactly_the_virtually_elapsed_time() {
    let (clock, vc) = Clock::manual();
    let script = FaultScript::new(vc.clone(), 250);
    let svc = SelectionService::start_full(
        1,
        64,
        Method::Multisection,
        FaultInjectingBackend::factory(script.clone()),
        CoordinatorOptions::default(),
        clock,
        CostModelPool::seeded(),
    )
    .unwrap();
    let mut rng = Rng::seeded(404);
    let data = Distribution::Normal.sample_vec(&mut rng, 4096);
    let want = sorted_median(&data);
    let id = svc.upload(data, DType::F64).unwrap();

    let passes_before = script.calls(id);
    let t0 = vc.now_us();
    let r = svc.query(id, KSpec::Median).unwrap();
    let elapsed = vc.now_us() - t0;
    let passes = script.calls(id) - passes_before;

    assert_eq!(r.value, want);
    assert!(passes > 0, "the scripted backend must have run fused passes");
    assert_eq!(elapsed, passes * 250, "virtual time advances only through scripted pass costs");
    assert_eq!(
        r.wall,
        Duration::from_micros(elapsed),
        "recorded run latency must equal the virtually-elapsed time"
    );
    assert_eq!(r.completed_us, t0 + elapsed, "completion stamp rides the service clock");
    let snap = svc.metrics.snapshot();
    assert_eq!(snap.latency_samples, 1);
    assert_eq!(snap.mean_latency_us, elapsed as f64, "one sample, recorded at face value");
    assert!(snap.p99_us >= elapsed, "bucketed p99 upper-bounds the sample: {snap}");
    svc.shutdown();
}

#[test]
fn quantile_ladder_consistency() {
    let svc = SelectionService::start(2, 64, Method::CuttingPlane, HostBackend::factory()).unwrap();
    let mut rng = Rng::seeded(304);
    let data = Distribution::Beta25.sample_vec(&mut rng, 2000);
    let mut sorted = data.clone();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let id = svc.upload(data, DType::F64).unwrap();
    let mut prev = f64::NEG_INFINITY;
    for i in 1..=10 {
        let q = i as f64 / 10.0;
        let r = svc.query(id, KSpec::Quantile(q)).unwrap();
        assert!(r.value >= prev, "quantiles must be monotone");
        let k = ((q * 2000.0).ceil() as usize).clamp(1, 2000);
        assert_eq!(r.value, sorted[k - 1]);
        prev = r.value;
    }
    svc.shutdown();
}
