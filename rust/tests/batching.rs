//! Property suites for the batched multi-probe engine: fused ladder
//! equivalence, chunk/shard merge consistency, and multisection exactness.

use cp_select::device::{shard_data, ShardedEvaluator};
use cp_select::select::multisection::{
    multi_order_statistics, multisection, MultisectOptions,
};
use cp_select::select::{self, Evaluator, HostEvaluator, Method, ProbeStats};
use cp_select::stats::{sorted_order_statistic, Distribution, Rng};
use cp_select::testkit::{check, Case, CaseGen};

/// Counts must match exactly; sums to a tolerance that scales with the
/// mass on each side (the fused composition's documented error bound).
fn assert_equivalent(a: &ProbeStats, b: &ProbeStats, data: &[f64], y: f64, ctx: &str) {
    assert_eq!(
        (a.c_lt, a.c_eq, a.c_gt),
        (b.c_lt, b.c_eq, b.c_gt),
        "{ctx}: counts diverge at y={y}"
    );
    let mass: f64 = data.iter().filter(|x| x.is_finite()).map(|x| x.abs()).sum::<f64>()
        + y.abs() * data.len() as f64;
    for (ga, wa, name) in [(a.s_lo, b.s_lo, "s_lo"), (a.s_hi, b.s_hi, "s_hi")] {
        if wa.is_infinite() {
            assert_eq!(ga, wa, "{ctx}: {name} at y={y}");
            continue;
        }
        let tol = 1e-12 * mass + 1e-9 * wa.abs().max(1.0);
        assert!((ga - wa).abs() <= tol, "{ctx}: {name} {ga} vs {wa} (tol {tol}) at y={y}");
    }
}

fn random_ladder(rng: &mut Rng, c: &Case) -> Vec<f64> {
    let n = c.data.len();
    let mut ys = Vec::new();
    for _ in 0..(1 + rng.below(9)) {
        let y = match rng.below(4) {
            0 => c.data[rng.below(n)],              // exact data value (dup-heavy)
            1 => c.data[rng.below(n)] + rng.range(-0.5, 0.5),
            2 => rng.range(-1e3, 1e3),
            _ => *ys.last().unwrap_or(&0.0),        // duplicate probe
        };
        ys.push(y);
    }
    ys
}

#[test]
fn prop_probe_many_equals_sequential_f64() {
    let mut lrng = Rng::seeded(77);
    check(10_000, 150, &CaseGen::default(), |c| {
        let ys = random_ladder(&mut lrng, c);
        let mut fused = HostEvaluator::new(&c.data);
        let batch = fused.probe_many(&ys).map_err(|e| e.to_string())?;
        let mut seq = HostEvaluator::new(&c.data);
        for (y, got) in ys.iter().zip(&batch) {
            let want = seq.probe(*y).map_err(|e| e.to_string())?;
            assert_equivalent(got, &want, &c.data, *y, &c.label);
        }
        (fused.probes() == 1)
            .then_some(())
            .ok_or_else(|| format!("ladder cost {} passes, want 1", fused.probes()))
    });
}

#[test]
fn prop_probe_many_equals_sequential_f32() {
    let mut lrng = Rng::seeded(78);
    check(11_000, 120, &CaseGen::default(), |c| {
        let ys = random_ladder(&mut lrng, c);
        let mut fused = HostEvaluator::new_f32(&c.data);
        let batch = fused.probe_many(&ys).map_err(|e| e.to_string())?;
        let mut seq = HostEvaluator::new_f32(&c.data);
        for (y, got) in ys.iter().zip(&batch) {
            let want = seq.probe(*y).map_err(|e| e.to_string())?;
            assert_equivalent(got, &want, &c.data, *y, &c.label);
        }
        Ok(())
    });
}

#[test]
fn prop_ladder_merge_across_chunk_and_shard_splits() {
    // A ladder pass over chunked threads and over shards must agree with
    // the unsplit pass — counts exactly, sums within merge tolerance.
    let mut lrng = Rng::seeded(79);
    check(12_000, 100, &CaseGen { min_n: 2, ..Default::default() }, |c| {
        let ys = random_ladder(&mut lrng, c);
        let mut whole = HostEvaluator::new(&c.data);
        let want = whole.probe_many(&ys).map_err(|e| e.to_string())?;

        // forced thread chunking
        let mut chunked = HostEvaluator::new(&c.data).with_threads(1 + c.data.len() % 4);
        let got = chunked.probe_many(&ys).map_err(|e| e.to_string())?;
        for ((a, b), y) in got.iter().zip(&want).zip(&ys) {
            assert_equivalent(a, b, &c.data, *y, "chunked");
        }

        // shard split + ProbeStats::merge
        let shards = 1 + c.data.len() % 5;
        let evs: Vec<HostEvaluator> =
            shard_data(&c.data, shards).into_iter().map(HostEvaluator::new).collect();
        let mut group = ShardedEvaluator::new(evs).map_err(|e| e.to_string())?;
        let got = group.probe_many(&ys).map_err(|e| e.to_string())?;
        for ((a, b), y) in got.iter().zip(&want).zip(&ys) {
            assert_equivalent(a, b, &c.data, *y, "sharded");
        }
        (group.probes() == 1)
            .then_some(())
            .ok_or_else(|| "sharded ladder must be one logical round".to_string())
    });
}

#[test]
fn prop_multisection_matches_sort_oracle() {
    check(13_000, 150, &CaseGen::default(), |c| {
        let mut ev = HostEvaluator::new(&c.data);
        let out = multisection(&mut ev, c.k, &MultisectOptions::default())
            .map_err(|e| e.to_string())?;
        let want = sorted_order_statistic(&c.data, c.k);
        (out.value == want)
            .then_some(())
            .ok_or_else(|| format!("multisection {} vs oracle {want}", out.value))
    });
}

#[test]
fn multisection_exact_for_every_k_in_the_matrix() {
    // the same k-matrix `every_method_arbitrary_k` sweeps in select::tests
    let mut rng = Rng::seeded(102);
    let data = Distribution::Uniform.sample_vec(&mut rng, 500);
    for k in [1, 17, 250, 499, 500] {
        let want = sorted_order_statistic(&data, k);
        let mut ev = HostEvaluator::new(&data);
        let got = select::order_statistic(&mut ev, k, Method::Multisection).unwrap();
        assert_eq!(got.value, want, "k={k}");
        for p in [1usize, 2, 7, 31] {
            let mut ev = HostEvaluator::new(&data);
            let out = multisection(
                &mut ev,
                k,
                &MultisectOptions { probes_per_pass: p, ..Default::default() },
            )
            .unwrap();
            assert_eq!(out.value, want, "k={k} p={p}");
        }
    }
}

#[test]
fn prop_multi_query_matches_per_query_runs() {
    let mut lrng = Rng::seeded(80);
    check(14_000, 60, &CaseGen { min_n: 2, ..Default::default() }, |c| {
        let n = c.data.len();
        let m = 1 + lrng.below(6);
        let ks: Vec<usize> = (0..m).map(|_| 1 + lrng.below(n)).collect();
        let mut ev = HostEvaluator::new(&c.data);
        let out = multi_order_statistics(&mut ev, &ks, &MultisectOptions::default())
            .map_err(|e| e.to_string())?;
        for (k, v) in ks.iter().zip(&out.values) {
            let want = sorted_order_statistic(&c.data, *k);
            if *v != want {
                return Err(format!("k={k}: {v} vs {want}"));
            }
        }
        Ok(())
    });
}
