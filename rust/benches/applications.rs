//! E10/E11 — the §VI applications as benchmarks:
//!
//! - LMS/LTS robust regression: breakdown curve (estimation error vs
//!   contamination) and wall time, demonstrating the selection workload
//!   (hundreds of medians) the paper accelerates;
//! - the LTS ρ-trick vs explicit partial sort (the paper's "cheaper method
//!   based on the median");
//! - kNN throughput via OS_k thresholds vs a full-sort kNN.

mod common;

use std::time::Instant;

use cp_select::knn::KnnModel;
use cp_select::regression::{
    lms, lts, ols, trimmed_sum_via_median, ContaminatedLinear, HostSelector, LmsOptions,
    LtsOptions,
};
use cp_select::stats::Rng;

fn max_err(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

fn main() {
    common::describe("applications (E10 regression, E11 kNN)");
    let fast = common::fast();
    let n = if fast { 500 } else { 2000 };

    // --- E10: breakdown curve ---------------------------------------------
    println!("E10 breakdown: estimation error vs contamination (n={n}, p=4):");
    println!(
        "{:>7} {:>10} {:>10} {:>10} {:>12} {:>12}",
        "contam", "OLS err", "LMS err", "LTS err", "LMS ms", "LTS ms"
    );
    let mut rng = Rng::seeded(2011);
    for contam in [0.0, 0.1, 0.2, 0.3, 0.4, 0.45] {
        let gen = ContaminatedLinear {
            n,
            p: 4,
            contamination: contam,
            sigma: 0.2,
            ..Default::default()
        };
        let d = gen.generate(&mut rng);
        let x = d.design();
        let mut sel = HostSelector::default();
        let e_ols = max_err(&ols(&x, &d.y).unwrap(), &d.theta);
        let t0 = Instant::now();
        let subsets = if fast { 100 } else { 700 };
        let f_lms = lms(&x, &d.y, &LmsOptions { subsets, ..Default::default() }, &mut sel).unwrap();
        let t_lms = t0.elapsed().as_secs_f64() * 1e3;
        let t0 = Instant::now();
        let f_lts = lts(&x, &d.y, &LtsOptions::default(), &mut sel).unwrap();
        let t_lts = t0.elapsed().as_secs_f64() * 1e3;
        println!(
            "{:>7.2} {:>10.3} {:>10.3} {:>10.3} {:>12.1} {:>12.1}",
            contam,
            e_ols,
            max_err(&f_lms.theta, &d.theta),
            max_err(&f_lts.theta, &d.theta),
            t_lms,
            t_lts
        );
    }

    // --- LTS rho-trick vs partial sort -------------------------------------
    println!("\nLTS objective: rho-trick (selection + threshold) vs full sort:");
    let mut rng = Rng::seeded(7);
    for log2n in [14usize, 16, 18] {
        let nn = 1usize << log2n;
        let r: Vec<f64> = (0..nn).map(|_| rng.normal().abs()).collect();
        let h = cp_select::util::lts_h(nn);
        let mut sel = HostSelector::default();
        let t0 = Instant::now();
        let via_med = trimmed_sum_via_median(&r, h, &mut sel).unwrap();
        let t_med = t0.elapsed().as_secs_f64() * 1e3;
        let t0 = Instant::now();
        let mut sorted = r.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let via_sort: f64 = sorted[..h].iter().map(|v| v * v).sum();
        let t_sort = t0.elapsed().as_secs_f64() * 1e3;
        assert!((via_med - via_sort).abs() <= 1e-9 * via_sort);
        println!(
            "  n=2^{log2n}: rho-trick {t_med:.2} ms vs sort {t_sort:.2} ms ({:.1}x)",
            t_sort / t_med
        );
    }

    // --- E11: kNN throughput ------------------------------------------------
    println!("\nE11 kNN: OS_k threshold vs full sort per query:");
    let nn = if fast { 2000 } else { 20_000 };
    let p = 8;
    let mut rows = Vec::with_capacity(nn);
    let mut f = Vec::with_capacity(nn);
    for _ in 0..nn {
        let row: Vec<f64> = (0..p).map(|_| rng.range(0.0, 2.0)).collect();
        f.push(row.iter().map(|v| v.sin()).sum::<f64>());
        rows.push(row);
    }
    let model = KnnModel::new(rows, f).unwrap();
    let mut sel = HostSelector::default();
    let nq = if fast { 10 } else { 50 };
    let queries: Vec<Vec<f64>> =
        (0..nq).map(|_| (0..p).map(|_| rng.range(0.2, 1.8)).collect()).collect();
    let k = 15;

    let t0 = Instant::now();
    let mut preds = Vec::new();
    for q in &queries {
        preds.push(model.predict_regression(q, k, &mut sel).unwrap());
    }
    let t_os = t0.elapsed().as_secs_f64() * 1e3 / queries.len() as f64;

    // full-sort baseline kNN
    let t0 = Instant::now();
    let mut preds_sort = Vec::new();
    for q in &queries {
        let mut d: Vec<(f64, f64)> = model
            .distances(q)
            .into_iter()
            .zip(model.f.iter().copied())
            .collect();
        d.sort_by(|a, b| a.0.total_cmp(&b.0));
        let (mut swf, mut sw) = (0.0, 0.0);
        let t = d[k - 1].0;
        for &(di, fi) in &d {
            if di > t {
                break;
            }
            let w = 1.0 / (1.0 + di);
            swf += w * fi;
            sw += w;
        }
        preds_sort.push(swf / sw);
    }
    let t_sort = t0.elapsed().as_secs_f64() * 1e3 / queries.len() as f64;
    for (a, b) in preds.iter().zip(&preds_sort) {
        assert!((a - b).abs() < 1e-9, "kNN selection vs sort mismatch");
    }
    println!(
        "  n={nn} k={k}: OS_k {t_os:.3} ms/query vs sort {t_sort:.3} ms/query ({:.1}x)",
        t_sort / t_os
    );
}
