//! E6 — Figure 5: outlier (in)sensitivity.
//!
//! One element of a normal sample is set to 10^3 … 10^13; for each
//! magnitude we record iterations, device reductions, and time for the
//! cutting plane vs bisection vs both Brent variants. The paper's claim:
//! bisection/Brent degrade with log(range) while the cutting plane's first
//! cut eliminates the outlier's linear piece. Also runs the E7 ablation
//! (1e20 magnitudes with the log-transform guard).

mod common;

use cp_select::harness::{outlier_sweep_fig5, report};
use cp_select::select::cutting_plane::CpOptions;
use cp_select::select::transform::select_transformed;
use cp_select::select::DType;
use cp_select::stats::{sorted_median, Distribution, Rng};

fn main() {
    common::describe("fig5_outliers (paper Fig 5 + §V.D transform)");
    let n = 1 << common::env_usize("CP_BENCH_LOG2N", if common::fast() { 13 } else { 17 });
    let mut runner = common::runner();
    let mags = [1e3, 1e5, 1e7, 1e9, 1e11, 1e13];
    let pts = outlier_sweep_fig5(&mut runner, n, &mags, DType::F64, 1234).expect("sweep");
    let csv = report::outlier_csv(&pts);
    report::write_result(&common::results_dir(), "fig5_outliers.csv", &csv).unwrap();

    println!("probes per method as the outlier grows (n={n}):");
    println!(
        "{:>10} {:>14} {:>10} {:>10} {:>10}",
        "magnitude", "cutting-plane", "bisection", "brent-min", "brent-root"
    );
    for &m in &mags {
        let get = |name: &str| {
            pts.iter()
                .find(|p| p.magnitude == m && p.method == name)
                .map(|p| p.probes)
                .unwrap_or(0)
        };
        println!(
            "{:>10.0e} {:>14} {:>10} {:>10} {:>10}",
            m,
            get("cutting-plane"),
            get("bisection"),
            get("brent-min"),
            get("brent-root")
        );
    }
    assert!(pts.iter().all(|p| p.correct), "all methods must stay exact");

    // E7: extreme 1e20 magnitudes need the monotone transform (paper §V.D)
    let mut rng = Rng::seeded(5);
    let mut data = Distribution::HalfNormal.sample_vec(&mut rng, n.min(1 << 16) | 1);
    data[0] = 1e20;
    data[1] = 7e20;
    let k = cp_select::util::median_rank(data.len());
    let oracle = sorted_median(&data);
    let (guarded, out) = select_transformed(&data, k, &CpOptions::default()).expect("transform");
    println!(
        "\nE7 transform guard @1e20: exact={} ({} iterations); oracle {:.9}",
        guarded == oracle,
        out.iterations,
        oracle
    );
    assert_eq!(guarded, oracle);
}
