//! BENCH_select.json — the machine-readable perf-trajectory artifact:
//! method × n × fused reductions × wall-ms for the probe-based methods,
//! plus the coordinator coalescing experiment (8 concurrent same-dataset
//! medians vs 8 sequential runs). Future PRs diff this file to track both
//! the pass-count and wall-clock trajectories.
//!
//! Writes to `CP_BENCH_OUT` (default `results/`); run the CLI's
//! `bench-select` from the repo root to refresh the committed copy.

mod common;

use cp_select::harness::{self, report, SelectBench};
use cp_select::select::DType;
use cp_select::util::json::Json;

/// Regression gate: fused-reduction counts must not grow against the
/// committed baseline (`CP_BENCH_BASELINE`, default `../BENCH_select.json`
/// — the repo-root copy when the bench runs from `rust/`). Rows are matched
/// on (method, n); rows absent from either side are skipped, so fast/full
/// sweeps both check their overlap with the baseline.
fn check_against_baseline(bench: &SelectBench) {
    let path = std::env::var("CP_BENCH_BASELINE")
        .unwrap_or_else(|_| "../BENCH_select.json".to_string());
    let text = match std::fs::read_to_string(&path) {
        Ok(t) => t,
        Err(_) => {
            println!("no baseline at {path}; skipping regression check");
            return;
        }
    };
    let base = Json::parse(&text).expect("baseline BENCH_select.json parses");
    let mut checked = 0usize;
    for b in base.get("rows").unwrap().as_arr().unwrap() {
        let method = b.get("method").unwrap().as_str().unwrap();
        let n = b.get("n").unwrap().as_usize().unwrap();
        let baseline = b.get("fused_reductions").unwrap().as_usize().unwrap() as u64;
        if let Some(r) = bench.rows.iter().find(|r| r.method == method && r.n == n) {
            assert!(
                r.fused_reductions <= baseline,
                "fused reductions regressed for {method} n={n}: \
                 {} > baseline {baseline}",
                r.fused_reductions
            );
            checked += 1;
        }
    }
    // Zero overlap means the gate checked nothing (renamed method, shifted
    // size grid): fail loudly instead of passing vacuously.
    assert!(
        checked > 0,
        "no (method, n) rows overlap the baseline at {path}; \
         regenerate the committed BENCH_select.json"
    );
    let cbase = base
        .get("coordinator")
        .unwrap()
        .get("concurrent_fused_reductions")
        .unwrap()
        .as_usize()
        .unwrap() as u64;
    assert!(
        bench.coordinator.concurrent_fused_reductions <= cbase,
        "coordinator coalescing regressed: {} > baseline {cbase}",
        bench.coordinator.concurrent_fused_reductions
    );
    println!("regression check vs {path}: {checked} rows + coordinator within baseline");
}

fn main() {
    common::describe("select_json (BENCH_select.json perf trajectory)");
    let mut runner = common::runner();
    let max = common::env_usize("CP_BENCH_MAX_LOG2N", if common::fast() { 16 } else { 20 }) as u32;
    let sizes: Vec<u32> = (14..=max).step_by(2).collect();
    let bench = harness::bench_select(&mut runner, &sizes, 42, DType::F64).expect("bench");
    let json = report::select_bench_json(
        &bench,
        "f64",
        if runner.is_device() { "pjrt-device" } else { "host" },
    );
    print!("{json}");
    let p = report::write_result(&common::results_dir(), "BENCH_select.json", &json).unwrap();
    println!("wrote {}", p.display());

    // the acceptance property this artifact exists to track
    let c = &bench.coordinator;
    assert!(
        c.concurrent_fused_reductions < c.sequential_fused_reductions,
        "coalescing regressed: {} concurrent vs {} sequential fused reductions",
        c.concurrent_fused_reductions,
        c.sequential_fused_reductions
    );
    assert!(bench.rows.iter().all(|r| r.exact), "a method returned an inexact result");
    check_against_baseline(&bench);
}
