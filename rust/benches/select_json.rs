//! BENCH_select.json — the machine-readable perf-trajectory artifact:
//! method × n × fused reductions × wall-ms for the probe-based methods,
//! plus the coordinator coalescing experiment (8 concurrent same-dataset
//! medians vs 8 sequential runs), the time-windowed coalescing experiment
//! (8 *independent* single-shot clients caught by one batching window),
//! and the cluster-parity experiment (the same burst answered through
//! remote backends over loopback wires). Future PRs diff this file to
//! track both the pass-count and wall-clock trajectories.
//!
//! Writes to `CP_BENCH_OUT` (default `results/`); run the CLI's
//! `bench-select` from the repo root to refresh the committed copy.

mod common;

use cp_select::harness::{self, report, SelectBench};
use cp_select::select::DType;
use cp_select::util::json::Json;

/// Regression gate: fused-reduction counts must not grow against the
/// committed baseline (`CP_BENCH_BASELINE`, default `../BENCH_select.json`
/// — the repo-root copy when the bench runs from `rust/`). Rows are matched
/// on (method, n); rows absent from either side are skipped, so fast/full
/// sweeps both check their overlap with the baseline.
fn check_against_baseline(bench: &SelectBench) {
    let path = std::env::var("CP_BENCH_BASELINE")
        .unwrap_or_else(|_| "../BENCH_select.json".to_string());
    let text = match std::fs::read_to_string(&path) {
        Ok(t) => t,
        Err(_) => {
            println!("no baseline at {path}; skipping regression check");
            return;
        }
    };
    let base = Json::parse(&text).expect("baseline BENCH_select.json parses");
    // Wall-time rows are only comparable like-for-like: the baseline's
    // host fingerprint must equal this machine's (cpu + cores + rustc),
    // otherwise every wall comparison is skipped and only counts gate.
    // (Baselines written before schema v2 carry no fingerprint: skip.)
    let same_host = base.get_opt("host").is_some_and(|h| {
        h.get("cpu").and_then(|v| v.as_str()).ok() == Some(bench.host.cpu.as_str())
            && h.get("logical_cores").and_then(|v| v.as_usize()).ok()
                == Some(bench.host.logical_cores)
            && h.get("rustc").and_then(|v| v.as_str()).ok() == Some(bench.host.rustc.as_str())
    });
    if !same_host {
        println!(
            "baseline fingerprint differs from this host ({}, {} cores); \
             wall-time comparisons skipped, counts still gate",
            bench.host.cpu, bench.host.logical_cores
        );
    }
    let mut checked = 0usize;
    for b in base.get("rows").unwrap().as_arr().unwrap() {
        let method = b.get("method").unwrap().as_str().unwrap();
        let n = b.get("n").unwrap().as_usize().unwrap();
        let baseline = b.get("fused_reductions").unwrap().as_usize().unwrap() as u64;
        if let Some(r) = bench.rows.iter().find(|r| r.method == method && r.n == n) {
            assert!(
                r.fused_reductions <= baseline,
                "fused reductions regressed for {method} n={n}: \
                 {} > baseline {baseline}",
                r.fused_reductions
            );
            checked += 1;
            // Informational wall ratchet, same fingerprint only: warn on a
            // large median drift so a trajectory regression is visible in
            // the log, but never fail — wall time on shared runners is
            // noisy and the counts above are the hard gate.
            if same_host {
                if let Some(base_wall) =
                    b.get_opt("wall_ms").and_then(|v| v.as_f64().ok()).filter(|w| *w > 0.0)
                {
                    let ratio = r.wall_ms / base_wall;
                    if ratio > 1.5 {
                        println!(
                            "WARN wall_ms drift for {method} n={n}: {:.3}ms vs \
                             baseline {base_wall:.3}ms ({ratio:.2}x, informational)",
                            r.wall_ms
                        );
                    }
                }
            }
        }
    }
    // Zero overlap means the gate checked nothing (renamed method, shifted
    // size grid): fail loudly instead of passing vacuously.
    assert!(
        checked > 0,
        "no (method, n) rows overlap the baseline at {path}; \
         regenerate the committed BENCH_select.json"
    );
    let cbase = base
        .get("coordinator")
        .unwrap()
        .get("concurrent_fused_reductions")
        .unwrap()
        .as_usize()
        .unwrap() as u64;
    assert!(
        bench.coordinator.concurrent_fused_reductions <= cbase,
        "coordinator coalescing regressed: {} > baseline {cbase}",
        bench.coordinator.concurrent_fused_reductions
    );
    // window-coalescing counts (baselines written before the batching
    // window landed lack the key; skip silently then)
    if let Some(wbase) = base.get_opt("window") {
        let fbase = wbase.get("fused_reductions").unwrap().as_usize().unwrap() as u64;
        assert!(
            bench.window.fused_reductions <= fbase,
            "window coalescing regressed: {} fused reductions > baseline {fbase}",
            bench.window.fused_reductions
        );
    }
    // adaptive-controller counts (same skip rule for older baselines)
    if let Some(abase) = base.get_opt("adaptive_window") {
        let fbase = abase.get("fused_reductions").unwrap().as_usize().unwrap() as u64;
        assert!(
            bench.adaptive.fused_reductions <= fbase,
            "adaptive-window coalescing regressed: {} fused reductions > baseline {fbase}",
            bench.adaptive.fused_reductions
        );
        let ibase = abase.get("idle_added_window_us").unwrap().as_usize().unwrap() as u64;
        assert!(
            bench.adaptive.idle_added_window_us <= ibase.max(1_000),
            "idle added window latency regressed: {}us > {}us",
            bench.adaptive.idle_added_window_us,
            ibase.max(1_000)
        );
    }
    // chaos/overload invariants (baselines written before the overload
    // harness landed lack the key; skip silently then). The counts are
    // exact consequences of the scripted admission math, so they gate by
    // equality — any drift means admission, deadlines, or fault isolation
    // changed behavior.
    if let Some(obase) = base.get_opt("overload") {
        let o = &bench.overload;
        for (key, got) in [
            ("tenants", o.tenants as u64),
            ("submitted", o.submitted as u64),
            ("shed", o.shed),
            ("deadline_exceeded", o.deadline_exceeded),
            ("worker_faults", o.worker_faults),
            ("ok", o.ok as u64),
        ] {
            let want = obase.get(key).unwrap().as_usize().unwrap() as u64;
            assert!(got == want, "overload.{key} drifted: {got} != baseline {want}");
        }
        let bound = obase.get("fairness_ratio_bound").unwrap().as_f64().unwrap();
        assert!(
            o.fairness_ratio <= bound,
            "tenant fairness regressed: max/min per-tenant completion ratio \
             {:.3} > bound {bound}",
            o.fairness_ratio
        );
    }
    // cluster parity (baselines written before cluster mode landed lack
    // the key; skip silently then). Fused parity gates by equality: the
    // wire path shares the in-process planner, so any drift means the
    // remote-backend seam changed the plan.
    if let Some(clbase) = base.get_opt("cluster") {
        let cl = &bench.cluster;
        let fbase = clbase.get("fused_reductions").unwrap().as_usize().unwrap() as u64;
        assert!(
            cl.fused_reductions <= fbase,
            "cluster coalescing regressed: {} fused reductions > baseline {fbase}",
            cl.fused_reductions
        );
        let wbase = clbase.get("workers").unwrap().as_usize().unwrap();
        assert!(
            cl.workers == wbase,
            "cluster.workers drifted: {} != baseline {wbase}",
            cl.workers
        );
    }
    println!("regression check vs {path}: {checked} rows + coalescing within baseline");
}

fn main() {
    common::describe("select_json (BENCH_select.json perf trajectory)");
    let mut runner = common::runner();
    let max = common::env_usize("CP_BENCH_MAX_LOG2N", if common::fast() { 16 } else { 20 }) as u32;
    let sizes: Vec<u32> = (14..=max).step_by(2).collect();
    let bench = harness::bench_select(&mut runner, &sizes, 42, DType::F64, 3).expect("bench");
    let json = report::select_bench_json(
        &bench,
        "f64",
        if runner.is_device() { "pjrt-device" } else { "host" },
    );
    print!("{json}");
    let p = report::write_result(&common::results_dir(), "BENCH_select.json", &json).unwrap();
    println!("wrote {}", p.display());

    // the acceptance properties this artifact exists to track
    let c = &bench.coordinator;
    assert!(
        c.concurrent_fused_reductions < c.sequential_fused_reductions,
        "coalescing regressed: {} concurrent vs {} sequential fused reductions",
        c.concurrent_fused_reductions,
        c.sequential_fused_reductions
    );
    // time-windowed coalescing: 8 independent single-shot query() clients
    // must land in one batching window (coalesced >= 8) and cost strictly
    // less than 8x the single-query multisection run
    let w = &bench.window;
    assert!(
        w.coalesced >= w.queries as u64,
        "batching window missed clients: coalesced {} < {} queries",
        w.coalesced,
        w.queries
    );
    let single = bench.rows.iter().find(|r| r.method == "multisection" && r.n == 16384);
    if let Some(row) = single {
        assert!(
            w.fused_reductions < row.fused_reductions * w.queries as u64,
            "window burst cost {} fused reductions, not below {} x {}",
            w.fused_reductions,
            w.queries,
            row.fused_reductions
        );
    }
    // adaptive controller: the same burst must coalesce to the fixed
    // window's cost (parity with the 250 ms window row), the controller
    // must actually have widened, and an idle single query after decay
    // must pay ≤ 1 ms of (virtual) added window latency
    let a = &bench.adaptive;
    assert!(
        a.coalesced >= a.queries as u64,
        "adaptive window missed clients: coalesced {} < {} queries",
        a.coalesced,
        a.queries
    );
    assert!(
        a.fused_reductions <= w.fused_reductions,
        "adaptive burst cost {} fused reductions vs fixed window {}",
        a.fused_reductions,
        w.fused_reductions
    );
    assert!(a.window_after_burst_us > 0, "controller never widened: {a:?}");
    assert!(
        a.idle_added_window_us <= 1_000,
        "idle query paid {}us of window latency (> 1ms)",
        a.idle_added_window_us
    );
    assert!(bench.rows.iter().all(|r| r.exact), "a method returned an inexact result");
    // overload harness: every submitted request must resolve (a result or
    // a typed shed/deadline/fault error — never a hung reply channel), and
    // fair-share planning must bound cross-tenant completion-time skew
    let o = &bench.overload;
    assert!(o.all_resolved, "a request hung or its reply channel was dropped: {o:?}");
    assert!(
        o.fairness_ratio >= 1.0 && o.fairness_ratio <= 3.0,
        "per-tenant completion skew out of bounds: {o:?}"
    );
    // cluster mode: the same windowed burst answered through remote
    // backends over loopback wires must return bit-exact values and cost
    // exactly the in-process fused-reduction count — the wire is a
    // transport, not a second planner
    let cl = &bench.cluster;
    assert!(cl.value_parity, "a cluster answer diverged from the host oracle: {cl:?}");
    assert!(
        cl.coalesced >= cl.queries as u64,
        "cluster window missed clients: coalesced {} < {} queries",
        cl.coalesced,
        cl.queries
    );
    assert!(
        cl.fused_reductions == w.fused_reductions,
        "cluster burst cost {} fused reductions vs in-process window {}",
        cl.fused_reductions,
        w.fused_reductions
    );
    check_against_baseline(&bench);
}
