//! BENCH_select.json — the machine-readable perf-trajectory artifact:
//! method × n × fused reductions × wall-ms for the probe-based methods,
//! plus the coordinator coalescing experiment (8 concurrent same-dataset
//! medians vs 8 sequential runs). Future PRs diff this file to track both
//! the pass-count and wall-clock trajectories.
//!
//! Writes to `CP_BENCH_OUT` (default `results/`); run the CLI's
//! `bench-select` from the repo root to refresh the committed copy.

mod common;

use cp_select::harness::{self, report};
use cp_select::select::DType;

fn main() {
    common::describe("select_json (BENCH_select.json perf trajectory)");
    let mut runner = common::runner();
    let max = common::env_usize("CP_BENCH_MAX_LOG2N", if common::fast() { 16 } else { 20 }) as u32;
    let sizes: Vec<u32> = (14..=max).step_by(2).collect();
    let bench = harness::bench_select(&mut runner, &sizes, 42, DType::F64).expect("bench");
    let json = report::select_bench_json(
        &bench,
        "f64",
        if runner.is_device() { "pjrt-device" } else { "host" },
    );
    print!("{json}");
    let p = report::write_result(&common::results_dir(), "BENCH_select.json", &json).unwrap();
    println!("wrote {}", p.display());

    // the acceptance property this artifact exists to track
    let c = &bench.coordinator;
    assert!(
        c.concurrent_fused_reductions < c.sequential_fused_reductions,
        "coalescing regressed: {} concurrent vs {} sequential fused reductions",
        c.concurrent_fused_reductions,
        c.sequential_fused_reductions
    );
    assert!(bench.rows.iter().all(|r| r.exact), "a method returned an inexact result");
}
