//! E3/E4 — Figures 2 & 3: log-log scaling series for both dtypes.
//!
//! Emits `results/fig2_float.csv` and `results/fig3_double.csv` with
//! method,n,ms series ready for log-log plotting, plus a textual slope
//! check: past the crossover, every method should scale ~O(n) (the paper's
//! observation that from n = 2^23 the curves differ only by constants).

mod common;

use cp_select::harness::{report, run_table, TableConfig};
use cp_select::select::DType;

fn slope_check(table: &cp_select::harness::Table) {
    // fit log(ms) vs log(n) slope over the last three sizes per method
    for row in &table.rows {
        let pts: Vec<(f64, f64)> = table
            .sizes
            .iter()
            .zip(&row.ms)
            .filter_map(|(&n, v)| v.map(|ms| ((n as f64).ln(), ms.ln())))
            .collect();
        if pts.len() < 3 {
            continue;
        }
        let tail = &pts[pts.len() - 3..];
        let slope = (tail[2].1 - tail[0].1) / (tail[2].0 - tail[0].0);
        println!("  {:<38} tail slope ≈ {slope:.2}", row.label);
    }
}

fn main() {
    common::describe("fig2_fig3_scaling (paper Figs 2-3)");
    let max = common::env_usize("CP_BENCH_MAX_LOG2N", if common::fast() { 15 } else { 21 }) as u32;
    let mut runner = common::runner();
    for (dtype, name) in [(DType::F32, "fig2_float"), (DType::F64, "fig3_double")] {
        let cfg = TableConfig {
            dtype,
            log2_sizes: (13..=max).collect(), // every power for smooth curves
            instances: if common::fast() { 1 } else { 2 },
            reps: if common::fast() { 1 } else { 2 },
            ..Default::default()
        };
        let table = run_table(&mut runner, &cfg).expect("run");
        let csv = report::table_csv(&table);
        report::write_result(&common::results_dir(), &format!("{name}.csv"), &csv).unwrap();
        println!("{name}: {} series points", csv.lines().count() - 1);
        slope_check(&table);
    }
}
