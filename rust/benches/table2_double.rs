//! E2 — Table II: mean time per method, dtype *double*.
//!
//! Same protocol as table1_float with f64 storage; the paper's key
//! observation is the larger sort-vs-cutting-plane gap (8 radix key passes
//! instead of 4, while reduction cost only doubles its bandwidth).

mod common;

use cp_select::harness::{report, run_table, TableConfig};
use cp_select::select::DType;

fn main() {
    common::describe("table2_double (paper Table II / Fig 3)");
    let max = common::env_usize("CP_BENCH_MAX_LOG2N", if common::fast() { 15 } else { 21 }) as u32;
    let cfg = TableConfig {
        dtype: DType::F64,
        log2_sizes: (13..=max).step_by(2).collect(),
        instances: if common::fast() { 1 } else { 3 },
        reps: if common::fast() { 1 } else { 3 },
        ..Default::default()
    };
    let mut runner = common::runner();
    let table = run_table(&mut runner, &cfg).expect("table run");
    let md = report::table_markdown(&table);
    println!("{md}");
    let dir = common::results_dir();
    report::write_result(&dir, "table2_double.md", &md).unwrap();
    report::write_result(&dir, "table2_double.csv", &report::table_csv(&table)).unwrap();

    let sort = table.rows.iter().find(|r| r.label.contains("Radix")).unwrap();
    let hyb = table.rows.iter().find(|r| r.label.contains("Cutting")).unwrap();
    if let (Some(s), Some(h)) =
        (sort.ms.last().copied().flatten(), hyb.ms.last().copied().flatten())
    {
        println!(
            "table2 headline: n=2^{max} f64 sort {s:.2} ms vs hybrid {h:.2} ms = {:.2}x",
            s / h
        );
    }
}
