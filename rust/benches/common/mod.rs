//! Shared bench plumbing: backend pick, env-var scale knobs, output paths.
//!
//! All benches honor:
//! - `CP_SELECT_ARTIFACTS` — artifacts dir (device backend when present);
//! - `CP_BENCH_BACKEND=host|device` — force a backend;
//! - `CP_BENCH_MAX_LOG2N` — cap the size sweep (default varies per bench);
//! - `CP_BENCH_FAST=1` — minimal sweep for CI smoke.

use cp_select::harness::{Backend, Runner};
use cp_select::runtime::{Flavor, Runtime};

pub fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

pub fn fast() -> bool {
    std::env::var("CP_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

pub fn backend() -> Backend {
    // Default: the host substrate. Its fused-reduction : sort cost ratio
    // (~1:76 at 2^23 f64) matches the paper's Tesla C2050 (~1:75), so the
    // table *shapes* reproduce faithfully. The PJRT device backend
    // (CP_BENCH_BACKEND=device) exercises the AOT path, but xla_extension
    // 0.5.1's scalar CPU reduce skews the balance to ~1:7 — see
    // EXPERIMENTS.md "substrate calibration".
    let dir = Runtime::default_dir();
    let have = dir.join("manifest.json").exists();
    match std::env::var("CP_BENCH_BACKEND").as_deref() {
        Ok("device") if have => Backend::Device { artifacts_dir: dir, flavor: Flavor::Jnp },
        _ => Backend::Host,
    }
}

pub fn runner() -> Runner {
    Runner::new(backend()).expect("backend init")
}

pub fn results_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(
        std::env::var("CP_BENCH_OUT").unwrap_or_else(|_| "results".to_string()),
    )
}

pub fn describe(name: &str) {
    let b = match backend() {
        Backend::Host => "host".to_string(),
        Backend::Device { .. } => "pjrt-device".to_string(),
    };
    println!("=== bench {name} (backend: {b}{}) ===", if fast() { ", FAST" } else { "" });
}
