//! E5/E8/E9/E12 — design-choice ablations:
//!
//! - `trace`       (E5, Fig 4): cutting-plane iterate trace;
//! - `hybrid_sweep`(E8, §IV): CP iteration budget vs |z| and phase times —
//!   reproduces the paper's "7 iterations at n=2^25 leaves |z| < 2^19";
//! - `primitives`  (E9, §V.B): cost of one fused reduction per size/dtype,
//!   measured download cost, and the modeled paper-PCIe transfer;
//! - `shards`      (E12, §V.D): group-probe cost vs shard count;
//! - `flavor`      (DESIGN §6.4): pallas-interpret vs jnp-fused artifact.

mod common;

use std::time::Instant;

use cp_select::device::{shard_data, ShardedEvaluator, TransferModel};
use cp_select::harness::{hybrid_sweep, report, trace_fig4};
use cp_select::runtime::{DeviceEvaluator, Flavor, Runtime};
use cp_select::select::{DType, Evaluator, HostEvaluator};
use cp_select::stats::{Distribution, Rng};

fn main() {
    common::describe("ablations (E5 trace, E8 hybrid, E9 primitives, E12 shards)");
    let dir = common::results_dir();

    // --- E5: Fig 4 trace -------------------------------------------------
    let trace = trace_fig4(4096, 42).expect("trace");
    report::write_result(&dir, "fig4_trace.csv", &report::trace_csv(&trace)).unwrap();
    println!("E5 fig4: {} trace rows, final bracket width {:.3e}",
        trace.len(),
        trace.last().map(|t| t.y_r - t.y_l).unwrap_or(0.0));

    // --- E8: hybrid budget sweep ------------------------------------------
    let n = 1 << common::env_usize("CP_BENCH_LOG2N", if common::fast() { 14 } else { 20 });
    let mut runner = common::runner();
    let budgets = [0usize, 2, 4, 5, 7, 9, 11, 14];
    let pts = hybrid_sweep(&mut runner, n, &budgets, DType::F64, 9).expect("sweep");
    report::write_result(&dir, "hybrid_sweep.csv", &report::hybrid_sweep_csv(&pts)).unwrap();
    println!("\nE8 hybrid budget sweep (n={n}):");
    println!(
        "{:>8} {:>10} {:>9} {:>9} {:>9} {:>9}",
        "cp_iters", "|z|", "cp ms", "copy ms", "sort ms", "total"
    );
    for p in &pts {
        println!(
            "{:>8} {:>10} {:>9.2} {:>9.2} {:>9.2} {:>9.2}",
            p.cp_iters, p.z_len, p.cp_ms, p.copy_ms, p.sort_ms, p.total_ms
        );
    }
    // paper's qualitative claim: |z| shrinks geometrically with the budget
    assert!(pts.first().unwrap().z_len > pts.last().unwrap().z_len);

    // --- E9: primitive costs ----------------------------------------------
    println!("\nE9 primitives (one fused reduction; measured download; modeled PCIe):");
    let have_device = Runtime::default_dir().join("manifest.json").exists();
    let rt = have_device.then(|| Runtime::new(&Runtime::default_dir()).unwrap());
    let mut rng = Rng::seeded(11);
    let max_log2 = common::env_usize("CP_BENCH_MAX_LOG2N", if common::fast() { 15 } else { 21 });
    println!(
        "{:>9} {:>6} {:>14} {:>14} {:>14} {:>16}",
        "n", "dtype", "host probe ms", "device probe ms", "download ms", "paper-PCIe ms"
    );
    for log2n in (13..=max_log2).step_by(2) {
        let n = 1usize << log2n;
        let data = Distribution::Uniform.sample_vec(&mut rng, n);
        for dtype in [DType::F32, DType::F64] {
            let mut host = match dtype {
                DType::F64 => HostEvaluator::new(&data),
                DType::F32 => HostEvaluator::new_f32(&data),
            };
            let t0 = Instant::now();
            let reps = 5;
            for i in 0..reps {
                host.probe(0.1 + i as f64 * 0.01).unwrap();
            }
            let host_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;

            let (dev_ms, dl_ms) = if let Some(rt) = &rt {
                let mut dev = DeviceEvaluator::upload(rt, &data, dtype).unwrap();
                dev.probe(0.1).unwrap(); // compile + warm
                let t0 = Instant::now();
                for i in 0..reps {
                    dev.probe(0.1 + i as f64 * 0.01).unwrap();
                }
                let dev_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
                let t0 = Instant::now();
                let _ = dev.download().unwrap();
                (dev_ms, t0.elapsed().as_secs_f64() * 1e3)
            } else {
                (f64::NAN, f64::NAN)
            };
            let bytes = if dtype == DType::F64 { 8 } else { 4 };
            let pcie = TransferModel::paper_pcie().cost(n, bytes).as_secs_f64() * 1e3;
            println!(
                "{:>9} {:>6} {:>14.3} {:>14.3} {:>14.3} {:>16.2}",
                n,
                dtype.name(),
                host_ms,
                dev_ms,
                dl_ms,
                pcie
            );
        }
    }

    // --- E12: shard scaling ------------------------------------------------
    println!("\nE12 shard scaling (group probe over host shards, n=2^20):");
    let data = Distribution::Normal.sample_vec(&mut rng, 1 << 20);
    for shards in [1usize, 2, 4, 8, 16] {
        let evs: Vec<HostEvaluator> =
            shard_data(&data, shards).into_iter().map(HostEvaluator::new).collect();
        let mut group = ShardedEvaluator::new(evs).unwrap();
        let t0 = Instant::now();
        for i in 0..5 {
            group.probe(i as f64 * 0.1).unwrap();
        }
        let ms = t0.elapsed().as_secs_f64() * 1e3 / 5.0;
        println!(
            "  shards={shards:>2}: {ms:.3} ms/probe, combine traffic = {} scalars",
            shards * 5
        );
    }

    // --- flavor ablation -----------------------------------------------------
    if let Some(rt) = &rt {
        println!("\nflavor ablation (fused_objective artifact, n=2^16 f32):");
        let data = Distribution::Uniform.sample_vec(&mut rng, 1 << 16);
        for flavor in [Flavor::Jnp, Flavor::Pallas] {
            let mut dev =
                DeviceEvaluator::upload_with_flavor(rt, &data, DType::F32, flavor).unwrap();
            dev.probe(0.5).unwrap();
            let t0 = Instant::now();
            for i in 0..5 {
                dev.probe(0.3 + 0.01 * i as f64).unwrap();
            }
            println!(
                "  {:>6}: {:.3} ms/probe",
                flavor.name(),
                t0.elapsed().as_secs_f64() * 1e3 / 5.0
            );
        }
        println!(
            "  (pallas = interpret-lowered authored kernel — correctness artifact, \
             not a TPU wallclock proxy)"
        );
    }
}
