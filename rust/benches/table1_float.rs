//! E1 — Table I: mean time per method, dtype *float*, n = 2^13 … 2^max.
//!
//! Regenerates the paper's Table I protocol (9 distributions, averaged)
//! on this substrate and writes `results/table1_float.{md,csv}`. The Fig. 2
//! series is the same data (see fig2_fig3_scaling).

mod common;

use cp_select::harness::{report, run_table, TableConfig};
use cp_select::select::DType;

fn main() {
    common::describe("table1_float (paper Table I / Fig 2)");
    let max = common::env_usize("CP_BENCH_MAX_LOG2N", if common::fast() { 15 } else { 21 }) as u32;
    let cfg = TableConfig {
        dtype: DType::F32,
        log2_sizes: (13..=max).step_by(2).collect(),
        instances: if common::fast() { 1 } else { 3 },
        reps: if common::fast() { 1 } else { 3 },
        ..Default::default()
    };
    let mut runner = common::runner();
    let table = run_table(&mut runner, &cfg).expect("table run");
    let md = report::table_markdown(&table);
    println!("{md}");
    let dir = common::results_dir();
    report::write_result(&dir, "table1_float.md", &md).unwrap();
    report::write_result(&dir, "table1_float.csv", &report::table_csv(&table)).unwrap();

    // headline check: hybrid vs the sort baseline at the largest n
    let sort = table.rows.iter().find(|r| r.label.contains("Radix")).unwrap();
    let hyb = table.rows.iter().find(|r| r.label.contains("Cutting")).unwrap();
    if let (Some(s), Some(h)) =
        (sort.ms.last().copied().flatten(), hyb.ms.last().copied().flatten())
    {
        println!(
            "table1 headline: n=2^{max} f32 sort {s:.2} ms vs hybrid {h:.2} ms = {:.2}x",
            s / h
        );
    }
}
