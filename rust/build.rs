//! Captures the compiler version into `CP_RUSTC_VERSION` so the wall-clock
//! host fingerprint (`harness::wall::HostFingerprint`) can record which
//! rustc produced the measured binary — wall rows are only comparable
//! like-for-like, and a toolchain bump is a fingerprint change.

use std::process::Command;

fn main() {
    let rustc = std::env::var("RUSTC").unwrap_or_else(|_| "rustc".to_string());
    let version = Command::new(&rustc)
        .arg("--version")
        .output()
        .ok()
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string());
    println!("cargo:rustc-env=CP_RUSTC_VERSION={version}");
    println!("cargo:rerun-if-changed=build.rs");
}
