//! Deterministic-testing toolkit: a property-testing mini-framework
//! (proptest is unavailable offline — DESIGN.md §7) and the virtual-clock
//! harness ([`clock`]) that time-dependent coordinator logic runs under in
//! tests.
//!
//! The property half provides seeded generators over the paper's data
//! regimes and a `forall`-style runner with failure shrinking: on a
//! counterexample the runner tries to shrink the input vector (halving,
//! then element simplification) before reporting, so failures are small
//! and actionable.

pub mod clock;
pub mod fault;

pub use clock::{Clock, VirtualClock};
pub use fault::{Fault, FaultInjectingBackend, FaultScript};

use std::time::Duration;

use crate::stats::{Distribution, Rng};

/// Deterministic width-varying synthetic run stream for `PassCostModel`
/// tests: `(passes, rungs, total_reductions, n, wall)` tuples following
/// the model's own cost law `wall = (a·total + b·probes)·n` where
/// `probes = passes·width + fixups`. One canonical copy so the unit,
/// integration and property suites all exercise the same regressor
/// contract (`xb = rungs + total − passes`) and identifiability spread.
pub fn synthetic_cost_runs(a: f64, b: f64) -> Vec<(usize, u64, u64, usize, Duration)> {
    [1usize, 3, 7, 15, 31, 63, 2, 5, 11, 23]
        .iter()
        .enumerate()
        .map(|(i, &w)| {
            let passes = 4 + i % 3;
            let fixups = 1 + i % 4;
            let total = (passes + fixups) as u64;
            let n = 1usize << (12 + i % 3);
            let probes = (passes * w + fixups) as f64;
            let secs = (a * total as f64 + b * probes) * n as f64;
            (passes, (passes * w) as u64, total, n, Duration::from_secs_f64(secs))
        })
        .collect()
}

/// A generated selection-problem case.
#[derive(Debug, Clone)]
pub struct Case {
    pub data: Vec<f64>,
    pub k: usize,
    pub label: String,
}

/// Configurable case generator.
#[derive(Debug, Clone)]
pub struct CaseGen {
    pub min_n: usize,
    pub max_n: usize,
    /// Probability of injecting huge outliers (paper §V.D regime).
    pub outlier_prob: f64,
    /// Probability of heavy duplication.
    pub dup_prob: f64,
}

impl Default for CaseGen {
    fn default() -> Self {
        CaseGen { min_n: 1, max_n: 600, outlier_prob: 0.25, dup_prob: 0.25 }
    }
}

impl CaseGen {
    pub fn generate(&self, rng: &mut Rng) -> Case {
        let n = self.min_n + rng.below(self.max_n - self.min_n + 1);
        let dist = Distribution::ALL[rng.below(9)];
        let mut data = dist.sample_vec(rng, n);
        let mut label = dist.name().to_string();
        if rng.f64() < self.dup_prob && n >= 4 {
            // duplicate a random value across a random span
            let v = data[rng.below(n)];
            let reps = 1 + rng.below(n / 2);
            for _ in 0..reps {
                let i = rng.below(n);
                data[i] = v;
            }
            label.push_str("+dups");
        }
        if rng.f64() < self.outlier_prob {
            let mag = [1e6, 1e9, 1e12, -1e9][rng.below(4)];
            let count = 1 + rng.below(3.min(n));
            for _ in 0..count {
                let i = rng.below(n);
                data[i] = mag;
            }
            label.push_str("+outliers");
        }
        let k = 1 + rng.below(n);
        Case { data, k, label }
    }
}

/// Outcome of a property run.
#[derive(Debug)]
pub enum PropResult {
    Ok { cases: usize },
    Failed { case: Case, message: String, shrunk: bool },
}

/// Run `prop` over `cases` generated cases; shrink on failure.
///
/// `prop` returns `Err(msg)` to signal a counterexample.
pub fn forall(
    seed: u64,
    cases: usize,
    gen: &CaseGen,
    mut prop: impl FnMut(&Case) -> Result<(), String>,
) -> PropResult {
    let mut rng = Rng::seeded(seed);
    for _ in 0..cases {
        let case = gen.generate(&mut rng);
        if let Err(message) = prop(&case) {
            let (case, shrunk) = shrink(case, &mut prop);
            return PropResult::Failed { case, message, shrunk };
        }
    }
    PropResult::Ok { cases }
}

/// Assert-style wrapper for tests.
pub fn check(
    seed: u64,
    cases: usize,
    gen: &CaseGen,
    prop: impl FnMut(&Case) -> Result<(), String>,
) {
    match forall(seed, cases, gen, prop) {
        PropResult::Ok { .. } => {}
        PropResult::Failed { case, message, shrunk } => {
            panic!(
                "property failed ({}): {message}\n  n={} k={} label={} data={:?}",
                if shrunk { "shrunk" } else { "unshrunk" },
                case.data.len(),
                case.k,
                case.label,
                &case.data[..case.data.len().min(24)]
            );
        }
    }
}

fn shrink(mut case: Case, prop: &mut impl FnMut(&Case) -> Result<(), String>) -> (Case, bool) {
    let mut shrunk = false;
    // 1) halve the vector while the failure persists
    loop {
        if case.data.len() <= 1 {
            break;
        }
        let half = case.data.len() / 2;
        let mut tried = false;
        for keep_front in [true, false] {
            let data: Vec<f64> = if keep_front {
                case.data[..half].to_vec()
            } else {
                case.data[half..].to_vec()
            };
            if data.is_empty() {
                continue;
            }
            let k = case.k.min(data.len());
            let cand = Case { data, k, label: case.label.clone() };
            if prop(&cand).is_err() {
                case = cand;
                shrunk = true;
                tried = true;
                break;
            }
        }
        if !tried {
            break;
        }
    }
    // 2) simplify elements toward 0/1 while the failure persists
    for i in 0..case.data.len() {
        for candidate in [0.0, 1.0] {
            if case.data[i] == candidate {
                continue;
            }
            let mut cand = case.clone();
            cand.data[i] = candidate;
            if prop(&cand).is_err() {
                case = cand;
                shrunk = true;
            }
        }
    }
    (case, shrunk)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let r = forall(1, 50, &CaseGen::default(), |c| {
            if (1..=c.data.len()).contains(&c.k) {
                Ok(())
            } else {
                Err("k out of range".into())
            }
        });
        assert!(matches!(r, PropResult::Ok { cases: 50 }));
    }

    #[test]
    fn failing_property_shrinks() {
        // fail whenever the vector contains a value > 100
        let r = forall(
            2,
            200,
            &CaseGen { outlier_prob: 1.0, ..Default::default() },
            |c| {
                if c.data.iter().any(|&v| v.abs() > 100.0) {
                    Err("big value".into())
                } else {
                    Ok(())
                }
            },
        );
        match r {
            PropResult::Failed { case, shrunk, .. } => {
                assert!(shrunk);
                // shrinking should get us to a tiny case
                assert!(case.data.len() <= 8, "shrunk to {} elems", case.data.len());
            }
            _ => panic!("property should have failed"),
        }
    }

    #[test]
    fn generator_respects_bounds() {
        let gen = CaseGen { min_n: 5, max_n: 9, ..Default::default() };
        let mut rng = Rng::seeded(3);
        for _ in 0..100 {
            let c = gen.generate(&mut rng);
            assert!((5..=9).contains(&c.data.len()));
            assert!((1..=c.data.len()).contains(&c.k));
        }
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn check_panics_on_failure() {
        check(4, 50, &CaseGen::default(), |_| Err("always".into()));
    }
}
