//! Time source abstraction: real monotonic time or a virtual clock under
//! manual test control.
//!
//! The coordinator's batching window is *time-dependent* control logic: a
//! worker holds the head of a batch while more traffic accumulates, and the
//! adaptive controller widens/shrinks that window from observed arrivals.
//! Testing such logic against the wall clock means sleeps, retries and
//! flakes — so every time read and every timed wait in the window path goes
//! through [`Clock`]:
//!
//! - [`Clock::Real`] reads a process-monotonic microsecond counter and
//!   waits with `recv_timeout` (production behavior, zero overhead);
//! - [`Clock::Virtual`] reads a [`VirtualClock`] that only moves when a
//!   test calls [`VirtualClock::advance`]. A worker waiting on a virtual
//!   deadline parks on a condvar; it is woken by *time advancing* or by a
//!   *waiter wakeup* ([`VirtualClock::notify`], issued by the service after
//!   every channel send so a parked worker re-checks its queue). Tests
//!   sequence deterministically with [`VirtualClock::wait_for_waiters`]:
//!   once a worker is parked, nothing happens until the test advances time
//!   — an open batching window is effectively infinite, which is exactly
//!   what makes burst-coalescing tests scheduler-proof.

use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::sync::{Arc, Condvar, OnceLock};
use std::time::{Duration, Instant};

use crate::util::sync::{OrderedGuard, OrderedMutex, RANK_VIRTUAL_CLOCK};

/// A monotonic microsecond time source: the real clock, or a virtual one
/// under manual control. Cloning is cheap; all clones of a virtual clock
/// share the same timeline.
#[derive(Clone)]
pub enum Clock {
    /// Process-monotonic wall time (`Instant`-backed).
    Real,
    /// Shared manually-advanced timeline (see [`VirtualClock`]).
    Virtual(Arc<VirtualClock>),
}

impl Clock {
    /// The production clock.
    pub fn real() -> Clock {
        Clock::Real
    }

    /// A fresh virtual clock at t = 0, plus the handle tests use to
    /// advance it and await parked waiters.
    pub fn manual() -> (Clock, Arc<VirtualClock>) {
        let vc = Arc::new(VirtualClock::new());
        (Clock::Virtual(vc.clone()), vc)
    }

    /// Microseconds since this clock's epoch (process start, or virtual 0).
    pub fn now_us(&self) -> u64 {
        match self {
            Clock::Real => real_now_us(),
            Clock::Virtual(vc) => vc.now_us(),
        }
    }

    /// Waiter wakeup: callers that enqueue work for a thread which may be
    /// parked on a virtual deadline must call this after the enqueue so
    /// the waiter re-checks its queue. No-op on the real clock (there,
    /// `recv_timeout` wakes on the send natively).
    pub fn notify(&self) {
        if let Clock::Virtual(vc) = self {
            vc.notify();
        }
    }

    /// Receive from `rx`, giving up once this clock reaches `deadline_us`.
    ///
    /// Real clock: plain `recv_timeout`. Virtual clock: drain/park loop —
    /// the caller is woken by [`VirtualClock::advance`] (deadline may now
    /// have passed) or [`VirtualClock::notify`] (a message may have
    /// arrived), so no real time is ever spent waiting.
    pub fn recv_deadline<T>(
        &self,
        rx: &Receiver<T>,
        deadline_us: u64,
    ) -> std::result::Result<T, RecvTimeoutError> {
        match self {
            Clock::Real => {
                let now = real_now_us();
                if now >= deadline_us {
                    return match rx.try_recv() {
                        Ok(v) => Ok(v),
                        Err(TryRecvError::Empty) => Err(RecvTimeoutError::Timeout),
                        Err(TryRecvError::Disconnected) => Err(RecvTimeoutError::Disconnected),
                    };
                }
                rx.recv_timeout(Duration::from_micros(deadline_us - now))
            }
            Clock::Virtual(vc) => loop {
                // Snapshot the wakeup generation BEFORE checking the
                // channel: a send+notify landing between the check and the
                // park bumps the generation, so the park returns
                // immediately instead of missing the wakeup.
                let gen = vc.generation();
                match rx.try_recv() {
                    Ok(v) => return Ok(v),
                    Err(TryRecvError::Disconnected) => return Err(RecvTimeoutError::Disconnected),
                    Err(TryRecvError::Empty) => {}
                }
                if vc.now_us() >= deadline_us {
                    return Err(RecvTimeoutError::Timeout);
                }
                vc.park(gen, deadline_us);
            },
        }
    }
}

fn real_now_us() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}

#[derive(Default)]
struct VcState {
    now_us: u64,
    /// Bumped by every wakeup-worthy event (advance or notify); parked
    /// threads wait for it to change.
    generation: u64,
    /// Threads currently parked in [`VirtualClock::park`] — the test-side
    /// handshake: once a worker is parked, the system is quiescent.
    waiters: usize,
}

/// Manually-advanced shared timeline (the virtual half of [`Clock`]).
pub struct VirtualClock {
    /// Rank [`RANK_VIRTUAL_CLOCK`] — the innermost lock in the rank
    /// table: anything may consult the clock while holding its own lock,
    /// and the clock never calls out.
    state: OrderedMutex<VcState>,
    cv: Condvar,
}

impl Default for VirtualClock {
    fn default() -> Self {
        VirtualClock {
            state: OrderedMutex::new(RANK_VIRTUAL_CLOCK, "clock.state", VcState::default()),
            cv: Condvar::new(),
        }
    }
}

impl VirtualClock {
    pub fn new() -> VirtualClock {
        VirtualClock::default()
    }

    fn lock(&self) -> OrderedGuard<'_, VcState> {
        self.state.lock()
    }

    /// Current virtual time in microseconds.
    pub fn now_us(&self) -> u64 {
        self.lock().now_us
    }

    /// Move time forward and wake every parked waiter to re-check its
    /// deadline. Time never moves on its own.
    pub fn advance(&self, d: Duration) {
        self.advance_us(d.as_micros() as u64);
    }

    pub fn advance_us(&self, us: u64) {
        let mut st = self.lock();
        st.now_us = st.now_us.saturating_add(us);
        st.generation += 1;
        self.cv.notify_all();
    }

    /// Waiter wakeup: wake parked waiters so they re-check their queues
    /// (called after enqueuing work for a potentially-parked thread).
    pub fn notify(&self) {
        let mut st = self.lock();
        st.generation += 1;
        self.cv.notify_all();
    }

    /// Number of threads currently parked on a virtual deadline.
    pub fn waiters(&self) -> usize {
        self.lock().waiters
    }

    /// Block (in real time) until at least `n` threads are parked on this
    /// clock — the deterministic test handshake: once the worker under
    /// test is parked, it cannot act until the test advances time.
    pub fn wait_for_waiters(&self, n: usize) {
        let mut st = self.lock();
        while st.waiters < n {
            st = st.wait(&self.cv);
        }
    }

    /// Park the calling thread until virtual time reaches `deadline_us`
    /// (immediately returns if it already has). The sleeper counts toward
    /// [`VirtualClock::waiters`], so a test can handshake with
    /// [`VirtualClock::wait_for_waiters`]: fault-injection backends use
    /// this to hold a worker *provably mid-execution* while the test
    /// stages queues around it, then release it with an advance. Unlike
    /// the receive park, a [`VirtualClock::notify`] does not wake it —
    /// only time passing does.
    pub fn sleep_until(&self, deadline_us: u64) {
        let mut st = self.lock();
        if st.now_us >= deadline_us {
            return;
        }
        st.waiters += 1;
        self.cv.notify_all(); // unblock wait_for_waiters observers
        while st.now_us < deadline_us {
            st = st.wait(&self.cv);
        }
        st.waiters -= 1;
        self.cv.notify_all();
    }

    fn generation(&self) -> u64 {
        self.lock().generation
    }

    /// Park until the generation moves past `gen` or time reaches
    /// `deadline_us`. Returns immediately if either already holds.
    fn park(&self, gen: u64, deadline_us: u64) {
        let mut st = self.lock();
        if st.generation != gen || st.now_us >= deadline_us {
            return;
        }
        st.waiters += 1;
        self.cv.notify_all(); // unblock wait_for_waiters observers
        while st.generation == gen && st.now_us < deadline_us {
            st = st.wait(&self.cv);
        }
        st.waiters -= 1;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::sync_channel;

    #[test]
    fn virtual_time_only_moves_on_advance() {
        let (clock, vc) = Clock::manual();
        assert_eq!(clock.now_us(), 0);
        vc.advance(Duration::from_millis(3));
        assert_eq!(clock.now_us(), 3000);
        vc.advance_us(7);
        assert_eq!(clock.now_us(), 3007);
    }

    #[test]
    fn recv_deadline_times_out_at_the_virtual_deadline() {
        let (clock, vc) = Clock::manual();
        let (_tx, rx) = sync_channel::<u32>(1);
        // deadline already passed: immediate timeout, no park
        vc.advance_us(10);
        assert!(matches!(clock.recv_deadline(&rx, 5), Err(RecvTimeoutError::Timeout)));
        // park a waiter, then expire its deadline from another thread
        let t = std::thread::spawn({
            let clock = clock.clone();
            move || clock.recv_deadline(&rx, 100)
        });
        vc.wait_for_waiters(1);
        vc.advance_us(200);
        assert!(matches!(t.join().unwrap(), Err(RecvTimeoutError::Timeout)));
        assert_eq!(vc.waiters(), 0);
    }

    #[test]
    fn notify_wakes_a_parked_receiver_for_a_new_message() {
        let (clock, vc) = Clock::manual();
        let (tx, rx) = sync_channel::<u32>(4);
        let t = std::thread::spawn({
            let clock = clock.clone();
            move || clock.recv_deadline(&rx, 1_000_000)
        });
        vc.wait_for_waiters(1);
        tx.send(42).unwrap();
        vc.notify();
        assert_eq!(t.join().unwrap().unwrap(), 42);
        // virtual time never moved: the wakeup was the notify, not a sleep
        assert_eq!(vc.now_us(), 0);
    }

    #[test]
    fn send_before_park_is_never_missed() {
        // The generation snapshot closes the check-then-park race: even a
        // send+notify issued before the receiver parks is picked up.
        let (clock, vc) = Clock::manual();
        let (tx, rx) = sync_channel::<u32>(4);
        tx.send(7).unwrap();
        vc.notify();
        assert_eq!(clock.recv_deadline(&rx, 50).unwrap(), 7);
    }

    #[test]
    fn disconnected_sender_ends_the_wait() {
        let (clock, vc) = Clock::manual();
        let (tx, rx) = sync_channel::<u32>(1);
        let t = std::thread::spawn({
            let clock = clock.clone();
            move || clock.recv_deadline(&rx, 1_000_000)
        });
        vc.wait_for_waiters(1);
        drop(tx);
        vc.notify();
        assert!(matches!(t.join().unwrap(), Err(RecvTimeoutError::Disconnected)));
    }

    #[test]
    fn sleep_until_parks_and_releases_on_advance() {
        let (_clock, vc) = Clock::manual();
        // already-passed deadline: immediate return, no waiter
        vc.advance_us(10);
        vc.sleep_until(5);
        assert_eq!(vc.waiters(), 0);
        let t = std::thread::spawn({
            let vc = vc.clone();
            move || {
                vc.sleep_until(1_000);
                vc.now_us()
            }
        });
        vc.wait_for_waiters(1);
        // a bare notify must NOT release a time-sleeper
        vc.notify();
        assert_eq!(vc.waiters(), 1);
        vc.advance_us(2_000);
        assert!(t.join().unwrap() >= 1_000);
        assert_eq!(vc.waiters(), 0);
    }

    #[test]
    fn real_clock_smoke() {
        let clock = Clock::real();
        let t0 = clock.now_us();
        let (_tx, rx) = sync_channel::<u32>(1);
        // 1ms real deadline: returns Timeout without hanging
        let r = clock.recv_deadline(&rx, t0 + 1_000);
        assert!(matches!(r, Err(RecvTimeoutError::Timeout)));
        assert!(clock.now_us() >= t0);
    }
}
