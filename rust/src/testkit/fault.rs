//! Deterministic fault and cost injection for coordinator chaos tests.
//!
//! [`FaultInjectingBackend`] is a host-memory [`DatasetBackend`] whose
//! evaluators route every fused pass through a shared [`FaultScript`]:
//! the script can make the Nth pass *on a given dataset* return an error,
//! panic (exercising worker `catch_unwind` isolation), or park the worker
//! on the virtual clock until a scripted release time ([`Fault::HoldUntil`]
//! — the deterministic "worker busy" gate overload tests stage queues
//! behind). Every pass also advances the virtual clock by a fixed
//! per-pass cost, so run latencies are exact functions of pass counts:
//! the chaos/overload harness measures per-tenant p99s with zero real
//! sleeps and zero scheduler dependence.
//!
//! Faults are keyed by `(dataset id, per-dataset pass index)` rather than
//! a global call counter, so a script stays valid even when unrelated
//! runs change their pass counts.

use std::collections::HashMap;
use std::sync::Arc;

use crate::coordinator::{BackendFactory, DatasetBackend};
use crate::select::objective::{
    DType, Evaluator, HostEvaluator, InitStats, IntervalCounts, Neighbors, ProbeStats,
};
use crate::testkit::VirtualClock;
use crate::util::sync::{OrderedGuard, OrderedMutex, RANK_FAULT_SCRIPT};
use crate::{Error, Result};

/// One scripted fault, consumed by the pass it targets.
#[derive(Debug, Clone)]
pub enum Fault {
    /// The pass returns `Error::Service(msg)` instead of running.
    Error(String),
    /// The pass panics with `msg` (contained by worker fault isolation).
    Panic(String),
    /// The pass parks the worker on the virtual clock until the given
    /// absolute virtual time, then runs normally. While parked the worker
    /// counts as a clock waiter, so tests can `wait_for_waiters` on it.
    HoldUntil(u64),
    /// The pass returns [`Error::Disconnected`] — the typed signal a
    /// cluster worker emits when its wire dies mid-ladder. Cluster tests
    /// use it to script a disconnect at an exact fused-pass index.
    Disconnect,
}

#[derive(Default)]
struct ScriptState {
    /// Per-dataset fused-pass counters.
    calls: HashMap<u64, u64>,
    /// Scheduled faults by (dataset, per-dataset pass index).
    faults: HashMap<(u64, u64), Fault>,
}

/// Shared fault schedule + virtual pass-cost model for a
/// [`FaultInjectingBackend`]. Clone the `Arc` into tests to script faults
/// while the service runs.
pub struct FaultScript {
    clock: Arc<VirtualClock>,
    /// Virtual microseconds charged (clock-advanced) per fused pass.
    pass_cost_us: u64,
    /// Rank [`RANK_FAULT_SCRIPT`]: below the clock, above the service
    /// locks — `on_pass` may park on the virtual clock, never the
    /// reverse.
    state: OrderedMutex<ScriptState>,
}

impl FaultScript {
    pub fn new(clock: Arc<VirtualClock>, pass_cost_us: u64) -> Arc<FaultScript> {
        Arc::new(FaultScript {
            clock,
            pass_cost_us,
            state: OrderedMutex::new(RANK_FAULT_SCRIPT, "fault.state", ScriptState::default()),
        })
    }

    fn lock(&self) -> OrderedGuard<'_, ScriptState> {
        self.state.lock()
    }

    /// Schedule `fault` for the `pass`-th fused pass (0-based) on
    /// `dataset`. Each scheduled fault fires at most once.
    pub fn fault_at(&self, dataset: u64, pass: u64, fault: Fault) {
        self.lock().faults.insert((dataset, pass), fault);
    }

    /// Total fused passes observed on `dataset` so far.
    pub fn calls(&self, dataset: u64) -> u64 {
        self.lock().calls.get(&dataset).copied().unwrap_or(0)
    }

    /// Account one fused pass on `dataset`: fire any scheduled fault,
    /// then charge the virtual pass cost.
    fn on_pass(&self, dataset: u64) -> Result<()> {
        let fault = {
            let mut st = self.lock();
            let c = st.calls.entry(dataset).or_insert(0);
            let idx = *c;
            *c += 1;
            st.faults.remove(&(dataset, idx))
        };
        match fault {
            None => {}
            Some(Fault::Error(msg)) => return Err(Error::Service(msg)),
            Some(Fault::Panic(msg)) => panic!("{msg}"),
            Some(Fault::HoldUntil(t_us)) => self.clock.sleep_until(t_us),
            Some(Fault::Disconnect) => {
                return Err(Error::Disconnected { peer: "fault-script".into() })
            }
        }
        if self.pass_cost_us > 0 {
            self.clock.advance_us(self.pass_cost_us);
        }
        Ok(())
    }
}

/// Host evaluator wrapper that charges scripted costs/faults per pass.
pub struct ScriptedEvaluator {
    id: u64,
    inner: HostEvaluator,
    script: Arc<FaultScript>,
}

impl Evaluator for ScriptedEvaluator {
    fn n(&self) -> usize {
        self.inner.n()
    }

    fn dtype(&self) -> DType {
        self.inner.dtype()
    }

    fn init_stats(&mut self) -> Result<InitStats> {
        self.script.on_pass(self.id)?;
        self.inner.init_stats()
    }

    fn probe(&mut self, y: f64) -> Result<ProbeStats> {
        self.script.on_pass(self.id)?;
        self.inner.probe(y)
    }

    fn probe_many(&mut self, ys: &[f64]) -> Result<Vec<ProbeStats>> {
        self.script.on_pass(self.id)?;
        self.inner.probe_many(ys)
    }

    fn neighbors(&mut self, y: f64) -> Result<Neighbors> {
        self.script.on_pass(self.id)?;
        self.inner.neighbors(y)
    }

    fn interval(&mut self, lo: f64, hi: f64) -> Result<IntervalCounts> {
        self.script.on_pass(self.id)?;
        self.inner.interval(lo, hi)
    }

    fn compact(&mut self, lo: f64, hi: f64) -> Result<Vec<f64>> {
        self.inner.compact(lo, hi)
    }

    fn download(&mut self) -> Result<Vec<f64>> {
        self.inner.download()
    }

    fn probes(&self) -> u64 {
        self.inner.probes()
    }
}

/// Host-memory backend whose evaluators obey a shared [`FaultScript`].
pub struct FaultInjectingBackend {
    datasets: HashMap<u64, ScriptedEvaluator>,
    script: Arc<FaultScript>,
}

impl FaultInjectingBackend {
    pub fn factory(script: Arc<FaultScript>) -> BackendFactory {
        Arc::new(move |_worker| {
            Ok(Box::new(FaultInjectingBackend {
                datasets: HashMap::new(),
                script: script.clone(),
            }) as Box<dyn DatasetBackend>)
        })
    }
}

impl DatasetBackend for FaultInjectingBackend {
    fn upload(&mut self, id: u64, data: &[f64], dtype: DType) -> Result<()> {
        let inner = match dtype {
            DType::F64 => HostEvaluator::new(data),
            DType::F32 => HostEvaluator::new_f32(data),
        };
        self.datasets.insert(id, ScriptedEvaluator { id, inner, script: self.script.clone() });
        Ok(())
    }

    fn evaluator(&mut self, id: u64) -> Result<&mut dyn Evaluator> {
        self.datasets
            .get_mut(&id)
            .map(|e| e as &mut dyn Evaluator)
            .ok_or_else(|| Error::Service(format!("unknown dataset {id}")))
    }

    fn drop_dataset(&mut self, id: u64) -> bool {
        self.datasets.remove(&id).is_some()
    }

    fn dataset_len(&self, id: u64) -> Option<usize> {
        self.datasets.get(&id).map(|e| e.n())
    }

    fn kind(&self) -> &'static str {
        "fault-injecting"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Clock;

    fn backend(script: &Arc<FaultScript>) -> Box<dyn DatasetBackend> {
        FaultInjectingBackend::factory(script.clone())(0).unwrap()
    }

    #[test]
    fn passes_charge_virtual_cost() {
        let (_clock, vc) = Clock::manual();
        let script = FaultScript::new(vc.clone(), 250);
        let mut b = backend(&script);
        b.upload(1, &[3.0, 1.0, 2.0], DType::F64).unwrap();
        let ev = b.evaluator(1).unwrap();
        ev.init_stats().unwrap();
        ev.probe(2.0).unwrap();
        ev.probe_many(&[1.0, 2.0]).unwrap();
        assert_eq!(vc.now_us(), 750, "three fused passes at 250us each");
        assert_eq!(script.calls(1), 3);
    }

    #[test]
    fn scripted_error_fires_once_on_the_right_pass() {
        let (_clock, vc) = Clock::manual();
        let script = FaultScript::new(vc, 0);
        script.fault_at(1, 1, Fault::Error("injected".into()));
        let mut b = backend(&script);
        b.upload(1, &[1.0, 2.0], DType::F64).unwrap();
        b.upload(2, &[1.0, 2.0], DType::F64).unwrap();
        // dataset 2 is unaffected by dataset 1's script
        b.evaluator(2).unwrap().probe(1.0).unwrap();
        let ev = b.evaluator(1).unwrap();
        ev.probe(1.0).unwrap(); // pass 0: clean
        let err = ev.probe(1.0).unwrap_err(); // pass 1: injected
        assert!(err.to_string().contains("injected"));
        ev.probe(1.0).unwrap(); // pass 2: fault consumed
    }

    #[test]
    fn scripted_disconnect_is_a_typed_disconnected_error() {
        let (_clock, vc) = Clock::manual();
        let script = FaultScript::new(vc, 0);
        script.fault_at(3, 0, Fault::Disconnect);
        let mut b = backend(&script);
        b.upload(3, &[1.0, 2.0], DType::F64).unwrap();
        let ev = b.evaluator(3).unwrap();
        let err = ev.probe(1.5).unwrap_err();
        assert_eq!(err.kind(), crate::error::ErrorKind::Disconnected);
        ev.probe(1.5).unwrap(); // fault consumed: next pass is clean
    }

    #[test]
    fn scripted_panic_fires() {
        let (_clock, vc) = Clock::manual();
        let script = FaultScript::new(vc, 0);
        script.fault_at(7, 0, Fault::Panic("boom".into()));
        let mut b = backend(&script);
        b.upload(7, &[1.0], DType::F64).unwrap();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = b.evaluator(7).unwrap().init_stats();
        }));
        assert!(r.is_err(), "pass 0 on dataset 7 panics");
    }

    #[test]
    fn hold_until_parks_the_calling_thread() {
        let (_clock, vc) = Clock::manual();
        let script = FaultScript::new(vc.clone(), 100);
        script.fault_at(1, 0, Fault::HoldUntil(5_000));
        let t = std::thread::spawn({
            let script = script.clone();
            move || {
                let mut b = backend(&script);
                b.upload(1, &[2.0, 1.0], DType::F64).unwrap();
                b.evaluator(1).unwrap().probe(1.5).unwrap();
            }
        });
        vc.wait_for_waiters(1); // thread is provably parked mid-pass
        vc.advance_us(5_000);
        t.join().unwrap();
        assert_eq!(vc.now_us(), 5_100, "release time plus one pass cost");
    }
}
