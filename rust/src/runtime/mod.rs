//! PJRT runtime layer: artifact manifest, executable cache, and the
//! device-backed [`Evaluator`](crate::select::Evaluator).
//!
//! Build-time contract: `make artifacts` runs `python/compile/aot.py`,
//! which lowers the Layer-2 JAX graphs (calling the Layer-1 Pallas kernels)
//! to HLO text plus `manifest.json`. This module is the only place the
//! coordinator touches XLA; everything above it sees the `Evaluator` trait.

pub mod client;
pub mod evaluator;
pub mod manifest;

pub use client::{Executable, Runtime};
pub use evaluator::DeviceEvaluator;
pub use manifest::{ArtifactEntry, Flavor, Kernel, Manifest};
