//! Artifact manifest: the contract between `python/compile/aot.py` (which
//! writes it) and the rust runtime (which loads artifacts by key).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::select::DType;
use crate::util::json::Json;
use crate::{Error, Result};

/// The kernels the AOT pipeline emits (DESIGN.md S1/S3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Kernel {
    FusedObjective,
    MinMaxSum,
    Neighbors,
    IntervalCount,
    ThresholdStats,
    KnnWeightedSum,
    Residuals,
    LmsProbe,
    Dists,
}

impl Kernel {
    pub fn name(&self) -> &'static str {
        match self {
            Kernel::FusedObjective => "fused_objective",
            Kernel::MinMaxSum => "minmaxsum",
            Kernel::Neighbors => "neighbors",
            Kernel::IntervalCount => "interval_count",
            Kernel::ThresholdStats => "threshold_stats",
            Kernel::KnnWeightedSum => "knn_weighted_sum",
            Kernel::Residuals => "residuals",
            Kernel::LmsProbe => "lms_probe",
            Kernel::Dists => "dists",
        }
    }

    pub fn from_name(s: &str) -> Option<Kernel> {
        use Kernel::*;
        Some(match s {
            "fused_objective" => FusedObjective,
            "minmaxsum" => MinMaxSum,
            "neighbors" => Neighbors,
            "interval_count" => IntervalCount,
            "threshold_stats" => ThresholdStats,
            "knn_weighted_sum" => KnnWeightedSum,
            "residuals" => Residuals,
            "lms_probe" => LmsProbe,
            "dists" => Dists,
            _ => return None,
        })
    }
}

/// Artifact flavor: authored Pallas kernel (interpret-lowered) or the
/// XLA-fused jnp reference (runtime default on the CPU substrate).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Flavor {
    Pallas,
    Jnp,
}

impl Flavor {
    pub fn name(&self) -> &'static str {
        match self {
            Flavor::Pallas => "pallas",
            Flavor::Jnp => "jnp",
        }
    }

    pub fn from_name(s: &str) -> Option<Flavor> {
        match s {
            "pallas" => Some(Flavor::Pallas),
            "jnp" => Some(Flavor::Jnp),
            _ => None,
        }
    }
}

/// Tensor spec (dtype + shape) of an artifact input/output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub dtype: String,
    pub shape: Vec<usize>,
}

/// One compiled-graph artifact.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub kernel: Kernel,
    pub flavor: Flavor,
    pub dtype: DType,
    pub n: usize,
    pub p: Option<usize>,
    pub path: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Lookup key: (kernel, flavor, dtype, n, p).
pub type Key = (Kernel, Flavor, &'static str, usize, Option<usize>);

/// Parsed manifest with bucket lookup.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
    /// (kernel, flavor, dtype) -> sorted available vector buckets.
    buckets: BTreeMap<(Kernel, Flavor, String), Vec<usize>>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::io(path.display().to_string(), e))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let version = j.get("version")?.as_usize()?;
        if version != 2 {
            return Err(Error::Artifact(format!(
                "manifest version {version} unsupported (expected 2); \
                 re-run `make artifacts`"
            )));
        }
        let mut entries = Vec::new();
        for e in j.get("entries")?.as_arr()? {
            let kernel_name = e.get("kernel")?.as_str()?;
            let kernel = Kernel::from_name(kernel_name).ok_or_else(|| {
                Error::Artifact(format!("unknown kernel {kernel_name:?} in manifest"))
            })?;
            let flavor_name = e.get("flavor")?.as_str()?;
            let flavor = Flavor::from_name(flavor_name).ok_or_else(|| {
                Error::Artifact(format!("unknown flavor {flavor_name:?}"))
            })?;
            let dtype_name = e.get("dtype")?.as_str()?;
            let dtype = DType::from_name(dtype_name).ok_or_else(|| {
                Error::Artifact(format!("unknown dtype {dtype_name:?}"))
            })?;
            let parse_specs = |field: &str| -> Result<Vec<TensorSpec>> {
                let mut out = Vec::new();
                for s in e.get(field)?.as_arr()? {
                    let shape = s
                        .get("shape")?
                        .as_arr()?
                        .iter()
                        .map(|d| d.as_usize())
                        .collect::<Result<Vec<_>>>()?;
                    out.push(TensorSpec {
                        dtype: s.get("dtype")?.as_str()?.to_string(),
                        shape,
                    });
                }
                Ok(out)
            };
            entries.push(ArtifactEntry {
                kernel,
                flavor,
                dtype,
                n: e.get("n")?.as_usize()?,
                p: match e.get_opt("p") {
                    Some(v) => Some(v.as_usize()?),
                    None => None,
                },
                path: dir.join(e.get("path")?.as_str()?),
                inputs: parse_specs("inputs")?,
                outputs: parse_specs("outputs")?,
            });
        }
        let mut buckets: BTreeMap<(Kernel, Flavor, String), Vec<usize>> = BTreeMap::new();
        for e in &entries {
            buckets
                .entry((e.kernel, e.flavor, e.dtype.name().to_string()))
                .or_default()
                .push(e.n);
        }
        for v in buckets.values_mut() {
            v.sort_unstable();
            v.dedup();
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries, buckets })
    }

    /// Smallest available bucket >= n for this kernel/flavor/dtype.
    pub fn bucket_for(
        &self,
        kernel: Kernel,
        flavor: Flavor,
        dtype: DType,
        n: usize,
    ) -> Result<usize> {
        let key = (kernel, flavor, dtype.name().to_string());
        let bs = self.buckets.get(&key).ok_or_else(|| {
            Error::Artifact(format!(
                "no artifacts for {}/{}/{} — re-run `make artifacts`",
                kernel.name(),
                flavor.name(),
                dtype.name()
            ))
        })?;
        bs.iter().copied().find(|&b| b >= n).ok_or_else(|| {
            Error::Artifact(format!(
                "n={n} exceeds the largest {}/{}/{} bucket ({}); raise \
                 --max-log2n in `make artifacts`",
                kernel.name(),
                flavor.name(),
                dtype.name(),
                bs.last().copied().unwrap_or(0)
            ))
        })
    }

    /// Exact entry lookup.
    pub fn entry(
        &self,
        kernel: Kernel,
        flavor: Flavor,
        dtype: DType,
        n: usize,
        p: Option<usize>,
    ) -> Result<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| {
                e.kernel == kernel
                    && e.flavor == flavor
                    && e.dtype == dtype
                    && e.n == n
                    && e.p == p
            })
            .ok_or_else(|| {
                Error::Artifact(format!(
                    "missing artifact {}/{}/{}/n{}{}",
                    kernel.name(),
                    flavor.name(),
                    dtype.name(),
                    n,
                    p.map(|p| format!("/p{p}")).unwrap_or_default()
                ))
            })
    }

    /// Largest bucket available (used to size benchmark sweeps).
    pub fn max_bucket(&self, kernel: Kernel, flavor: Flavor, dtype: DType) -> Option<usize> {
        self.buckets
            .get(&(kernel, flavor, dtype.name().to_string()))
            .and_then(|v| v.last().copied())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 2,
      "digest": "abc",
      "default_p": 8,
      "min_log2n": 12,
      "max_log2n": 13,
      "entries": [
        {"kernel": "fused_objective", "flavor": "jnp", "dtype": "f64",
         "n": 4096, "p": null, "path": "a.hlo.txt",
         "inputs": [{"dtype": "f64", "shape": [4096]},
                    {"dtype": "f64", "shape": [1]},
                    {"dtype": "i32", "shape": [1]}],
         "outputs": [{"dtype": "f64", "shape": [1]}]},
        {"kernel": "fused_objective", "flavor": "jnp", "dtype": "f64",
         "n": 8192, "p": null, "path": "b.hlo.txt",
         "inputs": [], "outputs": []},
        {"kernel": "residuals", "flavor": "pallas", "dtype": "f32",
         "n": 4096, "p": 8, "path": "c.hlo.txt",
         "inputs": [], "outputs": []}
      ]
    }"#;

    #[test]
    fn parses_and_indexes() {
        let m = Manifest::parse(Path::new("/tmp/arts"), SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 3);
        assert_eq!(
            m.bucket_for(Kernel::FusedObjective, Flavor::Jnp, DType::F64, 5000)
                .unwrap(),
            8192
        );
        assert_eq!(
            m.bucket_for(Kernel::FusedObjective, Flavor::Jnp, DType::F64, 4096)
                .unwrap(),
            4096
        );
        assert!(m
            .bucket_for(Kernel::FusedObjective, Flavor::Jnp, DType::F64, 9000)
            .is_err());
        assert!(m
            .bucket_for(Kernel::Neighbors, Flavor::Jnp, DType::F64, 10)
            .is_err());
    }

    #[test]
    fn entry_lookup_with_p() {
        let m = Manifest::parse(Path::new("/x"), SAMPLE).unwrap();
        let e = m
            .entry(Kernel::Residuals, Flavor::Pallas, DType::F32, 4096, Some(8))
            .unwrap();
        assert_eq!(e.path, Path::new("/x/c.hlo.txt"));
        assert!(m
            .entry(Kernel::Residuals, Flavor::Pallas, DType::F32, 4096, Some(4))
            .is_err());
    }

    #[test]
    fn rejects_wrong_version() {
        let bad = SAMPLE.replace("\"version\": 2", "\"version\": 1");
        assert!(Manifest::parse(Path::new("/x"), &bad).is_err());
    }

    #[test]
    fn input_specs_roundtrip() {
        let m = Manifest::parse(Path::new("/x"), SAMPLE).unwrap();
        let e = &m.entries[0];
        assert_eq!(e.inputs.len(), 3);
        assert_eq!(e.inputs[0].shape, vec![4096]);
        assert_eq!(e.inputs[2].dtype, "i32");
    }

    #[test]
    fn kernel_flavor_names_roundtrip() {
        for k in [
            Kernel::FusedObjective,
            Kernel::MinMaxSum,
            Kernel::Neighbors,
            Kernel::IntervalCount,
            Kernel::ThresholdStats,
            Kernel::KnnWeightedSum,
            Kernel::Residuals,
            Kernel::LmsProbe,
            Kernel::Dists,
        ] {
            assert_eq!(Kernel::from_name(k.name()), Some(k));
        }
        for f in [Flavor::Pallas, Flavor::Jnp] {
            assert_eq!(Flavor::from_name(f.name()), Some(f));
        }
    }
}
