//! Artifact manifest: the contract between `python/compile/aot.py` (which
//! writes it) and the rust runtime (which loads artifacts by key).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::select::DType;
use crate::util::json::Json;
use crate::{Error, Result};

/// The kernels the AOT pipeline emits (DESIGN.md S1/S3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Kernel {
    FusedObjective,
    /// Multi-probe ladder reduction: per-rung `fused_objective` stats for a
    /// sorted width-`p` ladder in one binned sweep (entries are keyed by
    /// ladder width through the manifest `p` field).
    FusedLadder,
    MinMaxSum,
    Neighbors,
    IntervalCount,
    ThresholdStats,
    KnnWeightedSum,
    Residuals,
    LmsProbe,
    Dists,
}

impl Kernel {
    pub fn name(&self) -> &'static str {
        match self {
            Kernel::FusedObjective => "fused_objective",
            Kernel::FusedLadder => "fused_ladder",
            Kernel::MinMaxSum => "minmaxsum",
            Kernel::Neighbors => "neighbors",
            Kernel::IntervalCount => "interval_count",
            Kernel::ThresholdStats => "threshold_stats",
            Kernel::KnnWeightedSum => "knn_weighted_sum",
            Kernel::Residuals => "residuals",
            Kernel::LmsProbe => "lms_probe",
            Kernel::Dists => "dists",
        }
    }

    pub fn from_name(s: &str) -> Option<Kernel> {
        use Kernel::*;
        Some(match s {
            "fused_objective" => FusedObjective,
            "fused_ladder" => FusedLadder,
            "minmaxsum" => MinMaxSum,
            "neighbors" => Neighbors,
            "interval_count" => IntervalCount,
            "threshold_stats" => ThresholdStats,
            "knn_weighted_sum" => KnnWeightedSum,
            "residuals" => Residuals,
            "lms_probe" => LmsProbe,
            "dists" => Dists,
            _ => return None,
        })
    }
}

/// Artifact flavor: authored Pallas kernel (interpret-lowered) or the
/// XLA-fused jnp reference (runtime default on the CPU substrate).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Flavor {
    Pallas,
    Jnp,
}

impl Flavor {
    pub fn name(&self) -> &'static str {
        match self {
            Flavor::Pallas => "pallas",
            Flavor::Jnp => "jnp",
        }
    }

    pub fn from_name(s: &str) -> Option<Flavor> {
        match s {
            "pallas" => Some(Flavor::Pallas),
            "jnp" => Some(Flavor::Jnp),
            _ => None,
        }
    }
}

/// Tensor spec (dtype + shape) of an artifact input/output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub dtype: String,
    pub shape: Vec<usize>,
}

/// One compiled-graph artifact.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub kernel: Kernel,
    pub flavor: Flavor,
    pub dtype: DType,
    pub n: usize,
    pub p: Option<usize>,
    pub path: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Lookup key: (kernel, flavor, dtype, n, p).
pub type Key = (Kernel, Flavor, &'static str, usize, Option<usize>);

/// Parsed manifest with bucket lookup.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
    /// (kernel, flavor, dtype, p) -> sorted available vector buckets. The
    /// `p` component keeps same-kernel families emitted at different
    /// parameters (regression dimension, ladder width) from aliasing.
    buckets: BTreeMap<(Kernel, Flavor, String, Option<usize>), Vec<usize>>,
    /// (flavor, dtype, n) -> sorted `fused_ladder` widths at that bucket.
    ladders: BTreeMap<(Flavor, String, usize), Vec<usize>>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::io(path.display().to_string(), e))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let version = j.get("version")?.as_usize()?;
        if version != 2 {
            return Err(Error::Artifact(format!(
                "manifest version {version} unsupported (expected 2); \
                 re-run `make artifacts`"
            )));
        }
        let mut entries = Vec::new();
        for e in j.get("entries")?.as_arr()? {
            let kernel_name = e.get("kernel")?.as_str()?;
            let kernel = Kernel::from_name(kernel_name).ok_or_else(|| {
                Error::Artifact(format!("unknown kernel {kernel_name:?} in manifest"))
            })?;
            let flavor_name = e.get("flavor")?.as_str()?;
            let flavor = Flavor::from_name(flavor_name).ok_or_else(|| {
                Error::Artifact(format!("unknown flavor {flavor_name:?}"))
            })?;
            let dtype_name = e.get("dtype")?.as_str()?;
            let dtype = DType::from_name(dtype_name).ok_or_else(|| {
                Error::Artifact(format!("unknown dtype {dtype_name:?}"))
            })?;
            let parse_specs = |field: &str| -> Result<Vec<TensorSpec>> {
                let mut out = Vec::new();
                for s in e.get(field)?.as_arr()? {
                    let shape = s
                        .get("shape")?
                        .as_arr()?
                        .iter()
                        .map(|d| d.as_usize())
                        .collect::<Result<Vec<_>>>()?;
                    out.push(TensorSpec {
                        dtype: s.get("dtype")?.as_str()?.to_string(),
                        shape,
                    });
                }
                Ok(out)
            };
            entries.push(ArtifactEntry {
                kernel,
                flavor,
                dtype,
                n: e.get("n")?.as_usize()?,
                p: match e.get_opt("p") {
                    Some(v) => Some(v.as_usize()?),
                    None => None,
                },
                path: dir.join(e.get("path")?.as_str()?),
                inputs: parse_specs("inputs")?,
                outputs: parse_specs("outputs")?,
            });
        }
        let mut buckets: BTreeMap<(Kernel, Flavor, String, Option<usize>), Vec<usize>> =
            BTreeMap::new();
        let mut ladders: BTreeMap<(Flavor, String, usize), Vec<usize>> = BTreeMap::new();
        for e in &entries {
            buckets
                .entry((e.kernel, e.flavor, e.dtype.name().to_string(), e.p))
                .or_default()
                .push(e.n);
            if e.kernel == Kernel::FusedLadder {
                if let Some(p) = e.p {
                    ladders
                        .entry((e.flavor, e.dtype.name().to_string(), e.n))
                        .or_default()
                        .push(p);
                }
            }
        }
        for v in buckets.values_mut().chain(ladders.values_mut()) {
            v.sort_unstable();
            v.dedup();
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries, buckets, ladders })
    }

    /// Smallest available bucket >= n for this kernel/flavor/dtype at the
    /// given kernel parameter `p` (regression dimension / ladder width;
    /// `None` for the plain vector kernels).
    pub fn bucket_for(
        &self,
        kernel: Kernel,
        flavor: Flavor,
        dtype: DType,
        n: usize,
        p: Option<usize>,
    ) -> Result<usize> {
        let key = (kernel, flavor, dtype.name().to_string(), p);
        let bs = self.buckets.get(&key).ok_or_else(|| {
            Error::Artifact(format!(
                "no artifacts for {}/{}/{}{} — re-run `make artifacts`",
                kernel.name(),
                flavor.name(),
                dtype.name(),
                p.map(|p| format!("/p{p}")).unwrap_or_default()
            ))
        })?;
        bs.iter().copied().find(|&b| b >= n).ok_or_else(|| {
            Error::Artifact(format!(
                "n={n} exceeds the largest {}/{}/{} bucket ({}); raise \
                 --max-log2n in `make artifacts`",
                kernel.name(),
                flavor.name(),
                dtype.name(),
                bs.last().copied().unwrap_or(0)
            ))
        })
    }

    /// Sorted `fused_ladder` widths available at this exact n bucket
    /// (empty when the artifact set predates the ladder kernel family).
    pub fn ladder_widths(&self, flavor: Flavor, dtype: DType, n: usize) -> &[usize] {
        self.ladders
            .get(&(flavor, dtype.name().to_string(), n))
            .map(|v| v.as_slice())
            .unwrap_or(&[])
    }

    /// Ladder-width bucket for a `want`-rung pass: the narrowest width
    /// >= `want` (the runtime pads by repeating the last rung), falling
    /// back to the widest available when the pass is wider than any bucket
    /// (the caller then chunks the ladder). `None` when no ladder
    /// artifacts exist at this n bucket.
    pub fn ladder_bucket(
        &self,
        flavor: Flavor,
        dtype: DType,
        n: usize,
        want: usize,
    ) -> Option<usize> {
        let ws = self.ladder_widths(flavor, dtype, n);
        ws.iter().copied().find(|&w| w >= want).or_else(|| ws.last().copied())
    }

    /// Widest `fused_ladder` bucket at this n bucket — what an adaptive
    /// probes-per-pass should use so one pass maps to one reduction.
    pub fn widest_ladder(&self, flavor: Flavor, dtype: DType, n: usize) -> Option<usize> {
        self.ladder_widths(flavor, dtype, n).last().copied()
    }

    /// Exact entry lookup.
    pub fn entry(
        &self,
        kernel: Kernel,
        flavor: Flavor,
        dtype: DType,
        n: usize,
        p: Option<usize>,
    ) -> Result<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| {
                e.kernel == kernel
                    && e.flavor == flavor
                    && e.dtype == dtype
                    && e.n == n
                    && e.p == p
            })
            .ok_or_else(|| {
                Error::Artifact(format!(
                    "missing artifact {}/{}/{}/n{}{}",
                    kernel.name(),
                    flavor.name(),
                    dtype.name(),
                    n,
                    p.map(|p| format!("/p{p}")).unwrap_or_default()
                ))
            })
    }

    /// Largest bucket available (used to size benchmark sweeps).
    pub fn max_bucket(
        &self,
        kernel: Kernel,
        flavor: Flavor,
        dtype: DType,
        p: Option<usize>,
    ) -> Option<usize> {
        self.buckets
            .get(&(kernel, flavor, dtype.name().to_string(), p))
            .and_then(|v| v.last().copied())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 2,
      "digest": "abc",
      "default_p": 8,
      "min_log2n": 12,
      "max_log2n": 13,
      "entries": [
        {"kernel": "fused_objective", "flavor": "jnp", "dtype": "f64",
         "n": 4096, "p": null, "path": "a.hlo.txt",
         "inputs": [{"dtype": "f64", "shape": [4096]},
                    {"dtype": "f64", "shape": [1]},
                    {"dtype": "i32", "shape": [1]}],
         "outputs": [{"dtype": "f64", "shape": [1]}]},
        {"kernel": "fused_objective", "flavor": "jnp", "dtype": "f64",
         "n": 8192, "p": null, "path": "b.hlo.txt",
         "inputs": [], "outputs": []},
        {"kernel": "residuals", "flavor": "pallas", "dtype": "f32",
         "n": 4096, "p": 8, "path": "c.hlo.txt",
         "inputs": [], "outputs": []},
        {"kernel": "fused_ladder", "flavor": "jnp", "dtype": "f64",
         "n": 4096, "p": 3, "path": "d.hlo.txt",
         "inputs": [], "outputs": []},
        {"kernel": "fused_ladder", "flavor": "jnp", "dtype": "f64",
         "n": 4096, "p": 7, "path": "e.hlo.txt",
         "inputs": [], "outputs": []},
        {"kernel": "fused_ladder", "flavor": "jnp", "dtype": "f64",
         "n": 8192, "p": 7, "path": "f.hlo.txt",
         "inputs": [], "outputs": []}
      ]
    }"#;

    #[test]
    fn parses_and_indexes() {
        let m = Manifest::parse(Path::new("/tmp/arts"), SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 6);
        assert_eq!(
            m.bucket_for(Kernel::FusedObjective, Flavor::Jnp, DType::F64, 5000, None)
                .unwrap(),
            8192
        );
        assert_eq!(
            m.bucket_for(Kernel::FusedObjective, Flavor::Jnp, DType::F64, 4096, None)
                .unwrap(),
            4096
        );
        assert!(m
            .bucket_for(Kernel::FusedObjective, Flavor::Jnp, DType::F64, 9000, None)
            .is_err());
        assert!(m
            .bucket_for(Kernel::Neighbors, Flavor::Jnp, DType::F64, 10, None)
            .is_err());
    }

    #[test]
    fn bucket_lookup_is_p_aware() {
        let m = Manifest::parse(Path::new("/tmp/arts"), SAMPLE).unwrap();
        // residuals exist only at p=8: a p=4 request must not alias them
        assert_eq!(
            m.bucket_for(Kernel::Residuals, Flavor::Pallas, DType::F32, 100, Some(8))
                .unwrap(),
            4096
        );
        assert!(m
            .bucket_for(Kernel::Residuals, Flavor::Pallas, DType::F32, 100, Some(4))
            .is_err());
        // ladder widths are distinct p families at one n bucket
        assert_eq!(
            m.bucket_for(Kernel::FusedLadder, Flavor::Jnp, DType::F64, 4096, Some(3))
                .unwrap(),
            4096
        );
        assert_eq!(
            m.bucket_for(Kernel::FusedLadder, Flavor::Jnp, DType::F64, 5000, Some(7))
                .unwrap(),
            8192
        );
        assert!(m
            .bucket_for(Kernel::FusedLadder, Flavor::Jnp, DType::F64, 5000, Some(3))
            .is_err());
    }

    #[test]
    fn ladder_width_lookup_and_fallback() {
        let m = Manifest::parse(Path::new("/tmp/arts"), SAMPLE).unwrap();
        assert_eq!(m.ladder_widths(Flavor::Jnp, DType::F64, 4096), &[3, 7]);
        assert_eq!(m.ladder_widths(Flavor::Jnp, DType::F64, 8192), &[7]);
        // no ladder artifacts at all for this flavor/dtype
        assert!(m.ladder_widths(Flavor::Pallas, DType::F64, 4096).is_empty());
        assert_eq!(m.ladder_bucket(Flavor::Pallas, DType::F64, 4096, 2), None);
        // narrowest width >= want
        assert_eq!(m.ladder_bucket(Flavor::Jnp, DType::F64, 4096, 2), Some(3));
        assert_eq!(m.ladder_bucket(Flavor::Jnp, DType::F64, 4096, 4), Some(7));
        // wider than every bucket: fall back to the widest (caller chunks)
        assert_eq!(m.ladder_bucket(Flavor::Jnp, DType::F64, 4096, 64), Some(7));
        assert_eq!(m.widest_ladder(Flavor::Jnp, DType::F64, 4096), Some(7));
        assert_eq!(m.widest_ladder(Flavor::Jnp, DType::F32, 4096), None);
    }

    #[test]
    fn entry_lookup_with_p() {
        let m = Manifest::parse(Path::new("/x"), SAMPLE).unwrap();
        let e = m
            .entry(Kernel::Residuals, Flavor::Pallas, DType::F32, 4096, Some(8))
            .unwrap();
        assert_eq!(e.path, Path::new("/x/c.hlo.txt"));
        assert!(m
            .entry(Kernel::Residuals, Flavor::Pallas, DType::F32, 4096, Some(4))
            .is_err());
    }

    #[test]
    fn rejects_wrong_version() {
        let bad = SAMPLE.replace("\"version\": 2", "\"version\": 1");
        assert!(Manifest::parse(Path::new("/x"), &bad).is_err());
    }

    #[test]
    fn input_specs_roundtrip() {
        let m = Manifest::parse(Path::new("/x"), SAMPLE).unwrap();
        let e = &m.entries[0];
        assert_eq!(e.inputs.len(), 3);
        assert_eq!(e.inputs[0].shape, vec![4096]);
        assert_eq!(e.inputs[2].dtype, "i32");
    }

    #[test]
    fn kernel_flavor_names_roundtrip() {
        for k in [
            Kernel::FusedObjective,
            Kernel::FusedLadder,
            Kernel::MinMaxSum,
            Kernel::Neighbors,
            Kernel::IntervalCount,
            Kernel::ThresholdStats,
            Kernel::KnnWeightedSum,
            Kernel::Residuals,
            Kernel::LmsProbe,
            Kernel::Dists,
        ] {
            assert_eq!(Kernel::from_name(k.name()), Some(k));
        }
        for f in [Flavor::Pallas, Flavor::Jnp] {
            assert_eq!(Flavor::from_name(f.name()), Some(f));
        }
    }
}
