//! `DeviceEvaluator`: the PJRT-backed implementation of
//! [`crate::select::Evaluator`].
//!
//! The data vector is uploaded **once** per dataset (the paper's premise:
//! x is produced and lives on the device); every probe ships only two
//! scalars up and five scalars down — the communication pattern that makes
//! the approach multi-device friendly (§V.D).

use std::rc::Rc;

use crate::runtime::client::{
    literal_scalar_f64, literal_scalar_i32, literal_vec_f64, literal_vec_i32, Runtime,
};
use crate::runtime::manifest::{Flavor, Kernel};
use crate::select::objective::{
    DType, Evaluator, InitStats, IntervalCounts, Neighbors, ProbeStats,
};
use crate::xla;
use crate::{Error, Result};

pub struct DeviceEvaluator {
    rt: Rc<Runtime>,
    flavor: Flavor,
    dtype: DType,
    /// Bucket the artifacts were compiled for (>= n, power of two).
    bucket: usize,
    n: usize,
    buf: xla::PjRtBuffer,
    /// n_valid as a device-resident i32 buffer — constant per dataset, so
    /// uploaded once instead of per probe (perf: saves one H2D per probe).
    nv_buf: xla::PjRtBuffer,
    /// Host mirror for compaction (DESIGN.md §7 copy_if substitution).
    mirror: Vec<f64>,
    probes: u64,
}

impl DeviceEvaluator {
    /// Upload `data` and prepare probe executables.
    pub fn upload(rt: &Rc<Runtime>, data: &[f64], dtype: DType) -> Result<Self> {
        Self::upload_with_flavor(rt, data, dtype, rt.flavor)
    }

    pub fn upload_with_flavor(
        rt: &Rc<Runtime>,
        data: &[f64],
        dtype: DType,
        flavor: Flavor,
    ) -> Result<Self> {
        if data.is_empty() {
            return Err(crate::invalid_arg!("empty input"));
        }
        let bucket =
            rt.manifest
                .bucket_for(Kernel::FusedObjective, flavor, dtype, data.len(), None)?;
        // All probe kernels must exist at this bucket; verify up front so a
        // missing artifact fails fast rather than mid-algorithm.
        for kernel in [Kernel::MinMaxSum, Kernel::Neighbors, Kernel::IntervalCount] {
            let fl = if kernel == Kernel::IntervalCount { Flavor::Jnp } else { flavor };
            rt.manifest.entry(kernel, fl, dtype, bucket, None)?;
        }
        let buf = rt.upload_vector(data, dtype, bucket)?;
        let nv_buf = rt.upload_i32(data.len() as i32)?;
        let mirror = match dtype {
            DType::F64 => data.to_vec(),
            // mirror what the device actually holds
            DType::F32 => data.iter().map(|&v| v as f32 as f64).collect(),
        };
        Ok(DeviceEvaluator {
            rt: rt.clone(),
            flavor,
            dtype,
            bucket,
            n: data.len(),
            buf,
            nv_buf,
            mirror,
            probes: 0,
        })
    }

    /// Wrap an existing device buffer (e.g. residuals produced by another
    /// artifact), with its host mirror.
    pub fn from_buffer(
        rt: &Rc<Runtime>,
        buf: xla::PjRtBuffer,
        mirror: Vec<f64>,
        n: usize,
        bucket: usize,
        dtype: DType,
    ) -> Result<Self> {
        let nv_buf = rt.upload_i32(n as i32)?;
        Ok(DeviceEvaluator {
            rt: rt.clone(),
            flavor: rt.flavor,
            dtype,
            bucket,
            n,
            buf,
            nv_buf,
            mirror,
            probes: 0,
        })
    }

    pub fn bucket(&self) -> usize {
        self.bucket
    }

    pub fn flavor(&self) -> Flavor {
        self.flavor
    }

    /// Whether this evaluator's artifact set has `fused_ladder` kernels at
    /// its bucket (older artifact sets fall back to per-launch batches).
    pub fn has_fused_ladder(&self) -> bool {
        !self
            .rt
            .manifest
            .ladder_widths(self.flavor, self.dtype, self.bucket)
            .is_empty()
    }

    /// One `fused_ladder` launch over a ladder chunk padded to width `p`.
    fn run_ladder_chunk(&mut self, chunk: &[f64], p: usize) -> Result<Vec<ProbeStats>> {
        let mut rungs = chunk.to_vec();
        let Some(&last) = rungs.last() else {
            return Err(Error::Xla("fused_ladder launch on an empty chunk".into()));
        };
        rungs.resize(p, last); // pad to the bucket by repeating the last probe
        let exe = self.rt.executable(
            Kernel::FusedLadder,
            self.flavor,
            self.dtype,
            self.bucket,
            Some(p),
        )?;
        let ys_buf = self.rt.upload_vector(&rungs, self.dtype, p)?;
        let args = [&self.buf, &ys_buf, &self.nv_buf];
        self.probes += 1; // the whole padded ladder is ONE device reduction
        let out = exe.run(&args)?;
        if out.len() != 5 {
            return Err(Error::Xla(format!("fused_ladder returned {} outputs", out.len())));
        }
        let s_lo = literal_vec_f64(&out[0], self.dtype)?;
        let s_hi = literal_vec_f64(&out[1], self.dtype)?;
        let c_lt = literal_vec_i32(&out[2])?;
        let c_eq = literal_vec_i32(&out[3])?;
        let c_gt = literal_vec_i32(&out[4])?;
        if s_lo.len() < chunk.len() {
            return Err(Error::Xla(format!("fused_ladder p={} returned {} rungs", p, s_lo.len())));
        }
        Ok((0..chunk.len())
            .map(|j| ProbeStats {
                s_lo: s_lo[j],
                s_hi: s_hi[j],
                c_lt: c_lt[j] as u64,
                c_eq: c_eq[j] as u64,
                c_gt: c_gt[j] as u64,
            })
            .collect())
    }

    fn run_probe_kernel(
        &mut self,
        kernel: Kernel,
        flavor: Flavor,
        scalars: &[f64],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self
            .rt
            .executable(kernel, flavor, self.dtype, self.bucket, None)?;
        let mut bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(scalars.len());
        for &s in scalars {
            bufs.push(self.rt.upload_scalar(s, self.dtype)?);
        }
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(bufs.len() + 2);
        args.push(&self.buf);
        args.extend(bufs.iter());
        args.push(&self.nv_buf); // cached: n_valid never changes
        self.probes += 1;
        exe.run(&args)
    }
}

fn parse_probe_stats(out: &[xla::Literal], dtype: DType) -> Result<ProbeStats> {
    if out.len() != 5 {
        return Err(Error::Xla(format!("fused_objective returned {} outputs", out.len())));
    }
    Ok(ProbeStats {
        s_lo: literal_scalar_f64(&out[0], dtype)?,
        s_hi: literal_scalar_f64(&out[1], dtype)?,
        c_lt: literal_scalar_i32(&out[2])? as u64,
        c_eq: literal_scalar_i32(&out[3])? as u64,
        c_gt: literal_scalar_i32(&out[4])? as u64,
    })
}

impl Evaluator for DeviceEvaluator {
    fn n(&self) -> usize {
        self.n
    }

    fn dtype(&self) -> DType {
        self.dtype
    }

    fn init_stats(&mut self) -> Result<InitStats> {
        let out = self.run_probe_kernel(Kernel::MinMaxSum, self.flavor, &[])?;
        if out.len() != 3 {
            return Err(Error::Xla(format!("minmaxsum returned {} outputs", out.len())));
        }
        Ok(InitStats {
            min: literal_scalar_f64(&out[0], self.dtype)?,
            max: literal_scalar_f64(&out[1], self.dtype)?,
            sum: literal_scalar_f64(&out[2], self.dtype)?,
        })
    }

    fn probe(&mut self, y: f64) -> Result<ProbeStats> {
        let out = self.run_probe_kernel(Kernel::FusedObjective, self.flavor, &[y])?;
        parse_probe_stats(&out, self.dtype)
    }

    fn probe_many(&mut self, ys: &[f64]) -> Result<Vec<ProbeStats>> {
        if ys.is_empty() {
            return Ok(Vec::new());
        }
        let maybe_widest = self.rt.manifest.widest_ladder(self.flavor, self.dtype, self.bucket);
        let Some(widest) = maybe_widest else {
            // No `fused_ladder` artifacts at this bucket (pre-ladder
            // artifact set): forward the batch in one round-trip — resolve
            // the executable once, upload every probe scalar up front, then
            // launch back-to-back. Each launch is a real device reduction
            // and is honestly counted as one.
            let exe = self.rt.executable(
                Kernel::FusedObjective,
                self.flavor,
                self.dtype,
                self.bucket,
                None,
            )?;
            let mut scalar_bufs = Vec::with_capacity(ys.len());
            for &y in ys {
                scalar_bufs.push(self.rt.upload_scalar(y, self.dtype)?);
            }
            let mut raw = Vec::with_capacity(ys.len());
            for sb in &scalar_bufs {
                let args = [&self.buf, sb, &self.nv_buf];
                self.probes += 1;
                raw.push(exe.run(&args)?);
            }
            return raw.iter().map(|out| parse_probe_stats(out, self.dtype)).collect();
        };
        // Fused path: sort/dedup the (canonicalized) ladder exactly like
        // the host oracle, pad each chunk up to the nearest width bucket by
        // repeating the last probe, and run ONE `fused_ladder` reduction
        // per chunk — so a whole multisection pass costs one launch and the
        // probe counter matches the host/sharded accounting.
        let (canon, ladder) = crate::select::objective::fused_ladder_rungs(ys, self.dtype);
        let mut stats = Vec::with_capacity(ladder.len());
        for chunk in ladder.chunks(widest) {
            let p = self
                .rt
                .manifest
                .ladder_bucket(self.flavor, self.dtype, self.bucket, chunk.len())
                .ok_or_else(|| {
                    Error::Xla(format!("no fused_ladder bucket covers width {}", chunk.len()))
                })?;
            stats.extend(self.run_ladder_chunk(chunk, p)?);
        }
        // Back to the caller's probe order; duplicates share one rung,
        // NaN probes get probe(NaN)'s all-zero stats.
        Ok(crate::select::objective::ladder_stats_in_probe_order(&canon, &ladder, &stats))
    }

    fn neighbors(&mut self, y: f64) -> Result<Neighbors> {
        let flavor = self.flavor;
        let out = self.run_probe_kernel(Kernel::Neighbors, flavor, &[y])?;
        Ok(Neighbors {
            lower: literal_scalar_f64(&out[0], self.dtype)?,
            upper: literal_scalar_f64(&out[1], self.dtype)?,
            c_le: literal_scalar_i32(&out[2])? as u64,
        })
    }

    fn interval(&mut self, lo: f64, hi: f64) -> Result<IntervalCounts> {
        let out = self.run_probe_kernel(Kernel::IntervalCount, Flavor::Jnp, &[lo, hi])?;
        Ok(IntervalCounts {
            c_le: literal_scalar_i32(&out[0])? as u64,
            c_in: literal_scalar_i32(&out[1])? as u64,
            c_ge: literal_scalar_i32(&out[2])? as u64,
        })
    }

    fn compact(&mut self, lo: f64, hi: f64) -> Result<Vec<f64>> {
        // Host-side copy_if over the mirror (documented substitution),
        // branchless like HostEvaluator::compact.
        let (lo, hi) = (self.canon(lo), self.canon(hi));
        let mut out = vec![0.0f64; self.mirror.len()];
        let mut idx = 0usize;
        for &x in &self.mirror {
            out[idx] = x;
            idx += ((x > lo) & (x < hi)) as usize;
        }
        out.truncate(idx);
        Ok(out)
    }

    fn download(&mut self) -> Result<Vec<f64>> {
        // Real device→host copy through PJRT (not the mirror) so the
        // harness's "copy to CPU" phase measures an actual transfer.
        let lit = self.buf.to_literal_sync()?;
        let mut v = crate::runtime::client::literal_vec_f64(&lit, self.dtype)?;
        v.truncate(self.n);
        Ok(v)
    }

    fn probes(&self) -> u64 {
        self.probes
    }

    fn ladder_width_hint(&self) -> Option<usize> {
        // Widest `fused_ladder` bucket at this n bucket: pass planners size
        // their ladders from it so one pass maps to exactly one launch.
        self.rt.manifest.widest_ladder(self.flavor, self.dtype, self.bucket)
    }
}

/// Probe-scalar caveat: y is cast to the array dtype before upload, so an
/// f32 evaluator quantizes probes exactly like the paper's float runs.
#[cfg(test)]
mod tests {
    // Device tests live in rust/tests/runtime_integration.rs (they need the
    // artifacts directory); this module only hosts compile-time checks.
    use super::DeviceEvaluator;

    #[test]
    fn device_evaluator_is_not_send() {
        // PJRT handles are thread-confined; this is a compile-time contract
        // documented for the coordinator. (Negative impl can't be asserted
        // directly; this test is a placeholder documenting the invariant.)
        let _ = std::any::type_name::<DeviceEvaluator>();
    }
}
