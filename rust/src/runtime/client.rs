//! PJRT runtime: load HLO-text artifacts, compile once, execute many.
//!
//! Mirrors `/opt/xla-example/load_hlo/`: `HloModuleProto::from_text_file`
//! (text is the 0.5.1-safe interchange) → `XlaComputation::from_proto` →
//! `client.compile` → `execute_b` with device-resident buffers.
//!
//! PJRT handles wrap raw pointers and are **not Send**: the coordinator
//! gives each simulated device its own OS thread owning a `Runtime`
//! (see `coordinator::service`), which is also how a real accelerator
//! stream executor is driven.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use crate::runtime::manifest::{ArtifactEntry, Flavor, Kernel, Manifest};
use crate::select::DType;
use crate::xla;
use crate::{Error, Result};

/// A compiled artifact with its I/O spec.
pub struct Executable {
    pub entry: ArtifactEntry,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with device buffers; returns the untupled output literals.
    pub fn run(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        if args.len() != self.entry.inputs.len() {
            return Err(Error::Xla(format!(
                "{}: expected {} inputs, got {}",
                self.entry.kernel.name(),
                self.entry.inputs.len(),
                args.len()
            )));
        }
        let out = self.exe.execute_b(args)?;
        let first = out
            .into_iter()
            .next()
            .and_then(|r| r.into_iter().next())
            .ok_or_else(|| Error::Xla("executable returned no outputs".into()))?;
        // aot.py lowers with return_tuple=True: one tuple-shaped buffer.
        let lit = first.to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }
}

/// Owns the PJRT client, the manifest, and a lazy executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<(Kernel, Flavor, DType, usize, Option<usize>), Rc<Executable>>>,
    /// Default flavor for hot kernels (config `kernel_flavor`).
    pub flavor: Flavor,
    /// Compile counter (observability / tests).
    compiles: RefCell<u64>,
}

impl Runtime {
    /// Load the manifest and start a CPU PJRT client.
    pub fn new(artifacts_dir: &Path) -> Result<Rc<Runtime>> {
        Self::with_flavor(artifacts_dir, Flavor::Jnp)
    }

    pub fn with_flavor(artifacts_dir: &Path, flavor: Flavor) -> Result<Rc<Runtime>> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Rc::new(Runtime {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            flavor,
            compiles: RefCell::new(0),
        }))
    }

    /// Default artifacts directory: `$CP_SELECT_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("CP_SELECT_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn compiles(&self) -> u64 {
        *self.compiles.borrow()
    }

    /// Fetch (compiling lazily) the executable for an artifact key.
    pub fn executable(
        &self,
        kernel: Kernel,
        flavor: Flavor,
        dtype: DType,
        n: usize,
        p: Option<usize>,
    ) -> Result<Rc<Executable>> {
        let key = (kernel, flavor, dtype, n, p);
        if let Some(e) = self.cache.borrow().get(&key) {
            return Ok(e.clone());
        }
        let entry = self.manifest.entry(kernel, flavor, dtype, n, p)?.clone();
        let path = entry.path.to_string_lossy().to_string();
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        *self.compiles.borrow_mut() += 1;
        let e = Rc::new(Executable { entry, exe });
        self.cache.borrow_mut().insert(key, e.clone());
        Ok(e)
    }

    /// Upload an f64 slice as a device buffer in the given dtype, padded to
    /// `bucket` elements (pad value is masked out by `n_valid` kernels).
    pub fn upload_vector(
        &self,
        data: &[f64],
        dtype: DType,
        bucket: usize,
    ) -> Result<xla::PjRtBuffer> {
        debug_assert!(bucket >= data.len());
        match dtype {
            DType::F64 => {
                let mut padded = Vec::with_capacity(bucket);
                padded.extend_from_slice(data);
                padded.resize(bucket, 0.0);
                Ok(self.client.buffer_from_host_buffer(&padded, &[bucket], None)?)
            }
            DType::F32 => {
                let mut padded: Vec<f32> = Vec::with_capacity(bucket);
                padded.extend(data.iter().map(|&v| v as f32));
                padded.resize(bucket, 0.0);
                Ok(self.client.buffer_from_host_buffer(&padded, &[bucket], None)?)
            }
        }
    }

    /// Upload a raw f32 slice (no conversion).
    pub fn upload_f32(&self, data: &[f32], bucket: usize) -> Result<xla::PjRtBuffer> {
        let mut padded: Vec<f32> = Vec::with_capacity(bucket);
        padded.extend_from_slice(data);
        padded.resize(bucket, 0.0);
        Ok(self.client.buffer_from_host_buffer(&padded, &[bucket], None)?)
    }

    /// Upload a scalar as a shape-(1,) buffer in the value dtype.
    pub fn upload_scalar(&self, v: f64, dtype: DType) -> Result<xla::PjRtBuffer> {
        match dtype {
            DType::F64 => Ok(self.client.buffer_from_host_buffer(&[v], &[1], None)?),
            DType::F32 => {
                Ok(self.client.buffer_from_host_buffer(&[v as f32], &[1], None)?)
            }
        }
    }

    /// Upload an i32 scalar (n_valid).
    pub fn upload_i32(&self, v: i32) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(&[v], &[1], None)?)
    }

    /// Upload an f64 matrix (row-major `n × p`) in the value dtype, padding
    /// rows with zeros up to `bucket`.
    pub fn upload_matrix(
        &self,
        data: &[f64],
        n: usize,
        p: usize,
        dtype: DType,
        bucket: usize,
    ) -> Result<xla::PjRtBuffer> {
        debug_assert_eq!(data.len(), n * p);
        match dtype {
            DType::F64 => {
                let mut padded = Vec::with_capacity(bucket * p);
                padded.extend_from_slice(data);
                padded.resize(bucket * p, 0.0);
                Ok(self
                    .client
                    .buffer_from_host_buffer(&padded, &[bucket, p], None)?)
            }
            DType::F32 => {
                let mut padded: Vec<f32> = Vec::with_capacity(bucket * p);
                padded.extend(data.iter().map(|&v| v as f32));
                padded.resize(bucket * p, 0.0);
                Ok(self
                    .client
                    .buffer_from_host_buffer(&padded, &[bucket, p], None)?)
            }
        }
    }
}

/// Read a scalar f64 out of an output literal (any float dtype).
pub fn literal_scalar_f64(lit: &xla::Literal, dtype: DType) -> Result<f64> {
    match dtype {
        DType::F64 => Ok(lit.to_vec::<f64>()?[0]),
        DType::F32 => Ok(lit.to_vec::<f32>()?[0] as f64),
    }
}

/// Read a scalar i32 (counts).
pub fn literal_scalar_i32(lit: &xla::Literal) -> Result<i64> {
    Ok(lit.to_vec::<i32>()?[0] as i64)
}

/// Download an i32 vector literal (per-rung ladder counts).
pub fn literal_vec_i32(lit: &xla::Literal) -> Result<Vec<i32>> {
    Ok(lit.to_vec::<i32>()?)
}

/// Download a float vector literal as f64.
pub fn literal_vec_f64(lit: &xla::Literal, dtype: DType) -> Result<Vec<f64>> {
    match dtype {
        DType::F64 => Ok(lit.to_vec::<f64>()?),
        DType::F32 => Ok(lit.to_vec::<f32>()?.into_iter().map(|v| v as f64).collect()),
    }
}
