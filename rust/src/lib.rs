//! # cp-select
//!
//! Production-grade reproduction of **Beliakov (2011), "Parallel calculation
//! of the median and order statistics on GPUs with application to robust
//! regression"** as a three-layer Rust + JAX + Pallas stack:
//!
//! - **Layer 3 (this crate)** — the coordinator: selection algorithms
//!   (Kelley's cutting plane, bisection, Brent, quickselect, radix-sort
//!   baselines, the hybrid method), the selection service, simulated
//!   multi-device sharding, robust regression (LMS/LTS) and kNN
//!   applications, plus the benchmark harness regenerating every table and
//!   figure of the paper.
//! - **Runtime** — [`runtime`] loads AOT-compiled HLO artifacts (emitted once
//!   by `python/compile/aot.py`) through the PJRT C API and executes them
//!   with device-resident buffers. Python never runs on the request path.
//! - **Layers 1–2** — Pallas kernels + JAX graphs live in `python/compile/`;
//!   see DESIGN.md for the architecture and the hardware-adaptation notes.
//!
//! ## Quick start
//!
//! ```no_run
//! use cp_select::select::{self, Method};
//! use cp_select::stats::{Distribution, Rng};
//!
//! let mut rng = Rng::seeded(42);
//! let data = Distribution::Normal.sample_vec(&mut rng, 1 << 20);
//! let mut ev = select::HostEvaluator::new(&data);
//! let res = select::median(&mut ev, Method::CuttingPlane).unwrap();
//! println!("median = {} in {} probes", res.value, res.probes);
//! ```

pub mod config;
pub mod coordinator;
pub mod device;
pub mod error;
pub mod harness;
pub mod knn;
pub mod regression;
pub mod runtime;
pub mod select;
pub mod stats;
pub mod testkit;
pub mod util;

pub use error::{Error, Result};
