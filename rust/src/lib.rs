//! # cp-select
//!
//! Production-grade reproduction of **Beliakov (2011), "Parallel calculation
//! of the median and order statistics on GPUs with application to robust
//! regression"** as a three-layer Rust + JAX + Pallas stack:
//!
//! - **Layer 3 (this crate)** — the coordinator: selection algorithms
//!   (Kelley's cutting plane, bisection, Brent, quickselect, radix-sort
//!   baselines, the hybrid method), the selection service, simulated
//!   multi-device sharding, robust regression (LMS/LTS) and kNN
//!   applications, plus the benchmark harness regenerating every table and
//!   figure of the paper.
//! - **Runtime** — [`runtime`] loads AOT-compiled HLO artifacts (emitted once
//!   by `python/compile/aot.py`) through the PJRT C API and executes them
//!   with device-resident buffers. Python never runs on the request path.
//! - **Layers 1–2** — Pallas kernels + JAX graphs live in `python/compile/`;
//!   see DESIGN.md for the architecture and the hardware-adaptation notes.
//!
//! ## Batched multi-probe evaluation (probes per pass)
//!
//! The paper's central observation is that selection cost on an accelerator
//! is dominated by the number of **full passes** (fused reductions) over the
//! array, not by the per-element work inside a pass. The [`select::Evaluator`]
//! trait therefore exposes two granularities:
//!
//! - [`select::Evaluator::probe`] — one probe, one pass (Algorithm 1's unit);
//! - [`select::Evaluator::probe_many`] — a sorted *probe ladder* evaluated in
//!   a **single fused pass**: each element is binned against the ladder and
//!   per-probe [`select::ProbeStats`] are recovered by prefix-summing the bin
//!   partials. One pass buys `p` probes' worth of information.
//!
//! "Probes per pass" is a first-class axis of the system:
//!
//! - [`select::multisection`] generalizes bisection to `p` probes per pass,
//!   converging in `log_{p+1}(range/tol)` passes instead of `log_2` — with
//!   `p = 15`, a 2²² array resolves in ≲ ⌈log₁₆(2·range/ε)⌉ passes;
//! - the cutting plane fuses its Kelley model minimizer with its bisection
//!   safeguard into one two-probe ladder per iteration, keeping the paper's
//!   `maxit + 1` reduction budget while shrinking the bracket by both cuts;
//! - [`device::ShardedEvaluator`] forwards whole ladders per shard
//!   round-trip (one scalar-combine round per *batch*, not per probe);
//! - the [`coordinator`] coalesces concurrent queries against the same
//!   resident dataset into shared `probe_many` rounds: the sufficient
//!   statistics of a probe are rank-independent, so one ladder pass serves
//!   every queued `k` simultaneously (`SelectionService::query_many`, or
//!   any singles caught by the batching window below).
//!
//! The tradeoff: wider ladders cost more per-element compare work per pass
//! (still memory-bound for small `p` on the host) in exchange for fewer
//! passes; `p` is tunable per method via its options struct and chosen by
//! a measured cost model (below) when nothing pins it.
//!
//! ## The batching window and the coalescing planner
//!
//! Serving-side, the win scales with how many concurrent queries ride each
//! ladder. Coordinator workers therefore batch their ingest queue over a
//! **time window** (`coordinator::CoordinatorOptions { batch_window,
//! batch_cap }`, CLI `--batch-window-us`/`--batch-cap`): a probe-based
//! query at the head of a batch opens the window, and the worker keeps
//! collecting (`recv_timeout`) until the deadline or the cap — so
//! independent clients that arrive within one window coalesce even though
//! none of them used `query_many`. Uploads, drops and download-method
//! queries start drain-only batches (holding them buys no sharing), and a
//! zero window — the library default; the deployment config defaults to
//! 200 µs — degrades everything to the old drain-what's-queued
//! micro-batching.
//!
//! Each collected window is compiled into an execution plan (the batch
//! planner in `coordinator/planner.rs`): probe-based `Query` singles and
//! `QueryMany` specs against the same dataset merge into **one** unified
//! `multi_order_statistics` group per dataset, while uploads, drops and
//! download-method queries keep per-dataset FIFO order (a drop never
//! overtakes the query that preceded it, and an interleaved `QueryMany` no
//! longer splits the singles around it). Groups ride the **measured
//! pass-cost model** ([`select::PassCostModel`]): pass cost vs
//! ladder width is seeded from the committed `BENCH_select.json`
//! trajectory, refined online from measured run timings, and
//! consulted by `MultisectOptions::for_evaluator[_with]` so probes-per-pass
//! follows measured cost (the device's native `fused_ladder` bucket, when
//! advertised, stays the plan: padding makes narrower ladders cost the
//! same launch and chunking shrinks less than adaptive passes).
//!
//! Accounting under coalescing: a shared group is **one run** — it records
//! one latency sample (`Metrics::count()` tracks runs; `queries` tracks
//! queries) and its fused reductions are split across members so per-query
//! `probes` still sum to the real total.
//!
//! ## The adaptive window and the cost-model pool
//!
//! Both knobs above started life static: the window was a fixed operator
//! config, and every worker re-learned its cost model from scratch. The
//! coordinator now closes both loops:
//!
//! - **Load-adaptive window** ([`coordinator::WindowController`],
//!   `CoordinatorOptions::adaptive`, config `[service] latency_sla_us` /
//!   `adaptive_window`, CLI `--latency-sla-us`): the window *widens*
//!   multiplicatively while closed windows keep catching ≥ 2 *same-dataset*
//!   coalescable arrivals (the only traffic a wider window can merge, and
//!   the signal that predicts the next window coalesces too), *shrinks* to
//!   exactly zero on idle
//!   windows (steady-idle traffic pays no latency floor at all), and is
//!   *clamped* so `window + p99(run) ≤ latency_sla` at every decision.
//!   Writing `batch_window_us` explicitly remains the manual override.
//!   Controller state is observable: `Snapshot { window_us, window_widen,
//!   window_shrink, window_sla_clamp }`, and `BENCH_select.json` carries
//!   an `adaptive_window` row (the 8-client burst coalesces to the same
//!   21 fused reductions as the fixed 250 ms window, while an idle query
//!   pays zero added window latency).
//! - **Cross-worker cost-model pool** ([`select::CostModelPool`]): workers
//!   plan each shared run from a snapshot of one pooled model and feed
//!   their measured timings back as *sufficient statistics* (the normal-
//!   equation accumulators merge associatively — order/partition of
//!   observations cannot change the fit), so a new worker warm-starts
//!   from the fleet's measurements and the identifiability guards act on
//!   the best-posed statistics available. Sidecar persistence
//!   (`[service] cost_model_sidecar`, `--cost-model-sidecar`,
//!   conventionally `BENCH_select.cost_model.json` next to the committed
//!   baseline) makes restarts start measured rather than seeded; corrupt
//!   sidecars log and fall back to the seed.
//!
//! Time-dependent control logic is only trustworthy if it is testable:
//! every window wait and time read goes through a [`testkit::Clock`]
//! (real, or a [`testkit::VirtualClock`] that moves only under manual
//! `advance`), so the whole coalescing/controller suite runs sleep-free
//! and deterministic — an open window under a frozen clock literally
//! cannot expire early, and `VirtualClock::wait_for_waiters` sequences
//! tests against a parked worker instead of against the scheduler.
//!
//! ## Overload behavior and degradation semantics
//!
//! The coordinator is overload-hardened: a service under pressure degrades
//! into **typed, actionable errors** — never hung reply channels, dead
//! workers, or unbounded queues. The contract, end to end:
//!
//! - **Admission control** — per-tenant token buckets
//!   ([`coordinator::CoordinatorOptions::tenant_quota`], config `[service]
//!   tenant_rate_per_sec` / `tenant_burst`, CLI `--tenant-rate` /
//!   `--tenant-burst`) gate queries *before* they enqueue. Buckets refill
//!   on the service clock (virtual in tests, so refill instants are
//!   exact). An over-quota query is shed synchronously with
//!   [`Error::Overloaded`]`{ retry_after_us }` — the hint says exactly
//!   when a token will exist. Uploads and drops are control-plane traffic
//!   and bypass admission.
//! - **Backpressure policy** — `shed_policy` (config `[service]
//!   shed_policy = "block" | "shed"`, CLI `--shed-policy`) picks what a
//!   full ingest queue does: `Block` (default) applies classic
//!   backpressure by blocking the caller; `Shed` rejects with
//!   `Overloaded`, hinting retry after the observed p99 run latency.
//!   `queue_cap` (config `queue_depth`, CLI `--queue-cap`) bounds the
//!   queue per worker.
//! - **Deadlines** — [`coordinator::QueryOptions::deadline`] is a
//!   per-query budget, converted to an absolute instant at dispatch and
//!   checked before the run starts *and* cooperatively between fused
//!   passes; an expired query resolves with
//!   [`Error::DeadlineExceeded`]`{ late_us }`. In a coalesced group the
//!   shared run cancels only when **every** member carries a deadline
//!   (a no-deadline member's work is never abandoned); a member whose own
//!   deadline lapsed while the shared run served the rest still reports
//!   `DeadlineExceeded`.
//! - **Fair-share planning** — each drained batch is round-robined across
//!   tenants (order of first appearance) without ever violating
//!   per-dataset FIFO barriers, so one tenant's flood cannot starve
//!   another's lone query (`planner::fair_order`).
//! - **Worker fault isolation** — every backend execution is wrapped in
//!   `catch_unwind`: a panicking evaluator pass fails *that batch's*
//!   repliers with a typed `worker fault …` error, bumps `worker_faults`,
//!   and the worker keeps serving the queue behind it.
//! - **Pressure-driven eviction** — [`coordinator::lru_factory`] (config
//!   `[service] max_resident_datasets`, CLI `--max-resident`) caps
//!   resident datasets per worker with O(1) LRU bookkeeping. A query for
//!   an evicted dataset resolves with a typed *re-upload* error — the
//!   cache-miss contract — and confirmed evictions surface in the
//!   `evictions` metric, racing in-flight queries safely.
//!
//! Observability: `Metrics`/`Snapshot` carry `shed`, `deadline_exceeded`,
//! `worker_faults`, `evictions`, and a live per-tenant queue-depth gauge
//! (`tenant_depth`/`max_tenant_depth`). The deterministic chaos harness
//! (`harness::bench_overload`: Zipf-weighted multi-tenant burst, scripted
//! faults, frozen virtual clock) gates these semantics in
//! `BENCH_select.json` — counts by equality, tenant fairness by a
//! max/min completion-ratio bound.
//!
//! ## The wall-clock trajectory and the vectorized host sweep
//!
//! Pass counts are the portable, host-independent trajectory — but the
//! paper's claims are ultimately about wall time, so the repo now tracks
//! both. Two coupled pieces:
//!
//! - **Lane-split binned sweep** — the host ladder kernel
//!   ([`select::ladder_sweep`], the engine under every `probe_many`) is a
//!   tiled, branch-free loop: each 8-element tile ([`select::LADDER_LANES`])
//!   computes its bin index as a sum of `(rung < x) as usize` compares —
//!   one SIMD compare per rung across the whole tile — and scatters into
//!   **lane-private** accumulators laid out bin-major × lane-minor
//!   (`cnt[bin * LANES + lane]`). The old scalar kernel accumulated all
//!   lanes into one shared bin array, so consecutive same-bin elements
//!   formed a store-to-load forwarding chain (~4–5 cycles/element) that
//!   also blocked autovectorization; giving every lane its own column
//!   breaks the dependence and lets LLVM vectorize the compare ladder.
//!   NaN elements route to a private trash slot and never surface; lanes
//!   fold into one [`select::LadderPartial`] per chunk via
//!   `LadderPartial::merge`, so the threaded scoped-chunk path and every
//!   caller above it are unchanged. Counts (`cnt`/`eq`) are bit-identical
//!   to the retained scalar oracle ([`select::ladder_sweep_scalar`], pinned
//!   by `tests/ladder_wall.rs`); `sum` may reassociate per lane, the same
//!   O(ε·Σ|x|) license the threaded reduction already claims.
//! - **`bench-wall`** — `cargo run --release -- bench-wall` (from
//!   `rust/`) measures the real trajectory: per-(method, n) wall medians
//!   and p99s over warmup + N reps (summarized by the repo's *own*
//!   order-statistic code — [`select::fixed_pivot`] at the paper's rank
//!   convention), the vector-vs-scalar bin-sweep throughput race in GB/s,
//!   and a measured `(sweep, per_probe)` pass-cost fit that seeds
//!   [`select::PassCostModel`] via `seeded_from_measured`. Everything
//!   lands in `BENCH_select.json` (schema v2) under a host fingerprint
//!   (cpu model, logical cores, rustc); the `select_json` gate compares
//!   wall numbers only between identical fingerprints — counts stay the
//!   hard cross-host gate, wall time is the informational per-host ratchet.
//!   `--quick 1` shrinks the grid for CI's perf-smoke leg, and `--smoke 1`
//!   additionally asserts the vectorized sweep beats the scalar oracle by
//!   ≥ 1.5× at n = 2²².
//!
//! [`Method::FixedPivot`](select::Method::FixedPivot) rides along as a
//! host baseline: the Azzini–Perrotta single-pass fixed-pivot selector
//! (pivot = `A[k]` each round), the simplest credible download-method
//! yardstick for the wall table.
//!
//! ## The device ladder path and probe accounting
//!
//! The AOT artifact set carries a `fused_ladder(p)` kernel family (emitted
//! per ladder-width bucket p ∈ {3, 7, 15} alongside the n buckets): one
//! binned device sweep returns per-rung sufficient statistics for a whole
//! sorted probe ladder, with prefix/suffix recovery of `(s_lo, s_hi)`
//! folded into the same HLO module. `runtime::DeviceEvaluator::probe_many`
//! sorts/dedups the (dtype-canonicalized) ladder, pads it up to the
//! nearest width bucket by repeating the last rung, and launches **one**
//! reduction per pass — chunking only when a ladder is wider than every
//! bucket. `select::MultisectOptions::for_evaluator` closes the loop: it
//! reads [`select::Evaluator::ladder_width_hint`] (the widest ladder
//! artifact at the dataset's bucket) so multisection sizes its passes to
//! exactly one launch each.
//!
//! **Accounting rules** (what [`select::Evaluator::probes`] counts, and
//! what `BENCH_select.json` tracks as `fused_reductions`):
//!
//! 1. one `probe`/`init_stats`/`neighbors`/`interval` call = one reduction;
//! 2. one natively-fused `probe_many` ladder = one reduction per width
//!    chunk (one chunk in the common case) — on the host oracle, the
//!    sharded group (logical count), *and* the device runtime with ladder
//!    artifacts present;
//! 3. without `fused_ladder` artifacts (a pre-ladder artifact set) the
//!    device evaluator falls back to back-to-back `fused_objective`
//!    launches and honestly counts one reduction per launch — counts are
//!    never under-reported.
//!
//! ## Cluster mode and the message layer
//!
//! [`cluster`] splits the service across processes: `cp-select cluster
//! coordinator` serves clients over TCP, and `cp-select cluster worker`
//! processes host dataset shards. One wire protocol
//! ([`coordinator::messages`]) covers both hops — length-prefixed JSON
//! frames with typed request/response enums, `u64` payloads as decimal
//! strings (no width loss), non-finite `f64` as tagged strings, and
//! errors as a typed frame that preserves [`Error::Overloaded`]'s
//! `retry_after_us` and [`Error::DeadlineExceeded`]'s `late_us` (both on
//! the coordinator's clock) plus [`Error::Disconnected`]'s peer.
//!
//! The load-bearing design decision: a remote worker is *just a
//! [`coordinator::DatasetBackend`]* ([`cluster::RemoteBackend`]) whose
//! `Evaluator` primitives each travel as one `Shard*` round trip, so a
//! fused probe ladder is still one wire exchange shipping per-rung
//! sufficient statistics, never raw data. Plugged in through the ordinary
//! `BackendFactory`, the wire path shares admission control, deadlines,
//! coalescing, and the [`coordinator::CostModelPool`] with the
//! in-process path by construction.
//!
//! Failure semantics mirror the in-process fault isolation: a worker
//! connection dying mid-batch surfaces as [`Error::Disconnected`] and
//! fails only that batch; the worker re-registers (each registration
//! bumps a **version** counter) and later queries proceed — workers keep
//! their backends across reconnects, so datasets survive a coordinator
//! hiccup without re-upload. Worker-side cost-model statistics ship on a
//! pull/reset protocol stamped with the registration version; the
//! coordinator merges sums only while the version is current, so a
//! restarted worker cannot smuggle stale timings into the pool. Probe
//! passes are timed on *both* clocks deliberately: the worker observes
//! compute-only wall time, the coordinator observes end-to-end wall time
//! including RTT — bracketing measurements for the same cost law, and
//! the pool's identifiability guards arbitrate.
//!
//! ## Static analysis and concurrency invariants
//!
//! The control plane's correctness rests on conventions, and [`analysis`]
//! makes them machine-checked: `cp-select lint` (a blocking CI leg;
//! `--format json` emits a stable versioned schema that CI turns into
//! inline annotations) runs a dependency-free pass over `src/` and
//! `tests/`. Rules share a structural layer — [`analysis::callgraph`]:
//! function spans, per-function call sets, a name-keyed cross-file call
//! graph with reachability and a reusable fact-set fixpoint — and each
//! is grounded in an existing repo idiom:
//!
//! - **clock_discipline** — `Instant::now`/`SystemTime::now` only in the
//!   wall-clock files (`testkit/clock.rs`, `util/timer.rs`, `main.rs`,
//!   benches, harness); `thread::sleep` only in benches. Everything else
//!   reads time from [`testkit::Clock`], so the batching window, SLA
//!   clamp, and latency accounting are deterministic under the virtual
//!   clock.
//! - **poison_discipline** — every `.lock()` recovers the guard with
//!   `unwrap_or_else(|e| e.into_inner())`; `.unwrap()`/`.expect()`/`?`
//!   on a lock result is an error (one poisoned lock must not cascade).
//! - **float_order_discipline** — in the numeric core (`src/select/`,
//!   `src/stats/`), float ordering goes through `f64::total_cmp` or a
//!   `util::fkey` key: `.partial_cmp(` and raw relational operators in
//!   `sort_by`-family comparator closures are findings. Raw comparisons
//!   outside comparators (convergence checks, NaN-propagating guards)
//!   stay legal — IEEE semantics are load-bearing there.
//! - **error_discipline** — no `.unwrap()`/`.expect()`/`panic!`/
//!   `unreachable!` on the worker-path directories (`coordinator/`,
//!   `runtime/`, `select/`; test modules excluded): fallible paths
//!   return [`Error`] instead of riding the fault-isolation machinery.
//! - **panic_boundary** — `DatasetBackend` calls in
//!   `coordinator/dispatch.rs` and `cluster/worker.rs` stay inside
//!   `catch_unwind` fault isolation.
//! - **metrics_triple_entry** — every `Metrics` counter also has a
//!   `Snapshot` field, a `snapshot()` copy, and a `Display` arm.
//! - **atomic_ordering** — every `Metrics` counter access uses
//!   `Ordering::Relaxed`; the counters are statistical, and nothing may
//!   synchronize through them.
//! - **lock_order** — nested `.lock()` scopes form a cross-file graph
//!   over the named lock fields (helper-routed acquisitions expanded
//!   through the call-graph fixpoint); cycles fail the build. The
//!   runtime half is [`util::sync::OrderedMutex`]: rank-annotated
//!   mutexes that panic on out-of-order acquisition (thread-local
//!   held-ranks stack), with the documented rank order admission (10) <
//!   tenant_depth (20) < cost-model pool (30) < fault script (40) <
//!   virtual clock (50).
//! - **cancellation_discipline** — every pass loop reachable from
//!   `order_statistic`/`solve_group` polls the cooperative cancel hook,
//!   so deadline aborts land at pass boundaries; single-pass download
//!   methods are exempt via a registry
//!   ([`analysis::rules::CANCEL_EXEMPT`]) that is itself checked for
//!   staleness.
//!
//! A finding is suppressed by a plain `//` comment on the same line or
//! the one above: `lint: allow(<rule>) — <justification>` (the
//! justification is mandatory, and malformed pragmas are themselves
//! findings). Doc comments never act as pragmas. Suppressed findings
//! stay on the report — tagged in the JSON output and pinned by an
//! exact-inventory test — so every pragma in the tree is a reviewed,
//! deliberate act.
//!
//! ## Quick start
//!
//! ```no_run
//! use cp_select::select::{self, Method};
//! use cp_select::stats::{Distribution, Rng};
//!
//! let mut rng = Rng::seeded(42);
//! let data = Distribution::Normal.sample_vec(&mut rng, 1 << 20);
//! let mut ev = select::HostEvaluator::new(&data);
//! let res = select::median(&mut ev, Method::CuttingPlane).unwrap();
//! println!("median = {} in {} probes", res.value, res.probes);
//! ```

pub mod analysis;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod device;
pub mod error;
pub mod harness;
pub mod knn;
pub mod regression;
pub mod runtime;
pub mod select;
pub mod stats;
pub mod testkit;
pub mod util;
pub mod xla;

pub use error::{Error, Result};
