//! Offline stub of the `xla` PJRT binding surface used by [`crate::runtime`].
//!
//! The build environment has no network and no PJRT shared library, so the
//! crate compiles against this API-compatible stub instead of the real
//! `xla` crate. Every entry point that would touch a device returns
//! [`Error`] with a clear message; the runtime layer surfaces it as
//! `Error::Xla`, and every caller (tests, benches, CLI) already degrades to
//! the host oracle when the device runtime is unavailable.
//!
//! To build against a real PJRT plugin, replace this module with the actual
//! binding crate: the method signatures below mirror `xla` 0.5.1
//! (`PjRtClient::cpu`, `compile`, `execute_b`, `Literal::to_vec`, ...), so
//! no call sites change.

use std::fmt;

/// Error type mirroring `xla::Error`; convertible into `crate::Error::Xla`.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error(
        "PJRT runtime unavailable: built against the offline xla stub \
         (src/xla.rs); use the host backend"
            .to_string(),
    )
}

type Result<T> = std::result::Result<T, Error>;

/// Element types accepted by [`PjRtClient::buffer_from_host_buffer`].
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// Device-resident buffer handle (stub: never instantiated).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Host-side literal (stub: never instantiated).
#[derive(Debug)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }
}

/// Parsed HLO module (stub).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// XLA computation wrapper (stub).
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Compiled executable handle (stub: never instantiated).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// The real binding starts an in-process CPU PJRT client; the stub
    /// fails fast so callers fall back to the host oracle.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_fast_with_clear_message() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("PJRT runtime unavailable"), "{e}");
        let e = HloModuleProto::from_text_file("x.hlo.txt").unwrap_err();
        assert!(e.to_string().contains("xla stub"));
    }
}
