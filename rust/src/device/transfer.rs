//! Interconnect cost model, calibrated to the paper's measured numbers.
//!
//! §V.B: "Transfer of a 32M array of floats/doubles from GPU to CPU on our
//! system takes over 230/455 ms, while transfer of a 500K array takes only
//! 4/6.1 ms." That is ≈ 0.55–0.59 GB/s effective PCIe bandwidth with ~1 ms
//! latency. On our CPU substrate a "device→host copy" is a memcpy, so the
//! harness *additionally* reports modeled PCIe time for the baseline rows,
//! clearly labeled (EXPERIMENTS.md documents both).

use std::time::Duration;

/// Linear latency + bandwidth cost model.
#[derive(Debug, Clone, Copy)]
pub struct TransferModel {
    /// One-way latency per transfer.
    pub latency: Duration,
    /// Effective bandwidth, bytes per second.
    pub bytes_per_sec: f64,
}

impl TransferModel {
    /// Calibrated to the paper's Tesla C2050 + PCIe 2.0 host (see module
    /// docs): 0.56 GB/s effective, 1 ms setup.
    pub fn paper_pcie() -> Self {
        TransferModel {
            latency: Duration::from_micros(1000),
            bytes_per_sec: 0.56e9,
        }
    }

    /// A modern NVLink-class interconnect (for the ablation).
    pub fn nvlink() -> Self {
        TransferModel {
            latency: Duration::from_micros(10),
            bytes_per_sec: 300e9,
        }
    }

    /// No modeled cost (measure the substrate as-is).
    pub fn free() -> Self {
        TransferModel { latency: Duration::ZERO, bytes_per_sec: f64::INFINITY }
    }

    /// Modeled duration for moving `n` elements of `bytes_per_elem` bytes.
    pub fn cost(&self, n: usize, bytes_per_elem: usize) -> Duration {
        let bytes = (n * bytes_per_elem) as f64;
        let secs = if self.bytes_per_sec.is_finite() {
            bytes / self.bytes_per_sec
        } else {
            0.0
        };
        self.latency + Duration::from_secs_f64(secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_numbers_reproduce() {
        let m = TransferModel::paper_pcie();
        // 32M floats ≈ 230 ms (paper's measured value)
        let t = m.cost(32 << 20, 4).as_secs_f64() * 1e3;
        assert!((200.0..280.0).contains(&t), "32M f32: {t} ms");
        // 32M doubles ≈ 455 ms
        let t = m.cost(32 << 20, 8).as_secs_f64() * 1e3;
        assert!((420.0..520.0).contains(&t), "32M f64: {t} ms");
        // 500K doubles ≈ 6.1 ms
        let t = m.cost(500_000, 8).as_secs_f64() * 1e3;
        assert!((4.0..10.0).contains(&t), "500K f64: {t} ms");
    }

    #[test]
    fn free_model_is_zero() {
        let m = TransferModel::free();
        assert_eq!(m.cost(1 << 25, 8), Duration::ZERO);
    }

    #[test]
    fn latency_dominates_small_transfers() {
        let m = TransferModel::paper_pcie();
        let small = m.cost(8, 8);
        assert!(small >= Duration::from_micros(1000));
        assert!(small < Duration::from_micros(1100));
    }
}
