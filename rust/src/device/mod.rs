//! Simulated multi-device substrate (DESIGN.md S5).
//!
//! The paper's §V.D argument: sorting across GPUs moves bulk data between
//! devices, while the minimization approach runs *independent reductions
//! per device* and combines a handful of scalars on the host. We model a
//! device group as a set of shards, each behind its own [`Evaluator`];
//! [`ShardedEvaluator`] performs the scalar combine exactly as the paper
//! describes (partial sums added on the CPU).
//!
//! An optional [`TransferModel`] charges simulated interconnect time for
//! data that *would* cross PCIe on the paper's testbed (the real CPU
//! substrate memcpy is nearly free, which would hide the transfer-cost
//! structure of Tables I–II; the harness reports both).

pub mod transfer;

pub use transfer::TransferModel;

use crate::select::objective::{
    DType, Evaluator, InitStats, IntervalCounts, Neighbors, ProbeStats,
};
use crate::Result;

/// Evenly shard a data vector for `devices` simulated devices.
///
/// More devices than elements would manufacture empty shards, whose
/// `InitStats` are poison (±inf min/max merge into every seed bracket) and
/// which a real `DeviceEvaluator::upload` rejects outright — so the count
/// is clamped: the result has `min(devices, n)` non-empty shards (and one
/// empty shard only for empty input, which evaluator constructors reject).
pub fn shard_data(data: &[f64], devices: usize) -> Vec<&[f64]> {
    assert!(devices >= 1);
    let n = data.len();
    let devices = devices.min(n).max(1);
    let base = n / devices;
    let extra = n % devices;
    let mut out = Vec::with_capacity(devices);
    let mut start = 0;
    for i in 0..devices {
        let len = base + usize::from(i < extra);
        out.push(&data[start..start + len]);
        start += len;
    }
    out
}

/// Combines per-shard evaluators into one logical device group.
///
/// Every probe fans out to all shards and merges the sufficient statistics
/// — O(shards) scalars of "interconnect" traffic per reduction, matching
/// the paper's multi-GPU communication pattern. Batched probes
/// (`probe_many`) forward the whole ladder in one round-trip per shard, so
/// a p-probe pass costs one combine round instead of p.
pub struct ShardedEvaluator<E: Evaluator> {
    shards: Vec<E>,
    probes: u64,
}

impl<E: Evaluator> ShardedEvaluator<E> {
    pub fn new(shards: Vec<E>) -> Result<Self> {
        if shards.is_empty() {
            return Err(crate::invalid_arg!("need at least one shard"));
        }
        if shards.iter().any(|s| s.n() == 0) {
            // An empty shard's InitStats (±inf min/max) would poison every
            // merge; shard_data never produces one for non-empty input.
            return Err(crate::invalid_arg!("empty shard (more devices than elements?)"));
        }
        let dt = shards[0].dtype();
        if shards.iter().any(|s| s.dtype() != dt) {
            return Err(crate::invalid_arg!("shards must share a dtype"));
        }
        Ok(ShardedEvaluator { shards, probes: 0 })
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Total per-shard device reductions (probes() reports logical group
    /// reductions; this exposes the fan-out for tests).
    pub fn shard_probes(&self) -> u64 {
        self.shards.iter().map(|s| s.probes()).sum()
    }
}

impl<E: Evaluator> Evaluator for ShardedEvaluator<E> {
    fn n(&self) -> usize {
        self.shards.iter().map(|s| s.n()).sum()
    }

    fn dtype(&self) -> DType {
        self.shards[0].dtype()
    }

    fn init_stats(&mut self) -> Result<InitStats> {
        self.probes += 1;
        let mut acc: Option<InitStats> = None;
        for s in &mut self.shards {
            let v = s.init_stats()?;
            acc = Some(match acc {
                None => v,
                Some(a) => a.merge(&v),
            });
        }
        Ok(acc.unwrap())
    }

    fn probe(&mut self, y: f64) -> Result<ProbeStats> {
        self.probes += 1;
        let mut acc = ProbeStats { s_lo: 0.0, s_hi: 0.0, c_lt: 0, c_eq: 0, c_gt: 0 };
        for s in &mut self.shards {
            acc = acc.merge(&s.probe(y)?);
        }
        Ok(acc)
    }

    fn probe_many(&mut self, ys: &[f64]) -> Result<Vec<ProbeStats>> {
        if ys.is_empty() {
            return Ok(Vec::new());
        }
        // The whole ladder travels in ONE round-trip per shard: the group
        // pays O(shards · |ys|) scalars of combine traffic per *pass*
        // instead of per probe, and one logical fused reduction overall.
        self.probes += 1;
        let zero = ProbeStats { s_lo: 0.0, s_hi: 0.0, c_lt: 0, c_eq: 0, c_gt: 0 };
        let mut acc = vec![zero; ys.len()];
        for s in &mut self.shards {
            let part = s.probe_many(ys)?;
            for (a, b) in acc.iter_mut().zip(&part) {
                *a = a.merge(b);
            }
        }
        Ok(acc)
    }

    fn neighbors(&mut self, y: f64) -> Result<Neighbors> {
        self.probes += 1;
        let mut acc = Neighbors { lower: f64::NEG_INFINITY, upper: f64::INFINITY, c_le: 0 };
        for s in &mut self.shards {
            acc = acc.merge(&s.neighbors(y)?);
        }
        Ok(acc)
    }

    fn interval(&mut self, lo: f64, hi: f64) -> Result<IntervalCounts> {
        self.probes += 1;
        let mut acc = IntervalCounts { c_le: 0, c_in: 0, c_ge: 0 };
        for s in &mut self.shards {
            acc = acc.merge(&s.interval(lo, hi)?);
        }
        Ok(acc)
    }

    fn compact(&mut self, lo: f64, hi: f64) -> Result<Vec<f64>> {
        // Each shard compacts locally; only the survivors (1–5% of n after
        // the CP phase) cross the interconnect — the paper's key point.
        let mut out = Vec::new();
        for s in &mut self.shards {
            out.extend(s.compact(lo, hi)?);
        }
        Ok(out)
    }

    fn download(&mut self) -> Result<Vec<f64>> {
        let mut out = Vec::with_capacity(self.n());
        for s in &mut self.shards {
            out.extend(s.download()?);
        }
        Ok(out)
    }

    fn probes(&self) -> u64 {
        self.probes
    }

    fn ladder_width_hint(&self) -> Option<usize> {
        // Every shard sees the whole ladder, so the narrowest shard
        // constrains the group (host shards report None = unconstrained).
        self.shards.iter().filter_map(|s| s.ladder_width_hint()).min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::select::objective::HostEvaluator;
    use crate::select::{self, Method};
    use crate::stats::{sorted_median, Distribution, Rng};

    fn sharded(data: &[f64], k: usize) -> ShardedEvaluator<HostEvaluator> {
        let shards = shard_data(data, k)
            .into_iter()
            .map(HostEvaluator::new)
            .collect();
        ShardedEvaluator::new(shards).unwrap()
    }

    #[test]
    fn shard_split_covers_everything() {
        let data: Vec<f64> = (0..103).map(|i| i as f64).collect();
        for devices in [1, 2, 3, 7, 8] {
            let shards = shard_data(&data, devices);
            assert_eq!(shards.len(), devices);
            let total: usize = shards.iter().map(|s| s.len()).sum();
            assert_eq!(total, 103);
            let lens: Vec<usize> = shards.iter().map(|s| s.len()).collect();
            let (mn, mx) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
            assert!(mx - mn <= 1, "{lens:?}");
        }
    }

    #[test]
    fn sharded_probe_equals_whole() {
        let mut rng = Rng::seeded(111);
        let data = Distribution::Mixture2.sample_vec(&mut rng, 1003);
        let mut whole = HostEvaluator::new(&data);
        for devices in [1, 2, 4, 8] {
            let mut sh = sharded(&data, devices);
            for y in [-5.0, 0.7, 50.0, 101.0] {
                let a = sh.probe(y).unwrap();
                let b = whole.probe(y).unwrap();
                // counts are exact; sums may differ by accumulation order
                assert_eq!((a.c_lt, a.c_eq, a.c_gt), (b.c_lt, b.c_eq, b.c_gt));
                assert!((a.s_lo - b.s_lo).abs() <= 1e-9 * b.s_lo.abs().max(1.0));
                assert!((a.s_hi - b.s_hi).abs() <= 1e-9 * b.s_hi.abs().max(1.0));
            }
            let (ia, ib) = (sh.init_stats().unwrap(), whole.init_stats().unwrap());
            assert_eq!((ia.min, ia.max), (ib.min, ib.max));
            assert!((ia.sum - ib.sum).abs() <= 1e-9 * ib.sum.abs().max(1.0));
            assert_eq!(sh.neighbors(0.5).unwrap(), whole.neighbors(0.5).unwrap());
            assert_eq!(sh.interval(0.0, 1.0).unwrap(), whole.interval(0.0, 1.0).unwrap());
        }
    }

    #[test]
    fn median_identical_across_shard_counts() {
        let mut rng = Rng::seeded(112);
        let data = Distribution::HalfNormal.sample_vec(&mut rng, 4096);
        let want = sorted_median(&data);
        for devices in [1, 2, 3, 5, 8] {
            let mut sh = sharded(&data, devices);
            let got = select::median(&mut sh, Method::CuttingPlane).unwrap();
            assert_eq!(got.value, want, "devices={devices}");
            let mut sh = sharded(&data, devices);
            let got = select::median(&mut sh, Method::Hybrid).unwrap();
            assert_eq!(got.value, want, "hybrid devices={devices}");
        }
    }

    #[test]
    fn group_probe_counter_is_logical() {
        let mut rng = Rng::seeded(113);
        let data = Distribution::Normal.sample_vec(&mut rng, 512);
        let mut sh = sharded(&data, 4);
        sh.probe(0.0).unwrap();
        sh.probe(1.0).unwrap();
        assert_eq!(sh.probes(), 2);
        assert_eq!(sh.shard_probes(), 8); // 2 logical × 4 shards
    }

    #[test]
    fn sharded_probe_many_equals_whole_and_counts_one_round() {
        let mut rng = Rng::seeded(114);
        let data = Distribution::Mixture4.sample_vec(&mut rng, 1031);
        let ys = [-2.0, 0.3, 0.3, 1.7, 95.0, 104.0];
        let mut whole = HostEvaluator::new(&data);
        let want = whole.probe_many(&ys).unwrap();
        for devices in [1, 2, 3, 8] {
            let mut sh = sharded(&data, devices);
            let got = sh.probe_many(&ys).unwrap();
            assert_eq!(got.len(), want.len());
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                assert_eq!(
                    (a.c_lt, a.c_eq, a.c_gt),
                    (b.c_lt, b.c_eq, b.c_gt),
                    "devices={devices} probe {i}"
                );
                assert!((a.s_lo - b.s_lo).abs() <= 1e-9 * b.s_lo.abs().max(1.0));
                assert!((a.s_hi - b.s_hi).abs() <= 1e-9 * b.s_hi.abs().max(1.0));
            }
            // one logical fused round, one batch round-trip per shard
            assert_eq!(sh.probes(), 1, "devices={devices}");
            assert_eq!(sh.shard_probes(), devices as u64);
        }
    }

    #[test]
    fn multisection_runs_sharded() {
        let mut rng = Rng::seeded(115);
        let data = Distribution::Beta25.sample_vec(&mut rng, 4099);
        let want = sorted_median(&data);
        for devices in [2, 5] {
            let mut sh = sharded(&data, devices);
            let got = select::median(&mut sh, Method::Multisection).unwrap();
            assert_eq!(got.value, want, "devices={devices}");
        }
    }

    #[test]
    fn compact_gathers_across_shards() {
        let data: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let mut sh = sharded(&data, 3);
        let z = sh.compact(10.0, 20.0).unwrap();
        let mut z = z;
        z.sort_by(|a, b| a.total_cmp(b));
        let want: Vec<f64> = (11..20).map(|i| i as f64).collect();
        assert_eq!(z, want);
    }

    #[test]
    fn rejects_empty_or_mixed() {
        assert!(ShardedEvaluator::<HostEvaluator>::new(vec![]).is_err());
        let a = HostEvaluator::new(&[1.0]);
        let b = HostEvaluator::new_f32(&[2.0]);
        assert!(ShardedEvaluator::new(vec![a, b]).is_err());
    }

    #[test]
    fn more_devices_than_elements_clamps_to_nonempty_shards() {
        // regression: devices > n used to produce empty shards whose
        // InitStats (±inf) poisoned min/max merges
        let data = [3.0, 1.0, 2.0];
        let shards = shard_data(&data, 8);
        assert_eq!(shards.len(), 3);
        assert!(shards.iter().all(|s| !s.is_empty()));
        let mut sh = sharded(&data, 8);
        assert_eq!(sh.shard_count(), 3);
        let init = sh.init_stats().unwrap();
        assert_eq!((init.min, init.max), (1.0, 3.0));
        assert!(init.min.is_finite() && init.max.is_finite());
        let got = select::median(&mut sh, Method::Multisection).unwrap();
        assert_eq!(got.value, 2.0);
        // single element, many devices
        let one = [7.0];
        assert_eq!(shard_data(&one, 5).len(), 1);
        let mut sh = sharded(&one, 5);
        assert_eq!(sh.init_stats().unwrap().min, 7.0);
    }

    #[test]
    fn rejects_empty_shard_directly() {
        let a = HostEvaluator::new(&[1.0]);
        let b = HostEvaluator::new(&[]);
        let err = ShardedEvaluator::new(vec![a, b]).unwrap_err();
        assert!(err.to_string().contains("empty shard"), "{err}");
    }
}
