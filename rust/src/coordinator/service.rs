//! The selection service: a router + worker pool in the style of a serving
//! frontend (vllm-project/router is the reference shape).
//!
//! - Datasets are uploaded once and pinned to a worker (consistent
//!   `id % workers` routing) — the device keeps the array resident, as in
//!   the paper's setting where x is *produced* on the GPU.
//! - Queries for a dataset are dispatched to its worker over a bounded
//!   channel (backpressure) and answered through per-request reply
//!   channels.
//! - Workers batch over a **time window** ([`CoordinatorOptions`]): a
//!   probe-based query at the head of a batch opens a window during which
//!   the worker keeps collecting up to `batch_cap` requests, so concurrent
//!   traffic that arrives within one window is planned together — not just
//!   whatever happened to be sitting in the queue. Uploads/drops start
//!   drain-only batches (no latency floor for non-coalescible traffic),
//!   and the library default window is zero — serving deployments opt in
//!   through `start_with` or the config. With
//!   `CoordinatorOptions::adaptive` set, the window is driven by the
//!   SLA-bounded [`WindowController`] instead of the fixed knob: it widens
//!   under observed concurrency, shrinks to zero when idle, and never
//!   exceeds the latency budget. Every window wait and time read goes
//!   through a [`Clock`] ([`SelectionService::start_full`]), so tests
//!   drive this logic deterministically under virtual time.
//! - Each collected window is turned into an execution plan by the batch
//!   planner (`plan_batch`): probe-based `Query` singles **and**
//!   `QueryMany` specs against the same dataset merge into one shared
//!   `probe_many` ladder run — a probe's sufficient statistics are
//!   rank-independent, so one fused ladder pass serves every collected `k`
//!   simultaneously — while uploads/drops/download-method queries keep
//!   per-dataset FIFO order.
//! - Shared runs ride the measured pass-cost model of a cross-worker
//!   [`CostModelPool`]: the ladder width starts at the
//!   `BENCH_select.json`-seeded optimum (or the device's native
//!   `fused_ladder` bucket), refines online from every worker's pass
//!   timings merged as sufficient statistics, and persists to a sidecar so
//!   restarts start measured rather than seeded.
//! - PJRT handles are thread-confined; each worker builds its own backend
//!   via the [`BackendFactory`].

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::backend::BackendFactory;
use super::controller::AdaptiveWindow;
use super::dispatch::worker_loop;
use super::metrics::Metrics;
use crate::select::gpu_model::CostModelPool;
use crate::select::objective::DType;
use crate::select::Method;
use crate::testkit::Clock;
use crate::util::sync::{OrderedMutex, RANK_ADMISSION};
use crate::{Error, Result};

/// What to select.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum KSpec {
    /// The paper's median, `x_([(n+1)/2])`.
    Median,
    /// Explicit 1-indexed rank.
    Rank(usize),
    /// Quantile in [0, 1] (rank = ceil(q·n) clamped to [1, n]).
    Quantile(f64),
}

impl KSpec {
    pub fn rank_for(&self, n: usize) -> Result<usize> {
        match *self {
            KSpec::Median => Ok(crate::util::median_rank(n)),
            KSpec::Rank(k) => {
                if k == 0 || k > n {
                    Err(crate::invalid_arg!("rank {k} out of range for n={n}"))
                } else {
                    Ok(k)
                }
            }
            KSpec::Quantile(q) => {
                if !(0.0..=1.0).contains(&q) {
                    return Err(crate::invalid_arg!("quantile {q} outside [0,1]"));
                }
                Ok(((q * n as f64).ceil() as usize).clamp(1, n))
            }
        }
    }
}

/// Answer to a query.
#[derive(Debug, Clone, PartialEq)]
pub struct QueryResult {
    pub value: f64,
    pub k: usize,
    /// The method that actually answered. Queries coalesced into shared
    /// same-dataset ladder rounds (explicit `query_many`, or probe-based
    /// singles collected in one batching window) report
    /// [`Method::Multisection`] regardless of the requested method — the
    /// value is the same exact order statistic either way, but
    /// `probes`/`iterations` describe the shared rounds (probes is this
    /// query's amortized share; the group's shares sum to the real total).
    pub method: Method,
    pub probes: u64,
    pub iterations: usize,
    pub wall: Duration,
    /// Service-clock timestamp (µs) at which the run's replies were
    /// issued. On a virtual clock this makes per-query completion times
    /// exact, which is what the overload harness computes per-tenant
    /// latency distributions from.
    pub completed_us: u64,
}

pub type DatasetId = u64;

/// Ingest batching knobs for [`SelectionService`] workers.
#[derive(Debug, Clone, Copy)]
pub struct CoordinatorOptions {
    /// How long a worker holds the first request of a batch while more
    /// traffic accumulates (coalescing catchment ↔ added latency floor).
    /// The window only *opens* when the batch starts with a coalescible
    /// probe-based query — uploads, drops and download-method queries
    /// start drain-only batches, since holding them buys no sharing.
    /// `Duration::ZERO` (the library default — no silent latency floor
    /// for existing callers) degrades to drain-what's-queued
    /// micro-batching; serving deployments opt in via
    /// [`SelectionService::start_with`] or the config's `batch_window_us`
    /// (whose deployment default is 200 µs).
    pub batch_window: Duration,
    /// Hard cap on requests collected into one planned batch; reaching it
    /// closes the window immediately.
    pub batch_cap: usize,
    /// `Some` puts the window under the load-adaptive SLA-bounded
    /// controller ([`super::WindowController`]): it widens under observed
    /// concurrency, shrinks to zero when idle, and never exceeds
    /// `latency_sla − p99(run)`. `None` keeps `batch_window` as the fixed
    /// manual override (and the zero library default).
    pub adaptive: Option<AdaptiveWindow>,
    /// What happens when a worker's bounded ingest queue is full:
    /// [`ShedPolicy::Block`] (library default — legacy backpressure)
    /// blocks the caller; [`ShedPolicy::Shed`] fails fast with
    /// [`Error::Overloaded`]. Queries only — uploads and drops are rare
    /// control-plane traffic and always use blocking backpressure.
    pub shed_policy: ShedPolicy,
    /// `Some` enables the per-tenant token-bucket admission gate: a
    /// tenant exceeding its refill rate (beyond its burst allowance) has
    /// queries shed with [`Error::Overloaded`] before they reach any
    /// queue. `None` (default) admits everything.
    pub tenant_quota: Option<TenantQuota>,
    /// Override of the per-worker bounded queue depth (`Some` wins over
    /// the `queue_depth` start argument — lets config/CLI carry the cap
    /// inside one options struct).
    pub queue_cap: Option<usize>,
}

impl Default for CoordinatorOptions {
    fn default() -> Self {
        CoordinatorOptions {
            batch_window: Duration::ZERO,
            batch_cap: 64,
            adaptive: None,
            shed_policy: ShedPolicy::Block,
            tenant_quota: None,
            queue_cap: None,
        }
    }
}

/// Full-queue behavior for query dispatch (see
/// [`CoordinatorOptions::shed_policy`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Block the caller until the worker drains (legacy backpressure).
    Block,
    /// Fail fast with [`Error::Overloaded`] carrying a retry hint.
    Shed,
}

impl ShedPolicy {
    /// Parse a config/CLI spelling.
    pub fn parse(s: &str) -> Result<ShedPolicy> {
        match s {
            "block" => Ok(ShedPolicy::Block),
            "shed" => Ok(ShedPolicy::Shed),
            other => Err(Error::Parse(format!(
                "shed_policy must be \"block\" or \"shed\", got {other:?}"
            ))),
        }
    }
}

/// Per-tenant token-bucket admission quota: buckets hold at most `burst`
/// tokens, refill at `rate_per_sec`, and each admitted query spends one.
/// Refill runs on the service clock, so virtual-clock tests control
/// admission exactly.
#[derive(Debug, Clone, Copy)]
pub struct TenantQuota {
    pub rate_per_sec: f64,
    pub burst: f64,
}

/// Per-query options: tenant attribution (admission + fair-share
/// planning) and an optional deadline relative to dispatch.
#[derive(Debug, Clone, Copy, Default)]
pub struct QueryOptions {
    /// `None` runs the service default method.
    pub method: Option<Method>,
    /// Tenant this query is attributed to (0 = default tenant).
    pub tenant: u32,
    /// Give-up time relative to dispatch. Once passed, the coordinator
    /// answers [`Error::DeadlineExceeded`] instead of (continuing to)
    /// spend fused reductions; in-flight shared runs stop at the next
    /// pass boundary.
    pub deadline: Option<Duration>,
}

/// One tenant's token bucket (see [`TenantQuota`]).
struct TokenBucket {
    tokens: f64,
    last_us: u64,
}

impl TokenBucket {
    /// Try to spend one token at `now_us`; on refusal returns the
    /// retry-after hint in µs.
    fn admit(&mut self, quota: &TenantQuota, now_us: u64) -> std::result::Result<(), u64> {
        let dt = now_us.saturating_sub(self.last_us) as f64 / 1e6;
        self.tokens = (self.tokens + dt * quota.rate_per_sec).min(quota.burst);
        self.last_us = now_us;
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            Ok(())
        } else {
            let deficit = 1.0 - self.tokens;
            Err((deficit / quota.rate_per_sec.max(1e-9) * 1e6).ceil() as u64)
        }
    }
}

pub(crate) enum Request {
    Upload {
        id: DatasetId,
        data: Arc<Vec<f64>>,
        dtype: DType,
        reply: SyncSender<Result<()>>,
    },
    Query {
        id: DatasetId,
        k: KSpec,
        method: Method,
        tenant: u32,
        /// Absolute give-up time on the service clock (µs), if any.
        deadline_us: Option<u64>,
        reply: SyncSender<Result<QueryResult>>,
    },
    /// A client-side batch: all specs resolve against one dataset in
    /// shared fused ladder rounds (all-or-nothing reply; the requested
    /// method is validated client-side and the rounds always run on the
    /// shared multisection engine, so it isn't carried here).
    QueryMany {
        id: DatasetId,
        specs: Vec<KSpec>,
        tenant: u32,
        deadline_us: Option<u64>,
        reply: SyncSender<Result<Vec<QueryResult>>>,
    },
    Drop {
        id: DatasetId,
        /// `Some` when the client wants to block until the drop has been
        /// processed ([`SelectionService::drop_dataset_sync`]).
        reply: Option<SyncSender<Result<()>>>,
    },
    Shutdown,
}

impl Request {
    /// The dataset this request could share a fused ladder on, if any.
    /// (Probe-based queries can share; uploads, drops and download-method
    /// queries cannot — holding them open buys nothing.)
    pub(crate) fn coalescible_dataset(&self) -> Option<DatasetId> {
        match self {
            Request::Query { id, method, .. } if !method.needs_download() => Some(*id),
            Request::QueryMany { id, .. } => Some(*id),
            _ => None,
        }
    }

    pub(crate) fn coalescible(&self) -> bool {
        self.coalescible_dataset().is_some()
    }
}

/// Handle to a running selection service.
pub struct SelectionService {
    worker_txs: Vec<SyncSender<Request>>,
    workers: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
    pub metrics: Arc<Metrics>,
    default_method: Method,
    clock: Clock,
    pool: Arc<CostModelPool>,
    /// Shed/admission knobs (window knobs live in the workers).
    opts: CoordinatorOptions,
    /// Per-tenant token buckets (lazily created full). Rank
    /// [`RANK_ADMISSION`] — the outermost coordinator lock.
    admission: OrderedMutex<HashMap<u32, TokenBucket>>,
}

impl SelectionService {
    /// Start `workers` threads with the default batching window
    /// ([`CoordinatorOptions::default`]); see
    /// [`SelectionService::start_with`].
    pub fn start(
        workers: usize,
        queue_depth: usize,
        default_method: Method,
        factory: BackendFactory,
    ) -> Result<SelectionService> {
        Self::start_with(
            workers,
            queue_depth,
            default_method,
            factory,
            CoordinatorOptions::default(),
        )
    }

    /// Start `workers` threads, each owning a backend from `factory` and
    /// batching its ingest queue over `opts.batch_window` (or the adaptive
    /// controller when `opts.adaptive` is set), on the real clock with an
    /// in-memory cost-model pool; see [`SelectionService::start_full`].
    pub fn start_with(
        workers: usize,
        queue_depth: usize,
        default_method: Method,
        factory: BackendFactory,
        opts: CoordinatorOptions,
    ) -> Result<SelectionService> {
        Self::start_full(
            workers,
            queue_depth,
            default_method,
            factory,
            opts,
            Clock::real(),
            CostModelPool::seeded(),
        )
    }

    /// Fully-parameterized start: `clock` drives every window wait and
    /// time read (tests pass [`Clock::manual`] so window behavior is
    /// deterministic under virtual time), and `pool` is the shared
    /// cross-worker [`CostModelPool`] (sidecar-bound pools are persisted
    /// on shutdown, so a restarted service plans with measured
    /// coefficients).
    pub fn start_full(
        workers: usize,
        queue_depth: usize,
        default_method: Method,
        factory: BackendFactory,
        opts: CoordinatorOptions,
        clock: Clock,
        pool: Arc<CostModelPool>,
    ) -> Result<SelectionService> {
        if workers == 0 {
            return Err(crate::invalid_arg!("need at least one worker"));
        }
        if opts.batch_cap == 0 {
            return Err(crate::invalid_arg!("batch_cap must be at least 1"));
        }
        let queue_depth = opts.queue_cap.unwrap_or(queue_depth);
        if queue_depth == 0 {
            return Err(crate::invalid_arg!("queue depth must be at least 1"));
        }
        if let Some(q) = opts.tenant_quota {
            let rate_ok = q.rate_per_sec.is_finite() && q.rate_per_sec > 0.0;
            let burst_ok = q.burst.is_finite() && q.burst >= 1.0;
            if !rate_ok || !burst_ok {
                return Err(crate::invalid_arg!(
                    "tenant quota needs rate_per_sec > 0 and burst >= 1 \
                     (got rate={} burst={})",
                    q.rate_per_sec,
                    q.burst
                ));
            }
        }
        let metrics = Arc::new(Metrics::new());
        let mut worker_txs = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let (tx, rx) = sync_channel::<Request>(queue_depth);
            let factory = factory.clone();
            let metrics = metrics.clone();
            let clock = clock.clone();
            let pool = pool.clone();
            let handle = std::thread::Builder::new()
                .name(format!("cp-select-worker-{w}"))
                .spawn(move || worker_loop(w, rx, factory, metrics, opts, clock, pool))
                .map_err(|e| Error::Service(format!("spawn failed: {e}")))?;
            worker_txs.push(tx);
            handles.push(handle);
        }
        Ok(SelectionService {
            worker_txs,
            workers: handles,
            next_id: AtomicU64::new(1),
            metrics,
            default_method,
            clock,
            pool,
            opts,
            admission: OrderedMutex::new(RANK_ADMISSION, "service.admission", HashMap::new()),
        })
    }

    /// The shared cross-worker cost-model pool this service plans with.
    pub fn cost_pool(&self) -> &Arc<CostModelPool> {
        &self.pool
    }

    fn route(&self, id: DatasetId) -> &SyncSender<Request> {
        &self.worker_txs[(id as usize) % self.worker_txs.len()]
    }

    /// Route + send + waiter wakeup: a worker parked on a *virtual* window
    /// deadline only re-checks its queue when notified, so every enqueue
    /// funnels through here (no-op notify on the real clock).
    fn dispatch(&self, id: DatasetId, req: Request) -> Result<()> {
        self.route(id).send(req).map_err(|_| Error::Service("worker channel closed".into()))?;
        self.clock.notify();
        Ok(())
    }

    /// Admission-gated query dispatch: per-tenant token-bucket check,
    /// then a queue send honoring the shed policy, tracking the tenant's
    /// in-flight depth gauge across both outcomes.
    fn dispatch_query(&self, id: DatasetId, tenant: u32, req: Request) -> Result<()> {
        if let Some(quota) = self.opts.tenant_quota {
            let now = self.clock.now_us();
            let mut buckets = self.admission.lock();
            let bucket = buckets
                .entry(tenant)
                .or_insert_with(|| TokenBucket { tokens: quota.burst, last_us: now });
            if let Err(retry_after_us) = bucket.admit(&quota, now) {
                drop(buckets);
                self.metrics.shed.fetch_add(1, Ordering::Relaxed);
                return Err(Error::Overloaded { retry_after_us });
            }
        }
        // Enter BEFORE the send: the worker may recv and reply (exiting
        // the gauge) before this thread resumes, and the gauge must never
        // underflow; un-enter on any failed send.
        self.metrics.tenant_enter(tenant);
        let sent = match self.opts.shed_policy {
            ShedPolicy::Block => self
                .route(id)
                .send(req)
                .map_err(|_| Error::Service("worker channel closed".into())),
            ShedPolicy::Shed => match self.route(id).try_send(req) {
                Ok(()) => Ok(()),
                Err(TrySendError::Full(_)) => {
                    self.metrics.shed.fetch_add(1, Ordering::Relaxed);
                    // retry hint: roughly one run's p99 (floor 100µs
                    // before any run has been measured)
                    let retry_after_us = self.metrics.latency_quantile_us(0.99).max(100);
                    Err(Error::Overloaded { retry_after_us })
                }
                Err(TrySendError::Disconnected(_)) => {
                    Err(Error::Service("worker channel closed".into()))
                }
            },
        };
        if let Err(e) = sent {
            self.metrics.tenant_exit(tenant);
            return Err(e);
        }
        self.clock.notify();
        Ok(())
    }

    /// Absolute service-clock deadline for a relative per-query deadline.
    fn deadline_us(&self, deadline: Option<Duration>) -> Option<u64> {
        deadline.map(|d| self.clock.now_us().saturating_add(d.as_micros() as u64))
    }

    /// Upload a dataset; returns its id. Blocks until the device holds it.
    pub fn upload(&self, data: Vec<f64>, dtype: DType) -> Result<DatasetId> {
        let (id, rx) = self.upload_async(data, dtype)?;
        recv_reply(&rx)??;
        self.metrics.uploads.fetch_add(1, Ordering::Relaxed);
        Ok(id)
    }

    /// Enqueue an upload without waiting for the device: returns the new
    /// dataset id plus the ack channel. Lets pipelined clients (and the
    /// eviction tests) queue an upload behind in-flight work without a
    /// second thread. Uploads are control-plane traffic: they use blocking
    /// backpressure and bypass tenant admission.
    pub fn upload_async(
        &self,
        data: Vec<f64>,
        dtype: DType,
    ) -> Result<(DatasetId, Receiver<Result<()>>)> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let (reply, rx) = sync_channel(1);
        self.dispatch(id, Request::Upload { id, data: Arc::new(data), dtype, reply })?;
        Ok((id, rx))
    }

    /// Blocking query with the service default method.
    pub fn query(&self, id: DatasetId, k: KSpec) -> Result<QueryResult> {
        self.query_opts(id, k, QueryOptions::default())
    }

    /// Blocking query with an explicit method.
    pub fn query_with(&self, id: DatasetId, k: KSpec, method: Method) -> Result<QueryResult> {
        self.query_opts(id, k, QueryOptions { method: Some(method), ..QueryOptions::default() })
    }

    /// Blocking query with full per-query options (method, tenant,
    /// deadline). Sheds with [`Error::Overloaded`] before enqueueing when
    /// the tenant is over quota or the queue is full under
    /// [`ShedPolicy::Shed`].
    pub fn query_opts(&self, id: DatasetId, k: KSpec, opts: QueryOptions) -> Result<QueryResult> {
        recv_reply(&self.query_async_opts(id, k, opts)?)?
    }

    /// Solve many order statistics of one dataset in **shared** fused
    /// ladder rounds: one `probe_many` pass per iteration serves every
    /// spec, so N same-dataset queries cost ~one run instead of N.
    /// Results align positionally with `specs` and report
    /// [`Method::Multisection`] — the engine the shared rounds run on —
    /// whatever `method` was requested (it is validated to be probe-based;
    /// download methods have no passes to share). All-or-nothing: any
    /// invalid spec fails the whole call.
    pub fn query_many(
        &self,
        id: DatasetId,
        specs: Vec<KSpec>,
        method: Method,
    ) -> Result<Vec<QueryResult>> {
        self.query_many_opts(
            id,
            specs,
            QueryOptions { method: Some(method), ..QueryOptions::default() },
        )
    }

    /// [`SelectionService::query_many`] with per-query options. The whole
    /// batch shares one tenant attribution and one deadline.
    pub fn query_many_opts(
        &self,
        id: DatasetId,
        specs: Vec<KSpec>,
        opts: QueryOptions,
    ) -> Result<Vec<QueryResult>> {
        if specs.is_empty() {
            return Ok(Vec::new());
        }
        let method = opts.method.unwrap_or(self.default_method);
        if method.needs_download() {
            return Err(crate::invalid_arg!(
                "query_many requires a probe-based method, got {}",
                method.name()
            ));
        }
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let deadline_us = self.deadline_us(opts.deadline);
        let (reply, rx) = sync_channel(1);
        self.dispatch_query(
            id,
            opts.tenant,
            Request::QueryMany { id, specs, tenant: opts.tenant, deadline_us, reply },
        )?;
        recv_reply(&rx)?
    }

    /// Fire a query and return the reply channel (for concurrent clients).
    pub fn query_async(
        &self,
        id: DatasetId,
        k: KSpec,
        method: Method,
    ) -> Result<Receiver<Result<QueryResult>>> {
        self.query_async_opts(
            id,
            k,
            QueryOptions { method: Some(method), ..QueryOptions::default() },
        )
    }

    /// Fire a query with per-query options; returns the reply channel.
    /// Admission shedding reports through the returned `Result`, so a shed
    /// query never allocates a reply channel a caller could hang on.
    pub fn query_async_opts(
        &self,
        id: DatasetId,
        k: KSpec,
        opts: QueryOptions,
    ) -> Result<Receiver<Result<QueryResult>>> {
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let method = opts.method.unwrap_or(self.default_method);
        let deadline_us = self.deadline_us(opts.deadline);
        let (reply, rx) = sync_channel(1);
        self.dispatch_query(
            id,
            opts.tenant,
            Request::Query { id, k, method, tenant: opts.tenant, deadline_us, reply },
        )?;
        Ok(rx)
    }

    /// Drop a dataset (fire-and-forget).
    pub fn drop_dataset(&self, id: DatasetId) -> Result<()> {
        self.dispatch(id, Request::Drop { id, reply: None })
    }

    /// Drop a dataset and block until the worker has processed the drop
    /// (fire-and-forget gives an observer nothing to await). Errors when
    /// the dataset was not resident on its worker.
    pub fn drop_dataset_sync(&self, id: DatasetId) -> Result<()> {
        let (reply, rx) = sync_channel(1);
        self.dispatch(id, Request::Drop { id, reply: Some(reply) })?;
        recv_reply(&rx)?
    }

    /// Graceful shutdown: drain queues, join workers, persist the
    /// cost-model pool's sidecar (when it has one) so the next start plans
    /// with this run's measured coefficients.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        if self.workers.is_empty() {
            return;
        }
        for tx in &self.worker_txs {
            let _ = tx.send(Request::Shutdown);
            // wake a worker parked on a virtual window so it sees the
            // shutdown without any test having to advance time
            self.clock.notify();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        if let Err(e) = self.pool.persist() {
            eprintln!("cost-model sidecar persist failed: {e}");
        }
    }
}

impl Drop for SelectionService {
    fn drop(&mut self) {
        self.stop();
    }
}

fn recv_reply<T>(rx: &Receiver<T>) -> Result<T> {
    rx.recv().map_err(|_| Error::Service("worker dropped the reply channel".into()))
}

/// Batch-of-datasets convenience: a `HashMap` of names to ids.
pub struct NamedDatasets {
    pub ids: HashMap<String, DatasetId>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::HostBackend;
    use crate::stats::{sorted_median, sorted_order_statistic, Distribution, Rng};

    fn start_host(workers: usize) -> SelectionService {
        SelectionService::start(workers, 64, Method::Hybrid, HostBackend::factory()).unwrap()
    }

    #[test]
    fn upload_query_roundtrip() {
        let svc = start_host(1);
        let mut rng = Rng::seeded(171);
        let data = Distribution::Normal.sample_vec(&mut rng, 2001);
        let want = sorted_median(&data);
        let id = svc.upload(data, DType::F64).unwrap();
        let r = svc.query(id, KSpec::Median).unwrap();
        assert_eq!(r.value, want);
        assert_eq!(r.k, 1001);
        assert!(r.wall > Duration::ZERO);
        svc.shutdown();
    }

    #[test]
    fn rank_and_quantile_specs() {
        let svc = start_host(2);
        let data: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let id = svc.upload(data, DType::F64).unwrap();
        assert_eq!(svc.query(id, KSpec::Rank(1)).unwrap().value, 1.0);
        assert_eq!(svc.query(id, KSpec::Rank(100)).unwrap().value, 100.0);
        assert_eq!(svc.query(id, KSpec::Quantile(0.25)).unwrap().value, 25.0);
        assert_eq!(svc.query(id, KSpec::Quantile(1.0)).unwrap().value, 100.0);
        assert!(svc.query(id, KSpec::Rank(0)).is_err());
        assert!(svc.query(id, KSpec::Quantile(1.5)).is_err());
        svc.shutdown();
    }

    #[test]
    fn methods_agree_through_service() {
        let svc = start_host(2);
        let mut rng = Rng::seeded(172);
        let data = Distribution::Mixture1.sample_vec(&mut rng, 999);
        let want = sorted_order_statistic(&data, 250);
        let id = svc.upload(data, DType::F64).unwrap();
        for m in [Method::CuttingPlane, Method::Hybrid, Method::Bisection, Method::Quickselect] {
            let r = svc.query_with(id, KSpec::Rank(250), m).unwrap();
            assert_eq!(r.value, want, "{}", m.name());
        }
        svc.shutdown();
    }

    #[test]
    fn unknown_dataset_errors_and_counts() {
        let svc = start_host(1);
        assert!(svc.query(42, KSpec::Median).is_err());
        assert_eq!(svc.metrics.snapshot().errors, 1);
        svc.shutdown();
    }

    #[test]
    fn concurrent_clients_many_datasets() {
        let svc = Arc::new(start_host(4));
        let mut rng = Rng::seeded(173);
        let mut wants = Vec::new();
        let mut ids = Vec::new();
        for i in 0..12 {
            let d = Distribution::ALL[i % 9].sample_vec(&mut rng, 500 + i * 37);
            wants.push(sorted_median(&d));
            ids.push(svc.upload(d, DType::F64).unwrap());
        }
        let mut handles = Vec::new();
        for (chunk_start, chunk) in ids.chunks(3).enumerate() {
            let svc = svc.clone();
            let chunk: Vec<_> = chunk.to_vec();
            handles.push(std::thread::spawn(move || {
                chunk
                    .iter()
                    .map(|&id| (chunk_start, svc.query(id, KSpec::Median).unwrap().value))
                    .collect::<Vec<_>>()
            }));
        }
        let mut got = Vec::new();
        for h in handles {
            got.extend(h.join().unwrap());
        }
        assert_eq!(got.len(), 12);
        for (i, (_, v)) in got.iter().enumerate() {
            // order within chunks preserved: map back via position
            let idx = (i / 3) * 3 + (i % 3);
            assert_eq!(*v, wants[idx]);
        }
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.queries, 12);
        assert_eq!(snap.uploads, 12);
        if let Ok(s) = Arc::try_unwrap(svc) {
            s.shutdown();
        }
    }

    #[test]
    fn burst_queries_get_batched() {
        let svc = start_host(1);
        let data: Vec<f64> = (0..1000).map(|i| (i * 7919 % 997) as f64).collect();
        let id = svc.upload(data.clone(), DType::F64).unwrap();
        // fire a burst asynchronously, then collect
        let mut rxs = Vec::new();
        for k in 1..=32 {
            rxs.push((k, svc.query_async(id, KSpec::Rank(k * 30), Method::CuttingPlane).unwrap()));
        }
        for (k, rx) in rxs {
            let r = rx.recv().unwrap().unwrap();
            assert_eq!(r.value, sorted_order_statistic(&data, k * 30));
        }
        svc.shutdown();
    }

    #[test]
    fn eight_concurrent_medians_share_ladder_passes() {
        let svc = start_host(1);
        let mut rng = Rng::seeded(175);
        let data = Distribution::Normal.sample_vec(&mut rng, 1 << 14);
        let want = sorted_median(&data);
        let id = svc.upload(data, DType::F64).unwrap();

        // baseline: 8 sequential runs, each paying its own passes
        let seq0 = svc.metrics.snapshot().probes;
        for _ in 0..8 {
            let r = svc.query_with(id, KSpec::Median, Method::Multisection).unwrap();
            assert_eq!(r.value, want);
        }
        let sequential = svc.metrics.snapshot().probes - seq0;

        // coalesced: the same 8 queries ride shared probe-ladder rounds
        let c0 = svc.metrics.snapshot().probes;
        let rs = svc
            .query_many(id, vec![KSpec::Median; 8], Method::Multisection)
            .unwrap();
        assert_eq!(rs.len(), 8);
        for r in &rs {
            assert_eq!(r.value, want);
            assert_eq!(r.k, 1 << 13);
        }
        let coalesced = svc.metrics.snapshot().probes - c0;
        assert!(
            coalesced < sequential,
            "8 coalesced medians used {coalesced} fused reductions, \
             8 sequential used {sequential}"
        );
        assert_eq!(svc.metrics.snapshot().coalesced, 8);
        svc.shutdown();
    }

    #[test]
    fn windowed_singles_coalesce_into_one_run() {
        // 8 independent single-shot queries fired into one batching window
        // coalesce exactly like an explicit query_many batch. The window
        // runs on a virtual clock that is never advanced, so it cannot
        // expire under a scheduler stall — the cap (8) is what closes it,
        // deterministically, with zero real waiting.
        let (clock, _vc) = Clock::manual();
        let svc = SelectionService::start_full(
            1,
            64,
            Method::Multisection,
            HostBackend::factory(),
            CoordinatorOptions {
                batch_window: Duration::from_millis(100),
                batch_cap: 8,
                ..Default::default()
            },
            clock,
            crate::select::CostModelPool::seeded(),
        )
        .unwrap();
        let mut rng = Rng::seeded(177);
        let data = Distribution::Normal.sample_vec(&mut rng, 1 << 13);
        let want = sorted_median(&data);
        let id = svc.upload(data, DType::F64).unwrap();
        let p0 = svc.metrics.snapshot().probes;
        let rxs: Vec<_> = (0..8)
            .map(|_| svc.query_async(id, KSpec::Median, Method::Multisection).unwrap())
            .collect();
        for rx in rxs {
            let r = rx.recv().unwrap().unwrap();
            assert_eq!(r.value, want);
            assert_eq!(r.method, Method::Multisection);
        }
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.coalesced, 8, "all 8 singles must land in one window");
        // one shared run: strictly fewer reductions than 8 solo runs
        let single = {
            let mut ev = crate::select::HostEvaluator::new(
                &Distribution::Normal.sample_vec(&mut Rng::seeded(177), 1 << 13),
            );
            crate::select::order_statistic(&mut ev, 1 << 12, Method::Multisection).unwrap();
            ev.probes()
        };
        assert!(
            snap.probes - p0 < 8 * single,
            "windowed run used {} reductions vs 8x single {}",
            snap.probes - p0,
            8 * single
        );
        svc.shutdown();
    }

    #[test]
    fn query_then_drop_in_one_window_keeps_fifo() {
        // Regression: the old drained-batch sort keyed Drop ahead of Query,
        // so a query→drop pair collected into one batch answered the drop
        // first and failed the query with "unknown dataset". Virtual clock:
        // the window cannot expire between the query and the drop, so the
        // pair lands in one batch on every run (cap 2 closes it).
        let (clock, vc) = Clock::manual();
        let svc = SelectionService::start_full(
            1,
            64,
            Method::Multisection,
            HostBackend::factory(),
            CoordinatorOptions {
                batch_window: Duration::from_millis(100),
                batch_cap: 2,
                ..Default::default()
            },
            clock,
            crate::select::CostModelPool::seeded(),
        )
        .unwrap();
        for round in 0..3 {
            let id = svc.upload(vec![1.0, 2.0, 3.0, 4.0, 5.0], DType::F64).unwrap();
            let rx = svc.query_async(id, KSpec::Median, Method::Multisection).unwrap();
            svc.drop_dataset(id).unwrap();
            // no clock advance: the cap, not the deadline, closed the batch
            let r = rx.recv().unwrap();
            assert_eq!(
                r.expect("query fired before the drop must succeed").value,
                3.0,
                "round {round}"
            );
            // the follow-up probe opens a lone window; expire it manually
            let rx = svc.query_async(id, KSpec::Median, Method::Multisection).unwrap();
            vc.wait_for_waiters(1);
            vc.advance(Duration::from_millis(101));
            assert!(rx.recv().unwrap().is_err(), "round {round}: drop must stick");
        }
        svc.shutdown();
    }

    #[test]
    fn adaptive_window_coalesces_bursts_and_decays_to_zero_when_idle() {
        // End-to-end controller behavior under virtual time: a burst of 8
        // independent singles is caught by the fresh controller's
        // min-window (frozen clock ⇒ it cannot expire early) and widens
        // it; idle singles then decay it to exactly zero, after which a
        // lone query pays no window latency at all.
        let (clock, vc) = Clock::manual();
        let svc = SelectionService::start_full(
            1,
            64,
            Method::Multisection,
            HostBackend::factory(),
            CoordinatorOptions {
                batch_window: Duration::ZERO,
                batch_cap: 8,
                adaptive: Some(AdaptiveWindow {
                    latency_sla: Duration::from_millis(250),
                    ..AdaptiveWindow::default()
                }),
                ..Default::default()
            },
            clock,
            crate::select::CostModelPool::seeded(),
        )
        .unwrap();
        let mut rng = Rng::seeded(179);
        let data = Distribution::Normal.sample_vec(&mut rng, 1 << 13);
        let want = sorted_median(&data);
        let id = svc.upload(data, DType::F64).unwrap();

        let rxs: Vec<_> = (0..8)
            .map(|_| svc.query_async(id, KSpec::Median, Method::Multisection).unwrap())
            .collect();
        for rx in rxs {
            assert_eq!(rx.recv().unwrap().unwrap().value, want);
        }
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.coalesced, 8, "adaptive window must coalesce the whole burst: {snap}");
        assert!(snap.window_us > 0 && snap.window_widen >= 1, "burst must widen: {snap}");
        assert!(
            snap.window_us as u128 <= Duration::from_millis(250).as_micros(),
            "window blew the SLA: {snap}"
        );

        // idle decay: each lone query opens the current window; expire it
        let mut rounds = 0;
        while svc.metrics.snapshot().window_us > 0 {
            rounds += 1;
            assert!(rounds <= 32, "idle decay must terminate");
            let w = svc.metrics.snapshot().window_us;
            let rx = svc.query_async(id, KSpec::Median, Method::Multisection).unwrap();
            vc.wait_for_waiters(1);
            vc.advance_us(w + 1);
            assert_eq!(rx.recv().unwrap().unwrap().value, want);
        }
        assert!(svc.metrics.snapshot().window_shrink >= 1);

        // at zero the worker never parks: an idle query costs no virtual
        // time (the "~zero added window latency" acceptance property)
        let t0 = vc.now_us();
        assert_eq!(svc.query(id, KSpec::Median).unwrap().value, want);
        assert_eq!(vc.now_us() - t0, 0, "idle query must pay no window latency");
        svc.shutdown();
    }

    #[test]
    fn cross_dataset_traffic_does_not_widen_the_adaptive_window() {
        // Two lone queries of *different* datasets caught by one window
        // cannot share a ladder (groups are per dataset), so they must
        // read as idle traffic to the controller — not as coalescable
        // concurrency that widens the window for zero payoff.
        let (clock, _vc) = Clock::manual();
        let svc = SelectionService::start_full(
            1,
            64,
            Method::Multisection,
            HostBackend::factory(),
            CoordinatorOptions {
                batch_window: Duration::ZERO,
                batch_cap: 2,
                adaptive: Some(AdaptiveWindow {
                    latency_sla: Duration::from_millis(250),
                    ..AdaptiveWindow::default()
                }),
                ..Default::default()
            },
            clock,
            crate::select::CostModelPool::seeded(),
        )
        .unwrap();
        let a = svc.upload(vec![1.0, 2.0, 3.0], DType::F64).unwrap();
        let b = svc.upload(vec![4.0, 5.0, 6.0], DType::F64).unwrap();
        // both routed to the single worker; cap 2 closes the window with
        // one lone query per dataset in hand
        let rx_a = svc.query_async(a, KSpec::Median, Method::Multisection).unwrap();
        let rx_b = svc.query_async(b, KSpec::Median, Method::Multisection).unwrap();
        assert_eq!(rx_a.recv().unwrap().unwrap().value, 2.0);
        assert_eq!(rx_b.recv().unwrap().unwrap().value, 5.0);
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.coalesced, 0, "different datasets must not share a group: {snap}");
        assert_eq!(snap.window_widen, 0, "cross-dataset singles are idle traffic: {snap}");
        assert!(snap.window_shrink >= 1, "{snap}");
        svc.shutdown();
    }

    #[test]
    fn coalesced_group_records_latency_once() {
        // Regression: account() used to record the group's wall time once
        // per member, inserting N identical histogram entries per shared
        // run and inflating mean/p50/p99.
        let svc = start_host(1);
        let mut rng = Rng::seeded(178);
        let data = Distribution::Uniform.sample_vec(&mut rng, 4096);
        let id = svc.upload(data, DType::F64).unwrap();
        assert_eq!(svc.metrics.count(), 0, "uploads record no query latency");
        svc.query_many(id, vec![KSpec::Median; 8], Method::Multisection).unwrap();
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.queries, 8);
        assert_eq!(
            svc.metrics.count(),
            1,
            "one shared run must contribute exactly one latency sample"
        );
        assert_eq!(snap.latency_samples, 1);
        // a solo query adds exactly one more sample
        svc.query(id, KSpec::Median).unwrap();
        assert_eq!(svc.metrics.count(), 2);
        assert_eq!(svc.metrics.snapshot().queries, 9);
        svc.shutdown();
    }

    #[test]
    fn query_many_mixed_quantiles_are_exact() {
        let svc = start_host(2);
        let mut rng = Rng::seeded(176);
        let data = Distribution::Mixture3.sample_vec(&mut rng, 3001);
        let mut sorted = data.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let id = svc.upload(data, DType::F64).unwrap();
        let specs = vec![
            KSpec::Rank(1),
            KSpec::Quantile(0.1),
            KSpec::Median,
            KSpec::Quantile(0.9),
            KSpec::Rank(3001),
        ];
        let rs = svc.query_many(id, specs, Method::Multisection).unwrap();
        assert_eq!(rs.len(), 5);
        for r in &rs {
            assert_eq!(r.value, sorted[r.k - 1], "k={}", r.k);
        }
        // per-query probes sum to the real shared total, so the metric
        // stays meaningful under coalescing
        let total: u64 = rs.iter().map(|r| r.probes).sum();
        assert!(total > 0);
        svc.shutdown();
    }

    #[test]
    fn query_many_rejects_bad_specs_and_methods() {
        let svc = start_host(1);
        let id = svc.upload((1..=50).map(|i| i as f64).collect(), DType::F64).unwrap();
        assert!(svc
            .query_many(id, vec![KSpec::Median, KSpec::Rank(0)], Method::CuttingPlane)
            .is_err());
        assert!(svc
            .query_many(id, vec![KSpec::Median], Method::Quickselect)
            .is_err());
        assert!(svc.query_many(id, vec![], Method::CuttingPlane).unwrap().is_empty());
        assert!(svc.query_many(99, vec![KSpec::Median], Method::CuttingPlane).is_err());
        // the service still works after the failed batches
        assert_eq!(svc.query(id, KSpec::Median).unwrap().value, 25.0);
        svc.shutdown();
    }

    #[test]
    fn drop_dataset_frees_it() {
        let svc = start_host(1);
        let id = svc.upload(vec![1.0, 2.0, 3.0], DType::F64).unwrap();
        assert_eq!(svc.query(id, KSpec::Median).unwrap().value, 2.0);
        // synchronous drop: nothing to sleep on, the ack IS the ordering
        svc.drop_dataset_sync(id).unwrap();
        assert!(svc.query(id, KSpec::Median).is_err());
        // dropping an unknown dataset reports it
        assert!(svc.drop_dataset_sync(id).is_err());
        svc.shutdown();
    }

    #[test]
    fn token_bucket_refills_on_the_clock() {
        let quota = TenantQuota { rate_per_sec: 2.0, burst: 2.0 };
        let mut b = TokenBucket { tokens: quota.burst, last_us: 0 };
        assert!(b.admit(&quota, 0).is_ok());
        assert!(b.admit(&quota, 0).is_ok());
        let retry = b.admit(&quota, 0).unwrap_err();
        assert_eq!(retry, 500_000, "one token at 2/s is half a second away");
        // exactly half a second refills exactly one token
        assert!(b.admit(&quota, 500_000).is_ok());
        assert!(b.admit(&quota, 500_000).is_err());
    }

    #[test]
    fn shed_policy_parse_spellings() {
        assert_eq!(ShedPolicy::parse("block").unwrap(), ShedPolicy::Block);
        assert_eq!(ShedPolicy::parse("shed").unwrap(), ShedPolicy::Shed);
        assert!(ShedPolicy::parse("drop").is_err());
    }

    #[test]
    fn bad_overload_options_are_rejected_at_start() {
        let bad = |opts: CoordinatorOptions| {
            SelectionService::start_with(1, 64, Method::Hybrid, HostBackend::factory(), opts)
                .is_err()
        };
        assert!(bad(CoordinatorOptions { queue_cap: Some(0), ..Default::default() }));
        assert!(bad(CoordinatorOptions {
            tenant_quota: Some(TenantQuota { rate_per_sec: 0.0, burst: 1.0 }),
            ..Default::default()
        }));
        assert!(bad(CoordinatorOptions {
            tenant_quota: Some(TenantQuota { rate_per_sec: 1.0, burst: 0.5 }),
            ..Default::default()
        }));
        assert!(bad(CoordinatorOptions {
            tenant_quota: Some(TenantQuota { rate_per_sec: f64::NAN, burst: 1.0 }),
            ..Default::default()
        }));
    }

    #[test]
    fn f32_datasets() {
        let svc = start_host(1);
        let id = svc.upload(vec![0.1, 0.2, 0.3], DType::F32).unwrap();
        let r = svc.query(id, KSpec::Median).unwrap();
        assert!((r.value - 0.2f32 as f64).abs() < 1e-9);
        svc.shutdown();
    }
}
