//! The selection service: a router + worker pool in the style of a serving
//! frontend (vllm-project/router is the reference shape).
//!
//! - Datasets are uploaded once and pinned to a worker (consistent
//!   `id % workers` routing) — the device keeps the array resident, as in
//!   the paper's setting where x is *produced* on the GPU.
//! - Queries for a dataset are dispatched to its worker over a bounded
//!   channel (backpressure) and answered through per-request reply
//!   channels.
//! - Workers micro-batch: they drain whatever is queued and group queries
//!   by dataset, so repeated medians of the same array (the LMS/LTS inner
//!   loop!) reuse the resident buffer back-to-back.
//! - PJRT handles are thread-confined; each worker builds its own backend
//!   via the [`BackendFactory`].

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use super::backend::BackendFactory;
use super::metrics::Metrics;
use crate::select::objective::DType;
use crate::select::{self, Method};
use crate::{Error, Result};

/// What to select.
#[derive(Debug, Clone, Copy)]
pub enum KSpec {
    /// The paper's median, `x_([(n+1)/2])`.
    Median,
    /// Explicit 1-indexed rank.
    Rank(usize),
    /// Quantile in [0, 1] (rank = ceil(q·n) clamped to [1, n]).
    Quantile(f64),
}

impl KSpec {
    pub fn rank_for(&self, n: usize) -> Result<usize> {
        match *self {
            KSpec::Median => Ok(crate::util::median_rank(n)),
            KSpec::Rank(k) => {
                if k == 0 || k > n {
                    Err(crate::invalid_arg!("rank {k} out of range for n={n}"))
                } else {
                    Ok(k)
                }
            }
            KSpec::Quantile(q) => {
                if !(0.0..=1.0).contains(&q) {
                    return Err(crate::invalid_arg!("quantile {q} outside [0,1]"));
                }
                Ok(((q * n as f64).ceil() as usize).clamp(1, n))
            }
        }
    }
}

/// Answer to a query.
#[derive(Debug, Clone)]
pub struct QueryResult {
    pub value: f64,
    pub k: usize,
    pub method: Method,
    pub probes: u64,
    pub iterations: usize,
    pub wall: std::time::Duration,
}

pub type DatasetId = u64;

enum Request {
    Upload {
        id: DatasetId,
        data: Arc<Vec<f64>>,
        dtype: DType,
        reply: SyncSender<Result<()>>,
    },
    Query {
        id: DatasetId,
        k: KSpec,
        method: Method,
        reply: SyncSender<Result<QueryResult>>,
    },
    Drop {
        id: DatasetId,
    },
    Shutdown,
}

/// Handle to a running selection service.
pub struct SelectionService {
    worker_txs: Vec<SyncSender<Request>>,
    workers: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
    pub metrics: Arc<Metrics>,
    default_method: Method,
}

impl SelectionService {
    /// Start `workers` threads, each owning a backend from `factory`.
    pub fn start(
        workers: usize,
        queue_depth: usize,
        default_method: Method,
        factory: BackendFactory,
    ) -> Result<SelectionService> {
        if workers == 0 {
            return Err(crate::invalid_arg!("need at least one worker"));
        }
        let metrics = Arc::new(Metrics::new());
        let mut worker_txs = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let (tx, rx) = sync_channel::<Request>(queue_depth);
            let factory = factory.clone();
            let metrics = metrics.clone();
            let handle = std::thread::Builder::new()
                .name(format!("cp-select-worker-{w}"))
                .spawn(move || worker_loop(w, rx, factory, metrics))
                .map_err(|e| Error::Service(format!("spawn failed: {e}")))?;
            worker_txs.push(tx);
            handles.push(handle);
        }
        Ok(SelectionService {
            worker_txs,
            workers: handles,
            next_id: AtomicU64::new(1),
            metrics,
            default_method,
        })
    }

    fn route(&self, id: DatasetId) -> &SyncSender<Request> {
        &self.worker_txs[(id as usize) % self.worker_txs.len()]
    }

    /// Upload a dataset; returns its id. Blocks until the device holds it.
    pub fn upload(&self, data: Vec<f64>, dtype: DType) -> Result<DatasetId> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let (reply, rx) = sync_channel(1);
        self.route(id)
            .send(Request::Upload { id, data: Arc::new(data), dtype, reply })
            .map_err(|_| Error::Service("worker channel closed".into()))?;
        recv_reply(&rx)??;
        self.metrics.uploads.fetch_add(1, Ordering::Relaxed);
        Ok(id)
    }

    /// Blocking query with the service default method.
    pub fn query(&self, id: DatasetId, k: KSpec) -> Result<QueryResult> {
        self.query_with(id, k, self.default_method)
    }

    /// Blocking query with an explicit method.
    pub fn query_with(&self, id: DatasetId, k: KSpec, method: Method) -> Result<QueryResult> {
        recv_reply(&self.query_async(id, k, method)?)?
    }

    /// Fire a query and return the reply channel (for concurrent clients).
    pub fn query_async(
        &self,
        id: DatasetId,
        k: KSpec,
        method: Method,
    ) -> Result<Receiver<Result<QueryResult>>> {
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let (reply, rx) = sync_channel(1);
        self.route(id)
            .send(Request::Query { id, k, method, reply })
            .map_err(|_| Error::Service("worker channel closed".into()))?;
        Ok(rx)
    }

    /// Drop a dataset (fire-and-forget).
    pub fn drop_dataset(&self, id: DatasetId) -> Result<()> {
        self.route(id)
            .send(Request::Drop { id })
            .map_err(|_| Error::Service("worker channel closed".into()))
    }

    /// Graceful shutdown: drain queues, join workers.
    pub fn shutdown(mut self) {
        for tx in &self.worker_txs {
            let _ = tx.send(Request::Shutdown);
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for SelectionService {
    fn drop(&mut self) {
        for tx in &self.worker_txs {
            let _ = tx.send(Request::Shutdown);
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn recv_reply<T>(rx: &Receiver<T>) -> Result<T> {
    rx.recv().map_err(|_| Error::Service("worker dropped the reply channel".into()))
}

fn worker_loop(
    worker_idx: usize,
    rx: Receiver<Request>,
    factory: BackendFactory,
    metrics: Arc<Metrics>,
) {
    let mut backend = match factory(worker_idx) {
        Ok(b) => b,
        Err(e) => {
            // Fail every request with a clear error rather than panicking.
            while let Ok(req) = rx.recv() {
                match req {
                    Request::Upload { reply, .. } => {
                        let _ = reply.send(Err(Error::Service(format!(
                            "backend init failed: {e}"
                        ))));
                    }
                    Request::Query { reply, .. } => {
                        let _ = reply.send(Err(Error::Service(format!(
                            "backend init failed: {e}"
                        ))));
                    }
                    Request::Shutdown => return,
                    Request::Drop { .. } => {}
                }
            }
            return;
        }
    };

    // Micro-batching: drain the queue, group queries by dataset so a burst
    // of medians against the same resident array runs back-to-back.
    let mut batch: Vec<Request> = Vec::new();
    'outer: loop {
        batch.clear();
        match rx.recv() {
            Ok(r) => batch.push(r),
            Err(_) => break,
        }
        while let Ok(r) = rx.try_recv() {
            batch.push(r);
            if batch.len() >= 64 {
                break;
            }
        }
        if batch.len() > 1 {
            metrics.batched.fetch_add(batch.len() as u64 - 1, Ordering::Relaxed);
            // Stable grouping by dataset id for queries.
            batch.sort_by_key(|r| match r {
                Request::Upload { id, .. } => (0u8, *id),
                Request::Drop { id } => (1, *id),
                Request::Query { id, .. } => (2, *id),
                Request::Shutdown => (3, u64::MAX),
            });
        }
        for req in batch.drain(..) {
            match req {
                Request::Upload { id, data, dtype, reply } => {
                    let r = backend.upload(id, &data, dtype);
                    if r.is_err() {
                        metrics.errors.fetch_add(1, Ordering::Relaxed);
                    }
                    let _ = reply.send(r);
                }
                Request::Drop { id } => backend.drop_dataset(id),
                Request::Query { id, k, method, reply } => {
                    let t0 = Instant::now();
                    let out = run_query(backend.as_mut(), id, k, method);
                    let wall = t0.elapsed();
                    metrics.queries.fetch_add(1, Ordering::Relaxed);
                    metrics.record_latency(wall);
                    match &out {
                        Ok(q) => {
                            metrics.probes.fetch_add(q.probes, Ordering::Relaxed);
                        }
                        Err(_) => {
                            metrics.errors.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    let _ = reply.send(out.map(|mut q| {
                        q.wall = wall;
                        q
                    }));
                }
                Request::Shutdown => break 'outer,
            }
        }
    }
}

fn run_query(
    backend: &mut dyn super::backend::DatasetBackend,
    id: DatasetId,
    k: KSpec,
    method: Method,
) -> Result<QueryResult> {
    let n = backend
        .dataset_len(id)
        .ok_or_else(|| Error::Service(format!("unknown dataset {id}")))?;
    let rank = k.rank_for(n)?;
    let ev = backend.evaluator(id)?;
    let r = select::order_statistic(ev, rank, method)?;
    Ok(QueryResult {
        value: r.value,
        k: rank,
        method,
        probes: r.probes,
        iterations: r.iterations,
        wall: std::time::Duration::ZERO, // filled by the worker loop
    })
}

/// Batch-of-datasets convenience: a `HashMap` of names to ids.
pub struct NamedDatasets {
    pub ids: HashMap<String, DatasetId>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::HostBackend;
    use crate::stats::{sorted_median, sorted_order_statistic, Distribution, Rng};

    fn start_host(workers: usize) -> SelectionService {
        SelectionService::start(workers, 64, Method::Hybrid, HostBackend::factory()).unwrap()
    }

    #[test]
    fn upload_query_roundtrip() {
        let svc = start_host(1);
        let mut rng = Rng::seeded(171);
        let data = Distribution::Normal.sample_vec(&mut rng, 2001);
        let want = sorted_median(&data);
        let id = svc.upload(data, DType::F64).unwrap();
        let r = svc.query(id, KSpec::Median).unwrap();
        assert_eq!(r.value, want);
        assert_eq!(r.k, 1001);
        assert!(r.wall > std::time::Duration::ZERO);
        svc.shutdown();
    }

    #[test]
    fn rank_and_quantile_specs() {
        let svc = start_host(2);
        let data: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let id = svc.upload(data, DType::F64).unwrap();
        assert_eq!(svc.query(id, KSpec::Rank(1)).unwrap().value, 1.0);
        assert_eq!(svc.query(id, KSpec::Rank(100)).unwrap().value, 100.0);
        assert_eq!(svc.query(id, KSpec::Quantile(0.25)).unwrap().value, 25.0);
        assert_eq!(svc.query(id, KSpec::Quantile(1.0)).unwrap().value, 100.0);
        assert!(svc.query(id, KSpec::Rank(0)).is_err());
        assert!(svc.query(id, KSpec::Quantile(1.5)).is_err());
        svc.shutdown();
    }

    #[test]
    fn methods_agree_through_service() {
        let svc = start_host(2);
        let mut rng = Rng::seeded(172);
        let data = Distribution::Mixture1.sample_vec(&mut rng, 999);
        let want = sorted_order_statistic(&data, 250);
        let id = svc.upload(data, DType::F64).unwrap();
        for m in [Method::CuttingPlane, Method::Hybrid, Method::Bisection, Method::Quickselect] {
            let r = svc.query_with(id, KSpec::Rank(250), m).unwrap();
            assert_eq!(r.value, want, "{}", m.name());
        }
        svc.shutdown();
    }

    #[test]
    fn unknown_dataset_errors_and_counts() {
        let svc = start_host(1);
        assert!(svc.query(42, KSpec::Median).is_err());
        assert_eq!(svc.metrics.snapshot().errors, 1);
        svc.shutdown();
    }

    #[test]
    fn concurrent_clients_many_datasets() {
        let svc = Arc::new(start_host(4));
        let mut rng = Rng::seeded(173);
        let mut wants = Vec::new();
        let mut ids = Vec::new();
        for i in 0..12 {
            let d = Distribution::ALL[i % 9].sample_vec(&mut rng, 500 + i * 37);
            wants.push(sorted_median(&d));
            ids.push(svc.upload(d, DType::F64).unwrap());
        }
        let mut handles = Vec::new();
        for (chunk_start, chunk) in ids.chunks(3).enumerate() {
            let svc = svc.clone();
            let chunk: Vec<_> = chunk.to_vec();
            handles.push(std::thread::spawn(move || {
                chunk
                    .iter()
                    .map(|&id| (chunk_start, svc.query(id, KSpec::Median).unwrap().value))
                    .collect::<Vec<_>>()
            }));
        }
        let mut got = Vec::new();
        for h in handles {
            got.extend(h.join().unwrap());
        }
        assert_eq!(got.len(), 12);
        for (i, (_, v)) in got.iter().enumerate() {
            // order within chunks preserved: map back via position
            let idx = (i / 3) * 3 + (i % 3);
            assert_eq!(*v, wants[idx]);
        }
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.queries, 12);
        assert_eq!(snap.uploads, 12);
        Arc::try_unwrap(svc).ok().map(|s| s.shutdown());
    }

    #[test]
    fn burst_queries_get_batched() {
        let svc = start_host(1);
        let data: Vec<f64> = (0..1000).map(|i| (i * 7919 % 997) as f64).collect();
        let id = svc.upload(data.clone(), DType::F64).unwrap();
        // fire a burst asynchronously, then collect
        let mut rxs = Vec::new();
        for k in 1..=32 {
            rxs.push((k, svc.query_async(id, KSpec::Rank(k * 30), Method::CuttingPlane).unwrap()));
        }
        for (k, rx) in rxs {
            let r = rx.recv().unwrap().unwrap();
            assert_eq!(r.value, sorted_order_statistic(&data, k * 30));
        }
        svc.shutdown();
    }

    #[test]
    fn drop_dataset_frees_it() {
        let svc = start_host(1);
        let id = svc.upload(vec![1.0, 2.0, 3.0], DType::F64).unwrap();
        assert_eq!(svc.query(id, KSpec::Median).unwrap().value, 2.0);
        svc.drop_dataset(id).unwrap();
        // allow the worker to process the drop
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(svc.query(id, KSpec::Median).is_err());
        svc.shutdown();
    }

    #[test]
    fn f32_datasets() {
        let svc = start_host(1);
        let id = svc.upload(vec![0.1, 0.2, 0.3], DType::F32).unwrap();
        let r = svc.query(id, KSpec::Median).unwrap();
        assert!((r.value - 0.2f32 as f64).abs() < 1e-9);
        svc.shutdown();
    }
}
