//! Layer-3 coordinator: the selection service (router + sticky workers +
//! micro-batching), dataset backends, and metrics.
//!
//! This is the runtime a downstream system embeds: upload device-resident
//! arrays once, then issue many order-statistic queries (the LMS/LTS and
//! kNN applications are exactly such workloads).

pub mod backend;
pub mod controller;
pub(crate) mod dispatch;
pub mod eviction;
pub mod messages;
pub mod metrics;
mod planner;
pub mod service;

pub use backend::{BackendFactory, DatasetBackend, DeviceBackend, HostBackend};
pub use controller::{AdaptiveWindow, WindowController, WindowDecision};
pub use eviction::{lru_factory, LruBackend};
pub use metrics::{Metrics, Snapshot};
pub use service::{
    CoordinatorOptions, DatasetId, KSpec, QueryOptions, QueryResult, SelectionService, ShedPolicy,
    TenantQuota,
};
// The cross-worker cost-model pool is defined next to `PassCostModel`
// (select::gpu_model) but is coordinator infrastructure; re-export it here.
pub use crate::select::gpu_model::CostModelPool;
