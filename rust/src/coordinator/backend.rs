//! Dataset backends: where uploaded arrays live and how probes execute.
//!
//! A backend instance is **thread-confined** (PJRT handles are not Send);
//! the service constructs one per worker thread through a `Send + Sync`
//! factory. Datasets are sticky to their worker — exactly how a real
//! router pins a user's KV-cache/array to one accelerator.

use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::Arc;

use crate::runtime::{DeviceEvaluator, Flavor, Runtime};
use crate::select::objective::{DType, Evaluator, HostEvaluator};
use crate::{Error, Result};

/// Per-worker dataset store + evaluator factory.
pub trait DatasetBackend {
    fn upload(&mut self, id: u64, data: &[f64], dtype: DType) -> Result<()>;
    fn evaluator(&mut self, id: u64) -> Result<&mut dyn Evaluator>;
    /// Release a dataset; returns whether it was resident (the service's
    /// synchronous drop ack reports an unknown id to the caller).
    fn drop_dataset(&mut self, id: u64) -> bool;
    fn dataset_len(&self, id: u64) -> Option<usize>;
    /// Human-readable backend kind (metrics / logs).
    fn kind(&self) -> &'static str;
    /// Drain the count of capacity evictions performed since the last
    /// call (pressure-driven, not client-requested drops). The worker
    /// polls this after each batch into `Metrics::evictions`. Backends
    /// without capacity pressure report none.
    fn take_evictions(&mut self) -> u64 {
        0
    }
}

/// Factory invoked inside each worker thread.
pub type BackendFactory = Arc<dyn Fn(usize) -> Result<Box<dyn DatasetBackend>> + Send + Sync>;

/// Host-memory backend (the CPU oracle; also useful for tests).
#[derive(Default)]
pub struct HostBackend {
    datasets: HashMap<u64, HostEvaluator>,
}

impl HostBackend {
    pub fn factory() -> BackendFactory {
        Arc::new(|_worker| Ok(Box::<HostBackend>::default() as Box<dyn DatasetBackend>))
    }
}

impl DatasetBackend for HostBackend {
    fn upload(&mut self, id: u64, data: &[f64], dtype: DType) -> Result<()> {
        let ev = match dtype {
            DType::F64 => HostEvaluator::new(data),
            DType::F32 => HostEvaluator::new_f32(data),
        };
        self.datasets.insert(id, ev);
        Ok(())
    }

    fn evaluator(&mut self, id: u64) -> Result<&mut dyn Evaluator> {
        self.datasets
            .get_mut(&id)
            .map(|e| e as &mut dyn Evaluator)
            .ok_or_else(|| Error::Service(format!("unknown dataset {id}")))
    }

    fn drop_dataset(&mut self, id: u64) -> bool {
        self.datasets.remove(&id).is_some()
    }

    fn dataset_len(&self, id: u64) -> Option<usize> {
        self.datasets.get(&id).map(|e| e.n())
    }

    fn kind(&self) -> &'static str {
        "host"
    }
}

/// PJRT device backend: one runtime per worker thread, datasets uploaded
/// once as device buffers.
pub struct DeviceBackend {
    rt: Rc<Runtime>,
    datasets: HashMap<u64, DeviceEvaluator>,
}

impl DeviceBackend {
    pub fn new(artifacts_dir: &std::path::Path, flavor: Flavor) -> Result<Self> {
        Ok(DeviceBackend {
            rt: Runtime::with_flavor(artifacts_dir, flavor)?,
            datasets: HashMap::new(),
        })
    }

    pub fn factory(artifacts_dir: PathBuf, flavor: Flavor) -> BackendFactory {
        Arc::new(move |_worker| {
            Ok(Box::new(DeviceBackend::new(&artifacts_dir, flavor)?) as Box<dyn DatasetBackend>)
        })
    }
}

impl DatasetBackend for DeviceBackend {
    fn upload(&mut self, id: u64, data: &[f64], dtype: DType) -> Result<()> {
        let ev = DeviceEvaluator::upload(&self.rt, data, dtype)?;
        self.datasets.insert(id, ev);
        Ok(())
    }

    fn evaluator(&mut self, id: u64) -> Result<&mut dyn Evaluator> {
        self.datasets
            .get_mut(&id)
            .map(|e| e as &mut dyn Evaluator)
            .ok_or_else(|| Error::Service(format!("unknown dataset {id}")))
    }

    fn drop_dataset(&mut self, id: u64) -> bool {
        self.datasets.remove(&id).is_some()
    }

    fn dataset_len(&self, id: u64) -> Option<usize> {
        self.datasets.get(&id).map(|e| e.n())
    }

    fn kind(&self) -> &'static str {
        "pjrt-device"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_backend_roundtrip() {
        let mut b = HostBackend::default();
        b.upload(1, &[3.0, 1.0, 2.0], DType::F64).unwrap();
        assert_eq!(b.dataset_len(1), Some(3));
        let ev = b.evaluator(1).unwrap();
        assert_eq!(ev.n(), 3);
        assert!(b.evaluator(99).is_err());
        assert!(b.drop_dataset(1), "dataset 1 was resident");
        assert!(!b.drop_dataset(1), "second drop finds nothing");
        assert!(b.evaluator(1).is_err());
        assert_eq!(b.kind(), "host");
    }

    #[test]
    fn factory_builds_independent_stores() {
        let f = HostBackend::factory();
        let mut a = f(0).unwrap();
        let b = f(1).unwrap();
        a.upload(7, &[1.0], DType::F64).unwrap();
        assert_eq!(a.dataset_len(7), Some(1));
        assert_eq!(b.dataset_len(7), None);
    }
}
