//! Capacity management: LRU eviction over dataset backends.
//!
//! Device memory is finite (the paper's 3 GB Tesla C2050 fits one 2²⁷ f64
//! array with little slack); a serving deployment needs a bound on resident
//! datasets per worker. [`LruBackend`] wraps any [`DatasetBackend`] and
//! evicts the least-recently-used dataset when the cap is exceeded —
//! queries for an evicted dataset fail with a clear "re-upload" error,
//! which the client can act on (the usual cache-miss contract).
//!
//! Recency is an O(1) structure (a sequence-stamped queue plus a
//! `HashMap` index), so touches on a hot serving path never scan the
//! resident set; evictions are counted only when the inner backend
//! confirms it actually dropped the dataset, and are reported upstream
//! through [`DatasetBackend::take_evictions`] so the coordinator's
//! `evictions` metric reflects live pressure.

use std::collections::{HashMap, VecDeque};

use super::backend::DatasetBackend;
use crate::select::objective::{DType, Evaluator};
use crate::{Error, Result};

pub struct LruBackend {
    inner: Box<dyn DatasetBackend>,
    /// `(seq, id)` in stamp order, most-recent at the back. Touching a
    /// dataset pushes a fresh stamp and leaves the old entry behind as a
    /// stale tombstone; [`LruBackend::evict_to_fit`] skips entries whose
    /// stamp no longer matches `index`.
    order: VecDeque<(u64, u64)>,
    /// Live datasets: id → its current (latest) stamp.
    index: HashMap<u64, u64>,
    next_seq: u64,
    capacity: usize,
    evictions: u64,
    /// Evictions since the last [`DatasetBackend::take_evictions`] drain.
    pending_evictions: u64,
}

impl LruBackend {
    /// Wrap `inner` with a residency cap. `capacity` of zero is a
    /// configuration error (a worker that can hold nothing can answer
    /// nothing), reported as a typed error rather than a panic so config
    /// and CLI paths degrade cleanly.
    pub fn new(inner: Box<dyn DatasetBackend>, capacity: usize) -> Result<Self> {
        if capacity == 0 {
            return Err(crate::invalid_arg!("LRU capacity must be at least 1 dataset"));
        }
        Ok(LruBackend {
            inner,
            order: VecDeque::new(),
            index: HashMap::new(),
            next_seq: 0,
            capacity,
            evictions: 0,
            pending_evictions: 0,
        })
    }

    /// Total evictions over this backend's lifetime.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    pub fn resident(&self) -> usize {
        self.index.len()
    }

    fn touch(&mut self, id: u64) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.order.push_back((seq, id));
        self.index.insert(id, seq);
        // Stale tombstones accumulate one per touch; compact once they
        // outnumber live entries enough to matter (amortized O(1)).
        if self.order.len() > 2 * self.index.len().max(self.capacity) {
            let index = &self.index;
            self.order.retain(|&(seq, id)| index.get(&id) == Some(&seq));
        }
    }

    fn evict_to_fit(&mut self) {
        while self.index.len() > self.capacity {
            let (seq, victim) = match self.order.pop_front() {
                Some(front) => front,
                None => return, // index/order diverged; nothing to evict
            };
            if self.index.get(&victim) != Some(&seq) {
                continue; // stale tombstone of a touched or dropped dataset
            }
            self.index.remove(&victim);
            // Count only confirmed drops: an inner backend that no longer
            // holds the victim (e.g. it failed mid-upload) must not
            // inflate the eviction metric.
            if self.inner.drop_dataset(victim) {
                self.evictions += 1;
                self.pending_evictions += 1;
            }
        }
    }
}

impl DatasetBackend for LruBackend {
    fn upload(&mut self, id: u64, data: &[f64], dtype: DType) -> Result<()> {
        self.inner.upload(id, data, dtype)?;
        self.touch(id);
        self.evict_to_fit();
        Ok(())
    }

    fn evaluator(&mut self, id: u64) -> Result<&mut dyn Evaluator> {
        if !self.index.contains_key(&id) {
            return Err(Error::Service(format!(
                "dataset {id} not resident (evicted or never uploaded); re-upload it"
            )));
        }
        self.touch(id);
        self.inner.evaluator(id)
    }

    fn drop_dataset(&mut self, id: u64) -> bool {
        // the order entry becomes a stale tombstone; evict/compact skip it
        self.index.remove(&id);
        self.inner.drop_dataset(id)
    }

    fn dataset_len(&self, id: u64) -> Option<usize> {
        if self.index.contains_key(&id) {
            self.inner.dataset_len(id)
        } else {
            None
        }
    }

    fn take_evictions(&mut self) -> u64 {
        std::mem::take(&mut self.pending_evictions)
    }

    fn kind(&self) -> &'static str {
        "lru"
    }
}

/// Wrap a backend factory with an LRU cap (applied per worker).
pub fn lru_factory(
    inner: super::backend::BackendFactory,
    capacity: usize,
) -> super::backend::BackendFactory {
    std::sync::Arc::new(move |worker| {
        Ok(Box::new(LruBackend::new(inner(worker)?, capacity)?) as Box<dyn DatasetBackend>)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::HostBackend;
    use crate::coordinator::{KSpec, SelectionService};
    use crate::select::Method;

    fn lru(cap: usize) -> LruBackend {
        LruBackend::new(Box::<HostBackend>::default(), cap).unwrap()
    }

    #[test]
    fn zero_capacity_is_a_typed_error() {
        assert!(LruBackend::new(Box::<HostBackend>::default(), 0).is_err());
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut b = lru(2);
        b.upload(1, &[1.0], DType::F64).unwrap();
        b.upload(2, &[2.0], DType::F64).unwrap();
        b.evaluator(1).unwrap(); // 1 is now most recent
        b.upload(3, &[3.0], DType::F64).unwrap(); // evicts 2
        assert_eq!(b.evictions(), 1);
        assert!(b.evaluator(2).is_err());
        assert!(b.evaluator(1).is_ok());
        assert!(b.evaluator(3).is_ok());
        assert_eq!(b.resident(), 2);
    }

    #[test]
    fn reupload_after_eviction_works() {
        let mut b = lru(1);
        b.upload(1, &[1.0, 2.0, 3.0], DType::F64).unwrap();
        b.upload(2, &[4.0], DType::F64).unwrap(); // evicts 1
        assert!(b.evaluator(1).is_err());
        b.upload(1, &[1.0, 2.0, 3.0], DType::F64).unwrap(); // evicts 2
        assert_eq!(b.evaluator(1).unwrap().n(), 3);
        assert_eq!(b.evictions(), 2);
    }

    #[test]
    fn explicit_drop_frees_slot() {
        let mut b = lru(2);
        b.upload(1, &[1.0], DType::F64).unwrap();
        b.upload(2, &[2.0], DType::F64).unwrap();
        b.drop_dataset(1);
        assert_eq!(b.resident(), 1);
        b.upload(3, &[3.0], DType::F64).unwrap();
        assert_eq!(b.evictions(), 0); // no eviction needed
        assert_eq!(b.dataset_len(1), None);
        assert_eq!(b.dataset_len(3), Some(1));
    }

    #[test]
    fn hot_touches_stay_correct_through_compaction() {
        // Hammer one dataset with touches so the order queue accumulates
        // stale stamps and compacts, then check eviction still picks the
        // true LRU victim.
        let mut b = lru(2);
        b.upload(1, &[1.0], DType::F64).unwrap();
        b.upload(2, &[2.0], DType::F64).unwrap();
        for _ in 0..64 {
            b.evaluator(2).unwrap();
        }
        assert!(b.order.len() <= 2 * b.index.len().max(b.capacity), "compaction must bound growth");
        b.upload(3, &[3.0], DType::F64).unwrap(); // evicts 1, the cold one
        assert!(b.evaluator(1).is_err());
        assert!(b.evaluator(2).is_ok());
        assert_eq!(b.evictions(), 1);
    }

    #[test]
    fn take_evictions_drains_pending() {
        let mut b = lru(1);
        b.upload(1, &[1.0], DType::F64).unwrap();
        b.upload(2, &[2.0], DType::F64).unwrap(); // evicts 1
        assert_eq!(b.take_evictions(), 1);
        assert_eq!(b.take_evictions(), 0, "drain must reset the pending count");
        assert_eq!(b.evictions(), 1, "lifetime counter is unaffected by draining");
    }

    #[test]
    fn lru_through_the_service() {
        let svc = SelectionService::start(
            1,
            16,
            Method::Hybrid,
            lru_factory(HostBackend::factory(), 2),
        )
        .unwrap();
        let a = svc.upload(vec![1.0, 2.0, 3.0], DType::F64).unwrap();
        let b = svc.upload(vec![4.0, 5.0, 6.0], DType::F64).unwrap();
        let c = svc.upload(vec![7.0, 8.0, 9.0], DType::F64).unwrap(); // evicts a
        assert!(svc.query(a, KSpec::Median).is_err());
        assert_eq!(svc.query(b, KSpec::Median).unwrap().value, 5.0);
        assert_eq!(svc.query(c, KSpec::Median).unwrap().value, 8.0);
        assert_eq!(svc.metrics.snapshot().evictions, 1, "live pressure reaches the metric");
        svc.shutdown();
    }
}
