//! Capacity management: LRU eviction over dataset backends.
//!
//! Device memory is finite (the paper's 3 GB Tesla C2050 fits one 2²⁷ f64
//! array with little slack); a serving deployment needs a bound on resident
//! datasets per worker. [`LruBackend`] wraps any [`DatasetBackend`] and
//! evicts the least-recently-used dataset when the cap is exceeded —
//! queries for an evicted dataset fail with a clear "re-upload" error,
//! which the client can act on (the usual cache-miss contract).

use std::collections::VecDeque;

use super::backend::DatasetBackend;
use crate::select::objective::{DType, Evaluator};
use crate::{Error, Result};

pub struct LruBackend {
    inner: Box<dyn DatasetBackend>,
    /// Most-recent at the back.
    order: VecDeque<u64>,
    capacity: usize,
    evictions: u64,
}

impl LruBackend {
    pub fn new(inner: Box<dyn DatasetBackend>, capacity: usize) -> Self {
        assert!(capacity >= 1);
        LruBackend { inner, order: VecDeque::new(), capacity, evictions: 0 }
    }

    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    pub fn resident(&self) -> usize {
        self.order.len()
    }

    fn touch(&mut self, id: u64) {
        if let Some(pos) = self.order.iter().position(|&d| d == id) {
            self.order.remove(pos);
        }
        self.order.push_back(id);
    }

    fn evict_to_fit(&mut self) {
        while self.order.len() > self.capacity {
            if let Some(victim) = self.order.pop_front() {
                self.inner.drop_dataset(victim);
                self.evictions += 1;
            }
        }
    }
}

impl DatasetBackend for LruBackend {
    fn upload(&mut self, id: u64, data: &[f64], dtype: DType) -> Result<()> {
        self.inner.upload(id, data, dtype)?;
        self.touch(id);
        self.evict_to_fit();
        Ok(())
    }

    fn evaluator(&mut self, id: u64) -> Result<&mut dyn Evaluator> {
        if !self.order.contains(&id) {
            return Err(Error::Service(format!(
                "dataset {id} not resident (evicted or never uploaded); re-upload it"
            )));
        }
        self.touch(id);
        self.inner.evaluator(id)
    }

    fn drop_dataset(&mut self, id: u64) -> bool {
        if let Some(pos) = self.order.iter().position(|&d| d == id) {
            self.order.remove(pos);
        }
        self.inner.drop_dataset(id)
    }

    fn dataset_len(&self, id: u64) -> Option<usize> {
        if self.order.contains(&id) {
            self.inner.dataset_len(id)
        } else {
            None
        }
    }

    fn kind(&self) -> &'static str {
        "lru"
    }
}

/// Wrap a backend factory with an LRU cap (applied per worker).
pub fn lru_factory(
    inner: super::backend::BackendFactory,
    capacity: usize,
) -> super::backend::BackendFactory {
    std::sync::Arc::new(move |worker| {
        Ok(Box::new(LruBackend::new(inner(worker)?, capacity)) as Box<dyn DatasetBackend>)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::HostBackend;
    use crate::coordinator::{KSpec, SelectionService};
    use crate::select::Method;

    fn lru(cap: usize) -> LruBackend {
        LruBackend::new(Box::<HostBackend>::default(), cap)
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut b = lru(2);
        b.upload(1, &[1.0], DType::F64).unwrap();
        b.upload(2, &[2.0], DType::F64).unwrap();
        b.evaluator(1).unwrap(); // 1 is now most recent
        b.upload(3, &[3.0], DType::F64).unwrap(); // evicts 2
        assert_eq!(b.evictions(), 1);
        assert!(b.evaluator(2).is_err());
        assert!(b.evaluator(1).is_ok());
        assert!(b.evaluator(3).is_ok());
        assert_eq!(b.resident(), 2);
    }

    #[test]
    fn reupload_after_eviction_works() {
        let mut b = lru(1);
        b.upload(1, &[1.0, 2.0, 3.0], DType::F64).unwrap();
        b.upload(2, &[4.0], DType::F64).unwrap(); // evicts 1
        assert!(b.evaluator(1).is_err());
        b.upload(1, &[1.0, 2.0, 3.0], DType::F64).unwrap(); // evicts 2
        assert_eq!(b.evaluator(1).unwrap().n(), 3);
        assert_eq!(b.evictions(), 2);
    }

    #[test]
    fn explicit_drop_frees_slot() {
        let mut b = lru(2);
        b.upload(1, &[1.0], DType::F64).unwrap();
        b.upload(2, &[2.0], DType::F64).unwrap();
        b.drop_dataset(1);
        assert_eq!(b.resident(), 1);
        b.upload(3, &[3.0], DType::F64).unwrap();
        assert_eq!(b.evictions(), 0); // no eviction needed
        assert_eq!(b.dataset_len(1), None);
        assert_eq!(b.dataset_len(3), Some(1));
    }

    #[test]
    fn lru_through_the_service() {
        let svc = SelectionService::start(
            1,
            16,
            Method::Hybrid,
            lru_factory(HostBackend::factory(), 2),
        )
        .unwrap();
        let a = svc.upload(vec![1.0, 2.0, 3.0], DType::F64).unwrap();
        let b = svc.upload(vec![4.0, 5.0, 6.0], DType::F64).unwrap();
        let c = svc.upload(vec![7.0, 8.0, 9.0], DType::F64).unwrap(); // evicts a
        assert!(svc.query(a, KSpec::Median).is_err());
        assert_eq!(svc.query(b, KSpec::Median).unwrap().value, 5.0);
        assert_eq!(svc.query(c, KSpec::Median).unwrap().value, 8.0);
        svc.shutdown();
    }
}
