//! Service observability: lock-free counters and a log-bucketed latency
//! histogram, in the style of a serving router's metrics endpoint.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use super::controller::WindowDecision;
use crate::util::sync::{OrderedMutex, RANK_TENANT_DEPTH};

/// Log₂-bucketed latency histogram: bucket i covers [2^i, 2^(i+1)) µs.
const BUCKETS: usize = 32;

#[derive(Debug)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub uploads: AtomicU64,
    pub queries: AtomicU64,
    pub errors: AtomicU64,
    pub probes: AtomicU64,
    pub batched: AtomicU64,
    /// Queries answered through shared probe-ladder rounds (coalesced
    /// same-dataset batches — see `service::solve_group`).
    pub coalesced: AtomicU64,
    /// Adaptive-controller gauge: the batching window (µs) currently in
    /// force (last controller decision wins across workers; 0 when idle
    /// or when the service runs a fixed window).
    pub window_us: AtomicU64,
    /// Controller decisions: window widened under observed concurrency.
    pub window_widen: AtomicU64,
    /// Controller decisions: window shrunk toward zero on idle batches.
    pub window_shrink: AtomicU64,
    /// Controller decisions cut short by the latency-SLA budget.
    pub window_sla_clamp: AtomicU64,
    /// Requests rejected by admission control (queue full under
    /// `ShedPolicy::Shed`, or tenant token bucket empty).
    pub shed: AtomicU64,
    /// Queries abandoned (before or between fused passes) because their
    /// deadline passed.
    pub deadline_exceeded: AtomicU64,
    /// Backend panics caught by worker fault isolation; each failed one
    /// batch step with typed errors instead of killing the worker.
    pub worker_faults: AtomicU64,
    /// Datasets evicted under capacity pressure (LRU backend), polled from
    /// the backend after each batch.
    pub evictions: AtomicU64,
    /// In-flight queries per tenant (admitted but not yet replied to).
    tenant_depth: OrderedMutex<HashMap<u32, u64>>,
    latency_us: [AtomicU64; BUCKETS],
    latency_sum_us: AtomicU64,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            requests: AtomicU64::new(0),
            uploads: AtomicU64::new(0),
            queries: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            probes: AtomicU64::new(0),
            batched: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            window_us: AtomicU64::new(0),
            window_widen: AtomicU64::new(0),
            window_shrink: AtomicU64::new(0),
            window_sla_clamp: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            deadline_exceeded: AtomicU64::new(0),
            worker_faults: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            tenant_depth: OrderedMutex::new(
                RANK_TENANT_DEPTH,
                "metrics.tenant_depth",
                HashMap::new(),
            ),
            latency_us: Default::default(),
            latency_sum_us: AtomicU64::new(0),
        }
    }

    /// Record one latency sample. The service records **one sample per
    /// executed run**: a coalesced group of N queries shares one wall time
    /// and contributes one sample (N samples of the same shared wall would
    /// systematically inflate mean/p50/p99), so `count()` tracks runs
    /// while `queries` tracks queries — under coalescing
    /// `count() ≤ queries` by exactly the shared-run savings.
    pub fn record_latency(&self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let bucket = (63 - us.leading_zeros() as usize).min(BUCKETS - 1);
        self.latency_us[bucket].fetch_add(1, Ordering::Relaxed);
        self.latency_sum_us.fetch_add(us, Ordering::Relaxed);
    }

    /// Number of latency samples (= executed runs; see
    /// [`Metrics::record_latency`]).
    pub fn count(&self) -> u64 {
        self.latency_us.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    pub fn mean_latency_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.latency_sum_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// Approximate quantile from bucket boundaries (upper bound).
    pub fn latency_quantile_us(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * n as f64).ceil() as u64).max(1);
        let mut seen = 0;
        for (i, b) in self.latency_us.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        // The scan can fall through when recorders race it: `count()` and
        // the per-bucket loads are separate Relaxed reads, so `target` may
        // be computed from increments the scan then misses (and for huge n
        // the f64 rounding of q*n can overshoot the true sum). The honest
        // answer is the top bucket edge — never `u64::MAX`, which would
        // flow into `Error::Overloaded { retry_after_us }` as an absurd
        // backoff hint.
        1u64 << BUCKETS
    }

    /// Record one adaptive-controller decision: refresh the window gauge
    /// and count the decision kind (see `coordinator::WindowController`).
    pub fn note_window(&self, window_us: u64, decision: WindowDecision) {
        self.window_us.store(window_us, Ordering::Relaxed);
        match decision {
            WindowDecision::Widen => self.window_widen.fetch_add(1, Ordering::Relaxed),
            WindowDecision::Shrink => self.window_shrink.fetch_add(1, Ordering::Relaxed),
            WindowDecision::SlaClamp => self.window_sla_clamp.fetch_add(1, Ordering::Relaxed),
            WindowDecision::Hold => 0,
        };
    }

    /// A query for `tenant` was admitted: bump its in-flight depth gauge.
    pub fn tenant_enter(&self, tenant: u32) {
        let mut map = self.tenant_depth.lock();
        *map.entry(tenant).or_insert(0) += 1;
    }

    /// A query for `tenant` was replied to (result or typed error): drop
    /// its in-flight depth gauge.
    pub fn tenant_exit(&self, tenant: u32) {
        let mut map = self.tenant_depth.lock();
        if let Some(d) = map.get_mut(&tenant) {
            *d = d.saturating_sub(1);
            if *d == 0 {
                map.remove(&tenant);
            }
        }
    }

    /// Current in-flight depth for one tenant.
    pub fn tenant_depth(&self, tenant: u32) -> u64 {
        let map = self.tenant_depth.lock();
        map.get(&tenant).copied().unwrap_or(0)
    }

    /// Deepest per-tenant in-flight depth right now (0 when idle).
    pub fn max_tenant_depth(&self) -> u64 {
        let map = self.tenant_depth.lock();
        map.values().copied().max().unwrap_or(0)
    }

    pub fn snapshot(&self) -> Snapshot {
        Snapshot {
            requests: self.requests.load(Ordering::Relaxed),
            uploads: self.uploads.load(Ordering::Relaxed),
            queries: self.queries.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            probes: self.probes.load(Ordering::Relaxed),
            batched: self.batched.load(Ordering::Relaxed),
            coalesced: self.coalesced.load(Ordering::Relaxed),
            window_us: self.window_us.load(Ordering::Relaxed),
            window_widen: self.window_widen.load(Ordering::Relaxed),
            window_shrink: self.window_shrink.load(Ordering::Relaxed),
            window_sla_clamp: self.window_sla_clamp.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            deadline_exceeded: self.deadline_exceeded.load(Ordering::Relaxed),
            worker_faults: self.worker_faults.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            max_tenant_depth: self.max_tenant_depth(),
            latency_samples: self.count(),
            mean_latency_us: self.mean_latency_us(),
            p50_us: self.latency_quantile_us(0.5),
            p99_us: self.latency_quantile_us(0.99),
        }
    }
}

/// Point-in-time metrics view.
#[derive(Debug, Clone, PartialEq)]
pub struct Snapshot {
    pub requests: u64,
    pub uploads: u64,
    pub queries: u64,
    pub errors: u64,
    pub probes: u64,
    pub batched: u64,
    pub coalesced: u64,
    /// Adaptive batching window currently in force (µs; 0 when idle or
    /// fixed-window).
    pub window_us: u64,
    /// Adaptive-controller widen decisions.
    pub window_widen: u64,
    /// Adaptive-controller shrink decisions.
    pub window_shrink: u64,
    /// Adaptive-controller decisions clamped by the latency SLA.
    pub window_sla_clamp: u64,
    /// Requests shed by admission control (queue full / tenant bucket).
    pub shed: u64,
    /// Queries abandoned past their deadline.
    pub deadline_exceeded: u64,
    /// Backend panics caught and contained by worker fault isolation.
    pub worker_faults: u64,
    /// Capacity evictions performed by a pressure-managed backend.
    pub evictions: u64,
    /// Deepest per-tenant in-flight depth at snapshot time.
    pub max_tenant_depth: u64,
    /// Latency samples recorded — one per executed *run*, so strictly
    /// fewer than `queries` when coalescing shares runs.
    pub latency_samples: u64,
    pub mean_latency_us: f64,
    pub p50_us: u64,
    pub p99_us: u64,
}

impl std::fmt::Display for Snapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "requests={} uploads={} queries={} errors={} probes={} batched={} \
             coalesced={} window(us={} widen={} shrink={} clamps={}) \
             overload(shed={} deadlines={} faults={} evictions={} depth={}) \
             latency(runs={} mean={:.0}us p50<{}us p99<{}us)",
            self.requests,
            self.uploads,
            self.queries,
            self.errors,
            self.probes,
            self.batched,
            self.coalesced,
            self.window_us,
            self.window_widen,
            self.window_shrink,
            self.window_sla_clamp,
            self.shed,
            self.deadline_exceeded,
            self.worker_faults,
            self.evictions,
            self.max_tenant_depth,
            self.latency_samples,
            self.mean_latency_us,
            self.p50_us,
            self.p99_us
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.requests.fetch_add(3, Ordering::Relaxed);
        m.errors.fetch_add(1, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.requests, 3);
        assert_eq!(s.errors, 1);
    }

    #[test]
    fn latency_histogram_quantiles() {
        let m = Metrics::new();
        for _ in 0..90 {
            m.record_latency(Duration::from_micros(100)); // bucket ~[64,128)
        }
        for _ in 0..10 {
            m.record_latency(Duration::from_millis(10)); // ~[8192,16384)
        }
        assert_eq!(m.count(), 100);
        assert!(m.latency_quantile_us(0.5) <= 256);
        assert!(m.latency_quantile_us(0.99) >= 8192);
        let mean = m.mean_latency_us();
        assert!((mean - (90.0 * 100.0 + 10.0 * 10_000.0) / 100.0).abs() < 50.0);
    }

    #[test]
    fn empty_histogram() {
        let m = Metrics::new();
        assert_eq!(m.latency_quantile_us(0.99), 0);
        assert_eq!(m.mean_latency_us(), 0.0);
    }

    #[test]
    fn snapshot_displays() {
        let m = Metrics::new();
        m.record_latency(Duration::from_micros(5));
        let s = m.snapshot().to_string();
        assert!(s.contains("requests=0"));
        assert!(s.contains("latency"));
        assert!(s.contains("window(us=0"));
    }

    #[test]
    fn tenant_depth_gauge_tracks_in_flight_queries() {
        let m = Metrics::new();
        assert_eq!(m.max_tenant_depth(), 0);
        m.tenant_enter(1);
        m.tenant_enter(1);
        m.tenant_enter(2);
        assert_eq!(m.tenant_depth(1), 2);
        assert_eq!(m.tenant_depth(2), 1);
        assert_eq!(m.max_tenant_depth(), 2);
        m.tenant_exit(1);
        m.tenant_exit(2);
        // exit below zero saturates instead of wrapping
        m.tenant_exit(2);
        assert_eq!(m.tenant_depth(1), 1);
        assert_eq!(m.tenant_depth(2), 0);
        assert_eq!(m.max_tenant_depth(), 1);
    }

    #[test]
    fn overload_counters_reach_snapshot_and_display() {
        let m = Metrics::new();
        m.shed.fetch_add(3, Ordering::Relaxed);
        m.deadline_exceeded.fetch_add(2, Ordering::Relaxed);
        m.worker_faults.fetch_add(1, Ordering::Relaxed);
        m.evictions.fetch_add(4, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.shed, 3);
        assert_eq!(s.deadline_exceeded, 2);
        assert_eq!(s.worker_faults, 1);
        assert_eq!(s.evictions, 4);
        let text = s.to_string();
        assert!(text.contains("overload(shed=3 deadlines=2 faults=1 evictions=4 depth=0)"));
    }

    #[test]
    fn quantile_never_returns_sentinel_under_recorder_race() {
        // Regression for the fall-through at the end of the bucket scan:
        // recorders racing the reader could make it return u64::MAX, which
        // flowed into `Error::Overloaded { retry_after_us }` as an absurd
        // backoff hint. The fall-through is now clamped to the top bucket
        // edge, so every value the reader observes is a sane upper bound.
        use std::sync::atomic::AtomicBool;
        use std::sync::Arc;

        let m = Arc::new(Metrics::new());
        let stop = Arc::new(AtomicBool::new(false));
        let recorders: Vec<_> = (0..3)
            .map(|t| {
                let m = Arc::clone(&m);
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    let mut us = 1u64;
                    while !stop.load(Ordering::Relaxed) {
                        m.record_latency(Duration::from_micros(us));
                        us = us.wrapping_mul(7).wrapping_add(t) % 1_000_000 + 1;
                    }
                })
            })
            .collect();
        let top_edge = 1u64 << BUCKETS;
        for _ in 0..20_000 {
            let p99 = m.latency_quantile_us(0.99);
            assert_ne!(p99, u64::MAX, "sentinel leaked out of the bucket scan");
            assert!(p99 <= top_edge, "quantile {p99} above the top bucket edge");
        }
        stop.store(true, Ordering::Relaxed);
        for r in recorders {
            r.join().expect("recorder thread panicked");
        }
        // sanity: with samples present the quantile is still a real edge
        assert!(m.latency_quantile_us(0.5) >= 1);
    }

    #[test]
    fn controller_decisions_accumulate() {
        let m = Metrics::new();
        m.note_window(100, WindowDecision::Widen);
        m.note_window(200, WindowDecision::Widen);
        m.note_window(100, WindowDecision::Shrink);
        m.note_window(50, WindowDecision::SlaClamp);
        m.note_window(50, WindowDecision::Hold);
        let s = m.snapshot();
        assert_eq!(s.window_us, 50, "gauge tracks the last decision");
        assert_eq!(s.window_widen, 2);
        assert_eq!(s.window_shrink, 1);
        assert_eq!(s.window_sla_clamp, 1);
    }
}
