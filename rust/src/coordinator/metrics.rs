//! Service observability: lock-free counters and a log-bucketed latency
//! histogram, in the style of a serving router's metrics endpoint.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use super::controller::WindowDecision;

/// Log₂-bucketed latency histogram: bucket i covers [2^i, 2^(i+1)) µs.
const BUCKETS: usize = 32;

#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub uploads: AtomicU64,
    pub queries: AtomicU64,
    pub errors: AtomicU64,
    pub probes: AtomicU64,
    pub batched: AtomicU64,
    /// Queries answered through shared probe-ladder rounds (coalesced
    /// same-dataset batches — see `service::solve_group`).
    pub coalesced: AtomicU64,
    /// Adaptive-controller gauge: the batching window (µs) currently in
    /// force (last controller decision wins across workers; 0 when idle
    /// or when the service runs a fixed window).
    pub window_us: AtomicU64,
    /// Controller decisions: window widened under observed concurrency.
    pub window_widen: AtomicU64,
    /// Controller decisions: window shrunk toward zero on idle batches.
    pub window_shrink: AtomicU64,
    /// Controller decisions cut short by the latency-SLA budget.
    pub window_sla_clamp: AtomicU64,
    latency_us: [AtomicU64; BUCKETS],
    latency_sum_us: AtomicU64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one latency sample. The service records **one sample per
    /// executed run**: a coalesced group of N queries shares one wall time
    /// and contributes one sample (N samples of the same shared wall would
    /// systematically inflate mean/p50/p99), so `count()` tracks runs
    /// while `queries` tracks queries — under coalescing
    /// `count() ≤ queries` by exactly the shared-run savings.
    pub fn record_latency(&self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let bucket = (63 - us.leading_zeros() as usize).min(BUCKETS - 1);
        self.latency_us[bucket].fetch_add(1, Ordering::Relaxed);
        self.latency_sum_us.fetch_add(us, Ordering::Relaxed);
    }

    /// Number of latency samples (= executed runs; see
    /// [`Metrics::record_latency`]).
    pub fn count(&self) -> u64 {
        self.latency_us.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    pub fn mean_latency_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.latency_sum_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// Approximate quantile from bucket boundaries (upper bound).
    pub fn latency_quantile_us(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * n as f64).ceil() as u64).max(1);
        let mut seen = 0;
        for (i, b) in self.latency_us.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        u64::MAX
    }

    /// Record one adaptive-controller decision: refresh the window gauge
    /// and count the decision kind (see `coordinator::WindowController`).
    pub fn note_window(&self, window_us: u64, decision: WindowDecision) {
        self.window_us.store(window_us, Ordering::Relaxed);
        match decision {
            WindowDecision::Widen => self.window_widen.fetch_add(1, Ordering::Relaxed),
            WindowDecision::Shrink => self.window_shrink.fetch_add(1, Ordering::Relaxed),
            WindowDecision::SlaClamp => self.window_sla_clamp.fetch_add(1, Ordering::Relaxed),
            WindowDecision::Hold => 0,
        };
    }

    pub fn snapshot(&self) -> Snapshot {
        Snapshot {
            requests: self.requests.load(Ordering::Relaxed),
            uploads: self.uploads.load(Ordering::Relaxed),
            queries: self.queries.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            probes: self.probes.load(Ordering::Relaxed),
            batched: self.batched.load(Ordering::Relaxed),
            coalesced: self.coalesced.load(Ordering::Relaxed),
            window_us: self.window_us.load(Ordering::Relaxed),
            window_widen: self.window_widen.load(Ordering::Relaxed),
            window_shrink: self.window_shrink.load(Ordering::Relaxed),
            window_sla_clamp: self.window_sla_clamp.load(Ordering::Relaxed),
            latency_samples: self.count(),
            mean_latency_us: self.mean_latency_us(),
            p50_us: self.latency_quantile_us(0.5),
            p99_us: self.latency_quantile_us(0.99),
        }
    }
}

/// Point-in-time metrics view.
#[derive(Debug, Clone, PartialEq)]
pub struct Snapshot {
    pub requests: u64,
    pub uploads: u64,
    pub queries: u64,
    pub errors: u64,
    pub probes: u64,
    pub batched: u64,
    pub coalesced: u64,
    /// Adaptive batching window currently in force (µs; 0 when idle or
    /// fixed-window).
    pub window_us: u64,
    /// Adaptive-controller widen decisions.
    pub window_widen: u64,
    /// Adaptive-controller shrink decisions.
    pub window_shrink: u64,
    /// Adaptive-controller decisions clamped by the latency SLA.
    pub window_sla_clamp: u64,
    /// Latency samples recorded — one per executed *run*, so strictly
    /// fewer than `queries` when coalescing shares runs.
    pub latency_samples: u64,
    pub mean_latency_us: f64,
    pub p50_us: u64,
    pub p99_us: u64,
}

impl std::fmt::Display for Snapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "requests={} uploads={} queries={} errors={} probes={} batched={} \
             coalesced={} window(us={} widen={} shrink={} clamps={}) \
             latency(runs={} mean={:.0}us p50<{}us p99<{}us)",
            self.requests,
            self.uploads,
            self.queries,
            self.errors,
            self.probes,
            self.batched,
            self.coalesced,
            self.window_us,
            self.window_widen,
            self.window_shrink,
            self.window_sla_clamp,
            self.latency_samples,
            self.mean_latency_us,
            self.p50_us,
            self.p99_us
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.requests.fetch_add(3, Ordering::Relaxed);
        m.errors.fetch_add(1, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.requests, 3);
        assert_eq!(s.errors, 1);
    }

    #[test]
    fn latency_histogram_quantiles() {
        let m = Metrics::new();
        for _ in 0..90 {
            m.record_latency(Duration::from_micros(100)); // bucket ~[64,128)
        }
        for _ in 0..10 {
            m.record_latency(Duration::from_millis(10)); // ~[8192,16384)
        }
        assert_eq!(m.count(), 100);
        assert!(m.latency_quantile_us(0.5) <= 256);
        assert!(m.latency_quantile_us(0.99) >= 8192);
        let mean = m.mean_latency_us();
        assert!((mean - (90.0 * 100.0 + 10.0 * 10_000.0) / 100.0).abs() < 50.0);
    }

    #[test]
    fn empty_histogram() {
        let m = Metrics::new();
        assert_eq!(m.latency_quantile_us(0.99), 0);
        assert_eq!(m.mean_latency_us(), 0.0);
    }

    #[test]
    fn snapshot_displays() {
        let m = Metrics::new();
        m.record_latency(Duration::from_micros(5));
        let s = m.snapshot().to_string();
        assert!(s.contains("requests=0"));
        assert!(s.contains("latency"));
        assert!(s.contains("window(us=0"));
    }

    #[test]
    fn controller_decisions_accumulate() {
        let m = Metrics::new();
        m.note_window(100, WindowDecision::Widen);
        m.note_window(200, WindowDecision::Widen);
        m.note_window(100, WindowDecision::Shrink);
        m.note_window(50, WindowDecision::SlaClamp);
        m.note_window(50, WindowDecision::Hold);
        let s = m.snapshot();
        assert_eq!(s.window_us, 50, "gauge tracks the last decision");
        assert_eq!(s.window_widen, 2);
        assert_eq!(s.window_shrink, 1);
        assert_eq!(s.window_sla_clamp, 1);
    }
}
