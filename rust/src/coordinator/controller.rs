//! Load-adaptive batching window: an SLA-bounded controller that replaces
//! the fixed `batch_window_us` knob.
//!
//! The paper's economics say the window should be *wide* exactly when
//! concurrent traffic is there to coalesce (one shared ladder run amortizes
//! its passes over every caught query) and *zero* when traffic is idle (a
//! lone query gains nothing from being held). A fixed window forces the
//! operator to pick one point on that tradeoff; [`WindowController`] moves
//! along it automatically:
//!
//! - **widen** multiplicatively when the window that just closed caught ≥ 2
//!   coalescable requests against one dataset (observed *same-dataset*
//!   concurrency — the only traffic a wider window can actually merge, and
//!   the only signal that predicts the next window will coalesce too);
//! - **shrink** multiplicatively toward zero on idle windows (≤ 1
//!   coalescable request), bottoming out at exactly zero so steady-idle
//!   traffic pays no latency floor at all;
//! - **clamp** to the latency SLA: the window is added head-of-batch
//!   latency, so it never exceeds `latency_sla − observed p99 run latency`
//!   (and never the hard `max_window`). A backend whose runs alone blow the
//!   SLA gets a zero window — the controller can't fix the backend, but it
//!   refuses to make the miss worse.
//!
//! Every decision is pure state → state on observed counts, so the
//! controller is driven deterministically by the virtual-clock tests in
//! this module and by `coordinator/service.rs`.

use std::time::Duration;

/// Adaptive-window configuration (`[service] latency_sla_us`,
/// `--latency-sla-us`). `CoordinatorOptions::adaptive: Some(..)` turns the
/// controller on; `None` keeps the fixed `batch_window` as a manual
/// override.
#[derive(Debug, Clone, Copy)]
pub struct AdaptiveWindow {
    /// p99 budget for (batch window + run execution): the controller keeps
    /// `window ≤ latency_sla − p99(run)` at every decision.
    pub latency_sla: Duration,
    /// Smallest nonzero window (also the re-opening width after idle, and
    /// the initial width so a fresh service can catch its first burst).
    pub min_window: Duration,
    /// Hard upper bound on the window regardless of SLA headroom.
    pub max_window: Duration,
}

impl Default for AdaptiveWindow {
    fn default() -> Self {
        AdaptiveWindow {
            latency_sla: Duration::from_micros(5_000),
            min_window: Duration::from_micros(50),
            max_window: Duration::from_micros(1_000),
        }
    }
}

/// What one [`WindowController::observe_batch`] call decided (surfaced as
/// metrics counters: `window_widen` / `window_shrink` / `window_sla_clamp`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WindowDecision {
    Widen,
    Shrink,
    /// Already at zero and still idle.
    Hold,
    /// The target width was cut to the SLA/max budget.
    SlaClamp,
}

/// Per-worker adaptive window state; see the module docs for the policy.
#[derive(Debug, Clone)]
pub struct WindowController {
    cfg: AdaptiveWindow,
    window_us: u64,
}

impl WindowController {
    pub fn new(cfg: AdaptiveWindow) -> WindowController {
        let min = cfg.min_window.as_micros() as u64;
        let max = cfg.max_window.as_micros() as u64;
        let sla = cfg.latency_sla.as_micros() as u64;
        // Start at min so the very first burst against a fresh service
        // already has a (tiny) catchment; idle decay closes it promptly.
        WindowController { cfg, window_us: min.min(max).min(sla) }
    }

    /// Current window the next coalescible-headed batch collects over.
    pub fn window(&self) -> Duration {
        Duration::from_micros(self.window_us)
    }

    pub fn window_us(&self) -> u64 {
        self.window_us
    }

    /// Feed one closed batch: `coalescable` is the largest *same-dataset*
    /// count of coalescible requests (probe-based queries / `QueryMany`)
    /// the window caught — only same-dataset requests can share a ladder,
    /// so lone queries of different datasets count as idle traffic — and
    /// `run_p99_us` the observed p99 of run execution latency (the
    /// non-window share of the client's wait). Returns the decision taken.
    pub fn observe_batch(&mut self, coalescable: usize, run_p99_us: u64) -> WindowDecision {
        let sla = self.cfg.latency_sla.as_micros() as u64;
        let max = self.cfg.max_window.as_micros() as u64;
        let min = self.cfg.min_window.as_micros() as u64;
        let budget = sla.saturating_sub(run_p99_us).min(max);
        let (target, decision) = if coalescable >= 2 {
            (self.window_us.saturating_mul(2).max(min), WindowDecision::Widen)
        } else if self.window_us > min {
            (self.window_us / 2, WindowDecision::Shrink)
        } else if self.window_us > 0 {
            (0, WindowDecision::Shrink)
        } else {
            (0, WindowDecision::Hold)
        };
        if target > budget {
            self.window_us = budget;
            WindowDecision::SlaClamp
        } else {
            self.window_us = target;
            decision
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(sla_us: u64, min_us: u64, max_us: u64) -> AdaptiveWindow {
        AdaptiveWindow {
            latency_sla: Duration::from_micros(sla_us),
            min_window: Duration::from_micros(min_us),
            max_window: Duration::from_micros(max_us),
        }
    }

    #[test]
    fn widens_under_a_sustained_arrival_burst() {
        let mut c = WindowController::new(cfg(10_000, 50, 1_000));
        assert_eq!(c.window_us(), 50, "fresh controller opens at min_window");
        let mut widths = vec![c.window_us()];
        let mut decisions = Vec::new();
        for _ in 0..6 {
            decisions.push(c.observe_batch(8, 100));
            widths.push(c.window_us());
        }
        // doubling until the budget cuts the last doublings short
        assert!(decisions[..4].iter().all(|d| *d == WindowDecision::Widen), "{decisions:?}");
        assert!(widths.windows(2).all(|w| w[1] >= w[0]), "{widths:?}");
        assert_eq!(c.window_us(), 1_000, "burst saturates at max_window");
        // further bursts hold the max (widen target is cut by the budget)
        assert_eq!(c.observe_batch(8, 100), WindowDecision::SlaClamp);
        assert_eq!(c.window_us(), 1_000);
    }

    #[test]
    fn decays_to_exactly_zero_when_idle() {
        let mut c = WindowController::new(cfg(10_000, 50, 1_000));
        for _ in 0..6 {
            c.observe_batch(4, 0);
        }
        assert!(c.window_us() > 0);
        let mut steps = 0;
        while c.window_us() > 0 {
            assert_eq!(c.observe_batch(1, 0), WindowDecision::Shrink);
            steps += 1;
            assert!(steps < 32, "idle decay must terminate");
        }
        assert_eq!(c.window_us(), 0);
        // steady idle: zero stays zero, no flapping
        assert_eq!(c.observe_batch(0, 0), WindowDecision::Hold);
        assert_eq!(c.observe_batch(1, 0), WindowDecision::Hold);
        assert_eq!(c.window_us(), 0);
    }

    #[test]
    fn burst_then_silence_then_burst_reopens() {
        let mut c = WindowController::new(cfg(10_000, 50, 1_000));
        for _ in 0..5 {
            c.observe_batch(8, 0);
        }
        assert_eq!(c.window_us(), 1_000);
        while c.window_us() > 0 {
            c.observe_batch(1, 0);
        }
        // a new burst re-opens from zero via min_window
        assert_eq!(c.observe_batch(5, 0), WindowDecision::Widen);
        assert_eq!(c.window_us(), 50);
        assert_eq!(c.observe_batch(5, 0), WindowDecision::Widen);
        assert_eq!(c.window_us(), 100);
    }

    #[test]
    fn simulated_p99_never_exceeds_the_sla() {
        // Time-stepped scenario: arrivals and run p99 both vary; at every
        // step the simulated client p99 (run p99 + window) must respect
        // the budget.
        let sla = 2_000;
        let mut c = WindowController::new(cfg(sla, 50, 10_000));
        let bursts = [8, 8, 1, 8, 8, 8, 1, 1, 8, 8, 8, 8, 1, 8];
        let p99s = [100, 500, 1_500, 1_900, 400, 0, 2_500, 100, 1_999, 2_000, 50, 800, 3_000, 0];
        for (i, (&b, &p99)) in bursts.iter().zip(&p99s).enumerate() {
            c.observe_batch(b, p99);
            assert!(
                c.window_us().saturating_add(p99) <= sla.max(p99),
                "step {i}: window {} + p99 {p99} blows the {sla}us SLA",
                c.window_us()
            );
            assert!(c.window_us() <= sla, "step {i}");
        }
        // runs alone already blow the SLA: the controller zeroes the window
        c.observe_batch(8, sla + 1);
        assert_eq!(c.window_us(), 0);
    }

    #[test]
    fn clamp_is_reported_as_a_clamp() {
        let mut c = WindowController::new(cfg(300, 50, 1_000));
        // widen target 100 fits the 300us budget...
        assert_eq!(c.observe_batch(4, 0), WindowDecision::Widen);
        // ...but with p99 eating the budget the widen is clamped
        assert_eq!(c.observe_batch(4, 250), WindowDecision::SlaClamp);
        assert_eq!(c.window_us(), 50);
        assert_eq!(c.observe_batch(4, 300), WindowDecision::SlaClamp);
        assert_eq!(c.window_us(), 0);
    }
}
