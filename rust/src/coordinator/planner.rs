//! Batch planner: turns one collected window of raw requests into an
//! ordered execution plan.
//!
//! The worker loop used to stable-sort its drained batch by `(kind, id)`,
//! which had two defects: a `Drop` sorted *ahead* of the queries that
//! preceded it (an upload→query→drop sequence drained together failed the
//! query with "unknown dataset"), and a `QueryMany` interleaved between
//! probe-based singles broke the adjacency the coalescing scan keyed on.
//! [`plan_batch`] replaces the sort with an explicit plan:
//!
//! - **Coalesce groups** — probe-based [`Request::Query`] singles and
//!   [`Request::QueryMany`] specs against the *same* dataset merge into one
//!   [`Step::Group`], anchored at the first member's arrival position. The
//!   whole group solves through one shared `multi_order_statistics` ladder,
//!   so every concurrent query of a dataset rides the same fused passes no
//!   matter how its requests interleaved in the window.
//! - **Per-dataset FIFO barriers** — uploads and drops mutate the dataset,
//!   so they execute in arrival order relative to that dataset's queries
//!   and *close* its open group (later probe queries start a fresh group
//!   after the barrier). Download-method queries keep their arrival slot
//!   but do not close the group: they only read, so probe queries on
//!   either side may still share one ladder without changing any answer.
//! - **Shutdown** never jumps the queue: the plan executes fully, then the
//!   worker exits.
//! - **Fair share across tenants** — after the coalesce/barrier pass the
//!   plan is re-ordered round-robin over tenants (in order of first
//!   appearance), so a tenant with 1000 queued queries cannot starve a
//!   tenant with 1: every tenant's head-of-line step executes within one
//!   round. The re-order never violates a dataset's internal order (a
//!   step only moves if every earlier step on its dataset has already
//!   been emitted — a blocked tenant forfeits that round's turn), so
//!   upload/drop barriers and group anchoring stay exactly as planned.
//!   Single-tenant batches come out in arrival order, unchanged.

use std::collections::{HashMap, VecDeque};

use super::service::{DatasetId, Request};

/// One executable step of a planned batch, in execution order.
pub(crate) enum Step {
    Upload {
        id: DatasetId,
        data: std::sync::Arc<Vec<f64>>,
        dtype: crate::select::objective::DType,
        reply: std::sync::mpsc::SyncSender<crate::Result<()>>,
    },
    Drop {
        id: DatasetId,
        reply: Option<std::sync::mpsc::SyncSender<crate::Result<()>>>,
    },
    /// A download-method query (or any query that cannot share ladders).
    Single {
        id: DatasetId,
        k: super::service::KSpec,
        method: crate::select::Method,
        tenant: u32,
        deadline_us: Option<u64>,
        reply: std::sync::mpsc::SyncSender<crate::Result<super::service::QueryResult>>,
    },
    /// Same-dataset probe-based queries unified into one shared-ladder run.
    Group { id: DatasetId, members: Vec<GroupMember> },
}

impl Step {
    fn dataset(&self) -> DatasetId {
        match self {
            Step::Upload { id, .. }
            | Step::Drop { id, .. }
            | Step::Single { id, .. }
            | Step::Group { id, .. } => *id,
        }
    }

    /// Tenant a step is attributed to for fair-share ordering: a group
    /// inherits its anchor (first) member's tenant; uploads and drops are
    /// control-plane traffic attributed to tenant 0.
    fn tenant(&self) -> u32 {
        match self {
            Step::Upload { .. } | Step::Drop { .. } => 0,
            Step::Single { tenant, .. } => *tenant,
            Step::Group { members, .. } => members.first().map_or(0, GroupMember::tenant),
        }
    }
}

/// A member of a coalesce group, in arrival order.
pub(crate) enum GroupMember {
    Single {
        k: super::service::KSpec,
        method: crate::select::Method,
        tenant: u32,
        deadline_us: Option<u64>,
        reply: std::sync::mpsc::SyncSender<crate::Result<super::service::QueryResult>>,
    },
    Many {
        specs: Vec<super::service::KSpec>,
        tenant: u32,
        deadline_us: Option<u64>,
        reply: std::sync::mpsc::SyncSender<crate::Result<Vec<super::service::QueryResult>>>,
    },
}

impl GroupMember {
    /// Number of order-statistic specs this member contributes.
    pub(crate) fn spec_count(&self) -> usize {
        match self {
            GroupMember::Single { .. } => 1,
            GroupMember::Many { specs, .. } => specs.len(),
        }
    }

    pub(crate) fn tenant(&self) -> u32 {
        match self {
            GroupMember::Single { tenant, .. } | GroupMember::Many { tenant, .. } => *tenant,
        }
    }

    pub(crate) fn deadline_us(&self) -> Option<u64> {
        match self {
            GroupMember::Single { deadline_us, .. } | GroupMember::Many { deadline_us, .. } => {
                *deadline_us
            }
        }
    }
}

/// Build the execution plan for one collected batch. Returns the ordered
/// steps and whether a shutdown request was seen (processed *after* every
/// step so queued work is never abandoned).
pub(crate) fn plan_batch(batch: Vec<Request>) -> (Vec<Step>, bool) {
    let mut steps: Vec<Step> = Vec::new();
    // Open coalesce group per dataset: id → index of its Group step.
    let mut open: HashMap<DatasetId, usize> = HashMap::new();
    let mut shutdown = false;
    for req in batch {
        match req {
            Request::Upload { id, data, dtype, reply } => {
                open.remove(&id);
                steps.push(Step::Upload { id, data, dtype, reply });
            }
            Request::Drop { id, reply } => {
                open.remove(&id);
                steps.push(Step::Drop { id, reply });
            }
            Request::Query { id, k, method, tenant, deadline_us, reply }
                if method.needs_download() =>
            {
                steps.push(Step::Single { id, k, method, tenant, deadline_us, reply });
            }
            Request::Query { id, k, method, tenant, deadline_us, reply } => {
                let member = GroupMember::Single { k, method, tenant, deadline_us, reply };
                push_member(&mut steps, &mut open, id, member);
            }
            Request::QueryMany { id, specs, tenant, deadline_us, reply } => {
                let member = GroupMember::Many { specs, tenant, deadline_us, reply };
                push_member(&mut steps, &mut open, id, member);
            }
            Request::Shutdown => shutdown = true,
        }
    }
    (fair_order(steps), shutdown)
}

/// Round-robin the plan across tenants, preserving per-dataset order.
///
/// Tenants take turns in order of first appearance; on its turn a tenant
/// emits its oldest unemitted step *if* every earlier planned step on that
/// step's dataset has been emitted (otherwise it forfeits the turn — the
/// barrier semantics of `plan_batch` are never violated). The globally
/// oldest unemitted step is always eligible, so every round makes
/// progress. With a single tenant (or an empty plan) the input order is
/// returned untouched.
fn fair_order(steps: Vec<Step>) -> Vec<Step> {
    let tenants_of: Vec<u32> = steps.iter().map(Step::tenant).collect();
    let mut tenants: Vec<u32> = Vec::new();
    for &t in &tenants_of {
        if !tenants.contains(&t) {
            tenants.push(t);
        }
    }
    if tenants.len() <= 1 {
        return steps;
    }
    // Per-dataset planned index lists + emit cursors (order preservation).
    let mut per_ds: HashMap<DatasetId, Vec<usize>> = HashMap::new();
    for (i, s) in steps.iter().enumerate() {
        per_ds.entry(s.dataset()).or_default().push(i);
    }
    let mut ds_pos: HashMap<DatasetId, usize> = HashMap::new();
    // Per-tenant FIFO queues of step indices.
    let mut queues: HashMap<u32, VecDeque<usize>> = HashMap::new();
    for (i, &t) in tenants_of.iter().enumerate() {
        queues.entry(t).or_default().push_back(i);
    }
    let mut slots: Vec<Option<Step>> = steps.into_iter().map(Some).collect();
    let mut out: Vec<Step> = Vec::with_capacity(slots.len());
    while out.len() < slots.len() {
        let emitted_before = out.len();
        for &t in &tenants {
            // Tenants were collected from the steps themselves, and a
            // queued index is only taken below after popping it, so both
            // lookups always hit; `continue` keeps the round-robin alive
            // even if that invariant ever breaks.
            let Some(queue) = queues.get_mut(&t) else { continue };
            let Some(&i) = queue.front() else { continue };
            let Some(ds) = slots[i].as_ref().map(|s| s.dataset()) else {
                queue.pop_front();
                continue;
            };
            let pos = ds_pos.entry(ds).or_insert(0);
            if per_ds[&ds][*pos] != i {
                continue; // an earlier step on this dataset is still queued
            }
            queue.pop_front();
            *pos += 1;
            if let Some(step) = slots[i].take() {
                out.push(step);
            }
        }
        if out.len() == emitted_before {
            // The oldest unemitted step is always eligible, so a full
            // no-progress round means the bookkeeping above was violated;
            // flush the remainder in slot order instead of spinning.
            out.extend(slots.iter_mut().filter_map(Option::take));
        }
    }
    out
}

fn push_member(
    steps: &mut Vec<Step>,
    open: &mut HashMap<DatasetId, usize>,
    id: DatasetId,
    member: GroupMember,
) {
    if let Some(&i) = open.get(&id) {
        if let Step::Group { members, .. } = &mut steps[i] {
            members.push(member);
            return;
        }
    }
    open.insert(id, steps.len());
    steps.push(Step::Group { id, members: vec![member] });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::service::{KSpec, QueryResult};
    use crate::select::Method;
    use crate::Result;
    use std::sync::mpsc::sync_channel;

    fn upload(id: DatasetId) -> Request {
        let (reply, _rx) = sync_channel::<Result<()>>(1);
        Request::Upload {
            id,
            data: std::sync::Arc::new(vec![1.0]),
            dtype: crate::select::DType::F64,
            reply,
        }
    }

    fn drop_req(id: DatasetId) -> Request {
        Request::Drop { id, reply: None }
    }

    fn query(id: DatasetId, method: Method) -> Request {
        tenant_query(id, method, 0)
    }

    fn tenant_query(id: DatasetId, method: Method, tenant: u32) -> Request {
        let (reply, _rx) = sync_channel::<Result<QueryResult>>(1);
        Request::Query { id, k: KSpec::Median, method, tenant, deadline_us: None, reply }
    }

    fn query_many(id: DatasetId, n: usize) -> Request {
        let (reply, _rx) = sync_channel::<Result<Vec<QueryResult>>>(1);
        Request::QueryMany {
            id,
            specs: vec![KSpec::Median; n],
            tenant: 0,
            deadline_us: None,
            reply,
        }
    }

    fn kinds(steps: &[Step]) -> Vec<String> {
        steps
            .iter()
            .map(|s| match s {
                Step::Upload { id, .. } => format!("upload:{id}"),
                Step::Drop { id, .. } => format!("drop:{id}"),
                Step::Single { id, .. } => format!("single:{id}"),
                Step::Group { id, members } => {
                    let specs: usize = members.iter().map(|m| m.spec_count()).sum();
                    format!("group:{id}x{specs}")
                }
            })
            .collect()
    }

    #[test]
    fn drop_never_jumps_ahead_of_a_query() {
        // The pre-planner sort keyed Drop at (1, id) ahead of Query at
        // (2, id): this exact batch used to fail the query.
        let (steps, shutdown) =
            plan_batch(vec![upload(1), query(1, Method::Multisection), drop_req(1)]);
        assert_eq!(kinds(&steps), ["upload:1", "group:1x1", "drop:1"]);
        assert!(!shutdown);
    }

    #[test]
    fn singles_and_many_merge_into_one_group() {
        let (steps, _) = plan_batch(vec![
            query(1, Method::Multisection),
            query_many(1, 3),
            query(1, Method::CuttingPlane),
            query(2, Method::Multisection),
        ]);
        assert_eq!(kinds(&steps), ["group:1x5", "group:2x1"]);
    }

    #[test]
    fn download_queries_keep_their_slot_without_closing_the_group() {
        let (steps, _) = plan_batch(vec![
            query(1, Method::Multisection),
            query(1, Method::Quickselect),
            query(1, Method::Multisection),
        ]);
        assert_eq!(kinds(&steps), ["group:1x2", "single:1"]);
    }

    #[test]
    fn upload_and_drop_are_barriers_that_reopen_groups() {
        let (steps, _) = plan_batch(vec![
            query(1, Method::Multisection),
            upload(1),
            query(1, Method::Multisection),
            drop_req(1),
            query(1, Method::Multisection),
        ]);
        assert_eq!(
            kinds(&steps),
            ["group:1x1", "upload:1", "group:1x1", "drop:1", "group:1x1"]
        );
    }

    #[test]
    fn shutdown_runs_after_every_step() {
        let (steps, shutdown) =
            plan_batch(vec![query(1, Method::Multisection), Request::Shutdown, drop_req(1)]);
        assert_eq!(kinds(&steps), ["group:1x1", "drop:1"]);
        assert!(shutdown);
    }

    #[test]
    fn independent_datasets_interleave_in_arrival_order() {
        let (steps, _) = plan_batch(vec![
            query(2, Method::Multisection),
            query(1, Method::Multisection),
            query(2, Method::Multisection),
            drop_req(2),
        ]);
        assert_eq!(kinds(&steps), ["group:2x2", "group:1x1", "drop:2"]);
    }

    #[test]
    fn heavy_tenant_cannot_starve_a_light_one() {
        // Tenant 1 floods four datasets; tenant 2's lone query arrived
        // last but executes in the first round-robin round, not fifth.
        let (steps, _) = plan_batch(vec![
            tenant_query(10, Method::Multisection, 1),
            tenant_query(11, Method::Multisection, 1),
            tenant_query(12, Method::Multisection, 1),
            tenant_query(13, Method::Multisection, 1),
            tenant_query(20, Method::Multisection, 2),
        ]);
        assert_eq!(
            kinds(&steps),
            ["group:10x1", "group:20x1", "group:11x1", "group:12x1", "group:13x1"]
        );
    }

    #[test]
    fn fair_share_keeps_per_dataset_fifo_across_tenants() {
        // Tenant 2's query on dataset 9 sits behind tenant 1's earlier
        // group and the re-upload barrier: round-robin must not hoist it
        // over either — tenants 0 and 2 forfeit turns until dataset 9's
        // earlier steps have been emitted.
        let (steps, _) = plan_batch(vec![
            tenant_query(5, Method::Multisection, 1),
            tenant_query(9, Method::Multisection, 1),
            upload(9),
            tenant_query(9, Method::Multisection, 2),
        ]);
        // Round 1: t1 → group:5; t0 (upload) and t2 both blocked on
        // dataset 9's earlier steps. Round 2: t1 → group:9, unblocking
        // the upload and then tenant 2 within the same round.
        assert_eq!(
            kinds(&steps),
            ["group:5x1", "group:9x1", "upload:9", "group:9x1"]
        );
    }

    #[test]
    fn fair_share_round_robins_multi_step_tenants() {
        let (steps, _) = plan_batch(vec![
            tenant_query(10, Method::Multisection, 1),
            tenant_query(11, Method::Multisection, 1),
            tenant_query(20, Method::Multisection, 2),
            tenant_query(21, Method::Multisection, 2),
        ]);
        assert_eq!(
            kinds(&steps),
            ["group:10x1", "group:20x1", "group:11x1", "group:21x1"]
        );
    }
}
