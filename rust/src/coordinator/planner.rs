//! Batch planner: turns one collected window of raw requests into an
//! ordered execution plan.
//!
//! The worker loop used to stable-sort its drained batch by `(kind, id)`,
//! which had two defects: a `Drop` sorted *ahead* of the queries that
//! preceded it (an upload→query→drop sequence drained together failed the
//! query with "unknown dataset"), and a `QueryMany` interleaved between
//! probe-based singles broke the adjacency the coalescing scan keyed on.
//! [`plan_batch`] replaces the sort with an explicit plan:
//!
//! - **Coalesce groups** — probe-based [`Request::Query`] singles and
//!   [`Request::QueryMany`] specs against the *same* dataset merge into one
//!   [`Step::Group`], anchored at the first member's arrival position. The
//!   whole group solves through one shared `multi_order_statistics` ladder,
//!   so every concurrent query of a dataset rides the same fused passes no
//!   matter how its requests interleaved in the window.
//! - **Per-dataset FIFO barriers** — uploads and drops mutate the dataset,
//!   so they execute in arrival order relative to that dataset's queries
//!   and *close* its open group (later probe queries start a fresh group
//!   after the barrier). Download-method queries keep their arrival slot
//!   but do not close the group: they only read, so probe queries on
//!   either side may still share one ladder without changing any answer.
//! - **Shutdown** never jumps the queue: the plan executes fully, then the
//!   worker exits.

use std::collections::HashMap;

use super::service::{DatasetId, Request};

/// One executable step of a planned batch, in execution order.
pub(crate) enum Step {
    Upload {
        id: DatasetId,
        data: std::sync::Arc<Vec<f64>>,
        dtype: crate::select::objective::DType,
        reply: std::sync::mpsc::SyncSender<crate::Result<()>>,
    },
    Drop {
        id: DatasetId,
        reply: Option<std::sync::mpsc::SyncSender<crate::Result<()>>>,
    },
    /// A download-method query (or any query that cannot share ladders).
    Single {
        id: DatasetId,
        k: super::service::KSpec,
        method: crate::select::Method,
        reply: std::sync::mpsc::SyncSender<crate::Result<super::service::QueryResult>>,
    },
    /// Same-dataset probe-based queries unified into one shared-ladder run.
    Group { id: DatasetId, members: Vec<GroupMember> },
}

/// A member of a coalesce group, in arrival order.
pub(crate) enum GroupMember {
    Single {
        k: super::service::KSpec,
        method: crate::select::Method,
        reply: std::sync::mpsc::SyncSender<crate::Result<super::service::QueryResult>>,
    },
    Many {
        specs: Vec<super::service::KSpec>,
        reply: std::sync::mpsc::SyncSender<crate::Result<Vec<super::service::QueryResult>>>,
    },
}

impl GroupMember {
    /// Number of order-statistic specs this member contributes.
    pub(crate) fn spec_count(&self) -> usize {
        match self {
            GroupMember::Single { .. } => 1,
            GroupMember::Many { specs, .. } => specs.len(),
        }
    }
}

/// Build the execution plan for one collected batch. Returns the ordered
/// steps and whether a shutdown request was seen (processed *after* every
/// step so queued work is never abandoned).
pub(crate) fn plan_batch(batch: Vec<Request>) -> (Vec<Step>, bool) {
    let mut steps: Vec<Step> = Vec::new();
    // Open coalesce group per dataset: id → index of its Group step.
    let mut open: HashMap<DatasetId, usize> = HashMap::new();
    let mut shutdown = false;
    for req in batch {
        match req {
            Request::Upload { id, data, dtype, reply } => {
                open.remove(&id);
                steps.push(Step::Upload { id, data, dtype, reply });
            }
            Request::Drop { id, reply } => {
                open.remove(&id);
                steps.push(Step::Drop { id, reply });
            }
            Request::Query { id, k, method, reply } if method.needs_download() => {
                steps.push(Step::Single { id, k, method, reply });
            }
            Request::Query { id, k, method, reply } => {
                push_member(&mut steps, &mut open, id, GroupMember::Single { k, method, reply });
            }
            Request::QueryMany { id, specs, reply } => {
                push_member(&mut steps, &mut open, id, GroupMember::Many { specs, reply });
            }
            Request::Shutdown => shutdown = true,
        }
    }
    (steps, shutdown)
}

fn push_member(
    steps: &mut Vec<Step>,
    open: &mut HashMap<DatasetId, usize>,
    id: DatasetId,
    member: GroupMember,
) {
    if let Some(&i) = open.get(&id) {
        if let Step::Group { members, .. } = &mut steps[i] {
            members.push(member);
            return;
        }
    }
    open.insert(id, steps.len());
    steps.push(Step::Group { id, members: vec![member] });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::service::{KSpec, QueryResult};
    use crate::select::Method;
    use crate::Result;
    use std::sync::mpsc::sync_channel;

    fn upload(id: DatasetId) -> Request {
        let (reply, _rx) = sync_channel::<Result<()>>(1);
        Request::Upload {
            id,
            data: std::sync::Arc::new(vec![1.0]),
            dtype: crate::select::DType::F64,
            reply,
        }
    }

    fn drop_req(id: DatasetId) -> Request {
        Request::Drop { id, reply: None }
    }

    fn query(id: DatasetId, method: Method) -> Request {
        let (reply, _rx) = sync_channel::<Result<QueryResult>>(1);
        Request::Query { id, k: KSpec::Median, method, reply }
    }

    fn query_many(id: DatasetId, n: usize) -> Request {
        let (reply, _rx) = sync_channel::<Result<Vec<QueryResult>>>(1);
        Request::QueryMany { id, specs: vec![KSpec::Median; n], reply }
    }

    fn kinds(steps: &[Step]) -> Vec<String> {
        steps
            .iter()
            .map(|s| match s {
                Step::Upload { id, .. } => format!("upload:{id}"),
                Step::Drop { id, .. } => format!("drop:{id}"),
                Step::Single { id, .. } => format!("single:{id}"),
                Step::Group { id, members } => {
                    let specs: usize = members.iter().map(|m| m.spec_count()).sum();
                    format!("group:{id}x{specs}")
                }
            })
            .collect()
    }

    #[test]
    fn drop_never_jumps_ahead_of_a_query() {
        // The pre-planner sort keyed Drop at (1, id) ahead of Query at
        // (2, id): this exact batch used to fail the query.
        let (steps, shutdown) =
            plan_batch(vec![upload(1), query(1, Method::Multisection), drop_req(1)]);
        assert_eq!(kinds(&steps), ["upload:1", "group:1x1", "drop:1"]);
        assert!(!shutdown);
    }

    #[test]
    fn singles_and_many_merge_into_one_group() {
        let (steps, _) = plan_batch(vec![
            query(1, Method::Multisection),
            query_many(1, 3),
            query(1, Method::CuttingPlane),
            query(2, Method::Multisection),
        ]);
        assert_eq!(kinds(&steps), ["group:1x5", "group:2x1"]);
    }

    #[test]
    fn download_queries_keep_their_slot_without_closing_the_group() {
        let (steps, _) = plan_batch(vec![
            query(1, Method::Multisection),
            query(1, Method::Quickselect),
            query(1, Method::Multisection),
        ]);
        assert_eq!(kinds(&steps), ["group:1x2", "single:1"]);
    }

    #[test]
    fn upload_and_drop_are_barriers_that_reopen_groups() {
        let (steps, _) = plan_batch(vec![
            query(1, Method::Multisection),
            upload(1),
            query(1, Method::Multisection),
            drop_req(1),
            query(1, Method::Multisection),
        ]);
        assert_eq!(
            kinds(&steps),
            ["group:1x1", "upload:1", "group:1x1", "drop:1", "group:1x1"]
        );
    }

    #[test]
    fn shutdown_runs_after_every_step() {
        let (steps, shutdown) =
            plan_batch(vec![query(1, Method::Multisection), Request::Shutdown, drop_req(1)]);
        assert_eq!(kinds(&steps), ["group:1x1", "drop:1"]);
        assert!(shutdown);
    }

    #[test]
    fn independent_datasets_interleave_in_arrival_order() {
        let (steps, _) = plan_batch(vec![
            query(2, Method::Multisection),
            query(1, Method::Multisection),
            query(2, Method::Multisection),
            drop_req(2),
        ]);
        assert_eq!(kinds(&steps), ["group:2x2", "group:1x1", "drop:2"]);
    }
}
