//! Batch execution machinery shared by every path into a worker: the
//! in-process channel path ([`super::service::SelectionService`]) and the
//! cluster wire path ([`crate::cluster`]) both feed [`worker_loop`], so
//! window collection, batch planning ([`super::planner::plan_batch`]),
//! fused-group execution, deadline enforcement, fault isolation
//! (`catch_unwind` around every backend call) and cost-model accounting
//! live here exactly once. A remote worker is just a
//! [`super::backend::DatasetBackend`] whose probes travel over TCP — it
//! plugs into this loop through the [`BackendFactory`] like any local
//! backend, which is what guarantees the wire path shares admission,
//! planning, and the [`CostModelPool`] with the in-process path by
//! construction rather than by duplication.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{Receiver, SyncSender, TryRecvError};
use std::sync::Arc;
use std::time::Duration;

use super::backend::BackendFactory;
use super::controller::WindowController;
use super::metrics::Metrics;
use super::planner::{plan_batch, GroupMember, Step};
use super::service::{CoordinatorOptions, DatasetId, KSpec, QueryResult, Request};
use crate::select::gpu_model::CostModelPool;
use crate::select::{self, Method};
use crate::testkit::Clock;
use crate::{Error, Result};

/// Collect one batch: the first request is already in `batch`; keep
/// receiving until the window deadline passes (on `clock` time — virtual
/// in tests, so the wait is a parked condvar rather than a sleep), the cap
/// fills, or a shutdown arrives. The caller passes `window = ZERO` for
/// non-coalescible heads, which reduces this to draining what is queued.
fn collect_batch(
    rx: &Receiver<Request>,
    batch: &mut Vec<Request>,
    window: Duration,
    cap: usize,
    clock: &Clock,
) {
    if matches!(batch.last(), Some(Request::Shutdown)) {
        return;
    }
    let deadline = clock.now_us().saturating_add(window.as_micros() as u64);
    while batch.len() < cap {
        match rx.try_recv() {
            Ok(r) => {
                let stop = matches!(r, Request::Shutdown);
                batch.push(r);
                if stop {
                    return;
                }
                continue;
            }
            Err(TryRecvError::Disconnected) => return,
            Err(TryRecvError::Empty) => {}
        }
        if clock.now_us() >= deadline {
            return;
        }
        match clock.recv_deadline(rx, deadline) {
            Ok(r) => {
                let stop = matches!(r, Request::Shutdown);
                batch.push(r);
                if stop {
                    return;
                }
            }
            Err(_) => return, // timeout or disconnect both close the batch
        }
    }
}

pub(crate) fn worker_loop(
    worker_idx: usize,
    rx: Receiver<Request>,
    factory: BackendFactory,
    metrics: Arc<Metrics>,
    opts: CoordinatorOptions,
    clock: Clock,
    pool: Arc<CostModelPool>,
) {
    let mut backend = match factory(worker_idx) {
        Ok(b) => b,
        Err(e) => {
            // Fail every request with a clear error rather than panicking.
            while let Ok(req) = rx.recv() {
                match req {
                    Request::Upload { reply, .. } => {
                        let _ = reply.send(Err(Error::Service(format!(
                            "backend init failed: {e}"
                        ))));
                    }
                    Request::Query { reply, tenant, .. } => {
                        let _ = reply.send(Err(Error::Service(format!(
                            "backend init failed: {e}"
                        ))));
                        metrics.tenant_exit(tenant);
                    }
                    Request::QueryMany { reply, tenant, .. } => {
                        let _ = reply.send(Err(Error::Service(format!(
                            "backend init failed: {e}"
                        ))));
                        metrics.tenant_exit(tenant);
                    }
                    Request::Drop { reply, .. } => {
                        if let Some(reply) = reply {
                            let _ = reply.send(Err(Error::Service(format!(
                                "backend init failed: {e}"
                            ))));
                        }
                    }
                    Request::Shutdown => return,
                }
            }
            return;
        }
    };

    // Load-adaptive batching window (None = fixed `opts.batch_window`).
    let mut controller = opts.adaptive.map(WindowController::new);
    loop {
        let mut batch: Vec<Request> = Vec::new();
        match rx.recv() {
            Ok(r) => batch.push(r),
            Err(_) => break,
        }
        // The window only opens on coalescible heads (holding an
        // upload/drop/download query buys no sharing).
        let head_coalescible = batch.last().map(Request::coalescible).unwrap_or(false);
        let window = if head_coalescible {
            controller.as_ref().map(|c| c.window()).unwrap_or(opts.batch_window)
        } else {
            Duration::ZERO
        };
        collect_batch(&rx, &mut batch, window, opts.batch_cap, &clock);
        if batch.len() > 1 {
            metrics.batched.fetch_add(batch.len() as u64 - 1, Ordering::Relaxed);
        }
        // Feed the controller what its window actually caught, BEFORE
        // executing: replies thus always see the post-decision gauge. The
        // widen signal is the max *same-dataset* coalescible count — only
        // same-dataset requests can share a ladder, so two lone queries of
        // different datasets are idle traffic, not coalescable concurrency.
        if head_coalescible {
            if let Some(c) = controller.as_mut() {
                let mut per_dataset: HashMap<DatasetId, usize> = HashMap::new();
                for id in batch.iter().filter_map(Request::coalescible_dataset) {
                    *per_dataset.entry(id).or_insert(0) += 1;
                }
                let coalescable = per_dataset.values().copied().max().unwrap_or(0);
                let decision = c.observe_batch(coalescable, metrics.latency_quantile_us(0.99));
                metrics.note_window(c.window_us(), decision);
            }
        }
        let (steps, shutdown) = plan_batch(batch);
        for step in steps {
            execute_step(backend.as_mut(), step, &metrics, &pool, &clock);
        }
        // Pressure-driven eviction accounting: backends that cap residency
        // (e.g. [`super::LruBackend`]) report what each batch pushed out.
        // Same fault boundary as every other backend call: a panicking
        // accounting hook must not kill the worker.
        let evicted = catch_unwind(AssertUnwindSafe(|| backend.take_evictions()))
            .unwrap_or_else(|_| {
                metrics.worker_faults.fetch_add(1, Ordering::Relaxed);
                0
            });
        if evicted > 0 {
            metrics.evictions.fetch_add(evicted, Ordering::Relaxed);
        }
        if shutdown {
            break;
        }
    }
}

/// Execute one planned step against the worker's backend. Backend panics
/// are caught here (and in the group path): a fault fails the affected
/// repliers with a typed error and bumps `worker_faults`, but the worker
/// thread — and every other dataset it serves — keeps running.
fn execute_step(
    backend: &mut dyn super::backend::DatasetBackend,
    step: Step,
    metrics: &Metrics,
    pool: &CostModelPool,
    clock: &Clock,
) {
    match step {
        Step::Upload { id, data, dtype, reply } => {
            let r = catch_unwind(AssertUnwindSafe(|| backend.upload(id, &data, dtype)))
                .unwrap_or_else(|p| {
                    metrics.worker_faults.fetch_add(1, Ordering::Relaxed);
                    Err(Error::Service(format!(
                        "worker fault uploading dataset {id}: {}",
                        panic_msg(&p)
                    )))
                });
            if r.is_err() {
                metrics.errors.fetch_add(1, Ordering::Relaxed);
            }
            let _ = reply.send(r);
        }
        Step::Drop { id, reply } => {
            let r = catch_unwind(AssertUnwindSafe(|| backend.drop_dataset(id))).map_err(|p| {
                metrics.worker_faults.fetch_add(1, Ordering::Relaxed);
                Error::Service(format!("worker fault dropping dataset {id}: {}", panic_msg(&p)))
            });
            if let Some(reply) = reply {
                let _ = reply.send(match r {
                    Ok(true) => Ok(()),
                    Ok(false) => Err(Error::Service(format!("unknown dataset {id}"))),
                    Err(e) => Err(e),
                });
            }
        }
        Step::Single { id, k, method, tenant, deadline_us, reply } => {
            answer_single(backend, id, k, method, tenant, deadline_us, &reply, metrics, clock);
        }
        Step::Group { id, members } => execute_group(backend, id, members, metrics, pool, clock),
    }
}

/// Best-effort rendering of a caught panic payload.
pub(crate) fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic".to_string()
    }
}

/// Answer one coalesce group: a lone single runs its requested method; any
/// larger (or `QueryMany`-bearing) group solves through shared fused
/// ladder rounds and replies are distributed back in member order.
fn execute_group(
    backend: &mut dyn super::backend::DatasetBackend,
    id: DatasetId,
    members: Vec<GroupMember>,
    metrics: &Metrics,
    pool: &CostModelPool,
    clock: &Clock,
) {
    if let [GroupMember::Single { .. }] = members.as_slice() {
        if let Some(GroupMember::Single { k, method, tenant, deadline_us, reply }) =
            members.into_iter().next()
        {
            answer_single(backend, id, k, method, tenant, deadline_us, &reply, metrics, clock);
        }
        return;
    }
    let total_specs: usize = members.iter().map(|m| m.spec_count()).sum();
    if total_specs == 0 {
        // empty QueryMany is answered client-side; defensive only
        for m in members {
            if let GroupMember::Many { reply, tenant, .. } = m {
                let _ = reply.send(Ok(Vec::new()));
                metrics.tenant_exit(tenant);
            }
        }
        return;
    }
    let specs: Vec<KSpec> = members
        .iter()
        .flat_map(|m| match m {
            GroupMember::Single { k, .. } => std::slice::from_ref(k),
            GroupMember::Many { specs, .. } => specs.as_slice(),
        })
        .copied()
        .collect();
    // The shared run cancels (at pass boundaries) only when EVERY member
    // carries a deadline — a no-deadline member's work must never be
    // abandoned — and then the latest deadline is the binding one.
    let cancel_at: Option<u64> = members
        .iter()
        .map(|m| m.deadline_us())
        .collect::<Option<Vec<_>>>()
        .and_then(|ds| ds.into_iter().max());
    let t0_us = clock.now_us();
    let mut results =
        catch_unwind(AssertUnwindSafe(|| solve_group(backend, id, &specs, pool, clock, cancel_at)))
            .unwrap_or_else(|p| {
                metrics.worker_faults.fetch_add(1, Ordering::Relaxed);
                let msg = panic_msg(&p);
                specs
                    .iter()
                    .map(|_| {
                        Err(Error::Service(format!("worker fault solving dataset {id}: {msg}")))
                    })
                    .collect()
            });
    // Per-member deadline override: a member whose own deadline passed
    // while the shared run served the rest reports DeadlineExceeded even
    // though its value happened to resolve.
    let now = clock.now_us();
    // Run wall time on the service clock: under a virtual clock this is
    // exactly the virtually-elapsed time, so the p99 feeding the SLA
    // clamp is deterministic (clock_discipline lint rule).
    let wall = Duration::from_micros(now.saturating_sub(t0_us));
    let mut idx = 0usize;
    for m in &members {
        let deadline = m.deadline_us();
        for _ in 0..m.spec_count() {
            if let (Some(d), Some(slot)) = (deadline, results.get_mut(idx)) {
                if now > d && slot.is_ok() {
                    *slot = Err(Error::DeadlineExceeded { late_us: now - d });
                }
            }
            idx += 1;
        }
    }
    if total_specs > 1 {
        metrics.coalesced.fetch_add(total_specs as u64, Ordering::Relaxed);
    }
    account_run(metrics, wall, now, &mut results);
    let mut it = results.into_iter();
    for m in members {
        match m {
            GroupMember::Single { tenant, reply, .. } => {
                let _ = reply.send(it.next().unwrap_or_else(|| mismatch_error(id, metrics)));
                metrics.tenant_exit(tenant);
            }
            GroupMember::Many { specs, tenant, reply, .. } => {
                let mut ok = Vec::with_capacity(specs.len());
                let mut first_err = None;
                for _ in 0..specs.len() {
                    match it.next().unwrap_or_else(|| mismatch_error(id, metrics)) {
                        Ok(q) => ok.push(q),
                        Err(e) => {
                            if first_err.is_none() {
                                first_err = Some(e);
                            }
                        }
                    }
                }
                let _ = reply.send(match first_err {
                    None => Ok(ok),
                    Some(e) => Err(e),
                });
                metrics.tenant_exit(tenant);
            }
        }
    }
}

/// A plan/result count mismatch is a coordinator bug; it must fail the
/// affected repliers with a typed error — never panic the worker and
/// strand every waiting channel on the queue behind it.
fn mismatch_error(id: DatasetId, metrics: &Metrics) -> Result<QueryResult> {
    metrics.errors.fetch_add(1, Ordering::Relaxed);
    Err(Error::Service(format!(
        "internal: plan/result count mismatch for dataset {id}; batch failed"
    )))
}

/// Per-run service accounting shared by every reply path: ONE latency
/// sample per executed run — a coalesced group is one run, so recording
/// its wall time once keeps the histogram a distribution over runs
/// instead of N copies of each shared wall time inflating mean/p50/p99 —
/// then per-query counting: every member counts toward `queries`,
/// contributes its probe share, and is stamped with the run's wall time.
fn account_run(
    metrics: &Metrics,
    wall: Duration,
    now_us: u64,
    results: &mut [Result<QueryResult>],
) {
    metrics.record_latency(wall);
    for r in results.iter_mut() {
        metrics.queries.fetch_add(1, Ordering::Relaxed);
        match r {
            Ok(q) => {
                q.wall = wall;
                q.completed_us = now_us;
                metrics.probes.fetch_add(q.probes, Ordering::Relaxed);
            }
            Err(Error::DeadlineExceeded { .. }) => {
                metrics.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
            }
            Err(_) => {
                metrics.errors.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn answer_single(
    backend: &mut dyn super::backend::DatasetBackend,
    id: DatasetId,
    k: KSpec,
    method: Method,
    tenant: u32,
    deadline_us: Option<u64>,
    reply: &SyncSender<Result<QueryResult>>,
    metrics: &Metrics,
    clock: &Clock,
) {
    let now = clock.now_us();
    let mut out = match deadline_us.filter(|&d| now > d) {
        // expired while queued: answer typed, spend nothing on the device
        Some(d) => Err(Error::DeadlineExceeded { late_us: now - d }),
        None => catch_unwind(AssertUnwindSafe(|| {
            run_query(backend, id, k, method, clock, deadline_us)
        }))
        .unwrap_or_else(|p| {
            metrics.worker_faults.fetch_add(1, Ordering::Relaxed);
            Err(Error::Service(format!("worker fault solving dataset {id}: {}", panic_msg(&p))))
        }),
    };
    let done_us = clock.now_us();
    let wall = Duration::from_micros(done_us.saturating_sub(now));
    account_run(metrics, wall, done_us, std::slice::from_mut(&mut out));
    let _ = reply.send(out);
    metrics.tenant_exit(tenant);
}

/// Answer a group of same-dataset specs through shared fused ladder rounds
/// (`select::multisection::multi_order_statistics`). Per-item results align
/// positionally; an invalid spec fails only its own slot, and the shared
/// reduction count is distributed across the group so per-query `probes`
/// still sum to the real total. The run plans with a snapshot of the
/// shared [`CostModelPool`] (so every worker rides the fleet's pooled
/// measurements) and feeds its pass timing back into the pool.
fn solve_group(
    backend: &mut dyn super::backend::DatasetBackend,
    id: DatasetId,
    specs: &[KSpec],
    pool: &CostModelPool,
    clock: &Clock,
    cancel_at: Option<u64>,
) -> Vec<Result<QueryResult>> {
    let n = match backend.dataset_len(id) {
        Some(n) => n,
        None => {
            // Route the miss through the backend's own evaluator error so
            // capped backends report their typed re-upload contract.
            let msg = match backend.evaluator(id) {
                Err(e) => e.to_string(),
                Ok(_) => format!("unknown dataset {id}"),
            };
            return specs.iter().map(|_| Err(Error::Service(msg.clone()))).collect();
        }
    };
    let ranks: Vec<Result<usize>> = specs.iter().map(|k| k.rank_for(n)).collect();
    let valid: Vec<usize> = ranks.iter().filter_map(|r| r.as_ref().ok().copied()).collect();
    let solved: Result<(Vec<f64>, usize, u64)> = if valid.is_empty() {
        Ok((Vec::new(), 0, 0))
    } else {
        (|| {
            let ev = backend.evaluator(id)?;
            let probes0 = ev.probes();
            // Shared rounds ride the pooled measured pass-cost model
            // (seeded to the evaluator's native ladder width).
            let model = pool.snapshot();
            let opts = select::MultisectOptions::for_evaluator_with(&*ev, &model);
            let t0_us = clock.now_us();
            // Cooperative deadline: polled at every pass boundary, so a
            // run that outlives `cancel_at` stops before its next fused
            // pass rather than running to convergence.
            let mut cancel = || match cancel_at {
                Some(d) => {
                    let now = clock.now_us();
                    if now > d {
                        Some(Error::DeadlineExceeded { late_us: now - d })
                    } else {
                        None
                    }
                }
                None => None,
            };
            let out = select::multisection::multi_order_statistics_cancellable(
                ev, &valid, &opts, &mut cancel,
            )?;
            let reductions = ev.probes() - probes0;
            let wall = Duration::from_micros(clock.now_us().saturating_sub(t0_us));
            pool.observe_run(out.passes, out.rungs, reductions, n, wall);
            Ok((out.values, out.passes, reductions))
        })()
    };
    match solved {
        Ok((values, passes, total)) => {
            let m = valid.len().max(1) as u64;
            let base = total / m;
            let mut rem = total % m;
            let mut vi = 0usize;
            ranks
                .into_iter()
                .map(|r| match r {
                    Err(e) => Err(e),
                    Ok(rank) => {
                        let value = values[vi];
                        vi += 1;
                        let probes = base
                            + if rem > 0 {
                                rem -= 1;
                                1
                            } else {
                                0
                            };
                        Ok(QueryResult {
                            value,
                            k: rank,
                            // what actually ran (see QueryResult::method)
                            method: Method::Multisection,
                            probes,
                            iterations: passes,
                            wall: Duration::ZERO, // filled by account_run
                            completed_us: 0,      // filled by account_run
                        })
                    }
                })
                .collect()
        }
        Err(e) => ranks
            .into_iter()
            .map(|r| match r {
                Err(re) => Err(re),
                // keep the deadline and disconnect types visible to
                // clients — a lost cluster peer must fail only this batch
                // and say so; everything else degrades to a service error
                // string
                Ok(_) => Err(match &e {
                    Error::DeadlineExceeded { late_us } => {
                        Error::DeadlineExceeded { late_us: *late_us }
                    }
                    Error::Disconnected { peer } => Error::Disconnected { peer: peer.clone() },
                    other => Error::Service(other.to_string()),
                }),
            })
            .collect(),
    }
}

fn run_query(
    backend: &mut dyn super::backend::DatasetBackend,
    id: DatasetId,
    k: KSpec,
    method: Method,
    clock: &Clock,
    deadline_us: Option<u64>,
) -> Result<QueryResult> {
    // Resolve the evaluator FIRST so a missing dataset reports the
    // backend's own typed message — a capped backend ([`super::LruBackend`])
    // says "evicted …; re-upload it", the contract clients act on.
    let ev = backend.evaluator(id)?;
    let n = ev.n();
    let rank = k.rank_for(n)?;
    // Cooperative deadline: polled at every pass boundary, so a
    // single-query run that outlives its deadline stops before its next
    // fused reduction instead of running to convergence.
    let mut cancel = || match deadline_us {
        Some(d) => {
            let now = clock.now_us();
            if now > d {
                Some(Error::DeadlineExceeded { late_us: now - d })
            } else {
                None
            }
        }
        None => None,
    };
    let r = select::order_statistic_cancellable(ev, rank, method, &mut cancel)?;
    Ok(QueryResult {
        value: r.value,
        k: rank,
        method,
        probes: r.probes,
        iterations: r.iterations,
        wall: Duration::ZERO, // filled by account_run
        completed_us: 0,      // filled by account_run
    })
}
