//! Transport-agnostic message layer for cluster mode.
//!
//! Typed request/response enums ([`WireRequest`], [`WireResponse`]) plus a
//! length-prefixed JSON codec over [`crate::util::json`]. The same frames
//! travel over the in-process loopback transport and TCP
//! ([`crate::cluster::transport`]); nothing here knows which.
//!
//! ## Wire schema
//!
//! A frame is a 4-byte big-endian payload length followed by that many
//! bytes of UTF-8 JSON. The JSON document is an object tagged by `"op"`
//! (requests) or `"re"` (responses); remaining keys are the variant's
//! fields. Two encoding rules keep the schema lossless over
//! [`crate::util::json`], whose only number type is `f64`:
//!
//! - **`u64` fields travel as decimal strings.** `retry_after_us`,
//!   `late_us`, dataset ids, counts and version counters may exceed 2⁵³,
//!   where `f64` silently rounds; `"18446744073709551615"` does not.
//! - **`f64` fields travel as JSON numbers when finite** (Rust's shortest
//!   round-trip display) **and as the strings `"NaN"`/`"Inf"`/`"-Inf"`
//!   otherwise** — `Neighbors` legitimately carries ±∞ sentinels, and
//!   bare `NaN` is not JSON.
//!
//! Frames larger than [`MAX_FRAME_BYTES`] are rejected on both send and
//! receive: an oversized header is how a corrupt stream or a non-protocol
//! peer shows up, and the guard bounds the allocation a hostile or broken
//! peer can force.
//!
//! Deadlines cross the wire **relative** (`deadline_rel_us`): the
//! coordinator stamps the absolute give-up time on its own service clock
//! at dispatch, so `Overloaded` retry hints and `DeadlineExceeded`
//! lateness are always computed on one clock (the coordinator's) no
//! matter which host executed the passes.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::time::Duration;

use super::service::{DatasetId, KSpec, QueryResult};
use crate::select::objective::{DType, InitStats, IntervalCounts, Neighbors, ProbeStats};
use crate::select::Method;
use crate::util::json::Json;
use crate::{Error, Result};

/// Hard cap on one frame's payload (64 MiB). Upload frames carry whole
/// datasets, so the cap is generous; anything larger is treated as stream
/// corruption rather than trusted as an allocation size.
pub const MAX_FRAME_BYTES: usize = 64 << 20;

// ---------------------------------------------------------------------------
// framing

/// Write one length-prefixed frame. I/O errors are returned raw so the
/// transport can classify them (EOF kinds become
/// [`Error::Disconnected`] with the peer's name attached).
pub fn write_frame(w: &mut dyn Write, payload: &[u8]) -> std::io::Result<()> {
    if payload.len() > MAX_FRAME_BYTES {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame of {} bytes exceeds the {MAX_FRAME_BYTES}-byte cap", payload.len()),
        ));
    }
    let len = payload.len() as u32;
    w.write_all(&len.to_be_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

/// Read one length-prefixed frame (see [`write_frame`]).
pub fn read_frame(r: &mut dyn Read) -> std::io::Result<Vec<u8>> {
    let mut hdr = [0u8; 4];
    r.read_exact(&mut hdr)?;
    let len = u32::from_be_bytes(hdr) as usize;
    if len > MAX_FRAME_BYTES {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame header claims {len} bytes (cap {MAX_FRAME_BYTES}): corrupt stream"),
        ));
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

// ---------------------------------------------------------------------------
// JSON rendering (util::json only parses)

fn render(j: &Json, out: &mut String) {
    match j {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        // Non-finite numbers never occur: f64 fields go through `jf64`,
        // which diverts them to strings. `null` keeps render total anyway.
        Json::Num(x) if x.is_finite() => {
            // Rust's shortest-round-trip float display parses back to the
            // identical f64, and is valid JSON for finite values.
            let mut s = format!("{x}");
            if !s.contains(['.', 'e', 'E']) {
                s.push_str(".0");
            }
            out.push_str(&s);
        }
        Json::Num(_) => out.push_str("null"),
        Json::Str(s) => render_str(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, v) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                render(v, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, v)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                render_str(k, out);
                out.push(':');
                render(v, out);
            }
            out.push('}');
        }
    }
}

fn render_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Serialize a [`Json`] value to compact text (the codec's output side;
/// the input side is [`Json::parse`]).
pub fn to_text(j: &Json) -> String {
    let mut out = String::new();
    render(j, &mut out);
    out
}

// ---------------------------------------------------------------------------
// field codecs

fn jobj(pairs: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in pairs {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

/// `u64` → decimal string (width-lossless; see module docs).
fn ju64(v: u64) -> Json {
    Json::Str(v.to_string())
}

fn u64_of(j: &Json, what: &str) -> Result<u64> {
    j.as_str()
        .map_err(|_| Error::Parse(format!("{what}: u64 fields travel as decimal strings")))?
        .parse::<u64>()
        .map_err(|_| Error::Parse(format!("{what}: not a u64 decimal string")))
}

fn u32_of(j: &Json, what: &str) -> Result<u32> {
    let v = u64_of(j, what)?;
    u32::try_from(v).map_err(|_| Error::Parse(format!("{what}: {v} exceeds u32")))
}

fn usize_of(j: &Json, what: &str) -> Result<usize> {
    let v = u64_of(j, what)?;
    usize::try_from(v).map_err(|_| Error::Parse(format!("{what}: {v} exceeds usize")))
}

/// `f64` → number when finite, `"NaN"`/`"Inf"`/`"-Inf"` otherwise.
fn jf64(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else if v.is_nan() {
        Json::Str("NaN".into())
    } else if v > 0.0 {
        Json::Str("Inf".into())
    } else {
        Json::Str("-Inf".into())
    }
}

fn f64_of(j: &Json, what: &str) -> Result<f64> {
    match j {
        Json::Num(x) => Ok(*x),
        Json::Str(s) => match s.as_str() {
            "NaN" => Ok(f64::NAN),
            "Inf" => Ok(f64::INFINITY),
            "-Inf" => Ok(f64::NEG_INFINITY),
            other => Err(Error::Parse(format!("{what}: unexpected float string {other:?}"))),
        },
        other => Err(Error::Parse(format!("{what}: expected float, got {other:?}"))),
    }
}

fn jf64s(vs: &[f64]) -> Json {
    Json::Arr(vs.iter().map(|&v| jf64(v)).collect())
}

fn f64s_of(j: &Json, what: &str) -> Result<Vec<f64>> {
    j.as_arr()?.iter().map(|v| f64_of(v, what)).collect()
}

fn opt_u64_of(j: &Json, key: &str) -> Result<Option<u64>> {
    j.get_opt(key).map(|v| u64_of(v, key)).transpose()
}

fn opt_str_of(j: &Json, key: &str) -> Result<Option<String>> {
    j.get_opt(key).map(|v| v.as_str().map(str::to_string)).transpose()
}

fn dtype_json(d: DType) -> Json {
    Json::Str(d.name().into())
}

fn dtype_of(j: &Json) -> Result<DType> {
    let s = j.as_str()?;
    DType::from_name(s).ok_or_else(|| Error::Parse(format!("unknown dtype {s:?}")))
}

fn method_of(j: &Json) -> Result<Method> {
    let s = j.as_str()?;
    Method::from_name(s).ok_or_else(|| Error::Parse(format!("unknown method {s:?}")))
}

fn kspec_json(k: &KSpec) -> Json {
    match *k {
        KSpec::Median => jobj(vec![("kind", Json::Str("median".into()))]),
        KSpec::Rank(r) => {
            jobj(vec![("kind", Json::Str("rank".into())), ("k", ju64(r as u64))])
        }
        KSpec::Quantile(q) => {
            jobj(vec![("kind", Json::Str("quantile".into())), ("q", jf64(q))])
        }
    }
}

fn kspec_of(j: &Json) -> Result<KSpec> {
    match j.get("kind")?.as_str()? {
        "median" => Ok(KSpec::Median),
        "rank" => Ok(KSpec::Rank(usize_of(j.get("k")?, "kspec.k")?)),
        "quantile" => Ok(KSpec::Quantile(f64_of(j.get("q")?, "kspec.q")?)),
        other => Err(Error::Parse(format!("unknown kspec kind {other:?}"))),
    }
}

fn probe_stats_json(p: &ProbeStats) -> Json {
    jobj(vec![
        ("s_lo", jf64(p.s_lo)),
        ("s_hi", jf64(p.s_hi)),
        ("c_lt", ju64(p.c_lt)),
        ("c_eq", ju64(p.c_eq)),
        ("c_gt", ju64(p.c_gt)),
    ])
}

fn probe_stats_of(j: &Json) -> Result<ProbeStats> {
    Ok(ProbeStats {
        s_lo: f64_of(j.get("s_lo")?, "probe.s_lo")?,
        s_hi: f64_of(j.get("s_hi")?, "probe.s_hi")?,
        c_lt: u64_of(j.get("c_lt")?, "probe.c_lt")?,
        c_eq: u64_of(j.get("c_eq")?, "probe.c_eq")?,
        c_gt: u64_of(j.get("c_gt")?, "probe.c_gt")?,
    })
}

fn result_json(r: &QueryResult) -> Json {
    jobj(vec![
        ("value", jf64(r.value)),
        ("k", ju64(r.k as u64)),
        ("method", Json::Str(r.method.name().into())),
        ("probes", ju64(r.probes)),
        ("iterations", ju64(r.iterations as u64)),
        ("wall_ns", ju64(r.wall.as_nanos().min(u64::MAX as u128) as u64)),
        ("completed_us", ju64(r.completed_us)),
    ])
}

fn result_of(j: &Json) -> Result<QueryResult> {
    Ok(QueryResult {
        value: f64_of(j.get("value")?, "result.value")?,
        k: usize_of(j.get("k")?, "result.k")?,
        method: method_of(j.get("method")?)?,
        probes: u64_of(j.get("probes")?, "result.probes")?,
        iterations: usize_of(j.get("iterations")?, "result.iterations")?,
        wall: Duration::from_nanos(u64_of(j.get("wall_ns")?, "result.wall_ns")?),
        completed_us: u64_of(j.get("completed_us")?, "result.completed_us")?,
    })
}

// ---------------------------------------------------------------------------
// requests

/// Everything a peer can ask over the wire.
///
/// Client-facing ops (`Upload`…`Shutdown`) are what `cluster client` /
/// the smoke harness sends to a coordinator; `Register`/`Heartbeat` and
/// the `Shard*` family are the coordinator↔worker protocol — each shard
/// op is one `Evaluator` pass primitive, so a remote worker ships the
/// paper's sufficient statistics (sums + counts), never raw data, per
/// fused pass.
#[derive(Debug, Clone, PartialEq)]
pub enum WireRequest {
    /// Worker announces itself (or re-announces after a reconnect). The
    /// coordinator bumps the worker's version counter and replies
    /// [`WireResponse::Registered`].
    Register { worker_id: u32 },
    /// Worker liveness ping on a short-lived side connection.
    Heartbeat { worker_id: u32 },
    /// Client: upload a dataset, receive its id.
    Upload { data: Vec<f64>, dtype: DType },
    /// Client: one order statistic. `deadline_rel_us` is relative to the
    /// coordinator's dispatch (see module docs).
    Query {
        dataset: DatasetId,
        spec: KSpec,
        method: Option<Method>,
        tenant: u32,
        deadline_rel_us: Option<u64>,
    },
    /// Client: many order statistics of one dataset in shared rounds.
    QueryMany {
        dataset: DatasetId,
        specs: Vec<KSpec>,
        method: Option<Method>,
        tenant: u32,
        deadline_rel_us: Option<u64>,
    },
    /// Client: drop a dataset.
    Drop { dataset: DatasetId },
    /// Client: coordinator metrics snapshot (rendered text).
    Stats,
    /// Client: stop the coordinator (and its workers' serve loops).
    Shutdown,
    /// Coordinator→worker: host this shard.
    ShardUpload { dataset: DatasetId, data: Vec<f64>, dtype: DType },
    /// Coordinator→worker: `Evaluator::init_stats` on a shard.
    ShardInit { dataset: DatasetId },
    /// Coordinator→worker: one fused multi-probe ladder pass
    /// (`Evaluator::probe_many`).
    ShardProbe { dataset: DatasetId, ys: Vec<f64> },
    /// Coordinator→worker: `Evaluator::neighbors`.
    ShardNeighbors { dataset: DatasetId, y: f64 },
    /// Coordinator→worker: `Evaluator::interval`.
    ShardInterval { dataset: DatasetId, lo: f64, hi: f64 },
    /// Coordinator→worker: `Evaluator::compact` (the hybrid's copy_if).
    ShardCompact { dataset: DatasetId, lo: f64, hi: f64 },
    /// Coordinator→worker: `Evaluator::download` (host baselines).
    ShardDownload { dataset: DatasetId },
    /// Coordinator→worker: shard length probe.
    ShardLen { dataset: DatasetId },
    /// Coordinator→worker: drop a shard.
    ShardDrop { dataset: DatasetId },
    /// Coordinator→worker: ship-and-reset the worker's locally
    /// accumulated cost-model statistics (see
    /// [`WireResponse::ShardStats`]).
    ShardStatsPull,
}

impl WireRequest {
    /// Encode to one frame payload (JSON bytes, no length prefix).
    pub fn encode(&self) -> Vec<u8> {
        let j = match self {
            WireRequest::Register { worker_id } => jobj(vec![
                ("op", Json::Str("register".into())),
                ("worker_id", ju64(*worker_id as u64)),
            ]),
            WireRequest::Heartbeat { worker_id } => jobj(vec![
                ("op", Json::Str("heartbeat".into())),
                ("worker_id", ju64(*worker_id as u64)),
            ]),
            WireRequest::Upload { data, dtype } => jobj(vec![
                ("op", Json::Str("upload".into())),
                ("data", jf64s(data)),
                ("dtype", dtype_json(*dtype)),
            ]),
            WireRequest::Query { dataset, spec, method, tenant, deadline_rel_us } => {
                let mut pairs = vec![
                    ("op", Json::Str("query".into())),
                    ("dataset", ju64(*dataset)),
                    ("spec", kspec_json(spec)),
                    ("tenant", ju64(*tenant as u64)),
                ];
                if let Some(m) = method {
                    pairs.push(("method", Json::Str(m.name().into())));
                }
                if let Some(d) = deadline_rel_us {
                    pairs.push(("deadline_rel_us", ju64(*d)));
                }
                jobj(pairs)
            }
            WireRequest::QueryMany { dataset, specs, method, tenant, deadline_rel_us } => {
                let mut pairs = vec![
                    ("op", Json::Str("query_many".into())),
                    ("dataset", ju64(*dataset)),
                    ("specs", Json::Arr(specs.iter().map(kspec_json).collect())),
                    ("tenant", ju64(*tenant as u64)),
                ];
                if let Some(m) = method {
                    pairs.push(("method", Json::Str(m.name().into())));
                }
                if let Some(d) = deadline_rel_us {
                    pairs.push(("deadline_rel_us", ju64(*d)));
                }
                jobj(pairs)
            }
            WireRequest::Drop { dataset } => jobj(vec![
                ("op", Json::Str("drop".into())),
                ("dataset", ju64(*dataset)),
            ]),
            WireRequest::Stats => jobj(vec![("op", Json::Str("stats".into()))]),
            WireRequest::Shutdown => jobj(vec![("op", Json::Str("shutdown".into()))]),
            WireRequest::ShardUpload { dataset, data, dtype } => jobj(vec![
                ("op", Json::Str("shard_upload".into())),
                ("dataset", ju64(*dataset)),
                ("data", jf64s(data)),
                ("dtype", dtype_json(*dtype)),
            ]),
            WireRequest::ShardInit { dataset } => jobj(vec![
                ("op", Json::Str("shard_init".into())),
                ("dataset", ju64(*dataset)),
            ]),
            WireRequest::ShardProbe { dataset, ys } => jobj(vec![
                ("op", Json::Str("shard_probe".into())),
                ("dataset", ju64(*dataset)),
                ("ys", jf64s(ys)),
            ]),
            WireRequest::ShardNeighbors { dataset, y } => jobj(vec![
                ("op", Json::Str("shard_neighbors".into())),
                ("dataset", ju64(*dataset)),
                ("y", jf64(*y)),
            ]),
            WireRequest::ShardInterval { dataset, lo, hi } => jobj(vec![
                ("op", Json::Str("shard_interval".into())),
                ("dataset", ju64(*dataset)),
                ("lo", jf64(*lo)),
                ("hi", jf64(*hi)),
            ]),
            WireRequest::ShardCompact { dataset, lo, hi } => jobj(vec![
                ("op", Json::Str("shard_compact".into())),
                ("dataset", ju64(*dataset)),
                ("lo", jf64(*lo)),
                ("hi", jf64(*hi)),
            ]),
            WireRequest::ShardDownload { dataset } => jobj(vec![
                ("op", Json::Str("shard_download".into())),
                ("dataset", ju64(*dataset)),
            ]),
            WireRequest::ShardLen { dataset } => jobj(vec![
                ("op", Json::Str("shard_len".into())),
                ("dataset", ju64(*dataset)),
            ]),
            WireRequest::ShardDrop { dataset } => jobj(vec![
                ("op", Json::Str("shard_drop".into())),
                ("dataset", ju64(*dataset)),
            ]),
            WireRequest::ShardStatsPull => {
                jobj(vec![("op", Json::Str("shard_stats_pull".into()))])
            }
        };
        to_text(&j).into_bytes()
    }

    /// Decode one frame payload.
    pub fn decode(bytes: &[u8]) -> Result<WireRequest> {
        let text = std::str::from_utf8(bytes)
            .map_err(|_| Error::Parse("request frame is not UTF-8".into()))?;
        let j = Json::parse(text)?;
        let dataset = |j: &Json| u64_of(j.get("dataset")?, "dataset");
        match j.get("op")?.as_str()? {
            "register" => {
                Ok(WireRequest::Register { worker_id: u32_of(j.get("worker_id")?, "worker_id")? })
            }
            "heartbeat" => {
                Ok(WireRequest::Heartbeat { worker_id: u32_of(j.get("worker_id")?, "worker_id")? })
            }
            "upload" => Ok(WireRequest::Upload {
                data: f64s_of(j.get("data")?, "data")?,
                dtype: dtype_of(j.get("dtype")?)?,
            }),
            "query" => Ok(WireRequest::Query {
                dataset: dataset(&j)?,
                spec: kspec_of(j.get("spec")?)?,
                method: j.get_opt("method").map(method_of).transpose()?,
                tenant: u32_of(j.get("tenant")?, "tenant")?,
                deadline_rel_us: opt_u64_of(&j, "deadline_rel_us")?,
            }),
            "query_many" => Ok(WireRequest::QueryMany {
                dataset: dataset(&j)?,
                specs: j.get("specs")?.as_arr()?.iter().map(kspec_of).collect::<Result<_>>()?,
                method: j.get_opt("method").map(method_of).transpose()?,
                tenant: u32_of(j.get("tenant")?, "tenant")?,
                deadline_rel_us: opt_u64_of(&j, "deadline_rel_us")?,
            }),
            "drop" => Ok(WireRequest::Drop { dataset: dataset(&j)? }),
            "stats" => Ok(WireRequest::Stats),
            "shutdown" => Ok(WireRequest::Shutdown),
            "shard_upload" => Ok(WireRequest::ShardUpload {
                dataset: dataset(&j)?,
                data: f64s_of(j.get("data")?, "data")?,
                dtype: dtype_of(j.get("dtype")?)?,
            }),
            "shard_init" => Ok(WireRequest::ShardInit { dataset: dataset(&j)? }),
            "shard_probe" => Ok(WireRequest::ShardProbe {
                dataset: dataset(&j)?,
                ys: f64s_of(j.get("ys")?, "ys")?,
            }),
            "shard_neighbors" => Ok(WireRequest::ShardNeighbors {
                dataset: dataset(&j)?,
                y: f64_of(j.get("y")?, "y")?,
            }),
            "shard_interval" => Ok(WireRequest::ShardInterval {
                dataset: dataset(&j)?,
                lo: f64_of(j.get("lo")?, "lo")?,
                hi: f64_of(j.get("hi")?, "hi")?,
            }),
            "shard_compact" => Ok(WireRequest::ShardCompact {
                dataset: dataset(&j)?,
                lo: f64_of(j.get("lo")?, "lo")?,
                hi: f64_of(j.get("hi")?, "hi")?,
            }),
            "shard_download" => Ok(WireRequest::ShardDownload { dataset: dataset(&j)? }),
            "shard_len" => Ok(WireRequest::ShardLen { dataset: dataset(&j)? }),
            "shard_drop" => Ok(WireRequest::ShardDrop { dataset: dataset(&j)? }),
            "shard_stats_pull" => Ok(WireRequest::ShardStatsPull),
            other => Err(Error::Parse(format!("unknown wire request op {other:?}"))),
        }
    }
}

// ---------------------------------------------------------------------------
// responses

/// Every reply a peer can send. Shard replies carry `probes`: the delta
/// of the executing evaluator's reduction counter attributable to the
/// op, which the coordinator-side proxy mirrors into its own counter so
/// fused-reduction accounting is bit-identical to the in-process path.
#[derive(Debug, Clone, PartialEq)]
pub enum WireResponse {
    /// Generic ack (drop, shutdown, heartbeat).
    Ok,
    /// Registration ack: the version the coordinator will tag this
    /// connection's cost statistics with (stale-stat fencing; see
    /// [`WireResponse::ShardStats`]).
    Registered { worker_id: u32, version: u64 },
    /// Upload ack with the assigned dataset id.
    Uploaded { dataset: DatasetId },
    /// One query's answer.
    Result { result: QueryResult },
    /// `query_many` answers, positionally aligned with the specs.
    Results { results: Vec<QueryResult> },
    /// Rendered metrics snapshot.
    StatsText { text: String },
    /// Shard-upload ack: the worker evaluator's shape facts, cached by
    /// the coordinator-side proxy (`n` is the evaluator's count, the
    /// hint sizes fused ladders).
    ShardUploaded { n: u64, dtype: DType, ladder_width_hint: Option<u64>, probes: u64 },
    /// `init_stats` sufficient statistics.
    ShardInit { stats: InitStats, probes: u64 },
    /// One ladder pass's per-rung sufficient statistics.
    ShardProbes { stats: Vec<ProbeStats>, probes: u64 },
    /// `neighbors` reply.
    ShardNeighbors { stats: Neighbors, probes: u64 },
    /// `interval` reply.
    ShardInterval { counts: IntervalCounts, probes: u64 },
    /// `compact`/`download` reply (the only ops that move raw values).
    ShardValues { values: Vec<f64>, probes: u64 },
    /// Shard length.
    ShardLen { n: u64 },
    /// Ship-and-reset cost statistics: the worker's locally accumulated
    /// `PassCostModel` document (its own sufficient-statistic sums since
    /// the previous pull) plus the registration version they were
    /// accumulated under. The coordinator merges them into the
    /// [`crate::coordinator::CostModelPool`] only while the version is
    /// current — a restarted worker re-registers under a bumped version,
    /// so statistics from before the restart are dropped, not merged.
    ShardStats { model_json: String, version: u64 },
    /// Typed failure. `kind` is the [`crate::error::ErrorKind`] kebab
    /// name; the µs payloads of `Overloaded`/`DeadlineExceeded` and the
    /// peer of `Disconnected` survive the round trip losslessly.
    Err {
        kind: String,
        message: String,
        retry_after_us: Option<u64>,
        late_us: Option<u64>,
        peer: Option<String>,
    },
}

impl WireResponse {
    /// Wrap a service error for the wire, preserving the typed payloads.
    pub fn from_error(e: &Error) -> WireResponse {
        WireResponse::Err {
            kind: e.kind().to_string(),
            message: e.to_string(),
            retry_after_us: match e {
                Error::Overloaded { retry_after_us } => Some(*retry_after_us),
                _ => None,
            },
            late_us: match e {
                Error::DeadlineExceeded { late_us } => Some(*late_us),
                _ => None,
            },
            peer: match e {
                Error::Disconnected { peer } => Some(peer.clone()),
                _ => None,
            },
        }
    }

    /// Rebuild the typed error a [`WireResponse::Err`] carries; non-error
    /// responses return `None`.
    pub fn into_error(self) -> Option<Error> {
        let WireResponse::Err { kind, message, retry_after_us, late_us, peer } = self else {
            return None;
        };
        Some(match kind.as_str() {
            "overloaded" => Error::Overloaded { retry_after_us: retry_after_us.unwrap_or(100) },
            "deadline-exceeded" => Error::DeadlineExceeded { late_us: late_us.unwrap_or(0) },
            "disconnected" => Error::Disconnected { peer: peer.unwrap_or(message) },
            "invalid-arg" => Error::InvalidArg(message),
            "parse" => Error::Parse(message),
            "algorithm" => Error::Algorithm(message),
            "xla" => Error::Xla(message),
            "artifact" => Error::Artifact(message),
            "io" => Error::io(
                "remote",
                std::io::Error::new(std::io::ErrorKind::Other, message),
            ),
            _ => Error::Service(message),
        })
    }

    /// Encode to one frame payload (JSON bytes, no length prefix).
    pub fn encode(&self) -> Vec<u8> {
        let j = match self {
            WireResponse::Ok => jobj(vec![("re", Json::Str("ok".into()))]),
            WireResponse::Registered { worker_id, version } => jobj(vec![
                ("re", Json::Str("registered".into())),
                ("worker_id", ju64(*worker_id as u64)),
                ("version", ju64(*version)),
            ]),
            WireResponse::Uploaded { dataset } => jobj(vec![
                ("re", Json::Str("uploaded".into())),
                ("dataset", ju64(*dataset)),
            ]),
            WireResponse::Result { result } => jobj(vec![
                ("re", Json::Str("result".into())),
                ("result", result_json(result)),
            ]),
            WireResponse::Results { results } => jobj(vec![
                ("re", Json::Str("results".into())),
                ("results", Json::Arr(results.iter().map(result_json).collect())),
            ]),
            WireResponse::StatsText { text } => jobj(vec![
                ("re", Json::Str("stats_text".into())),
                ("text", Json::Str(text.clone())),
            ]),
            WireResponse::ShardUploaded { n, dtype, ladder_width_hint, probes } => {
                let mut pairs = vec![
                    ("re", Json::Str("shard_uploaded".into())),
                    ("n", ju64(*n)),
                    ("dtype", dtype_json(*dtype)),
                    ("probes", ju64(*probes)),
                ];
                if let Some(h) = ladder_width_hint {
                    pairs.push(("ladder_width_hint", ju64(*h)));
                }
                jobj(pairs)
            }
            WireResponse::ShardInit { stats, probes } => jobj(vec![
                ("re", Json::Str("shard_init".into())),
                ("min", jf64(stats.min)),
                ("max", jf64(stats.max)),
                ("sum", jf64(stats.sum)),
                ("probes", ju64(*probes)),
            ]),
            WireResponse::ShardProbes { stats, probes } => jobj(vec![
                ("re", Json::Str("shard_probes".into())),
                ("stats", Json::Arr(stats.iter().map(probe_stats_json).collect())),
                ("probes", ju64(*probes)),
            ]),
            WireResponse::ShardNeighbors { stats, probes } => jobj(vec![
                ("re", Json::Str("shard_neighbors".into())),
                ("lower", jf64(stats.lower)),
                ("upper", jf64(stats.upper)),
                ("c_le", ju64(stats.c_le)),
                ("probes", ju64(*probes)),
            ]),
            WireResponse::ShardInterval { counts, probes } => jobj(vec![
                ("re", Json::Str("shard_interval".into())),
                ("c_le", ju64(counts.c_le)),
                ("c_in", ju64(counts.c_in)),
                ("c_ge", ju64(counts.c_ge)),
                ("probes", ju64(*probes)),
            ]),
            WireResponse::ShardValues { values, probes } => jobj(vec![
                ("re", Json::Str("shard_values".into())),
                ("values", jf64s(values)),
                ("probes", ju64(*probes)),
            ]),
            WireResponse::ShardLen { n } => jobj(vec![
                ("re", Json::Str("shard_len".into())),
                ("n", ju64(*n)),
            ]),
            WireResponse::ShardStats { model_json, version } => jobj(vec![
                ("re", Json::Str("shard_stats".into())),
                ("model_json", Json::Str(model_json.clone())),
                ("version", ju64(*version)),
            ]),
            WireResponse::Err { kind, message, retry_after_us, late_us, peer } => {
                let mut pairs = vec![
                    ("re", Json::Str("err".into())),
                    ("kind", Json::Str(kind.clone())),
                    ("message", Json::Str(message.clone())),
                ];
                if let Some(v) = retry_after_us {
                    pairs.push(("retry_after_us", ju64(*v)));
                }
                if let Some(v) = late_us {
                    pairs.push(("late_us", ju64(*v)));
                }
                if let Some(p) = peer {
                    pairs.push(("peer", Json::Str(p.clone())));
                }
                jobj(pairs)
            }
        };
        to_text(&j).into_bytes()
    }

    /// Decode one frame payload.
    pub fn decode(bytes: &[u8]) -> Result<WireResponse> {
        let text = std::str::from_utf8(bytes)
            .map_err(|_| Error::Parse("response frame is not UTF-8".into()))?;
        let j = Json::parse(text)?;
        let probes = |j: &Json| u64_of(j.get("probes")?, "probes");
        match j.get("re")?.as_str()? {
            "ok" => Ok(WireResponse::Ok),
            "registered" => Ok(WireResponse::Registered {
                worker_id: u32_of(j.get("worker_id")?, "worker_id")?,
                version: u64_of(j.get("version")?, "version")?,
            }),
            "uploaded" => {
                Ok(WireResponse::Uploaded { dataset: u64_of(j.get("dataset")?, "dataset")? })
            }
            "result" => Ok(WireResponse::Result { result: result_of(j.get("result")?)? }),
            "results" => Ok(WireResponse::Results {
                results: j.get("results")?.as_arr()?.iter().map(result_of).collect::<Result<_>>()?,
            }),
            "stats_text" => {
                Ok(WireResponse::StatsText { text: j.get("text")?.as_str()?.to_string() })
            }
            "shard_uploaded" => Ok(WireResponse::ShardUploaded {
                n: u64_of(j.get("n")?, "n")?,
                dtype: dtype_of(j.get("dtype")?)?,
                ladder_width_hint: opt_u64_of(&j, "ladder_width_hint")?,
                probes: probes(&j)?,
            }),
            "shard_init" => Ok(WireResponse::ShardInit {
                stats: InitStats {
                    min: f64_of(j.get("min")?, "init.min")?,
                    max: f64_of(j.get("max")?, "init.max")?,
                    sum: f64_of(j.get("sum")?, "init.sum")?,
                },
                probes: probes(&j)?,
            }),
            "shard_probes" => Ok(WireResponse::ShardProbes {
                stats: j
                    .get("stats")?
                    .as_arr()?
                    .iter()
                    .map(probe_stats_of)
                    .collect::<Result<_>>()?,
                probes: probes(&j)?,
            }),
            "shard_neighbors" => Ok(WireResponse::ShardNeighbors {
                stats: Neighbors {
                    lower: f64_of(j.get("lower")?, "neighbors.lower")?,
                    upper: f64_of(j.get("upper")?, "neighbors.upper")?,
                    c_le: u64_of(j.get("c_le")?, "neighbors.c_le")?,
                },
                probes: probes(&j)?,
            }),
            "shard_interval" => Ok(WireResponse::ShardInterval {
                counts: IntervalCounts {
                    c_le: u64_of(j.get("c_le")?, "interval.c_le")?,
                    c_in: u64_of(j.get("c_in")?, "interval.c_in")?,
                    c_ge: u64_of(j.get("c_ge")?, "interval.c_ge")?,
                },
                probes: probes(&j)?,
            }),
            "shard_values" => Ok(WireResponse::ShardValues {
                values: f64s_of(j.get("values")?, "values")?,
                probes: probes(&j)?,
            }),
            "shard_len" => Ok(WireResponse::ShardLen { n: u64_of(j.get("n")?, "n")? }),
            "shard_stats" => Ok(WireResponse::ShardStats {
                model_json: j.get("model_json")?.as_str()?.to_string(),
                version: u64_of(j.get("version")?, "version")?,
            }),
            "err" => Ok(WireResponse::Err {
                kind: j.get("kind")?.as_str()?.to_string(),
                message: j.get("message")?.as_str()?.to_string(),
                retry_after_us: opt_u64_of(&j, "retry_after_us")?,
                late_us: opt_u64_of(&j, "late_us")?,
                peer: opt_str_of(&j, "peer")?,
            }),
            other => Err(Error::Parse(format!("unknown wire response tag {other:?}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::Rng;

    fn rt_req(r: WireRequest) {
        let bytes = r.encode();
        let back = WireRequest::decode(&bytes).expect("request decodes");
        assert_eq!(back, r, "payload: {}", String::from_utf8_lossy(&bytes));
    }

    fn rt_resp(r: WireResponse) {
        let bytes = r.encode();
        let back = WireResponse::decode(&bytes).expect("response decodes");
        assert_eq!(back, r, "payload: {}", String::from_utf8_lossy(&bytes));
    }

    #[test]
    fn every_request_variant_roundtrips() {
        rt_req(WireRequest::Register { worker_id: 7 });
        rt_req(WireRequest::Heartbeat { worker_id: u32::MAX });
        rt_req(WireRequest::Upload { data: vec![1.5, -0.25, 1e300], dtype: DType::F64 });
        rt_req(WireRequest::Query {
            dataset: u64::MAX,
            spec: KSpec::Median,
            method: None,
            tenant: 0,
            deadline_rel_us: None,
        });
        rt_req(WireRequest::Query {
            dataset: 3,
            spec: KSpec::Rank(usize::MAX >> 1),
            method: Some(Method::Hybrid),
            tenant: 42,
            deadline_rel_us: Some(u64::MAX),
        });
        rt_req(WireRequest::QueryMany {
            dataset: 9,
            specs: vec![KSpec::Median, KSpec::Quantile(0.25), KSpec::Rank(1)],
            method: Some(Method::Multisection),
            tenant: 1,
            deadline_rel_us: Some(200),
        });
        rt_req(WireRequest::Drop { dataset: 11 });
        rt_req(WireRequest::Stats);
        rt_req(WireRequest::Shutdown);
        rt_req(WireRequest::ShardUpload {
            dataset: 2,
            data: vec![0.1, 0.2, 0.3],
            dtype: DType::F32,
        });
        rt_req(WireRequest::ShardInit { dataset: 2 });
        rt_req(WireRequest::ShardProbe { dataset: 2, ys: vec![-1.0, 0.0, 1.0] });
        rt_req(WireRequest::ShardNeighbors { dataset: 2, y: 0.125 });
        rt_req(WireRequest::ShardInterval { dataset: 2, lo: -1.0, hi: 1.0 });
        rt_req(WireRequest::ShardCompact { dataset: 2, lo: -0.5, hi: 0.5 });
        rt_req(WireRequest::ShardDownload { dataset: 2 });
        rt_req(WireRequest::ShardLen { dataset: 2 });
        rt_req(WireRequest::ShardDrop { dataset: 2 });
        rt_req(WireRequest::ShardStatsPull);
    }

    #[test]
    fn every_response_variant_roundtrips() {
        rt_resp(WireResponse::Ok);
        rt_resp(WireResponse::Registered { worker_id: 1, version: u64::MAX });
        rt_resp(WireResponse::Uploaded { dataset: 17 });
        rt_resp(WireResponse::Result {
            result: QueryResult {
                value: -0.015625,
                k: 500,
                method: Method::Multisection,
                probes: 21,
                iterations: 3,
                wall: Duration::from_nanos(123_456_789),
                completed_us: 42,
            },
        });
        rt_resp(WireResponse::Results { results: vec![] });
        rt_resp(WireResponse::StatsText { text: "requests=8\nerrors=0 \"quoted\"".into() });
        rt_resp(WireResponse::ShardUploaded {
            n: 1 << 40,
            dtype: DType::F64,
            ladder_width_hint: Some(15),
            probes: 0,
        });
        rt_resp(WireResponse::ShardUploaded {
            n: 3,
            dtype: DType::F32,
            ladder_width_hint: None,
            probes: 0,
        });
        rt_resp(WireResponse::ShardInit {
            stats: InitStats { min: -3.5, max: 7.25, sum: 11.0 },
            probes: 1,
        });
        rt_resp(WireResponse::ShardProbes {
            stats: vec![
                ProbeStats { s_lo: 1.0, s_hi: 2.0, c_lt: 3, c_eq: 0, c_gt: u64::MAX },
                ProbeStats { s_lo: -1.0, s_hi: 0.0, c_lt: 0, c_eq: 1, c_gt: 0 },
            ],
            probes: 1,
        });
        // ±∞ sentinels are the Neighbors contract — must survive JSON
        rt_resp(WireResponse::ShardNeighbors {
            stats: Neighbors { lower: f64::NEG_INFINITY, upper: f64::INFINITY, c_le: 0 },
            probes: 1,
        });
        rt_resp(WireResponse::ShardInterval {
            counts: IntervalCounts { c_le: 1, c_in: 2, c_ge: 3 },
            probes: 1,
        });
        rt_resp(WireResponse::ShardValues { values: vec![0.5, 0.25], probes: 1 });
        rt_resp(WireResponse::ShardLen { n: 4096 });
        rt_resp(WireResponse::ShardStats {
            model_json: "{\"schema\":\"cp-select/cost_model/v1\"}".into(),
            version: 3,
        });
        rt_resp(WireResponse::Err {
            kind: "service".into(),
            message: "boom".into(),
            retry_after_us: None,
            late_us: None,
            peer: None,
        });
    }

    #[test]
    fn nan_floats_survive_the_codec() {
        let bytes = WireRequest::ShardNeighbors { dataset: 1, y: f64::NAN }.encode();
        let back = WireRequest::decode(&bytes).expect("decodes");
        match back {
            WireRequest::ShardNeighbors { dataset: 1, y } => assert!(y.is_nan()),
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn prop_error_us_payloads_survive_roundtrip_without_width_loss() {
        // Satellite bugfix pin: `retry_after_us`/`late_us` are u64 and may
        // exceed 2^53, where a JSON double silently rounds. The codec
        // ships them as decimal strings, so every u64 — including
        // u64::MAX — must come back bit-identical, with the error type
        // preserved.
        let mut rng = Rng::seeded(505);
        let mut cases: Vec<u64> = (0..200).map(|_| rng.next_u64()).collect();
        cases.extend([0, 1, (1 << 53) - 1, 1 << 53, (1 << 53) + 1, u64::MAX - 1, u64::MAX]);
        for us in cases {
            let e = Error::Overloaded { retry_after_us: us };
            let bytes = WireResponse::from_error(&e).encode();
            let back = WireResponse::decode(&bytes)
                .expect("decodes")
                .into_error()
                .expect("is an error");
            match back {
                Error::Overloaded { retry_after_us } => assert_eq!(retry_after_us, us),
                other => panic!("overloaded became {other:?}"),
            }

            let e = Error::DeadlineExceeded { late_us: us };
            let bytes = WireResponse::from_error(&e).encode();
            let back = WireResponse::decode(&bytes)
                .expect("decodes")
                .into_error()
                .expect("is an error");
            match back {
                Error::DeadlineExceeded { late_us } => assert_eq!(late_us, us),
                other => panic!("deadline became {other:?}"),
            }
        }
    }

    #[test]
    fn disconnected_error_keeps_its_peer_across_the_wire() {
        let e = Error::Disconnected { peer: "worker-2@127.0.0.1:7171".into() };
        let back = WireResponse::decode(&WireResponse::from_error(&e).encode())
            .expect("decodes")
            .into_error()
            .expect("is an error");
        match back {
            Error::Disconnected { peer } => assert_eq!(peer, "worker-2@127.0.0.1:7171"),
            other => panic!("disconnected became {other:?}"),
        }
    }

    #[test]
    fn prop_f64_numbers_roundtrip_bit_exact() {
        // shortest-display + strict parse must be the identity on finite
        // doubles, including subnormals and huge magnitudes
        let mut rng = Rng::seeded(506);
        let mut cases: Vec<f64> = Vec::new();
        for _ in 0..300 {
            let bits = rng.next_u64();
            let x = f64::from_bits(bits);
            if x.is_finite() {
                cases.push(x);
            }
        }
        cases.extend([0.0, -0.0, f64::MIN_POSITIVE, f64::MAX, f64::MIN, 5e-324, 0.1, 1e300]);
        for x in cases {
            let bytes = WireRequest::ShardNeighbors { dataset: 0, y: x }.encode();
            match WireRequest::decode(&bytes).expect("decodes") {
                WireRequest::ShardNeighbors { y, .. } => {
                    assert_eq!(y.to_bits(), x.to_bits(), "{x:?} mangled by the codec")
                }
                other => panic!("wrong variant: {other:?}"),
            }
        }
    }

    #[test]
    fn frames_roundtrip_and_guard_against_corruption() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").expect("write");
        write_frame(&mut buf, b"").expect("write empty");
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r).expect("frame 1"), b"hello");
        assert_eq!(read_frame(&mut r).expect("frame 2"), b"");
        assert!(read_frame(&mut r).is_err(), "EOF must error, not hang");

        // oversized header: rejected before allocating
        let mut huge = Vec::new();
        huge.extend_from_slice(&(u32::MAX).to_be_bytes());
        huge.extend_from_slice(b"x");
        let err = read_frame(&mut &huge[..]).expect_err("oversized header");
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);

        // truncated payload: read_exact reports UnexpectedEof
        let mut trunc = Vec::new();
        trunc.extend_from_slice(&8u32.to_be_bytes());
        trunc.extend_from_slice(b"abc");
        let err = read_frame(&mut &trunc[..]).expect_err("truncated payload");
        assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof);
    }

    #[test]
    fn garbage_payloads_decode_to_parse_errors() {
        for bad in [
            &b"\xff\xfe"[..],
            b"not json",
            b"{}",
            b"{\"op\":\"no_such_op\"}",
            b"{\"op\":\"query\",\"dataset\":7}",
            b"{\"op\":\"query\",\"dataset\":\"7\",\"spec\":{\"kind\":\"median\"},\"tenant\":\"0\",\"deadline_rel_us\":\"-1\"}",
        ] {
            let e = WireRequest::decode(bad).expect_err("must not decode");
            assert!(matches!(e, Error::Parse(_)), "{e:?}");
        }
        assert!(WireResponse::decode(b"{\"re\":\"nope\"}").is_err());
    }
}
