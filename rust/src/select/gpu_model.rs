//! Cost model for "quickselect on GPU as a single thread" (paper §II,
//! alternative 3; Tables I–II row "Quickselect (on GPU)").
//!
//! The paper runs quickselect in one CUDA thread to avoid the device→host
//! transfer; a single GPU core is ~30× slower than a CPU core on this
//! branchy serial workload (Tables I–II: 21 951 ms vs 708 ms at n = 2²⁵
//! float). Our substrate has no such core, so we *model* it: run the real
//! quickselect, then scale the measured time by a calibrated slowdown
//! constant (documented substitution, DESIGN.md §7). The returned value is
//! exact; only the reported time is modeled.

use std::time::Duration;

use super::quickselect::quickselect;

/// Slowdown calibrated from the paper's own measurements:
/// 21951.0 / 708.1 ≈ 31 (f32, n = 2²⁵).
pub const PAPER_SLOWDOWN: f64 = 31.0;

#[derive(Debug, Clone, Copy)]
pub struct GpuQuickselectModel {
    pub slowdown: f64,
}

impl Default for GpuQuickselectModel {
    fn default() -> Self {
        GpuQuickselectModel { slowdown: PAPER_SLOWDOWN }
    }
}

#[derive(Debug, Clone, Copy)]
pub struct ModeledRun {
    pub value: f64,
    /// Actual wall time of the host quickselect.
    pub measured: Duration,
    /// Modeled single-GPU-thread time = measured × slowdown.
    pub modeled: Duration,
}

impl GpuQuickselectModel {
    pub fn run(&self, data: &[f64], k: usize) -> ModeledRun {
        let mut scratch = data.to_vec();
        let t0 = std::time::Instant::now();
        let value = quickselect(&mut scratch, k);
        let measured = t0.elapsed();
        ModeledRun {
            value,
            measured,
            modeled: measured.mul_f64(self.slowdown),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::{sorted_median, Distribution, Rng};

    #[test]
    fn value_is_exact_time_is_scaled() {
        let mut rng = Rng::seeded(95);
        let data = Distribution::Normal.sample_vec(&mut rng, 10_000);
        let m = GpuQuickselectModel::default();
        let run = m.run(&data, 5_000);
        assert_eq!(run.value, sorted_median(&data));
        let ratio = run.modeled.as_secs_f64() / run.measured.as_secs_f64().max(1e-12);
        assert!((ratio - PAPER_SLOWDOWN).abs() < 0.5, "ratio {ratio}");
    }

    #[test]
    fn custom_slowdown() {
        let data = [5.0, 1.0, 3.0];
        let m = GpuQuickselectModel { slowdown: 2.0 };
        let run = m.run(&data, 2);
        assert_eq!(run.value, 3.0);
        assert!(run.modeled >= run.measured);
    }
}
