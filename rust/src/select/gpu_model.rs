//! Cost models for the GPU substrate.
//!
//! Two distinct models live here:
//!
//! - [`GpuQuickselectModel`] — the paper's "quickselect on GPU as a single
//!   thread" (§II alternative 3; Tables I–II row "Quickselect (on GPU)").
//!   The paper runs quickselect in one CUDA thread to avoid the
//!   device→host transfer; a single GPU core is ~30× slower than a CPU
//!   core on this branchy serial workload (Tables I–II: 21 951 ms vs
//!   708 ms at n = 2²⁵ float). Our substrate has no such core, so we
//!   *model* it: run the real quickselect, then scale the measured time by
//!   a calibrated slowdown constant (documented substitution, DESIGN.md
//!   §7). The returned value is exact; only the reported time is modeled.
//! - [`PassCostModel`] — pass cost vs ladder width, the knob behind
//!   "probes per pass". It is seeded from the committed
//!   `BENCH_select.json` trajectory and refined online from measured run
//!   timings, and [`crate::select::MultisectOptions::for_evaluator`]
//!   consults it so the ladder width is chosen by cost rather than by a
//!   hard-coded constant.

use std::time::Duration;

use super::quickselect::quickselect;

/// Widest ladder the pass planner will consider on an evaluator with no
/// native width limit (the host oracle sweeps any width in one pass; the
/// returns of an even wider ladder shrink like `1/ln p`).
pub const MAX_PLANNED_WIDTH: usize = 64;

/// Linear pass-cost model: one fused pass over `n` elements with a
/// `p`-rung ladder costs `(a + b·p)·n` seconds, `a` the fixed per-element
/// sweep cost (read + bin bookkeeping) and `b` the incremental per-probe
/// compare cost. Selection spends `log_{p+1}(range/tol)` passes, so the
/// total cost of a run is proportional to `(a + b·p)/ln(p + 1)` and the
/// best width is its integer argmin — wider ladders buy geometrically
/// fewer passes until the `b·p` term wins.
///
/// **Seeding.** The committed `BENCH_select.json` trajectory records the
/// width-15 ladder resolving 2²² elements in 10 passes (21 fused
/// reductions vs bisection's 52 at width 1) — the width the repo's
/// measured trajectory was recorded at. Absent local measurements the
/// model is seeded to reproduce exactly that choice: the indifference
/// condition `d/dp [(a + b·p)/ln(p+1)] = 0` at `p* = 15` fixes
/// `a/b = (p*+1)·ln(p*+1) − p* ≈ 29.36`, and only the ratio matters for
/// the argmin.
///
/// **Online refinement.** Each coordinator worker owns a model and feeds
/// it one sample per shared-ladder run ([`PassCostModel::observe_run`]):
/// a run with `P` ladder passes evaluating `G` rungs in total (the solver
/// reports the *actual* count — bracket dedup and budget splitting make it
/// differ from `P × planned width`) plus `R − P` single-probe reductions
/// over `n` elements predicts `wall = a·(R·n) + b·((G + R − P)·n)`, a
/// two-regressor linear system whose normal equations accumulate in O(1)
/// space. The fit replaces the seed only when it is *identifiable*: the
/// probes-per-reduction ratio must genuinely vary across samples (a
/// worker that always runs the same ladder shape cannot separate sweep
/// cost from probe cost, and fitting its timing noise could lock the
/// planner into a bad width), the normal equations must be well
/// conditioned, and the coefficients must be physical (positive sweep
/// cost); otherwise the seed holds.
#[derive(Debug, Clone)]
pub struct PassCostModel {
    // Normal-equation accumulators for wall = a·xa + b·xb over observed
    // runs, where xa = element-passes and xb = element-probes.
    s_aa: f64,
    s_ab: f64,
    s_bb: f64,
    s_ay: f64,
    s_by: f64,
    // Identifiability tracking: spread of the xb/xa ratio across samples.
    ratio_lo: f64,
    ratio_hi: f64,
    samples: u64,
    seed_sweep: f64,
    seed_per_probe: f64,
}

/// Samples required before the fitted coefficients replace the seed.
const MIN_FIT_SAMPLES: u64 = 8;

impl Default for PassCostModel {
    fn default() -> Self {
        Self::seeded()
    }
}

impl PassCostModel {
    /// Model seeded from the committed `BENCH_select.json` trajectory (see
    /// the type docs): argmin width 15 on a width-unlimited evaluator.
    pub fn seeded() -> Self {
        let p_star = 15.0f64;
        let seed_sweep = 1.0e-9; // ~1 ns/element full sweep; scale cancels
        let seed_per_probe = seed_sweep / ((p_star + 1.0) * (p_star + 1.0).ln() - p_star);
        PassCostModel {
            s_aa: 0.0,
            s_ab: 0.0,
            s_bb: 0.0,
            s_ay: 0.0,
            s_by: 0.0,
            ratio_lo: f64::INFINITY,
            ratio_hi: 0.0,
            samples: 0,
            seed_sweep,
            seed_per_probe,
        }
    }

    /// Number of runs observed so far.
    pub fn samples(&self) -> u64 {
        self.samples
    }

    /// Record one measured run: `ladder_passes` fused passes evaluating
    /// `ladder_rungs` probe rungs in total (the solver's actual count, see
    /// `MultiOutcome::rungs`) plus (`total_reductions − ladder_passes`)
    /// single-probe reductions, all over `n` elements, in `wall` seconds.
    pub fn observe_run(
        &mut self,
        ladder_passes: usize,
        ladder_rungs: u64,
        total_reductions: u64,
        n: usize,
        wall: Duration,
    ) {
        if n == 0 || total_reductions == 0 || ladder_passes as u64 > total_reductions {
            return;
        }
        let r = total_reductions as f64;
        let p = ladder_passes as f64;
        let xa = r * n as f64;
        let xb = (ladder_rungs as f64 + (r - p)) * n as f64;
        let y = wall.as_secs_f64();
        self.s_aa += xa * xa;
        self.s_ab += xa * xb;
        self.s_bb += xb * xb;
        self.s_ay += xa * y;
        self.s_by += xb * y;
        let ratio = xb / xa;
        self.ratio_lo = self.ratio_lo.min(ratio);
        self.ratio_hi = self.ratio_hi.max(ratio);
        self.samples += 1;
    }

    /// Minimum spread of the probes-per-reduction ratio across samples
    /// before the fit is considered identifiable (below it, timing noise
    /// rather than width variation would drive the coefficients).
    const MIN_RATIO_SPREAD: f64 = 1.5;

    /// `(sweep, per_probe)` coefficients: the regression fit when it is
    /// identifiable and well conditioned, the seed otherwise.
    fn coeffs(&self) -> (f64, f64) {
        let identifiable = self.ratio_hi > self.ratio_lo * Self::MIN_RATIO_SPREAD;
        if self.samples >= MIN_FIT_SAMPLES && identifiable {
            let det = self.s_aa * self.s_bb - self.s_ab * self.s_ab;
            if det > 1e-9 * self.s_aa * self.s_bb {
                let a = (self.s_bb * self.s_ay - self.s_ab * self.s_by) / det;
                let b = (self.s_aa * self.s_by - self.s_ab * self.s_ay) / det;
                if a > 0.0 && b >= 0.0 {
                    return (a, b);
                }
            }
        }
        (self.seed_sweep, self.seed_per_probe)
    }

    /// Modeled seconds for one `p`-rung pass over `n` elements.
    pub fn pass_cost(&self, p: usize, n: usize) -> f64 {
        let (a, b) = self.coeffs();
        (a + b * p.max(1) as f64) * n as f64
    }

    /// Cost-model-chosen probes per pass, minimizing
    /// `per-pass cost / ln(p + 1)` — total run cost up to the
    /// range-resolution constant shared by every width.
    ///
    /// `native` is the evaluator's fused-ladder width hint. When present
    /// the hint *is* the plan: narrower ladders pad to the bucket (same
    /// launch, less shrink), and exceeding it chunks into `m` launches
    /// whose single ladder shrinks the bracket by `ln(m·w + 1)` — strictly
    /// less than the `m·ln(w + 1)` that `m` sequential *adaptive* passes
    /// buy for the same launch budget. When absent, every width up to
    /// [`MAX_PLANNED_WIDTH`] costs its linear model price and the argmin
    /// is taken over all of them.
    pub fn best_width(&self, native: Option<usize>) -> usize {
        if let Some(w) = native {
            return w.max(1);
        }
        let (a, b) = self.coeffs();
        let score = |p: usize| (a + b * p as f64) / (p as f64 + 1.0).ln();
        (1..=MAX_PLANNED_WIDTH)
            .min_by(|&p1, &p2| score(p1).total_cmp(&score(p2)))
            .unwrap_or(15)
    }
}

/// Slowdown calibrated from the paper's own measurements:
/// 21951.0 / 708.1 ≈ 31 (f32, n = 2²⁵).
pub const PAPER_SLOWDOWN: f64 = 31.0;

#[derive(Debug, Clone, Copy)]
pub struct GpuQuickselectModel {
    pub slowdown: f64,
}

impl Default for GpuQuickselectModel {
    fn default() -> Self {
        GpuQuickselectModel { slowdown: PAPER_SLOWDOWN }
    }
}

#[derive(Debug, Clone, Copy)]
pub struct ModeledRun {
    pub value: f64,
    /// Actual wall time of the host quickselect.
    pub measured: Duration,
    /// Modeled single-GPU-thread time = measured × slowdown.
    pub modeled: Duration,
}

impl GpuQuickselectModel {
    pub fn run(&self, data: &[f64], k: usize) -> ModeledRun {
        let mut scratch = data.to_vec();
        let t0 = std::time::Instant::now();
        let value = quickselect(&mut scratch, k);
        let measured = t0.elapsed();
        ModeledRun {
            value,
            measured,
            modeled: measured.mul_f64(self.slowdown),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::{sorted_median, Distribution, Rng};

    #[test]
    fn value_is_exact_time_is_scaled() {
        let mut rng = Rng::seeded(95);
        let data = Distribution::Normal.sample_vec(&mut rng, 10_000);
        let m = GpuQuickselectModel::default();
        let run = m.run(&data, 5_000);
        assert_eq!(run.value, sorted_median(&data));
        let ratio = run.modeled.as_secs_f64() / run.measured.as_secs_f64().max(1e-12);
        assert!((ratio - PAPER_SLOWDOWN).abs() < 0.5, "ratio {ratio}");
    }

    #[test]
    fn custom_slowdown() {
        let data = [5.0, 1.0, 3.0];
        let m = GpuQuickselectModel { slowdown: 2.0 };
        let run = m.run(&data, 2);
        assert_eq!(run.value, 3.0);
        assert!(run.modeled >= run.measured);
    }

    #[test]
    fn seeded_model_reproduces_the_committed_trajectory_width() {
        let m = PassCostModel::seeded();
        // host oracle (no native limit): the BENCH_select.json width
        assert_eq!(m.best_width(None), 15);
        // device buckets: one launch per pass at the native width wins
        assert_eq!(m.best_width(Some(3)), 3);
        assert_eq!(m.best_width(Some(7)), 7);
        assert_eq!(m.best_width(Some(15)), 15);
        assert!(m.pass_cost(15, 1 << 14) > m.pass_cost(1, 1 << 14));
    }

    /// Synthesize runs from known coefficients and check the fit drives
    /// the planned width in the right direction.
    fn feed_synthetic(model: &mut PassCostModel, a: f64, b: f64) {
        for (i, &w) in [1usize, 3, 7, 15, 31, 63, 2, 5, 11, 23].iter().enumerate() {
            let passes = 4 + i % 3;
            let fixups = 1 + i % 4;
            let total = (passes + fixups) as u64;
            let n = 1usize << (12 + i % 3);
            let probes = (passes * w + fixups) as f64;
            let secs = (a * total as f64 + b * probes) * n as f64;
            let rungs = (passes * w) as u64;
            model.observe_run(passes, rungs, total, n, Duration::from_secs_f64(secs));
        }
    }

    #[test]
    fn probe_heavy_measurements_narrow_the_ladder() {
        let mut m = PassCostModel::seeded();
        // per-probe cost equals the sweep cost: compares dominate, so the
        // optimal ladder is narrow (argmin of (1 + p)/ln(p + 1) is p = 2)
        feed_synthetic(&mut m, 1e-9, 1e-9);
        assert!(m.samples() >= 8);
        let w = m.best_width(None);
        assert!(w <= 4, "expected a narrow ladder, got {w}");
    }

    #[test]
    fn overhead_heavy_measurements_widen_the_ladder() {
        let mut m = PassCostModel::seeded();
        // per-probe cost ~free: passes dominate (the paper's premise at
        // its strongest) and the widest planned ladder wins
        feed_synthetic(&mut m, 1e-9, 1e-14);
        let w = m.best_width(None);
        assert!(w >= 32, "expected a wide ladder, got {w}");
        // a native bucket stays the plan: chunked launches shrink less
        // than the same number of sequential adaptive passes
        assert_eq!(m.best_width(Some(15)), 15);
    }

    #[test]
    fn degenerate_fits_fall_back_to_the_seed() {
        let mut m = PassCostModel::seeded();
        // identical collinear samples: the ratio spread is zero and the
        // normal equations are singular — both guards hold the seed
        for _ in 0..20 {
            m.observe_run(10, 150, 10, 1 << 14, Duration::from_millis(1));
        }
        assert_eq!(m.best_width(None), 15);
        // nonsense inputs are ignored outright
        let before = m.samples();
        m.observe_run(5, 75, 2, 1 << 14, Duration::from_millis(1)); // passes > total
        m.observe_run(1, 15, 1, 0, Duration::from_millis(1)); // n = 0
        assert_eq!(m.samples(), before);
    }
}
