//! Cost models for the GPU substrate.
//!
//! Two distinct models live here:
//!
//! - [`GpuQuickselectModel`] — the paper's "quickselect on GPU as a single
//!   thread" (§II alternative 3; Tables I–II row "Quickselect (on GPU)").
//!   The paper runs quickselect in one CUDA thread to avoid the
//!   device→host transfer; a single GPU core is ~30× slower than a CPU
//!   core on this branchy serial workload (Tables I–II: 21 951 ms vs
//!   708 ms at n = 2²⁵ float). Our substrate has no such core, so we
//!   *model* it: run the real quickselect, then scale the measured time by
//!   a calibrated slowdown constant (documented substitution, DESIGN.md
//!   §7). The returned value is exact; only the reported time is modeled.
//! - [`PassCostModel`] — pass cost vs ladder width, the knob behind
//!   "probes per pass". It is seeded from the committed
//!   `BENCH_select.json` trajectory and refined online from measured run
//!   timings, and [`crate::select::MultisectOptions::for_evaluator`]
//!   consults it so the ladder width is chosen by cost rather than by a
//!   hard-coded constant.

use std::path::{Path, PathBuf};
use std::time::Duration;

use super::quickselect::quickselect;
use crate::testkit::Clock;
use crate::util::json::Json;
use crate::util::sync::{OrderedGuard, OrderedMutex, RANK_COST_MODEL_POOL};
use crate::{Error, Result};

/// Widest ladder the pass planner will consider on an evaluator with no
/// native width limit (the host oracle sweeps any width in one pass; the
/// returns of an even wider ladder shrink like `1/ln p`).
pub const MAX_PLANNED_WIDTH: usize = 64;

/// Linear pass-cost model: one fused pass over `n` elements with a
/// `p`-rung ladder costs `(a + b·p)·n` seconds, `a` the fixed per-element
/// sweep cost (read + bin bookkeeping) and `b` the incremental per-probe
/// compare cost. Selection spends `log_{p+1}(range/tol)` passes, so the
/// total cost of a run is proportional to `(a + b·p)/ln(p + 1)` and the
/// best width is its integer argmin — wider ladders buy geometrically
/// fewer passes until the `b·p` term wins.
///
/// **Seeding.** The committed `BENCH_select.json` trajectory records the
/// width-15 ladder resolving 2²² elements in 10 passes (21 fused
/// reductions vs bisection's 52 at width 1) — the width the repo's
/// measured trajectory was recorded at. Absent local measurements the
/// model is seeded to reproduce exactly that choice: the indifference
/// condition `d/dp [(a + b·p)/ln(p+1)] = 0` at `p* = 15` fixes
/// `a/b = (p*+1)·ln(p*+1) − p* ≈ 29.36`, and only the ratio matters for
/// the argmin.
///
/// **Online refinement.** Each coordinator worker owns a model and feeds
/// it one sample per shared-ladder run ([`PassCostModel::observe_run`]):
/// a run with `P` ladder passes evaluating `G` rungs in total (the solver
/// reports the *actual* count — bracket dedup and budget splitting make it
/// differ from `P × planned width`) plus `R − P` single-probe reductions
/// over `n` elements predicts `wall = a·(R·n) + b·((G + R − P)·n)`, a
/// two-regressor linear system whose normal equations accumulate in O(1)
/// space. The fit replaces the seed only when it is *identifiable*: the
/// probes-per-reduction ratio must genuinely vary across samples (a
/// worker that always runs the same ladder shape cannot separate sweep
/// cost from probe cost, and fitting its timing noise could lock the
/// planner into a bad width), the normal equations must be well
/// conditioned, and the coefficients must be physical (positive sweep
/// cost); otherwise the seed holds.
#[derive(Debug, Clone)]
pub struct PassCostModel {
    // Normal-equation accumulators for wall = a·xa + b·xb over observed
    // runs, where xa = element-passes and xb = element-probes.
    s_aa: f64,
    s_ab: f64,
    s_bb: f64,
    s_ay: f64,
    s_by: f64,
    // Identifiability tracking: spread of the xb/xa ratio across samples.
    ratio_lo: f64,
    ratio_hi: f64,
    samples: u64,
    seed_sweep: f64,
    seed_per_probe: f64,
}

/// Samples required before the fitted coefficients replace the seed.
const MIN_FIT_SAMPLES: u64 = 8;

impl Default for PassCostModel {
    fn default() -> Self {
        Self::seeded()
    }
}

impl PassCostModel {
    /// Model seeded from the committed `BENCH_select.json` trajectory (see
    /// the type docs): argmin width 15 on a width-unlimited evaluator.
    pub fn seeded() -> Self {
        let p_star = 15.0f64;
        let seed_sweep = 1.0e-9; // ~1 ns/element full sweep; scale cancels
        let seed_per_probe = seed_sweep / ((p_star + 1.0) * (p_star + 1.0).ln() - p_star);
        PassCostModel {
            s_aa: 0.0,
            s_ab: 0.0,
            s_bb: 0.0,
            s_ay: 0.0,
            s_by: 0.0,
            ratio_lo: f64::INFINITY,
            ratio_hi: 0.0,
            samples: 0,
            seed_sweep,
            seed_per_probe,
        }
    }

    /// Model seeded from *measured* sweep coefficients instead of the
    /// committed-trajectory ratio: `bench-wall` fits `(a, b)` from a
    /// two-width kernel sweep on the local host
    /// ([`crate::harness::wall::measure_pass_cost`]) and hands them here,
    /// so a fresh model plans from this machine's real throughput before
    /// any coordinator runs have been observed. The measured pair replaces
    /// only the *seed*; the online normal-equation refinement and all its
    /// identifiability guards behave exactly as with [`PassCostModel::seeded`].
    /// Non-physical measurements (non-finite, zero or negative sweep cost,
    /// negative per-probe cost — what a mis-timed quick run produces)
    /// fall back to the trajectory seed rather than poisoning the planner.
    pub fn seeded_from_measured(sweep: f64, per_probe: f64) -> Self {
        if !(sweep.is_finite() && sweep > 0.0 && per_probe.is_finite() && per_probe >= 0.0) {
            return Self::seeded();
        }
        let mut m = Self::seeded();
        m.seed_sweep = sweep;
        m.seed_per_probe = per_probe;
        m
    }

    /// Number of runs observed so far.
    pub fn samples(&self) -> u64 {
        self.samples
    }

    /// Record one measured run: `ladder_passes` fused passes evaluating
    /// `ladder_rungs` probe rungs in total (the solver's actual count, see
    /// `MultiOutcome::rungs`) plus (`total_reductions − ladder_passes`)
    /// single-probe reductions, all over `n` elements, in `wall` seconds.
    pub fn observe_run(
        &mut self,
        ladder_passes: usize,
        ladder_rungs: u64,
        total_reductions: u64,
        n: usize,
        wall: Duration,
    ) {
        if n == 0 || total_reductions == 0 || ladder_passes as u64 > total_reductions {
            return;
        }
        let r = total_reductions as f64;
        let p = ladder_passes as f64;
        let xa = r * n as f64;
        let xb = (ladder_rungs as f64 + (r - p)) * n as f64;
        let y = wall.as_secs_f64();
        self.s_aa += xa * xa;
        self.s_ab += xa * xb;
        self.s_bb += xb * xb;
        self.s_ay += xa * y;
        self.s_by += xb * y;
        let ratio = xb / xa;
        self.ratio_lo = self.ratio_lo.min(ratio);
        self.ratio_hi = self.ratio_hi.max(ratio);
        self.samples += 1;
    }

    /// Minimum spread of the probes-per-reduction ratio across samples
    /// before the fit is considered identifiable (below it, timing noise
    /// rather than width variation would drive the coefficients).
    const MIN_RATIO_SPREAD: f64 = 1.5;

    /// `(sweep, per_probe)` coefficients: the regression fit when it is
    /// identifiable and well conditioned, the seed otherwise.
    fn coeffs(&self) -> (f64, f64) {
        let identifiable = self.ratio_hi > self.ratio_lo * Self::MIN_RATIO_SPREAD;
        if self.samples >= MIN_FIT_SAMPLES && identifiable {
            let det = self.s_aa * self.s_bb - self.s_ab * self.s_ab;
            if det > 1e-9 * self.s_aa * self.s_bb {
                let a = (self.s_bb * self.s_ay - self.s_ab * self.s_by) / det;
                let b = (self.s_aa * self.s_by - self.s_ab * self.s_ay) / det;
                if a > 0.0 && b >= 0.0 {
                    return (a, b);
                }
            }
        }
        (self.seed_sweep, self.seed_per_probe)
    }

    /// Modeled seconds for one `p`-rung pass over `n` elements.
    pub fn pass_cost(&self, p: usize, n: usize) -> f64 {
        let (a, b) = self.coeffs();
        (a + b * p.max(1) as f64) * n as f64
    }

    /// Cost-model-chosen probes per pass, minimizing
    /// `per-pass cost / ln(p + 1)` — total run cost up to the
    /// range-resolution constant shared by every width.
    ///
    /// `native` is the evaluator's fused-ladder width hint. When present
    /// the hint *is* the plan: narrower ladders pad to the bucket (same
    /// launch, less shrink), and exceeding it chunks into `m` launches
    /// whose single ladder shrinks the bracket by `ln(m·w + 1)` — strictly
    /// less than the `m·ln(w + 1)` that `m` sequential *adaptive* passes
    /// buy for the same launch budget. When absent, every width up to
    /// [`MAX_PLANNED_WIDTH`] costs its linear model price and the argmin
    /// is taken over all of them.
    pub fn best_width(&self, native: Option<usize>) -> usize {
        if let Some(w) = native {
            return w.max(1);
        }
        let (a, b) = self.coeffs();
        let score = |p: usize| (a + b * p as f64) / (p as f64 + 1.0).ln();
        (1..=MAX_PLANNED_WIDTH)
            .min_by_key(|&p| crate::util::f64_key(score(p)))
            .unwrap_or(15)
    }

    /// The `(sweep, per_probe)` coefficients currently in force: the
    /// identifiable fit, or the seed (see [`PassCostModel::observe_run`]'s
    /// guards). Public so pooling/persistence tests can check fits against
    /// raw observations.
    pub fn coefficients(&self) -> (f64, f64) {
        self.coeffs()
    }

    /// Fold `other`'s observations into `self`. The model keeps sufficient
    /// statistics (normal-equation sums + ratio extrema), all of which are
    /// associative and commutative, so merging per-worker models in any
    /// order/partition yields the same pooled fit (up to float rounding of
    /// the sums) as one model that saw every run directly.
    pub fn merge(&mut self, other: &PassCostModel) {
        self.s_aa += other.s_aa;
        self.s_ab += other.s_ab;
        self.s_bb += other.s_bb;
        self.s_ay += other.s_ay;
        self.s_by += other.s_by;
        self.ratio_lo = self.ratio_lo.min(other.ratio_lo);
        self.ratio_hi = self.ratio_hi.max(other.ratio_hi);
        self.samples += other.samples;
    }

    /// Serialize the sufficient statistics (schema
    /// `cp-select/cost_model/v1`) — the cost-model sidecar format. `{:e}`
    /// with 17 significant digits round-trips every finite f64 exactly;
    /// the empty-model `ratio_lo = +inf` sentinel becomes `null`.
    pub fn to_json(&self) -> String {
        let num = |v: f64| format!("{v:.17e}");
        let ratio = |v: f64| if v.is_finite() { format!("{v:.17e}") } else { "null".to_string() };
        format!(
            "{{\n  \"schema\": \"cp-select/cost_model/v1\",\n  \"samples\": {},\n  \
             \"s_aa\": {},\n  \"s_ab\": {},\n  \"s_bb\": {},\n  \"s_ay\": {},\n  \
             \"s_by\": {},\n  \"ratio_lo\": {},\n  \"ratio_hi\": {},\n  \
             \"fitted_width\": {}\n}}\n",
            self.samples,
            num(self.s_aa),
            num(self.s_ab),
            num(self.s_bb),
            num(self.s_ay),
            num(self.s_by),
            ratio(self.ratio_lo),
            ratio(self.ratio_hi),
            self.best_width(None)
        )
    }

    /// Parse a sidecar produced by [`PassCostModel::to_json`]. Strict:
    /// wrong schema, missing fields, non-finite or negative accumulators
    /// all error so a corrupt sidecar is *detected* (the pool logs and
    /// falls back to the seed rather than serving garbage coefficients).
    pub fn from_json(text: &str) -> Result<PassCostModel> {
        let j = Json::parse(text)?;
        let schema = j.get("schema")?.as_str()?;
        if schema != "cp-select/cost_model/v1" {
            return Err(Error::Parse(format!("unknown cost-model schema {schema:?}")));
        }
        let mut m = PassCostModel::seeded();
        m.samples = j.get("samples")?.as_usize()? as u64;
        let field = |key: &str| -> Result<f64> {
            let v = j.get(key)?.as_f64()?;
            if !v.is_finite() || v < 0.0 {
                return Err(Error::Parse(format!("cost-model field {key} = {v} out of range")));
            }
            Ok(v)
        };
        m.s_aa = field("s_aa")?;
        m.s_ab = field("s_ab")?;
        m.s_bb = field("s_bb")?;
        m.s_ay = field("s_ay")?;
        m.s_by = field("s_by")?;
        m.ratio_lo = match j.get_opt("ratio_lo") {
            None => f64::INFINITY,
            Some(v) => {
                let v = v.as_f64()?;
                if !(v.is_finite() && v >= 0.0) {
                    return Err(Error::Parse(format!("cost-model ratio_lo = {v} out of range")));
                }
                v
            }
        };
        m.ratio_hi = match j.get_opt("ratio_hi") {
            None => 0.0,
            Some(_) => field("ratio_hi")?,
        };
        if m.samples > 0 && m.ratio_lo.is_finite() && m.ratio_lo > m.ratio_hi {
            return Err(Error::Parse(format!(
                "cost-model ratio extrema inverted: {} > {}",
                m.ratio_lo, m.ratio_hi
            )));
        }
        Ok(m)
    }
}

/// Cross-worker cost-model pool: one shared [`PassCostModel`] every
/// coordinator worker reads its planning snapshot from and feeds its
/// measured runs into.
///
/// Workers used to each refine a private model from their own runs — N
/// workers re-learned the same curve N times, and a restart threw all of
/// it away. The pool merges observations as **sufficient statistics** (the
/// model's normal-equation accumulators, not raw samples), so:
///
/// - a new worker warm-starts from everything the fleet has measured
///   ([`CostModelPool::snapshot`] at planning time — cross-worker sharing
///   is live, not start-only);
/// - the identifiability/conditioning guards apply to the *pooled* fit,
///   which is strictly better posed than any single worker's (ratio spread
///   and sample count only grow under merge);
/// - the statistics persist to a JSON sidecar next to `BENCH_select.json`
///   ([`CostModelPool::persist`] on service shutdown,
///   [`CostModelPool::load_or_seed`] on start), so a restarted service
///   plans with measured coefficients instead of the seed. A missing
///   sidecar is a silent cold start; a corrupt one logs and seeds.
pub struct CostModelPool {
    /// Rank [`RANK_COST_MODEL_POOL`] in the coordinator lock order.
    inner: OrderedMutex<PassCostModel>,
    sidecar: Option<PathBuf>,
}

impl CostModelPool {
    /// In-memory pool starting from the trajectory seed (no persistence).
    pub fn seeded() -> std::sync::Arc<CostModelPool> {
        std::sync::Arc::new(CostModelPool {
            inner: OrderedMutex::new(
                RANK_COST_MODEL_POOL,
                "gpu_model.inner",
                PassCostModel::seeded(),
            ),
            sidecar: None,
        })
    }

    /// Pool bound to a sidecar file: loads prior statistics when the file
    /// parses, logs and seeds when it is corrupt, and silently seeds when
    /// it does not exist yet (first boot). [`CostModelPool::persist`]
    /// writes back to the same path.
    ///
    /// The "seed" here is the committed-trajectory ratio
    /// ([`PassCostModel::seeded`]). A host that has run `bench-wall` can
    /// do better: the harness fits real `(sweep, per_probe)` coefficients
    /// from the kernel sweep and constructs the starting model with
    /// [`PassCostModel::seeded_from_measured`], merging any sidecar
    /// statistics on top — so a cold pool on a measured machine plans
    /// from that machine's actual memory bandwidth, not the trajectory's.
    /// Because the committed trajectory was recorded at the width-15
    /// argmin, any faithfully measured host lands in the same argmin
    /// basin (see `measured_seed_still_yields_the_trajectory_width`).
    pub fn load_or_seed(sidecar: impl Into<PathBuf>) -> std::sync::Arc<CostModelPool> {
        let sidecar = sidecar.into();
        let model = match std::fs::read_to_string(&sidecar) {
            Err(_) => PassCostModel::seeded(),
            Ok(text) => match PassCostModel::from_json(&text) {
                Ok(m) => m,
                Err(e) => {
                    eprintln!(
                        "cost-model sidecar {} unreadable ({e}); starting from the seed",
                        sidecar.display()
                    );
                    PassCostModel::seeded()
                }
            },
        };
        std::sync::Arc::new(CostModelPool {
            inner: OrderedMutex::new(RANK_COST_MODEL_POOL, "gpu_model.inner", model),
            sidecar: Some(sidecar),
        })
    }

    fn lock(&self) -> OrderedGuard<'_, PassCostModel> {
        self.inner.lock()
    }

    /// Point-in-time copy of the pooled model (what a worker plans with).
    pub fn snapshot(&self) -> PassCostModel {
        self.lock().clone()
    }

    /// Pooled runs observed so far (across every worker + loaded sidecar).
    pub fn samples(&self) -> u64 {
        self.lock().samples()
    }

    /// Pooled-fit planned width (see [`PassCostModel::best_width`]).
    pub fn best_width(&self, native: Option<usize>) -> usize {
        self.lock().best_width(native)
    }

    /// Record one measured run into the pool (same contract as
    /// [`PassCostModel::observe_run`]).
    pub fn observe_run(
        &self,
        ladder_passes: usize,
        ladder_rungs: u64,
        total_reductions: u64,
        n: usize,
        wall: Duration,
    ) {
        self.lock().observe_run(ladder_passes, ladder_rungs, total_reductions, n, wall);
    }

    /// Fold a privately-refined model into the pool (sufficient-statistic
    /// merge; see [`PassCostModel::merge`]).
    pub fn merge(&self, worker_model: &PassCostModel) {
        self.lock().merge(worker_model);
    }

    /// Path this pool persists to, when sidecar-bound.
    pub fn sidecar(&self) -> Option<&Path> {
        self.sidecar.as_deref()
    }

    /// Write the pooled statistics to the sidecar (no-op `Ok(None)` for
    /// in-memory pools). Called by the service on shutdown. Writes a temp
    /// file and renames it over the sidecar so a crash mid-write leaves
    /// the previous statistics intact instead of a truncated document.
    /// The temp name carries the writing pid: two processes sharing one
    /// sidecar (coordinator + workers, or concurrent test binaries) must
    /// not interleave bytes into the same staging file, or the rename
    /// publishes a mix of both documents.
    pub fn persist(&self) -> Result<Option<PathBuf>> {
        let Some(path) = &self.sidecar else {
            return Ok(None);
        };
        let json = self.lock().to_json();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir).map_err(|e| Error::io(dir.display().to_string(), e))?;
            }
        }
        let tmp = path.with_extension(format!("json.{}.tmp", std::process::id()));
        std::fs::write(&tmp, json).map_err(|e| Error::io(tmp.display().to_string(), e))?;
        std::fs::rename(&tmp, path).map_err(|e| Error::io(path.display().to_string(), e))?;
        Ok(Some(path.clone()))
    }
}

/// Slowdown calibrated from the paper's own measurements:
/// 21951.0 / 708.1 ≈ 31 (f32, n = 2²⁵).
pub const PAPER_SLOWDOWN: f64 = 31.0;

#[derive(Debug, Clone, Copy)]
pub struct GpuQuickselectModel {
    pub slowdown: f64,
}

impl Default for GpuQuickselectModel {
    fn default() -> Self {
        GpuQuickselectModel { slowdown: PAPER_SLOWDOWN }
    }
}

#[derive(Debug, Clone, Copy)]
pub struct ModeledRun {
    pub value: f64,
    /// Actual wall time of the host quickselect.
    pub measured: Duration,
    /// Modeled single-GPU-thread time = measured × slowdown.
    pub modeled: Duration,
}

impl GpuQuickselectModel {
    /// Run against the production clock (see [`GpuQuickselectModel::run_on`]).
    pub fn run(&self, data: &[f64], k: usize) -> ModeledRun {
        self.run_on(&Clock::real(), data, k)
    }

    /// Run the real quickselect, timing it on `clock` — under a virtual
    /// clock the measured wall is exactly the virtually-elapsed time, so
    /// tests of the modeled slowdown are deterministic.
    pub fn run_on(&self, clock: &Clock, data: &[f64], k: usize) -> ModeledRun {
        let mut scratch = data.to_vec();
        let t0_us = clock.now_us();
        let value = quickselect(&mut scratch, k);
        let measured = Duration::from_micros(clock.now_us().saturating_sub(t0_us));
        ModeledRun {
            value,
            measured,
            modeled: measured.mul_f64(self.slowdown),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::{sorted_median, Distribution, Rng};

    #[test]
    fn value_is_exact_time_is_scaled() {
        let mut rng = Rng::seeded(95);
        // large enough that the µs-resolution clock sees a nonzero wall
        let data = Distribution::Normal.sample_vec(&mut rng, 100_000);
        let m = GpuQuickselectModel::default();
        let run = m.run(&data, 50_000);
        assert_eq!(run.value, sorted_median(&data));
        let ratio = run.modeled.as_secs_f64() / run.measured.as_secs_f64().max(1e-12);
        assert!((ratio - PAPER_SLOWDOWN).abs() < 0.5, "ratio {ratio}");
    }

    #[test]
    fn custom_slowdown() {
        let data = [5.0, 1.0, 3.0];
        let m = GpuQuickselectModel { slowdown: 2.0 };
        let run = m.run(&data, 2);
        assert_eq!(run.value, 3.0);
        assert!(run.modeled >= run.measured);
    }

    #[test]
    fn seeded_model_reproduces_the_committed_trajectory_width() {
        let m = PassCostModel::seeded();
        // host oracle (no native limit): the BENCH_select.json width
        assert_eq!(m.best_width(None), 15);
        // device buckets: one launch per pass at the native width wins
        assert_eq!(m.best_width(Some(3)), 3);
        assert_eq!(m.best_width(Some(7)), 7);
        assert_eq!(m.best_width(Some(15)), 15);
        assert!(m.pass_cost(15, 1 << 14) > m.pass_cost(1, 1 << 14));
    }

    /// Feed the canonical synthetic stream (`testkit::synthetic_cost_runs`)
    /// and check the fit drives the planned width in the right direction.
    fn feed_synthetic(model: &mut PassCostModel, a: f64, b: f64) {
        for (passes, rungs, total, n, wall) in crate::testkit::synthetic_cost_runs(a, b) {
            model.observe_run(passes, rungs, total, n, wall);
        }
    }

    #[test]
    fn measured_seed_still_yields_the_trajectory_width() {
        // bench-wall on the build host measured ~these shapes: a full
        // sweep costs a fraction of a ns per element and the per-probe
        // compare sits near the committed trajectory's indifference ratio
        // a/b = 16·ln 16 − 15 ≈ 29.36. Any measured pair inside the
        // width-15 argmin basin (ratio ∈ ~(27.96, 30.76)) must reproduce
        // the committed trajectory's plan — at *any* absolute scale,
        // since only the ratio enters the argmin.
        for scale in [1.0, 0.37, 4.2] {
            let sweep = 0.9e-9 * scale;
            for ratio in [28.5, 29.36, 30.5] {
                let m = PassCostModel::seeded_from_measured(sweep, sweep / ratio);
                assert_eq!(m.best_width(None), 15, "scale={scale} ratio={ratio}");
                assert_eq!(m.best_width(Some(7)), 7);
            }
        }
        // out-of-basin measurements move the plan (the point of measuring)
        let sweep = 0.9e-9;
        assert!(PassCostModel::seeded_from_measured(sweep, sweep).best_width(None) <= 4);
        // non-physical measurements fall back to the trajectory seed
        for (a, b) in [(f64::NAN, 1e-11), (0.0, 1e-11), (1e-9, f64::NAN), (1e-9, -1e-11)] {
            let m = PassCostModel::seeded_from_measured(a, b);
            assert_eq!(m.best_width(None), 15);
            assert_eq!(m.coefficients(), PassCostModel::seeded().coefficients());
        }
    }

    #[test]
    fn probe_heavy_measurements_narrow_the_ladder() {
        let mut m = PassCostModel::seeded();
        // per-probe cost equals the sweep cost: compares dominate, so the
        // optimal ladder is narrow (argmin of (1 + p)/ln(p + 1) is p = 2)
        feed_synthetic(&mut m, 1e-9, 1e-9);
        assert!(m.samples() >= 8);
        let w = m.best_width(None);
        assert!(w <= 4, "expected a narrow ladder, got {w}");
    }

    #[test]
    fn overhead_heavy_measurements_widen_the_ladder() {
        let mut m = PassCostModel::seeded();
        // per-probe cost ~free: passes dominate (the paper's premise at
        // its strongest) and the widest planned ladder wins
        feed_synthetic(&mut m, 1e-9, 1e-14);
        let w = m.best_width(None);
        assert!(w >= 32, "expected a wide ladder, got {w}");
        // a native bucket stays the plan: chunked launches shrink less
        // than the same number of sequential adaptive passes
        assert_eq!(m.best_width(Some(15)), 15);
    }

    #[test]
    fn merge_pools_observations_across_models() {
        // two workers see disjoint halves of the synthetic stream; the
        // merged model must fit like one model that saw everything
        let mut whole = PassCostModel::seeded();
        feed_synthetic(&mut whole, 1e-9, 1e-14);
        let mut w1 = PassCostModel::seeded();
        let mut w2 = PassCostModel::seeded();
        let runs = crate::testkit::synthetic_cost_runs(1e-9, 1e-14);
        for (i, (passes, rungs, total, n, wall)) in runs.into_iter().enumerate() {
            let model = if i % 2 == 0 { &mut w1 } else { &mut w2 };
            model.observe_run(passes, rungs, total, n, wall);
        }
        // neither half alone is identifiable (fewer than MIN_FIT_SAMPLES)
        assert_eq!(w1.best_width(None), 15);
        let mut pooled = PassCostModel::seeded();
        pooled.merge(&w1);
        pooled.merge(&w2);
        assert_eq!(pooled.samples(), whole.samples());
        assert_eq!(pooled.best_width(None), whole.best_width(None));
        let (pa, pb) = pooled.coefficients();
        let (wa, wb) = whole.coefficients();
        // tolerances scale with the sweep coefficient: the tiny per-probe
        // term is recovered through a cancellation-prone determinant, so
        // only its contribution at the sweep's scale is meaningful
        assert!((pa - wa).abs() <= 1e-9 * wa.abs(), "{pa} vs {wa}");
        assert!((pb - wb).abs() <= 1e-9 * wa.abs(), "{pb} vs {wb}");
    }

    #[test]
    fn sidecar_json_roundtrips_exactly() {
        let mut m = PassCostModel::seeded();
        feed_synthetic(&mut m, 2e-9, 3e-10);
        let j = m.to_json();
        let back = PassCostModel::from_json(&j).unwrap();
        assert_eq!(back.samples(), m.samples());
        assert_eq!(back.coefficients(), m.coefficients(), "17-sig-digit floats roundtrip");
        assert_eq!(back.best_width(None), m.best_width(None));
        // empty model roundtrips through the null ratio sentinel
        let empty = PassCostModel::seeded();
        let back = PassCostModel::from_json(&empty.to_json()).unwrap();
        assert_eq!(back.samples(), 0);
        assert_eq!(back.best_width(None), 15);
    }

    #[test]
    fn from_json_rejects_garbage() {
        assert!(PassCostModel::from_json("").is_err());
        assert!(PassCostModel::from_json("not json at all").is_err());
        // truncated document
        let whole = PassCostModel::seeded().to_json();
        assert!(PassCostModel::from_json(&whole[..whole.len() / 2]).is_err());
        // wrong schema
        assert!(PassCostModel::from_json("{\"schema\": \"other/v9\"}").is_err());
        // out-of-range accumulator
        let bad = whole.replace("\"s_aa\": 0.00000000000000000e0", "\"s_aa\": -1.0");
        assert!(PassCostModel::from_json(&bad).is_err());
    }

    #[test]
    fn pool_persists_and_reloads_measured_statistics() {
        let dir = std::env::temp_dir().join(format!("cp_select_pool_{}", std::process::id()));
        let path = dir.join("BENCH_select.cost_model.json");
        let pool = CostModelPool::load_or_seed(&path);
        assert_eq!(pool.samples(), 0, "missing sidecar is a cold start");
        {
            let mut m = PassCostModel::seeded();
            feed_synthetic(&mut m, 1e-9, 1e-14);
            pool.merge(&m);
        }
        let fitted = pool.best_width(None);
        assert!(fitted >= 32, "synthetic overhead-heavy stream must widen, got {fitted}");
        pool.persist().unwrap();
        let reloaded = CostModelPool::load_or_seed(&path);
        assert_eq!(reloaded.samples(), pool.samples());
        assert_eq!(reloaded.best_width(None), fitted);
        // corrupt the sidecar: next load logs and seeds instead of erroring
        std::fs::write(&path, "{\"schema\": \"cp-select/cost_model/v1\", \"samples\":").unwrap();
        let seeded = CostModelPool::load_or_seed(&path);
        assert_eq!(seeded.samples(), 0);
        assert_eq!(seeded.best_width(None), 15);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn degenerate_fits_fall_back_to_the_seed() {
        let mut m = PassCostModel::seeded();
        // identical collinear samples: the ratio spread is zero and the
        // normal equations are singular — both guards hold the seed
        for _ in 0..20 {
            m.observe_run(10, 150, 10, 1 << 14, Duration::from_millis(1));
        }
        assert_eq!(m.best_width(None), 15);
        // nonsense inputs are ignored outright
        let before = m.samples();
        m.observe_run(5, 75, 2, 1 << 14, Duration::from_millis(1)); // passes > total
        m.observe_run(1, 15, 1, 0, Duration::from_millis(1)); // n = 0
        assert_eq!(m.samples(), before);
    }
}
