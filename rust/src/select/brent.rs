//! Brent's method, in both roles the paper evaluates:
//!
//! - [`brent_minimize`] — Numerical-Recipes-style minimization of f
//!   (parabolic interpolation with golden-section fallback);
//! - [`brent_root`] — Brent–Dekker root finding on the subgradient
//!   `g(y) = w_lo·c_lt − w_hi·c_gt` (inverse-quadratic / secant with
//!   bisection fallback).
//!
//! Both degrade on outlier-stretched data (paper Fig. 5): f is exactly
//! linear over most of the range, parabolic/quadratic fits degenerate, and
//! the methods fall back to their slow golden/bisection safeguards.

use super::exact;
use super::objective::{Evaluator, ObjectiveSpec};
use crate::util::PhaseTimer;
use crate::Result;

const GOLD: f64 = 0.381_966_011_250_105; // 1 - (√5−1)/2

#[derive(Debug, Clone)]
pub struct BrentOptions {
    pub max_iters: usize,
    pub tol: f64,
}

impl Default for BrentOptions {
    fn default() -> Self {
        BrentOptions { max_iters: 200, tol: 1e-12 }
    }
}

#[derive(Debug, Clone)]
pub struct BrentOutcome {
    pub value: f64,
    pub iterations: usize,
    pub phases: PhaseTimer,
}

/// Brent minimization of the selection objective.
pub fn brent_minimize(
    ev: &mut dyn Evaluator,
    k: usize,
    opts: &BrentOptions,
) -> Result<BrentOutcome> {
    brent_minimize_cancellable(ev, k, opts, &mut || None)
}

/// [`brent_minimize`] with a cooperative cancellation hook, polled at
/// every pass boundary (before each probe reduction) — never mid-pass.
pub fn brent_minimize_cancellable(
    ev: &mut dyn Evaluator,
    k: usize,
    opts: &BrentOptions,
    cancel: &mut dyn FnMut() -> Option<crate::Error>,
) -> Result<BrentOutcome> {
    let n = ev.n();
    let spec = ObjectiveSpec::order(n, k)?;
    let mut phases = PhaseTimer::new();

    let init = phases.time("iterations", || ev.init_stats())?;
    let (mut a, mut b) = (init.min, init.max);
    if a == b || k == 1 || k == n {
        let v = if k == n { b } else { a };
        return Ok(BrentOutcome { value: v, iterations: 0, phases });
    }

    // NR brent: x = best, w = second best, v = previous w.
    let mut x = a + GOLD * (b - a);
    let mut fx = spec.f(&phases.time("iterations", || ev.probe(x))?);
    let (mut w, mut v) = (x, x);
    let (mut fw, mut fv) = (fx, fx);
    let mut d: f64 = 0.0;
    let mut e: f64 = 0.0;
    let mut iterations = 1;

    while iterations < opts.max_iters {
        if let Some(err) = cancel() {
            return Err(err);
        }
        let xm = 0.5 * (a + b);
        let tol1 = opts.tol * x.abs().max(1.0);
        let tol2 = 2.0 * tol1;
        if (x - xm).abs() <= tol2 - 0.5 * (b - a) {
            break;
        }
        let mut use_golden = true;
        if e.abs() > tol1 {
            // parabolic fit through (x,fx), (w,fw), (v,fv)
            let r = (x - w) * (fx - fv);
            let mut q = (x - v) * (fx - fw);
            let mut p = (x - v) * q - (x - w) * r;
            q = 2.0 * (q - r);
            if q > 0.0 {
                p = -p;
            }
            q = q.abs();
            let etemp = e;
            e = d;
            if p.abs() < (0.5 * q * etemp).abs() && p > q * (a - x) && p < q * (b - x) {
                d = p / q;
                let u = x + d;
                if u - a < tol2 || b - u < tol2 {
                    d = if xm >= x { tol1 } else { -tol1 };
                }
                use_golden = false;
            }
        }
        if use_golden {
            e = if x >= xm { a - x } else { b - x };
            d = GOLD * e;
        }
        let u = if d.abs() >= tol1 {
            x + d
        } else if d >= 0.0 {
            x + tol1
        } else {
            x - tol1
        };
        let su = phases.time("iterations", || ev.probe(u))?;
        iterations += 1;
        let fu = spec.f(&su);
        if spec.is_optimal(&su) {
            x = u;
            break;
        }
        if fu <= fx {
            if u >= x {
                a = x;
            } else {
                b = x;
            }
            v = w;
            fv = fw;
            w = x;
            fw = fx;
            x = u;
            fx = fu;
        } else {
            if u < x {
                a = u;
            } else {
                b = u;
            }
            if fu <= fw || w == x {
                v = w;
                fv = fw;
                w = u;
                fw = fu;
            } else if fu <= fv || v == x || v == w {
                v = u;
                fv = fu;
            }
        }
    }

    let value = phases.time("exact_fixup", || exact::resolve(ev, k, x))?;
    Ok(BrentOutcome { value, iterations, phases })
}

/// Brent–Dekker root finding on the subgradient point value.
pub fn brent_root(ev: &mut dyn Evaluator, k: usize, opts: &BrentOptions) -> Result<BrentOutcome> {
    brent_root_cancellable(ev, k, opts, &mut || None)
}

/// [`brent_root`] with a cooperative cancellation hook, polled at every
/// pass boundary (before each probe reduction) — never mid-pass.
pub fn brent_root_cancellable(
    ev: &mut dyn Evaluator,
    k: usize,
    opts: &BrentOptions,
    cancel: &mut dyn FnMut() -> Option<crate::Error>,
) -> Result<BrentOutcome> {
    let n = ev.n();
    let spec = ObjectiveSpec::order(n, k)?;
    let mut phases = PhaseTimer::new();

    let init = phases.time("iterations", || ev.init_stats())?;
    if init.min == init.max || k == 1 || k == n {
        let v = if k == n { init.max } else { init.min };
        return Ok(BrentOutcome { value: v, iterations: 0, phases });
    }

    // g at the seeds, closed form (duplicate-safe edges).
    let seed = spec.seed(&init);
    let (mut a, mut b) = (seed.y_l, seed.y_r);
    let (mut fa, mut fb) = (seed.g_l, seed.g_r);
    let (mut c, mut fc) = (a, fa);
    let (mut d, mut e) = (b - a, b - a);
    let mut iterations = 0;

    while iterations < opts.max_iters {
        if let Some(err) = cancel() {
            return Err(err);
        }
        if (fb > 0.0 && fc > 0.0) || (fb < 0.0 && fc < 0.0) {
            c = a;
            fc = fa;
            d = b - a;
            e = d;
        }
        if fc.abs() < fb.abs() {
            a = b;
            b = c;
            c = a;
            fa = fb;
            fb = fc;
            fc = fa;
        }
        let tol1 = 2.0 * f64::EPSILON * b.abs() + 0.5 * opts.tol;
        let xm = 0.5 * (c - b);
        if xm.abs() <= tol1 || fb == 0.0 {
            break;
        }
        if e.abs() >= tol1 && fa.abs() > fb.abs() {
            // inverse quadratic / secant
            let s = fb / fa;
            let (mut p, mut q);
            if a == c {
                p = 2.0 * xm * s;
                q = 1.0 - s;
            } else {
                let qq = fa / fc;
                let r = fb / fc;
                p = s * (2.0 * xm * qq * (qq - r) - (b - a) * (r - 1.0));
                q = (qq - 1.0) * (r - 1.0) * (s - 1.0);
            }
            if p > 0.0 {
                q = -q;
            }
            p = p.abs();
            let min1 = 3.0 * xm * q - (tol1 * q).abs();
            let min2 = (e * q).abs();
            if 2.0 * p < min1.min(min2) {
                e = d;
                d = p / q;
            } else {
                d = xm;
                e = d;
            }
        } else {
            d = xm;
            e = d;
        }
        a = b;
        fa = fb;
        if d.abs() > tol1 {
            b += d;
        } else {
            b += if xm >= 0.0 { tol1 } else { -tol1 };
        }
        let sb = phases.time("iterations", || ev.probe(b))?;
        iterations += 1;
        if spec.is_optimal(&sb) {
            break;
        }
        fb = spec.g_point(&sb);
        if fb == 0.0 {
            break;
        }
    }

    let value = phases.time("exact_fixup", || exact::resolve(ev, k, b))?;
    Ok(BrentOutcome { value, iterations, phases })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::select::objective::HostEvaluator;
    use crate::stats::{sorted_median, sorted_order_statistic, Distribution, Rng};
    use crate::util::median_rank;

    #[test]
    fn minimize_matches_oracle() {
        let mut rng = Rng::seeded(51);
        for d in Distribution::ALL {
            let data = d.sample_vec(&mut rng, 2000);
            let mut ev = HostEvaluator::new(&data);
            let out = brent_minimize(&mut ev, median_rank(2000), &BrentOptions::default()).unwrap();
            assert_eq!(out.value, sorted_median(&data), "{}", d.name());
        }
    }

    #[test]
    fn root_matches_oracle() {
        let mut rng = Rng::seeded(52);
        for d in Distribution::ALL {
            let data = d.sample_vec(&mut rng, 2000);
            let mut ev = HostEvaluator::new(&data);
            let out = brent_root(&mut ev, median_rank(2000), &BrentOptions::default()).unwrap();
            assert_eq!(out.value, sorted_median(&data), "{}", d.name());
        }
    }

    #[test]
    fn root_order_statistics() {
        let mut rng = Rng::seeded(53);
        let data = Distribution::Beta25.sample_vec(&mut rng, 777);
        for k in [1, 2, 100, 389, 776, 777] {
            let mut ev = HostEvaluator::new(&data);
            let out = brent_root(&mut ev, k, &BrentOptions::default()).unwrap();
            assert_eq!(out.value, sorted_order_statistic(&data, k), "k={k}");
        }
    }

    #[test]
    fn outliers_inflate_brent_iterations_fig5() {
        let mut rng = Rng::seeded(54);
        let base = Distribution::Normal.sample_vec(&mut rng, 4096);
        let mut clean = base.clone();
        let mut ev = HostEvaluator::new(&clean);
        let clean_iters =
            brent_minimize(&mut ev, 2048, &BrentOptions::default()).unwrap().iterations;
        clean[0] = 1e12;
        let mut ev = HostEvaluator::new(&clean);
        let dirty = brent_minimize(&mut ev, 2048, &BrentOptions::default()).unwrap();
        assert_eq!(dirty.value, sorted_median(&clean));
        assert!(
            dirty.iterations > clean_iters,
            "outlier should slow Brent: {} vs {}",
            dirty.iterations,
            clean_iters
        );
    }

    #[test]
    fn constant_data() {
        let mut ev = HostEvaluator::new(&[7.0; 64]);
        assert_eq!(brent_minimize(&mut ev, 32, &BrentOptions::default()).unwrap().value, 7.0);
        let mut ev = HostEvaluator::new(&[7.0; 64]);
        assert_eq!(brent_root(&mut ev, 32, &BrentOptions::default()).unwrap().value, 7.0);
    }
}
