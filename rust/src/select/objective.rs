//! The convex selection objective and the `Evaluator` abstraction.
//!
//! Eq. (1) of the paper: `Med(x) = argmin_y f(y) = argmin_y Σ|x_i − y|`.
//! Eq. (2) generalizes to any order statistic with the piecewise-linear
//! penalty `u_k`. One device reduction returns the *sufficient statistics*
//! of x against a probe y:
//!
//! ```text
//!   s_lo = Σ_{x_i < y} (y − x_i)     c_lt = #{x_i < y}
//!   s_hi = Σ_{x_i > y} (x_i − y)     c_eq = #{x_i = y},  c_gt = #{x_i > y}
//! ```
//!
//! from which the host composes, for the k-th smallest element,
//!
//! ```text
//!   f(y)  = w_lo·s_lo + w_hi·s_hi,     w_lo = (n−k+½)·2/n,  w_hi = (k−½)·2/n
//!   ∂f(y) = [w_lo·c_lt − w_hi·(c_gt+c_eq),  w_lo·(c_lt+c_eq) − w_hi·c_gt]
//! ```
//!
//! (the 2/n normalization makes the median case coincide exactly with
//! Eq. (1): w_lo = w_hi = 1). The weights are arranged so the minimizer is
//! the k-th **smallest** element: `0 ∈ ∂f(y)` ⇔ `c_lt ≤ k−1 ∧ c_lt+c_eq ≥ k`
//! — i.e. the subgradient test *is* the rank test, which is what makes every
//! probe-based algorithm exact rather than approximate.
//!
//! `Evaluator` is the only interface the algorithms see; it is implemented
//! by [`HostEvaluator`] (CPU oracle), `runtime::DeviceEvaluator` (PJRT
//! artifacts) and `device::ShardedEvaluator` (multi-device combine).

use crate::{invalid_arg, Result};

/// Sufficient statistics of one probe (one fused device reduction).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ProbeStats {
    pub s_lo: f64,
    pub s_hi: f64,
    pub c_lt: u64,
    pub c_eq: u64,
    pub c_gt: u64,
}

impl ProbeStats {
    pub fn n(&self) -> u64 {
        self.c_lt + self.c_eq + self.c_gt
    }

    /// Count of elements ≤ y.
    pub fn c_le(&self) -> u64 {
        self.c_lt + self.c_eq
    }

    /// Combine statistics from two shards (paper §V.D: partial sums from
    /// several GPUs are added on the CPU).
    pub fn merge(&self, other: &ProbeStats) -> ProbeStats {
        ProbeStats {
            s_lo: self.s_lo + other.s_lo,
            s_hi: self.s_hi + other.s_hi,
            c_lt: self.c_lt + other.c_lt,
            c_eq: self.c_eq + other.c_eq,
            c_gt: self.c_gt + other.c_gt,
        }
    }
}

/// Result of the seed reduction (Algorithm 1, step 0).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InitStats {
    pub min: f64,
    pub max: f64,
    pub sum: f64,
}

impl InitStats {
    pub fn merge(&self, other: &InitStats) -> InitStats {
        InitStats {
            min: self.min.min(other.min),
            max: self.max.max(other.max),
            sum: self.sum + other.sum,
        }
    }
}

/// Result of the neighbor reduction (exact-rank fixup, paper footnote 1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Neighbors {
    /// Largest x_i ≤ y (−inf if none).
    pub lower: f64,
    /// Smallest x_i ≥ y (+inf if none).
    pub upper: f64,
    /// #{x_i ≤ y}.
    pub c_le: u64,
}

impl Neighbors {
    pub fn merge(&self, other: &Neighbors) -> Neighbors {
        Neighbors {
            lower: self.lower.max(other.lower),
            upper: self.upper.min(other.upper),
            c_le: self.c_le + other.c_le,
        }
    }
}

/// Pivot-interval occupancy (hybrid method).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IntervalCounts {
    /// #{x_i ≤ lo}  — the paper's rank offset m.
    pub c_le: u64,
    /// #{lo < x_i < hi} — |z|.
    pub c_in: u64,
    /// #{x_i ≥ hi}.
    pub c_ge: u64,
}

impl IntervalCounts {
    pub fn merge(&self, other: &IntervalCounts) -> IntervalCounts {
        IntervalCounts {
            c_le: self.c_le + other.c_le,
            c_in: self.c_in + other.c_in,
            c_ge: self.c_ge + other.c_ge,
        }
    }
}

/// Value dtype of the device-resident array.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    F64,
}

impl DType {
    pub fn name(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::F64 => "f64",
        }
    }

    pub fn from_name(s: &str) -> Option<DType> {
        match s {
            "f32" => Some(DType::F32),
            "f64" => Some(DType::F64),
            _ => None,
        }
    }
}

/// The device abstraction every selection algorithm drives.
///
/// One call = one parallel reduction on the device (or a host pass for the
/// oracle). `probes()` exposes the reduction counter used to verify the
/// paper's complexity claims (`maxit + 1` reductions for Algorithm 1).
pub trait Evaluator {
    /// Number of (valid) elements.
    fn n(&self) -> usize;

    /// Value dtype of the backing array.
    fn dtype(&self) -> DType;

    /// Fused (min, max, sum) — Algorithm 1 step 0.
    fn init_stats(&mut self) -> Result<InitStats>;

    /// Fused objective statistics at probe y.
    fn probe(&mut self, y: f64) -> Result<ProbeStats>;

    /// Fused objective statistics for a whole *probe ladder* in one batch.
    ///
    /// This is the "probes per pass" primitive: native implementations
    /// ([`HostEvaluator`], `device::ShardedEvaluator`) evaluate the entire
    /// ladder in a **single fused pass** over the data — binning each
    /// element against the sorted ladder and recovering per-probe stats by
    /// prefix-summing the bin partials — and count the batch as **one**
    /// reduction in [`Evaluator::probes`]. The default implementation falls
    /// back to sequential [`Evaluator::probe`] calls (costing `ys.len()`
    /// passes), so foreign implementations stay correct even if they never
    /// override it.
    ///
    /// Results are positionally aligned with `ys`; duplicate and unordered
    /// probe values are fine (duplicates share one ladder rung). A NaN probe
    /// yields all-zero stats, exactly like `probe(NaN)`.
    fn probe_many(&mut self, ys: &[f64]) -> Result<Vec<ProbeStats>> {
        ys.iter().map(|&y| self.probe(y)).collect()
    }

    /// Neighbor values + rank at y.
    fn neighbors(&mut self, y: f64) -> Result<Neighbors>;

    /// Occupancy of the open interval ]lo, hi[.
    fn interval(&mut self, lo: f64, hi: f64) -> Result<IntervalCounts>;

    /// Stream-compact elements in the open interval ]lo, hi[ (the paper's
    /// `copy_if`). On the device backend this runs against the host mirror
    /// (static-shape XLA cannot express compaction — DESIGN.md §7).
    fn compact(&mut self, lo: f64, hi: f64) -> Result<Vec<f64>>;

    /// Full download of the array (the "copy to CPU" phase of the
    /// quickselect-on-CPU baseline).
    fn download(&mut self) -> Result<Vec<f64>>;

    /// Total number of device reductions issued so far. A natively fused
    /// [`Evaluator::probe_many`] batch counts as one reduction (it is one
    /// pass over the data — the unit the paper's complexity claims count).
    fn probes(&self) -> u64;

    /// Widest probe ladder this evaluator answers in **one** fused
    /// reduction, or `None` when there is no native limit (the host oracle
    /// sweeps any width in a single pass). The device runtime reports its
    /// widest `fused_ladder` artifact bucket; pass planners
    /// (`MultisectOptions::for_evaluator`) size their ladders from this
    /// hint so every pass maps to exactly one launch.
    fn ladder_width_hint(&self) -> Option<usize> {
        None
    }

    /// Canonicalize a probe value through the array dtype: an f32-backed
    /// evaluator compares in f32, so any value reported as *equal to data*
    /// must be quantized to f32 to be the data value itself.
    fn canon(&self, y: f64) -> f64 {
        canon_value(y, self.dtype())
    }
}

/// [`Evaluator::canon`] as a free function (shared by the fused-ladder
/// helpers below, which run outside any evaluator borrow).
pub(crate) fn canon_value(y: f64, dtype: DType) -> f64 {
    match dtype {
        DType::F64 => y,
        DType::F32 => y as f32 as f64,
    }
}

/// Shared prologue of natively-fused `probe_many` batches (host oracle and
/// device runtime): canonicalize every probe through the array dtype, then
/// build the deduplicated sorted ladder with NaN rungs dropped. Returns
/// `(canonicalized probes, ladder)`; an empty ladder means every probe was
/// NaN.
pub(crate) fn fused_ladder_rungs(ys: &[f64], dtype: DType) -> (Vec<f64>, Vec<f64>) {
    let canon: Vec<f64> = ys.iter().map(|&y| canon_value(y, dtype)).collect();
    let mut ladder: Vec<f64> = canon.iter().copied().filter(|y| !y.is_nan()).collect();
    ladder.sort_by(crate::util::total_cmp_f64);
    ladder.dedup();
    (canon, ladder)
}

/// Shared epilogue: map per-rung `stats` (aligned with `ladder`) back to
/// the caller's probe order. Duplicates share one rung; a NaN probe yields
/// all-zero stats, exactly like `probe(NaN)`.
pub(crate) fn ladder_stats_in_probe_order(
    canon: &[f64],
    ladder: &[f64],
    stats: &[ProbeStats],
) -> Vec<ProbeStats> {
    let zero = ProbeStats { s_lo: 0.0, s_hi: 0.0, c_lt: 0, c_eq: 0, c_gt: 0 };
    canon
        .iter()
        .map(|&y| {
            if y.is_nan() {
                zero
            } else {
                stats[ladder.partition_point(|&l| l < y)]
            }
        })
        .collect()
}

/// Weighted objective for the k-th smallest of n (Eqs. 1–2).
#[derive(Debug, Clone, Copy)]
pub struct ObjectiveSpec {
    pub n: usize,
    pub k: usize,
    /// Weight on s_lo (elements below the probe).
    pub w_lo: f64,
    /// Weight on s_hi (elements above the probe).
    pub w_hi: f64,
}

impl ObjectiveSpec {
    /// Objective whose minimizer is the k-th smallest of n elements
    /// (1-indexed). The median (`k = [(n+1)/2]`) yields unit weights —
    /// exactly Eq. (1).
    pub fn order(n: usize, k: usize) -> Result<Self> {
        if n == 0 || k == 0 || k > n {
            return Err(invalid_arg!("order statistic k={k} out of range for n={n}"));
        }
        let nf = n as f64;
        let kf = k as f64;
        Ok(ObjectiveSpec {
            n,
            k,
            w_lo: (nf - kf + 0.5) * 2.0 / nf,
            w_hi: (kf - 0.5) * 2.0 / nf,
        })
    }

    /// The paper's median spec.
    pub fn median(n: usize) -> Result<Self> {
        Self::order(n, crate::util::median_rank(n))
    }

    /// Objective value at the probe.
    pub fn f(&self, s: &ProbeStats) -> f64 {
        self.w_lo * s.s_lo + self.w_hi * s.s_hi
    }

    /// Subgradient interval ∂f(y) = [g_lo, g_hi].
    pub fn g(&self, s: &ProbeStats) -> (f64, f64) {
        let lo = self.w_lo * s.c_lt as f64 - self.w_hi * (s.c_gt + s.c_eq) as f64;
        let hi = self.w_lo * (s.c_lt + s.c_eq) as f64 - self.w_hi * s.c_gt as f64;
        (lo, hi)
    }

    /// A single representative subgradient (0 if the probe is optimal).
    pub fn g_point(&self, s: &ProbeStats) -> f64 {
        let (lo, hi) = self.g(s);
        if lo <= 0.0 && 0.0 <= hi {
            0.0
        } else if hi < 0.0 {
            hi
        } else {
            lo
        }
    }

    /// `0 ∈ ∂f(y)` ⇔ y has rank k (ties included) ⇔ probe is a minimizer.
    pub fn is_optimal(&self, s: &ProbeStats) -> bool {
        (s.c_lt as usize) <= self.k - 1 && (s.c_lt + s.c_eq) as usize >= self.k
    }

    /// Should the bracket move right (answer strictly above the probe)?
    pub fn answer_above(&self, s: &ProbeStats) -> bool {
        ((s.c_lt + s.c_eq) as usize) < self.k
    }

    /// Closed-form seed values at the data extremes from one (min,max,sum)
    /// reduction — paper §IV: g(y_L), f(y_L), g(y_R), f(y_R) without extra
    /// passes. Subgradients use the duplicate-safe edge −w_hi(n−1) /
    /// +w_lo(n−1) (valid for any multiplicity of the extremes).
    pub fn seed(&self, init: &InitStats) -> SeedValues {
        let nf = self.n as f64;
        SeedValues {
            y_l: init.min,
            y_r: init.max,
            f_l: self.w_hi * (init.sum - nf * init.min),
            g_l: -self.w_hi * (nf - 1.0),
            f_r: self.w_lo * (nf * init.max - init.sum),
            g_r: self.w_lo * (nf - 1.0),
        }
    }
}

/// Seed state for the cutting plane (Algorithm 1, step 0).
#[derive(Debug, Clone, Copy)]
pub struct SeedValues {
    pub y_l: f64,
    pub y_r: f64,
    pub f_l: f64,
    pub g_l: f64,
    pub f_r: f64,
    pub g_r: f64,
}

// ---------------------------------------------------------------------------
// HostEvaluator — the CPU oracle backend
// ---------------------------------------------------------------------------

/// Backing storage in the array's native dtype (affects radix-sort key
/// width and the device-transfer volume, mirroring the paper's
/// float/double split).
#[derive(Debug, Clone)]
enum HostData {
    F32(Vec<f32>),
    F64(Vec<f64>),
}

/// CPU implementation of [`Evaluator`]: single fused pass per probe, f64
/// accumulators regardless of storage dtype.
///
/// The probe loop is branchless (`min`/`max` selects + boolean counts) and
/// 4-way unrolled so LLVM autovectorizes it — measured 14× over the naive
/// branchy loop at n = 2²² (EXPERIMENTS.md §Perf/L3). This is the paper's
/// "no divergence" point materialized on the CPU substrate.
///
/// Every pass (`probe`, `probe_many`, `init_stats`, `neighbors`,
/// `interval`) additionally fans out across cores with `std::thread::scope`
/// chunking — each worker runs the same branchless kernel on a 4-aligned
/// chunk and the partials combine through the same `merge` used for
/// multi-device shards, so the chunked pass is bit-compatible in counts and
/// tolerance-compatible in sums with a sharded run.
#[derive(Debug, Clone)]
pub struct HostEvaluator {
    data: HostData,
    probes: u64,
    /// Worker threads per pass (1 = sequential; sized from n at build).
    threads: usize,
}

/// Minimum elements per worker before a pass fans out across cores (a
/// thread spawn costs tens of µs; below this the sequential sweep wins).
const PAR_MIN_CHUNK: usize = 1 << 16;

fn default_threads(n: usize) -> usize {
    let hw = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    hw.min(n / PAR_MIN_CHUNK).max(1)
}

/// Run `map` over ≤ `threads` chunks of `data` (aligned to the widest
/// kernel tile, [`LADDER_LANES`], so every worker's unrolled body sees
/// full tiles and only the last chunk carries a remainder) in a thread
/// scope and fold the partials with `merge`.
fn par_reduce<T: Sync, R: Send>(
    data: &[T],
    threads: usize,
    map: impl Fn(&[T]) -> R + Sync,
    merge: impl Fn(R, R) -> R,
) -> R {
    let t = threads.max(1).min(data.len().max(1));
    if t == 1 {
        return map(data);
    }
    let align = LADDER_LANES;
    let chunk = ((data.len().div_ceil(t) + (align - 1)) & !(align - 1)).max(align);
    let partials: Vec<R> = std::thread::scope(|s| {
        let map = &map;
        let handles: Vec<_> = data.chunks(chunk).map(|c| s.spawn(move || map(c))).collect();
        handles
            .into_iter()
            // lint: allow(error_discipline) — join() only fails if a scoped worker panicked; re-raising that panic on the caller thread is the intended propagation
            .map(|h| h.join().expect("host evaluator worker panicked"))
            .collect()
    });
    // lint: allow(error_discipline) — t >= 1 and data is non-empty here (t == 1 early-returns above), so chunks() yields at least one partial
    partials.into_iter().reduce(merge).expect("at least one chunk")
}

macro_rules! probe_kernel {
    ($data:expr, $y:expr) => {{
        let y = $y;
        let mut slo = [0.0f64; 4];
        let mut shi = [0.0f64; 4];
        let mut clt = [0u64; 4];
        let mut cgt = [0u64; 4];
        let mut ceq = [0u64; 4];
        let mut chunks = $data.chunks_exact(4);
        for c in &mut chunks {
            // branchless lane-wise selects; autovectorizes
            for l in 0..4 {
                let d = c[l] as f64 - y;
                slo[l] -= d.min(0.0);
                shi[l] += d.max(0.0);
                clt[l] += (d < 0.0) as u64;
                cgt[l] += (d > 0.0) as u64;
                ceq[l] += (d == 0.0) as u64;
            }
        }
        let mut a = ProbeStats {
            s_lo: slo.iter().sum(),
            s_hi: shi.iter().sum(),
            c_lt: clt.iter().sum(),
            c_eq: ceq.iter().sum(),
            c_gt: cgt.iter().sum(),
        };
        for &x in chunks.remainder() {
            let d = x as f64 - y;
            if d < 0.0 {
                a.s_lo -= d;
                a.c_lt += 1;
            } else if d > 0.0 {
                a.s_hi += d;
                a.c_gt += 1;
            } else if d == 0.0 {
                a.c_eq += 1;
            }
        }
        a
    }};
}

macro_rules! interval_kernel {
    ($data:expr, $lo:expr, $hi:expr) => {{
        let (lo, hi) = ($lo, $hi);
        let mut cle = [0u64; 4];
        let mut cin = [0u64; 4];
        let mut cge = [0u64; 4];
        let mut chunks = $data.chunks_exact(4);
        for c in &mut chunks {
            for l in 0..4 {
                let x = c[l] as f64;
                cle[l] += (x <= lo) as u64;
                cin[l] += ((x > lo) & (x < hi)) as u64;
                cge[l] += (x >= hi) as u64;
            }
        }
        let mut a = IntervalCounts {
            c_le: cle.iter().sum(),
            c_in: cin.iter().sum(),
            c_ge: cge.iter().sum(),
        };
        for &x in chunks.remainder() {
            let x = x as f64;
            if x <= lo {
                a.c_le += 1;
            } else if x < hi {
                a.c_in += 1;
            } else {
                a.c_ge += 1;
            }
        }
        a
    }};
}

macro_rules! neighbors_kernel {
    ($data:expr, $y:expr) => {{
        let y = $y;
        let mut lo = [f64::NEG_INFINITY; 4];
        let mut hi = [f64::INFINITY; 4];
        let mut cle = [0u64; 4];
        let mut chunks = $data.chunks_exact(4);
        for c in &mut chunks {
            for l in 0..4 {
                let x = c[l] as f64;
                let le = x <= y;
                lo[l] = lo[l].max(if le { x } else { f64::NEG_INFINITY });
                hi[l] = hi[l].min(if x >= y { x } else { f64::INFINITY });
                cle[l] += le as u64;
            }
        }
        let mut a = Neighbors {
            lower: lo.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
            upper: hi.iter().cloned().fold(f64::INFINITY, f64::min),
            c_le: cle.iter().sum(),
        };
        for &x in chunks.remainder() {
            let x = x as f64;
            if x <= y {
                a.lower = a.lower.max(x);
                a.c_le += 1;
            }
            if x >= y {
                a.upper = a.upper.min(x);
            }
        }
        a
    }};
}

macro_rules! minmaxsum_kernel {
    ($data:expr) => {{
        let mut mn = [f64::INFINITY; 4];
        let mut mx = [f64::NEG_INFINITY; 4];
        let mut sm = [0.0f64; 4];
        let mut chunks = $data.chunks_exact(4);
        for c in &mut chunks {
            for l in 0..4 {
                let x = c[l] as f64;
                mn[l] = mn[l].min(x);
                mx[l] = mx[l].max(x);
                sm[l] += x;
            }
        }
        let mut a = InitStats {
            min: mn.iter().cloned().fold(f64::INFINITY, f64::min),
            max: mx.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
            sum: sm.iter().sum(),
        };
        for &x in chunks.remainder() {
            let x = x as f64;
            a.min = a.min.min(x);
            a.max = a.max.max(x);
            a.sum += x;
        }
        a
    }};
}

/// Per-chunk partials of one fused ladder pass (`probe_many`): bin `j`
/// holds the count/sum of elements in `(y_{j-1}, y_j]` against the sorted
/// ladder, plus the per-rung equality count. Mergeable across chunks and
/// shards like every other partial in the system. Public so the bench-wall
/// harness and the kernel-parity property tests can drive the two sweep
/// kernels ([`ladder_sweep`], [`ladder_sweep_scalar`]) directly.
#[derive(Debug, Clone, PartialEq)]
pub struct LadderPartial {
    /// `cnt[j]` = elements in `(y_{j-1}, y_j]` (`cnt[p]` = above the top rung).
    pub cnt: Vec<u64>,
    /// `sum[j]` = sum of those elements.
    pub sum: Vec<f64>,
    /// `eq[j]` = elements exactly equal to rung `y_j`.
    pub eq: Vec<u64>,
}

impl LadderPartial {
    pub fn zero(p: usize) -> LadderPartial {
        LadderPartial { cnt: vec![0; p + 1], sum: vec![0.0; p + 1], eq: vec![0; p] }
    }

    pub fn merge(mut self, other: LadderPartial) -> LadderPartial {
        for (a, b) in self.cnt.iter_mut().zip(&other.cnt) {
            *a += b;
        }
        for (a, b) in self.sum.iter_mut().zip(&other.sum) {
            *a += b;
        }
        for (a, b) in self.eq.iter_mut().zip(&other.eq) {
            *a += b;
        }
        self
    }
}

/// Lanes per tile of the vectorized ladder sweep: one AVX-512 f64 compare
/// vector (two AVX2 vectors), and small enough that the lane-private bin
/// columns stay L1-resident at every planned width (`8·(p+2)·16` bytes
/// ≈ 8 KiB at [`gpu_model::MAX_PLANNED_WIDTH`](super::gpu_model::MAX_PLANNED_WIDTH)).
pub const LADDER_LANES: usize = 8;

/// The pre-vectorization reference kernel: one element at a time, scatter
/// into *shared* bins. Two consecutive elements landing in the same bin
/// serialize on the same memory address (a store-to-load dependence ~4–5
/// cycles long), which is what caps the scalar sweep's throughput and why
/// LLVM cannot vectorize it. Kept as the exact-count oracle for the tiled
/// kernel's property tests and as the denominator of the CI perf-smoke
/// speedup gate (see [`ladder_sweep_scalar`]).
macro_rules! ladder_kernel_scalar {
    ($data:expr, $ys:expr) => {{
        let ys: &[f64] = $ys;
        let p = ys.len();
        let mut part = LadderPartial::zero(p);
        for &x in $data {
            let x = x as f64;
            if x.is_nan() {
                continue; // match probe(): NaN elements fall through uncounted
            }
            // Branchless ladder scan: b = #{y in ladder : y < x}, i.e. the
            // bin (y_{b-1}, y_b] the element falls into. Linear in p, which
            // is small (≲ 64); a binary search would branch.
            let mut b = 0usize;
            for &y in ys {
                b += (y < x) as usize;
            }
            part.cnt[b] += 1;
            part.sum[b] += x;
            if b < p && ys[b] == x {
                part.eq[b] += 1;
            }
        }
        part
    }};
}

/// The tiled, lane-split ladder sweep — the `probe_many` hot kernel.
///
/// Two restructurings over [`ladder_kernel_scalar!`], both needed:
///
/// 1. **Branchless bin indices per lane.** The `b += (y < x)` rung scan is
///    hoisted into a lane-wise loop over a [`LADDER_LANES`]-element tile,
///    so the inner loop is a fixed-width compare over 8 independent lanes —
///    the shape LLVM turns into SIMD compares (`vcmpltpd`/`vpsubq` on
///    AVX2+). This is the O(n·p) term of the sweep.
/// 2. **Lane-private accumulators.** Each lane scatters into its own
///    column of a bin-major `(p+2)×LANES` accumulator block
///    (`cnt[bin·LANES + lane]`), so consecutive elements *never* write the
///    same address even when they land in the same bin — the scalar
///    kernel's store-to-load dependence is gone and the O(n) scatter
///    pipelines. The columns merge once per chunk (O(p), amortized to
///    nothing), and the chunk partials merge through the same
///    [`LadderPartial::merge`] the multi-device shards use.
///
/// Slot `p+1` of the block is a trash bin for NaN elements: every rung
/// compare is false on NaN, so `b = 0` — rerouting to the discarded slot
/// keeps NaN elements uncounted (matching the scalar oracle and the device
/// kernels) without a branch in the scatter. Counts (`cnt`, `eq`) are
/// bit-identical to the scalar kernel; `sum` reassociates per lane, so it
/// carries the usual O(ε·Σ|x|) chunked-summation bound — the same contract
/// as the multi-threaded and sharded paths.
macro_rules! ladder_kernel {
    ($data:expr, $ys:expr) => {{
        let ys: &[f64] = $ys;
        let p = ys.len();
        const L: usize = LADDER_LANES;
        let mut cnt = vec![0u64; (p + 2) * L];
        let mut sum = vec![0.0f64; (p + 2) * L];
        let mut eq = vec![0u64; p.max(1) * L];
        let mut x = [0.0f64; L];
        let mut b = [0usize; L];
        let mut tiles = $data.chunks_exact(L);
        for tile in &mut tiles {
            for l in 0..L {
                x[l] = tile[l] as f64;
                b[l] = 0;
            }
            for &y in ys {
                for l in 0..L {
                    b[l] += (y < x[l]) as usize; // SIMD compare across lanes
                }
            }
            for l in 0..L {
                let bin = if x[l].is_nan() { p + 1 } else { b[l] };
                cnt[bin * L + l] += 1;
                sum[bin * L + l] += x[l];
                if bin < p && ys[bin] == x[l] {
                    eq[bin * L + l] += 1;
                }
            }
        }
        // Merge the lane columns once per chunk (bins 0..=p; the NaN trash
        // slot p+1 is dropped)…
        let mut part = LadderPartial::zero(p);
        for j in 0..=p {
            let mut c = 0u64;
            let mut s = 0.0f64;
            for l in 0..L {
                c += cnt[j * L + l];
                s += sum[j * L + l];
            }
            part.cnt[j] = c;
            part.sum[j] = s;
        }
        for (j, e) in part.eq.iter_mut().enumerate() {
            *e = eq[j * L..(j + 1) * L].iter().sum();
        }
        // …and fold the sub-tile remainder through the scalar kernel.
        part.merge(ladder_kernel_scalar!(tiles.remainder(), ys))
    }};
}

/// One vectorized binned sweep of `data` against the sorted rung ladder
/// `ys` (sequential; `probe_many` fans the same kernel across cores).
/// Public entry point for the bench-wall throughput harness and the
/// kernel-parity property tests.
pub fn ladder_sweep(data: &[f64], ys: &[f64]) -> LadderPartial {
    ladder_kernel!(data, ys)
}

/// The scalar reference sweep (see [`ladder_kernel_scalar!`]): the exact
/// oracle [`ladder_sweep`] is pinned against, and the baseline the CI
/// perf-smoke leg requires the vectorized kernel to beat by ≥ 1.5×.
pub fn ladder_sweep_scalar(data: &[f64], ys: &[f64]) -> LadderPartial {
    ladder_kernel_scalar!(data, ys)
}

/// Recover per-probe sufficient statistics from the bin partials:
/// `c_le(y_j) = Σ_{i≤j} cnt_i` by prefix summation, then
/// `s_lo = y·c_lt − Σ_{x<y} x` and `s_hi = Σ_{x>y} x − y·c_gt`. The high
/// side uses **suffix** sums (not `total − prefix`), so each side's
/// rounding error scales only with its own mass — an outlier below a probe
/// cannot cancel away that probe's s_hi. Counts are exact regardless; the
/// sums carry the usual sum-then-subtract error bound `O(ε·Σ_side |x|)`,
/// vs the sequential kernel's `O(ε·Σ_side |x−y|)`.
fn compose_ladder(ys: &[f64], part: &LadderPartial) -> Vec<ProbeStats> {
    let p = ys.len();
    let mut c_gt_suf = vec![0u64; p];
    let mut s_gt_suf = vec![0.0f64; p];
    let mut cacc = 0u64;
    let mut sacc = 0.0f64;
    for j in (1..=p).rev() {
        cacc += part.cnt[j];
        sacc += part.sum[j];
        c_gt_suf[j - 1] = cacc;
        s_gt_suf[j - 1] = sacc;
    }
    let mut out = Vec::with_capacity(p);
    let mut c_le = 0u64;
    let mut sum_le = 0.0f64;
    for (j, &y) in ys.iter().enumerate() {
        c_le += part.cnt[j];
        sum_le += part.sum[j];
        let c_eq = part.eq[j];
        let c_lt = c_le - c_eq;
        let c_gt = c_gt_suf[j];
        // (branch also avoids inf·0 = NaN for an infinite probe value)
        let sum_lt = if c_eq == 0 { sum_le } else { sum_le - y * c_eq as f64 };
        // Guard the empty sides: avoids inf·0 = NaN for infinite probes and
        // keeps the mathematically-zero sums exactly zero.
        let s_lo = if c_lt == 0 { 0.0 } else { (y * c_lt as f64 - sum_lt).max(0.0) };
        let s_hi = if c_gt == 0 {
            0.0
        } else {
            (s_gt_suf[j] - y * c_gt as f64).max(0.0)
        };
        out.push(ProbeStats { s_lo, s_hi, c_lt, c_eq, c_gt });
    }
    out
}

impl HostEvaluator {
    /// f64 storage.
    pub fn new(data: &[f64]) -> Self {
        Self {
            data: HostData::F64(data.to_vec()),
            probes: 0,
            threads: default_threads(data.len()),
        }
    }

    /// f32 storage (values rounded to f32, as on a single-precision device).
    pub fn new_f32(data: &[f64]) -> Self {
        Self {
            data: HostData::F32(data.iter().map(|&v| v as f32).collect()),
            probes: 0,
            threads: default_threads(data.len()),
        }
    }

    pub fn from_f32(data: Vec<f32>) -> Self {
        let threads = default_threads(data.len());
        Self { data: HostData::F32(data), probes: 0, threads }
    }

    /// Override the per-pass worker count (tests force multi-threaded
    /// chunking on small arrays; 1 restores the sequential sweep).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn into_f64_vec(self) -> Vec<f64> {
        match self.data {
            HostData::F64(v) => v,
            HostData::F32(v) => v.into_iter().map(|x| x as f64).collect(),
        }
    }

}

impl Evaluator for HostEvaluator {
    fn n(&self) -> usize {
        match &self.data {
            HostData::F64(v) => v.len(),
            HostData::F32(v) => v.len(),
        }
    }

    fn dtype(&self) -> DType {
        match &self.data {
            HostData::F64(_) => DType::F64,
            HostData::F32(_) => DType::F32,
        }
    }

    fn init_stats(&mut self) -> Result<InitStats> {
        if self.n() == 0 {
            return Err(invalid_arg!("empty input"));
        }
        self.probes += 1;
        let t = self.threads;
        Ok(match &self.data {
            HostData::F64(v) => {
                par_reduce(v, t, |c| minmaxsum_kernel!(c), |a, b| a.merge(&b))
            }
            HostData::F32(v) => {
                par_reduce(v, t, |c| minmaxsum_kernel!(c), |a, b| a.merge(&b))
            }
        })
    }

    fn probe(&mut self, y: f64) -> Result<ProbeStats> {
        self.probes += 1;
        let y = self.canon(y); // f32 storage compares in f32, like a device
        let t = self.threads;
        // NaN differences fall through uncounted in both the unrolled and
        // the remainder loop — matching the device kernels, whose
        // comparisons are all false on NaN.
        Ok(match &self.data {
            HostData::F64(v) => par_reduce(v, t, |c| probe_kernel!(c, y), |a, b| a.merge(&b)),
            HostData::F32(v) => par_reduce(v, t, |c| probe_kernel!(c, y), |a, b| a.merge(&b)),
        })
    }

    fn probe_many(&mut self, ys: &[f64]) -> Result<Vec<ProbeStats>> {
        if ys.is_empty() {
            return Ok(Vec::new());
        }
        self.probes += 1; // the whole ladder is ONE fused pass
        let (canon, ladder) = fused_ladder_rungs(ys, self.dtype());
        if ladder.is_empty() {
            // all-NaN ladder, like probe(NaN)
            return Ok(ladder_stats_in_probe_order(&canon, &ladder, &[]));
        }
        let t = self.threads;
        let rungs = &ladder;
        let part = match &self.data {
            HostData::F64(v) => {
                par_reduce(v, t, |c| ladder_kernel!(c, rungs), LadderPartial::merge)
            }
            HostData::F32(v) => {
                par_reduce(v, t, |c| ladder_kernel!(c, rungs), LadderPartial::merge)
            }
        };
        let stats = compose_ladder(&ladder, &part);
        // Back to the caller's probe order; duplicates share one rung.
        Ok(ladder_stats_in_probe_order(&canon, &ladder, &stats))
    }

    fn neighbors(&mut self, y: f64) -> Result<Neighbors> {
        self.probes += 1;
        let y = self.canon(y);
        let t = self.threads;
        Ok(match &self.data {
            HostData::F64(v) => par_reduce(v, t, |c| neighbors_kernel!(c, y), |a, b| a.merge(&b)),
            HostData::F32(v) => par_reduce(v, t, |c| neighbors_kernel!(c, y), |a, b| a.merge(&b)),
        })
    }

    fn interval(&mut self, lo: f64, hi: f64) -> Result<IntervalCounts> {
        self.probes += 1;
        let (lo, hi) = (self.canon(lo), self.canon(hi));
        let t = self.threads;
        Ok(match &self.data {
            HostData::F64(v) => {
                par_reduce(v, t, |c| interval_kernel!(c, lo, hi), |a, b| a.merge(&b))
            }
            HostData::F32(v) => {
                par_reduce(v, t, |c| interval_kernel!(c, lo, hi), |a, b| a.merge(&b))
            }
        })
    }

    fn compact(&mut self, lo: f64, hi: f64) -> Result<Vec<f64>> {
        let (lo, hi) = (self.canon(lo), self.canon(hi));
        // Branchless stream compaction (predicated write-index advance):
        // 8× over the push loop at n = 2²² (EXPERIMENTS.md §Perf/L3).
        let mut out = vec![0.0f64; self.n()];
        let mut idx = 0usize;
        match &self.data {
            HostData::F64(v) => {
                for &x in v {
                    out[idx] = x;
                    idx += ((x > lo) & (x < hi)) as usize;
                }
            }
            HostData::F32(v) => {
                for &x in v {
                    let x = x as f64;
                    out[idx] = x;
                    idx += ((x > lo) & (x < hi)) as usize;
                }
            }
        }
        out.truncate(idx);
        Ok(out)
    }

    fn download(&mut self) -> Result<Vec<f64>> {
        Ok(match &self.data {
            HostData::F64(v) => v.clone(),
            HostData::F32(v) => v.iter().map(|&x| x as f64).collect(),
        })
    }

    fn probes(&self) -> u64 {
        self.probes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(data: &[f64]) -> HostEvaluator {
        HostEvaluator::new(data)
    }

    #[test]
    fn probe_stats_basic() {
        let mut e = ev(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        let s = e.probe(3.0).unwrap();
        assert_eq!(s, ProbeStats { s_lo: 3.0, s_hi: 3.0, c_lt: 2, c_eq: 1, c_gt: 2 });
        assert_eq!(s.c_le(), 3);
        assert_eq!(s.n(), 5);
    }

    #[test]
    fn median_objective_is_eq1() {
        let spec = ObjectiveSpec::median(5).unwrap();
        assert_eq!(spec.k, 3);
        assert!((spec.w_lo - 1.0).abs() < 1e-15);
        assert!((spec.w_hi - 1.0).abs() < 1e-15);
        let mut e = ev(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        let s = e.probe(3.0).unwrap();
        // f(3) = |1-3|+|2-3|+0+|4-3|+|5-3| = 6
        assert!((spec.f(&s) - 6.0).abs() < 1e-12);
        assert!(spec.is_optimal(&s));
        assert_eq!(spec.g_point(&s), 0.0);
    }

    #[test]
    fn subgradient_sign_tracks_rank() {
        let data = [10.0, 20.0, 30.0, 40.0];
        for k in 1..=4 {
            let spec = ObjectiveSpec::order(4, k).unwrap();
            let mut e = ev(&data);
            let probes =
                [(5.0, true), (15.0, k > 1), (25.0, k > 2), (35.0, k > 3), (45.0, false)];
            for (y, below) in probes {
                let s = e.probe(y).unwrap();
                assert_eq!(spec.answer_above(&s), below, "k={k} y={y}");
            }
            // optimality exactly at the k-th element
            for (i, &v) in data.iter().enumerate() {
                let s = e.probe(v).unwrap();
                assert_eq!(spec.is_optimal(&s), i + 1 == k, "k={k} v={v}");
            }
        }
    }

    #[test]
    fn optimality_with_duplicates() {
        let data = [1.0, 2.0, 2.0, 2.0, 7.0];
        let mut e = ev(&data);
        let s = e.probe(2.0).unwrap();
        for k in 2..=4 {
            let spec = ObjectiveSpec::order(5, k).unwrap();
            assert!(spec.is_optimal(&s), "k={k}");
        }
        assert!(!ObjectiveSpec::order(5, 1).unwrap().is_optimal(&s));
        assert!(!ObjectiveSpec::order(5, 5).unwrap().is_optimal(&s));
    }

    #[test]
    fn seed_matches_direct_evaluation() {
        let data = [3.0, -1.0, 4.0, 1.5, 9.0, 2.5];
        let n = data.len();
        let spec = ObjectiveSpec::median(n).unwrap();
        let mut e = ev(&data);
        let init = e.init_stats().unwrap();
        let seed = spec.seed(&init);
        assert_eq!(seed.y_l, -1.0);
        assert_eq!(seed.y_r, 9.0);
        // f at the extremes equals the directly probed objective
        let s_l = e.probe(seed.y_l).unwrap();
        let s_r = e.probe(seed.y_r).unwrap();
        assert!((seed.f_l - spec.f(&s_l)).abs() < 1e-9, "{} vs {}", seed.f_l, spec.f(&s_l));
        assert!((seed.f_r - spec.f(&s_r)).abs() < 1e-9);
        // seed subgradients are valid: within the true subdifferential
        let (gl_lo, gl_hi) = spec.g(&s_l);
        assert!(seed.g_l >= gl_lo - 1e-12 && seed.g_l <= gl_hi + 1e-12);
        let (gr_lo, gr_hi) = spec.g(&s_r);
        assert!(seed.g_r >= gr_lo - 1e-12 && seed.g_r <= gr_hi + 1e-12);
    }

    #[test]
    fn seed_subgradient_valid_with_duplicate_extremes() {
        let data = [1.0, 1.0, 1.0, 5.0, 9.0, 9.0];
        let spec = ObjectiveSpec::median(6).unwrap();
        let mut e = ev(&data);
        let init = e.init_stats().unwrap();
        let seed = spec.seed(&init);
        let s_l = e.probe(1.0).unwrap();
        let (lo, hi) = spec.g(&s_l);
        assert!(seed.g_l >= lo && seed.g_l <= hi, "{} not in [{lo},{hi}]", seed.g_l);
        let s_r = e.probe(9.0).unwrap();
        let (lo, hi) = spec.g(&s_r);
        assert!(seed.g_r >= lo && seed.g_r <= hi);
    }

    #[test]
    fn neighbors_and_interval() {
        let mut e = ev(&[1.0, 3.0, 3.0, 8.0]);
        let nb = e.neighbors(4.0).unwrap();
        assert_eq!(nb, Neighbors { lower: 3.0, upper: 8.0, c_le: 3 });
        let nb = e.neighbors(3.0).unwrap();
        assert_eq!(nb, Neighbors { lower: 3.0, upper: 3.0, c_le: 3 });
        let ic = e.interval(1.0, 8.0).unwrap();
        assert_eq!(ic, IntervalCounts { c_le: 1, c_in: 2, c_ge: 1 });
        assert_eq!(e.compact(1.0, 8.0).unwrap(), vec![3.0, 3.0]);
    }

    #[test]
    fn f32_storage_rounds_values() {
        let mut e = HostEvaluator::new_f32(&[0.1, 0.2, 0.3]);
        assert_eq!(e.dtype(), DType::F32);
        let s = e.probe(0.1f32 as f64).unwrap();
        assert_eq!(s.c_eq, 1);
    }

    #[test]
    fn merge_combines_shard_stats() {
        let mut a = ev(&[1.0, 2.0]);
        let mut b = ev(&[3.0, 4.0]);
        let mut whole = ev(&[1.0, 2.0, 3.0, 4.0]);
        let y = 2.5;
        let m = a.probe(y).unwrap().merge(&b.probe(y).unwrap());
        assert_eq!(m, whole.probe(y).unwrap());
        let m = a
            .init_stats()
            .unwrap()
            .merge(&b.init_stats().unwrap());
        assert_eq!(m, whole.init_stats().unwrap());
        let m = a.neighbors(y).unwrap().merge(&b.neighbors(y).unwrap());
        assert_eq!(m, whole.neighbors(y).unwrap());
        let m = a
            .interval(1.5, 3.5)
            .unwrap()
            .merge(&b.interval(1.5, 3.5).unwrap());
        assert_eq!(m, whole.interval(1.5, 3.5).unwrap());
    }

    #[test]
    fn probe_counter_increments() {
        let mut e = ev(&[1.0, 2.0]);
        assert_eq!(e.probes(), 0);
        e.probe(0.0).unwrap();
        e.init_stats().unwrap();
        e.neighbors(0.0).unwrap();
        e.interval(0.0, 1.0).unwrap();
        assert_eq!(e.probes(), 4);
    }

    #[test]
    fn order_spec_rejects_bad_k() {
        assert!(ObjectiveSpec::order(5, 0).is_err());
        assert!(ObjectiveSpec::order(5, 6).is_err());
        assert!(ObjectiveSpec::order(0, 1).is_err());
    }

    fn assert_stats_close(a: &ProbeStats, b: &ProbeStats, scale: f64, ctx: &str) {
        assert_eq!((a.c_lt, a.c_eq, a.c_gt), (b.c_lt, b.c_eq, b.c_gt), "{ctx}");
        let tol = 1e-9 * scale.max(1.0);
        assert!((a.s_lo - b.s_lo).abs() <= tol, "{ctx}: s_lo {} vs {}", a.s_lo, b.s_lo);
        assert!((a.s_hi - b.s_hi).abs() <= tol, "{ctx}: s_hi {} vs {}", a.s_hi, b.s_hi);
    }

    #[test]
    fn probe_many_matches_sequential_probes() {
        let data = [3.0, -1.0, 4.0, 1.5, 9.0, 2.5, 2.5, 2.5, -7.0];
        // unsorted ladder with duplicates, data values, and out-of-range probes
        let ys = [2.5, -100.0, 9.0, 2.5, 0.0, 100.0, 3.7];
        let mut fused = ev(&data);
        let batch = fused.probe_many(&ys).unwrap();
        assert_eq!(batch.len(), ys.len());
        let mut seq = ev(&data);
        for (y, got) in ys.iter().zip(&batch) {
            let want = seq.probe(*y).unwrap();
            assert_stats_close(got, &want, 1e3, &format!("y={y}"));
        }
        assert_eq!(fused.probes(), 1, "whole ladder must be one fused pass");
    }

    #[test]
    fn probe_many_f32_quantizes_like_probe() {
        let data = [0.1, 0.2, 0.3, 0.2, 0.7];
        let ys = [0.2, 0.1000000001, 0.65];
        let mut fused = HostEvaluator::new_f32(&data);
        let batch = fused.probe_many(&ys).unwrap();
        let mut seq = HostEvaluator::new_f32(&data);
        for (y, got) in ys.iter().zip(&batch) {
            let want = seq.probe(*y).unwrap();
            assert_stats_close(got, &want, 1.0, &format!("f32 y={y}"));
        }
        // 0.2 is a data value in f32: equality must be detected
        assert_eq!(batch[0].c_eq, 2);
    }

    #[test]
    fn probe_many_handles_nan_and_infinite_probes() {
        let data = [1.0, 2.0, 3.0];
        let mut e = ev(&data);
        let batch = e.probe_many(&[f64::NAN, f64::INFINITY, f64::NEG_INFINITY]).unwrap();
        let mut seq = ev(&data);
        assert_eq!(batch[0], seq.probe(f64::NAN).unwrap());
        assert_eq!(
            (batch[1].c_lt, batch[1].c_eq, batch[1].c_gt),
            (3, 0, 0),
            "+inf probe sees everything below"
        );
        assert_eq!(batch[1].s_lo, f64::INFINITY);
        assert_eq!(batch[1].s_hi, 0.0);
        assert_eq!((batch[2].c_lt, batch[2].c_eq, batch[2].c_gt), (0, 0, 3));
        assert_eq!(batch[2].s_lo, 0.0);
        assert_eq!(batch[2].s_hi, f64::INFINITY);
    }

    #[test]
    fn probe_many_skips_nan_data_like_probe() {
        let data = [1.0, f64::NAN, 3.0, f64::NAN, 5.0];
        let mut fused = ev(&data);
        let batch = fused.probe_many(&[0.0, 3.0, 9.0]).unwrap();
        let mut seq = ev(&data);
        for (y, got) in [0.0, 3.0, 9.0].iter().zip(&batch) {
            assert_eq!(*got, seq.probe(*y).unwrap(), "y={y}");
        }
    }

    #[test]
    fn forced_multithreading_matches_sequential() {
        // deterministic pseudo-random data, small enough to run everywhere,
        // forced onto 4 workers so the chunk/merge path actually executes
        let data: Vec<f64> = (0u64..1003)
            .map(|i| ((i * 2654435761 % 1000) as f64) / 10.0 - 40.0)
            .collect();
        let mut par = ev(&data).with_threads(4);
        let mut seq = ev(&data).with_threads(1);
        assert_eq!(par.threads(), 4);
        for y in [-100.0, -3.5, 0.0, 17.3, 99.0] {
            let a = par.probe(y).unwrap();
            let b = seq.probe(y).unwrap();
            assert_stats_close(&a, &b, 1e5, &format!("probe y={y}"));
            assert_eq!(par.neighbors(y).unwrap(), seq.neighbors(y).unwrap(), "y={y}");
        }
        let (ia, ib) = (par.init_stats().unwrap(), seq.init_stats().unwrap());
        assert_eq!((ia.min, ia.max), (ib.min, ib.max));
        assert!((ia.sum - ib.sum).abs() <= 1e-9 * ib.sum.abs().max(1.0));
        assert_eq!(par.interval(-3.0, 40.0).unwrap(), seq.interval(-3.0, 40.0).unwrap());
        let ys = [-5.0, 0.0, 13.37, 55.5];
        let ba = par.probe_many(&ys).unwrap();
        let bb = seq.probe_many(&ys).unwrap();
        for ((a, b), y) in ba.iter().zip(&bb).zip(&ys) {
            assert_stats_close(a, b, 1e5, &format!("probe_many y={y}"));
        }
    }

    #[test]
    fn ladder_partials_merge_like_shards() {
        // chunk-split ladder partials must match the unsplit pass exactly in
        // counts — the same guarantee ProbeStats::merge gives across shards
        let data: Vec<f64> = (0..257).map(|i| (i % 17) as f64).collect();
        let ys = [0.0, 3.0, 8.5, 16.0];
        let whole = ladder_kernel!(&data[..], &ys[..]);
        let split = ladder_kernel!(&data[..100], &ys[..])
            .merge(ladder_kernel!(&data[100..], &ys[..]));
        assert_eq!(whole.cnt, split.cnt);
        assert_eq!(whole.eq, split.eq);
        for (a, b) in whole.sum.iter().zip(&split.sum) {
            assert!((a - b).abs() <= 1e-9);
        }
        let sa = compose_ladder(&ys, &whole);
        let sb = compose_ladder(&ys, &split);
        assert_eq!(sa, sb);
    }

    #[test]
    fn probe_many_empty_ladder() {
        let mut e = ev(&[1.0, 2.0]);
        assert!(e.probe_many(&[]).unwrap().is_empty());
        assert_eq!(e.probes(), 0, "empty batch is not a pass");
    }
}
