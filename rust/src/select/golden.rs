//! Golden-section minimization of the objective (paper §III, method 2).
//!
//! Uses only objective *values* — no subgradients — so it cannot skip the
//! flat linear pieces created by outliers; the paper found it uniformly
//! inferior to Brent and excluded it from the final comparison. We keep it
//! as an ablation baseline.

use super::exact;
use super::objective::{Evaluator, ObjectiveSpec};
use crate::util::PhaseTimer;
use crate::Result;

const INV_PHI: f64 = 0.618_033_988_749_894_9; // (√5 − 1)/2

#[derive(Debug, Clone)]
pub struct GoldenOptions {
    pub max_iters: usize,
    pub tol: f64,
}

impl Default for GoldenOptions {
    fn default() -> Self {
        GoldenOptions { max_iters: 300, tol: 1e-12 }
    }
}

#[derive(Debug, Clone)]
pub struct GoldenOutcome {
    pub value: f64,
    pub iterations: usize,
    pub phases: PhaseTimer,
}

pub fn golden_section(
    ev: &mut dyn Evaluator,
    k: usize,
    opts: &GoldenOptions,
) -> Result<GoldenOutcome> {
    golden_section_cancellable(ev, k, opts, &mut || None)
}

/// [`golden_section`] with a cooperative cancellation hook, polled at
/// every pass boundary (before each probe reduction) — never mid-pass.
pub fn golden_section_cancellable(
    ev: &mut dyn Evaluator,
    k: usize,
    opts: &GoldenOptions,
    cancel: &mut dyn FnMut() -> Option<crate::Error>,
) -> Result<GoldenOutcome> {
    let n = ev.n();
    let spec = ObjectiveSpec::order(n, k)?;
    let mut phases = PhaseTimer::new();

    let init = phases.time("iterations", || ev.init_stats())?;
    let (mut a, mut b) = (init.min, init.max);
    if a == b || k == 1 || k == n {
        let v = if k == n { b } else { a };
        return Ok(GoldenOutcome { value: v, iterations: 0, phases });
    }

    let mut c = b - INV_PHI * (b - a);
    let mut d = a + INV_PHI * (b - a);
    let mut fc = spec.f(&phases.time("iterations", || ev.probe(c))?);
    let mut fd = spec.f(&phases.time("iterations", || ev.probe(d))?);
    let mut iterations = 2;

    while iterations < opts.max_iters {
        if let Some(err) = cancel() {
            return Err(err);
        }
        if (b - a) <= opts.tol * a.abs().max(b.abs()).max(1.0) {
            break;
        }
        if fc < fd {
            b = d;
            d = c;
            fd = fc;
            c = b - INV_PHI * (b - a);
            if c >= d {
                break; // interval exhausted
            }
            fc = spec.f(&phases.time("iterations", || ev.probe(c))?);
        } else {
            a = c;
            c = d;
            fc = fd;
            d = a + INV_PHI * (b - a);
            if d <= c {
                break;
            }
            fd = spec.f(&phases.time("iterations", || ev.probe(d))?);
        }
        iterations += 1;
    }

    let approx = 0.5 * (a + b);
    let value = phases.time("exact_fixup", || exact::resolve(ev, k, approx))?;
    Ok(GoldenOutcome { value, iterations, phases })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::select::objective::HostEvaluator;
    use crate::stats::{sorted_median, Distribution, Rng};
    use crate::util::median_rank;

    #[test]
    fn matches_oracle() {
        let mut rng = Rng::seeded(41);
        for d in [Distribution::Uniform, Distribution::Normal, Distribution::Mixture1] {
            let data = d.sample_vec(&mut rng, 1024);
            let mut ev = HostEvaluator::new(&data);
            let out =
                golden_section(&mut ev, median_rank(1024), &GoldenOptions::default()).unwrap();
            assert_eq!(out.value, sorted_median(&data), "{}", d.name());
        }
    }

    #[test]
    fn needs_more_probes_than_cutting_plane() {
        // the paper's rationale for discarding golden section
        let mut rng = Rng::seeded(42);
        let data = Distribution::Normal.sample_vec(&mut rng, 8192);
        let k = median_rank(8192);

        let mut ev_g = HostEvaluator::new(&data);
        golden_section(&mut ev_g, k, &GoldenOptions::default()).unwrap();
        let mut ev_c = HostEvaluator::new(&data);
        crate::select::cutting_plane::cutting_plane(
            &mut ev_c,
            k,
            &crate::select::cutting_plane::CpOptions::default(),
        )
        .unwrap();
        assert!(
            ev_g.probes() > ev_c.probes(),
            "golden {} probes vs cp {}",
            ev_g.probes(),
            ev_c.probes()
        );
    }

    #[test]
    fn duplicate_heavy_data() {
        let data = [3.0, 3.0, 3.0, 1.0, 9.0, 3.0, 3.0];
        let mut ev = HostEvaluator::new(&data);
        let out = golden_section(&mut ev, 4, &GoldenOptions::default()).unwrap();
        assert_eq!(out.value, 3.0);
    }
}
