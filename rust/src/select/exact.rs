//! Exact-rank resolution from an approximate minimizer.
//!
//! The cutting plane (and every other convex-minimization method) converges
//! to an approximation ỹ of the order statistic; the paper (footnote 1)
//! finishes with one more reduction selecting the largest `x_i ≤ ỹ`. With
//! duplicates, even-n flat regions, and far-off starting points this needs
//! care; we use rank-guided value bisection:
//!
//! 1. probe ỹ — if `c_lt < k ≤ c_lt + c_eq` the probe *is* the k-th
//!    smallest (one reduction, the common case after convergence);
//! 2. otherwise bracket the answer between values with ranks straddling k
//!    and bisect; whenever the bracket is plausibly tight, a `neighbors`
//!    reduction snaps to the largest data value `≤ hi`, verified by rank.
//!
//! Every query is a device reduction; the counter tests assert the common
//! path stays within a handful of probes.

use super::objective::Evaluator;
use crate::{algo_err, Result};

/// Hard cap on bisection steps. Value bisection over the f64 range reaches
/// adjacent floats in ≲ 2100 halvings; snap checks fire long before.
const MAX_STEPS: usize = 4096;

/// Bisection rounds between snap attempts.
const SNAP_EVERY: usize = 8;

/// Resolve the exact k-th smallest element starting from the approximation
/// `y`. Returns the exact order statistic (a data value).
pub fn resolve(ev: &mut dyn Evaluator, k: usize, y: f64) -> Result<f64> {
    resolve_with_bracket(ev, k, y, None)
}

/// Like [`resolve`], seeded with a value bracket known (or strongly
/// believed) to contain the k-th order statistic — e.g. the cutting-plane
/// bracket. A stale bracket still terminates correctly: bisection collapses
/// onto the boundary and the rank-verified snap rejects wrong values.
pub fn resolve_with_bracket(
    ev: &mut dyn Evaluator,
    k: usize,
    y: f64,
    bracket: Option<(f64, f64)>,
) -> Result<f64> {
    let n = ev.n();
    if k == 0 || k > n {
        return Err(crate::invalid_arg!("k={k} out of range for n={n}"));
    }
    let y = if y.is_nan() { 0.0 } else { y };

    // Fast path: the approximation already has rank k.
    let s = ev.probe(y)?;
    if rank_ok(&s, k) {
        // rank_ok with c_eq > 0 means the probe equals a data value in the
        // array's dtype — return the canonical (dtype-quantized) value.
        return Ok(ev.canon(y));
    }

    // Establish a rank bracket: c_le(lo) < k <= c_le(hi).
    let (lo, hi);
    if let Some((bl, bh)) = bracket {
        if (s.c_lt + s.c_eq) as usize >= k {
            lo = bl.min(y);
            hi = y.min(bh);
        } else {
            lo = y.max(bl);
            hi = bh.max(y);
        }
        if !lo.is_finite() || !hi.is_finite() || lo >= hi {
            return resolve_with_bracket(ev, k, y, None);
        }
    } else if (s.c_lt + s.c_eq) as usize >= k {
        let init = ev.init_stats()?;
        hi = y.min(init.max);
        lo = f64::next_down(init.min); // c_le = 0 < k
        if init.min >= hi {
            // y is at/below the minimum; answer must be the minimum itself
            return snap(ev, k, init.min);
        }
    } else {
        let init = ev.init_stats()?;
        lo = y.max(f64::next_down(init.min));
        hi = init.max; // c_le = n >= k
        if lo >= hi {
            return snap(ev, k, init.max);
        }
    }

    let out = bisect_resolve(ev, k, lo, hi);
    if out.is_err() && bracket.is_some() {
        // Stale bracket hint — retry against the full data range.
        return resolve_with_bracket(ev, k, y, None);
    }
    out
}

fn bisect_resolve(ev: &mut dyn Evaluator, k: usize, mut lo: f64, mut hi: f64) -> Result<f64> {
    for step in 0..MAX_STEPS {
        // Periodic snap: one neighbors reduction often finishes the job.
        if step % SNAP_EVERY == SNAP_EVERY - 1 {
            if let Some(v) = try_snap(ev, k, hi)? {
                return Ok(v);
            }
        }
        let mid = 0.5 * (lo + hi);
        if !(mid > lo && mid < hi) {
            // Bracket reached adjacent floats.
            return snap(ev, k, hi);
        }
        let s = ev.probe(mid)?;
        if rank_ok(&s, k) {
            return Ok(ev.canon(mid));
        }
        if ((s.c_lt + s.c_eq) as usize) < k {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    Err(algo_err!("exact resolution did not converge (k={k})"))
}

#[inline]
fn rank_ok(s: &super::objective::ProbeStats, k: usize) -> bool {
    (s.c_lt as usize) < k && k <= (s.c_lt + s.c_eq) as usize
}

/// The candidate answer is the largest data value ≤ hi; verify by rank.
fn try_snap(ev: &mut dyn Evaluator, k: usize, hi: f64) -> Result<Option<f64>> {
    let nb = ev.neighbors(hi)?;
    if !nb.lower.is_finite() {
        return Ok(None);
    }
    let s = ev.probe(nb.lower)?;
    if rank_ok(&s, k) {
        return Ok(Some(nb.lower));
    }
    Ok(None)
}

fn snap(ev: &mut dyn Evaluator, k: usize, hi: f64) -> Result<f64> {
    if let Some(v) = try_snap(ev, k, hi)? {
        return Ok(v);
    }
    // hi itself may sit just below the answer (rounding at adjacent
    // floats): look one data value up.
    let nb = ev.neighbors(hi)?;
    if nb.upper.is_finite() {
        let s = ev.probe(nb.upper)?;
        if rank_ok(&s, k) {
            return Ok(nb.upper);
        }
    }
    Err(algo_err!("rank snap failed near {hi} (k={k})"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::select::objective::HostEvaluator;
    use crate::stats::{sorted_order_statistic, Distribution, Rng};

    #[test]
    fn resolves_from_nearby_point() {
        let data = [5.0, 1.0, 9.0, 3.0, 7.0];
        for k in 1..=5 {
            let want = sorted_order_statistic(&data, k);
            for start in [want, want - 0.4, want + 0.4, 0.0, 10.0] {
                let mut ev = HostEvaluator::new(&data);
                let got = resolve(&mut ev, k, start).unwrap();
                assert_eq!(got, want, "k={k} start={start}");
            }
        }
    }

    #[test]
    fn resolves_with_heavy_duplicates() {
        let data = [2.0, 2.0, 2.0, 2.0, 1.0, 3.0, 2.0, 2.0];
        for k in 1..=8 {
            let want = sorted_order_statistic(&data, k);
            let mut ev = HostEvaluator::new(&data);
            assert_eq!(resolve(&mut ev, k, 2.0).unwrap(), want, "k={k}");
        }
    }

    #[test]
    fn resolves_even_n_flat_region() {
        // even n: starting inside the flat [x_(n/2), x_(n/2+1)] region
        let data = [1.0, 2.0, 8.0, 9.0];
        let mut ev = HostEvaluator::new(&data);
        assert_eq!(resolve(&mut ev, 2, 5.0).unwrap(), 2.0);
        let mut ev = HostEvaluator::new(&data);
        assert_eq!(resolve(&mut ev, 3, 5.0).unwrap(), 8.0);
    }

    #[test]
    fn random_fuzz_against_sort() {
        let mut rng = Rng::seeded(11);
        for trial in 0..100 {
            let n = 1 + rng.below(300);
            let d = Distribution::ALL[trial % 9];
            let data = d.sample_vec(&mut rng, n);
            let k = 1 + rng.below(n);
            let want = sorted_order_statistic(&data, k);
            let start = data[rng.below(n)] + rng.range(-0.5, 0.5);
            let mut ev = HostEvaluator::new(&data);
            let got = resolve(&mut ev, k, start).unwrap();
            assert_eq!(got, want, "trial={trial} n={n} k={k}");
        }
    }

    #[test]
    fn cheap_when_start_is_converged() {
        // post-cutting-plane case: the start has rank k already, or is one
        // value off — must resolve in a handful of reductions.
        let mut rng = Rng::seeded(12);
        let data = Distribution::Normal.sample_vec(&mut rng, 4096);
        let want = sorted_order_statistic(&data, 2048);
        let mut ev = HostEvaluator::new(&data);
        let got = resolve(&mut ev, 2048, want + 1e-9).unwrap();
        assert_eq!(got, want);
        assert!(ev.probes() <= 24, "{} probes", ev.probes());
    }

    #[test]
    fn extreme_start_positions() {
        let data = [4.0, -2.0, 6.5];
        let mut ev = HostEvaluator::new(&data);
        assert_eq!(resolve(&mut ev, 1, 1e18).unwrap(), -2.0);
        let mut ev = HostEvaluator::new(&data);
        assert_eq!(resolve(&mut ev, 3, -1e18).unwrap(), 6.5);
        let mut ev = HostEvaluator::new(&data);
        assert_eq!(resolve(&mut ev, 2, f64::INFINITY).unwrap(), 4.0);
    }

    #[test]
    fn huge_outlier_data() {
        let mut rng = Rng::seeded(13);
        let mut data = Distribution::Normal.sample_vec(&mut rng, 1001);
        data[0] = 1e18;
        data[1] = -1e18;
        for k in [1, 2, 500, 501, 1000, 1001] {
            let want = sorted_order_statistic(&data, k);
            let mut ev = HostEvaluator::new(&data);
            assert_eq!(resolve(&mut ev, k, 0.0).unwrap(), want, "k={k}");
        }
    }

    #[test]
    fn rejects_bad_k() {
        let mut ev = HostEvaluator::new(&[1.0]);
        assert!(resolve(&mut ev, 0, 0.0).is_err());
        assert!(resolve(&mut ev, 2, 0.0).is_err());
    }
}
