//! Monotone-transform guard for extreme magnitudes (paper §V.D).
//!
//! When elements reach ~1e20, `Σ|x_i − y|` loses the bulk terms to floating
//! point absorption. Order statistics are invariant under increasing maps,
//! so the paper computes the median of `F(x)` with `F(t) = log(1 + t −
//! x_(1))` and inverts. We implement the transform as an evaluator wrapper:
//! probes are made in transformed space, and the final exact value is
//! mapped back through F⁻¹ — then *snapped to the original data* with one
//! extra neighbors reduction, so no precision is lost to the round trip.

use super::exact;
use super::objective::Evaluator;
use crate::select::cutting_plane::{cutting_plane, CpOptions, CpOutcome};
use crate::Result;

/// F(t) = log1p(t − min) and its inverse, anchored at the data minimum.
#[derive(Debug, Clone, Copy)]
pub struct LogTransform {
    pub min: f64,
}

impl LogTransform {
    pub fn forward(&self, t: f64) -> f64 {
        (t - self.min).max(0.0).ln_1p()
    }

    pub fn inverse(&self, v: f64) -> f64 {
        v.exp_m1() + self.min
    }
}

/// Decide whether the guard is worth applying: the paper's failure mode
/// needs a range so wide that `max - min` rounds the bulk away.
pub fn needs_transform(min: f64, max: f64) -> bool {
    // Heuristic: range exceeding ~2^53 times the bulk scale means doubles
    // absorb unit-scale terms entirely.
    (max - min).abs() > 1e15 * min.abs().max(1.0)
}

/// Median / order statistic through the log transform.
///
/// Host-side: transforms a copy of the data, runs the cutting plane in
/// transformed space, maps the result back and snaps to the nearest
/// original data value by rank.
pub fn select_transformed(data: &[f64], k: usize, opts: &CpOptions) -> Result<(f64, CpOutcome)> {
    let min = data.iter().copied().fold(f64::INFINITY, f64::min);
    let tr = LogTransform { min };
    let tdata: Vec<f64> = data.iter().map(|&t| tr.forward(t)).collect();
    let mut tev = super::objective::HostEvaluator::new(&tdata);
    let out = cutting_plane(&mut tev, k, opts)?;
    let back = tr.inverse(out.value);
    // Snap to the exact original value: the transform+inverse round trip
    // can be off by a few ulps, so resolve the rank on the original data.
    let mut ev = super::objective::HostEvaluator::new(data);
    let exactv = exact::resolve(&mut ev, k, back)?;
    Ok((exactv, out))
}

/// Convenience: evaluator-level rank resolution after an external
/// transformed solve (used by the device path, which uploads transformed
/// data and snaps against the untransformed buffer).
pub fn snap_to_rank(ev: &mut dyn Evaluator, k: usize, approx: f64) -> Result<f64> {
    exact::resolve(ev, k, approx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::{sorted_median, sorted_order_statistic, Distribution, Rng};
    use crate::util::median_rank;

    #[test]
    fn transform_roundtrip() {
        let tr = LogTransform { min: -3.0 };
        for t in [-3.0, 0.0, 1.0, 1e6, 1e18] {
            let v = tr.inverse(tr.forward(t));
            assert!((v - t).abs() <= 1e-9 * t.abs().max(1.0), "{t} -> {v}");
        }
    }

    #[test]
    fn forward_is_monotone() {
        let tr = LogTransform { min: 0.0 };
        let pts = [0.0, 1e-6, 1.0, 100.0, 1e10, 1e20];
        for w in pts.windows(2) {
            assert!(tr.forward(w[0]) < tr.forward(w[1]));
        }
    }

    #[test]
    fn median_with_1e20_outliers() {
        // the paper's §V.D stress case: plain summation in f64 absorbs the
        // bulk; through the transform the median is still exact.
        let mut rng = Rng::seeded(91);
        let mut data = Distribution::HalfNormal.sample_vec(&mut rng, 4095);
        data[0] = 1e20;
        data[1] = 3e20;
        data[2] = 7e19;
        let want = sorted_median(&data);
        let (got, out) =
            select_transformed(&data, median_rank(data.len()), &CpOptions::default()).unwrap();
        assert_eq!(got, want);
        assert!(out.iterations < 60);
    }

    #[test]
    fn matches_plain_path_on_benign_data() {
        let mut rng = Rng::seeded(92);
        let data = Distribution::Normal.sample_vec(&mut rng, 2048);
        let k = 1024;
        let (got, _) = select_transformed(&data, k, &CpOptions::default()).unwrap();
        assert_eq!(got, sorted_order_statistic(&data, k));
    }

    #[test]
    fn needs_transform_heuristic() {
        assert!(!needs_transform(0.0, 1.0));
        assert!(!needs_transform(-100.0, 100.0));
        assert!(needs_transform(0.0, 1e20));
        assert!(!needs_transform(1e20, 1.0000001e20)); // huge but narrow
    }

    #[test]
    fn negative_bulk_with_positive_monsters() {
        let mut rng = Rng::seeded(93);
        let mut data: Vec<f64> = (0..999).map(|_| rng.normal() - 5.0).collect();
        data.push(1e21);
        let want = sorted_median(&data);
        let (got, _) =
            select_transformed(&data, median_rank(data.len()), &CpOptions::default()).unwrap();
        assert_eq!(got, want);
    }
}
