//! LSD radix sort on order-preserving float keys — the "GPU radix sort"
//! baseline substrate (DESIGN.md §7).
//!
//! Matches the algorithm family of Satish–Harris–Garland / Merrill–Grimshaw
//! (the paper's references [29], [20]): fixed 8-bit digits, one counting
//! pass per digit, ping-pong buffers. Like the GPU original, cost scales
//! with key width — 4 passes for f32 vs 8 for f64 — which reproduces the
//! paper's float/double performance split for the sort baseline.

use crate::util::{f32_key, f64_key, key_f32, key_f64};

const RADIX_BITS: u32 = 8;
const BUCKETS: usize = 1 << RADIX_BITS;

/// Sort f64s ascending (total order; NaNs last).
///
/// Perf (EXPERIMENTS.md §Perf/L3): all 8 digit histograms are gathered in
/// a single read pass (instead of one counting pass per digit), and
/// uniform-digit passes are skipped — the common case for data with a
/// narrow exponent range.
pub fn radix_sort_f64(data: &mut Vec<f64>) {
    let n = data.len();
    if n <= 1 {
        return;
    }
    let mut keys: Vec<u64> = data.iter().map(|&v| f64_key(v)).collect();
    let mut tmp = vec![0u64; n];

    // one histogram pass for all 8 digits
    let mut counts = [[0usize; BUCKETS]; 8];
    for &k in &keys {
        for (pass, c) in counts.iter_mut().enumerate() {
            c[((k >> (pass as u32 * RADIX_BITS)) & 0xFF) as usize] += 1;
        }
    }

    for (pass, c) in counts.iter().enumerate() {
        if c.iter().any(|&b| b == n) {
            continue; // all keys share this digit — skip the scatter
        }
        let shift = pass as u32 * RADIX_BITS;
        let mut offsets = [0usize; BUCKETS];
        let mut acc = 0;
        for (o, &b) in offsets.iter_mut().zip(c) {
            *o = acc;
            acc += b;
        }
        for &k in &keys {
            let b = ((k >> shift) & 0xFF) as usize;
            tmp[offsets[b]] = k;
            offsets[b] += 1;
        }
        std::mem::swap(&mut keys, &mut tmp);
    }
    for (d, k) in data.iter_mut().zip(&keys) {
        *d = key_f64(*k);
    }
}

/// Sort f32s ascending (total order; NaNs last).
pub fn radix_sort_f32(data: &mut Vec<f32>) {
    let n = data.len();
    if n <= 1 {
        return;
    }
    let mut keys: Vec<u32> = data.iter().map(|&v| f32_key(v)).collect();
    let mut tmp = vec![0u32; n];

    let mut counts = [[0usize; BUCKETS]; 4];
    for &k in &keys {
        for (pass, c) in counts.iter_mut().enumerate() {
            c[((k >> (pass as u32 * RADIX_BITS)) & 0xFF) as usize] += 1;
        }
    }

    for (pass, c) in counts.iter().enumerate() {
        if c.iter().any(|&b| b == n) {
            continue;
        }
        let shift = pass as u32 * RADIX_BITS;
        let mut offsets = [0usize; BUCKETS];
        let mut acc = 0;
        for (o, &b) in offsets.iter_mut().zip(c) {
            *o = acc;
            acc += b;
        }
        for &k in &keys {
            let b = ((k >> shift) & 0xFF) as usize;
            tmp[offsets[b]] = k;
            offsets[b] += 1;
        }
        std::mem::swap(&mut keys, &mut tmp);
    }
    for (d, k) in data.iter_mut().zip(&keys) {
        *d = key_f32(*k);
    }
}

/// Full-sort selection baseline: sort everything, index the k-th element.
/// This is the paper's "Radix Sort (on GPU)" method row.
pub fn sort_select_f64(data: &[f64], k: usize) -> f64 {
    assert!((1..=data.len()).contains(&k));
    let mut v = data.to_vec();
    radix_sort_f64(&mut v);
    v[k - 1]
}

/// f32 variant (4 key passes — the paper's float advantage).
pub fn sort_select_f32(data: &[f32], k: usize) -> f32 {
    assert!((1..=data.len()).contains(&k));
    let mut v = data.to_vec();
    radix_sort_f32(&mut v);
    v[k - 1]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::{Distribution, Rng};

    #[test]
    fn sorts_like_std_f64() {
        let mut rng = Rng::seeded(71);
        for d in Distribution::ALL {
            let mut a = d.sample_vec(&mut rng, 3000);
            let mut b = a.clone();
            radix_sort_f64(&mut a);
            b.sort_by(crate::util::total_cmp_f64);
            assert_eq!(a, b, "{}", d.name());
        }
    }

    #[test]
    fn sorts_like_std_f32() {
        let mut rng = Rng::seeded(72);
        let mut a: Vec<f32> = (0..5000).map(|_| rng.normal() as f32).collect();
        let mut b = a.clone();
        radix_sort_f32(&mut a);
        b.sort_by_key(|&x| crate::util::f32_key(x));
        assert_eq!(a, b);
    }

    #[test]
    fn handles_signs_zeros_infinities() {
        let mut v = vec![0.0, -0.0, 1.0, -1.0, f64::INFINITY, f64::NEG_INFINITY, 1e-310, -1e-310];
        radix_sort_f64(&mut v);
        assert_eq!(v[0], f64::NEG_INFINITY);
        assert_eq!(*v.last().unwrap(), f64::INFINITY);
        assert!(v.windows(2).all(|w| w[0].total_cmp(&w[1]).is_le()));
    }

    #[test]
    fn nans_sort_last() {
        let mut v = vec![3.0, f64::NAN, 1.0, 2.0];
        radix_sort_f64(&mut v);
        assert_eq!(&v[..3], &[1.0, 2.0, 3.0]);
        assert!(v[3].is_nan());
    }

    #[test]
    fn sort_select_matches_oracle() {
        let mut rng = Rng::seeded(73);
        let data = Distribution::Mixture2.sample_vec(&mut rng, 999);
        for k in [1, 500, 999] {
            assert_eq!(sort_select_f64(&data, k), crate::stats::sorted_order_statistic(&data, k));
        }
    }

    #[test]
    fn skip_pass_optimization_preserves_order() {
        // all values share high bytes -> several passes are skipped
        let mut v: Vec<f64> = (0..1000).map(|i| 1000.0 + i as f64 * 1e-6).collect();
        let mut b = v.clone();
        let mut rng = Rng::seeded(74);
        rng.shuffle(&mut v);
        radix_sort_f64(&mut v);
        b.sort_by(crate::util::total_cmp_f64);
        assert_eq!(v, b);
    }

    #[test]
    fn empty_and_single() {
        let mut v: Vec<f64> = vec![];
        radix_sort_f64(&mut v);
        let mut v = vec![42.0];
        radix_sort_f64(&mut v);
        assert_eq!(v, [42.0]);
    }
}
