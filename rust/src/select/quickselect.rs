//! Serial selection baselines: Hoare quickselect (median-of-3, three-way
//! partition) and the BFPRT median-of-medians algorithm (deterministic
//! O(n)), both operating on host-resident data.
//!
//! These reproduce the paper's "Quickselect (on CPU)" row; the time spent
//! downloading the array from the device is charged separately by the
//! harness (the paper's "copy to CPU" sub-row).

/// k-th smallest (1-indexed) via iterative three-way quickselect.
/// Operates on a scratch copy the caller provides (mutated in place).
pub fn quickselect(data: &mut [f64], k: usize) -> f64 {
    assert!((1..=data.len()).contains(&k), "k={k} n={}", data.len());
    let mut lo = 0usize;
    let mut hi = data.len();
    let mut rank = k - 1; // 0-indexed within [lo, hi)
    loop {
        let len = hi - lo;
        if len <= 16 {
            let s = &mut data[lo..hi];
            insertion_sort(s);
            return s[rank];
        }
        let pivot = median_of_3(data, lo, lo + len / 2, hi - 1);
        // three-way partition (Dutch national flag) of [lo, hi)
        let (mut i, mut j, mut p) = (lo, lo, hi);
        while j < p {
            if data[j] < pivot {
                data.swap(i, j);
                i += 1;
                j += 1;
            } else if data[j] > pivot {
                p -= 1;
                data.swap(j, p);
            } else {
                j += 1;
            }
        }
        let n_lt = i - lo;
        let n_eq = p - i;
        if rank < n_lt {
            hi = i;
        } else if rank < n_lt + n_eq {
            return pivot;
        } else {
            rank -= n_lt + n_eq;
            lo = p;
        }
    }
}

fn insertion_sort(s: &mut [f64]) {
    for i in 1..s.len() {
        let mut j = i;
        while j > 0 && s[j - 1] > s[j] {
            s.swap(j - 1, j);
            j -= 1;
        }
    }
}

fn median_of_3(d: &[f64], a: usize, b: usize, c: usize) -> f64 {
    let (x, y, z) = (d[a], d[b], d[c]);
    if (x <= y && y <= z) || (z <= y && y <= x) {
        y
    } else if (y <= x && x <= z) || (z <= x && x <= y) {
        x
    } else {
        z
    }
}

/// BFPRT median-of-medians: deterministic worst-case O(n) selection.
pub fn bfprt(data: &mut [f64], k: usize) -> f64 {
    assert!((1..=data.len()).contains(&k));
    let n = data.len();
    bfprt_range(data, 0, n, k - 1)
}

fn bfprt_range(data: &mut [f64], lo: usize, hi: usize, rank: usize) -> f64 {
    let len = hi - lo;
    if len <= 32 {
        let s = &mut data[lo..hi];
        insertion_sort(s);
        return s[rank];
    }
    let pivot = median_of_medians(data, lo, hi);
    let (mut i, mut j, mut p) = (lo, lo, hi);
    while j < p {
        if data[j] < pivot {
            data.swap(i, j);
            i += 1;
            j += 1;
        } else if data[j] > pivot {
            p -= 1;
            data.swap(j, p);
        } else {
            j += 1;
        }
    }
    let n_lt = i - lo;
    let n_eq = p - i;
    if rank < n_lt {
        bfprt_range(data, lo, i, rank)
    } else if rank < n_lt + n_eq {
        pivot
    } else {
        bfprt_range(data, p, hi, rank - n_lt - n_eq)
    }
}

fn median_of_medians(data: &mut [f64], lo: usize, hi: usize) -> f64 {
    let mut medians: Vec<f64> = Vec::with_capacity((hi - lo).div_ceil(5));
    let mut i = lo;
    while i < hi {
        let end = (i + 5).min(hi);
        let g = &mut data[i..end];
        insertion_sort(g);
        medians.push(g[g.len() / 2]);
        i = end;
    }
    let m = medians.len();
    bfprt_range(&mut medians, 0, m, m / 2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::{sorted_order_statistic, Distribution, Rng};

    #[test]
    fn quickselect_matches_sort() {
        let mut rng = Rng::seeded(61);
        for d in Distribution::ALL {
            let data = d.sample_vec(&mut rng, 3001);
            for k in [1, 2, 1500, 1501, 3000, 3001] {
                let want = sorted_order_statistic(&data, k);
                let mut scratch = data.clone();
                assert_eq!(quickselect(&mut scratch, k), want, "{} k={k}", d.name());
            }
        }
    }

    #[test]
    fn bfprt_matches_sort() {
        let mut rng = Rng::seeded(62);
        for d in [Distribution::Uniform, Distribution::Mixture3, Distribution::Normal] {
            let data = d.sample_vec(&mut rng, 2500);
            for k in [1, 1250, 2500] {
                let want = sorted_order_statistic(&data, k);
                let mut scratch = data.clone();
                assert_eq!(bfprt(&mut scratch, k), want, "{} k={k}", d.name());
            }
        }
    }

    #[test]
    fn adversarial_patterns() {
        for pattern in ["sorted", "reverse", "constant", "organ"] {
            let n = 1024usize;
            let data: Vec<f64> = match pattern {
                "sorted" => (0..n).map(|i| i as f64).collect(),
                "reverse" => (0..n).rev().map(|i| i as f64).collect(),
                "constant" => vec![5.0; n],
                _ => (0..n).map(|i| (i.min(n - i)) as f64).collect(),
            };
            for k in [1, n / 2, n] {
                let want = sorted_order_statistic(&data, k);
                let mut s = data.clone();
                assert_eq!(quickselect(&mut s, k), want, "{pattern} k={k}");
                let mut s = data.clone();
                assert_eq!(bfprt(&mut s, k), want, "{pattern} bfprt k={k}");
            }
        }
    }

    #[test]
    fn tiny_inputs() {
        assert_eq!(quickselect(&mut [3.0], 1), 3.0);
        assert_eq!(quickselect(&mut [3.0, 1.0], 1), 1.0);
        assert_eq!(quickselect(&mut [3.0, 1.0], 2), 3.0);
        assert_eq!(bfprt(&mut [3.0, 1.0, 2.0], 2), 2.0);
    }

    #[test]
    fn duplicates_heavy() {
        let mut rng = Rng::seeded(63);
        let data: Vec<f64> = (0..5000).map(|_| (rng.below(7)) as f64).collect();
        for k in [1, 2500, 5000] {
            let want = sorted_order_statistic(&data, k);
            let mut s = data.clone();
            assert_eq!(quickselect(&mut s, k), want);
        }
    }
}
