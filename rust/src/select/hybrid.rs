//! The paper's headline method (§IV, last part): a few cutting-plane
//! iterations shrink the pivot interval, then `copy_if` compacts the
//! survivors into a small array `z` which is radix sorted; the answer is
//! read out of `z` at the rank offset `k − m` with `m = #{x ≤ y_L}`.
//!
//! The number of CP iterations trades reduction cost against compaction +
//! sort cost; the paper empirically stops after 7 iterations at n = 2²⁵
//! (pivot interval under 2¹⁹ elements). `hybrid_sweep` in the ablations
//! bench reproduces that tuning curve.

use super::cutting_plane::{cutting_plane_cancellable, CpOptions};
use super::exact;
use super::objective::{DType, Evaluator, IntervalCounts};
use super::radix::{radix_sort_f32, radix_sort_f64};
use crate::util::PhaseTimer;
use crate::{algo_err, Result};

#[derive(Debug, Clone)]
pub struct HybridOptions {
    /// CP iterations before switching to compaction + sort (paper: 7).
    pub cp_iters: usize,
    /// Safety valve: if the pivot interval still holds more than this
    /// fraction of the data, keep cutting (up to `max_extra` more rounds).
    pub max_fraction: f64,
    pub max_extra: usize,
}

impl Default for HybridOptions {
    fn default() -> Self {
        HybridOptions { cp_iters: 7, max_fraction: 0.25, max_extra: 20 }
    }
}

#[derive(Debug, Clone)]
pub struct HybridOutcome {
    pub value: f64,
    pub cp_iterations: usize,
    /// |z| — elements compacted and sorted.
    pub z_len: usize,
    pub phases: PhaseTimer,
}

/// Hybrid cutting-plane + compaction + radix-sort selection.
pub fn hybrid_select(
    ev: &mut dyn Evaluator,
    k: usize,
    opts: &HybridOptions,
) -> Result<HybridOutcome> {
    hybrid_select_cancellable(ev, k, opts, &mut || None)
}

/// [`hybrid_select`] with a cooperative cancellation hook, polled at
/// every pass boundary (between cutting-plane rounds and before the
/// occupancy peek) and threaded through the inner cutting plane — never
/// mid-pass.
pub fn hybrid_select_cancellable(
    ev: &mut dyn Evaluator,
    k: usize,
    opts: &HybridOptions,
    cancel: &mut dyn FnMut() -> Option<crate::Error>,
) -> Result<HybridOutcome> {
    let n = ev.n();
    let mut phases = PhaseTimer::new();

    // Phase 1: bounded cutting plane.
    let mut budget = opts.cp_iters;
    let mut extra_rounds = 0;
    let (mut bracket, mut cp_iterations, mut maybe_exact);
    let mut peeked: Option<IntervalCounts> = None;
    loop {
        if let Some(err) = cancel() {
            return Err(err);
        }
        let cp = cutting_plane_cancellable(
            ev,
            k,
            &CpOptions { stop_after: Some(budget), ..CpOptions::default() },
            cancel,
        )?;
        phases.merge(&cp.phases);
        bracket = cp.bracket;
        cp_iterations = cp.iterations;
        maybe_exact = if cp.exact { Some(cp.value) } else { None };

        if maybe_exact.is_some() {
            break;
        }
        // Peek at the interval occupancy; one extra reduction.
        let ic = phases.time("cp_iterations", || ev.interval(bracket.0, bracket.1))?;
        if (ic.c_in as f64) <= opts.max_fraction * n as f64
            || extra_rounds >= opts.max_extra
        {
            // The bracket can't change between here and phase 2: keep the
            // counts so copy_if doesn't re-issue the same reduction.
            peeked = Some(ic);
            break;
        }
        extra_rounds += 1;
        budget += 4;
    }

    if let Some(v) = maybe_exact {
        return Ok(HybridOutcome { value: v, cp_iterations, z_len: 0, phases });
    }

    let (y_l, y_r) = bracket;

    // Phase 2: occupancy (reusing the loop's peek) + compaction (the
    // paper's copy_if).
    let ic = match peeked {
        Some(ic) => ic,
        None => phases.time("copy_if", || ev.interval(y_l, y_r))?,
    };
    let m = ic.c_le as usize;

    if k <= m {
        // Only possible when y_L is still the initial minimum with
        // multiplicity >= k (CP updates keep #{x <= y_L} < k otherwise).
        return Ok(HybridOutcome {
            value: phases.time("exact_fixup", || exact::resolve(ev, k, y_l))?,
            cp_iterations,
            z_len: 0,
            phases,
        });
    }
    if k > m + ic.c_in as usize {
        // Answer sits at or beyond y_R (duplicates at the boundary).
        return Ok(HybridOutcome {
            value: phases.time("exact_fixup", || exact::resolve(ev, k, y_r))?,
            cp_iterations,
            z_len: 0,
            phases,
        });
    }

    let mut z = phases.time("copy_if", || ev.compact(y_l, y_r))?;
    if z.len() != ic.c_in as usize {
        return Err(algo_err!(
            "compaction returned {} elements, interval count said {}",
            z.len(),
            ic.c_in
        ));
    }

    // Phase 3: radix sort of z (key width follows the array dtype).
    let idx = k - m - 1;
    let value = phases.time("sort_z", || match ev.dtype() {
        DType::F64 => {
            radix_sort_f64(&mut z);
            z[idx]
        }
        DType::F32 => {
            let mut zf: Vec<f32> = z.iter().map(|&v| v as f32).collect();
            radix_sort_f32(&mut zf);
            zf[idx] as f64
        }
    });

    Ok(HybridOutcome { value, cp_iterations, z_len: z.len(), phases })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::select::objective::HostEvaluator;
    use crate::stats::{sorted_median, sorted_order_statistic, Distribution, Rng};
    use crate::util::median_rank;

    #[test]
    fn matches_oracle_all_distributions() {
        let mut rng = Rng::seeded(81);
        for d in Distribution::ALL {
            for n in [128usize, 1000, 8192] {
                let data = d.sample_vec(&mut rng, n);
                let mut ev = HostEvaluator::new(&data);
                let out =
                    hybrid_select(&mut ev, median_rank(n), &HybridOptions::default()).unwrap();
                assert_eq!(out.value, sorted_median(&data), "{} n={n}", d.name());
            }
        }
    }

    #[test]
    fn z_is_small_after_default_iterations() {
        // paper: after 7 iterations z is typically 1-5% of n
        let mut rng = Rng::seeded(82);
        let n = 1 << 16;
        let data = Distribution::Uniform.sample_vec(&mut rng, n);
        let mut ev = HostEvaluator::new(&data);
        let out = hybrid_select(&mut ev, median_rank(n), &HybridOptions::default()).unwrap();
        assert!(out.z_len <= n / 4, "pivot interval too large: {} of {n}", out.z_len);
    }

    #[test]
    fn random_order_statistics() {
        let mut rng = Rng::seeded(83);
        for _ in 0..30 {
            let n = 64 + rng.below(4000);
            let d = Distribution::ALL[rng.below(9)];
            let data = d.sample_vec(&mut rng, n);
            let k = 1 + rng.below(n);
            let mut ev = HostEvaluator::new(&data);
            let out = hybrid_select(&mut ev, k, &HybridOptions::default()).unwrap();
            assert_eq!(out.value, sorted_order_statistic(&data, k), "{} n={n} k={k}", d.name());
        }
    }

    #[test]
    fn f32_dtype_path() {
        let mut rng = Rng::seeded(84);
        let data = Distribution::Normal.sample_vec(&mut rng, 4096);
        let mut ev = HostEvaluator::new_f32(&data);
        let out = hybrid_select(&mut ev, 2048, &HybridOptions::default()).unwrap();
        // oracle on the rounded data
        let rounded: Vec<f64> = data.iter().map(|&v| v as f32 as f64).collect();
        assert_eq!(out.value, sorted_order_statistic(&rounded, 2048));
    }

    #[test]
    fn heavy_duplicates_and_boundaries() {
        let mut data = vec![5.0; 1000];
        data.extend(std::iter::repeat(1.0).take(500));
        data.extend(std::iter::repeat(9.0).take(500));
        let mut rng = Rng::seeded(85);
        rng.shuffle(&mut data);
        for k in [1, 500, 501, 1000, 1500, 1501, 2000] {
            let mut ev = HostEvaluator::new(&data);
            let out = hybrid_select(&mut ev, k, &HybridOptions::default()).unwrap();
            assert_eq!(out.value, sorted_order_statistic(&data, k), "k={k}");
        }
    }

    #[test]
    fn few_cp_iterations_forces_large_z() {
        let mut rng = Rng::seeded(86);
        let data = Distribution::Normal.sample_vec(&mut rng, 8192);
        let mut ev = HostEvaluator::new(&data);
        let out = hybrid_select(
            &mut ev,
            4096,
            &HybridOptions { cp_iters: 2, max_fraction: 1.0, max_extra: 0 },
        )
        .unwrap();
        assert_eq!(out.value, sorted_median(&data));
        // with only 2 cuts the pivot interval is big — still correct
        assert!(out.z_len > 0);
    }

    #[test]
    fn outlier_data_still_exact() {
        let mut rng = Rng::seeded(87);
        let mut data = Distribution::HalfNormal.sample_vec(&mut rng, 4096);
        data[0] = 1e9;
        data[1] = -1e9;
        let mut ev = HostEvaluator::new(&data);
        let out = hybrid_select(&mut ev, 2048, &HybridOptions::default()).unwrap();
        assert_eq!(out.value, sorted_order_statistic(&data, 2048));
    }
}
