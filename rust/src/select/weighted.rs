//! Weighted medians and weighted order statistics.
//!
//! A natural extension along the paper's own penalty-based aggregation
//! lineage (its refs [6, 7], Calvo–Beliakov–Mesiar–Yager): the weighted
//! median minimizes `Σ w_i |x_i − y|` (w_i > 0), still convex piecewise
//! linear, so the exact same cutting-plane machinery applies with the
//! sufficient statistics generalized to weighted sums:
//!
//! ```text
//!   s_lo = Σ_{x_i<y} w_i (y−x_i)   W_lt = Σ_{x_i<y} w_i   (etc.)
//! ```
//!
//! The rank test becomes a *weight-mass* test: y is a weighted k-statistic
//! at mass fraction q when `W_lt < q·W ≤ W_lt + W_eq`. The weighted median
//! is q = 1/2 (lower convention, matching the unweighted paper definition
//! when all weights are equal).
//!
//! Applications: weighted LMS variants, importance-weighted quantiles in
//! the serving layer.

use crate::{algo_err, invalid_arg, Result};

/// Weighted probe statistics (one fused pass).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WeightedStats {
    pub s_lo: f64,
    pub s_hi: f64,
    pub w_lt: f64,
    pub w_eq: f64,
    pub w_gt: f64,
}

/// Host evaluator over (value, weight) pairs.
#[derive(Debug, Clone)]
pub struct WeightedHostEvaluator {
    x: Vec<f64>,
    w: Vec<f64>,
    total: f64,
    probes: u64,
}

impl WeightedHostEvaluator {
    pub fn new(x: &[f64], w: &[f64]) -> Result<Self> {
        if x.is_empty() || x.len() != w.len() {
            return Err(invalid_arg!("need equally many values and weights"));
        }
        if w.iter().any(|&v| !(v > 0.0) || !v.is_finite()) {
            return Err(invalid_arg!("weights must be positive and finite"));
        }
        let total = w.iter().sum();
        Ok(WeightedHostEvaluator { x: x.to_vec(), w: w.to_vec(), total, probes: 0 })
    }

    pub fn n(&self) -> usize {
        self.x.len()
    }

    pub fn total_weight(&self) -> f64 {
        self.total
    }

    pub fn probes(&self) -> u64 {
        self.probes
    }

    pub fn min_max(&mut self) -> (f64, f64) {
        self.probes += 1;
        let mut mn = f64::INFINITY;
        let mut mx = f64::NEG_INFINITY;
        for &v in &self.x {
            mn = mn.min(v);
            mx = mx.max(v);
        }
        (mn, mx)
    }

    /// One fused weighted transform-reduce (branchless, like the unweighted
    /// probe kernel).
    pub fn probe(&mut self, y: f64) -> WeightedStats {
        self.probes += 1;
        let mut s = WeightedStats { s_lo: 0.0, s_hi: 0.0, w_lt: 0.0, w_eq: 0.0, w_gt: 0.0 };
        for (&x, &w) in self.x.iter().zip(&self.w) {
            let d = x - y;
            s.s_lo -= w * d.min(0.0);
            s.s_hi += w * d.max(0.0);
            s.w_lt += if d < 0.0 { w } else { 0.0 };
            s.w_gt += if d > 0.0 { w } else { 0.0 };
            s.w_eq += if d == 0.0 { w } else { 0.0 };
        }
        s
    }

    /// Largest x_i ≤ y and smallest x_i ≥ y.
    pub fn neighbors(&mut self, y: f64) -> (f64, f64) {
        self.probes += 1;
        let mut lo = f64::NEG_INFINITY;
        let mut hi = f64::INFINITY;
        for &x in &self.x {
            if x <= y {
                lo = lo.max(x);
            }
            if x >= y {
                hi = hi.min(x);
            }
        }
        (lo, hi)
    }
}

/// Options for the weighted cutting plane.
#[derive(Debug, Clone)]
pub struct WeightedOptions {
    pub max_iters: usize,
    pub tol: f64,
}

impl Default for WeightedOptions {
    fn default() -> Self {
        WeightedOptions { max_iters: 200, tol: 1e-13 }
    }
}

/// Is y a weighted q-statistic? (`W(<y) < q·W ≤ W(≤y)`, tolerating fp dust)
fn mass_ok(s: &WeightedStats, target: f64) -> bool {
    // Strictness matters when the target mass is hit exactly (e.g. unit
    // weights): W(<y) must be genuinely below the target, so the eps slack
    // only absorbs summation noise on the other side.
    let eps = 1e-12 * (s.w_lt + s.w_eq + s.w_gt);
    s.w_lt + eps < target && target <= s.w_lt + s.w_eq + eps
}

/// Weighted quantile: the smallest data value y with `Σ_{x_i ≤ y} w_i ≥
/// q·W` (q ∈ (0, 1]). `q = 0.5` is the lower weighted median.
pub fn weighted_quantile(
    ev: &mut WeightedHostEvaluator,
    q: f64,
    opts: &WeightedOptions,
) -> Result<f64> {
    if !(0.0 < q && q <= 1.0) {
        return Err(invalid_arg!("quantile {q} outside (0,1]"));
    }
    let target = q * ev.total_weight();
    let (mn, mx) = ev.min_max();
    if mn == mx {
        return Ok(mn);
    }

    // Rank-mass bisection with neighbor snapping — the cutting-plane
    // bracket logic specialized to weighted masses. (The weighted f/g cut
    // formula works too; mass bisection is simpler and the probe count is
    // within a small factor — see the module tests.)
    let (mut lo, mut hi) = (f64::next_down(mn), mx);
    for step in 0..opts.max_iters {
        if step % 8 == 7 {
            // snap attempt
            let (cand, _) = ev.neighbors(hi);
            if cand.is_finite() {
                let s = ev.probe(cand);
                if mass_ok(&s, target) {
                    return Ok(cand);
                }
            }
        }
        let mid = 0.5 * (lo + hi);
        if !(mid > lo && mid < hi) {
            break;
        }
        let s = ev.probe(mid);
        if mass_ok(&s, target) {
            // mid may be between data values; snap down to the data value
            let (cand, _) = ev.neighbors(mid);
            let sc = ev.probe(cand);
            if mass_ok(&sc, target) {
                return Ok(cand);
            }
            return Ok(mid);
        }
        if s.w_lt + s.w_eq < target {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    // final snap
    let (cand, upper) = ev.neighbors(hi);
    for c in [cand, upper] {
        if c.is_finite() {
            let s = ev.probe(c);
            if mass_ok(&s, target) {
                return Ok(c);
            }
        }
    }
    Err(algo_err!("weighted quantile did not converge (q={q})"))
}

/// The lower weighted median.
pub fn weighted_median(x: &[f64], w: &[f64]) -> Result<f64> {
    let mut ev = WeightedHostEvaluator::new(x, w)?;
    weighted_quantile(&mut ev, 0.5, &WeightedOptions::default())
}

/// Sort-based oracle for tests: smallest x with cumulative weight ≥ q·W.
pub fn weighted_quantile_oracle(x: &[f64], w: &[f64], q: f64) -> f64 {
    let mut idx: Vec<usize> = (0..x.len()).collect();
    idx.sort_by_key(|&i| crate::util::f64_key(x[i]));
    let total: f64 = w.iter().sum();
    let target = q * total;
    let mut acc = 0.0;
    for &i in &idx {
        acc += w[i];
        if acc >= target - 1e-12 * total {
            return x[i];
        }
    }
    idx.last().map_or(f64::NAN, |&i| x[i])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::{Distribution, Rng};

    #[test]
    fn equal_weights_reduce_to_plain_median() {
        let mut rng = Rng::seeded(211);
        for n in [1usize, 2, 7, 101, 1000] {
            let x = Distribution::Normal.sample_vec(&mut rng, n);
            let w = vec![1.0; n];
            let got = weighted_median(&x, &w).unwrap();
            // lower weighted median with equal weights = x_(ceil(n/2))
            let want = weighted_quantile_oracle(&x, &w, 0.5);
            assert_eq!(got, want, "n={n}");
        }
    }

    #[test]
    fn dominant_weight_wins() {
        let x = [1.0, 2.0, 3.0, 4.0, 100.0];
        let w = [0.1, 0.1, 0.1, 0.1, 10.0];
        assert_eq!(weighted_median(&x, &w).unwrap(), 100.0);
        let w = [10.0, 0.1, 0.1, 0.1, 0.1];
        assert_eq!(weighted_median(&x, &w).unwrap(), 1.0);
    }

    #[test]
    fn random_fuzz_against_oracle() {
        let mut rng = Rng::seeded(212);
        for trial in 0..120 {
            let n = 1 + rng.below(300);
            let x = Distribution::ALL[trial % 9].sample_vec(&mut rng, n);
            let w: Vec<f64> = (0..n).map(|_| rng.range(0.01, 5.0)).collect();
            let q = [0.1, 0.25, 0.5, 0.75, 0.9][trial % 5];
            let want = weighted_quantile_oracle(&x, &w, q);
            let mut ev = WeightedHostEvaluator::new(&x, &w).unwrap();
            let got = weighted_quantile(&mut ev, q, &WeightedOptions::default()).unwrap();
            assert_eq!(got, want, "trial={trial} n={n} q={q}");
        }
    }

    #[test]
    fn duplicates_and_probe_budget() {
        let x = [2.0, 2.0, 2.0, 1.0, 3.0];
        let w = [1.0, 1.0, 1.0, 1.0, 1.0];
        assert_eq!(weighted_median(&x, &w).unwrap(), 2.0);

        let mut rng = Rng::seeded(213);
        let xs = Distribution::Uniform.sample_vec(&mut rng, 10_000);
        let ws: Vec<f64> = (0..10_000).map(|_| rng.range(0.5, 2.0)).collect();
        let mut ev = WeightedHostEvaluator::new(&xs, &ws).unwrap();
        let got = weighted_quantile(&mut ev, 0.5, &WeightedOptions::default()).unwrap();
        assert_eq!(got, weighted_quantile_oracle(&xs, &ws, 0.5));
        assert!(ev.probes() < 120, "{} probes", ev.probes());
    }

    #[test]
    fn rejects_bad_input() {
        assert!(WeightedHostEvaluator::new(&[], &[]).is_err());
        assert!(WeightedHostEvaluator::new(&[1.0], &[1.0, 2.0]).is_err());
        assert!(WeightedHostEvaluator::new(&[1.0], &[0.0]).is_err());
        assert!(WeightedHostEvaluator::new(&[1.0], &[-1.0]).is_err());
        assert!(WeightedHostEvaluator::new(&[1.0], &[f64::NAN]).is_err());
        let mut ev = WeightedHostEvaluator::new(&[1.0], &[1.0]).unwrap();
        assert!(weighted_quantile(&mut ev, 0.0, &WeightedOptions::default()).is_err());
        assert!(weighted_quantile(&mut ev, 1.5, &WeightedOptions::default()).is_err());
    }

    #[test]
    fn extreme_quantiles() {
        let x = [5.0, 1.0, 9.0, 3.0];
        let w = [1.0, 1.0, 1.0, 1.0];
        let mut ev = WeightedHostEvaluator::new(&x, &w).unwrap();
        assert_eq!(weighted_quantile(&mut ev, 1.0, &WeightedOptions::default()).unwrap(), 9.0);
        let mut ev = WeightedHostEvaluator::new(&x, &w).unwrap();
        assert_eq!(weighted_quantile(&mut ev, 0.25, &WeightedOptions::default()).unwrap(), 1.0);
    }
}
