//! Kelley's cutting plane method (Algorithm 1 of the paper).
//!
//! Iteratively builds a piecewise-linear lower model of the convex objective
//! from (f, subgradient) pairs; in 1-D the model minimizer is the
//! intersection of the two bracketing tangents:
//!
//! ```text
//!   t = (f_R − f_L + y_L·g_L − y_R·g_R) / (g_L − g_R)
//! ```
//!
//! The bracket [y_L, y_R] always contains the minimizer; each iteration
//! costs exactly one fused device reduction. Since the batched-probe
//! engine landed, that one reduction is a **two-probe ladder**
//! (`probe_many`): the Kelley model minimizer and the bisection midpoint
//! safeguard are evaluated in the same fused pass, so every iteration gets
//! both the superlinear model cut and a guaranteed ≥ half-bracket shrink.
//! Seeding uses a single (min, max, sum) reduction with closed-form f/g at
//! the extremes (§IV), so total cost is `maxit + 1` reductions — the
//! paper's complexity claim, asserted by our tests via the evaluator's
//! probe counter. Caveat: that budget holds on evaluators with a native
//! fused `probe_many` (host oracle, sharded groups); the PJRT device
//! backend has no ladder artifact yet and honestly counts the pair as two
//! launches (up to `2·maxit + 1` device reductions) until the
//! `fused_ladder` kernel lands (ROADMAP open item).
//!
//! Unlike bisection/golden/Brent, the cut exploits both convexity and the
//! subgradient, which is why it is insensitive to extreme outliers (Fig. 5):
//! one evaluation eliminates the entire linear piece between an outlier and
//! the bulk of the data.

use super::exact;
use super::objective::{Evaluator, ObjectiveSpec};
use crate::util::PhaseTimer;
use crate::{algo_err, Result};

/// Tuning knobs for Algorithm 1.
#[derive(Debug, Clone)]
pub struct CpOptions {
    /// Upper bound on iterations (paper: < 30 suffice for n ≤ 2²⁵ at
    /// tolerance 1e-12).
    pub max_iters: usize,
    /// Stop when the bracket width falls below `tol_f · max(1, |y|)`.
    pub tol_f: f64,
    /// Stop when |g(t)| ≤ tol_g (0 disables; g = 0 always stops).
    pub tol_g: f64,
    /// Record per-iteration state (Fig. 4 trace).
    pub trace: bool,
    /// Stop early after this many iterations without exact resolution —
    /// used by the hybrid method, which takes the bracket and sorts the
    /// surviving pivot interval instead.
    pub stop_after: Option<usize>,
}

impl Default for CpOptions {
    fn default() -> Self {
        CpOptions {
            max_iters: 60,
            tol_f: 1e-12,
            tol_g: 0.0,
            trace: false,
            stop_after: None,
        }
    }
}

/// One row of the Fig. 4 trace.
#[derive(Debug, Clone, Copy)]
pub struct TracePoint {
    pub iter: usize,
    pub y: f64,
    pub f: f64,
    pub g: f64,
    pub y_l: f64,
    pub y_r: f64,
}

/// Outcome of a cutting-plane run.
#[derive(Debug, Clone)]
pub struct CpOutcome {
    /// Exact order statistic if resolution ran, else the approximation.
    pub value: f64,
    /// Final bracket (contains the k-th order statistic).
    pub bracket: (f64, f64),
    /// Number of cut iterations executed (excludes the seed reduction).
    pub iterations: usize,
    /// True iff `value` is the exact data value of rank k.
    pub exact: bool,
    pub trace: Vec<TracePoint>,
    pub phases: PhaseTimer,
}

/// Run Algorithm 1 for the k-th smallest element.
///
/// When `opts.stop_after` is `None`, the approximate minimizer is refined to
/// the exact order statistic via `exact::resolve`. With `stop_after = m`,
/// iteration stops early and the (bracket, iterations) are returned for the
/// hybrid path.
pub fn cutting_plane(ev: &mut dyn Evaluator, k: usize, opts: &CpOptions) -> Result<CpOutcome> {
    cutting_plane_cancellable(ev, k, opts, &mut || None)
}

/// [`cutting_plane`] with a cooperative cancellation hook, polled at
/// every pass boundary (before each fused candidate-pair reduction) —
/// never mid-pass, so an in-flight reduction always completes.
pub fn cutting_plane_cancellable(
    ev: &mut dyn Evaluator,
    k: usize,
    opts: &CpOptions,
    cancel: &mut dyn FnMut() -> Option<crate::Error>,
) -> Result<CpOutcome> {
    let n = ev.n();
    let spec = ObjectiveSpec::order(n, k)?;
    let mut phases = PhaseTimer::new();
    let mut trace = Vec::new();

    // --- step 0: one fused (min, max, sum) reduction seeds everything.
    let init = phases.time("cp_iterations", || ev.init_stats())?;
    let seed = spec.seed(&init);
    let (mut y_l, mut y_r) = (seed.y_l, seed.y_r);
    let (mut f_l, mut g_l) = (seed.f_l, seed.g_l);
    let (mut f_r, mut g_r) = (seed.f_r, seed.g_r);

    if opts.trace {
        trace.push(TracePoint { iter: 0, y: y_l, f: f_l, g: g_l, y_l, y_r });
        trace.push(TracePoint { iter: 0, y: y_r, f: f_r, g: g_r, y_l, y_r });
    }

    // Degenerate cases: constant array, or extreme ranks.
    if y_l == y_r {
        return Ok(CpOutcome {
            value: y_l,
            bracket: (y_l, y_r),
            iterations: 0,
            exact: true,
            trace,
            phases,
        });
    }
    if k == 1 || k == n {
        let v = if k == 1 { y_l } else { y_r };
        return Ok(CpOutcome {
            value: v,
            bracket: (v, v),
            iterations: 0,
            exact: true,
            trace,
            phases,
        });
    }

    let budget = opts.stop_after.unwrap_or(opts.max_iters).min(opts.max_iters);
    let mut iterations = 0;
    let mut approx = 0.5 * (y_l + y_r);
    let mut optimal_at = None;

    'outer: while iterations < budget {
        if let Some(err) = cancel() {
            return Err(err);
        }
        // Fused candidate pair, ONE probe-ladder pass per iteration: the
        // Kelley model minimizer (step 1.1) and the bisection midpoint
        // safeguard travel together through `probe_many`. The model cut
        // keeps the outlier-insensitive superlinear step (Fig. 5); the
        // midpoint guarantees ≥ half-bracket progress per pass; the pass
        // budget stays the paper's `maxit + 1` reductions.
        let denom = g_l - g_r;
        let t_model = if denom.abs() > 0.0 {
            (f_r - f_l + y_l * g_l - y_r * g_r) / denom
        } else {
            f64::NAN // flat model: fall back to the midpoint alone
        };
        let t_mid = 0.5 * (y_l + y_r);
        let mut cands = [0.0f64; 2];
        let mut m = 0;
        if t_model.is_finite() && t_model > y_l && t_model < y_r {
            cands[m] = t_model;
            m += 1;
        }
        if t_mid > y_l && t_mid < y_r && (m == 0 || t_mid != cands[0]) {
            cands[m] = t_mid;
            m += 1;
        }
        if m == 0 {
            break; // bracket exhausted to adjacent floats
        }
        cands[..m].sort_by(crate::util::total_cmp_f64);

        let stats = phases.time("cp_iterations", || ev.probe_many(&cands[..m]))?;
        iterations += 1;

        let mut f_best = f64::INFINITY;
        for (&t, s) in cands[..m].iter().zip(&stats) {
            let f_t = spec.f(s);
            let g_t = spec.g_point(s);
            if opts.trace {
                trace.push(TracePoint { iter: iterations, y: t, f: f_t, g: g_t, y_l, y_r });
            }
            if f_t < f_best {
                f_best = f_t;
                approx = t;
            }

            // Stopping criteria (step 1.3), per candidate.
            if spec.is_optimal(s) {
                optimal_at = Some(t);
                break 'outer;
            }
            if opts.tol_g > 0.0 && g_t.abs() <= opts.tol_g {
                break 'outer;
            }

            // Bracket update (step 1.4) — skip a candidate an earlier cut
            // of this same pass has already pushed out of the bracket.
            if t <= y_l || t >= y_r {
                continue;
            }
            if g_t < 0.0 {
                y_l = t;
                f_l = f_t;
                g_l = g_t;
            } else {
                y_r = t;
                f_r = f_t;
                g_r = g_t;
            }
        }

        if (y_r - y_l) <= opts.tol_f * y_l.abs().max(y_r.abs()).max(1.0) {
            break;
        }
    }

    if g_l >= 0.0 || g_r <= 0.0 {
        // The bracket invariant g(y_L) < 0 < g(y_R) must hold throughout.
        return Err(algo_err!("cutting plane lost its bracket invariant: g_l={g_l} g_r={g_r}"));
    }

    if opts.stop_after.is_some() {
        return Ok(CpOutcome {
            value: optimal_at.unwrap_or(approx),
            bracket: (y_l, y_r),
            iterations,
            exact: false,
            trace,
            phases,
        });
    }

    // Exact fixup (paper footnote 1): typically 1–2 extra reductions; the
    // converged bracket seeds the rank bisection so even the slow path
    // stays cheap.
    let start = optimal_at.unwrap_or(approx);
    let value = phases.time("exact_fixup", || {
        exact::resolve_with_bracket(ev, k, start, Some((y_l, y_r)))
    })?;
    Ok(CpOutcome {
        value,
        bracket: (y_l, y_r),
        iterations,
        exact: true,
        trace,
        phases,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::select::objective::HostEvaluator;
    use crate::stats::{sorted_median, sorted_order_statistic, Distribution, Rng};
    use crate::util::median_rank;

    fn median_of(data: &[f64]) -> CpOutcome {
        let mut ev = HostEvaluator::new(data);
        cutting_plane(&mut ev, median_rank(data.len()), &CpOptions::default()).unwrap()
    }

    #[test]
    fn exact_median_small() {
        let data = [9.0, 1.0, 5.0, 3.0, 7.0];
        let out = median_of(&data);
        assert_eq!(out.value, 5.0);
        assert!(out.exact);
    }

    #[test]
    fn matches_sort_oracle_all_distributions() {
        let mut rng = Rng::seeded(21);
        for d in Distribution::ALL {
            for n in [5usize, 64, 1001, 4096] {
                let data = d.sample_vec(&mut rng, n);
                let out = median_of(&data);
                assert_eq!(out.value, sorted_median(&data), "{} n={n}", d.name());
            }
        }
    }

    #[test]
    fn arbitrary_order_statistics() {
        let mut rng = Rng::seeded(22);
        let data = Distribution::Normal.sample_vec(&mut rng, 999);
        for k in [1usize, 2, 10, 250, 500, 750, 998, 999] {
            let mut ev = HostEvaluator::new(&data);
            let out = cutting_plane(&mut ev, k, &CpOptions::default()).unwrap();
            assert_eq!(out.value, sorted_order_statistic(&data, k), "k={k}");
        }
    }

    #[test]
    fn few_iterations_even_at_large_n() {
        // paper: under 30 iterations for n up to 32M at tol 1e-12
        let mut rng = Rng::seeded(23);
        let data = Distribution::Uniform.sample_vec(&mut rng, 1 << 18);
        let out = median_of(&data);
        assert!(out.iterations <= 40, "{} iterations", out.iterations);
        assert_eq!(out.value, sorted_median(&data));
    }

    #[test]
    fn insensitive_to_huge_outliers_fig5() {
        // Fig. 5: CP stays usable as one element grows to 1e9 (mild growth
        // from f-precision erosion is expected — see §V.D — but it must be
        // far below bisection's log2(range) blowup, asserted below).
        let mut rng = Rng::seeded(24);
        let base = Distribution::Normal.sample_vec(&mut rng, 4096);
        let mut iters = Vec::new();
        for mag in [1e3, 1e6, 1e9] {
            let mut data = base.clone();
            data[0] = mag;
            let out = median_of(&data);
            assert_eq!(out.value, sorted_median(&data), "mag={mag}");
            iters.push(out.iterations);
        }
        let spread = iters.iter().max().unwrap() - iters.iter().min().unwrap();
        assert!(spread <= 20, "iteration counts vary too much: {iters:?}");
    }

    #[test]
    fn beats_bisection_on_outliers_fig5() {
        // the comparative Fig. 5 claim: at extreme magnitudes CP needs far
        // fewer probes than bisection on the same data.
        let mut rng = Rng::seeded(29);
        let mut data = Distribution::Normal.sample_vec(&mut rng, 4096);
        data[0] = 1e12;
        let want = sorted_median(&data);

        let mut ev_cp = HostEvaluator::new(&data);
        let cp = cutting_plane(&mut ev_cp, 2048, &CpOptions::default()).unwrap();
        assert_eq!(cp.value, want);

        let mut ev_bi = HostEvaluator::new(&data);
        let bi = crate::select::bisection::bisection(
            &mut ev_bi,
            2048,
            &crate::select::bisection::BisectOptions::default(),
        )
        .unwrap();
        assert_eq!(bi.value, want);

        assert!(
            ev_cp.probes() < ev_bi.probes(),
            "cp {} probes vs bisection {}",
            ev_cp.probes(),
            ev_bi.probes()
        );
    }

    #[test]
    fn probe_budget_is_maxit_plus_one_plus_fixup() {
        let mut rng = Rng::seeded(25);
        let data = Distribution::Normal.sample_vec(&mut rng, 8192);
        let mut ev = HostEvaluator::new(&data);
        let out = cutting_plane(&mut ev, 4096, &CpOptions::default()).unwrap();
        // seed (1) + iterations + exact fixup (a handful of probe/neighbor
        // pairs). The paper's "maxit + 1 reductions" claim allows the fixup
        // loop as footnote-1 extra work.
        assert!(
            ev.probes() <= out.iterations as u64 + 1 + 12,
            "probes={} iters={}",
            ev.probes(),
            out.iterations
        );
    }

    #[test]
    fn stop_after_returns_valid_bracket() {
        let mut rng = Rng::seeded(26);
        let data = Distribution::HalfNormal.sample_vec(&mut rng, 8192);
        let k = median_rank(data.len());
        let mut ev = HostEvaluator::new(&data);
        let out = cutting_plane(
            &mut ev,
            k,
            &CpOptions { stop_after: Some(7), ..CpOptions::default() },
        )
        .unwrap();
        assert!(out.iterations <= 7);
        assert!(!out.exact);
        let med = sorted_median(&data);
        assert!(
            out.bracket.0 <= med && med <= out.bracket.1,
            "bracket {:?} excludes median {med}",
            out.bracket
        );
        // the paper: after ~7 iterations the pivot interval is small
        let inside = data
            .iter()
            .filter(|&&x| x > out.bracket.0 && x < out.bracket.1)
            .count();
        assert!(inside * 4 <= data.len(), "pivot interval still holds {inside}");
    }

    #[test]
    fn trace_records_bracket_shrinkage() {
        let mut rng = Rng::seeded(27);
        let data = Distribution::Beta25.sample_vec(&mut rng, 2048);
        let mut ev = HostEvaluator::new(&data);
        let out = cutting_plane(&mut ev, 1024, &CpOptions { trace: true, ..CpOptions::default() })
            .unwrap();
        assert!(out.trace.len() >= 3);
        // bracket widths are non-increasing over the trace
        let widths: Vec<f64> = out.trace.iter().map(|t| t.y_r - t.y_l).collect();
        for w in widths.windows(2) {
            assert!(w[1] <= w[0] + 1e-15, "{widths:?}");
        }
    }

    #[test]
    fn constant_and_tiny_arrays() {
        assert_eq!(median_of(&[4.0, 4.0, 4.0, 4.0]).value, 4.0);
        assert_eq!(median_of(&[1.0]).value, 1.0);
        assert_eq!(median_of(&[2.0, 1.0]).value, 1.0); // lower median
        let mut ev = HostEvaluator::new(&[5.0, -3.0]);
        let out = cutting_plane(&mut ev, 2, &CpOptions::default()).unwrap();
        assert_eq!(out.value, 5.0);
    }

    #[test]
    fn duplicates_at_median() {
        let data = [1.0, 2.0, 2.0, 2.0, 2.0, 2.0, 9.0];
        assert_eq!(median_of(&data).value, 2.0);
    }

    #[test]
    fn mixture3_with_mass_at_ten() {
        let mut rng = Rng::seeded(28);
        let data = Distribution::Mixture3.sample_vec(&mut rng, 4097);
        assert_eq!(median_of(&data).value, sorted_median(&data));
    }
}
