//! Selection algorithms — the paper's contribution and every baseline.
//!
//! | paper method (Tables I–II)    | implementation                        |
//! |-------------------------------|---------------------------------------|
//! | Radix Sort (on GPU)           | [`radix::sort_select_f64`] baseline   |
//! | Quickselect (on CPU)          | [`quickselect::quickselect`] + download phase |
//! | Quickselect (on GPU)          | [`gpu_model::GpuQuickselectModel`]    |
//! | Cutting Plane (total)         | [`hybrid::hybrid_select`] (CP+copy_if+sort) |
//! | Bisection                     | [`bisection::bisection`]              |
//! | Brent's minimization          | [`brent::brent_minimize`]             |
//! | Brent's nonlinear eqn         | [`brent::brent_root`]                 |
//! | (excluded: golden section)    | [`golden::golden_section`] (ablation) |
//! | (beyond the paper) p-section  | [`multisection::multisection`] — p probes per fused pass |
//! | (beyond the paper) fixed-pivot | [`fixed_pivot::fixed_pivot_select`] (Azzini–Perrotta) |
//!
//! All probe-based methods drive the [`Evaluator`] abstraction and therefore
//! run unchanged against the host oracle, the PJRT device runtime, or the
//! sharded multi-device simulation.

pub mod bisection;
pub mod brent;
pub mod cutting_plane;
pub mod exact;
pub mod fixed_pivot;
pub mod golden;
pub mod gpu_model;
pub mod hybrid;
pub mod multisection;
pub mod objective;
pub mod quickselect;
pub mod radix;
pub mod transform;
pub mod weighted;

pub use cutting_plane::{CpOptions, CpOutcome, TracePoint};
pub use gpu_model::{CostModelPool, PassCostModel};
pub use hybrid::{HybridOptions, HybridOutcome};
pub use multisection::{MultiOutcome, MultisectOptions, MultisectOutcome};
pub use objective::{
    ladder_sweep, ladder_sweep_scalar, DType, Evaluator, HostEvaluator, InitStats, IntervalCounts,
    LadderPartial, Neighbors, ObjectiveSpec, ProbeStats, LADDER_LANES,
};

use crate::util::PhaseTimer;
use crate::Result;

/// Selection method identifier (CLI / config / harness facing).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    /// Pure cutting plane to convergence + exact fixup.
    CuttingPlane,
    /// The paper's headline hybrid: CP + copy_if + radix sort of z.
    Hybrid,
    Bisection,
    /// p-section: batched bisection probing p points per fused pass
    /// (log_{p+1} passes instead of log_2).
    Multisection,
    BrentMinimize,
    BrentRoot,
    GoldenSection,
    /// Host quickselect on downloaded data (the CPU baseline).
    Quickselect,
    /// Deterministic median-of-medians on downloaded data.
    Bfprt,
    /// Full radix sort on downloaded data, index k.
    SortRadix,
    /// Azzini–Perrotta fixed-pivot selector on downloaded data (arxiv
    /// 2302.05705): the single-pass host baseline the wall-clock
    /// trajectory races the vectorized bin sweep against.
    FixedPivot,
}

impl Method {
    pub const ALL: [Method; 11] = [
        Method::CuttingPlane,
        Method::Hybrid,
        Method::Bisection,
        Method::Multisection,
        Method::BrentMinimize,
        Method::BrentRoot,
        Method::GoldenSection,
        Method::Quickselect,
        Method::Bfprt,
        Method::SortRadix,
        Method::FixedPivot,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Method::CuttingPlane => "cutting-plane",
            Method::Hybrid => "hybrid",
            Method::Bisection => "bisection",
            Method::Multisection => "multisection",
            Method::BrentMinimize => "brent-min",
            Method::BrentRoot => "brent-root",
            Method::GoldenSection => "golden",
            Method::Quickselect => "quickselect",
            Method::Bfprt => "bfprt",
            Method::SortRadix => "sort-radix",
            Method::FixedPivot => "fixed-pivot",
        }
    }

    pub fn from_name(s: &str) -> Option<Method> {
        Method::ALL.iter().copied().find(|m| m.name() == s)
    }

    /// Probe-based methods never leave the device; data-movement methods
    /// download the array first (the paper's "copy to CPU" cost).
    pub fn needs_download(&self) -> bool {
        matches!(
            self,
            Method::Quickselect | Method::Bfprt | Method::SortRadix | Method::FixedPivot
        )
    }
}

/// Unified result of any selection run.
#[derive(Debug, Clone)]
pub struct SelectResult {
    pub value: f64,
    pub method: Method,
    pub k: usize,
    /// Main-loop iterations (0 for download-based methods).
    pub iterations: usize,
    /// Device reductions issued.
    pub probes: u64,
    pub phases: PhaseTimer,
}

/// Compute the k-th smallest element with the chosen method.
pub fn order_statistic(ev: &mut dyn Evaluator, k: usize, method: Method) -> Result<SelectResult> {
    order_statistic_cancellable(ev, k, method, &mut || None)
}

/// [`order_statistic`] with a cooperative cancellation hook.
///
/// Every multi-pass method polls `cancel` at its pass boundaries (before
/// each fused reduction, never mid-pass); returning `Some(err)` aborts
/// the run with that error. Download-based single-pass methods
/// (`Quickselect`, `Bfprt`, `SortRadix`, `FixedPivot`) issue no fused
/// passes after the copy and run to completion — they are registered
/// exemptions in the `cancellation_discipline` lint rule.
pub fn order_statistic_cancellable(
    ev: &mut dyn Evaluator,
    k: usize,
    method: Method,
    cancel: &mut dyn FnMut() -> Option<crate::Error>,
) -> Result<SelectResult> {
    let probes0 = ev.probes();
    let (value, iterations, phases) = match method {
        Method::CuttingPlane => {
            let o = cutting_plane::cutting_plane_cancellable(ev, k, &CpOptions::default(), cancel)?;
            (o.value, o.iterations, o.phases)
        }
        Method::Hybrid => {
            let o = hybrid::hybrid_select_cancellable(ev, k, &HybridOptions::default(), cancel)?;
            (o.value, o.cp_iterations, o.phases)
        }
        Method::Bisection => {
            let o = bisection::bisection_cancellable(
                ev,
                k,
                &bisection::BisectOptions::default(),
                cancel,
            )?;
            (o.value, o.iterations, o.phases)
        }
        Method::Multisection => {
            // Ladder width adapts to the evaluator: a device evaluator
            // advertises its widest fused_ladder bucket so every pass is
            // exactly one launch; the host default stays 15.
            let opts = MultisectOptions::for_evaluator(&*ev);
            let o = multisection::multisection_cancellable(ev, k, &opts, cancel)?;
            (o.value, o.passes, o.phases)
        }
        Method::BrentMinimize => {
            let o =
                brent::brent_minimize_cancellable(ev, k, &brent::BrentOptions::default(), cancel)?;
            (o.value, o.iterations, o.phases)
        }
        Method::BrentRoot => {
            let o = brent::brent_root_cancellable(ev, k, &brent::BrentOptions::default(), cancel)?;
            (o.value, o.iterations, o.phases)
        }
        Method::GoldenSection => {
            let o =
                golden::golden_section_cancellable(ev, k, &golden::GoldenOptions::default(), cancel)?;
            (o.value, o.iterations, o.phases)
        }
        Method::Quickselect => {
            let mut phases = PhaseTimer::new();
            let mut data = phases.time("copy_to_host", || ev.download())?;
            let v = phases.time("algorithm", || quickselect::quickselect(&mut data, k));
            (v, 0, phases)
        }
        Method::Bfprt => {
            let mut phases = PhaseTimer::new();
            let mut data = phases.time("copy_to_host", || ev.download())?;
            let v = phases.time("algorithm", || quickselect::bfprt(&mut data, k));
            (v, 0, phases)
        }
        Method::SortRadix => {
            let mut phases = PhaseTimer::new();
            let data = phases.time("copy_to_host", || ev.download())?;
            let v = phases.time("algorithm", || match ev.dtype() {
                DType::F64 => radix::sort_select_f64(&data, k),
                DType::F32 => {
                    let f: Vec<f32> = data.iter().map(|&x| x as f32).collect();
                    radix::sort_select_f32(&f, k) as f64
                }
            });
            (v, 0, phases)
        }
        Method::FixedPivot => {
            let mut phases = PhaseTimer::new();
            let mut data = phases.time("copy_to_host", || ev.download())?;
            let v = phases.time("algorithm", || fixed_pivot::fixed_pivot_select(&mut data, k));
            (v, 0, phases)
        }
    };
    Ok(SelectResult {
        value,
        method,
        k,
        iterations,
        probes: ev.probes() - probes0,
        phases,
    })
}

/// Median with the paper's index convention `x_([(n+1)/2])`.
pub fn median(ev: &mut dyn Evaluator, method: Method) -> Result<SelectResult> {
    let k = crate::util::median_rank(ev.n());
    order_statistic(ev, k, method)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::{sorted_median, sorted_order_statistic, Distribution, Rng};

    #[test]
    fn every_method_matches_oracle() {
        let mut rng = Rng::seeded(101);
        let data = Distribution::Mixture4.sample_vec(&mut rng, 3001);
        let want = sorted_median(&data);
        for m in Method::ALL {
            let mut ev = HostEvaluator::new(&data);
            let got = median(&mut ev, m).unwrap();
            assert_eq!(got.value, want, "{}", m.name());
            assert_eq!(got.method, m);
        }
    }

    #[test]
    fn every_method_arbitrary_k() {
        let mut rng = Rng::seeded(102);
        let data = Distribution::Uniform.sample_vec(&mut rng, 500);
        for k in [1, 17, 250, 499, 500] {
            let want = sorted_order_statistic(&data, k);
            for m in Method::ALL {
                let mut ev = HostEvaluator::new(&data);
                let got = order_statistic(&mut ev, k, m).unwrap();
                assert_eq!(got.value, want, "{} k={k}", m.name());
            }
        }
    }

    #[test]
    fn method_names_roundtrip() {
        for m in Method::ALL {
            assert_eq!(Method::from_name(m.name()), Some(m));
        }
        assert_eq!(Method::from_name("nope"), None);
    }

    #[test]
    fn download_methods_report_copy_phase() {
        let mut rng = Rng::seeded(103);
        let data = Distribution::Normal.sample_vec(&mut rng, 10_000);
        let mut ev = HostEvaluator::new(&data);
        let r = median(&mut ev, Method::Quickselect).unwrap();
        assert!(r.phases.get_ms("algorithm") >= 0.0);
        assert_eq!(r.probes, 0, "quickselect must not issue device reductions");
    }

    #[test]
    fn probe_methods_cancel_at_pass_boundaries() {
        let mut rng = Rng::seeded(105);
        let data = Distribution::Normal.sample_vec(&mut rng, 4096);
        for m in Method::ALL.iter().copied().filter(|m| !m.needs_download()) {
            // Cancel at the third poll: the run must stop with the injected
            // error after a bounded number of fused reductions.
            let mut ev = HostEvaluator::new(&data);
            let mut polls = 0;
            let err = order_statistic_cancellable(&mut ev, 2048, m, &mut || {
                polls += 1;
                (polls > 2).then_some(crate::Error::DeadlineExceeded { late_us: 1 })
            })
            .unwrap_err();
            assert!(
                matches!(err, crate::Error::DeadlineExceeded { .. }),
                "{}: {err}",
                m.name()
            );
            assert!(ev.probes() <= 6, "{}: {} probes after cancel", m.name(), ev.probes());
        }
        // Download methods are single-pass: nothing to cancel between, so
        // an always-firing hook must not abort them.
        let mut ev = HostEvaluator::new(&data);
        let r = order_statistic_cancellable(&mut ev, 2048, Method::FixedPivot, &mut || {
            Some(crate::Error::DeadlineExceeded { late_us: 1 })
        })
        .unwrap();
        assert_eq!(r.value, sorted_order_statistic(&data, 2048));
    }

    #[test]
    fn probe_methods_count_reductions() {
        let mut rng = Rng::seeded(104);
        let data = Distribution::Normal.sample_vec(&mut rng, 10_000);
        let mut ev = HostEvaluator::new(&data);
        let r = median(&mut ev, Method::CuttingPlane).unwrap();
        assert!(r.probes >= 2, "cp must issue reductions, got {}", r.probes);
        assert!(r.probes <= 60, "cp issued too many: {}", r.probes);
    }
}
