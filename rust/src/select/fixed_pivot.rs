//! Azzini–Perrotta fixed-pivot selection (arxiv 2302.05705) — the
//! single-pass host baseline the wall-clock trajectory races.
//!
//! Wirth-style `kSmallest` with the pivot *fixed at the target rank's
//! current occupant* (`A[k]`) instead of a sampled or median-of-3 pivot:
//! after each Hoare partition the element that lands at position `k` is
//! the next pivot, so the window `[lo, hi]` collapses onto `k` from both
//! sides and the expected scan cost is a small constant number of passes
//! over the shrinking window — no recursion, no scratch allocation, no
//! three-way pass. On the throughput axis this is the strongest simple
//! host selector we know of, which is exactly why `bench-wall` uses it
//! as the baseline for the vectorized bin-sweep trajectory (see the
//! crate docs §"The wall-clock trajectory and the vectorized host
//! sweep").
//!
//! NaN handling: every comparison against NaN is false, so both scan
//! loops stop *earlier* than they would under a total order — the
//! explicit `i < hi` / `j > lo` bounds make that safe (no sentinel
//! argument needed) and the routine always terminates, but the returned
//! rank is unspecified when NaNs are present. That matches the other
//! download baselines ([`super::quickselect`]); callers that may carry
//! NaN payloads use the probe-based methods, whose NaN semantics are
//! pinned by the evaluator contract.

/// k-th smallest (1-indexed, matching [`super::quickselect::quickselect`])
/// via the Azzini–Perrotta fixed-pivot partition. Operates on a scratch
/// copy the caller provides (mutated in place).
pub fn fixed_pivot_select(data: &mut [f64], k: usize) -> f64 {
    assert!((1..=data.len()).contains(&k), "k={k} n={}", data.len());
    let kk = (k - 1) as isize;
    let mut lo = 0isize;
    let mut hi = data.len() as isize - 1;
    while lo < hi {
        // The fixed pivot: whatever currently occupies the target rank.
        let pivot = data[kk as usize];
        let mut i = lo;
        let mut j = hi;
        loop {
            // Hoare scans. Under a total order the pivot value itself
            // bounds both scans (it sits inside [i, j]); the explicit
            // index guards only matter when NaNs have broken the order,
            // and then they guarantee termination instead of UB.
            while i < hi && data[i as usize] < pivot {
                i += 1;
            }
            while j > lo && pivot < data[j as usize] {
                j -= 1;
            }
            if i <= j {
                data.swap(i as usize, j as usize);
                i += 1;
                j -= 1;
            }
            if i > j {
                break;
            }
        }
        // Keep only the side still holding rank kk; when the crossing
        // straddles kk both fire and the loop exits with data[kk] final.
        if j < kk {
            lo = i;
        }
        if kk < i {
            hi = j;
        }
    }
    data[kk as usize]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::{sorted_order_statistic, Distribution, Rng};

    #[test]
    fn matches_sort_oracle() {
        let mut rng = Rng::seeded(71);
        for d in Distribution::ALL {
            let data = d.sample_vec(&mut rng, 3001);
            for k in [1, 2, 1500, 1501, 3000, 3001] {
                let want = sorted_order_statistic(&data, k);
                let mut scratch = data.clone();
                assert_eq!(fixed_pivot_select(&mut scratch, k), want, "{} k={k}", d.name());
            }
        }
    }

    #[test]
    fn adversarial_patterns() {
        for pattern in ["sorted", "reverse", "constant", "organ"] {
            let n = 1024usize;
            let data: Vec<f64> = match pattern {
                "sorted" => (0..n).map(|i| i as f64).collect(),
                "reverse" => (0..n).rev().map(|i| i as f64).collect(),
                "constant" => vec![5.0; n],
                _ => (0..n).map(|i| (i.min(n - i)) as f64).collect(),
            };
            for k in [1, 2, n / 2, n - 1, n] {
                let want = sorted_order_statistic(&data, k);
                let mut s = data.clone();
                assert_eq!(fixed_pivot_select(&mut s, k), want, "{pattern} k={k}");
            }
        }
    }

    #[test]
    fn duplicates_heavy() {
        let mut rng = Rng::seeded(72);
        let data: Vec<f64> = (0..5000).map(|_| (rng.below(7)) as f64).collect();
        for k in [1, 13, 2500, 4999, 5000] {
            let want = sorted_order_statistic(&data, k);
            let mut s = data.clone();
            assert_eq!(fixed_pivot_select(&mut s, k), want, "k={k}");
        }
    }

    #[test]
    fn tiny_inputs() {
        assert_eq!(fixed_pivot_select(&mut [3.0], 1), 3.0);
        assert_eq!(fixed_pivot_select(&mut [3.0, 1.0], 1), 1.0);
        assert_eq!(fixed_pivot_select(&mut [3.0, 1.0], 2), 3.0);
        assert_eq!(fixed_pivot_select(&mut [2.0, 2.0, 1.0], 2), 2.0);
    }

    #[test]
    fn terminates_on_nan_payloads() {
        // Result is unspecified with NaNs present; the contract is only
        // that the bounds-guarded scans terminate without panicking.
        let mut rng = Rng::seeded(73);
        for frac in [1, 3, 7] {
            let data: Vec<f64> = (0..999)
                .map(|i| if i % frac == 0 { f64::NAN } else { rng.f64() })
                .collect();
            for k in [1, 500, 999] {
                let mut s = data.clone();
                let _ = fixed_pivot_select(&mut s, k);
            }
        }
    }
}
