//! Bisection on the subgradient inclusion `0 ∈ ∂f(y)` (paper §III).
//!
//! The classical root-finding baseline: halve the value interval, keep the
//! half whose endpoint subgradients bracket zero. Iteration count is
//! `O(log r)` with `r = x_(n) − x_(1)` — *unbounded* in the data range,
//! which is exactly the sensitivity to large outliers the paper demonstrates
//! in Fig. 5 (and our `fig5_outliers` bench reproduces).

use super::exact;
use super::objective::{Evaluator, ObjectiveSpec};
use crate::util::PhaseTimer;
use crate::Result;

#[derive(Debug, Clone)]
pub struct BisectOptions {
    pub max_iters: usize,
    /// Relative bracket-width tolerance.
    pub tol: f64,
}

impl Default for BisectOptions {
    fn default() -> Self {
        // ~52 halvings resolve any f64 bracket to adjacent floats, but an
        // outlier-stretched range needs many more to *reach* the bulk.
        BisectOptions { max_iters: 200, tol: 1e-12 }
    }
}

#[derive(Debug, Clone)]
pub struct BisectOutcome {
    pub value: f64,
    pub iterations: usize,
    pub phases: PhaseTimer,
}

/// Bisection for the k-th smallest element; exact via rank resolution.
pub fn bisection(ev: &mut dyn Evaluator, k: usize, opts: &BisectOptions) -> Result<BisectOutcome> {
    bisection_cancellable(ev, k, opts, &mut || None)
}

/// [`bisection`] with a cooperative cancellation hook, polled at every
/// pass boundary (before each probe reduction) — never mid-pass. The
/// coordinator wires deadline expiry through this hook.
pub fn bisection_cancellable(
    ev: &mut dyn Evaluator,
    k: usize,
    opts: &BisectOptions,
    cancel: &mut dyn FnMut() -> Option<crate::Error>,
) -> Result<BisectOutcome> {
    let n = ev.n();
    let spec = ObjectiveSpec::order(n, k)?;
    let mut phases = PhaseTimer::new();

    let init = phases.time("iterations", || ev.init_stats())?;
    let (mut lo, mut hi) = (init.min, init.max);
    if lo == hi || k == 1 || k == n {
        let v = if k == n { hi } else if k == 1 { lo } else { lo };
        return Ok(BisectOutcome { value: v, iterations: 0, phases });
    }

    let mut iterations = 0;
    let mut mid = 0.5 * (lo + hi);
    while iterations < opts.max_iters {
        if let Some(err) = cancel() {
            return Err(err);
        }
        mid = 0.5 * (lo + hi);
        if mid <= lo || mid >= hi {
            break; // adjacent floats
        }
        let s = phases.time("iterations", || ev.probe(mid))?;
        iterations += 1;
        if spec.is_optimal(&s) {
            break;
        }
        if spec.answer_above(&s) {
            lo = mid;
        } else {
            hi = mid;
        }
        if (hi - lo) <= opts.tol * lo.abs().max(hi.abs()).max(1.0) {
            break;
        }
    }

    let value = phases.time("exact_fixup", || exact::resolve(ev, k, mid))?;
    Ok(BisectOutcome { value, iterations, phases })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::select::objective::HostEvaluator;
    use crate::stats::{sorted_median, sorted_order_statistic, Distribution, Rng};
    use crate::util::median_rank;

    #[test]
    fn matches_oracle_across_distributions() {
        let mut rng = Rng::seeded(31);
        for d in Distribution::ALL {
            let data = d.sample_vec(&mut rng, 2048);
            let mut ev = HostEvaluator::new(&data);
            let out = bisection(&mut ev, median_rank(2048), &BisectOptions::default()).unwrap();
            assert_eq!(out.value, sorted_median(&data), "{}", d.name());
        }
    }

    #[test]
    fn order_statistics_random_k() {
        let mut rng = Rng::seeded(32);
        let data = Distribution::Mixture1.sample_vec(&mut rng, 1000);
        for k in [1, 7, 333, 500, 999, 1000] {
            let mut ev = HostEvaluator::new(&data);
            let out = bisection(&mut ev, k, &BisectOptions::default()).unwrap();
            assert_eq!(out.value, sorted_order_statistic(&data, k), "k={k}");
        }
    }

    #[test]
    fn iteration_count_grows_with_range_fig5() {
        // the paper's Fig. 5 pathology: iterations scale with log(range)
        let mut rng = Rng::seeded(33);
        let base = Distribution::Normal.sample_vec(&mut rng, 4096);
        let mut prev = 0usize;
        let mut grew = 0;
        for mag in [1e3, 1e6, 1e9, 1e12] {
            let mut data = base.clone();
            data[0] = mag;
            let mut ev = HostEvaluator::new(&data);
            let out = bisection(&mut ev, 2048, &BisectOptions::default()).unwrap();
            assert_eq!(out.value, sorted_median(&data));
            if out.iterations > prev {
                grew += 1;
            }
            prev = out.iterations;
        }
        assert!(grew >= 3, "bisection should need more iterations as the outlier grows");
    }

    #[test]
    fn constant_array() {
        let mut ev = HostEvaluator::new(&[2.0; 100]);
        let out = bisection(&mut ev, 50, &BisectOptions::default()).unwrap();
        assert_eq!(out.value, 2.0);
        assert_eq!(out.iterations, 0);
    }
}
