//! p-section: generalized bisection probing `p` points per fused pass.
//!
//! Bisection needs `log₂(range/ε)` passes because each pass asks one rank
//! question. With batched multi-probe evaluation
//! ([`Evaluator::probe_many`]) one pass can ask `p` questions at once: the
//! bracket is divided into `p + 1` equal segments, the whole probe ladder
//! is evaluated in a **single fused reduction**, and the rank test
//! (`c_le < k`?) localizes the answer to one segment — so the bracket
//! shrinks by `p + 1` per pass and convergence takes
//! `log_{p+1}(range/ε)` passes. With the default `p = 15` that is 4× fewer
//! passes than bisection for the same tolerance (16× shrink per pass), at
//! the cost of `p` compares per element per pass — a good trade whenever
//! passes (reductions) dominate, which is the paper's central premise.
//!
//! This is the successive-binning idea of Tibshirani (2008) and the
//! multi-pivot batching of Azzini et al. (2023) expressed through the
//! evaluator abstraction; see PAPERS.md.
//!
//! [`multi_order_statistics`] extends the same ladder sharing across
//! *queries*: the sufficient statistics of a probe are rank-independent, so
//! one fused ladder pass serves any number of concurrent `k`s against the
//! same array. The coordinator uses it to coalesce queued same-dataset
//! queries (`coordinator::SelectionService::query_many`).

use std::collections::HashMap;

use super::exact;
use super::gpu_model::PassCostModel;
use super::objective::{Evaluator, ObjectiveSpec};
use crate::util::PhaseTimer;
use crate::Result;

#[derive(Debug, Clone)]
pub struct MultisectOptions {
    /// Probes per fused pass; the bracket shrinks by `probes_per_pass + 1`
    /// each pass (1 degenerates to plain bisection).
    pub probes_per_pass: usize,
    /// Hard cap on ladder passes.
    pub max_passes: usize,
    /// Relative bracket-width tolerance (same meaning as bisection's).
    pub tol: f64,
}

impl Default for MultisectOptions {
    fn default() -> Self {
        MultisectOptions { probes_per_pass: 15, max_passes: 64, tol: 1e-12 }
    }
}

impl MultisectOptions {
    /// Ladder-width-adapted options under the *seeded* pass-cost model:
    /// when the evaluator advertises a native fused-ladder width
    /// ([`Evaluator::ladder_width_hint`] — the device runtime's widest
    /// `fused_ladder` artifact bucket) that width is the plan (each pass
    /// is exactly one device reduction); otherwise the seeded
    /// [`PassCostModel`] picks the width that minimizes modeled run cost
    /// — which, by construction of the seed, is the committed
    /// `BENCH_select.json` trajectory's width 15.
    pub fn for_evaluator(ev: &dyn Evaluator) -> Self {
        Self::for_evaluator_with(ev, &PassCostModel::seeded())
    }

    /// Like [`MultisectOptions::for_evaluator`] but consulting a *measured*
    /// cost model — the coordinator threads each worker's online-refined
    /// [`PassCostModel`] through here, so probes-per-pass follows measured
    /// pass cost vs ladder width rather than a hard-coded constant.
    pub fn for_evaluator_with(ev: &dyn Evaluator, model: &PassCostModel) -> Self {
        MultisectOptions {
            probes_per_pass: model.best_width(ev.ladder_width_hint()).max(1),
            ..Self::default()
        }
    }
}

#[derive(Debug, Clone)]
pub struct MultisectOutcome {
    pub value: f64,
    /// Fused ladder passes executed — each is ONE device reduction.
    pub passes: usize,
    pub phases: PhaseTimer,
}

/// Evenly spaced interior ladder for the open bracket `(lo, hi)`.
fn ladder_points(lo: f64, hi: f64, p: usize) -> Vec<f64> {
    let width = hi - lo;
    let mut ys = Vec::with_capacity(p);
    for i in 1..=p {
        let y = lo + width * i as f64 / (p + 1) as f64;
        // strictly interior and strictly increasing (guards float collapse
        // once the bracket nears adjacent representable values)
        if y > lo && y < hi && ys.last().is_none_or(|&prev| y > prev) {
            ys.push(y);
        }
    }
    ys
}

/// p-section for the k-th smallest element; exact via rank resolution.
pub fn multisection(
    ev: &mut dyn Evaluator,
    k: usize,
    opts: &MultisectOptions,
) -> Result<MultisectOutcome> {
    multisection_cancellable(ev, k, opts, &mut || None)
}

/// [`multisection`] with a cooperative cancellation hook, polled at every
/// pass boundary (before each fused ladder pass) — never mid-pass.
pub fn multisection_cancellable(
    ev: &mut dyn Evaluator,
    k: usize,
    opts: &MultisectOptions,
    cancel: &mut dyn FnMut() -> Option<crate::Error>,
) -> Result<MultisectOutcome> {
    let n = ev.n();
    let spec = ObjectiveSpec::order(n, k)?;
    let mut phases = PhaseTimer::new();

    let init = phases.time("iterations", || ev.init_stats())?;
    let (mut lo, mut hi) = (init.min, init.max);
    if lo == hi || k == 1 || k == n {
        let v = if k == n { hi } else { lo };
        return Ok(MultisectOutcome { value: v, passes: 0, phases });
    }

    let p = opts.probes_per_pass.max(1);
    let mut passes = 0;
    let mut resolved = None;
    while passes < opts.max_passes {
        if let Some(err) = cancel() {
            return Err(err);
        }
        let ys = ladder_points(lo, hi, p);
        if ys.is_empty() {
            break; // bracket exhausted to adjacent floats
        }
        let stats = phases.time("iterations", || ev.probe_many(&ys))?;
        passes += 1;
        for (y, s) in ys.iter().zip(&stats) {
            if spec.is_optimal(s) {
                // 0 ∈ ∂f at a probe forces c_eq ≥ 1: the (canonicalized)
                // probe IS the data value of rank k.
                resolved = Some(ev.canon(*y));
                break;
            }
            if spec.answer_above(s) {
                if *y > lo {
                    lo = *y;
                }
            } else if *y < hi {
                hi = *y;
            }
        }
        if resolved.is_some() {
            break;
        }
        if (hi - lo) <= opts.tol * lo.abs().max(hi.abs()).max(1.0) {
            break;
        }
    }

    if let Some(value) = resolved {
        return Ok(MultisectOutcome { value, passes, phases });
    }
    let mid = 0.5 * (lo + hi);
    let value = phases.time("exact_fixup", || {
        exact::resolve_with_bracket(ev, k, mid, Some((lo, hi)))
    })?;
    Ok(MultisectOutcome { value, passes, phases })
}

/// Result of a shared multi-query run.
#[derive(Debug, Clone)]
pub struct MultiOutcome {
    /// Exact order statistics, positionally aligned with the input `ks`.
    pub values: Vec<f64>,
    /// Shared fused ladder passes (excludes the one shared seed reduction
    /// and the per-query exact-fixup tail).
    pub passes: usize,
    /// Total ladder rungs actually evaluated across those passes — after
    /// bracket dedup and budget splitting this can differ from
    /// `passes × probes_per_pass`, and it is what a pass-cost model should
    /// regress on.
    pub rungs: u64,
}

/// Solve many order statistics of one array with **shared** ladder passes.
///
/// All queries see every probe of every pass: the sufficient statistics of
/// a probe y are properties of (data, y) alone, so each query applies its
/// own rank test to the same [`super::objective::ProbeStats`]. N queries on
/// one resident array therefore cost ~one probe-ladder pass per iteration
/// instead of N (identical brackets — e.g. N concurrent medians — collapse
/// to literally the same ladder).
pub fn multi_order_statistics(
    ev: &mut dyn Evaluator,
    ks: &[usize],
    opts: &MultisectOptions,
) -> Result<MultiOutcome> {
    multi_order_statistics_cancellable(ev, ks, opts, &mut || None)
}

/// [`multi_order_statistics`] with a cooperative cancellation hook.
///
/// `cancel` is polled at every **pass boundary** (before each shared
/// ladder pass and before each exact-fixup resolution) — never mid-pass,
/// so a fused reduction already in flight always completes. Returning
/// `Some(err)` aborts the run with that error; the coordinator uses this
/// to stop spending fused reductions on queries whose deadline has
/// passed.
pub fn multi_order_statistics_cancellable(
    ev: &mut dyn Evaluator,
    ks: &[usize],
    opts: &MultisectOptions,
    cancel: &mut dyn FnMut() -> Option<crate::Error>,
) -> Result<MultiOutcome> {
    let n = ev.n();
    if ks.is_empty() {
        return Ok(MultiOutcome { values: Vec::new(), passes: 0, rungs: 0 });
    }
    let specs: Vec<ObjectiveSpec> = ks
        .iter()
        .map(|&k| ObjectiveSpec::order(n, k))
        .collect::<Result<Vec<_>>>()?;

    let init = ev.init_stats()?; // one shared seed reduction
    struct Q {
        lo: f64,
        hi: f64,
        done: Option<f64>,
    }
    let mut qs: Vec<Q> = ks
        .iter()
        .map(|&k| {
            let done = if init.min == init.max || k == 1 {
                Some(init.min)
            } else if k == n {
                Some(init.max)
            } else {
                None
            };
            Q { lo: init.min, hi: init.max, done }
        })
        .collect();

    let p_total = opts.probes_per_pass.max(1);
    // Identical ranks (e.g. N concurrent medians) have identical answers:
    // resolve the fixup tail once per distinct rank.
    let mut memo: HashMap<usize, f64> = HashMap::new();
    let mut passes = 0;
    let mut rungs: u64 = 0;
    while passes < opts.max_passes {
        let unresolved: Vec<usize> = (0..qs.len()).filter(|&i| qs[i].done.is_none()).collect();
        if unresolved.is_empty() {
            break;
        }
        if let Some(err) = cancel() {
            return Err(err);
        }
        // Distribute the pass budget over *distinct* open brackets, so N
        // identical queries (e.g. N concurrent medians) ride one
        // full-resolution ladder instead of splitting the budget N ways.
        let mut brackets: Vec<(f64, f64)> = Vec::new();
        for &i in &unresolved {
            let b = (qs[i].lo, qs[i].hi);
            if !brackets.contains(&b) {
                brackets.push(b);
            }
        }
        let per_b = (p_total / brackets.len()).max(1);
        let mut ys: Vec<f64> = Vec::new();
        for &(lo, hi) in &brackets {
            ys.extend(ladder_points(lo, hi, per_b));
        }
        ys.sort_by(crate::util::total_cmp_f64);
        ys.dedup();
        if ys.is_empty() {
            break;
        }
        let stats = ev.probe_many(&ys)?; // ONE fused pass serves every query
        passes += 1;
        rungs += ys.len() as u64;
        for &i in &unresolved {
            {
                let q = &mut qs[i];
                let spec = &specs[i];
                for (y, s) in ys.iter().zip(&stats) {
                    if spec.is_optimal(s) {
                        q.done = Some(ev.canon(*y));
                        break;
                    }
                    if spec.answer_above(s) {
                        if *y > q.lo {
                            q.lo = *y;
                        }
                    } else if *y < q.hi {
                        q.hi = *y;
                    }
                }
            }
            let (lo, hi, open) = {
                let q = &qs[i];
                (q.lo, q.hi, q.done.is_none())
            };
            if open && (hi - lo) <= opts.tol * lo.abs().max(hi.abs()).max(1.0) {
                let v = match memo.get(&ks[i]) {
                    Some(&v) => v,
                    None => {
                        let v = exact::resolve_with_bracket(
                            ev,
                            ks[i],
                            0.5 * (lo + hi),
                            Some((lo, hi)),
                        )?;
                        memo.insert(ks[i], v);
                        v
                    }
                };
                qs[i].done = Some(v);
            }
        }
    }
    // Pass budget exhausted with open queries: finish them individually.
    for (i, q) in qs.iter_mut().enumerate() {
        if q.done.is_none() {
            if let Some(err) = cancel() {
                return Err(err);
            }
            let v = match memo.get(&ks[i]) {
                Some(&v) => v,
                None => {
                    let v = exact::resolve_with_bracket(
                        ev,
                        ks[i],
                        0.5 * (q.lo + q.hi),
                        Some((q.lo, q.hi)),
                    )?;
                    memo.insert(ks[i], v);
                    v
                }
            };
            q.done = Some(v);
        }
    }
    Ok(MultiOutcome {
        // lint: allow(error_discipline) — the budget-exhausted tail above resolves every open query; a None here is a logic bug worth a loud panic
        values: qs.into_iter().map(|q| q.done.expect("resolved")).collect(),
        passes,
        rungs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::select::objective::HostEvaluator;
    use crate::stats::{sorted_median, sorted_order_statistic, Distribution, Rng};
    use crate::util::median_rank;

    #[test]
    fn matches_oracle_across_distributions() {
        let mut rng = Rng::seeded(61);
        for d in Distribution::ALL {
            for n in [5usize, 64, 1001, 4096] {
                let data = d.sample_vec(&mut rng, n);
                let mut ev = HostEvaluator::new(&data);
                let out =
                    multisection(&mut ev, median_rank(n), &MultisectOptions::default()).unwrap();
                assert_eq!(out.value, sorted_median(&data), "{} n={n}", d.name());
            }
        }
    }

    #[test]
    fn order_statistics_random_k() {
        let mut rng = Rng::seeded(62);
        let data = Distribution::Mixture2.sample_vec(&mut rng, 1000);
        for k in [1, 7, 333, 500, 999, 1000] {
            let mut ev = HostEvaluator::new(&data);
            let out = multisection(&mut ev, k, &MultisectOptions::default()).unwrap();
            assert_eq!(out.value, sorted_order_statistic(&data, k), "k={k}");
        }
    }

    #[test]
    fn pass_count_beats_bisection_geometrically() {
        // p probes per pass shrink the bracket by (p+1): passes scale like
        // log_{p+1}(range/tol), so p = 15 needs ~1/4 of bisection's passes.
        let mut rng = Rng::seeded(63);
        let data = Distribution::Uniform.sample_vec(&mut rng, 1 << 14);
        let k = median_rank(data.len());

        let mut ev_ms = HostEvaluator::new(&data);
        let ms = multisection(&mut ev_ms, k, &MultisectOptions::default()).unwrap();
        assert_eq!(ms.value, sorted_median(&data));

        let mut ev_bi = HostEvaluator::new(&data);
        let bi = crate::select::bisection::bisection(
            &mut ev_bi,
            k,
            &crate::select::bisection::BisectOptions::default(),
        )
        .unwrap();
        assert!(
            ms.passes * 3 <= bi.iterations,
            "multisection {} passes vs bisection {} iterations",
            ms.passes,
            bi.iterations
        );
    }

    #[test]
    fn meets_the_log16_pass_bound_at_2_22() {
        // Acceptance criterion: p = 15 probes/pass reaches the exact median
        // of n = 2²² within ⌈log₁₆(range·2/ε)⌉ passes.
        let mut rng = Rng::seeded(64);
        let n = 1 << 22;
        let data = Distribution::Uniform.sample_vec(&mut rng, n);
        let opts = MultisectOptions::default();
        let mut ev = HostEvaluator::new(&data);
        let out = multisection(&mut ev, median_rank(n), &opts).unwrap();
        assert_eq!(out.value, sorted_median(&data));
        let range: f64 = 1.0; // U(0,1) support; observed range is tighter
        let eps = opts.tol; // relative scale is 1 on this data
        let bound = (range * 2.0 / eps).log(16.0).ceil() as usize;
        assert!(out.passes <= bound, "{} passes exceeds the log16 bound {bound}", out.passes);
        // seed + passes + a handful of fixup reductions (the analytic
        // mirror run records exactly 1 + 10 + 10 on this seed)
        assert!(
            ev.probes() <= out.passes as u64 + 1 + 16,
            "probes={} passes={}",
            ev.probes(),
            out.passes
        );
    }

    #[test]
    fn probes_per_pass_one_is_bisection() {
        let mut rng = Rng::seeded(65);
        let data = Distribution::Normal.sample_vec(&mut rng, 2048);
        let mut ev = HostEvaluator::new(&data);
        let out = multisection(
            &mut ev,
            1024,
            &MultisectOptions { probes_per_pass: 1, ..Default::default() },
        )
        .unwrap();
        assert_eq!(out.value, sorted_order_statistic(&data, 1024));
    }

    #[test]
    fn constant_and_tiny_arrays() {
        let mut ev = HostEvaluator::new(&[4.0; 7]);
        let out = multisection(&mut ev, 3, &MultisectOptions::default()).unwrap();
        assert_eq!(out.value, 4.0);
        assert_eq!(out.passes, 0);
        let mut ev = HostEvaluator::new(&[2.0, 1.0]);
        let out = multisection(&mut ev, 2, &MultisectOptions::default()).unwrap();
        assert_eq!(out.value, 2.0);
    }

    #[test]
    fn heavy_duplicates() {
        let mut data = vec![5.0; 1000];
        data.extend(std::iter::repeat(1.0).take(500));
        data.extend(std::iter::repeat(9.0).take(500));
        let mut rng = Rng::seeded(66);
        rng.shuffle(&mut data);
        for k in [1, 500, 501, 1000, 1500, 1501, 2000] {
            let mut ev = HostEvaluator::new(&data);
            let out = multisection(&mut ev, k, &MultisectOptions::default()).unwrap();
            assert_eq!(out.value, sorted_order_statistic(&data, k), "k={k}");
        }
    }

    #[test]
    fn outliers_only_cost_log16_of_the_stretch() {
        let mut rng = Rng::seeded(67);
        let mut data = Distribution::Normal.sample_vec(&mut rng, 4096);
        data[0] = 1e12;
        let mut ev = HostEvaluator::new(&data);
        let out = multisection(&mut ev, 2048, &MultisectOptions::default()).unwrap();
        assert_eq!(out.value, sorted_median(&data));
        // bisection needs ~log2(1e12/1e-12·...) ≈ 90+ iterations here;
        // p-section divides the same stretch by 16 per pass
        assert!(out.passes <= 30, "{} passes", out.passes);
    }

    #[test]
    fn multi_query_shares_ladder_passes() {
        let mut rng = Rng::seeded(68);
        let data = Distribution::HalfNormal.sample_vec(&mut rng, 8192);
        let ks = [1usize, 512, 2048, 4096, 4097, 6000, 8000, 8192];
        let mut ev = HostEvaluator::new(&data);
        let out = multi_order_statistics(&mut ev, &ks, &MultisectOptions::default()).unwrap();
        for (k, v) in ks.iter().zip(&out.values) {
            assert_eq!(*v, sorted_order_statistic(&data, *k), "k={k}");
        }
        let shared = ev.probes();

        // the same queries run one-by-one cost strictly more reductions
        let mut total_individual = 0;
        for &k in &ks {
            let mut ev = HostEvaluator::new(&data);
            multisection(&mut ev, k, &MultisectOptions::default()).unwrap();
            total_individual += ev.probes();
        }
        assert!(
            shared < total_individual,
            "shared {shared} reductions vs {total_individual} individual"
        );
    }

    #[test]
    fn multi_query_identical_ks_cost_one_run() {
        let mut rng = Rng::seeded(69);
        let data = Distribution::Normal.sample_vec(&mut rng, 4096);
        let want = sorted_median(&data);
        let ks = [2048usize; 8];
        let mut ev = HostEvaluator::new(&data);
        let out = multi_order_statistics(&mut ev, &ks, &MultisectOptions::default()).unwrap();
        assert!(out.values.iter().all(|&v| v == want));
        let shared = ev.probes();
        let mut ev1 = HostEvaluator::new(&data);
        multisection(&mut ev1, 2048, &MultisectOptions::default()).unwrap();
        // 8 identical queries ride the single query's ladder (identical
        // brackets dedupe to one set of rungs; the fixup tail may replay
        // per query, so allow a small additive slack)
        assert!(shared <= ev1.probes() + 16, "shared {} vs single {}", shared, ev1.probes());
    }

    #[test]
    fn measured_cost_model_steers_the_planned_width() {
        let data: Vec<f64> = (0..256).map(|i| i as f64).collect();
        let ev = HostEvaluator::new(&data);
        // seeded: the committed-trajectory width
        assert_eq!(MultisectOptions::for_evaluator(&ev).probes_per_pass, 15);
        // probe-heavy measurements (per-probe cost = sweep cost) narrow it
        let mut model = PassCostModel::seeded();
        for (i, &w) in [1usize, 3, 7, 15, 31, 2, 5, 11, 23, 63].iter().enumerate() {
            let passes = 4 + i % 3;
            let total = (passes + 2) as u64;
            let n = 1usize << 12;
            let secs = 1e-9 * (total as f64 + (passes * w + 2) as f64) * n as f64;
            let rungs = (passes * w) as u64;
            model.observe_run(passes, rungs, total, n, std::time::Duration::from_secs_f64(secs));
        }
        let opts = MultisectOptions::for_evaluator_with(&ev, &model);
        assert!(opts.probes_per_pass < 15, "got {}", opts.probes_per_pass);
        // whatever width the model picks, the answer stays exact
        let mut ev = HostEvaluator::new(&data);
        let out = multisection(&mut ev, 128, &opts).unwrap();
        assert_eq!(out.value, 127.0);
    }

    #[test]
    fn cancellation_stops_at_a_pass_boundary() {
        let mut rng = Rng::seeded(70);
        let data = Distribution::Normal.sample_vec(&mut rng, 4096);
        // cancel after two shared passes
        let mut remaining = 2u32;
        let mut ev = HostEvaluator::new(&data);
        let err = multi_order_statistics_cancellable(
            &mut ev,
            &[2048, 100],
            &MultisectOptions::default(),
            &mut || {
                if remaining == 0 {
                    Some(crate::Error::DeadlineExceeded { late_us: 1 })
                } else {
                    remaining -= 1;
                    None
                }
            },
        )
        .unwrap_err();
        assert!(matches!(err, crate::Error::DeadlineExceeded { .. }));
        // seed + exactly the two granted passes, nothing mid-pass
        assert_eq!(ev.probes(), 3, "cancel lands on the pass boundary");
        // never cancelling reproduces multi_order_statistics exactly
        let mut ev = HostEvaluator::new(&data);
        let out = multi_order_statistics_cancellable(
            &mut ev,
            &[2048],
            &MultisectOptions::default(),
            &mut || None,
        )
        .unwrap();
        assert_eq!(out.values[0], sorted_median(&data));
    }

    #[test]
    fn multi_query_rejects_bad_k() {
        let mut ev = HostEvaluator::new(&[1.0, 2.0]);
        assert!(multi_order_statistics(&mut ev, &[0], &MultisectOptions::default()).is_err());
        assert!(multi_order_statistics(&mut ev, &[3], &MultisectOptions::default()).is_err());
        let out = multi_order_statistics(&mut ev, &[], &MultisectOptions::default()).unwrap();
        assert!(out.values.is_empty());
    }
}
