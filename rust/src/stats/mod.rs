//! Data-generation substrate: deterministic RNG and the paper's nine test
//! distributions (§V.A), plus outlier injection for the §V.D experiments.

pub mod distributions;
pub mod rng;
pub mod robust;

pub use distributions::{Distribution, OutlierSpec};
pub use rng::Rng;

/// Exact (sort-based) k-th order statistic, 1-indexed — the test oracle.
pub fn sorted_order_statistic(data: &[f64], k: usize) -> f64 {
    assert!((1..=data.len()).contains(&k));
    let mut v = data.to_vec();
    v.sort_by(crate::util::total_cmp_f64);
    v[k - 1]
}

/// Exact lower median, `x_([(n+1)/2])` — the paper's definition.
pub fn sorted_median(data: &[f64]) -> f64 {
    sorted_order_statistic(data, crate::util::median_rank(data.len()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn order_statistic_oracle() {
        let v = [5.0, 1.0, 4.0, 2.0, 3.0];
        assert_eq!(sorted_order_statistic(&v, 1), 1.0);
        assert_eq!(sorted_order_statistic(&v, 3), 3.0);
        assert_eq!(sorted_order_statistic(&v, 5), 5.0);
        assert_eq!(sorted_median(&v), 3.0);
    }

    #[test]
    fn even_n_uses_lower_median() {
        let v = [4.0, 1.0, 3.0, 2.0];
        // [(4+1)/2] = 2 -> x_(2) = 2
        assert_eq!(sorted_median(&v), 2.0);
    }
}
