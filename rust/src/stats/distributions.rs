//! The paper's nine test distributions (§V.A) and the §V.D outlier regimes.
//!
//! 1. Uniform U(0,1)                       6. Mixture 2: 50% N(0,1)+1, 50% N(100,1)
//! 2. Normal N(0,1)                        7. Mixture 3: 90% |N(0,1)|, 10% == 10
//! 3. Half-normal |N(0,1)|                 8. Mixture 4: 66.6% |N(0,1)|, 33.3% N(100,1)
//! 4. Beta(2,5)                            9. Mixture 5: 50% |N(0,1)|+1, 50% N(100,1)
//! 5. Mixture 1: 66.6% N(0,1), 33.3% N(100,1)
//!
//! Half-normal mixtures model regression residuals with outliers — the
//! paper's motivating application.

use super::rng::Rng;

/// One of the paper's §V.A data distributions.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Distribution {
    Uniform,
    Normal,
    HalfNormal,
    Beta25,
    Mixture1,
    Mixture2,
    Mixture3,
    Mixture4,
    Mixture5,
}

impl Distribution {
    /// All nine, in the paper's order.
    pub const ALL: [Distribution; 9] = [
        Distribution::Uniform,
        Distribution::Normal,
        Distribution::HalfNormal,
        Distribution::Beta25,
        Distribution::Mixture1,
        Distribution::Mixture2,
        Distribution::Mixture3,
        Distribution::Mixture4,
        Distribution::Mixture5,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Distribution::Uniform => "uniform",
            Distribution::Normal => "normal",
            Distribution::HalfNormal => "halfnormal",
            Distribution::Beta25 => "beta25",
            Distribution::Mixture1 => "mixture1",
            Distribution::Mixture2 => "mixture2",
            Distribution::Mixture3 => "mixture3",
            Distribution::Mixture4 => "mixture4",
            Distribution::Mixture5 => "mixture5",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        Self::ALL.iter().copied().find(|d| d.name() == s)
    }

    /// Draw one sample.
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        match self {
            Distribution::Uniform => rng.f64(),
            Distribution::Normal => rng.normal(),
            Distribution::HalfNormal => rng.normal().abs(),
            Distribution::Beta25 => rng.beta(2.0, 5.0),
            Distribution::Mixture1 => {
                if rng.f64() < 2.0 / 3.0 {
                    rng.normal()
                } else {
                    100.0 + rng.normal()
                }
            }
            Distribution::Mixture2 => {
                if rng.f64() < 0.5 {
                    rng.normal() + 1.0
                } else {
                    100.0 + rng.normal()
                }
            }
            Distribution::Mixture3 => {
                if rng.f64() < 0.9 {
                    rng.normal().abs()
                } else {
                    10.0
                }
            }
            Distribution::Mixture4 => {
                if rng.f64() < 2.0 / 3.0 {
                    rng.normal().abs()
                } else {
                    100.0 + rng.normal()
                }
            }
            Distribution::Mixture5 => {
                if rng.f64() < 0.5 {
                    rng.normal().abs() + 1.0
                } else {
                    100.0 + rng.normal()
                }
            }
        }
    }

    /// Sample a full vector.
    pub fn sample_vec(&self, rng: &mut Rng, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.sample(rng)).collect()
    }
}

/// Outlier injection for the §V.D sensitivity experiments: set `count`
/// random elements to `magnitude`.
#[derive(Debug, Clone, Copy)]
pub struct OutlierSpec {
    pub magnitude: f64,
    pub count: usize,
}

impl OutlierSpec {
    pub fn inject(&self, rng: &mut Rng, data: &mut [f64]) {
        for _ in 0..self.count {
            let i = rng.below(data.len());
            data[i] = self.magnitude;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::sorted_median;

    #[test]
    fn names_roundtrip() {
        for d in Distribution::ALL {
            assert_eq!(Distribution::from_name(d.name()), Some(d));
        }
        assert_eq!(Distribution::from_name("bogus"), None);
    }

    #[test]
    fn uniform_median_near_half() {
        let mut rng = Rng::seeded(1);
        let v = Distribution::Uniform.sample_vec(&mut rng, 50_000);
        assert!((sorted_median(&v) - 0.5).abs() < 0.01);
    }

    #[test]
    fn halfnormal_is_nonnegative() {
        let mut rng = Rng::seeded(2);
        let v = Distribution::HalfNormal.sample_vec(&mut rng, 10_000);
        assert!(v.iter().all(|&x| x >= 0.0));
        // median of |N(0,1)| is ~0.6745
        assert!((sorted_median(&v) - 0.6745).abs() < 0.03);
    }

    #[test]
    fn mixture1_is_bimodal() {
        let mut rng = Rng::seeded(3);
        let v = Distribution::Mixture1.sample_vec(&mut rng, 30_000);
        let hi = v.iter().filter(|&&x| x > 50.0).count() as f64 / v.len() as f64;
        assert!((hi - 1.0 / 3.0).abs() < 0.02, "hi fraction {hi}");
        // median stays in the bulk (2/3 below 50)
        assert!(sorted_median(&v) < 10.0);
    }

    #[test]
    fn mixture2_median_near_boundary() {
        // 50/50 mixture: lower median sits at the top of the N(1,1) bulk
        let mut rng = Rng::seeded(4);
        let v = Distribution::Mixture2.sample_vec(&mut rng, 30_000);
        let m = sorted_median(&v);
        assert!(m > 1.0 && m < 20.0, "median {m}");
    }

    #[test]
    fn mixture3_duplicates_at_ten() {
        let mut rng = Rng::seeded(5);
        let v = Distribution::Mixture3.sample_vec(&mut rng, 10_000);
        let tens = v.iter().filter(|&&x| x == 10.0).count();
        assert!(tens > 800 && tens < 1200, "{tens}");
    }

    #[test]
    fn beta_bounded() {
        let mut rng = Rng::seeded(6);
        let v = Distribution::Beta25.sample_vec(&mut rng, 10_000);
        assert!(v.iter().all(|&x| (0.0..=1.0).contains(&x)));
    }

    #[test]
    fn outlier_injection_replaces_elements() {
        let mut rng = Rng::seeded(7);
        let mut v = vec![0.0; 1000];
        OutlierSpec { magnitude: 1e9, count: 5 }.inject(&mut rng, &mut v);
        let big = v.iter().filter(|&&x| x == 1e9).count();
        assert!((1..=5).contains(&big)); // collisions possible
    }
}
