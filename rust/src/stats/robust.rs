//! Robust scale and location estimators built on the selection machinery.
//!
//! The paper's applications consume these: the MAD (median absolute
//! deviation, the paper's ref [26] Rousseeuw–Croux subject), trimmed means,
//! and the IQR. Each costs O(1) selections — exactly the workload the
//! cutting-plane backend accelerates — and works through any
//! [`MedianSelector`](crate::regression::MedianSelector).

use crate::regression::MedianSelector;
use crate::util::median_rank;
use crate::{invalid_arg, Result};

/// Consistency factor making MAD estimate σ for normal data.
pub const MAD_NORMAL_CONSISTENCY: f64 = 1.4826;

/// Median absolute deviation: `MAD = Med(|x_i − Med(x)|)`.
///
/// Two selections + one elementwise map (on the device backend the map is
/// one fused kernel and the deviations never leave the accelerator).
pub fn mad(x: &[f64], selector: &mut dyn MedianSelector) -> Result<f64> {
    if x.is_empty() {
        return Err(invalid_arg!("empty input"));
    }
    let med = selector.median(x)?;
    let dev: Vec<f64> = x.iter().map(|&v| (v - med).abs()).collect();
    selector.median(&dev)
}

/// Normal-consistent robust σ estimate.
pub fn mad_sigma(x: &[f64], selector: &mut dyn MedianSelector) -> Result<f64> {
    Ok(MAD_NORMAL_CONSISTENCY * mad(x, selector)?)
}

/// Interquartile range via two order statistics.
pub fn iqr(x: &[f64], selector: &mut dyn MedianSelector) -> Result<f64> {
    let n = x.len();
    if n < 4 {
        return Err(invalid_arg!("need n >= 4 for IQR"));
    }
    let k25 = ((0.25 * n as f64).ceil() as usize).clamp(1, n);
    let k75 = ((0.75 * n as f64).ceil() as usize).clamp(1, n);
    Ok(selector.order_statistic(x, k75)? - selector.order_statistic(x, k25)?)
}

/// α-trimmed mean: average of the values between the α- and (1−α)-order
/// statistics, computed with two selections plus one thresholded pass (the
/// same pattern as the paper's LTS ρ-trick).
pub fn trimmed_mean(x: &[f64], alpha: f64, selector: &mut dyn MedianSelector) -> Result<f64> {
    let n = x.len();
    if n == 0 {
        return Err(invalid_arg!("empty input"));
    }
    if !(0.0..0.5).contains(&alpha) {
        return Err(invalid_arg!("alpha {alpha} outside [0, 0.5)"));
    }
    let cut = (alpha * n as f64).floor() as usize;
    if cut == 0 {
        return Ok(x.iter().sum::<f64>() / n as f64);
    }
    let lo = selector.order_statistic(x, cut + 1)?;
    let hi = selector.order_statistic(x, n - cut)?;
    // one pass: sum strictly-interior values and count boundary duplicates
    let (mut sum, mut count) = (0.0, 0usize);
    let (mut n_lo, mut n_hi) = (0usize, 0usize);
    for &v in x {
        if v > lo && v < hi {
            sum += v;
            count += 1;
        } else if v == lo {
            n_lo += 1;
        } else if v == hi {
            n_hi += 1;
        }
    }
    // include the right multiplicity of the boundary values so exactly
    // n − 2·cut values participate
    let below = x.iter().filter(|&&v| v < lo).count();
    let take_lo = (cut + 1).saturating_sub(below).min(n_lo).min(n - 2 * cut);
    let mut remaining = n - 2 * cut - count - take_lo.min(n - 2 * cut);
    let take_hi = remaining.min(n_hi);
    remaining -= take_hi;
    if remaining != 0 {
        // duplicates straddle both cuts; fall back to the exact definition
        let mut v = x.to_vec();
        v.sort_by(crate::util::total_cmp_f64);
        let inner = &v[cut..n - cut];
        return Ok(inner.iter().sum::<f64>() / inner.len() as f64);
    }
    sum += lo * take_lo as f64 + hi * take_hi as f64;
    count += take_lo + take_hi;
    Ok(sum / count as f64)
}

/// Winsorized mean: clamp to the [α, 1−α] order statistics, then average.
pub fn winsorized_mean(x: &[f64], alpha: f64, selector: &mut dyn MedianSelector) -> Result<f64> {
    let n = x.len();
    if n == 0 {
        return Err(invalid_arg!("empty input"));
    }
    if !(0.0..0.5).contains(&alpha) {
        return Err(invalid_arg!("alpha {alpha} outside [0, 0.5)"));
    }
    let cut = (alpha * n as f64).floor() as usize;
    if cut == 0 {
        return Ok(x.iter().sum::<f64>() / n as f64);
    }
    let lo = selector.order_statistic(x, cut + 1)?;
    let hi = selector.order_statistic(x, n - cut)?;
    Ok(x.iter().map(|&v| v.clamp(lo, hi)).sum::<f64>() / n as f64)
}

/// Standardized robust z-scores: `(x − Med) / (1.4826·MAD)`; the classic
/// outlier detector the regression RLS step uses.
pub fn robust_zscores(x: &[f64], selector: &mut dyn MedianSelector) -> Result<Vec<f64>> {
    let med = selector.median(x)?;
    let sigma = mad_sigma(x, selector)?;
    if sigma <= 0.0 {
        return Err(invalid_arg!("MAD is zero — degenerate sample"));
    }
    Ok(x.iter().map(|&v| (v - med) / sigma).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::regression::HostSelector;
    use crate::stats::{sorted_median, Distribution, Rng};
    use crate::util;

    fn sel() -> HostSelector {
        HostSelector::default()
    }

    #[test]
    fn mad_of_normal_estimates_sigma() {
        let mut rng = Rng::seeded(221);
        let x: Vec<f64> = (0..50_000).map(|_| 3.0 * rng.normal() + 10.0).collect();
        let s = mad_sigma(&x, &mut sel()).unwrap();
        assert!((s - 3.0).abs() < 0.05, "sigma estimate {s}");
    }

    #[test]
    fn mad_ignores_30_percent_outliers() {
        let mut rng = Rng::seeded(222);
        let mut x: Vec<f64> = (0..10_000).map(|_| rng.normal()).collect();
        for i in 0..3000 {
            x[i] = 1e6 + rng.normal();
        }
        let s = mad_sigma(&x, &mut sel()).unwrap();
        assert!(s < 10.0, "MAD blown up by outliers: {s}");
    }

    #[test]
    fn mad_matches_direct_definition() {
        let mut rng = Rng::seeded(223);
        let x = Distribution::Mixture1.sample_vec(&mut rng, 1001);
        let got = mad(&x, &mut sel()).unwrap();
        let med = sorted_median(&x);
        let dev: Vec<f64> = x.iter().map(|&v| (v - med).abs()).collect();
        assert_eq!(got, sorted_median(&dev));
    }

    #[test]
    fn iqr_on_uniform() {
        let mut rng = Rng::seeded(224);
        let x = Distribution::Uniform.sample_vec(&mut rng, 40_000);
        let got = iqr(&x, &mut sel()).unwrap();
        assert!((got - 0.5).abs() < 0.01, "IQR {got}");
    }

    #[test]
    fn trimmed_mean_matches_sorted_definition() {
        let mut rng = Rng::seeded(225);
        for trial in 0..40 {
            let n = 8 + rng.below(500);
            let x = Distribution::ALL[trial % 9].sample_vec(&mut rng, n);
            for alpha in [0.05, 0.1, 0.25] {
                let got = trimmed_mean(&x, alpha, &mut sel()).unwrap();
                let mut v = x.clone();
                v.sort_by(crate::util::total_cmp_f64);
                let cut = (alpha * n as f64).floor() as usize;
                let inner = &v[cut..n - cut];
                let want = inner.iter().sum::<f64>() / inner.len() as f64;
                assert!(
                    (got - want).abs() <= 1e-9 * want.abs().max(1.0),
                    "trial {trial} n={n} alpha={alpha}: {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn trimmed_mean_heavy_duplicates() {
        let x = vec![1.0, 2.0, 2.0, 2.0, 2.0, 2.0, 2.0, 9.0];
        let got = trimmed_mean(&x, 0.25, &mut sel()).unwrap();
        // sorted: cut 2 from each side -> [2,2,2,2] -> mean 2
        assert_eq!(got, 2.0);
    }

    #[test]
    fn winsorized_mean_bounds_outliers() {
        let mut rng = Rng::seeded(226);
        let mut x: Vec<f64> = (0..1000).map(|_| rng.normal()).collect();
        x[0] = 1e9;
        let got = winsorized_mean(&x, 0.05, &mut sel()).unwrap();
        assert!(got.abs() < 0.5, "winsorized mean {got}");
    }

    #[test]
    fn zscores_flag_outliers() {
        let mut rng = Rng::seeded(227);
        let mut x: Vec<f64> = (0..2000).map(|_| rng.normal()).collect();
        x[7] = 50.0;
        let z = robust_zscores(&x, &mut sel()).unwrap();
        assert!(z[7] > 10.0);
        let flagged = z.iter().filter(|v| v.abs() > 3.5).count();
        assert!(flagged < 20, "too many false positives: {flagged}");
    }

    #[test]
    fn degenerate_inputs() {
        assert!(mad(&[], &mut sel()).is_err());
        assert!(iqr(&[1.0, 2.0], &mut sel()).is_err());
        assert!(trimmed_mean(&[1.0], 0.6, &mut sel()).is_err());
        assert!(robust_zscores(&[5.0; 10], &mut sel()).is_err()); // MAD = 0
        // alpha = 0 is the plain mean
        let m = trimmed_mean(&[1.0, 2.0, 3.0], 0.0, &mut sel()).unwrap();
        assert!((m - 2.0).abs() < 1e-12);
        let _ = util::median_rank(1);
        let _ = median_rank(2);
    }
}
