//! PCG64 (XSL-RR 128/64) pseudo-random generator.
//!
//! Deterministic, seedable, dependency-free. All experiments in the harness
//! derive their streams from explicit seeds so every table/figure is exactly
//! reproducible run-to-run.

/// PCG XSL-RR 128/64.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u128,
    inc: u128,
}

const MUL: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Rng {
    /// Create from a 64-bit seed (stream constant fixed).
    pub fn seeded(seed: u64) -> Self {
        let mut r = Rng {
            state: 0,
            inc: ((seed as u128) << 1) | 1,
        };
        r.state = r.state.wrapping_mul(MUL).wrapping_add(r.inc);
        r.state = r.state.wrapping_add(0x853c_49e6_748f_ea9b_da3e_39cb_94b9_5bdb ^ (seed as u128));
        r.state = r.state.wrapping_mul(MUL).wrapping_add(r.inc);
        r
    }

    /// Derive an independent child stream (for shards / parallel workers).
    pub fn split(&mut self, tag: u64) -> Rng {
        let s = self.next_u64() ^ tag.rotate_left(17);
        Rng::seeded(s)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(MUL).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free approximation is fine here
        // (bench/test usage, not cryptography).
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; one transcendental pair per draw).
    #[inline]
    pub fn normal(&mut self) -> f64 {
        let u1 = (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        let u1 = u1.max(1e-300); // avoid log(0)
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Gamma(shape a >= 1e-3) via Marsaglia–Tsang; used for Beta sampling.
    pub fn gamma(&mut self, a: f64) -> f64 {
        if a < 1.0 {
            // boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let g = self.gamma(a + 1.0);
            let u = self.f64().max(1e-300);
            return g * u.powf(1.0 / a);
        }
        let d = a - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64().max(1e-300);
            if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
                return d * v;
            }
        }
    }

    /// Beta(a, b).
    pub fn beta(&mut self, a: f64, b: f64) -> f64 {
        let x = self.gamma(a);
        let y = self.gamma(b);
        x / (x + y)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // For small k relative to n use rejection, else shuffle.
        if k * 4 < n {
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let c = self.below(n);
                if !out.contains(&c) {
                    out.push(c);
                }
            }
            out
        } else {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::seeded(7);
        let mut b = Rng::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::seeded(1);
        let mut b = Rng::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_mean_and_bounds() {
        let mut r = Rng::seeded(3);
        let n = 100_000;
        let mut s = 0.0;
        for _ in 0..n {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            s += v;
        }
        let mean = s / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seeded(4);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn beta_2_5_mean() {
        let mut r = Rng::seeded(5);
        let n = 100_000;
        let mut s = 0.0;
        for _ in 0..n {
            let v = r.beta(2.0, 5.0);
            assert!((0.0..=1.0).contains(&v));
            s += v;
        }
        // E[Beta(2,5)] = 2/7
        assert!((s / n as f64 - 2.0 / 7.0).abs() < 0.01);
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::seeded(6);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::seeded(8);
        for (n, k) in [(100, 3), (10, 9), (10, 10), (1000, 100)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let mut u = s.clone();
            u.sort_unstable();
            u.dedup();
            assert_eq!(u.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn split_streams_are_independent() {
        let mut base = Rng::seeded(9);
        let mut a = base.split(1);
        let mut b = base.split(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same <= 1);
    }
}
