//! Minimal TOML-subset parser (sections, scalars, arrays, comments).

use std::collections::BTreeMap;

use crate::{Error, Result};

/// A parsed scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

/// Parsed document: section -> key -> value. Top-level keys live under "".
#[derive(Debug, Default)]
pub struct TomlDoc {
    sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or_else(|| {
                    Error::Parse(format!("line {}: unterminated section header", lineno + 1))
                })?;
                section = name.trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let (key, value) = line.split_once('=').ok_or_else(|| {
                Error::Parse(format!("line {}: expected `key = value`", lineno + 1))
            })?;
            let v = parse_value(value.trim())
                .map_err(|e| Error::Parse(format!("line {}: {e}", lineno + 1)))?;
            doc.sections
                .entry(section.clone())
                .or_default()
                .insert(key.trim().to_string(), v);
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section)?.get(key)
    }

    pub fn get_str(&self, section: &str, key: &str) -> Result<Option<String>> {
        match self.get(section, key) {
            None => Ok(None),
            Some(TomlValue::Str(s)) => Ok(Some(s.clone())),
            Some(v) => Err(Error::Parse(format!("{section}.{key}: expected string, got {v:?}"))),
        }
    }

    pub fn get_int(&self, section: &str, key: &str) -> Result<Option<i64>> {
        match self.get(section, key) {
            None => Ok(None),
            Some(TomlValue::Int(i)) => Ok(Some(*i)),
            Some(v) => Err(Error::Parse(format!("{section}.{key}: expected integer, got {v:?}"))),
        }
    }

    pub fn get_float(&self, section: &str, key: &str) -> Result<Option<f64>> {
        match self.get(section, key) {
            None => Ok(None),
            Some(TomlValue::Float(f)) => Ok(Some(*f)),
            Some(TomlValue::Int(i)) => Ok(Some(*i as f64)),
            Some(v) => Err(Error::Parse(format!("{section}.{key}: expected float, got {v:?}"))),
        }
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Result<Option<bool>> {
        match self.get(section, key) {
            None => Ok(None),
            Some(TomlValue::Bool(b)) => Ok(Some(*b)),
            Some(v) => Err(Error::Parse(format!("{section}.{key}: expected bool, got {v:?}"))),
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // respect # inside quoted strings
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> std::result::Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(body) = s.strip_prefix('"') {
        let body = body.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(TomlValue::Str(body.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body.strip_suffix(']').ok_or("unterminated array")?;
        let mut out = Vec::new();
        let body = body.trim();
        if !body.is_empty() {
            for item in body.split(',') {
                let item = item.trim();
                if item.is_empty() {
                    continue; // trailing comma
                }
                out.push(parse_value(item)?);
            }
        }
        return Ok(TomlValue::Array(out));
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.replace('_', "").parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let d = TomlDoc::parse(
            "top = 1\n[a]\nx = \"hi\" # comment\ny = -3\nz = 2.5\nw = true\n[b]\narr = [1, 2, 3]\n",
        )
        .unwrap();
        assert_eq!(d.get_int("", "top").unwrap(), Some(1));
        assert_eq!(d.get_str("a", "x").unwrap(), Some("hi".into()));
        assert_eq!(d.get_int("a", "y").unwrap(), Some(-3));
        assert_eq!(d.get_float("a", "z").unwrap(), Some(2.5));
        assert_eq!(d.get_bool("a", "w").unwrap(), Some(true));
        assert_eq!(
            d.get("b", "arr"),
            Some(&TomlValue::Array(vec![
                TomlValue::Int(1),
                TomlValue::Int(2),
                TomlValue::Int(3)
            ]))
        );
    }

    #[test]
    fn missing_keys_are_none() {
        let d = TomlDoc::parse("[a]\nx = 1\n").unwrap();
        assert_eq!(d.get_int("a", "missing").unwrap(), None);
        assert_eq!(d.get_int("nope", "x").unwrap(), None);
    }

    #[test]
    fn type_mismatch_is_error() {
        let d = TomlDoc::parse("[a]\nx = 1\n").unwrap();
        assert!(d.get_str("a", "x").is_err());
        assert!(d.get_bool("a", "x").is_err());
        // int coerces to float deliberately
        assert_eq!(d.get_float("a", "x").unwrap(), Some(1.0));
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let d = TomlDoc::parse("[a]\nx = \"with # hash\"\n").unwrap();
        assert_eq!(d.get_str("a", "x").unwrap(), Some("with # hash".into()));
    }

    #[test]
    fn bad_lines_error_with_lineno() {
        let e = TomlDoc::parse("[a]\nnonsense\n").unwrap_err();
        assert!(e.to_string().contains("line 2"), "{e}");
        assert!(TomlDoc::parse("[unterminated\n").is_err());
        assert!(TomlDoc::parse("x = \n").is_err());
        assert!(TomlDoc::parse("x = [1, 2\n").is_err());
    }

    #[test]
    fn underscores_in_numbers() {
        let d = TomlDoc::parse("n = 33_554_432\n").unwrap();
        assert_eq!(d.get_int("", "n").unwrap(), Some(33554432));
    }
}
