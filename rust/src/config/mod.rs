//! Configuration system: a TOML-subset parser and the typed [`Config`].
//!
//! serde/toml are unavailable offline (DESIGN.md §7); the parser supports
//! the subset a deployment config needs: `[sections]`, `key = value` with
//! strings, integers, floats, booleans, and homogeneous scalar arrays,
//! plus `#` comments.

pub mod toml;

use std::path::{Path, PathBuf};
use std::time::Duration;

use crate::coordinator::{AdaptiveWindow, CoordinatorOptions, ShedPolicy, TenantQuota};
use crate::runtime::Flavor;
use crate::select::{DType, Method};
use crate::{Error, Result};
use toml::TomlDoc;

/// Runtime configuration for the coordinator and harness.
#[derive(Debug, Clone)]
pub struct Config {
    /// Where AOT artifacts live.
    pub artifacts_dir: PathBuf,
    /// Hot-kernel flavor: `jnp` (XLA-fused, default) or `pallas`.
    pub kernel_flavor: Flavor,
    /// Default selection method for service requests.
    pub default_method: Method,
    /// Default value dtype.
    pub dtype: DType,
    /// Simulated device shards.
    pub shards: usize,
    /// Service worker threads (each owns one shard's runtime).
    pub workers: usize,
    /// Max queued requests before callers block.
    pub queue_depth: usize,
    /// Fixed coordinator batching window in microseconds — the *manual
    /// override*: writing `[service] batch_window_us` turns the adaptive
    /// controller off and pins this width (0 = drain-only). When the
    /// controller is on (the deployment default) this value is unused.
    /// The *library* default (`CoordinatorOptions::default`) stays 0 so
    /// embedding `SelectionService::start` keeps its drain-only latency
    /// profile.
    pub batch_window_us: u64,
    /// Load-adaptive batching window (`[service] adaptive_window`,
    /// deployment default on): the SLA-bounded controller widens the
    /// window under observed concurrency and shrinks it to zero when
    /// idle, so the latency/coalescing tradeoff leaves operator hands.
    pub adaptive_window: bool,
    /// p99 latency budget for the adaptive controller in microseconds
    /// (`[service] latency_sla_us`, `--latency-sla-us`): batching window +
    /// observed p99 run latency never exceeds it.
    pub latency_sla_us: u64,
    /// Hard cap on requests collected into one planned batch.
    pub batch_cap: usize,
    /// Full-queue behavior for queries (`[service] shed_policy`,
    /// `"block"` or `"shed"`): `shed` fails fast with a typed
    /// `Overloaded` error instead of blocking the caller.
    pub shed_policy: ShedPolicy,
    /// Per-tenant admission quota (`[service] tenant_rate_per_sec` +
    /// optional `tenant_burst`, which defaults to the rate). Unset admits
    /// everything.
    pub tenant_quota: Option<TenantQuota>,
    /// Per-worker residency cap (`[service] max_resident_datasets`):
    /// `Some` wraps the backend in LRU eviction; evicted datasets answer
    /// with a "re-upload" error. Zero is rejected at parse time.
    pub max_resident_datasets: Option<usize>,
    /// Cost-model sidecar path (`[service] cost_model_sidecar`): when set,
    /// the service loads pooled pass-cost statistics from here at start
    /// and persists them on shutdown (conventionally
    /// `BENCH_select.cost_model.json` next to the committed
    /// `BENCH_select.json`). Unset = in-memory pool only.
    pub cost_model_sidecar: Option<PathBuf>,
    /// Hybrid CP iterations before compaction (paper: 7).
    pub hybrid_cp_iters: usize,
    /// Apply the log-transform guard automatically for extreme ranges.
    pub guard_extremes: bool,
    /// Benchmark repetitions per measurement.
    pub bench_reps: usize,
    /// Benchmark instances per distribution (paper: 10 × 10).
    pub bench_instances: usize,
    /// Largest log2(n) the benches sweep.
    pub bench_max_log2n: u32,
    /// Measured repetitions per `bench-wall` row (`[bench] wall_reps`; one
    /// extra warmup run is always discarded). Higher than `bench_reps`
    /// because wall medians/p99s are what gets committed to the
    /// trajectory, and a committed number deserves more samples than a
    /// CI count check.
    pub bench_wall_reps: usize,
    /// Cluster-mode settings (`[cluster]`), shared by the coordinator and
    /// worker subcommands so one config file describes a deployment.
    pub cluster: ClusterConfig,
}

/// `[cluster]` section: where the coordinator listens and how the
/// processes pace their wire I/O.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    /// Coordinator listen / worker dial address (`listen`).
    pub listen: String,
    /// Remote worker count (`workers`); the coordinator runs one service
    /// worker thread per remote worker (1:1 pinning).
    pub workers: u32,
    /// TCP connect deadline in ms (`connect_timeout_ms`).
    pub connect_timeout_ms: u64,
    /// Coordinator→worker per-op read/write deadline in ms
    /// (`io_timeout_ms`): a hung worker fails its batch, not the process.
    pub io_timeout_ms: u64,
    /// Worker heartbeat cadence in ms (`heartbeat_ms`, 0 disables).
    pub heartbeat_ms: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            listen: "127.0.0.1:7171".to_string(),
            workers: 2,
            connect_timeout_ms: 5_000,
            io_timeout_ms: 30_000,
            heartbeat_ms: 2_000,
        }
    }
}

impl Default for Config {
    fn default() -> Self {
        Config {
            artifacts_dir: PathBuf::from("artifacts"),
            kernel_flavor: Flavor::Jnp,
            default_method: Method::Hybrid,
            dtype: DType::F64,
            shards: 1,
            workers: 1,
            queue_depth: 1024,
            batch_window_us: 200,
            adaptive_window: true,
            latency_sla_us: 5_000,
            batch_cap: 64,
            shed_policy: ShedPolicy::Block,
            tenant_quota: None,
            max_resident_datasets: None,
            cost_model_sidecar: None,
            hybrid_cp_iters: 7,
            guard_extremes: true,
            bench_reps: 3,
            bench_instances: 3,
            bench_max_log2n: 22,
            bench_wall_reps: 7,
            cluster: ClusterConfig::default(),
        }
    }
}

impl Config {
    /// Load from a TOML file; missing keys fall back to defaults.
    pub fn load(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::io(path.display().to_string(), e))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Config> {
        let doc = TomlDoc::parse(text)?;
        let mut c = Config::default();
        if let Some(v) = doc.get_str("runtime", "artifacts_dir")? {
            c.artifacts_dir = PathBuf::from(v);
        }
        if let Some(v) = doc.get_str("runtime", "kernel_flavor")? {
            c.kernel_flavor = Flavor::from_name(&v)
                .ok_or_else(|| Error::Parse(format!("unknown kernel_flavor {v:?}")))?;
        }
        if let Some(v) = doc.get_str("select", "method")? {
            c.default_method = Method::from_name(&v)
                .ok_or_else(|| Error::Parse(format!("unknown method {v:?}")))?;
        }
        if let Some(v) = doc.get_str("select", "dtype")? {
            c.dtype = DType::from_name(&v)
                .ok_or_else(|| Error::Parse(format!("unknown dtype {v:?}")))?;
        }
        if let Some(v) = doc.get_int("select", "hybrid_cp_iters")? {
            c.hybrid_cp_iters = v as usize;
        }
        if let Some(v) = doc.get_bool("select", "guard_extremes")? {
            c.guard_extremes = v;
        }
        if let Some(v) = doc.get_int("service", "shards")? {
            c.shards = (v as usize).max(1);
        }
        if let Some(v) = doc.get_int("service", "workers")? {
            c.workers = (v as usize).max(1);
        }
        if let Some(v) = doc.get_int("service", "queue_depth")? {
            c.queue_depth = (v as usize).max(1);
        }
        if let Some(v) = doc.get_int("service", "batch_window_us")? {
            c.batch_window_us = v.max(0) as u64;
            // an explicitly pinned window is a manual override of the
            // adaptive controller (re-enable with adaptive_window = true)
            c.adaptive_window = false;
        }
        if let Some(v) = doc.get_bool("service", "adaptive_window")? {
            c.adaptive_window = v;
        }
        if let Some(v) = doc.get_int("service", "latency_sla_us")? {
            c.latency_sla_us = v.max(0) as u64;
        }
        if let Some(v) = doc.get_str("service", "cost_model_sidecar")? {
            c.cost_model_sidecar = Some(PathBuf::from(v));
        }
        if let Some(v) = doc.get_int("service", "batch_cap")? {
            c.batch_cap = (v as usize).max(1);
        }
        if let Some(v) = doc.get_str("service", "shed_policy")? {
            c.shed_policy = ShedPolicy::parse(&v)?;
        }
        let rate = doc.get_float("service", "tenant_rate_per_sec")?;
        let burst = doc.get_float("service", "tenant_burst")?;
        match (rate, burst) {
            (Some(rate), burst) => {
                let rate_ok = rate.is_finite() && rate > 0.0;
                if !rate_ok {
                    return Err(Error::Parse(format!(
                        "tenant_rate_per_sec must be finite and > 0, got {rate}"
                    )));
                }
                let burst = burst.unwrap_or(rate);
                let burst_ok = burst.is_finite() && burst >= 1.0;
                if !burst_ok {
                    return Err(Error::Parse(format!(
                        "tenant_burst must be finite and >= 1, got {burst}"
                    )));
                }
                c.tenant_quota = Some(TenantQuota { rate_per_sec: rate, burst });
            }
            (None, Some(_)) => {
                return Err(Error::Parse(
                    "tenant_burst requires tenant_rate_per_sec".to_string(),
                ));
            }
            (None, None) => {}
        }
        if let Some(v) = doc.get_int("service", "max_resident_datasets")? {
            if v < 1 {
                return Err(Error::Parse(format!(
                    "max_resident_datasets must be at least 1, got {v}"
                )));
            }
            c.max_resident_datasets = Some(v as usize);
        }
        if let Some(v) = doc.get_int("bench", "reps")? {
            c.bench_reps = (v as usize).max(1);
        }
        if let Some(v) = doc.get_int("bench", "instances")? {
            c.bench_instances = (v as usize).max(1);
        }
        if let Some(v) = doc.get_int("bench", "max_log2n")? {
            c.bench_max_log2n = v as u32;
        }
        if let Some(v) = doc.get_int("bench", "wall_reps")? {
            c.bench_wall_reps = (v as usize).max(1);
        }
        if let Some(v) = doc.get_str("cluster", "listen")? {
            if !v.contains(':') {
                return Err(Error::Parse(format!(
                    "cluster listen must be host:port, got {v:?}"
                )));
            }
            c.cluster.listen = v;
        }
        if let Some(v) = doc.get_int("cluster", "workers")? {
            if v < 1 {
                return Err(Error::Parse(format!(
                    "cluster workers must be at least 1, got {v}"
                )));
            }
            c.cluster.workers = v as u32;
        }
        if let Some(v) = doc.get_int("cluster", "connect_timeout_ms")? {
            c.cluster.connect_timeout_ms = v.max(0) as u64;
        }
        if let Some(v) = doc.get_int("cluster", "io_timeout_ms")? {
            c.cluster.io_timeout_ms = v.max(0) as u64;
        }
        if let Some(v) = doc.get_int("cluster", "heartbeat_ms")? {
            c.cluster.heartbeat_ms = v.max(0) as u64;
        }
        Ok(c)
    }

    /// The coordinator ingest options this config describes: the adaptive
    /// controller bounded by `latency_sla_us` when `adaptive_window` is on,
    /// the fixed `batch_window_us` otherwise.
    pub fn coordinator_options(&self) -> CoordinatorOptions {
        CoordinatorOptions {
            batch_window: Duration::from_micros(self.batch_window_us),
            batch_cap: self.batch_cap,
            adaptive: self.adaptive_window.then(|| AdaptiveWindow {
                latency_sla: Duration::from_micros(self.latency_sla_us),
                ..AdaptiveWindow::default()
            }),
            shed_policy: self.shed_policy,
            tenant_quota: self.tenant_quota,
            queue_cap: Some(self.queue_depth),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = Config::default();
        assert_eq!(c.default_method, Method::Hybrid);
        assert_eq!(c.bench_wall_reps, 7);
        assert_eq!(c.hybrid_cp_iters, 7);
        assert_eq!(c.kernel_flavor, Flavor::Jnp);
        assert_eq!(c.batch_window_us, 200);
        assert_eq!(c.batch_cap, 64);
        assert!(c.adaptive_window, "deployment default is the adaptive controller");
        assert_eq!(c.latency_sla_us, 5_000);
        assert!(c.cost_model_sidecar.is_none());
        let o = c.coordinator_options();
        let a = o.adaptive.expect("adaptive on by default");
        assert_eq!(a.latency_sla, std::time::Duration::from_micros(5_000));
    }

    #[test]
    fn parses_full_document() {
        let c = Config::parse(
            r#"
            # cp-select deployment config
            [runtime]
            artifacts_dir = "/data/artifacts"
            kernel_flavor = "pallas"

            [select]
            method = "cutting-plane"
            dtype = "f32"
            hybrid_cp_iters = 9
            guard_extremes = false

            [service]
            shards = 4
            workers = 2
            queue_depth = 64
            batch_window_us = 750
            batch_cap = 32

            [bench]
            reps = 5
            instances = 10
            max_log2n = 25
            wall_reps = 11
            "#,
        )
        .unwrap();
        assert_eq!(c.artifacts_dir, PathBuf::from("/data/artifacts"));
        assert_eq!(c.kernel_flavor, Flavor::Pallas);
        assert_eq!(c.default_method, Method::CuttingPlane);
        assert_eq!(c.dtype, DType::F32);
        assert_eq!(c.hybrid_cp_iters, 9);
        assert!(!c.guard_extremes);
        assert_eq!(c.shards, 4);
        assert_eq!(c.workers, 2);
        assert_eq!(c.queue_depth, 64);
        assert_eq!(c.batch_window_us, 750);
        assert!(!c.adaptive_window, "a pinned batch_window_us is a manual override");
        assert!(c.coordinator_options().adaptive.is_none());
        assert_eq!(c.batch_cap, 32);
        assert_eq!(c.bench_reps, 5);
        assert_eq!(c.bench_instances, 10);
        assert_eq!(c.bench_max_log2n, 25);
        assert_eq!(c.bench_wall_reps, 11);
    }

    #[test]
    fn partial_document_keeps_defaults() {
        let c = Config::parse("[service]\nshards = 2\n").unwrap();
        assert_eq!(c.shards, 2);
        assert_eq!(c.default_method, Method::Hybrid);
        assert!(c.adaptive_window);
    }

    #[test]
    fn adaptive_window_config_roundtrip() {
        // SLA + sidecar configured; no pinned window, so adaptive stays on
        let c = Config::parse(
            "[service]\nlatency_sla_us = 900\ncost_model_sidecar = \"results/cm.json\"\n",
        )
        .unwrap();
        assert!(c.adaptive_window);
        assert_eq!(c.latency_sla_us, 900);
        assert_eq!(c.cost_model_sidecar, Some(PathBuf::from("results/cm.json")));
        let a = c.coordinator_options().adaptive.unwrap();
        assert_eq!(a.latency_sla, std::time::Duration::from_micros(900));

        // explicit adaptive_window = true wins over a pinned window
        let c = Config::parse("[service]\nbatch_window_us = 10\nadaptive_window = true\n").unwrap();
        assert!(c.adaptive_window);
        assert_eq!(c.batch_window_us, 10);

        // and adaptive_window = false alone keeps the default fixed window
        let c = Config::parse("[service]\nadaptive_window = false\n").unwrap();
        assert!(c.coordinator_options().adaptive.is_none());
        let window = c.coordinator_options().batch_window;
        assert_eq!(window, std::time::Duration::from_micros(200));
    }

    #[test]
    fn cluster_section_parses_and_defaults() {
        let c = Config::default();
        assert_eq!(c.cluster.listen, "127.0.0.1:7171");
        assert_eq!(c.cluster.workers, 2);
        assert_eq!(c.cluster.io_timeout_ms, 30_000);
        assert_eq!(c.cluster.heartbeat_ms, 2_000);

        let c = Config::parse(
            "[cluster]\nlisten = \"0.0.0.0:9001\"\nworkers = 4\n\
             connect_timeout_ms = 250\nio_timeout_ms = 1500\nheartbeat_ms = 0\n",
        )
        .unwrap();
        assert_eq!(c.cluster.listen, "0.0.0.0:9001");
        assert_eq!(c.cluster.workers, 4);
        assert_eq!(c.cluster.connect_timeout_ms, 250);
        assert_eq!(c.cluster.io_timeout_ms, 1500);
        assert_eq!(c.cluster.heartbeat_ms, 0, "zero disables the heartbeat");
    }

    #[test]
    fn rejects_bad_cluster_values() {
        assert!(Config::parse("[cluster]\nlisten = \"no-port\"\n").is_err());
        assert!(Config::parse("[cluster]\nworkers = 0\n").is_err());
    }

    #[test]
    fn rejects_bad_values() {
        assert!(Config::parse("[select]\nmethod = \"warp-speed\"\n").is_err());
        assert!(Config::parse("[select]\ndtype = \"f16\"\n").is_err());
        assert!(Config::parse("[runtime]\nkernel_flavor = \"cuda\"\n").is_err());
    }

    #[test]
    fn overload_keys_parse_and_reach_coordinator_options() {
        let c = Config::parse(
            "[service]\nshed_policy = \"shed\"\ntenant_rate_per_sec = 50.0\n\
             tenant_burst = 10.0\nmax_resident_datasets = 8\nqueue_depth = 32\n",
        )
        .unwrap();
        assert_eq!(c.shed_policy, ShedPolicy::Shed);
        let q = c.tenant_quota.expect("quota set");
        assert_eq!(q.rate_per_sec, 50.0);
        assert_eq!(q.burst, 10.0);
        assert_eq!(c.max_resident_datasets, Some(8));
        let o = c.coordinator_options();
        assert_eq!(o.shed_policy, ShedPolicy::Shed);
        assert!(o.tenant_quota.is_some());
        assert_eq!(o.queue_cap, Some(32), "config queue depth rides the options struct");
    }

    #[test]
    fn tenant_burst_defaults_to_the_rate() {
        let c = Config::parse("[service]\ntenant_rate_per_sec = 4.0\n").unwrap();
        let q = c.tenant_quota.unwrap();
        assert_eq!(q.rate_per_sec, 4.0);
        assert_eq!(q.burst, 4.0);
    }

    #[test]
    fn rejects_bad_overload_values() {
        assert!(Config::parse("[service]\nshed_policy = \"drop\"\n").is_err());
        assert!(Config::parse("[service]\ntenant_rate_per_sec = 0.0\n").is_err());
        assert!(Config::parse("[service]\ntenant_rate_per_sec = -1.0\n").is_err());
        assert!(Config::parse("[service]\ntenant_burst = 3.0\n").is_err(), "burst without rate");
        assert!(Config::parse("[service]\ntenant_rate_per_sec = 2.0\ntenant_burst = 0.5\n")
            .is_err());
        assert!(Config::parse("[service]\nmax_resident_datasets = 0\n").is_err());
    }
}
