//! Wall-clock measurement: the `bench-wall` half of the perf trajectory.
//!
//! Everything here produces the numbers `BENCH_select.json` commits under
//! a [`HostFingerprint`]: repetition summaries (median + p99, computed
//! with the repo's *own* order-statistics code — the bench eats its own
//! dogfood), bin-sweep throughput in GB/s for the vectorized and scalar
//! ladder kernels, and a two-width fit of the pass-cost coefficients that
//! seeds [`crate::select::PassCostModel`] with measured numbers
//! ([`crate::select::PassCostModel::seeded_from_measured`]).
//!
//! Wall times are only comparable on the machine that produced them, so
//! every consumer (the `select_json` gate, the CI perf-smoke leg) first
//! checks [`HostFingerprint::matches`] and degrades to count-only
//! comparison across differing hosts — counts are the hard gate, wall
//! time is the trajectory.

use std::time::Instant;

use crate::select::{fixed_pivot::fixed_pivot_select, ladder_sweep, ladder_sweep_scalar};
use crate::stats::Rng;
use crate::{Error, Result};

/// Identity of the machine a wall-time row was measured on. Two rows are
/// comparable iff their fingerprints are equal ([`HostFingerprint::matches`]);
/// the committed trajectory's fingerprint additionally tells a reader
/// exactly which hardware the numbers describe.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HostFingerprint {
    /// CPU model string (`/proc/cpuinfo` "model name"; "unknown" when the
    /// platform does not expose it).
    pub cpu: String,
    /// Logical core count (`std::thread::available_parallelism`).
    pub logical_cores: usize,
    /// Compiler that built the binary (`rustc --version`, captured at
    /// build time by `build.rs` into `CP_RUSTC_VERSION`).
    pub rustc: String,
}

impl HostFingerprint {
    /// Fingerprint of the machine running this process.
    pub fn detect() -> HostFingerprint {
        let cpu = std::fs::read_to_string("/proc/cpuinfo")
            .ok()
            .and_then(|text| {
                text.lines()
                    .find(|l| l.starts_with("model name"))
                    .and_then(|l| l.split(':').nth(1))
                    .map(|v| v.trim().to_string())
            })
            .filter(|s| !s.is_empty())
            .unwrap_or_else(|| "unknown".to_string());
        let logical_cores = std::thread::available_parallelism().map_or(1, |c| c.get());
        HostFingerprint {
            cpu,
            logical_cores,
            rustc: env!("CP_RUSTC_VERSION").to_string(),
        }
    }

    /// Whether wall times measured under `other` are comparable to ours.
    pub fn matches(&self, other: &HostFingerprint) -> bool {
        self == other
    }
}

/// Summarize repetition samples (milliseconds) as `(median, p99)` — with
/// the repo's own selection code ([`fixed_pivot_select`]), not a sort.
/// The p99 is the `ceil(0.99·n)`-th order statistic, which for the usual
/// handful of reps is the max; both use the paper's `x_([(n+1)/2])` rank
/// convention via [`crate::util::median_rank`].
pub fn summarize_ms(samples: &[f64]) -> (f64, f64) {
    assert!(!samples.is_empty(), "summarize_ms needs at least one sample");
    let n = samples.len();
    let mut scratch = samples.to_vec();
    let median = fixed_pivot_select(&mut scratch, crate::util::median_rank(n));
    let p99_rank = ((0.99 * n as f64).ceil() as usize).clamp(1, n);
    let mut scratch = samples.to_vec();
    let p99 = fixed_pivot_select(&mut scratch, p99_rank);
    (median, p99)
}

/// The bin-sweep throughput race: the vectorized lane-split kernel
/// ([`ladder_sweep`]) vs the scalar oracle ([`ladder_sweep_scalar`]) over
/// the same data and ladder. `speedup` is what the CI perf-smoke leg
/// gates (≥ 1.5× at n = 2²²).
#[derive(Debug, Clone)]
pub struct BinSweepBench {
    pub n: usize,
    /// Ladder width swept (the committed trajectory's planning width, 15).
    pub width: usize,
    /// Measured repetitions per kernel (after one warmup each).
    pub reps: usize,
    pub vector_ms: f64,
    pub scalar_ms: f64,
    /// Median data throughput, GB/s of f64 payload (`8·n / median_s / 1e9`).
    pub vector_gbps: f64,
    pub scalar_gbps: f64,
    /// `vector_gbps / scalar_gbps`.
    pub speedup: f64,
}

fn rungs_for(width: usize) -> Vec<f64> {
    (1..=width).map(|i| i as f64 / (width + 1) as f64).collect()
}

/// Median milliseconds of `reps` timed calls of `f` (one untimed warmup).
fn time_reps_ms<R>(reps: usize, mut f: impl FnMut() -> R) -> f64 {
    std::hint::black_box(f());
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    summarize_ms(&samples).0
}

/// Race the two sweep kernels over `n` uniform elements against a
/// `width`-rung ladder. Before timing, the two partials are checked for
/// exact `cnt`/`eq` agreement — a throughput number from a kernel that
/// miscounts would poison the trajectory, so disagreement is an error,
/// not a row.
pub fn bench_bin_sweep(n: usize, width: usize, reps: usize, seed: u64) -> Result<BinSweepBench> {
    let mut rng = Rng::seeded(seed);
    let data: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
    let ys = rungs_for(width);
    let vec_part = ladder_sweep(&data, &ys);
    let sca_part = ladder_sweep_scalar(&data, &ys);
    if vec_part.cnt != sca_part.cnt || vec_part.eq != sca_part.eq {
        return Err(Error::Service(
            "bin-sweep bench: vectorized kernel disagrees with the scalar oracle".into(),
        ));
    }
    let reps = reps.max(1);
    let vector_ms = time_reps_ms(reps, || ladder_sweep(&data, &ys));
    let scalar_ms = time_reps_ms(reps, || ladder_sweep_scalar(&data, &ys));
    let gbps = |ms: f64| (n as f64 * 8.0) / (ms.max(1e-9) * 1e-3) / 1e9;
    let (vector_gbps, scalar_gbps) = (gbps(vector_ms), gbps(scalar_ms));
    Ok(BinSweepBench {
        n,
        width,
        reps,
        vector_ms,
        scalar_ms,
        vector_gbps,
        scalar_gbps,
        speedup: vector_gbps / scalar_gbps.max(1e-12),
    })
}

/// Measured pass-cost coefficients: one `p`-rung fused pass over `n`
/// elements costs `(sweep + per_probe·p)·n` seconds (the
/// [`crate::select::PassCostModel`] shape). Fitted from a two-width
/// kernel sweep; feed into
/// [`crate::select::PassCostModel::seeded_from_measured`].
#[derive(Debug, Clone, Copy)]
pub struct PassCostFit {
    /// Fixed per-element sweep cost, seconds.
    pub sweep: f64,
    /// Incremental per-element per-rung compare cost, seconds.
    pub per_probe: f64,
}

/// Fit `(sweep, per_probe)` from the vectorized kernel at widths 1 and
/// 15 (the committed trajectory's planning width): two points determine
/// the linear model exactly, and the width-15 point anchors the fit
/// where the planner actually operates. A quick noisy run can produce a
/// non-physical pair (e.g. width-15 faster than width-1);
/// `seeded_from_measured` guards against that downstream, so the raw fit
/// is reported as measured.
pub fn measure_pass_cost(n: usize, reps: usize, seed: u64) -> PassCostFit {
    const WIDE: usize = 15;
    let mut rng = Rng::seeded(seed);
    let data: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
    let narrow = rungs_for(1);
    let wide = rungs_for(WIDE);
    let t1 = time_reps_ms(reps, || ladder_sweep(&data, &narrow)) * 1e-3;
    let tw = time_reps_ms(reps, || ladder_sweep(&data, &wide)) * 1e-3;
    let per_probe = (tw - t1) / ((WIDE - 1) as f64 * n as f64);
    let sweep = t1 / n as f64 - per_probe;
    PassCostFit { sweep, per_probe }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summaries_use_the_paper_rank_convention() {
        // odd count: median is the exact middle, p99 rank ceil(.99·5)=5
        assert_eq!(summarize_ms(&[5.0, 1.0, 4.0, 2.0, 3.0]), (3.0, 5.0));
        // even count: x_([(n+1)/2]) is the lower middle
        assert_eq!(summarize_ms(&[4.0, 1.0, 3.0, 2.0]), (2.0, 4.0));
        assert_eq!(summarize_ms(&[7.5]), (7.5, 7.5));
        // 100+ samples: p99 stops being the max
        let v: Vec<f64> = (1..=200).map(|i| i as f64).collect();
        assert_eq!(summarize_ms(&v), (100.0, 198.0));
    }

    #[test]
    fn fingerprint_detects_and_compares() {
        let f = HostFingerprint::detect();
        assert!(f.logical_cores >= 1);
        assert!(!f.cpu.is_empty());
        assert!(!f.rustc.is_empty());
        assert!(f.matches(&f.clone()));
        let other = HostFingerprint { cpu: "different".into(), ..f.clone() };
        assert!(!f.matches(&other));
    }

    #[test]
    fn bin_sweep_bench_produces_consistent_rows() {
        // small n: this is a schema/consistency test, not a perf assertion
        // (the 1.5× gate lives in the CI perf-smoke leg at n = 2²²)
        let b = bench_bin_sweep(1 << 14, 15, 3, 9).unwrap();
        assert_eq!(b.n, 1 << 14);
        assert_eq!(b.width, 15);
        assert!(b.vector_ms > 0.0 && b.scalar_ms > 0.0);
        assert!(b.vector_gbps > 0.0 && b.scalar_gbps > 0.0);
        assert!(b.speedup > 0.0);
    }

    #[test]
    fn pass_cost_fit_is_finite() {
        let fit = measure_pass_cost(1 << 14, 3, 11);
        assert!(fit.sweep.is_finite());
        assert!(fit.per_probe.is_finite());
    }
}
