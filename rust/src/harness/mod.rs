//! Benchmark harness: regenerates every table and figure of the paper
//! (experiment index in DESIGN.md §4).
//!
//! - [`run_table`] — Tables I/II (+ the breakdown sub-rows and Figs 2/3
//!   series, which are the same data on a log-log scale);
//! - [`trace_fig4`] — the cutting-plane iteration trace of Fig. 4;
//! - [`outlier_sweep_fig5`] — the outlier-sensitivity experiment of Fig. 5;
//! - ablation drivers for the hybrid iteration budget (§IV), the
//!   log-transform guard (§V.D), shard scaling (§V.D) and primitive costs
//!   (§V.B).
//!
//! Times are wall-clock on this substrate; the *shape* (who wins, where
//! crossovers fall) is the reproduction target — see EXPERIMENTS.md.

pub mod report;
pub mod wall;

use std::rc::Rc;
use std::time::Instant;

use crate::runtime::{Flavor, Runtime};
use crate::select::{
    self, cutting_plane::CpOptions, gpu_model::GpuQuickselectModel, hybrid::HybridOptions,
    DType, Evaluator, HostEvaluator, Method,
};
use crate::stats::{Distribution, Rng};
use crate::util::PhaseTimer;
use crate::Result;

/// Where probe reductions execute.
#[derive(Debug, Clone)]
pub enum Backend {
    /// Host oracle (pure rust loops).
    Host,
    /// PJRT device runtime over AOT artifacts.
    Device { artifacts_dir: std::path::PathBuf, flavor: Flavor },
}

/// Evaluator factory with a persistent runtime (compile cache reuse).
pub struct Runner {
    backend: Backend,
    rt: Option<Rc<Runtime>>,
}

impl Runner {
    pub fn new(backend: Backend) -> Result<Runner> {
        let rt = match &backend {
            Backend::Host => None,
            Backend::Device { artifacts_dir, flavor } => {
                Some(Runtime::with_flavor(artifacts_dir, *flavor)?)
            }
        };
        Ok(Runner { backend, rt })
    }

    pub fn is_device(&self) -> bool {
        matches!(self.backend, Backend::Device { .. })
    }

    pub fn evaluator(&mut self, data: &[f64], dtype: DType) -> Result<Box<dyn Evaluator>> {
        match &self.backend {
            Backend::Host => Ok(match dtype {
                DType::F64 => Box::new(HostEvaluator::new(data)),
                DType::F32 => Box::new(HostEvaluator::new_f32(data)),
            }),
            Backend::Device { .. } => {
                let rt = self.rt.as_ref().expect("device runner has runtime");
                Ok(Box::new(crate::runtime::DeviceEvaluator::upload(rt, data, dtype)?))
            }
        }
    }
}

/// Table configuration (defaults reproduce the paper's protocol scaled to
/// this substrate).
#[derive(Debug, Clone)]
pub struct TableConfig {
    pub dtype: DType,
    /// log2 sizes to sweep (paper: 13, 15, 17, 19, 21, 23, 25, 27).
    pub log2_sizes: Vec<u32>,
    /// Data instances averaged per size (paper: 10 per distribution).
    pub instances: usize,
    /// Repetitions per instance (paper: 10).
    pub reps: usize,
    /// Distributions included (paper: all nine, reported as the average).
    pub distributions: Vec<Distribution>,
    pub seed: u64,
    /// Skip quadratic-ish methods above this size (paper also truncates
    /// the slowest columns).
    pub slow_method_cap_log2n: u32,
}

impl Default for TableConfig {
    fn default() -> Self {
        TableConfig {
            dtype: DType::F64,
            log2_sizes: vec![13, 15, 17, 19, 21],
            instances: 2,
            reps: 3,
            distributions: Distribution::ALL.to_vec(),
            seed: 0xD15EA5E,
            slow_method_cap_log2n: 24,
        }
    }
}

/// One method's measured row (means in ms per size; None = skipped).
#[derive(Debug, Clone)]
pub struct MethodRow {
    pub label: String,
    pub ms: Vec<Option<f64>>,
    /// Phase breakdown sub-rows (label, per-size ms).
    pub phases: Vec<(String, Vec<Option<f64>>)>,
}

/// A regenerated paper table.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub sizes: Vec<usize>,
    pub rows: Vec<MethodRow>,
}

/// The method set of Tables I–II, in the paper's row order.
pub fn paper_methods() -> Vec<Method> {
    vec![
        Method::SortRadix,
        Method::Quickselect,
        Method::Hybrid,
        Method::Bisection,
        Method::BrentMinimize,
        Method::BrentRoot,
    ]
}

/// Run the Table I/II protocol.
pub fn run_table(runner: &mut Runner, cfg: &TableConfig) -> Result<Table> {
    let sizes: Vec<usize> = cfg.log2_sizes.iter().map(|&b| 1usize << b).collect();
    let methods = paper_methods();
    let mut rows: Vec<MethodRow> = methods
        .iter()
        .map(|m| MethodRow {
            label: paper_label(*m).to_string(),
            ms: vec![None; sizes.len()],
            phases: Vec::new(),
        })
        .collect();
    // modeled single-thread GPU quickselect row
    let mut gpu_row = MethodRow {
        label: "Quickselect (1-thread GPU, modeled)".to_string(),
        ms: vec![None; sizes.len()],
        phases: Vec::new(),
    };

    let mut rng = Rng::seeded(cfg.seed);
    for (si, (&n, &log2n)) in sizes.iter().zip(&cfg.log2_sizes).enumerate() {
        // Warm the executable cache at this bucket so XLA compile time
        // doesn't pollute the first measured method.
        {
            let data = Distribution::Uniform.sample_vec(&mut rng, n);
            let mut ev = runner.evaluator(&data, cfg.dtype)?;
            let _ = ev.init_stats();
            let _ = ev.probe(0.5);
            let _ = ev.neighbors(0.5);
            let _ = ev.interval(0.2, 0.8);
        }
        let mut sums = vec![0.0f64; methods.len()];
        let mut counts = vec![0usize; methods.len()];
        let mut phase_sums: Vec<PhaseTimer> = methods.iter().map(|_| PhaseTimer::new()).collect();
        let mut gpu_sum = 0.0;
        let mut gpu_count = 0usize;

        for inst in 0..cfg.instances {
            let dist = cfg.distributions[inst % cfg.distributions.len()];
            let data = dist.sample_vec(&mut rng, n);
            let k = crate::util::median_rank(n);

            for (mi, &m) in methods.iter().enumerate() {
                if slow_method(m) && log2n > cfg.slow_method_cap_log2n {
                    continue;
                }
                for _ in 0..cfg.reps {
                    let mut ev = runner.evaluator(&data, cfg.dtype)?;
                    let t0 = Instant::now();
                    let r = select::order_statistic(ev.as_mut(), k, m)?;
                    sums[mi] += t0.elapsed().as_secs_f64() * 1e3;
                    counts[mi] += 1;
                    phase_sums[mi].merge(&r.phases);
                }
            }
            // modeled GPU-1-thread quickselect (value exact, time scaled)
            if log2n <= cfg.slow_method_cap_log2n {
                for _ in 0..cfg.reps {
                    let run = GpuQuickselectModel::default().run(&data, k);
                    gpu_sum += run.modeled.as_secs_f64() * 1e3;
                    gpu_count += 1;
                }
            }
        }

        for (mi, row) in rows.iter_mut().enumerate() {
            if counts[mi] > 0 {
                row.ms[si] = Some(sums[mi] / counts[mi] as f64);
            }
        }
        if gpu_count > 0 {
            gpu_row.ms[si] = Some(gpu_sum / gpu_count as f64);
        }

        // phase breakdown sub-rows (normalized per run)
        for (mi, pt) in phase_sums.iter().enumerate() {
            if counts[mi] == 0 {
                continue;
            }
            for (phase, total_ms) in pt.phases() {
                let mean = total_ms / counts[mi] as f64;
                let row = &mut rows[mi];
                match row.phases.iter_mut().find(|(l, _)| l == phase) {
                    Some((_, v)) => v[si] = Some(mean),
                    None => {
                        let mut v = vec![None; sizes.len()];
                        v[si] = Some(mean);
                        row.phases.push((phase.to_string(), v));
                    }
                }
            }
        }
    }

    rows.insert(2, gpu_row); // after Quickselect, as in the paper
    Ok(Table {
        title: format!(
            "Mean time (ms) to compute the median, dtype {}, backend {}",
            cfg.dtype.name(),
            if runner.is_device() { "pjrt-device" } else { "host" }
        ),
        sizes,
        rows,
    })
}

fn slow_method(m: Method) -> bool {
    // quadratic-free but slow-at-scale methods we cap, like the paper
    // truncating its slowest columns at 2^25.
    matches!(m, Method::Bisection)
}

fn paper_label(m: Method) -> &'static str {
    match m {
        Method::SortRadix => "Radix Sort (baseline)",
        Method::Quickselect => "Quickselect (on CPU)",
        Method::Hybrid => "Cutting Plane (total, hybrid)",
        Method::Bisection => "Bisection",
        Method::BrentMinimize => "Brent's minimization",
        Method::BrentRoot => "Brent's nonlinear eqn",
        Method::CuttingPlane => "Cutting Plane (pure)",
        Method::GoldenSection => "Golden section",
        Method::Bfprt => "BFPRT",
        Method::Multisection => "p-section (batched bisection)",
        Method::FixedPivot => "Fixed-pivot (Azzini-Perrotta)",
    }
}

/// Fig. 4: the per-iteration cutting-plane trace on a small instance.
pub fn trace_fig4(n: usize, seed: u64) -> Result<Vec<select::TracePoint>> {
    let mut rng = Rng::seeded(seed);
    let data = Distribution::Normal.sample_vec(&mut rng, n);
    let mut ev = HostEvaluator::new(&data);
    let out = select::cutting_plane::cutting_plane(
        &mut ev,
        crate::util::median_rank(n),
        &CpOptions { trace: true, ..CpOptions::default() },
    )?;
    Ok(out.trace)
}

/// One row of the Fig. 5 sweep.
#[derive(Debug, Clone)]
pub struct OutlierPoint {
    pub magnitude: f64,
    pub method: &'static str,
    pub iterations: usize,
    pub probes: u64,
    pub ms: f64,
    pub correct: bool,
}

/// Fig. 5: iterations/time of bisection, Brent and CP as one element grows.
pub fn outlier_sweep_fig5(
    runner: &mut Runner,
    n: usize,
    magnitudes: &[f64],
    dtype: DType,
    seed: u64,
) -> Result<Vec<OutlierPoint>> {
    let mut rng = Rng::seeded(seed);
    let base = Distribution::Normal.sample_vec(&mut rng, n);
    let mut out = Vec::new();
    for &mag in magnitudes {
        let mut data = base.clone();
        data[0] = mag;
        let want = crate::stats::sorted_median(&data);
        for (name, m) in [
            ("cutting-plane", Method::CuttingPlane),
            ("bisection", Method::Bisection),
            ("brent-min", Method::BrentMinimize),
            ("brent-root", Method::BrentRoot),
        ] {
            let mut ev = runner.evaluator(&data, dtype)?;
            let t0 = Instant::now();
            let r = select::median(ev.as_mut(), m)?;
            out.push(OutlierPoint {
                magnitude: mag,
                method: name,
                iterations: r.iterations,
                probes: r.probes,
                ms: t0.elapsed().as_secs_f64() * 1e3,
                correct: r.value == want
                    || (dtype == DType::F32 && (r.value - want).abs() <= want.abs() * 1e-6),
            });
        }
    }
    Ok(out)
}

/// One row of the `BENCH_select.json` perf-trajectory artifact
/// (method × n × fused reductions × wall-ms).
#[derive(Debug, Clone)]
pub struct SelectBenchRow {
    pub method: &'static str,
    pub n: usize,
    /// Fused reductions issued — the paper's cost unit (a `probe_many`
    /// ladder counts once on natively batched evaluators).
    pub fused_reductions: u64,
    pub iterations: usize,
    /// Median wall time across the measured repetitions (one warmup run is
    /// discarded; summarized by [`wall::summarize_ms`], so this agrees
    /// exactly with the `bench-wall` path).
    pub wall_ms: f64,
    /// p99 wall time across the same repetitions (the max for the usual
    /// handful of reps — still worth committing: a median that holds while
    /// the p99 drifts is a scheduling story, not a kernel story).
    pub wall_p99_ms: f64,
    pub exact: bool,
}

/// The coordinator-coalescing experiment: the same 8 median queries against
/// one resident dataset, shared-ladder vs sequential.
#[derive(Debug, Clone)]
pub struct CoordinatorBench {
    pub queries: usize,
    pub concurrent_fused_reductions: u64,
    pub sequential_fused_reductions: u64,
}

/// The time-windowed coalescing experiment: N *independent* single-shot
/// `query()` clients (no `query_many`, no shared client-side state) fired
/// concurrently at one dataset must land in one batching window and share
/// ladder rounds.
#[derive(Debug, Clone)]
pub struct WindowBench {
    pub queries: usize,
    /// Batching window the service ran with.
    pub window_us: u64,
    /// Coordinator `coalesced` metric after the burst (≥ `queries` when
    /// the window caught every client).
    pub coalesced: u64,
    /// Total fused reductions the burst cost.
    pub fused_reductions: u64,
}

/// The adaptive-controller experiment: the same 8-client burst as
/// [`WindowBench`], but with the window under the SLA-bounded controller
/// instead of a fixed knob, followed by an idle-decay phase — all on a
/// virtual clock, so both numbers are exact, not statistical.
#[derive(Debug, Clone)]
pub struct AdaptiveWindowBench {
    pub queries: usize,
    /// Controller p99 budget the service ran with.
    pub latency_sla_us: u64,
    /// Coordinator `coalesced` metric after the burst.
    pub coalesced: u64,
    /// Total fused reductions the burst cost (parity target: the fixed
    /// 250 ms `window` row).
    pub fused_reductions: u64,
    /// Controller window gauge right after the burst (must have widened).
    pub window_after_burst_us: u64,
    /// Virtual microseconds of window latency an idle single query paid
    /// once the controller decayed to zero (acceptance: ≤ 1000).
    pub idle_added_window_us: u64,
}

/// The chaos/overload experiment: a Zipf-weighted multi-tenant burst
/// against a single worker held busy by a scripted long run, with one
/// injected backend error, one injected panic and one expiring deadline —
/// all on the virtual clock, so every count below is an exact function of
/// the admission math and the fault script, not of scheduler timing.
#[derive(Debug, Clone)]
pub struct OverloadBench {
    pub tenants: usize,
    /// Burst queries submitted across all tenants (Zipf ~16/t).
    pub submitted: usize,
    /// Queries shed by per-tenant token-bucket admission (burst 3 at
    /// frozen virtual time ⇒ exactly `submitted − 3·tenants`).
    pub shed: u64,
    pub deadline_exceeded: u64,
    pub worker_faults: u64,
    /// Queries that returned a value (admitted − deadline − error − panic).
    pub ok: usize,
    /// Every submitted request resolved — a result or a typed error; no
    /// reply channel hung and the worker survived the injected faults.
    pub all_resolved: bool,
    /// max over tenants of per-tenant p99 completion time divided by the
    /// min — the fair-share acceptance gate (arrival-order execution of
    /// the same burst scores ~2–3× worse).
    pub fairness_ratio: f64,
}

/// The out-of-process cluster experiment: the same 8-client windowed burst
/// as [`WindowBench`], but answered over the cluster message layer — the
/// service's backends are [`crate::cluster::RemoteBackend`]s talking to
/// loopback worker serve loops, so every probe ladder crosses the wire.
/// Acceptance is *parity*: identical answers (bit-exact) and identical
/// fused-reduction count to the in-process window run, because the wire
/// path enters through the same `BackendFactory` seam.
#[derive(Debug, Clone)]
pub struct ClusterBench {
    pub queries: usize,
    /// Remote worker serve loops (and coordinator worker threads, 1:1).
    pub workers: usize,
    /// Wire used for the experiment (`"loopback"` here; the CI smoke job
    /// repeats the scenario over real TCP processes).
    pub transport: &'static str,
    /// Coordinator `coalesced` metric after the burst.
    pub coalesced: u64,
    /// Total fused reductions the burst cost (parity target: the
    /// [`WindowBench`] count on the same data).
    pub fused_reductions: u64,
    /// Every cluster answer was bit-identical to the host-oracle median.
    pub value_parity: bool,
}

#[derive(Debug, Clone)]
pub struct SelectBench {
    pub rows: Vec<SelectBenchRow>,
    pub coordinator: CoordinatorBench,
    pub window: WindowBench,
    pub adaptive: AdaptiveWindowBench,
    pub overload: OverloadBench,
    pub cluster: ClusterBench,
    /// Native fused-ladder width advertised by the benched evaluator
    /// (`None` on the host oracle): the adaptive probes-per-pass the
    /// multisection rows actually ran with on a device backend.
    pub ladder_width_hint: Option<usize>,
    /// Machine the wall-time rows were measured on. Consumers must skip
    /// wall comparisons across differing fingerprints (counts stay
    /// comparable everywhere).
    pub host: wall::HostFingerprint,
    /// Bin-sweep throughput race (vectorized vs scalar kernel), populated
    /// by the `bench-wall` path; `None` from the count-focused
    /// `select_json` bench leg.
    pub bin_sweep: Option<wall::BinSweepBench>,
    /// Measured pass-cost coefficients (the `PassCostModel` measured-seed
    /// path), populated by `bench-wall`; `None` otherwise.
    pub pass_cost: Option<wall::PassCostFit>,
}

/// Probe-based methods tracked by the perf-trajectory bench.
pub fn bench_select_methods() -> Vec<Method> {
    vec![
        Method::CuttingPlane,
        Method::Multisection,
        Method::Bisection,
        Method::Hybrid,
    ]
}

/// Drive the probe-based methods across sizes and the coordinator
/// coalescing experiment; the result serializes to `BENCH_select.json`
/// (see `report::select_bench_json`) so future changes can track the
/// passes/wall trajectory.
///
/// Each (method, n) row runs once untimed (warmup: cache/frequency
/// settling, device executable reuse) and then `reps` timed repetitions;
/// `wall_ms`/`wall_p99_ms` are the [`wall::summarize_ms`] median/p99 of
/// those samples — the same summarization `bench-wall` commits, so
/// harness rows and bench rows agree by construction.
pub fn bench_select(
    runner: &mut Runner,
    log2_sizes: &[u32],
    seed: u64,
    dtype: DType,
    reps: usize,
) -> Result<SelectBench> {
    let mut rng = Rng::seeded(seed);
    let mut rows = Vec::new();
    let mut ladder_width_hint = None;
    for &b in log2_sizes {
        let n = 1usize << b;
        let data = Distribution::Uniform.sample_vec(&mut rng, n);
        let k = crate::util::median_rank(n);
        let want = crate::stats::sorted_order_statistic(&data, k);
        // Warm the executable cache (device backend: XLA compiles lazily)
        // so the first measured method doesn't absorb compile time. The
        // ladder warm-up uses the evaluator's full native width — the
        // bucket multisection actually runs with — so the widest
        // fused_ladder executable is compiled before any timed row.
        {
            let mut ev = runner.evaluator(&data, dtype)?;
            let _ = ev.init_stats();
            let _ = ev.probe(0.5);
            ladder_width_hint = ev.ladder_width_hint();
            let w = ladder_width_hint.unwrap_or(3);
            let rungs: Vec<f64> = (1..=w).map(|i| i as f64 / (w + 1) as f64).collect();
            let _ = ev.probe_many(&rungs);
            let _ = ev.neighbors(0.5);
            let _ = ev.interval(0.2, 0.8);
        }
        for m in bench_select_methods() {
            // warmup rep: not measured, and not the row's count source
            // either (counts are deterministic — every rep agrees)
            {
                let mut ev = runner.evaluator(&data, dtype)?;
                let _ = select::order_statistic(ev.as_mut(), k, m)?;
            }
            let mut samples = Vec::with_capacity(reps.max(1));
            let mut measured = None;
            for _ in 0..reps.max(1) {
                let mut ev = runner.evaluator(&data, dtype)?;
                let t0 = Instant::now();
                let r = select::order_statistic(ev.as_mut(), k, m)?;
                samples.push(t0.elapsed().as_secs_f64() * 1e3);
                measured = Some(r);
            }
            let r = measured.expect("at least one rep");
            let (wall_ms, wall_p99_ms) = wall::summarize_ms(&samples);
            rows.push(SelectBenchRow {
                method: m.name(),
                n,
                fused_reductions: r.probes,
                iterations: r.iterations,
                wall_ms,
                wall_p99_ms,
                exact: r.value == want
                    || (dtype == DType::F32 && (r.value - want).abs() <= want.abs() * 1e-6),
            });
        }
    }

    // Coordinator coalescing: 8 concurrent same-dataset medians must cost
    // strictly fewer total fused reductions than 8 sequential runs.
    let n = 1usize << 14;
    let data = Distribution::Uniform.sample_vec(&mut rng, n);
    let svc = crate::coordinator::SelectionService::start(
        1,
        64,
        Method::Multisection,
        crate::coordinator::HostBackend::factory(),
    )?;
    let id = svc.upload(data.clone(), DType::F64)?;
    let s0 = svc.metrics.snapshot().probes;
    for _ in 0..8 {
        svc.query_with(id, crate::coordinator::KSpec::Median, Method::Multisection)?;
    }
    let sequential = svc.metrics.snapshot().probes - s0;
    let c0 = svc.metrics.snapshot().probes;
    svc.query_many(id, vec![crate::coordinator::KSpec::Median; 8], Method::Multisection)?;
    let concurrent = svc.metrics.snapshot().probes - c0;
    svc.shutdown();

    let window = bench_window_coalescing(&data, 8, 250_000)?;
    let adaptive = bench_adaptive_window(&data, 8, 250_000)?;
    let overload = bench_overload()?;
    let cluster = bench_cluster(&data, 8, 2)?;

    Ok(SelectBench {
        rows,
        coordinator: CoordinatorBench {
            queries: 8,
            concurrent_fused_reductions: concurrent,
            sequential_fused_reductions: sequential,
        },
        window,
        adaptive,
        overload,
        cluster,
        ladder_width_hint,
        host: wall::HostFingerprint::detect(),
        bin_sweep: None,
        pass_cost: None,
    })
}

/// Drive the chaos/overload experiment (see [`OverloadBench`]): six
/// tenants fire a Zipf-weighted burst (~16/t queries each, 41 total) at a
/// one-worker service whose backend is held mid-pass by a scripted
/// [`crate::testkit::Fault::HoldUntil`], in the most adversarial arrival
/// order (all of tenant 1, then tenant 2, …). Admission: token buckets
/// with burst 3 at frozen virtual time admit exactly 3 per tenant and
/// shed the rest with `Error::Overloaded`. While the worker is held, one
/// admitted query's deadline expires, and two others carry scripted
/// faults (an error and a panic). Every count in the result is exact;
/// the fairness ratio measures how evenly fair-share planning spreads
/// completion times across tenants once the plug releases.
pub fn bench_overload() -> Result<OverloadBench> {
    use crate::coordinator::{
        CoordinatorOptions, CostModelPool, KSpec, QueryOptions, SelectionService, ShedPolicy,
        TenantQuota,
    };
    use crate::testkit::{Fault, FaultInjectingBackend, FaultScript};
    use crate::Error;
    use std::time::Duration;

    const TENANTS: usize = 6;
    const ADMIT_BURST: usize = 3;
    const PASS_COST_US: u64 = 500;
    const PLUG_RELEASE_US: u64 = 1_000;
    // generous real-time bound so a hung reply channel fails loudly
    // instead of wedging the bench (virtual-time work is real-time fast)
    const RECV_TIMEOUT: Duration = Duration::from_secs(60);
    let per_tenant: Vec<usize> = (1..=TENANTS).map(|t| 16usize.div_ceil(t)).collect();

    let (clock, vc) = crate::testkit::Clock::manual();
    let script = FaultScript::new(vc.clone(), PASS_COST_US);
    let svc = SelectionService::start_full(
        1,
        64,
        Method::Multisection,
        FaultInjectingBackend::factory(script.clone()),
        CoordinatorOptions {
            batch_cap: 64,
            shed_policy: ShedPolicy::Shed,
            tenant_quota: Some(TenantQuota { rate_per_sec: 1.0, burst: ADMIT_BURST as f64 }),
            ..Default::default()
        },
        clock,
        CostModelPool::seeded(),
    )?;

    // The plug: a query whose first pass parks the worker on the virtual
    // clock, so the whole burst arrives while it is provably busy.
    let mut rng = Rng::seeded(0x0BAD_CAFE);
    let plug = svc.upload(Distribution::Normal.sample_vec(&mut rng, 4096), DType::F64)?;
    script.fault_at(plug, 0, Fault::HoldUntil(PLUG_RELEASE_US));

    // One private dataset per burst query (uploads are control-plane
    // traffic: they bypass admission and block until resident).
    let mut datasets: Vec<Vec<u64>> = Vec::new();
    for &n_q in &per_tenant {
        let mut ids = Vec::new();
        for _ in 0..n_q {
            ids.push(svc.upload(Distribution::Normal.sample_vec(&mut rng, 512), DType::F64)?);
        }
        datasets.push(ids);
    }

    let plug_rx = svc.query_async(plug, KSpec::Median, Method::Multisection)?;
    vc.wait_for_waiters(1); // worker parked inside the plug's held pass

    // Scripted faults on the 3rd admitted query of tenants 2 and 3: a
    // typed backend error and a panic the worker must contain.
    script.fault_at(datasets[1][2], 0, Fault::Error("injected backend error".into()));
    script.fault_at(datasets[2][2], 0, Fault::Panic("injected backend panic".into()));

    // Adversarial arrival order: every tenant-1 query first, then tenant
    // 2, and so on. Admission at frozen time takes the first ADMIT_BURST
    // per tenant and sheds the rest synchronously.
    let mut shed_local = 0u64;
    let mut pending: Vec<(usize, std::sync::mpsc::Receiver<Result<_>>)> = Vec::new();
    for (ti, ids) in datasets.iter().enumerate() {
        let tenant = (ti + 1) as u32;
        for (qi, &id) in ids.iter().enumerate() {
            // tenant 1's 3rd admitted query expires while the plug still
            // holds the worker (release at 1000 + one 500us pass > 1200)
            let deadline =
                (ti == 0 && qi == 2).then_some(Duration::from_micros(1_200));
            let opts = QueryOptions { method: None, tenant, deadline };
            match svc.query_async_opts(id, KSpec::Median, opts) {
                Ok(rx) => pending.push((ti, rx)),
                Err(Error::Overloaded { retry_after_us }) => {
                    if retry_after_us == 0 {
                        return Err(Error::Service("shed without a retry hint".into()));
                    }
                    shed_local += 1;
                }
                Err(e) => return Err(e),
            }
        }
    }
    if pending.len() != TENANTS * ADMIT_BURST {
        return Err(Error::Service(format!(
            "admission admitted {} queries, expected {}",
            pending.len(),
            TENANTS * ADMIT_BURST
        )));
    }

    // Release the plug; the queued burst then executes as one drain batch
    // under fair-share planning, each pass advancing the virtual clock.
    vc.advance_us(PLUG_RELEASE_US);

    let dropped = || Error::Service("overload-bench reply dropped or hung".into());
    let mut ok = 0usize;
    let mut max_done = vec![0u64; TENANTS];
    for (ti, rx) in pending {
        match rx.recv_timeout(RECV_TIMEOUT).map_err(|_| dropped())? {
            Ok(r) => {
                ok += 1;
                max_done[ti] = max_done[ti].max(r.completed_us);
            }
            Err(
                Error::DeadlineExceeded { .. } | Error::Service(_) | Error::Overloaded { .. },
            ) => {}
            Err(e) => return Err(e),
        }
    }
    plug_rx.recv_timeout(RECV_TIMEOUT).map_err(|_| dropped())??;

    // Per-tenant p99 over ≤3 samples is the max completion time; burst
    // submission happened at virtual time 0, so completed_us IS latency.
    let slowest = max_done.iter().copied().max().unwrap_or(0);
    let fastest = max_done.iter().copied().filter(|&v| v > 0).min().unwrap_or(0);
    if fastest == 0 {
        return Err(Error::Service("a tenant finished no queries at all".into()));
    }
    let snap = svc.metrics.snapshot();
    svc.shutdown();
    if snap.shed != shed_local {
        return Err(Error::Service(format!(
            "shed metric {} disagrees with client-side count {shed_local}",
            snap.shed
        )));
    }
    Ok(OverloadBench {
        tenants: TENANTS,
        submitted: per_tenant.iter().sum(),
        shed: snap.shed,
        deadline_exceeded: snap.deadline_exceeded,
        worker_faults: snap.worker_faults,
        ok,
        all_resolved: true, // every recv above returned within the bound
        fairness_ratio: slowest as f64 / fastest as f64,
    })
}

/// Drive the time-windowed coalescing experiment: `clients` independent
/// single-shot `query()` calls against a single-worker service whose fixed
/// batching window is `window_us` of **virtual** time. The clock is never
/// advanced, so the window cannot expire under a scheduler stall — the
/// `batch_cap` (= `clients`) is what closes it, which makes the burst
/// deterministically coalesce on every run. (This replaced a real-time
/// version that needed a retry to absorb pathological scheduler stalls;
/// under virtual time there is nothing to retry.)
fn bench_window_coalescing(data: &[f64], clients: usize, window_us: u64) -> Result<WindowBench> {
    use crate::coordinator::{
        CoordinatorOptions, CostModelPool, HostBackend, KSpec, SelectionService,
    };
    let (clock, _vc) = crate::testkit::Clock::manual();
    let svc = SelectionService::start_full(
        1,
        64,
        Method::Multisection,
        HostBackend::factory(),
        CoordinatorOptions {
            batch_window: std::time::Duration::from_micros(window_us),
            batch_cap: clients,
            ..Default::default()
        },
        clock,
        CostModelPool::seeded(),
    )?;
    let id = svc.upload(data.to_vec(), DType::F64)?;
    let p0 = svc.metrics.snapshot().probes;
    let rxs: Vec<_> = (0..clients)
        .map(|_| svc.query_async(id, KSpec::Median, Method::Multisection))
        .collect::<Result<_>>()?;
    let mut values = Vec::with_capacity(clients);
    for rx in rxs {
        let dropped = || crate::Error::Service("window-bench reply dropped".into());
        values.push(rx.recv().map_err(|_| dropped())??.value);
    }
    if values.iter().any(|&v| v != values[0]) {
        return Err(crate::Error::Service("window-bench clients disagreed".into()));
    }
    let snap = svc.metrics.snapshot();
    let bench = WindowBench {
        queries: clients,
        window_us,
        coalesced: snap.coalesced,
        fused_reductions: snap.probes - p0,
    };
    svc.shutdown();
    Ok(bench)
}

/// Drive the adaptive-controller experiment on a virtual clock: the same
/// 8-client burst as [`bench_window_coalescing`] but with no fixed window
/// at all — the controller's min-window catches the burst (frozen virtual
/// time cannot expire it) and widens; idle singles then decay the window
/// to zero, at which point a lone query pays zero virtual microseconds of
/// window latency.
fn bench_adaptive_window(
    data: &[f64],
    clients: usize,
    latency_sla_us: u64,
) -> Result<AdaptiveWindowBench> {
    use crate::coordinator::{
        AdaptiveWindow, CoordinatorOptions, CostModelPool, HostBackend, KSpec, SelectionService,
    };
    let (clock, vc) = crate::testkit::Clock::manual();
    let svc = SelectionService::start_full(
        1,
        64,
        Method::Multisection,
        HostBackend::factory(),
        CoordinatorOptions {
            batch_window: std::time::Duration::ZERO,
            batch_cap: clients,
            adaptive: Some(AdaptiveWindow {
                latency_sla: std::time::Duration::from_micros(latency_sla_us),
                ..AdaptiveWindow::default()
            }),
            ..Default::default()
        },
        clock,
        CostModelPool::seeded(),
    )?;
    let id = svc.upload(data.to_vec(), DType::F64)?;
    let p0 = svc.metrics.snapshot().probes;
    let rxs: Vec<_> = (0..clients)
        .map(|_| svc.query_async(id, KSpec::Median, Method::Multisection))
        .collect::<Result<_>>()?;
    let dropped = || crate::Error::Service("adaptive-bench reply dropped".into());
    for rx in rxs {
        rx.recv().map_err(|_| dropped())??;
    }
    let snap = svc.metrics.snapshot();
    let coalesced = snap.coalesced;
    let fused_reductions = snap.probes - p0;
    let window_after_burst_us = snap.window_us;

    // idle decay: lone queries shrink the window step by step; each round
    // parks the worker on the current window, which we expire by advancing
    // virtual time
    let mut rounds = 0;
    while svc.metrics.snapshot().window_us > 0 {
        rounds += 1;
        if rounds > 64 {
            return Err(crate::Error::Service("adaptive window failed to decay".into()));
        }
        let w = svc.metrics.snapshot().window_us;
        let rx = svc.query_async(id, KSpec::Median, Method::Multisection)?;
        vc.wait_for_waiters(1);
        vc.advance_us(w + 1);
        rx.recv().map_err(|_| dropped())??;
    }

    // idle single query at a closed window: no park, no advance — the
    // virtual clock measures exactly the added window latency
    let t0 = vc.now_us();
    svc.query(id, KSpec::Median)?;
    let idle_added_window_us = vc.now_us() - t0;
    svc.shutdown();
    Ok(AdaptiveWindowBench {
        queries: clients,
        latency_sla_us,
        coalesced,
        fused_reductions,
        window_after_burst_us,
        idle_added_window_us,
    })
}

/// Drive the cluster-parity experiment (see [`ClusterBench`]): register
/// `workers` loopback serve loops (each a [`crate::cluster::worker::serve`]
/// thread over a local host backend) in a cluster
/// [`Registry`](crate::cluster::coordinator::Registry), start the ordinary
/// service with [`crate::cluster::RemoteBackend`]s as its backends, and
/// replay the [`bench_window_coalescing`] burst: `clients` single-shot
/// medians against one dataset under a frozen virtual clock, so the
/// `batch_cap` closes the window deterministically. Every probe ladder the
/// coalesced plan issues crosses the wire as one `ShardProbe` frame;
/// parity with the in-process run is the acceptance.
fn bench_cluster(data: &[f64], clients: usize, workers: usize) -> Result<ClusterBench> {
    use crate::cluster::coordinator::Registry;
    use crate::cluster::transport::loopback_pair;
    use crate::cluster::{serve, RemoteBackend, ServeExit};
    use crate::coordinator::messages::WireRequest;
    use crate::coordinator::{
        CoordinatorOptions, CostModelPool, HostBackend, KSpec, SelectionService,
    };
    use crate::select::PassCostModel;

    let (clock, _vc) = crate::testkit::Clock::manual();
    let registry = Registry::new();
    let mut serves = Vec::with_capacity(workers);
    for w in 0..workers as u32 {
        let (coord_side, mut worker_side) = loopback_pair(&format!("worker-{w}"), "coordinator");
        let version = registry.register(w, Box::new(coord_side), 0)?;
        let w_clock = clock.clone();
        serves.push(std::thread::spawn(move || {
            // consume the Registered ack `register` already sent
            let _ = worker_side.recv();
            let mut backend = HostBackend::default();
            let mut stats = PassCostModel::seeded();
            serve(&mut worker_side, &mut backend, &mut stats, version, &w_clock)
        }));
    }
    let pool = CostModelPool::seeded();
    let factory = RemoteBackend::factory(
        std::sync::Arc::clone(&registry),
        std::sync::Arc::clone(&pool),
        workers as u32,
        std::time::Duration::from_secs(10),
    );
    let svc = SelectionService::start_full(
        workers,
        64,
        Method::Multisection,
        factory,
        CoordinatorOptions {
            batch_window: std::time::Duration::from_micros(250_000),
            batch_cap: clients,
            ..Default::default()
        },
        clock,
        pool,
    )?;
    let want = crate::stats::sorted_median(data);
    let id = svc.upload(data.to_vec(), DType::F64)?;
    let p0 = svc.metrics.snapshot().probes;
    let rxs: Vec<_> = (0..clients)
        .map(|_| svc.query_async(id, KSpec::Median, Method::Multisection))
        .collect::<Result<_>>()?;
    let mut value_parity = true;
    for rx in rxs {
        let dropped = || crate::Error::Service("cluster-bench reply dropped".into());
        let r = rx.recv().map_err(|_| dropped())??;
        value_parity &= r.value.to_bits() == want.to_bits();
    }
    let snap = svc.metrics.snapshot();
    let bench = ClusterBench {
        queries: clients,
        workers,
        transport: "loopback",
        coalesced: snap.coalesced,
        fused_reductions: snap.probes - p0,
        value_parity,
    };
    // Service shutdown parks every worker connection back in the registry;
    // draining it propagates shutdown to the serve loops (same sequence as
    // `cluster::run_coordinator`).
    svc.shutdown();
    for mut conn in registry.drain_conns() {
        if conn.send(&WireRequest::Shutdown.encode()).is_ok() {
            let _ = conn.recv();
        }
    }
    for h in serves {
        let exit = h
            .join()
            .map_err(|_| crate::Error::Service("cluster-bench serve thread panicked".into()))?;
        if exit != ServeExit::Shutdown {
            return Err(crate::Error::Service(
                "cluster-bench worker exited without a shutdown handshake".into(),
            ));
        }
    }
    Ok(bench)
}

/// §IV ablation: hybrid iteration budget vs |z| and phase times.
#[derive(Debug, Clone)]
pub struct HybridSweepPoint {
    pub cp_iters: usize,
    pub z_len: usize,
    pub cp_ms: f64,
    pub copy_ms: f64,
    pub sort_ms: f64,
    pub total_ms: f64,
}

pub fn hybrid_sweep(
    runner: &mut Runner,
    n: usize,
    budgets: &[usize],
    dtype: DType,
    seed: u64,
) -> Result<Vec<HybridSweepPoint>> {
    let mut rng = Rng::seeded(seed);
    let data = Distribution::Uniform.sample_vec(&mut rng, n);
    let k = crate::util::median_rank(n);
    let want = crate::stats::sorted_order_statistic(&data, k);
    // Warm the executable cache so the first budget point doesn't absorb
    // one-time XLA compilation.
    {
        let mut ev = runner.evaluator(&data, dtype)?;
        ev.init_stats()?;
        ev.probe(0.0)?;
        ev.neighbors(0.0)?;
        ev.interval(0.0, 1.0)?;
    }
    let mut out = Vec::new();
    for &b in budgets {
        let mut ev = runner.evaluator(&data, dtype)?;
        let t0 = Instant::now();
        let r = select::hybrid::hybrid_select(
            ev.as_mut(),
            k,
            &HybridOptions { cp_iters: b, max_fraction: 1.0, max_extra: 0 },
        )?;
        let total_ms = t0.elapsed().as_secs_f64() * 1e3;
        if dtype == DType::F64 {
            assert_eq!(r.value, want, "hybrid_sweep must stay exact");
        }
        out.push(HybridSweepPoint {
            cp_iters: b,
            z_len: r.z_len,
            cp_ms: r.phases.get_ms("cp_iterations"),
            copy_ms: r.phases.get_ms("copy_if"),
            sort_ms: r.phases.get_ms("sort_z"),
            total_ms,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mini_table_runs_on_host() {
        let mut runner = Runner::new(Backend::Host).unwrap();
        let cfg = TableConfig {
            log2_sizes: vec![10, 12],
            instances: 1,
            reps: 1,
            ..Default::default()
        };
        let t = run_table(&mut runner, &cfg).unwrap();
        assert_eq!(t.sizes, vec![1024, 4096]);
        assert_eq!(t.rows.len(), 7); // 6 methods + modeled GPU row
        for row in &t.rows {
            assert!(row.ms.iter().any(|v| v.is_some()), "{} all-none", row.label);
        }
        // hybrid row must carry the paper's three phase sub-rows
        let hybrid = t
            .rows
            .iter()
            .find(|r| r.label.contains("Cutting Plane"))
            .unwrap();
        let labels: Vec<&str> = hybrid.phases.iter().map(|(l, _)| l.as_str()).collect();
        assert!(labels.contains(&"cp_iterations"), "{labels:?}");
    }

    #[test]
    fn bench_select_emits_valid_json_and_coalescing_wins() {
        let mut runner = Runner::new(Backend::Host).unwrap();
        let b = bench_select(&mut runner, &[10, 12], 7, DType::F64, 3).unwrap();
        assert_eq!(b.rows.len(), 8); // 4 methods × 2 sizes
        assert!(b.rows.iter().all(|r| r.exact), "{:?}", b.rows);
        // wall summaries are real medians/p99s of the reps: positive, and
        // the p99 can never sit below the median
        assert!(
            b.rows.iter().all(|r| r.wall_ms > 0.0 && r.wall_p99_ms >= r.wall_ms),
            "{:?}",
            b.rows
        );
        assert!(
            b.coordinator.concurrent_fused_reductions
                < b.coordinator.sequential_fused_reductions,
            "{:?}",
            b.coordinator
        );
        // acceptance: 8 single-shot clients through the batching window
        // coalesce and cost strictly less than 8 solo runs
        assert!(b.window.coalesced >= b.window.queries as u64, "{:?}", b.window);
        assert!(
            b.window.fused_reductions < b.coordinator.sequential_fused_reductions,
            "window burst {:?} vs sequential {}",
            b.window,
            b.coordinator.sequential_fused_reductions
        );
        // acceptance: the adaptive controller matches the fixed window's
        // coalescing (same 8-client burst, same shared-run cost) while an
        // idle query pays no window latency at all
        assert!(b.adaptive.coalesced >= b.adaptive.queries as u64, "{:?}", b.adaptive);
        assert_eq!(
            b.adaptive.fused_reductions,
            b.window.fused_reductions,
            "adaptive burst must match the fixed-window run: {:?}",
            b.adaptive
        );
        assert!(b.adaptive.window_after_burst_us > 0, "{:?}", b.adaptive);
        assert_eq!(b.adaptive.idle_added_window_us, 0, "{:?}", b.adaptive);
        // acceptance: the chaos/overload run resolves every request and its
        // counts are the exact consequences of the scripted admission math
        // (6 tenants × burst 3 admitted out of 41; one deadline, one error,
        // one panic among the admitted)
        assert!(b.overload.all_resolved, "{:?}", b.overload);
        assert_eq!(b.overload.tenants, 6, "{:?}", b.overload);
        assert_eq!(b.overload.submitted, 41, "{:?}", b.overload);
        assert_eq!(b.overload.shed, 23, "{:?}", b.overload);
        assert_eq!(b.overload.deadline_exceeded, 1, "{:?}", b.overload);
        assert_eq!(b.overload.worker_faults, 1, "{:?}", b.overload);
        assert_eq!(b.overload.ok, 15, "{:?}", b.overload);
        assert!(
            b.overload.fairness_ratio >= 1.0 && b.overload.fairness_ratio <= 3.0,
            "fair-share must bound tenant skew: {:?}",
            b.overload
        );
        // acceptance: the cluster path (remote backends over loopback
        // wires) answers the same windowed burst with bit-exact values and
        // the exact fused-reduction count of the in-process run
        assert!(b.cluster.value_parity, "{:?}", b.cluster);
        assert_eq!(b.cluster.workers, 2, "{:?}", b.cluster);
        assert!(b.cluster.coalesced >= b.cluster.queries as u64, "{:?}", b.cluster);
        assert_eq!(
            b.cluster.fused_reductions, b.window.fused_reductions,
            "cluster burst must match the in-process window run: {:?} vs {:?}",
            b.cluster, b.window
        );
        let json = report::select_bench_json(&b, "f64", "host");
        let parsed = crate::util::json::Json::parse(&json).unwrap();
        assert_eq!(parsed.get("schema").unwrap().as_str().unwrap(), "cp-select/bench_select/v2");
        // host oracle has no native ladder-width limit
        assert!(b.ladder_width_hint.is_none());
        assert!(json.contains("\"ladder_width_hint\": null"), "{json}");
        // the host fingerprint block gates like-for-like wall comparison
        let host = parsed.get("host").unwrap();
        assert!(!host.get("cpu").unwrap().as_str().unwrap().is_empty());
        assert!(host.get("logical_cores").unwrap().as_usize().unwrap() >= 1);
        assert!(!host.get("rustc").unwrap().as_str().unwrap().is_empty());
        // bench_select leaves the bench-wall-only blocks null
        assert!(json.contains("\"bin_sweep\": null"), "{json}");
        assert!(json.contains("\"pass_cost\": null"), "{json}");
        assert_eq!(parsed.get("rows").unwrap().as_arr().unwrap().len(), 8);
        let row0 = &parsed.get("rows").unwrap().as_arr().unwrap()[0];
        assert!(row0.get("wall_ms").unwrap().as_f64().unwrap() > 0.0);
        assert!(row0.get("wall_p99_ms").unwrap().as_f64().unwrap() > 0.0);
        let queries = parsed.get("coordinator").unwrap().get("queries").unwrap();
        assert_eq!(queries.as_usize().unwrap(), 8);
        let w = parsed.get("window").unwrap();
        assert_eq!(w.get("queries").unwrap().as_usize().unwrap(), 8);
        assert!(w.get("coalesced").unwrap().as_usize().unwrap() >= 8);
        let a = parsed.get("adaptive_window").unwrap();
        assert_eq!(a.get("queries").unwrap().as_usize().unwrap(), 8);
        assert!(a.get("window_after_burst_us").unwrap().as_usize().unwrap() > 0);
        assert_eq!(a.get("idle_added_window_us").unwrap().as_usize().unwrap(), 0);
        let o = parsed.get("overload").unwrap();
        assert_eq!(o.get("tenants").unwrap().as_usize().unwrap(), 6);
        assert_eq!(o.get("shed").unwrap().as_usize().unwrap(), 23);
        assert!(o.get("fairness_ratio").unwrap().as_f64().unwrap() >= 1.0);
        let cl = parsed.get("cluster").unwrap();
        assert_eq!(cl.get("transport").unwrap().as_str().unwrap(), "loopback");
        assert_eq!(cl.get("queries").unwrap().as_usize().unwrap(), 8);
        assert_eq!(cl.get("workers").unwrap().as_usize().unwrap(), 2);
        assert_eq!(
            cl.get("fused_reductions").unwrap().as_usize().unwrap() as u64,
            b.window.fused_reductions
        );
    }

    #[test]
    fn fig4_trace_is_plausible() {
        let tr = trace_fig4(2048, 42).unwrap();
        assert!(tr.len() >= 3);
        assert!(tr.iter().all(|p| p.y_l <= p.y_r));
    }

    #[test]
    fn fig5_sweep_shows_bisection_growth() {
        let mut runner = Runner::new(Backend::Host).unwrap();
        let pts =
            outlier_sweep_fig5(&mut runner, 4096, &[1e3, 1e9], DType::F64, 7).unwrap();
        assert!(pts.iter().all(|p| p.correct), "{pts:?}");
        let bi: Vec<&OutlierPoint> =
            pts.iter().filter(|p| p.method == "bisection").collect();
        assert!(bi[1].iterations > bi[0].iterations);
        let cp: Vec<&OutlierPoint> =
            pts.iter().filter(|p| p.method == "cutting-plane").collect();
        assert!(cp[1].probes < bi[1].probes as u64 + bi[1].iterations as u64);
    }

    #[test]
    fn hybrid_sweep_z_shrinks_with_budget() {
        let mut runner = Runner::new(Backend::Host).unwrap();
        let pts = hybrid_sweep(&mut runner, 1 << 14, &[2, 5, 9], DType::F64, 9).unwrap();
        assert!(pts[0].z_len >= pts[2].z_len, "{pts:?}");
    }
}
