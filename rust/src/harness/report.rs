//! Rendering: markdown tables (paper layout) and CSV series (Figs 2/3/5).

use std::io::Write;
use std::path::Path;

use super::{HybridSweepPoint, OutlierPoint, SelectBench, Table};
use crate::select::TracePoint;
use crate::{Error, Result};

fn fmt_ms(v: Option<f64>) -> String {
    match v {
        None => "—".to_string(),
        Some(ms) if ms >= 100.0 => format!("{ms:.0}"),
        Some(ms) if ms >= 1.0 => format!("{ms:.2}"),
        Some(ms) => format!("{ms:.3}"),
    }
}

/// Render a [`Table`] as github-flavored markdown in the paper's layout
/// (methods as rows, sizes as columns, phase breakdowns indented).
pub fn table_markdown(t: &Table) -> String {
    let mut s = String::new();
    s.push_str(&format!("### {}\n\n", t.title));
    s.push_str("| Method |");
    for n in &t.sizes {
        s.push_str(&format!(" n=2^{} |", n.trailing_zeros()));
    }
    s.push('\n');
    s.push_str("|---|");
    for _ in &t.sizes {
        s.push_str("---:|");
    }
    s.push('\n');
    for row in &t.rows {
        s.push_str(&format!("| **{}** |", row.label));
        for v in &row.ms {
            s.push_str(&format!(" {} |", fmt_ms(*v)));
        }
        s.push('\n');
        for (phase, vals) in &row.phases {
            s.push_str(&format!("| &nbsp;&nbsp;– {phase} |"));
            for v in vals {
                s.push_str(&format!(" {} |", fmt_ms(*v)));
            }
            s.push('\n');
        }
    }
    s
}

/// CSV series for Figs 2/3: method,n,ms.
pub fn table_csv(t: &Table) -> String {
    let mut s = String::from("method,n,ms\n");
    for row in &t.rows {
        for (n, v) in t.sizes.iter().zip(&row.ms) {
            if let Some(ms) = v {
                s.push_str(&format!("{},{},{:.6}\n", row.label.replace(',', ";"), n, ms));
            }
        }
    }
    s
}

/// CSV for the Fig. 4 trace.
pub fn trace_csv(trace: &[TracePoint]) -> String {
    let mut s = String::from("iter,y,f,g,y_l,y_r,width\n");
    for p in trace {
        s.push_str(&format!(
            "{},{:.17e},{:.17e},{:.17e},{:.17e},{:.17e},{:.17e}\n",
            p.iter,
            p.y,
            p.f,
            p.g,
            p.y_l,
            p.y_r,
            p.y_r - p.y_l
        ));
    }
    s
}

/// CSV for the Fig. 5 sweep.
pub fn outlier_csv(points: &[OutlierPoint]) -> String {
    let mut s = String::from("magnitude,method,iterations,probes,ms,correct\n");
    for p in points {
        s.push_str(&format!(
            "{:.1e},{},{},{},{:.4},{}\n",
            p.magnitude, p.method, p.iterations, p.probes, p.ms, p.correct
        ));
    }
    s
}

/// CSV for the hybrid-budget ablation.
pub fn hybrid_sweep_csv(points: &[HybridSweepPoint]) -> String {
    let mut s = String::from("cp_iters,z_len,cp_ms,copy_ms,sort_ms,total_ms\n");
    for p in points {
        s.push_str(&format!(
            "{},{},{:.4},{:.4},{:.4},{:.4}\n",
            p.cp_iters, p.z_len, p.cp_ms, p.copy_ms, p.sort_ms, p.total_ms
        ));
    }
    s
}

/// Minimal JSON string escape for the hand-rolled writer (fingerprint
/// strings carry arbitrary `/proc/cpuinfo` content).
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Machine-readable `BENCH_select.json` (hand-rolled writer; serde is
/// unavailable offline). Schema `cp-select/bench_select/v2`:
/// method × n × fused reductions × wall-ms (median + p99 of the reps)
/// rows under a `host` fingerprint, plus the coordinator coalescing
/// counts, the cluster-parity block (the windowed burst over loopback
/// wires) and — from the `bench-wall` path — the bin-sweep throughput
/// race and the measured pass-cost coefficients, so future PRs can diff
/// both the count trajectory (hard gate, host-independent) and the
/// wall-clock trajectory (informational, fingerprint-scoped).
pub fn select_bench_json(b: &SelectBench, dtype: &str, backend: &str) -> String {
    let mut s = String::from("{\n");
    s.push_str("  \"schema\": \"cp-select/bench_select/v2\",\n");
    s.push_str(&format!("  \"backend\": \"{backend}\",\n"));
    s.push_str(&format!("  \"dtype\": \"{dtype}\",\n"));
    s.push_str(&format!(
        "  \"host\": {{\"cpu\": {}, \"logical_cores\": {}, \"rustc\": {}}},\n",
        json_str(&b.host.cpu),
        b.host.logical_cores,
        json_str(&b.host.rustc)
    ));
    s.push_str(&format!(
        "  \"ladder_width_hint\": {},\n",
        b.ladder_width_hint.map_or("null".to_string(), |w| w.to_string())
    ));
    s.push_str("  \"rows\": [\n");
    for (i, r) in b.rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"method\": \"{}\", \"n\": {}, \"fused_reductions\": {}, \
             \"iterations\": {}, \"wall_ms\": {:.4}, \"wall_p99_ms\": {:.4}, \
             \"exact\": {}}}{}\n",
            r.method,
            r.n,
            r.fused_reductions,
            r.iterations,
            r.wall_ms,
            r.wall_p99_ms,
            r.exact,
            if i + 1 < b.rows.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n");
    // bench-wall-only blocks: the kernel throughput race and the measured
    // pass-cost seed; null when the count-focused bench leg produced the
    // document.
    match &b.bin_sweep {
        None => s.push_str("  \"bin_sweep\": null,\n"),
        Some(bs) => s.push_str(&format!(
            "  \"bin_sweep\": {{\"n\": {}, \"width\": {}, \"reps\": {}, \
             \"vector_ms\": {:.4}, \"scalar_ms\": {:.4}, \"vector_gbps\": {:.3}, \
             \"scalar_gbps\": {:.3}, \"speedup\": {:.3}}},\n",
            bs.n, bs.width, bs.reps, bs.vector_ms, bs.scalar_ms, bs.vector_gbps,
            bs.scalar_gbps, bs.speedup
        )),
    }
    match &b.pass_cost {
        None => s.push_str("  \"pass_cost\": null,\n"),
        Some(pc) => s.push_str(&format!(
            "  \"pass_cost\": {{\"sweep_s_per_elem\": {:.6e}, \
             \"per_probe_s_per_elem\": {:.6e}}},\n",
            pc.sweep, pc.per_probe
        )),
    }
    // the coordinator + window experiments always run on the host backend
    // (their counts are substrate-independent), whatever the rows were
    // measured on
    s.push_str(&format!(
        "  \"window\": {{\"backend\": \"host\", \"queries\": {}, \"window_us\": {}, \
         \"coalesced\": {}, \"fused_reductions\": {}}},\n",
        b.window.queries,
        b.window.window_us,
        b.window.coalesced,
        b.window.fused_reductions
    ));
    s.push_str(&format!(
        "  \"adaptive_window\": {{\"backend\": \"host\", \"queries\": {}, \
         \"latency_sla_us\": {}, \"coalesced\": {}, \"fused_reductions\": {}, \
         \"window_after_burst_us\": {}, \"idle_added_window_us\": {}}},\n",
        b.adaptive.queries,
        b.adaptive.latency_sla_us,
        b.adaptive.coalesced,
        b.adaptive.fused_reductions,
        b.adaptive.window_after_burst_us,
        b.adaptive.idle_added_window_us
    ));
    s.push_str(&format!(
        "  \"coordinator\": {{\"backend\": \"host\", \"queries\": {}, \
         \"concurrent_fused_reductions\": {}, \
         \"sequential_fused_reductions\": {}}},\n",
        b.coordinator.queries,
        b.coordinator.concurrent_fused_reductions,
        b.coordinator.sequential_fused_reductions
    ));
    // chaos/overload invariants: the counts are exact consequences of the
    // scripted admission math (see `bench_overload`), so the baseline gate
    // compares them by equality; only the fairness ratio is a bound.
    s.push_str(&format!(
        "  \"overload\": {{\"backend\": \"host\", \"tenants\": {}, \"submitted\": {}, \
         \"shed\": {}, \"deadline_exceeded\": {}, \"worker_faults\": {}, \"ok\": {}, \
         \"all_resolved\": {}, \"fairness_ratio\": {:.4}, \"fairness_ratio_bound\": 3.0}},\n",
        b.overload.tenants,
        b.overload.submitted,
        b.overload.shed,
        b.overload.deadline_exceeded,
        b.overload.worker_faults,
        b.overload.ok,
        b.overload.all_resolved,
        b.overload.fairness_ratio
    ));
    // cluster parity: the same windowed burst answered over the cluster
    // message layer (loopback wires) must coalesce identically — value
    // parity is bit-exact, fused parity gates by equality with `window`.
    s.push_str(&format!(
        "  \"cluster\": {{\"backend\": \"host\", \"transport\": \"{}\", \"queries\": {}, \
         \"workers\": {}, \"coalesced\": {}, \"fused_reductions\": {}, \
         \"value_parity\": {}}}\n",
        b.cluster.transport,
        b.cluster.queries,
        b.cluster.workers,
        b.cluster.coalesced,
        b.cluster.fused_reductions,
        b.cluster.value_parity
    ));
    s.push_str("}\n");
    s
}

/// Write a string artifact under `results/`, creating the directory.
pub fn write_result(dir: &Path, name: &str, content: &str) -> Result<std::path::PathBuf> {
    std::fs::create_dir_all(dir).map_err(|e| Error::io(dir.display().to_string(), e))?;
    let path = dir.join(name);
    let mut f = std::fs::File::create(&path)
        .map_err(|e| Error::io(path.display().to_string(), e))?;
    f.write_all(content.as_bytes())
        .map_err(|e| Error::io(path.display().to_string(), e))?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::MethodRow;

    fn sample_table() -> Table {
        Table {
            title: "Test".into(),
            sizes: vec![1024, 4096],
            rows: vec![MethodRow {
                label: "Hybrid".into(),
                ms: vec![Some(1.234), None],
                phases: vec![("cp_iterations".into(), vec![Some(0.5), None])],
            }],
        }
    }

    #[test]
    fn markdown_has_structure() {
        let md = table_markdown(&sample_table());
        assert!(md.contains("| Method |"));
        assert!(md.contains("n=2^10"));
        assert!(md.contains("**Hybrid**"));
        assert!(md.contains("– cp_iterations"));
        assert!(md.contains("—")); // missing cell marker
    }

    #[test]
    fn csv_skips_missing() {
        let csv = table_csv(&sample_table());
        assert_eq!(csv.lines().count(), 2); // header + one data point
        assert!(csv.contains("Hybrid,1024,1.234"));
    }

    #[test]
    fn write_result_creates_dir() {
        let dir = std::env::temp_dir().join(format!("cp_select_test_{}", std::process::id()));
        let p = write_result(&dir, "x.csv", "a,b\n").unwrap();
        assert!(p.exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
