//! In-repo static analysis: invariant lint for the conventions the
//! coordinator's correctness rests on.
//!
//! The crate is offline and dependency-free, so this subsystem ships its
//! own minimal tokenizer ([`tokenizer`]), a shared structural layer
//! ([`callgraph`]: function spans, per-function call sets, a name-keyed
//! cross-file call graph with reachability and a reusable fact-set
//! fixpoint), and the rules themselves ([`rules`]) over `rust/src` and
//! `rust/tests`. It is wired to the `cp-select lint` subcommand (text or
//! `--format json`, see [`report`]) and runs as a blocking CI leg.
//!
//! ## Rules
//!
//! Per-file, lexical:
//!
//! - `clock_discipline` — no `Instant::now`/`SystemTime::now` outside the
//!   wall-clock files (`testkit/clock.rs`, `util/timer.rs`, `main.rs`,
//!   benches, harness) and no `thread::sleep` outside benches. All other
//!   time flows through `testkit::Clock`, which is what keeps the
//!   control plane deterministic under the virtual clock.
//! - `poison_discipline` — every `.lock()` recovers from poisoning with
//!   `unwrap_or_else(|e| e.into_inner())`; `.unwrap()`, `.expect(..)` and
//!   `?` on lock results are findings.
//! - `float_order_discipline` — in `src/select/` and `src/stats/`, float
//!   ordering goes through `total_cmp` or `util::fkey`: `.partial_cmp(`
//!   and raw relational operators inside `sort_by`-family comparator
//!   closures are findings. Raw comparisons outside comparator closures
//!   (convergence checks, NaN-propagating guards) stay legal — IEEE
//!   semantics are load-bearing there.
//! - `error_discipline` — no `.unwrap()`/`.expect(..)`/`panic!`/
//!   `unreachable!` in `src/coordinator/`, `src/runtime/`, `src/select/`,
//!   `src/cluster/` (test modules excluded); worker paths return
//!   `crate::Error`. The escape hatch is a justified suppression pragma
//!   on the site.
//!
//! Cross-file, on the shared call graph:
//!
//! - `panic_boundary` — in `coordinator/dispatch.rs` and
//!   `cluster/worker.rs`, `DatasetBackend` method calls must sit inside a
//!   `catch_unwind` span (directly, or in a function only ever entered
//!   through one), so a panicking backend is contained as a worker fault
//!   instead of killing the worker thread.
//! - `metrics_triple_entry` — every `pub … AtomicU64` counter on
//!   `Metrics` also appears as a `Snapshot` field, is copied in
//!   `Metrics::snapshot()`, and is rendered by `Display for Snapshot`.
//! - `atomic_ordering` — every access to a `Metrics` `AtomicU64` counter
//!   uses `Ordering::Relaxed`; the counters are statistical and nothing
//!   synchronizes through them.
//! - `lock_order` — builds a cross-file lock-order graph from nested
//!   `.lock()` scopes over the named lock fields (helper-routed
//!   acquisitions expanded through [`callgraph::CallGraph::fixpoint_union`])
//!   and fails on cycles; the runtime half of the same invariant is
//!   [`crate::util::sync::OrderedMutex`].
//! - `cancellation_discipline` — every pass loop (a loop issuing fused
//!   reductions) in a function reachable from `order_statistic`/
//!   `solve_group` polls the cooperative cancel hook. Functions named
//!   like the pass primitives (`probe`, `probe_many`, `interval`) are
//!   the pass implementations — their fan-out loops run within one pass
//!   — and single-pass download methods are exempt via a registry that
//!   is itself checked for staleness ([`rules::CANCEL_EXEMPT`]).
//!
//! Call resolution is by bare function name across the scanned set —
//! an over-approximation (no receiver types, no module paths) that errs
//! toward reporting, which for a lint is the safe side.
//!
//! ## Pragmas
//!
//! A finding is suppressed by a plain `//` comment on the same line or
//! the line directly above, of the form `lint: allow(<rule>) — <why>`.
//! The justification is mandatory; a pragma naming an unknown rule or
//! missing its justification is itself a finding (rule `pragma`, not
//! suppressible). Doc comments (`///`, `//!`) are never read as pragmas,
//! which is why this paragraph can spell the syntax out. Suppressed
//! findings are retained on the [`Report`] (and tagged in the JSON
//! output) so the suppression inventory stays auditable.

pub mod callgraph;
pub mod report;
pub mod rules;
pub mod tokenizer;

use std::collections::HashMap;
use std::fmt;
use std::path::{Path, PathBuf};

use callgraph::{CallGraph, FileTokens};
use tokenizer::{tokenize, Token};

/// Every rule the engine knows, in report order. `pragma` covers
/// malformed suppression comments and cannot itself be suppressed.
pub const RULE_NAMES: [&str; 10] = [
    "clock_discipline",
    "poison_discipline",
    "panic_boundary",
    "metrics_triple_entry",
    "lock_order",
    "float_order_discipline",
    "cancellation_discipline",
    "error_discipline",
    "atomic_ordering",
    "pragma",
];

/// One file handed to the linter: a display path plus its full source.
pub struct SourceFile {
    pub path: String,
    pub src: String,
}

/// One lint violation, anchored to a source line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub rule: &'static str,
    pub path: String,
    pub line: u32,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.path, self.line, self.rule, self.message)
    }
}

/// Lint outcome over a file set: surviving findings (sorted by path,
/// line, rule) plus the pragma-suppressed findings, retained so the
/// suppression inventory is auditable (and lands in the JSON output).
pub struct Report {
    pub findings: Vec<Finding>,
    pub files: usize,
    pub suppressed: Vec<Finding>,
}

impl Report {
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Serialize to the stable machine-readable schema
    /// ([`report::SCHEMA_VERSION`]).
    pub fn to_json(&self) -> String {
        report::to_json(self)
    }
}

impl fmt::Display for Report {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for finding in &self.findings {
            writeln!(f, "{finding}")?;
        }
        write!(
            f,
            "lint: {} file(s), {} finding(s), {} suppressed by pragma",
            self.files,
            self.findings.len(),
            self.suppressed.len()
        )
    }
}

struct Pragma {
    rule: String,
    line: u32,
}

/// Read suppression pragmas out of a file's comment tokens. Only plain
/// `//` comments qualify — doc comments may quote the syntax freely.
fn collect_pragmas(file: &SourceFile, toks: &[Token]) -> (Vec<Pragma>, Vec<Finding>) {
    let mut pragmas = Vec::new();
    let mut bad = Vec::new();
    for t in toks {
        if t.kind != tokenizer::TokenKind::LineComment {
            continue;
        }
        let body = &t.text[2..];
        if body.starts_with('/') || body.starts_with('!') {
            continue;
        }
        let Some(at) = body.find("lint:") else { continue };
        let rest = body[at + "lint:".len()..].trim_start();
        let mut fail = |msg: &str| {
            bad.push(Finding {
                rule: "pragma",
                path: file.path.clone(),
                line: t.line,
                message: msg.to_string(),
            });
        };
        let Some(inner) = rest.strip_prefix("allow(") else {
            fail("malformed pragma: expected `lint: allow(<rule>) — <justification>`");
            continue;
        };
        let Some(close) = inner.find(')') else {
            fail("malformed pragma: unclosed allow(...)");
            continue;
        };
        let rule = inner[..close].trim().replace('-', "_");
        if !RULE_NAMES.contains(&rule.as_str()) {
            fail(&format!("pragma names unknown rule `{rule}`"));
            continue;
        }
        let justification = inner[close + 1..].trim_matches(&[' ', '—', '-', ':', '–'][..]);
        if justification.is_empty() {
            fail("pragma needs a justification after allow(...)");
            continue;
        }
        pragmas.push(Pragma { rule, line: t.line });
    }
    (pragmas, bad)
}

/// Run every rule over `files` and fold in pragma suppression.
pub fn lint_files(files: &[SourceFile]) -> Report {
    let streams: Vec<Vec<Token>> = files.iter().map(|f| tokenize(&f.src)).collect();
    let mut findings = Vec::new();
    let mut pragmas_by_path: HashMap<&str, Vec<Pragma>> = HashMap::new();
    for (f, ts) in files.iter().zip(&streams) {
        let (ps, mut bad) = collect_pragmas(f, ts);
        pragmas_by_path.insert(f.path.as_str(), ps);
        findings.append(&mut bad);
    }
    let fts: Vec<FileTokens> = files
        .iter()
        .zip(&streams)
        .map(|(f, ts)| FileTokens {
            file: f,
            code: ts.iter().filter(|t| !t.is_comment()).cloned().collect(),
        })
        .collect();
    let cg = CallGraph::build(&fts);
    for ft in &fts {
        findings.extend(rules::clock_discipline(ft));
        findings.extend(rules::poison_discipline(ft));
        findings.extend(rules::float_order_discipline(ft));
        findings.extend(rules::error_discipline(ft));
    }
    findings.extend(rules::panic_boundary(&fts));
    findings.extend(rules::metrics_triple_entry(&fts));
    findings.extend(rules::atomic_ordering(&fts));
    findings.extend(rules::lock_order(&fts, &cg));
    findings.extend(rules::cancellation_discipline(&fts, &cg));

    let mut kept = Vec::new();
    let mut suppressed = Vec::new();
    for f in findings {
        let covered = f.rule != "pragma"
            && pragmas_by_path.get(f.path.as_str()).is_some_and(|ps| {
                ps.iter().any(|p| p.rule == f.rule && (p.line == f.line || p.line + 1 == f.line))
            });
        if covered {
            suppressed.push(f);
        } else {
            kept.push(f);
        }
    }
    kept.sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
    suppressed.sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
    Report { findings: kept, files: files.len(), suppressed }
}

/// Lint every `.rs` file under `roots` (files or directories; `target`
/// subtrees are skipped). Paths are sorted so reports are deterministic.
pub fn lint_paths(roots: &[PathBuf]) -> crate::Result<Report> {
    let mut paths = Vec::new();
    for r in roots {
        collect_rs(r, &mut paths)?;
    }
    paths.sort();
    paths.dedup();
    let mut files = Vec::new();
    for p in paths {
        let src = std::fs::read_to_string(&p)
            .map_err(|e| crate::Error::io(p.display().to_string(), e))?;
        files.push(SourceFile { path: p.display().to_string(), src });
    }
    Ok(lint_files(&files))
}

fn collect_rs(path: &Path, out: &mut Vec<PathBuf>) -> crate::Result<()> {
    if path.is_file() {
        if path.extension().is_some_and(|e| e == "rs") {
            out.push(path.to_path_buf());
        }
        return Ok(());
    }
    let entries =
        std::fs::read_dir(path).map_err(|e| crate::Error::io(path.display().to_string(), e))?;
    for entry in entries {
        let entry = entry.map_err(|e| crate::Error::io(path.display().to_string(), e))?;
        let p = entry.path();
        if p.is_dir() {
            if p.file_name().is_some_and(|n| n == "target") {
                continue;
            }
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}
