//! The five invariant rules. Every rule is a lexical token-sequence
//! analysis over the [`crate::analysis::tokenizer`] stream — no parse
//! tree, just patterns plus balanced-delimiter spans. See the module docs
//! in [`crate::analysis`] for what each rule enforces and why, and for
//! the known approximations (one-level call expansion, lexical guard
//! scopes).

use std::collections::{BTreeSet, HashMap, HashSet};

use super::tokenizer::{Token, TokenKind};
use super::{Finding, SourceFile};

/// One scanned file with its comment-stripped token stream (rules never
/// match inside comments; the pragma engine reads them separately).
pub(crate) struct FileTokens<'a> {
    pub file: &'a SourceFile,
    pub code: Vec<Token>,
}

fn norm(path: &str) -> String {
    path.replace('\\', "/")
}

fn file_stem(path: &str) -> String {
    let p = norm(path);
    let base = p.rsplit('/').next().unwrap_or(&p);
    base.strip_suffix(".rs").unwrap_or(base).to_string()
}

fn mk(rule: &'static str, file: &SourceFile, line: u32, message: String) -> Finding {
    Finding { rule, path: file.path.clone(), line, message }
}

/// Index of the matching `}` for the `{` at `open` (end of stream if
/// unbalanced — strings/comments are already opaque single tokens).
fn match_brace(code: &[Token], open: usize) -> usize {
    let mut depth = 0usize;
    for (k, t) in code.iter().enumerate().skip(open) {
        if t.is_punct('{') {
            depth += 1;
        } else if t.is_punct('}') {
            depth = depth.saturating_sub(1);
            if depth == 0 {
                return k;
            }
        }
    }
    code.len().saturating_sub(1)
}

/// Index of the matching `)` for the `(` at `open`.
fn match_paren(code: &[Token], open: usize) -> usize {
    let mut depth = 0usize;
    for (k, t) in code.iter().enumerate().skip(open) {
        if t.is_punct('(') {
            depth += 1;
        } else if t.is_punct(')') {
            depth = depth.saturating_sub(1);
            if depth == 0 {
                return k;
            }
        }
    }
    code.len().saturating_sub(1)
}

pub(crate) struct FnSpan {
    pub name: String,
    /// Token range of the body `{ … }` inclusive; `None` for bodyless
    /// trait-method declarations.
    pub body: Option<(usize, usize)>,
}

/// Every `fn name …` in the stream, nested functions included (their
/// spans overlap; innermost wins for enclosing-fn lookup).
pub(crate) fn fn_spans(code: &[Token]) -> Vec<FnSpan> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < code.len() {
        let heads_fn = code[i].is_ident("fn")
            && code.get(i + 1).is_some_and(|t| t.kind == TokenKind::Ident);
        if !heads_fn {
            i += 1;
            continue;
        }
        let name = code[i + 1].text.clone();
        let mut j = i + 2;
        let mut depth = 0usize; // () and [] nesting inside the signature
        let mut body = None;
        while j < code.len() {
            let t = &code[j];
            if t.is_punct('(') || t.is_punct('[') {
                depth += 1;
            } else if t.is_punct(')') || t.is_punct(']') {
                depth = depth.saturating_sub(1);
            } else if depth == 0 && t.is_punct('{') {
                body = Some((j, match_brace(code, j)));
                break;
            } else if depth == 0 && t.is_punct(';') {
                break;
            }
            j += 1;
        }
        out.push(FnSpan { name, body });
        i += 2;
    }
    out
}

fn enclosing_fn<'a>(spans: &'a [FnSpan], idx: usize) -> Option<&'a FnSpan> {
    spans
        .iter()
        .filter(|s| s.body.is_some_and(|(b0, b1)| idx >= b0 && idx <= b1))
        .max_by_key(|s| s.body.map(|(b0, _)| b0))
}

// ---------------------------------------------------------------------------
// clock_discipline

/// Files whose *job* is reading the wall clock: the real half of
/// `testkit::Clock`, the phase-timer instruments, the CLI front end, and
/// the bench/harness wall-timing sites.
fn wall_clock_allowed(path: &str) -> bool {
    let p = norm(path);
    p.ends_with("testkit/clock.rs")
        || p.ends_with("util/timer.rs")
        || p.ends_with("main.rs")
        || p.contains("benches/")
        || p.contains("harness/")
}

/// No `Instant::now` / `SystemTime::now` outside the wall-clock files,
/// and no `thread::sleep` anywhere but benches: coordinator and select
/// code must take time from the service [`crate::testkit::Clock`] so the
/// control plane stays deterministic under the virtual clock.
pub(crate) fn clock_discipline(ft: &FileTokens) -> Vec<Finding> {
    let mut out = Vec::new();
    let code = &ft.code;
    let allowed = wall_clock_allowed(&ft.file.path);
    let benches = norm(&ft.file.path).contains("benches/");
    for (i, t) in code.iter().enumerate() {
        if t.kind != TokenKind::Ident {
            continue;
        }
        let calls = |a: &str, b: &str| {
            t.is_ident(a)
                && code.get(i + 1).is_some_and(|x| x.is_punct(':'))
                && code.get(i + 2).is_some_and(|x| x.is_punct(':'))
                && code.get(i + 3).is_some_and(|x| x.is_ident(b))
        };
        if !allowed && (calls("Instant", "now") || calls("SystemTime", "now")) {
            out.push(mk(
                "clock_discipline",
                ft.file,
                t.line,
                format!(
                    "{}::now() bypasses testkit::Clock; read the service clock instead",
                    t.text
                ),
            ));
        } else if !benches && calls("thread", "sleep") {
            out.push(mk(
                "clock_discipline",
                ft.file,
                t.line,
                "thread::sleep waits in wall time; park on the virtual clock instead".to_string(),
            ));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// poison_discipline

/// Every `.lock()` on a poisonable mutex must recover the guard with
/// `unwrap_or_else(|e| e.into_inner())` — the repo-wide idiom — rather
/// than `.unwrap()`/`.expect()` (panic amplification: one poisoned lock
/// cascades through every thread that touches it) or `?` (propagates a
/// non-actionable error). A bare `.lock()` whose result is not consumed
/// inline is fine: that is `util::sync::OrderedMutex` or a helper whose
/// body is checked where it lives.
pub(crate) fn poison_discipline(ft: &FileTokens) -> Vec<Finding> {
    let mut out = Vec::new();
    let code = &ft.code;
    for i in 0..code.len() {
        let is_lock_call = code[i].is_punct('.')
            && code.get(i + 1).is_some_and(|t| t.is_ident("lock"))
            && code.get(i + 2).is_some_and(|t| t.is_punct('('))
            && code.get(i + 3).is_some_and(|t| t.is_punct(')'));
        if !is_lock_call {
            continue;
        }
        let line = code[i + 1].line;
        let after = &code[i + 4..];
        if after.first().is_some_and(|t| t.is_punct('?')) {
            out.push(mk(
                "poison_discipline",
                ft.file,
                line,
                ".lock()? propagates poison; recover with unwrap_or_else(|e| e.into_inner())"
                    .to_string(),
            ));
            continue;
        }
        if !after.first().is_some_and(|t| t.is_punct('.')) {
            continue;
        }
        let Some(m) = after.get(1) else { continue };
        if m.is_ident("unwrap") || m.is_ident("expect") {
            out.push(mk(
                "poison_discipline",
                ft.file,
                line,
                format!(
                    ".lock().{}() panics on poison; recover with unwrap_or_else(|e| e.into_inner())",
                    m.text
                ),
            ));
        } else if m.is_ident("unwrap_or_else")
            && !after.iter().take(16).any(|t| t.is_ident("into_inner"))
        {
            out.push(mk(
                "poison_discipline",
                ft.file,
                line,
                ".lock().unwrap_or_else(..) must recover the guard via e.into_inner()".to_string(),
            ));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// panic_boundary

fn backend_trait_methods(files: &[FileTokens]) -> HashSet<String> {
    let mut methods = HashSet::new();
    for ft in files {
        let code = &ft.code;
        for i in 0..code.len() {
            if code[i].is_ident("trait")
                && code.get(i + 1).is_some_and(|t| t.is_ident("DatasetBackend"))
            {
                let Some(open) = (i + 2..code.len()).find(|&j| code[j].is_punct('{')) else {
                    continue;
                };
                let end = match_brace(code, open);
                for k in open..end {
                    if code[k].is_ident("fn") {
                        if let Some(name) = code.get(k + 1) {
                            methods.insert(name.text.clone());
                        }
                    }
                }
            }
        }
    }
    methods
}

fn cfg_test_start(code: &[Token]) -> usize {
    for i in 0..code.len() {
        if code[i].is_punct('#')
            && code.get(i + 1).is_some_and(|t| t.is_punct('['))
            && code.get(i + 2).is_some_and(|t| t.is_ident("cfg"))
            && code.get(i + 3).is_some_and(|t| t.is_punct('('))
            && code.get(i + 4).is_some_and(|t| t.is_ident("test"))
        {
            return i;
        }
    }
    code.len()
}

fn in_region(regions: &[(usize, usize)], idx: usize) -> bool {
    regions.iter().any(|&(a, b)| idx > a && idx < b)
}

/// In the coordinator worker paths (`coordinator/service.rs`, test module
/// excluded), every `backend.<DatasetBackend method>(…)` call must be
/// lexically inside a `catch_unwind(…)` span — or inside a function whose
/// every call site in the file is (`solve_group`/`run_query`, which are
/// only ever entered through the fault-isolation boundary). The method
/// set is read from the `DatasetBackend` trait declaration itself, and
/// the receiver-name convention (`backend`) is the file's own.
pub(crate) fn panic_boundary(files: &[FileTokens]) -> Vec<Finding> {
    let methods = backend_trait_methods(files);
    if methods.is_empty() {
        return Vec::new();
    }
    let mut out = Vec::new();
    for ft in files {
        if !norm(&ft.file.path).ends_with("coordinator/service.rs") {
            continue;
        }
        let limit = cfg_test_start(&ft.code);
        let code = &ft.code[..limit];
        let regions: Vec<(usize, usize)> = (0..code.len())
            .filter(|&i| {
                code[i].is_ident("catch_unwind") && code.get(i + 1).is_some_and(|t| t.is_punct('('))
            })
            .map(|i| (i, match_paren(code, i + 1)))
            .collect();
        let spans = fn_spans(code);
        let mut protected: HashSet<&str> = HashSet::new();
        for s in &spans {
            let mut sites = 0usize;
            let mut covered = true;
            for i in 0..code.len() {
                let own_body = s.body.is_some_and(|(b0, b1)| i >= b0 && i <= b1);
                if code[i].is_ident(&s.name)
                    && code.get(i + 1).is_some_and(|t| t.is_punct('('))
                    && (i == 0 || !code[i - 1].is_ident("fn"))
                    && !own_body
                {
                    sites += 1;
                    covered &= in_region(&regions, i);
                }
            }
            if sites > 0 && covered {
                protected.insert(s.name.as_str());
            }
        }
        for i in 0..code.len() {
            let method = match code.get(i + 2) {
                Some(t) if t.kind == TokenKind::Ident => &t.text,
                _ => continue,
            };
            let is_backend_call = code[i].is_ident("backend")
                && code.get(i + 1).is_some_and(|t| t.is_punct('.'))
                && methods.contains(method);
            if !is_backend_call || in_region(&regions, i) {
                continue;
            }
            if enclosing_fn(&spans, i).is_some_and(|s| protected.contains(s.name.as_str())) {
                continue;
            }
            out.push(mk(
                "panic_boundary",
                ft.file,
                code[i + 2].line,
                format!(
                    "DatasetBackend::{method} runs outside catch_unwind; \
                     a backend panic here kills the worker"
                ),
            ));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// metrics_triple_entry

struct Field {
    name: String,
    ty: String,
    public: bool,
    line: u32,
}

fn struct_fields(code: &[Token], name: &str) -> Option<Vec<Field>> {
    for i in 0..code.len() {
        if !(code[i].is_ident("struct") && code.get(i + 1).is_some_and(|t| t.is_ident(name))) {
            continue;
        }
        let mut j = i + 2;
        while j < code.len() && !code[j].is_punct('{') {
            if code[j].is_punct(';') {
                return Some(Vec::new());
            }
            j += 1;
        }
        let end = match_brace(code, j);
        let mut fields = Vec::new();
        for k in j + 1..end {
            let is_field = code[k].kind == TokenKind::Ident
                && code.get(k + 1).is_some_and(|t| t.is_punct(':'))
                && !code.get(k + 2).is_some_and(|t| t.is_punct(':'))
                && !code[k - 1].is_punct(':');
            if is_field {
                fields.push(Field {
                    name: code[k].text.clone(),
                    ty: code.get(k + 2).map(|t| t.text.clone()).unwrap_or_default(),
                    public: code[k - 1].is_ident("pub"),
                    line: code[k].line,
                });
            }
        }
        return Some(fields);
    }
    None
}

fn display_impl_span(code: &[Token], for_name: &str) -> Option<(usize, usize)> {
    for i in 0..code.len() {
        if code[i].is_ident("Display")
            && code.get(i + 1).is_some_and(|t| t.is_ident("for"))
            && code.get(i + 2).is_some_and(|t| t.is_ident(for_name))
        {
            let open = (i + 3..code.len()).find(|&j| code[j].is_punct('{'))?;
            return Some((open, match_brace(code, open)));
        }
    }
    None
}

fn span_has_field_init(code: &[Token], span: (usize, usize), name: &str) -> bool {
    (span.0..=span.1).any(|k| {
        code[k].is_ident(name)
            && code.get(k + 1).is_some_and(|t| t.is_punct(':'))
            && !code.get(k + 2).is_some_and(|t| t.is_punct(':'))
    })
}

fn span_has_self_field(code: &[Token], span: (usize, usize), name: &str) -> bool {
    (span.0..=span.1).any(|k| {
        code[k].is_ident("self")
            && code.get(k + 1).is_some_and(|t| t.is_punct('.'))
            && code.get(k + 2).is_some_and(|t| t.is_ident(name))
    })
}

/// Every `pub … : AtomicU64` counter declared on `Metrics`
/// (`coordinator/metrics.rs`) must appear three more times, all
/// maintained by hand today: as a `Snapshot` field, copied in
/// `Metrics::snapshot()`, and rendered in `Display for Snapshot`. A
/// counter that misses any leg silently vanishes from observability.
pub(crate) fn metrics_triple_entry(files: &[FileTokens]) -> Vec<Finding> {
    let mut out = Vec::new();
    for ft in files {
        if !norm(&ft.file.path).ends_with("coordinator/metrics.rs") {
            continue;
        }
        let code = &ft.code;
        let Some(metrics_fields) = struct_fields(code, "Metrics") else { continue };
        let counters: Vec<&Field> =
            metrics_fields.iter().filter(|f| f.public && f.ty == "AtomicU64").collect();
        let snap_fields = struct_fields(code, "Snapshot");
        let snap_body =
            fn_spans(code).into_iter().find(|s| s.name == "snapshot").and_then(|s| s.body);
        let display = display_impl_span(code, "Snapshot");
        let (Some(snap_fields), Some(snap_body), Some(display)) = (snap_fields, snap_body, display)
        else {
            out.push(mk(
                "metrics_triple_entry",
                ft.file,
                1,
                "expected struct Snapshot, fn snapshot() and a Display impl alongside Metrics"
                    .to_string(),
            ));
            continue;
        };
        for c in counters {
            if !snap_fields.iter().any(|f| f.name == c.name) {
                out.push(mk(
                    "metrics_triple_entry",
                    ft.file,
                    c.line,
                    format!("Metrics counter `{}` has no matching Snapshot field", c.name),
                ));
            }
            if !span_has_field_init(code, snap_body, &c.name) {
                out.push(mk(
                    "metrics_triple_entry",
                    ft.file,
                    c.line,
                    format!("Metrics counter `{}` is not copied in Metrics::snapshot()", c.name),
                ));
            }
            if !span_has_self_field(code, display, &c.name) {
                out.push(mk(
                    "metrics_triple_entry",
                    ft.file,
                    c.line,
                    format!("Metrics counter `{}` has no Display arm on Snapshot", c.name),
                ));
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// lock_order

#[derive(Clone)]
struct Held {
    node: usize,
    depth: usize,
    var: Option<String>,
    temp: bool,
}

struct FnScan {
    file: usize,
    name: String,
    body: (usize, usize),
}

/// Cross-file lock-order graph over the named lock fields (`name:
/// Mutex<…>` / `name: OrderedMutex<…>` declarations; nodes are
/// `<file stem>.<field>`). Within every function body, a resolved
/// `receiver.lock()` acquisition draws an edge from each lock still
/// lexically held (let-bound guards live to their block or `drop(var)`;
/// temporaries to the end of the statement) to the acquired one; calls to
/// named local functions are expanded through a name-keyed
/// direct-lock-set fixpoint so helper-routed acquisitions still
/// contribute edges. Any cycle in the resulting graph is a finding: two
/// code paths that disagree about acquisition order are a deadlock
/// waiting for a schedule.
pub(crate) fn lock_order(files: &[FileTokens]) -> Vec<Finding> {
    // Pass 0: discover lock-field nodes.
    let mut nodes: Vec<String> = Vec::new();
    let mut per_file: Vec<HashMap<String, usize>> = Vec::new();
    let mut global: HashMap<String, Vec<usize>> = HashMap::new();
    for ft in files {
        let stem = file_stem(&ft.file.path);
        let code = &ft.code;
        let mut map = HashMap::new();
        for i in 0..code.len() {
            let is_decl = code[i].kind == TokenKind::Ident
                && code.get(i + 1).is_some_and(|t| t.is_punct(':'))
                && code
                    .get(i + 2)
                    .is_some_and(|t| t.is_ident("Mutex") || t.is_ident("OrderedMutex"))
                && code.get(i + 3).is_some_and(|t| t.is_punct('<'))
                && (i == 0 || !code[i - 1].is_punct(':'));
            if !is_decl {
                continue;
            }
            let field = code[i].text.clone();
            let name = format!("{stem}.{field}");
            let node = match nodes.iter().position(|n| *n == name) {
                Some(p) => p,
                None => {
                    nodes.push(name);
                    nodes.len() - 1
                }
            };
            map.insert(field.clone(), node);
            global.entry(field).or_default().push(node);
        }
        per_file.push(map);
    }
    if nodes.is_empty() {
        return Vec::new();
    }

    // Resolve `receiver.lock()` at the `.` token `i`; empty = unresolved.
    let resolve = |fidx: usize, code: &[Token], i: usize| -> Vec<usize> {
        if i == 0 {
            return Vec::new();
        }
        let recv = &code[i - 1];
        if recv.kind != TokenKind::Ident {
            return Vec::new();
        }
        if let Some(&n) = per_file[fidx].get(&recv.text) {
            return vec![n];
        }
        global.get(&recv.text).cloned().unwrap_or_default()
    };

    let is_lock_call = |code: &[Token], i: usize| {
        code[i].is_punct('.')
            && code.get(i + 1).is_some_and(|t| t.is_ident("lock"))
            && code.get(i + 2).is_some_and(|t| t.is_punct('('))
            && code.get(i + 3).is_some_and(|t| t.is_punct(')'))
    };

    // Pass A: per-function direct lock sets, then a name-keyed fixpoint
    // through calls (a helper that locks makes its callers lock too).
    let mut fns: Vec<FnScan> = Vec::new();
    for (fidx, ft) in files.iter().enumerate() {
        for s in fn_spans(&ft.code) {
            if let Some(body) = s.body {
                fns.push(FnScan { file: fidx, name: s.name, body });
            }
        }
    }
    let mut locks_by_name: HashMap<String, BTreeSet<usize>> = HashMap::new();
    let mut calls_by_fn: Vec<Vec<String>> = Vec::new();
    for f in &fns {
        let code = &files[f.file].code;
        let mut direct = BTreeSet::new();
        let mut calls = Vec::new();
        for i in f.body.0..=f.body.1 {
            if is_lock_call(code, i) && !resolve(f.file, code, i).is_empty() {
                direct.extend(resolve(f.file, code, i));
            } else if code[i].kind == TokenKind::Ident
                && code.get(i + 1).is_some_and(|t| t.is_punct('('))
                && !code[i - 1].is_ident("fn")
            {
                calls.push(code[i].text.clone());
            }
        }
        locks_by_name.entry(f.name.clone()).or_default().extend(direct);
        calls_by_fn.push(calls);
    }
    for _ in 0..12 {
        let mut changed = false;
        for (f, calls) in fns.iter().zip(&calls_by_fn) {
            let mut add = BTreeSet::new();
            for callee in calls {
                if let Some(set) = locks_by_name.get(callee) {
                    add.extend(set.iter().copied());
                }
            }
            let mine = locks_by_name.entry(f.name.clone()).or_default();
            let before = mine.len();
            mine.extend(add);
            changed |= mine.len() != before;
        }
        if !changed {
            break;
        }
    }

    // Pass B: held-scope walk per function, drawing held → acquired edges.
    let mut edges: HashMap<(usize, usize), (String, u32)> = HashMap::new();
    for f in &fns {
        let code = &files[f.file].code;
        let mut held: Vec<Held> = Vec::new();
        let mut depth = 0usize;
        let mut edge = |held: &[Held], to: usize, line: u32, edges: &mut HashMap<_, _>| {
            for h in held {
                if h.node != to {
                    edges
                        .entry((h.node, to))
                        .or_insert_with(|| (files[f.file].file.path.clone(), line));
                }
            }
        };
        let mut i = f.body.0;
        while i <= f.body.1 {
            let t = &code[i];
            if t.is_punct('{') {
                depth += 1;
            } else if t.is_punct('}') {
                depth = depth.saturating_sub(1);
                held.retain(|h| h.depth <= depth);
            } else if t.is_punct(';') {
                held.retain(|h| !h.temp);
            } else if is_lock_call(code, i) {
                let targets = resolve(f.file, code, i);
                if targets.is_empty() {
                    // unresolved receiver (`self.lock()` helpers): treat
                    // as a call named `lock`, expanded below via i+1
                } else {
                    for &n in &targets {
                        edge(&held, n, code[i + 1].line, &mut edges);
                    }
                    let (let_bound, var) = statement_binding(code, f.body.0, i);
                    for &n in &targets {
                        held.push(Held { node: n, depth, var: var.clone(), temp: !let_bound });
                    }
                    i += 4;
                    continue;
                }
            } else if t.is_ident("drop")
                && code.get(i + 1).is_some_and(|x| x.is_punct('('))
                && code.get(i + 3).is_some_and(|x| x.is_punct(')'))
            {
                if let Some(v) = code.get(i + 2) {
                    held.retain(|h| h.var.as_deref() != Some(v.text.as_str()));
                }
            }
            // Call expansion (includes unresolved `.lock()` by name).
            if !held.is_empty()
                && t.kind == TokenKind::Ident
                && code.get(i + 1).is_some_and(|x| x.is_punct('('))
                && (i == 0 || !code[i - 1].is_ident("fn"))
            {
                let resolved_recv =
                    i > 0 && is_lock_call(code, i - 1) && !resolve(f.file, code, i - 1).is_empty();
                if !resolved_recv {
                    if let Some(set) = locks_by_name.get(&t.text) {
                        for &n in set {
                            edge(&held, n, t.line, &mut edges);
                        }
                    }
                }
            }
            i += 1;
        }
    }

    // Cycle detection: one finding per nontrivial strongly-connected
    // component, anchored at the lexically-last edge inside it.
    let mut adj = vec![Vec::new(); nodes.len()];
    for &(a, b) in edges.keys() {
        adj[a].push(b);
    }
    let mut out = Vec::new();
    for scc in tarjan_sccs(&adj) {
        if scc.len() < 2 {
            continue;
        }
        let in_scc: HashSet<usize> = scc.iter().copied().collect();
        let mut names: Vec<&str> =
            scc.iter().map(|&n| nodes[n].as_str()).collect::<Vec<_>>();
        names.sort_unstable();
        let site = edges
            .iter()
            .filter(|((a, b), _)| in_scc.contains(a) && in_scc.contains(b))
            .map(|(_, site)| site)
            .max_by(|a, b| (a.0.as_str(), a.1).cmp(&(b.0.as_str(), b.1)));
        let Some((path, line)) = site else { continue };
        out.push(Finding {
            rule: "lock_order",
            path: path.clone(),
            line: *line,
            message: format!(
                "lock-order cycle among {{{}}}: acquisition order must be globally consistent \
                 (see the rank table in util::sync)",
                names.join(", ")
            ),
        });
    }
    out
}

/// Is the statement containing token `at` a `let` binding, and to which
/// variable? Scans back to the nearest statement boundary.
fn statement_binding(code: &[Token], lo: usize, at: usize) -> (bool, Option<String>) {
    let mut k = at;
    while k > lo {
        k -= 1;
        let t = &code[k];
        if t.is_punct(';') || t.is_punct('{') || t.is_punct('}') {
            return (false, None);
        }
        if t.is_ident("let") {
            let mut v = k + 1;
            if code.get(v).is_some_and(|t| t.is_ident("mut")) {
                v += 1;
            }
            let var = code.get(v).filter(|t| t.kind == TokenKind::Ident).map(|t| t.text.clone());
            return (true, var);
        }
    }
    (false, None)
}

fn tarjan_sccs(adj: &[Vec<usize>]) -> Vec<Vec<usize>> {
    struct State<'a> {
        adj: &'a [Vec<usize>],
        index: Vec<Option<u32>>,
        low: Vec<u32>,
        on_stack: Vec<bool>,
        stack: Vec<usize>,
        next: u32,
        out: Vec<Vec<usize>>,
    }
    fn go(st: &mut State, v: usize) {
        st.index[v] = Some(st.next);
        st.low[v] = st.next;
        st.next += 1;
        st.stack.push(v);
        st.on_stack[v] = true;
        let neighbors = st.adj[v].clone();
        for w in neighbors {
            if st.index[w].is_none() {
                go(st, w);
                st.low[v] = st.low[v].min(st.low[w]);
            } else if st.on_stack[w] {
                st.low[v] = st.low[v].min(st.index[w].unwrap_or(0));
            }
        }
        if Some(st.low[v]) == st.index[v] {
            let mut scc = Vec::new();
            while let Some(w) = st.stack.pop() {
                st.on_stack[w] = false;
                scc.push(w);
                if w == v {
                    break;
                }
            }
            st.out.push(scc);
        }
    }
    let n = adj.len();
    let mut st = State {
        adj,
        index: vec![None; n],
        low: vec![0; n],
        on_stack: vec![false; n],
        stack: Vec::new(),
        next: 0,
        out: Vec::new(),
    };
    for v in 0..n {
        if st.index[v].is_none() {
            go(&mut st, v);
        }
    }
    st.out
}
